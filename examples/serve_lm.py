"""Batched serving example: continuous-batching decode over a shared cache.

    PYTHONPATH=src python examples/serve_lm.py --arch falcon_mamba_7b
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_reduced
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_3_8b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    args = ap.parse_args()

    arch = get_reduced(args.arch)
    model = build_model(arch)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, batch_slots=args.slots, max_seq=64)

    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, arch.vocab, size=4).astype(np.int32),
                    max_new_tokens=8) for i in range(args.requests)]
    for r in reqs:
        engine.submit(r)

    t0 = time.perf_counter()
    ticks = 0
    while (engine.queue or any(engine.active)) and ticks < 200:
        engine.step()
        ticks += 1
    wall = time.perf_counter() - t0
    done = sum(r.done for r in reqs)
    toks = sum(len(r.out_tokens) for r in reqs)
    print(f"served {done}/{len(reqs)} requests, {toks} tokens in {wall:.2f}s "
          f"({toks / wall:.1f} tok/s, {args.slots} slots, "
          f"continuous batching)")
    for r in reqs[:3]:
        print(f"  req {r.uid}: prompt={r.prompt.tolist()} -> {r.out_tokens}")


if __name__ == "__main__":
    main()

"""Continuous-batching serving example, end-to-end on CPU.

Drives the real engine (`repro.serve.engine.ServeEngine`): chunked PARALLEL
prefill on admission (the DEER/associative-scan paths — no token-by-token
prompt loop), one batched decode tick per generated token across all slots,
streaming callbacks, and slot recycling (continuous batching).

    PYTHONPATH=src python examples/serve_lm.py --arch falcon_mamba_7b
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_reduced
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="falcon_mamba_7b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--prefill-chunk", type=int, default=8)
    args = ap.parse_args()

    arch = get_reduced(args.arch)
    model = build_model(arch)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, batch_slots=args.slots, max_seq=64,
                         prefill_chunk=args.prefill_chunk)

    streamed = []
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, arch.vocab,
                                        size=args.prompt_len)
                    .astype(np.int32),
                    max_new_tokens=args.max_new,
                    on_token=lambda uid, tok, done:
                        streamed.append((uid, tok, done)))
            for i in range(args.requests)]
    for r in reqs:
        engine.submit(r)

    t0 = time.perf_counter()
    engine.run_until_drained()
    wall = time.perf_counter() - t0

    done = sum(r.done for r in reqs)
    toks = sum(len(r.out_tokens) for r in reqs)
    lat = engine.latency_percentiles()
    print(f"served {done}/{len(reqs)} requests, {toks} tokens in {wall:.2f}s "
          f"({toks / wall:.1f} tok/s, {args.slots} slots, "
          f"continuous batching, {len(streamed)} streamed callbacks)")
    print(f"per-token decode latency: "
          f"p50={lat.get('decode_p50_s', 0)*1e3:.2f}ms "
          f"p99={lat.get('decode_p99_s', 0)*1e3:.2f}ms")
    for r in reqs[:3]:
        print(f"  req {r.uid}: prompt[:4]={r.prompt[:4].tolist()}... "
              f"-> {r.out_tokens}")


if __name__ == "__main__":
    main()

"""Quickstart: train the paper's LrcSSM sequence classifier (Figure 1) with
the exact-DEER parallel solver on a long-horizon synthetic task, then serve
a tiny LM through the continuous-batching engine (parallel prefill + O(D)
state-cache decode — the same API examples/serve_lm.py drives at scale).

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import TrainConfig
from repro.core.block import LrcSSMConfig, apply_lrcssm, init_lrcssm
from repro.core.deer import DeerConfig
from repro.data.pipeline import UEALikeSource
from repro.optim.adamw import adamw_init, adamw_update


def main():
    cfg = LrcSSMConfig(
        d_input=6, d_hidden=32, d_state=32, n_blocks=2, n_classes=2,
        cell="lrc", solver="deer",
        deer=DeerConfig(max_iters=10, mode="fixed", grad="implicit"))
    src = UEALikeSource("scp1", batch=16, seed=0, seq_len=512)
    params = init_lrcssm(cfg, jax.random.PRNGKey(0))
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=150)
    opt = adamw_init(params)

    def loss_fn(p, x, y):
        logits = apply_lrcssm(cfg, p, x)
        return jnp.mean(jax.nn.logsumexp(logits, -1)
                        - jnp.take_along_axis(logits, y[:, None], -1)[:, 0])

    @jax.jit
    def step(p, o, x, y):
        l, g = jax.value_and_grad(loss_fn)(p, x, y)
        p, o, m = adamw_update(tcfg, g, o, p)
        return p, o, l

    print("training LrcSSM (T=512, 2 blocks, DEER implicit-grad solver)...")
    for s in range(150):
        x, y = src.batch_at(s)
        params, opt, l = step(params, opt, x, y)
        if s % 25 == 0:
            print(f"  step {s:4d}  loss {float(l):.4f}")

    correct = tot = 0
    for s in range(4):
        x, y = src.batch_at(10_000 + s)
        pred = jnp.argmax(apply_lrcssm(cfg, params, x), -1)
        correct += int(jnp.sum(pred == y)); tot += len(y)
    print(f"test accuracy: {correct / tot:.3f} (chance 0.5)")


def serve_snippet():
    """Serve a reduced SSM LM with the continuous-batching engine: chunked
    parallel prefill on admission, one batched decode tick per token,
    streamed greedy tokens (matches examples/serve_lm.py)."""
    from repro.configs import get_reduced
    from repro.models import build_model
    from repro.serve.engine import Request, ServeEngine

    arch = get_reduced("falcon_mamba_7b")
    model = build_model(arch)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, batch_slots=2, max_seq=64,
                         prefill_chunk=8)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, arch.vocab, size=6)
                    .astype(np.int32),
                    max_new_tokens=4)
            for i in range(3)]
    for r in reqs:
        engine.submit(r)
    engine.run_until_drained()
    print("serving demo (continuous batching, parallel prefill):")
    for r in reqs:
        print(f"  req {r.uid}: {r.prompt.tolist()} -> {r.out_tokens}")


if __name__ == "__main__":
    main()
    serve_snippet()

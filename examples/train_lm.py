"""End-to-end LM training driver: any assigned architecture at reduced or
full scale, with the fault-tolerant Trainer (checkpoints, auto-resume,
straggler watchdog).

    # ~15M-param LrcSSM-mixer LM, a few hundred steps on CPU:
    PYTHONPATH=src python examples/train_lm.py --arch falcon_mamba_7b \
        --reduced --steps 200

    # ~100M-parameter run (the assignment's end-to-end driver; give it time
    # on CPU or run on real accelerators):
    PYTHONPATH=src python examples/train_lm.py --arch starcoder2_3b \
        --params-100m --steps 300
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro import nn
from repro.config import TrainConfig
from repro.configs import get_config, get_reduced
from repro.data.pipeline import TokenTaskSource
from repro.launch.mesh import make_local_mesh
from repro.models import build_model
from repro.train.loop import Trainer


def hundred_m_variant(arch):
    """Scale any arch family to ~100M params."""
    return dataclasses.replace(
        arch, n_layers=8, d_model=768,
        n_heads=12 if arch.n_heads else 0,
        n_kv_heads=4 if arch.n_kv_heads else 0,
        d_ff=3072 if arch.d_ff else 0, vocab=32768,
        dtype=jnp.float32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2_3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--params-100m", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    arch = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if args.params_100m:
        arch = hundred_m_variant(get_config(args.arch))
    model = build_model(arch)
    n_params_est = None

    tcfg = TrainConfig(learning_rate=3e-4, warmup_steps=20,
                       total_steps=args.steps, checkpoint_every=50,
                       checkpoint_dir=args.ckpt_dir)
    mesh = make_local_mesh(1, 1)
    trainer = Trainer(model, tcfg, mesh)
    print(f"arch={arch.name}  params={nn.count_params(trainer.params)/1e6:.1f}M")
    if args.resume:
        trainer.maybe_resume()

    data = TokenTaskSource(vocab=arch.vocab, seq_len=args.seq,
                           batch=args.batch, seed=0)
    hist = trainer.fit(iter(data), n_steps=args.steps)
    print(f"loss: first={hist[0].loss:.3f}  last={hist[-1].loss:.3f}  "
          f"median_step={sorted(h.wall for h in hist)[len(hist)//2]*1e3:.0f}ms")
    trainer.checkpoint(sync=True)
    print(f"checkpointed at step {trainer.step} -> {args.ckpt_dir}")


if __name__ == "__main__":
    main()

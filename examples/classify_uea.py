"""The paper's experiment: UEA-style long-horizon classification with the
Table-5 tuned hyperparameters, selectable dataset / cell / solver.

    PYTHONPATH=src python examples/classify_uea.py --dataset ethanol \
        --cell lrc --solver deer --steps 150

Compare the Appendix-D variants (Table 2):
    ... --cell gru | mgu | lstm | stc
Or validate the sequential oracle (identical accuracy, O(T) depth):
    ... --solver sequential
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.config import TrainConfig
from repro.configs.lrcssm_uea import TABLE5, uea_config, uea_lr
from repro.core.block import apply_lrcssm, init_lrcssm
from repro.data.pipeline import UEALikeSource
from repro.optim.adamw import adamw_init, adamw_update


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="scp1", choices=list(TABLE5))
    ap.add_argument("--cell", default="lrc",
                    choices=["lrc", "stc", "gru", "mgu", "lstm"])
    ap.add_argument("--solver", default="deer",
                    choices=["deer", "elk", "sequential"])
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--seq-cap", type=int, default=2048,
                    help="cap sequence length for CPU feasibility")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    T = min(TABLE5[args.dataset][2], args.seq_cap)
    cfg = uea_config(args.dataset, cell=args.cell, solver=args.solver,
                     d_hidden=32, d_state=32, n_blocks=2)
    src = UEALikeSource(args.dataset, batch=16, seed=args.seed, seq_len=T)
    params = init_lrcssm(cfg, jax.random.PRNGKey(args.seed))
    tcfg = TrainConfig(learning_rate=uea_lr(args.dataset), warmup_steps=10,
                       total_steps=args.steps)
    opt = adamw_init(params)

    def loss_fn(p, x, y):
        logits = apply_lrcssm(cfg, p, x)
        return jnp.mean(jax.nn.logsumexp(logits, -1)
                        - jnp.take_along_axis(logits, y[:, None], -1)[:, 0])

    @jax.jit
    def step(p, o, x, y):
        l, g = jax.value_and_grad(loss_fn)(p, x, y)
        p, o, _ = adamw_update(tcfg, g, o, p)
        return p, o, l

    print(f"dataset={args.dataset} T={T} cell={args.cell} "
          f"solver={args.solver}")
    t0 = time.perf_counter()
    for s in range(args.steps):
        x, y = src.batch_at(s)
        params, opt, l = step(params, opt, x, y)
        if s % 25 == 0:
            print(f"  step {s:4d} loss {float(l):.4f}")
    print(f"trained in {time.perf_counter() - t0:.1f}s")

    correct = tot = 0
    for s in range(4):
        x, y = src.batch_at(10_000 + s)
        pred = jnp.argmax(apply_lrcssm(cfg, params, x), -1)
        correct += int(jnp.sum(pred == y)); tot += len(y)
    k = TABLE5[args.dataset][1]
    print(f"test acc {correct/tot:.3f}  (chance {1.0/k:.2f})")


if __name__ == "__main__":
    main()

"""Core: the paper's contribution — nonlinear diagonal-Jacobian SSMs solved
with exact parallel (DEER/ELK) fixed-point iterations.

Public surface:
  scan          — diagonal linear recurrence solvers (assoc/chunked/sharded)
  lrc           — the LrcSSM cell (Eqs. 8-14)
  deer          — exact-Newton parallel solver + implicit differentiation
  deer_sharded  — the whole Newton solve on time shards (seq parallel)
  elk           — trust-region (parallel Kalman) solver
  elk_sharded   — the whole ELK solve on time shards (seq parallel)
  variants      — Gru/Mgu/Lstm/Stc diagonal-design cells (Appendix D)
  full_lrc      — dense-Jacobian LRC + quasi-DEER baseline (Table 9)
  block         — Figure 1 block architecture & sequence classifier
"""
from repro.core.deer import DeerConfig, deer_solve, deer_residual
from repro.core.deer_sharded import sharded_deer_solve
from repro.core.elk import ElkConfig, elk_solve
from repro.core.elk_sharded import sharded_elk_solve
from repro.core.lrc import (LrcCellConfig, init_lrc_params, input_features,
                            lrc_gates, lrc_step, lrc_step_and_diag_jac,
                            lrc_sequential)
from repro.core.scan import (chunked_diag_scan, diag_linear_scan,
                             diag_linear_scan_seq, sharded_diag_scan)
from repro.core.block import (LrcSSMConfig, apply_lrcssm,
                              apply_lrcssm_regression, init_lrcssm)

"""Sequence-parallel DEER Newton solver (solver-level sequence parallelism).

``core/deer.py`` parallelises each Newton iteration's *linear solve* over
time but keeps the full (T, D) trajectory replicated on every device —
capping context length at single-device memory. This module pushes the
sharding up into the Newton iteration itself (the ParaRNN / predictability-
parallelisation construction): the time axis lives sharded over a mesh axis
for the ENTIRE solve, so per-device trajectory memory is O(T/P * D) and the
collective volume per iteration is O(P * D) — independent of T.

Per Newton iteration, on each time shard (all inside one shard_map):

  1. boundary exchange — the shard's left-edge predecessor state
     x_{t0 - 1} arrives from the left neighbour with ONE ppermute of a
     single (D,) state (shard 0 substitutes x0);
  2. local linearisation — one jvp of the elementwise step over the local
     (T/P, D) slice gives the exact diagonal Jacobian J and affine term b
     (same algebra as core/deer.py, no approximation);
  3. distributed linear solve — local associative scan + all-gather of the
     P per-shard (lam_prod, b_total) summaries + exclusive-prefix fixup
     (``core/scan.sharded_scan_local``, the same body the scan-level
     primitive uses);
  4. convergence (``tol`` mode) — the global residual max|x_new - x| is the
     pmax of the per-shard residuals, so every shard runs the identical
     while_loop trip count.

Differentiation mirrors core/deer.py:
  * ``unroll``   — differentiate straight through the shard_map'd Newton
                   loop (fixed mode; collective transposes are handled by
                   jax: all_gather <-> psum_scatter, ppermute <-> inverse).
  * ``implicit`` — custom_vjp via the implicit function theorem; the adjoint
                   g_t = gbar_t + J_{t+1} g_{t+1} is a REVERSED diagonal
                   recurrence solved with the mirrored suffix-summary
                   sharded scan, plus one local vjp. Parameter cotangents
                   psum over the sequence axis; x0's cotangent comes from
                   shard 0 only.

Fallback: when T is not divisible by the shard count (or the axis is absent
from the mesh) the replicated ``deer_solve`` is used — same contract, no
caller-side branching.

All collectives resolve through distributed/compat.py (version-portable
shard_map: jax 0.4.x through current).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from repro.distributed.sharding import make_spec as P

from repro.core.deer import DeerConfig, StepFn, deer_solve
from repro.core.scan import residual_init, sharded_scan_local
from repro.distributed import compat


# ---------------------------------------------------------------------------
# axis plumbing
# ---------------------------------------------------------------------------

def seq_axis_tuple(seq_axis) -> tuple:
    """Normalise a seq_axis spec (name or tuple of names) to a tuple."""
    return seq_axis if isinstance(seq_axis, tuple) else (seq_axis,)


def n_seq_shards(mesh, seq_axis) -> int:
    """Number of time shards for ``seq_axis`` (a mesh axis name or a tuple of
    them — sharded over the row-major-flattened product axis). Returns 0 when
    any named axis is absent from the mesh (caller falls back to the
    replicated solver)."""
    shape = dict(mesh.shape)
    n = 1
    for a in seq_axis_tuple(seq_axis):
        if a not in shape:
            return 0
        n *= shape[a]
    return n


# ---------------------------------------------------------------------------
# boundary exchange
# ---------------------------------------------------------------------------

def _left_boundary(states_s: jax.Array, x0: jax.Array, seq_axis,
                   n_shards: int) -> jax.Array:
    """State just left of this shard: neighbour's last state, or x0 on
    shard 0. One (D,)-sized ppermute."""
    idx = compat.axis_index(seq_axis)
    if n_shards == 1:
        return jnp.asarray(x0, states_s.dtype)
    prev_last = compat.ppermute(
        states_s[-1], seq_axis,
        [(i, i + 1) for i in range(n_shards - 1)])
    return jnp.where(idx == 0, jnp.asarray(x0, states_s.dtype), prev_last)


def _right_jac_first(jac_s: jax.Array, seq_axis,
                     n_shards: int) -> jax.Array:
    """J at the first step of the right neighbour (zero past the end) —
    the boundary element of the shifted-left Jacobian the adjoint needs."""
    idx = compat.axis_index(seq_axis)
    if n_shards == 1:
        return jnp.zeros_like(jac_s[0])
    nxt = compat.ppermute(
        jac_s[0], seq_axis,
        [(i + 1, i) for i in range(n_shards - 1)])
    return jnp.where(idx == n_shards - 1, jnp.zeros_like(nxt), nxt)


# ---------------------------------------------------------------------------
# one Newton iteration on a time shard
# ---------------------------------------------------------------------------

def _local_newton_iteration(step_fn, feats_s, params, x0, states_s,
                            cfg: DeerConfig, seq_axis: str, n_shards: int):
    left = _left_boundary(states_s, x0, seq_axis, n_shards)
    shifted = jnp.concatenate([left[None], states_s[:-1]], axis=0)
    fn = lambda xs: step_fn(xs, feats_s, params)
    ones = jnp.ones_like(shifted)
    # One jvp = value + exact diagonal Jacobian (J @ 1 == diag(J)).
    f_s, jac = jax.jvp(fn, (shifted,), (ones,))
    if cfg.jac_clip is not None:
        jac = jnp.clip(jac, -cfg.jac_clip, cfg.jac_clip)
    b_s = f_s - jac * shifted
    new_states = sharded_scan_local(jac, b_s, x0, seq_axis)
    if cfg.damping != 1.0:
        new_states = (1.0 - cfg.damping) * states_s + cfg.damping * new_states
    return new_states


# ---------------------------------------------------------------------------
# sharded Newton loop (forward)
# ---------------------------------------------------------------------------

def _specs(feats, params, seq_axis, batch_axes):
    t_spec = P(seq_axis, batch_axes) if batch_axes else P(seq_axis)
    x0_spec = P(batch_axes) if batch_axes else P()
    feats_specs = jax.tree_util.tree_map(lambda _: t_spec, feats)
    params_specs = jax.tree_util.tree_map(lambda _: P(), params)
    return t_spec, x0_spec, feats_specs, params_specs


def _replicated_axes(seq_axis, batch_axes):
    """Mesh axes over which per-shard PARTIAL sums must be psum'd to make a
    replicated quantity: the sequence axes always, plus the batch axes when
    the batch rides sharded through the solve."""
    axes = seq_axis_tuple(seq_axis)
    if batch_axes:
        axes = axes + (batch_axes if isinstance(batch_axes, tuple)
                       else (batch_axes,))
    return axes


def _solve_shmapped(step_fn, feats, params, x0, init_guess, cfg: DeerConfig,
                    mesh, seq_axis, batch_axes):
    n_shards = n_seq_shards(mesh, seq_axis)
    t_spec, x0_spec, feats_specs, params_specs = _specs(
        feats, params, seq_axis, batch_axes)

    def local(feats_s, params_r, x0_r, init_s):
        if cfg.mode == "fixed":
            def body(_, st):
                return _local_newton_iteration(step_fn, feats_s, params_r,
                                               x0_r, st, cfg, seq_axis,
                                               n_shards)
            states = jax.lax.fori_loop(0, cfg.max_iters, body, init_s,
                                       unroll=cfg.unroll)
            return states, jnp.asarray(cfg.max_iters, jnp.int32)

        def cond(carry):
            _, diff, it = carry
            return jnp.logical_and(diff > cfg.tol, it < cfg.max_iters)

        def body(carry):
            st, _, it = carry
            new = _local_newton_iteration(step_fn, feats_s, params_r, x0_r,
                                          st, cfg, seq_axis, n_shards)
            # global max-norm residual: pmax of the per-shard residual over
            # the time axis AND any batch axes, so the while_loop trip
            # count (and the returned iters) is identical on every device
            diff = compat.pmax(
                jnp.max(jnp.abs(new - st)).astype(jnp.float32),
                _replicated_axes(seq_axis, batch_axes))
            return new, diff, it + 1

        states, _, iters = jax.lax.while_loop(
            cond, body, (init_s, residual_init(),
                         jnp.asarray(0, jnp.int32)))
        return states, iters

    return compat.shard_map(
        local, mesh=mesh,
        in_specs=(feats_specs, params_specs, x0_spec, t_spec),
        out_specs=(t_spec, P()),
        check_vma=False,
    )(feats, params, x0, init_guess)


# ---------------------------------------------------------------------------
# implicit differentiation at the fixed point (sharded adjoint)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 5, 6, 7, 8, 9))
def _sharded_fixed_point(step_fn, feats, params, x0, init_guess,
                         cfg: DeerConfig, mesh, seq_axis, batch_axes,
                         fused_scan):
    states, _ = _solve_shmapped(step_fn, feats, params, x0,
                                jax.lax.stop_gradient(init_guess), cfg,
                                mesh, seq_axis, batch_axes)
    return states


def _sfp_fwd(step_fn, feats, params, x0, init_guess, cfg, mesh, seq_axis,
             batch_axes, fused_scan):
    states = _sharded_fixed_point(step_fn, feats, params, x0, init_guess,
                                  cfg, mesh, seq_axis, batch_axes,
                                  fused_scan)
    return states, (feats, params, x0, states)


def sharded_implicit_adjoint(step_fn, feats, params, x0, states, gbar, *,
                             mesh, seq_axis, batch_axes, fused_scan=None):
    """IFT adjoint of the fixed point x = F(shift(x)), distributed on time
    shards. SHARED between the sharded DEER and sharded ELK solvers: both
    iterations converge to the same fixed-point equation, so the backward
    pass — reversed suffix-summary scan for g_t = gbar_t + J_{t+1} g_{t+1},
    one local vjp, psum of parameter cotangents over the sequence axes AND
    any batch shards, x0 cotangent from shard 0 — is identical.

    ``fused_scan``: optional per-shard fused-adjoint hook
    ``(shifted, feats, params, gbar, jac_right, seq_axis) -> g`` running
    gate recompute + exact diagonal J + the reverse chunk scan in one
    fused kernel and composing shards through the reverse summary fixup
    (kernels.lrc_deer.ops.make_fused_adjoint_scans).  The hook only needs
    the boundary Jacobian ``jac_right`` — the right neighbour's FIRST-row
    J — which this function produces with a one-row jvp + the same
    ppermute the generic path uses.

    Returns (d_feats, d_params, d_x0).
    """
    n_shards = n_seq_shards(mesh, seq_axis)
    t_spec, x0_spec, feats_specs, params_specs = _specs(
        feats, params, seq_axis, batch_axes)

    def local(feats_s, params_r, x0_r, states_s, gbar_s):
        idx = compat.axis_index(seq_axis)
        left = _left_boundary(states_s, x0_r, seq_axis, n_shards)
        shifted = jnp.concatenate([left[None], states_s[:-1]], axis=0)

        if fused_scan is not None:
            # one-row J (the boundary element the LEFT neighbour needs for
            # its shifted-left Jacobian), exchanged with one ppermute
            feats_row = jax.tree_util.tree_map(lambda a: a[:1], feats_s)
            fn_row = lambda xs: step_fn(xs, feats_row, params_r)
            _, j0 = jax.jvp(fn_row, (shifted[:1],),
                            (jnp.ones_like(shifted[:1]),))
            nxt = _right_jac_first(j0, seq_axis, n_shards)
            g = fused_scan(shifted, feats_s, params_r, gbar_s, nxt,
                           seq_axis)
        else:
            fn_of_x = lambda xs: step_fn(xs, feats_s, params_r)
            ones = jnp.ones_like(shifted)
            _, jac = jax.jvp(fn_of_x, (shifted,), (ones,))  # J = dF/dx_{t-1}

            # Adjoint recurrence g_t = gbar_t + J_{t+1} g_{t+1}: shift J
            # left (boundary element from the right neighbour), then the
            # REVERSED sharded scan with the suffix-summary fixup.
            nxt = _right_jac_first(jac, seq_axis, n_shards)
            jac_next = jnp.concatenate([jac[1:], nxt[None]], axis=0)
            g = sharded_scan_local(jac_next, gbar_s, None, seq_axis,
                                   reverse=True)

        # Cotangents via one local vjp through the step at the converged
        # trajectory. Interior-state cotangents (d_shifted[1:], and slot 0
        # on shards > 0 — the neighbour's last state) are already folded
        # into g by the adjoint solve and are discarded, exactly as in the
        # replicated core/deer.py adjoint.
        _, vjp = jax.vjp(lambda sh, ft, pr: step_fn(sh, ft, pr),
                         shifted, feats_s, params_r)
        d_shifted, d_feats, d_params = vjp(g)
        # params are replicated over BOTH the time shards and any batch
        # shards: each device holds the partial sum of its (time, batch)
        # slice, so the cotangent reduces over all of those axes
        d_params = jax.tree_util.tree_map(
            lambda t: compat.psum(t, _replicated_axes(seq_axis, batch_axes)),
            d_params)
        # x0 enters only through shard 0's boundary slot
        d_x0 = compat.psum(
            jnp.where(idx == 0, d_shifted[0], jnp.zeros_like(d_shifted[0])),
            seq_axis)
        return d_feats, d_params, d_x0

    return compat.shard_map(
        local, mesh=mesh,
        in_specs=(feats_specs, params_specs, x0_spec, t_spec, t_spec),
        out_specs=(feats_specs, params_specs, x0_spec),
        check_vma=False,
    )(feats, params, x0, states, gbar)


def _sfp_bwd(step_fn, cfg, mesh, seq_axis, batch_axes, fused_scan, res,
             gbar):
    feats, params, x0, states = res
    d_feats, d_params, d_x0 = sharded_implicit_adjoint(
        step_fn, feats, params, x0, states, gbar, mesh=mesh,
        seq_axis=seq_axis, batch_axes=batch_axes, fused_scan=fused_scan)
    d_init = jnp.zeros_like(states)  # init guess does not affect the solution
    return d_feats, d_params, d_x0, d_init


_sharded_fixed_point.defvjp(_sfp_fwd, _sfp_bwd)


# ---------------------------------------------------------------------------
# public entry point
# ---------------------------------------------------------------------------

def sharded_deer_solve(step_fn: StepFn, feats, x0: jax.Array, T: int,
                       cfg: DeerConfig = DeerConfig(), *, mesh,
                       seq_axis="data",
                       init_guess: Optional[jax.Array] = None,
                       params=None,
                       batch_axes=None,
                       fused_scan=None) -> Tuple[jax.Array, jax.Array]:
    """Solve x_t = step_fn(x_{t-1}, feats_t[, params]) with the trajectory
    SHARDED over mesh axis ``seq_axis`` for the whole Newton solve.

    Same contract as ``core.deer.deer_solve`` — returns (states (T, ...),
    n_iters ()), differentiable per cfg.grad w.r.t. feats, x0 and params —
    plus:

      mesh / seq_axis: the device mesh and the axis the time dimension is
        sharded over (P shards; per-device trajectory is (T/P, ...)).
        ``seq_axis`` may be a TUPLE of mesh axes (e.g. ("data", "model")) —
        the time axis is then sharded over the row-major-flattened product
        axis, engaging the whole mesh for batch=1 long-sequence cells.
      batch_axes: optional mesh axis (or tuple) the SECOND feats dimension /
        first x0 dimension is sharded over, so a batch folded into the state
        dims stays distributed instead of being all-gathered into every
        shard (the ring-attention batch-spec lesson).
      fused_scan: optional per-shard fused-adjoint hook (grad="implicit"
        only) — see ``sharded_implicit_adjoint``.

    Falls back to the replicated ``deer_solve`` when T is not divisible by
    the shard count or any ``seq_axis`` name is missing from the mesh.
    """
    if params is None:
        orig = step_fn
        step_fn = lambda x, f, _p: orig(x, f)
        params = ()

    n_shards = n_seq_shards(mesh, seq_axis)
    if n_shards == 0 or T % max(n_shards, 1) != 0:
        return deer_solve(step_fn, feats, x0, T, cfg,
                          init_guess=init_guess, params=params)

    if init_guess is None:
        init_guess = jnp.zeros((T,) + x0.shape, x0.dtype)

    if cfg.grad == "implicit":
        states = _sharded_fixed_point(step_fn, feats, params, x0, init_guess,
                                      cfg, mesh, seq_axis, batch_axes,
                                      fused_scan)
        return states, jnp.asarray(cfg.max_iters, jnp.int32)
    return _solve_shmapped(step_fn, feats, params, x0, init_guess, cfg,
                           mesh, seq_axis, batch_axes)

"""Generalised diagonal model design (Sec. 5.2, Appendix D).

Any gated nonlinear RNN  x_t = q_t(x_{t-1}, u_t) * x_{t-1} + s_t(x_{t-1}, u_t)
becomes a parallelisable nonlinear SSM by restricting the state-dependence of
every gate to the neuron's own state (self-loop synapses) while keeping full
input dependence. The resulting step function is elementwise in x, hence the
Jacobian is diagonal by construction and the exact DEER machinery of
core/deer.py applies unchanged.

Implemented cells (Table 2 / Table 8):
  * GruSSM  — diagonal-design GRU (Appendix D.1)
  * MguSSM  — diagonal-design Minimal Gated Unit
  * LstmSSM — diagonal-design LSTM (cell state is the SSM state)
  * StcSSM  — saturated LTC without the elastance term (constant capacitance,
              Table 8 ablation)

Every cell exposes the same functional surface as core/lrc.py:
  init_params(cfg, key) -> Params
  input_features(p, u)  -> per-timestep features (computed once, two matmuls)
  step(p, cfg, x_prev, *feats) -> x_next   (elementwise in x_prev)
  sequential(p, cfg, u, x0)    -> oracle rollout
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, jax.Array]


@dataclasses.dataclass(frozen=True)
class CellConfig:
    d_input: int
    d_state: int
    dt: float = 1.0
    param_dtype: Any = jnp.float32


def _dense(key, shape, scale, dtype):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# GruSSM (Appendix D.1): A(x,u) = -z, b(x,u) = z * c
#   z = sigma(wz_x * x + U_z u + bz)
#   r = sigma(wr_x * x + U_r u + br)
#   c = tanh(wh_x * (r * x) + U_h u + bh)
#   x_t = (1 - z) x_{t-1} + z c
# ---------------------------------------------------------------------------

def gru_init(cfg: CellConfig, key) -> Params:
    D, n, dt = cfg.d_state, cfg.d_input, cfg.param_dtype
    ks = jax.random.split(key, 6)
    s = (1.0 / max(n, 1)) ** 0.5
    return {
        "wz_x": _dense(ks[0], (D,), 0.5, dt), "U_z": _dense(ks[1], (n, D), s, dt),
        "bz": jnp.zeros((D,), dt),
        "wr_x": _dense(ks[2], (D,), 0.5, dt), "U_r": _dense(ks[3], (n, D), s, dt),
        "br": jnp.zeros((D,), dt),
        "wh_x": _dense(ks[4], (D,), 0.5, dt), "U_h": _dense(ks[5], (n, D), s, dt),
        "bh": jnp.zeros((D,), dt),
    }


def gru_features(p: Params, u: jax.Array):
    return u @ p["U_z"] + p["bz"], u @ p["U_r"] + p["br"], u @ p["U_h"] + p["bh"]


def gru_step(p: Params, cfg: CellConfig, x, fz, fr, fh):
    z = jax.nn.sigmoid(p["wz_x"] * x + fz)
    r = jax.nn.sigmoid(p["wr_x"] * x + fr)
    c = jnp.tanh(p["wh_x"] * (r * x) + fh)
    return (1.0 - z) * x + z * c


# ---------------------------------------------------------------------------
# MguSSM: single forget gate
# ---------------------------------------------------------------------------

def mgu_init(cfg: CellConfig, key) -> Params:
    D, n, dt = cfg.d_state, cfg.d_input, cfg.param_dtype
    ks = jax.random.split(key, 4)
    s = (1.0 / max(n, 1)) ** 0.5
    return {
        "wf_x": _dense(ks[0], (D,), 0.5, dt), "U_f": _dense(ks[1], (n, D), s, dt),
        "bf": jnp.zeros((D,), dt),
        "wh_x": _dense(ks[2], (D,), 0.5, dt), "U_h": _dense(ks[3], (n, D), s, dt),
        "bh": jnp.zeros((D,), dt),
    }


def mgu_features(p: Params, u: jax.Array):
    return u @ p["U_f"] + p["bf"], u @ p["U_h"] + p["bh"]


def mgu_step(p: Params, cfg: CellConfig, x, ff, fh):
    f = jax.nn.sigmoid(p["wf_x"] * x + ff)
    h = jnp.tanh(p["wh_x"] * (f * x) + fh)
    return (1.0 - f) * x + f * h


# ---------------------------------------------------------------------------
# LstmSSM: the cell state c is the SSM state; i/f/g/o gates diagonal in c.
#   c_t = f * c_{t-1} + i * g ;  readout h = o * tanh(c) applied post-solve.
# ---------------------------------------------------------------------------

def lstm_init(cfg: CellConfig, key) -> Params:
    D, n, dt = cfg.d_state, cfg.d_input, cfg.param_dtype
    ks = jax.random.split(key, 8)
    s = (1.0 / max(n, 1)) ** 0.5
    p = {}
    for i, gate in enumerate(("i", "f", "g", "o")):
        p[f"w{gate}_x"] = _dense(ks[2 * i], (D,), 0.5, dt)
        p[f"U_{gate}"] = _dense(ks[2 * i + 1], (n, D), s, dt)
        p[f"b{gate}"] = (jnp.ones((D,), dt) if gate == "f" else jnp.zeros((D,), dt))
    return p


def lstm_features(p: Params, u: jax.Array):
    return (u @ p["U_i"] + p["bi"], u @ p["U_f"] + p["bf"],
            u @ p["U_g"] + p["bg"], u @ p["U_o"] + p["bo"])


def lstm_step(p: Params, cfg: CellConfig, c, fi, ff, fg, fo):
    i = jax.nn.sigmoid(p["wi_x"] * c + fi)
    f = jax.nn.sigmoid(p["wf_x"] * c + ff)
    g = jnp.tanh(p["wg_x"] * c + fg)
    return f * c + i * g


def lstm_readout(p: Params, c, fo):
    o = jax.nn.sigmoid(p["wo_x"] * c + fo)
    return o * jnp.tanh(c)


# ---------------------------------------------------------------------------
# StcSSM (Table 8): LRC without elastance — constant capacitance.
#   dx = -sigma(f*) x + tanh(z*) e_leak
# ---------------------------------------------------------------------------

def stc_init(cfg: CellConfig, key) -> Params:
    D, n, dt = cfg.d_state, cfg.d_input, cfg.param_dtype
    ks = jax.random.split(key, 6)
    s = (1.0 / max(n, 1)) ** 0.5
    return {
        "a_x": _dense(ks[0], (D,), 1.0, dt), "b_x": jnp.zeros((D,), dt),
        "g_max_x": _dense(ks[1], (D,), 0.5, dt), "k_max_x": _dense(ks[2], (D,), 0.5, dt),
        "a_u": _dense(ks[3], (n, D), s, dt), "b_u": jnp.zeros((D,), dt),
        "g_max_u": _dense(ks[4], (D,), 0.5, dt), "k_max_u": _dense(ks[5], (D,), 0.5, dt),
        "g_leak": jnp.full((D,), 0.1, dt), "e_leak": jnp.ones((D,), dt),
    }


def stc_features(p: Params, u: jax.Array):
    return (jax.nn.sigmoid(u @ p["a_u"] + p["b_u"]),)


def stc_step(p: Params, cfg: CellConfig, x, s_u):
    s_x = jax.nn.sigmoid(p["a_x"] * x + p["b_x"])
    f = p["g_max_x"] * s_x + p["g_max_u"] * s_u + p["g_leak"]
    z = p["k_max_x"] * s_x + p["k_max_u"] * s_u + p["g_leak"]
    lam = 1.0 - cfg.dt * jax.nn.sigmoid(f)
    beta = cfg.dt * jnp.tanh(z) * p["e_leak"]
    return lam * x + beta


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

CELLS = {
    "gru": (gru_init, gru_features, gru_step),
    "mgu": (mgu_init, mgu_features, mgu_step),
    "lstm": (lstm_init, lstm_features, lstm_step),
    "stc": (stc_init, stc_features, stc_step),
}


def sequential(kind: str, p: Params, cfg: CellConfig, u: jax.Array,
               x0: Optional[jax.Array] = None) -> jax.Array:
    """Oracle rollout for any registered cell (O(T) depth)."""
    _, feat_fn, step_fn = CELLS[kind]
    feats = feat_fn(p, u)
    if x0 is None:
        x0 = jnp.zeros((cfg.d_state,), u.dtype)

    def step(x, fs):
        x_new = step_fn(p, cfg, x, *fs)
        return x_new, x_new

    _, xs = jax.lax.scan(step, x0, feats)
    return xs

"""Sequence-parallel ELK solver: the trust-region (LM/Kalman) Newton
iteration on time shards.

``core/elk.py`` runs each ELK iteration as one parallel Kalman smoother pass
over the FULL (T, D) trajectory, replicated on every device. This module
composes the same iteration with the cross-chip shard decomposition of
``core/deer_sharded.py``: the trajectory lives sharded over one or more mesh
axes for the entire solve, so per-device memory is O(T/P * D) and the
collective volume per iteration is O(P * D) — independent of T.

Per ELK iteration, on each time shard (all inside one shard_map):

  1. boundary exchange — the shard's left-edge predecessor state arrives
     from the left neighbour with one ppermute of a (D,) state (shard 0
     substitutes x0); identical to the DEER solver's exchange.
  2. local linearisation — one jvp over the local (T/P, D) slice gives the
     exact diagonal Jacobian J and affine term b.
  3. distributed smoother — BOTH smoother passes are sharded associative
     scans: each shard scans its local 5-tuple filtering elements
     (Sarkka & Garcia-Fernandez), all-gathers the P per-shard summary
     elements, applies the exclusive cross-shard prefix locally; the reverse
     (RTS) pass mirrors this with 3-tuple smoothing elements and an
     exclusive cross-shard SUFFIX. The smoothing elements need F/c/q at
     global t+1, which crosses shard boundaries: one more ppermute of three
     (D,) rows from the right neighbour.
  4. convergence (``tol`` mode) — pmax of the per-shard residuals, so every
     shard runs the identical while_loop trip count.

Differentiation mirrors core/deer_sharded.py — the ELK iteration converges
to the same fixed point x = F(shift(x)) as DEER (the smoother's
observations become self-consistent at the solution), so grad="implicit"
reuses ``sharded_implicit_adjoint`` verbatim: reversed suffix-summary scan,
one local vjp, parameter cotangents psum'd over the sequence axes AND any
batch shards, x0's cotangent from shard 0.

``seq_axis`` may be a tuple of mesh axes (e.g. ("data", "model")): the time
axis is sharded over the row-major-flattened product axis, engaging the
whole mesh for batch=1 long-sequence cells.

Fallback: when T is not divisible by the shard count (or any axis is absent
from the mesh) the replicated ``elk_solve`` is used — same contract.

All collectives resolve through distributed/compat.py (version-portable
shard_map: jax 0.4.x through current).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.deer_sharded import (_left_boundary, _replicated_axes,
                                     _specs, n_seq_shards,
                                     sharded_implicit_adjoint)
from repro.core.elk import (ElkConfig, _filter_combine, _smooth_combine,
                            elk_solve)
from repro.core.deer import StepFn
from repro.core.scan import residual_init
from repro.distributed import compat
from repro.distributed.sharding import make_spec


# ---------------------------------------------------------------------------
# sharded associative scan with an arbitrary combine
# ---------------------------------------------------------------------------

def _sharded_cumulative(combine, elems, identities, seq_axis,
                        reverse: bool = False):
    """GLOBAL inclusive cumulative of ``combine`` over time shards, from the
    per-shard local slices. MUST run inside a shard_map sharded over
    ``seq_axis``.

    ``elems``: tuple of (T_local, ...) arrays forming one scan element per
    step. ``identities``: matching tuple of scalars — the combine's identity
    element, substituted for the exclusive prefix on the edge shard.

    Forward: local prefix scan, all-gather of each shard's LAST cumulative
    element (the whole-shard summary), redundant exclusive prefix over the P
    summaries, folded in as the EARLIER argument of ``combine``. Reverse
    (suffix) mirrors it: summaries are each shard's FIRST reverse-cumulative
    element, the exclusive suffix folds in as the LATER argument — both
    combines here take the accumulator side first, so the same call works.

    The per-element summaries are stacked so each pass issues ONE
    all-gather (launch latency, not volume, dominates P-sized collectives);
    total volume len(elems) * P * D per call — independent of T.
    """
    cum = jax.lax.associative_scan(combine, elems, axis=0, reverse=reverse)
    idx = compat.axis_index(seq_axis)
    edge = 0 if reverse else -1
    gathered = compat.all_gather(                      # (P, len(elems), ...)
        jnp.stack([c[edge] for c in cum], axis=0), seq_axis)
    summ = tuple(gathered[:, i] for i in range(len(cum)))
    n = summ[0].shape[0]
    acc = jax.lax.associative_scan(combine, summ, axis=0, reverse=reverse)
    if reverse:
        at_edge = idx == n - 1
        sel = jnp.minimum(idx + 1, n - 1)
    else:
        at_edge = idx == 0
        sel = jnp.maximum(idx - 1, 0)
    excl = tuple(jnp.where(at_edge, jnp.full_like(a[0], ident), a[sel])
                 for ident, a in zip(identities, acc))
    return combine(excl, cum)


_FILTER_IDENTITY = (1.0, 0.0, 0.0, 0.0, 0.0)   # (A, b, C, eta, J)
_SMOOTH_IDENTITY = (1.0, 0.0, 0.0)             # (E, g, L)


# ---------------------------------------------------------------------------
# per-shard parallel Kalman smoother
# ---------------------------------------------------------------------------

def _right_first_rows(rows, seq_axis, n_shards: int, fillers):
    """First time-step of each array in ``rows`` on the RIGHT neighbour
    (``fillers`` past the end) — the boundary elements the shifted-left
    smoothing pass needs. One ppermute of len(rows) (D,) rows."""
    if n_shards == 1:
        return tuple(jnp.full_like(r[0], f) for r, f in zip(rows, fillers))
    idx = compat.axis_index(seq_axis)
    stacked = jnp.stack([r[0] for r in rows], axis=0)
    nxt = compat.ppermute(stacked, seq_axis,
                          [(i + 1, i) for i in range(n_shards - 1)])
    last = idx == n_shards - 1
    return tuple(jnp.where(last, jnp.full_like(nxt[i], f), nxt[i])
                 for i, f in enumerate(fillers))


def kalman_smoother_parallel_local(F: jax.Array, c: jax.Array, q: jax.Array,
                                   y: jax.Array, r: jax.Array,
                                   m0: jax.Array, P0: jax.Array,
                                   seq_axis, n_shards: int
                                   ) -> Tuple[jax.Array, jax.Array]:
    """Per-shard body of ``core.elk.kalman_smoother_parallel`` — identical
    contract, but F/c/q/y/r are the LOCAL (T/P, ...) time slices and the
    two associative scans run distributed (local scan + P-sized summary
    exchange + exclusive prefix/suffix fixup). MUST run inside a shard_map
    sharded over ``seq_axis``; m0/P0 are replicated across time shards.
    """
    q = jnp.broadcast_to(jnp.asarray(q, y.dtype), y.shape)
    r = jnp.broadcast_to(jnp.asarray(r, y.dtype), y.shape)
    idx = compat.axis_index(seq_axis)
    first_shard = idx == 0

    # ---- filtering elements (standard form everywhere) ----------------------
    S = q + r
    K = q / S
    A = (1.0 - K) * F
    b = c + K * (y - c)
    C = (1.0 - K) * q
    eta = F * (y - c) / S
    J = F * F / S

    # Global element 0 (shard 0 only) conditions on the prior (m0, P0).
    P1p = F[0] * F[0] * P0 + q[0]
    m1p = F[0] * m0 + c[0]
    S1 = P1p + r[0]
    K1 = P1p / S1
    z0 = jnp.zeros_like(A[0])
    A0 = jnp.where(first_shard, z0, A[0])
    b0 = jnp.where(first_shard, m1p + K1 * (y[0] - m1p), b[0])
    C0 = jnp.where(first_shard, (1.0 - K1) * P1p, C[0])
    eta0 = jnp.where(first_shard, z0, eta[0])
    J0 = jnp.where(first_shard, z0, J[0])

    A = jnp.concatenate([A0[None], A[1:]], 0)
    b = jnp.concatenate([b0[None], b[1:]], 0)
    C = jnp.concatenate([C0[None], C[1:]], 0)
    eta = jnp.concatenate([eta0[None], eta[1:]], 0)
    J = jnp.concatenate([J0[None], J[1:]], 0)

    fA, fb, fC, _, _ = _sharded_cumulative(
        _filter_combine, (A, b, C, eta, J), _FILTER_IDENTITY, seq_axis)
    m_f, P_f = fb, fC                           # filtered means/vars

    # ---- smoothing elements (reverse suffix scan) ---------------------------
    # F/c/q at global t+1: shift left, boundary from the right neighbour
    # (fillers (1, 0, 1) past the global end — overwritten below anyway).
    F_b, c_b, q_b = _right_first_rows((F, c, q), seq_axis, n_shards,
                                      (1.0, 0.0, 1.0))
    F_next = jnp.concatenate([F[1:], F_b[None]], 0)
    c_next = jnp.concatenate([c[1:], c_b[None]], 0)
    q_next = jnp.concatenate([q[1:], q_b[None]], 0)
    Pp_next = F_next * F_next * P_f + q_next    # P_{t+1|t}
    E = P_f * F_next / Pp_next
    g = m_f - E * (F_next * m_f + c_next)
    L = P_f - E * E * Pp_next
    # global last element (last shard only): conditional == filtered marginal
    last_shard = idx == n_shards - 1
    E_l = jnp.where(last_shard, jnp.zeros_like(E[-1]), E[-1])
    g_l = jnp.where(last_shard, m_f[-1], g[-1])
    L_l = jnp.where(last_shard, P_f[-1], L[-1])
    E = jnp.concatenate([E[:-1], E_l[None]], 0)
    g = jnp.concatenate([g[:-1], g_l[None]], 0)
    L = jnp.concatenate([L[:-1], L_l[None]], 0)

    _, ms, Ls = _sharded_cumulative(_smooth_combine, (E, g, L),
                                    _SMOOTH_IDENTITY, seq_axis, reverse=True)
    return ms, Ls


# ---------------------------------------------------------------------------
# one ELK iteration on a time shard
# ---------------------------------------------------------------------------

def _local_elk_iteration(step_fn, feats_s, params, x0, states_s,
                         cfg: ElkConfig, seq_axis, n_shards: int):
    left = _left_boundary(states_s, x0, seq_axis, n_shards)
    shifted = jnp.concatenate([left[None], states_s[:-1]], axis=0)
    fn = lambda xs: step_fn(xs, feats_s, params)
    ones = jnp.ones_like(shifted)
    f_s, jac = jax.jvp(fn, (shifted,), (ones,))
    b_s = f_s - jac * shifted
    q = jnp.ones_like(states_s)
    r = jnp.full_like(states_s, 1.0 / max(cfg.trust_mu, 1e-12))
    P0 = jnp.zeros_like(x0) + 1e-6
    ms, _ = kalman_smoother_parallel_local(jac, b_s, q, states_s, r, x0, P0,
                                           seq_axis, n_shards)
    return ms


# ---------------------------------------------------------------------------
# sharded ELK loop (forward)
# ---------------------------------------------------------------------------

def _elk_shmapped(step_fn, feats, params, x0, init_guess, cfg: ElkConfig,
                  mesh, seq_axis, batch_axes):
    n_shards = n_seq_shards(mesh, seq_axis)
    t_spec, x0_spec, feats_specs, params_specs = _specs(
        feats, params, seq_axis, batch_axes)

    def local(feats_s, params_r, x0_r, init_s):
        if cfg.mode == "fixed":
            def body(_, st):
                return _local_elk_iteration(step_fn, feats_s, params_r, x0_r,
                                            st, cfg, seq_axis, n_shards)
            states = jax.lax.fori_loop(0, cfg.max_iters, body, init_s)
            return states, jnp.asarray(cfg.max_iters, jnp.int32)

        def cond(carry):
            _, diff, it = carry
            return jnp.logical_and(diff > cfg.tol, it < cfg.max_iters)

        def body(carry):
            st, _, it = carry
            new = _local_elk_iteration(step_fn, feats_s, params_r, x0_r, st,
                                       cfg, seq_axis, n_shards)
            # global max-norm residual (pmax over the time axes AND any batch
            # axes) so the while_loop trip count is identical on every device
            diff = compat.pmax(
                jnp.max(jnp.abs(new - st)).astype(jnp.float32),
                _replicated_axes(seq_axis, batch_axes))
            return new, diff, it + 1

        states, _, iters = jax.lax.while_loop(
            cond, body, (init_s, residual_init(),
                         jnp.asarray(0, jnp.int32)))
        return states, iters

    return compat.shard_map(
        local, mesh=mesh,
        in_specs=(feats_specs, params_specs, x0_spec, t_spec),
        out_specs=(t_spec, make_spec()),
        check_vma=False,
    )(feats, params, x0, init_guess)


# ---------------------------------------------------------------------------
# implicit differentiation at the fixed point (shared sharded adjoint)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 5, 6, 7, 8))
def _sharded_elk_fixed_point(step_fn, feats, params, x0, init_guess,
                             cfg: ElkConfig, mesh, seq_axis, batch_axes):
    states, _ = _elk_shmapped(step_fn, feats, params, x0,
                              jax.lax.stop_gradient(init_guess), cfg,
                              mesh, seq_axis, batch_axes)
    return states


def _sefp_fwd(step_fn, feats, params, x0, init_guess, cfg, mesh, seq_axis,
              batch_axes):
    states = _sharded_elk_fixed_point(step_fn, feats, params, x0, init_guess,
                                      cfg, mesh, seq_axis, batch_axes)
    return states, (feats, params, x0, states)


def _sefp_bwd(step_fn, cfg, mesh, seq_axis, batch_axes, res, gbar):
    feats, params, x0, states = res
    d_feats, d_params, d_x0 = sharded_implicit_adjoint(
        step_fn, feats, params, x0, states, gbar, mesh=mesh,
        seq_axis=seq_axis, batch_axes=batch_axes)
    return d_feats, d_params, d_x0, jnp.zeros_like(states)


_sharded_elk_fixed_point.defvjp(_sefp_fwd, _sefp_bwd)


# ---------------------------------------------------------------------------
# public entry point
# ---------------------------------------------------------------------------

def sharded_elk_solve(step_fn: StepFn, feats, x0: jax.Array, T: int,
                      cfg: ElkConfig = ElkConfig(), *, mesh,
                      seq_axis="data",
                      init_guess: Optional[jax.Array] = None,
                      params=None,
                      batch_axes=None) -> Tuple[jax.Array, jax.Array]:
    """Solve x_t = step_fn(x_{t-1}, feats_t[, params]) with the ELK
    (trust-region Kalman) iteration, the trajectory SHARDED over mesh axis
    (or axes tuple) ``seq_axis`` for the whole solve.

    Same contract as ``core.elk.elk_solve`` — returns (states (T, ...),
    n_iters ()), differentiable per ``cfg.grad`` w.r.t. feats, x0 and params
    — plus mesh / seq_axis / batch_axes exactly as
    ``core.deer_sharded.sharded_deer_solve``.

    Falls back to the replicated ``elk_solve`` when T is not divisible by
    the shard count or any ``seq_axis`` name is missing from the mesh.
    """
    if params is None:
        orig = step_fn
        step_fn = lambda x, f, _p: orig(x, f)
        params = ()

    n_shards = n_seq_shards(mesh, seq_axis)
    if n_shards == 0 or T % max(n_shards, 1) != 0:
        return elk_solve(step_fn, feats, x0, T, cfg,
                         init_guess=init_guess, params=params)

    if init_guess is None:
        init_guess = jnp.zeros((T,) + x0.shape, x0.dtype)

    if cfg.grad == "implicit":
        states = _sharded_elk_fixed_point(step_fn, feats, params, x0,
                                          init_guess, cfg, mesh, seq_axis,
                                          batch_axes)
        return states, jnp.asarray(cfg.max_iters, jnp.int32)
    return _elk_shmapped(step_fn, feats, params, x0, init_guess, cfg,
                         mesh, seq_axis, batch_axes)

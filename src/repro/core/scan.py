"""Diagonal linear-recurrence scan primitives.

The paper's central computational object is the first-order diagonal linear
recurrence

    x_t = lam_t * x_{t-1} + b_t ,        t = 1..T,   lam_t, b_t, x_t in R^D (or C^D)

which every DEER/ELK Newton iteration must solve (Algorithm 1, line 9).
Because the LrcSSM Jacobian is diagonal *by model design* (Sec. 3.1), the
recurrence decouples per hidden dimension, so the whole (T, D) solve is an
embarrassingly-parallel-over-D set of scalar prefix problems with O(log T)
sequential depth via an associative scan.

The same primitive also implements the Mamba-1/Mamba-2 selective scans used
by the assigned `ssm`/`hybrid` architectures, so it is shared framework-wide.

Three implementations, one contract:
  * ``diag_linear_scan``      — jax.lax.associative_scan (default; O(log T) depth)
  * ``diag_linear_scan_seq``  — jax.lax.scan oracle (O(T) depth; tests/serving)
  * ``sharded_diag_scan``     — shard_map sequence-parallel scan: local scan +
                                all-gather of per-shard summaries + prefix fixup.
                                Used for long-context cells (seq sharded over mesh).

Sequence parallelism exists at TWO levels. This module provides the
scan-level primitive (one linear solve distributed over the mesh), and
``sharded_scan_local`` exposes its per-shard body so that SOLVER-level
sequence parallelism (core/deer_sharded.py — the whole DEER Newton
iteration on time shards, trajectory never replicated) can reuse the exact
same summary/fixup algebra inside its own shard_map, in both time
directions (the reverse scan serves the implicit-diff adjoint).

All operate on leading time axis: lam, b have shape (T, ...) broadcastable.
All collectives resolve through distributed/compat.py (version-portable
shard_map).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from repro.distributed.sharding import make_spec as P

from repro.distributed import compat


def residual_init(dtype=jnp.float32) -> jax.Array:
    """Initial residual carry for a ``tol``-mode while_loop: +inf in the
    float dtype the residual is tracked in (non-float state dtypes — int,
    complex — track the max-abs residual in float32).

    Hoisted here because every tol-mode solver (core/deer, core/deer_sharded,
    core/elk, core/elk_sharded) needs the identical expression; it was
    previously duplicated inline at each while_loop init.
    """
    if not jnp.issubdtype(jnp.dtype(dtype), jnp.floating):
        dtype = jnp.float32
    return jnp.asarray(jnp.inf, dtype)


def _combine(elem_a, elem_b):
    """Associative combine for affine maps  x -> a*x + b.

    Composition (apply a then b):  x -> a2*(a1*x + b1) + b2
    => (a1, b1) . (a2, b2) = (a1*a2, a2*b1 + b2)
    """
    a1, b1 = elem_a
    a2, b2 = elem_b
    return a1 * a2, a2 * b1 + b2


def diag_linear_scan(lam: jax.Array, b: jax.Array, x0: jax.Array | None = None,
                     *, axis: int = 0, reverse: bool = False) -> jax.Array:
    """Solve x_t = lam_t * x_{t-1} + b_t in parallel over axis ``axis``.

    Args:
      lam: (T, ...) multiplicative coefficients.
      b:   (T, ...) additive coefficients.
      x0:  initial state (...,) or None for zero init.
      reverse: solve the time-reversed recurrence (used by the adjoint pass).

    Returns:
      states x_{1..T}, same shape as b.
    """
    if x0 is not None:
        # Fold x0 into the first step: x_1 = lam_1 * x0 + b_1.
        if reverse:
            idx = [slice(None)] * b.ndim
            idx[axis] = slice(-1, None)
            b = jnp.concatenate(
                [b[tuple(slice(None) if i != axis else slice(None, -1) for i in range(b.ndim))],
                 b[tuple(idx)] + lam[tuple(idx)] * x0[None]], axis=axis)
        else:
            first = tuple(slice(None) if i != axis else slice(0, 1) for i in range(b.ndim))
            rest = tuple(slice(None) if i != axis else slice(1, None) for i in range(b.ndim))
            b = jnp.concatenate([b[first] + lam[first] * x0[None], b[rest]], axis=axis)
    _, states = jax.lax.associative_scan(_combine, (lam, b), axis=axis, reverse=reverse)
    return states


def diag_linear_scan_seq(lam: jax.Array, b: jax.Array,
                         x0: jax.Array | None = None) -> jax.Array:
    """Sequential oracle: identical contract to ``diag_linear_scan`` (axis 0)."""
    if x0 is None:
        x0 = jnp.zeros(b.shape[1:], b.dtype)

    def step(carry, lb):
        lam_t, b_t = lb
        x = lam_t * carry + b_t
        return x, x

    _, states = jax.lax.scan(step, x0, (lam, b))
    return states


def chunked_diag_scan(lam: jax.Array, b: jax.Array, x0: jax.Array | None = None,
                      *, chunk: int = 256) -> jax.Array:
    """Two-level blocked scan: intra-chunk associative scan (parallel) +
    inter-chunk sequential carry via lax.scan.

    This mirrors the TPU Pallas kernel's schedule (VMEM-resident chunks with a
    sequential carry) and bounds the associative-scan workspace to
    O(chunk * D) instead of O(T * D) — the memory-side optimisation recorded
    in EXPERIMENTS.md §Perf.
    """
    T = lam.shape[0]
    if chunk <= 0 or T % chunk != 0:
        return diag_linear_scan(lam, b, x0)
    n = T // chunk
    lam_c = lam.reshape((n, chunk) + lam.shape[1:])
    b_c = b.reshape((n, chunk) + b.shape[1:])
    # Per-chunk cumulative affine maps (parallel over chunks).
    A_cum, B_cum = jax.lax.associative_scan(_combine, (lam_c, b_c), axis=1)

    def carry_step(carry, ab):
        a_cum, b_cum = ab                       # (chunk, ...)
        states = a_cum * carry + b_cum          # apply incoming carry
        new_carry = states[-1]
        return new_carry, states

    init = jnp.zeros(b.shape[1:], b.dtype) if x0 is None else x0.astype(b.dtype)
    _, states = jax.lax.scan(carry_step, init, (A_cum, B_cum))
    return states.reshape(lam.shape[0:1] + b.shape[1:])


def sharded_scan_local(lam_s: jax.Array, b_s: jax.Array,
                       x0: jax.Array | None, seq_axis, *,
                       reverse: bool = False) -> jax.Array:
    """Per-shard body of the sequence-parallel scan. MUST run inside a
    shard_map whose time axis is sharded over ``seq_axis`` (a mesh axis name
    or a tuple of them — the time dimension is then sharded over the
    row-major-flattened product axis, matching ``P(seq_axis)``).

    Forward (reverse=False): solves x_t = lam_t * x_{t-1} + b_t globally,
    with x_0 := ``x0`` (replicated; None = zero). Each shard computes its
    local cumulative affine map (O(T/P) work, O(log T/P) depth), the
    per-shard summaries (one (lam_prod, b_total) pair each) are all-gathered
    (P tiny elements), an exclusive prefix over shards is computed
    redundantly on every device, and applied locally.

    Reverse (reverse=True): solves g_t = lam_t * g_{t+1} + b_t with terminal
    g_{T+1} := ``x0`` (None = zero) — the adjoint recurrence of the
    implicit-diff backward pass, distributed with the mirrored
    suffix-summary fixup.

    Collective volume: 2 * P * D elements per call — independent of T.
    """
    A_cum, B_cum = jax.lax.associative_scan(_combine, (lam_s, b_s), axis=0,
                                            reverse=reverse)
    return sharded_scan_fixup(A_cum, B_cum, x0, seq_axis, reverse=reverse)


def sharded_scan_fixup(A_cum: jax.Array, B_cum: jax.Array,
                       x0: jax.Array | None, seq_axis, *,
                       reverse: bool = False) -> jax.Array:
    """Cross-shard summary exchange + prefix fixup, given the LOCAL cumulative
    affine maps (A_cum, B_cum) along axis 0 (inclusive; from the shard's left
    edge forward, or from its right edge when ``reverse``).

    Factored out of ``sharded_scan_local`` so producers that compute the
    local cumulative maps elsewhere — the fused Pallas DEER kernel
    (kernels/lrc_deer) runs its on-chip chunk scan with a zero carry and
    emits exactly (A_cum, B_cum) — compose with the identical summary/fixup
    algebra. MUST run inside a shard_map sharded over ``seq_axis``.
    """
    idx = compat.axis_index(seq_axis)
    if reverse:
        # Per-shard summary = cumulative map across the whole shard, seen
        # from its LEFT edge (element 0 of the reverse cumulative scan).
        summ_A = compat.all_gather(A_cum[0], seq_axis)     # (P, ...)
        summ_B = compat.all_gather(B_cum[0], seq_axis)
        n = summ_A.shape[0]
        A_suf, B_suf = jax.lax.associative_scan(_combine, (summ_A, summ_B),
                                                axis=0, reverse=True)
        ones = jnp.ones_like(summ_A[0])
        zeros = jnp.zeros_like(summ_B[0])
        # exclusive suffix: state just RIGHT of shard i = shards > i applied
        # to the terminal condition
        last = idx == n - 1
        A_excl = jnp.where(last, ones, A_suf[jnp.minimum(idx + 1, n - 1)])
        B_excl = jnp.where(last, zeros, B_suf[jnp.minimum(idx + 1, n - 1)])
        x_right = B_excl if x0 is None else A_excl * x0 + B_excl
        return A_cum * x_right + B_cum

    summ_A = compat.all_gather(A_cum[-1], seq_axis)        # (P, ...)
    summ_B = compat.all_gather(B_cum[-1], seq_axis)
    A_pref, B_pref = jax.lax.associative_scan(_combine, (summ_A, summ_B),
                                              axis=0)
    # prefix state BEFORE shard i = combine of shards < i applied to x0
    ones = jnp.ones_like(summ_A[0])
    zeros = jnp.zeros_like(summ_B[0])
    A_excl = jnp.where(idx == 0, ones, A_pref[jnp.maximum(idx - 1, 0)])
    B_excl = jnp.where(idx == 0, zeros, B_pref[jnp.maximum(idx - 1, 0)])
    x_left = B_excl if x0 is None else A_excl * x0 + B_excl
    return A_cum * x_left + B_cum


def sharded_diag_scan(lam: jax.Array, b: jax.Array, x0: jax.Array,
                      *, mesh, seq_axis) -> jax.Array:
    """Sequence-parallel diagonal scan: shard_map over ``sharded_scan_local``.

    The time axis is sharded over mesh axis ``seq_axis`` — a name or a tuple
    of names (e.g. ``("data", "model")`` engages the whole mesh for a
    batch=1 long-sequence cell); P = product of the axis sizes. Collective
    volume is 2 * P * D elements per call — independent of T.
    """
    pspec = P(seq_axis)
    return compat.shard_map(
        lambda lam_s, b_s, x0_s: sharded_scan_local(lam_s, b_s, x0_s,
                                                    seq_axis),
        mesh=mesh,
        in_specs=(pspec, pspec, P()),
        out_specs=pspec,
    )(lam, b, x0)


def scan_flops(T: int, D: int) -> int:
    """Work of one parallel scan (for roofline napkin math): ~3*T*D mul-adds
    per Blelloch up+down sweep against 2*T*D for the sequential oracle."""
    return 6 * T * D

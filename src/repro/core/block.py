"""LrcSSM block architecture (Figure 1) and the sequence-classification model.

    input (B, T, p)
      -> input encoder (dense p -> H) -> pre-norm
      -> [ LrcSSM block ] x L:
             norm -> nonlinear SSM core (DEER-parallel solve, state dim S)
                  -> MLP (S -> H) -> + skip
      -> post-norm -> decoder (mean-pool -> classes | per-step regression)

The SSM core is selectable: "lrc" (the paper's model), "stc", "gru", "mgu",
"lstm" (Appendix D variants) — all solved with the same exact-diagonal DEER
solver, or "elk" solver, or "sequential" (oracle; O(T) depth) for parity
tests and the runtime benchmark (Table 6 comparison).

Long-context scaling: with ``seq_axis`` set (and an active mesh), the DEER
solve itself runs sequence-parallel (core/deer_sharded.py) — the trajectory
is sharded over the mesh for the whole Newton iteration, so per-device
memory is O(T/P * D) instead of O(T * D).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import nn
from repro.core import variants
from repro.core.deer import DeerConfig, deer_solve
from repro.core.elk import ElkConfig, elk_solve
from repro.core.lrc import (LrcCellConfig, init_lrc_params, input_features,
                            lrc_step, lrc_sequential)

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class LrcSSMConfig:
    d_input: int                 # raw input channels p
    d_hidden: int = 64           # encoder width H ("hidden dimension")
    d_state: int = 64            # SSM state width S ("state-space dimension")
    n_blocks: int = 4
    n_classes: int = 2
    cell: str = "lrc"            # lrc | stc | gru | mgu | lstm
    solver: str = "deer"         # deer | elk | sequential
    deer: DeerConfig = DeerConfig()
    elk: ElkConfig = ElkConfig()
    dt: float = 1.0
    rho: Optional[float] = None
    state_dependent_a: bool = True
    state_dependent_b: bool = True
    complex_state_params: bool = False
    pool: str = "mean"           # mean | last  (classification readout)
    param_dtype: Any = jnp.float32
    include_time: bool = False   # append normalised time channel
    # sequence-parallel DEER (core/deer_sharded.py): shard the time axis of
    # the Newton solve over this mesh axis. None = replicated solver. Takes
    # effect only for solver="deer" under an active mesh containing the
    # axis; otherwise falls back to the vmapped replicated path.
    seq_axis: Optional[str] = None


def _cell_cfg(cfg: LrcSSMConfig):
    if cfg.cell == "lrc":
        return LrcCellConfig(
            d_input=cfg.d_hidden, d_state=cfg.d_state, dt=cfg.dt, rho=cfg.rho,
            state_dependent_a=cfg.state_dependent_a,
            state_dependent_b=cfg.state_dependent_b,
            complex_state_params=cfg.complex_state_params,
            param_dtype=cfg.param_dtype)
    return variants.CellConfig(d_input=cfg.d_hidden, d_state=cfg.d_state,
                               dt=cfg.dt, param_dtype=cfg.param_dtype)


def init_lrcssm(cfg: LrcSSMConfig, key: jax.Array) -> Params:
    keys = jax.random.split(key, 3 + cfg.n_blocks)
    d_in = cfg.d_input + (1 if cfg.include_time else 0)
    ccfg = _cell_cfg(cfg)
    p: Params = {
        "encoder": nn.dense_init(keys[0], d_in, cfg.d_hidden, cfg.param_dtype),
        "pre_norm": nn.layernorm_init(cfg.d_hidden, cfg.param_dtype),
        "post_norm": nn.layernorm_init(cfg.d_hidden, cfg.param_dtype),
        "decoder": nn.dense_init(keys[1], cfg.d_hidden, cfg.n_classes,
                                 cfg.param_dtype),
        "blocks": [],
    }
    for i in range(cfg.n_blocks):
        bk = jax.random.split(keys[3 + i], 3)
        if cfg.cell == "lrc":
            cell = init_lrc_params(ccfg, bk[0])
        else:
            cell = variants.CELLS[cfg.cell][0](ccfg, bk[0])
        p["blocks"].append({
            "norm": nn.layernorm_init(cfg.d_hidden, cfg.param_dtype),
            "cell": cell,
            "mlp": nn.mlp_init(bk[1], cfg.d_state, cfg.d_hidden, cfg.d_hidden,
                               cfg.param_dtype),
        })
    return p


def _solve_cell(cfg: LrcSSMConfig, cell_p: Params, h: jax.Array
                ) -> Tuple[jax.Array, jax.Array]:
    """Run the nonlinear SSM over one sequence h: (T, H) -> states (T, S)."""
    ccfg = _cell_cfg(cfg)
    T = h.shape[0]

    if cfg.cell == "lrc":
        feats = input_features(cell_p, h)
        step = lambda x, fs, cp: lrc_step(cp, ccfg, x, *fs)
        x0 = jnp.zeros((cfg.d_state,),
                       ccfg.state_dtype if cfg.complex_state_params else h.dtype)
        if cfg.solver == "sequential":
            return lrc_sequential(cell_p, ccfg, h), jnp.asarray(T, jnp.int32)
    else:
        _, feat_fn, step_fn = variants.CELLS[cfg.cell]
        feats = feat_fn(cell_p, h)
        step = lambda x, fs, cp: step_fn(cp, ccfg, x, *fs)
        x0 = jnp.zeros((cfg.d_state,), h.dtype)
        if cfg.solver == "sequential":
            return (variants.sequential(cfg.cell, cell_p, ccfg, h),
                    jnp.asarray(T, jnp.int32))

    if cfg.solver == "elk":
        states, iters = elk_solve(step, feats, x0, T, cfg.elk, params=cell_p)
    else:
        states, iters = deer_solve(step, feats, x0, T, cfg.deer,
                                   params=cell_p)
    if cfg.complex_state_params:
        states = states.real
    if cfg.cell == "lstm":
        states = variants.lstm_readout(cell_p, states, feats[3])
    return states, iters


def _seq_shard_mesh(cfg: LrcSSMConfig, T: int):
    """The active mesh when the sequence-parallel solve applies, else None."""
    if cfg.seq_axis is None or cfg.solver != "deer":
        return None
    from repro.distributed.sharding import current_mesh
    mesh = current_mesh()
    if (mesh is None or cfg.seq_axis not in mesh.axis_names
            or T % mesh.shape[cfg.seq_axis] != 0):
        return None
    return mesh


def _solve_cell_seq_sharded(cfg: LrcSSMConfig, cell_p: Params, hn: jax.Array,
                            mesh) -> Tuple[jax.Array, jax.Array]:
    """Batched sequence-parallel solve: hn (B, T, H) -> states (B, T, S).

    The batch rides along in the trailing dims ((T, B, ·) layout — every
    cell step is elementwise/matmul-on-last-dim, so the solver is oblivious
    to it), and the TIME axis is sharded over cfg.seq_axis for the whole
    Newton iteration (per-device trajectory (T/P, B, S))."""
    from repro.core.deer_sharded import sharded_deer_solve
    ccfg = _cell_cfg(cfg)
    hT = jnp.swapaxes(hn, 0, 1)                       # (T, B, H)
    T, B = hT.shape[0], hT.shape[1]

    if cfg.cell == "lrc":
        feats = input_features(cell_p, hT)
        step = lambda x, fs, cp: lrc_step(cp, ccfg, x, *fs)
        x0 = jnp.zeros((B, cfg.d_state),
                       ccfg.state_dtype if cfg.complex_state_params
                       else hn.dtype)
    else:
        _, feat_fn, step_fn = variants.CELLS[cfg.cell]
        feats = feat_fn(cell_p, hT)
        step = lambda x, fs, cp: step_fn(cp, ccfg, x, *fs)
        x0 = jnp.zeros((B, cfg.d_state), hn.dtype)

    states, iters = sharded_deer_solve(step, feats, x0, T, cfg.deer,
                                       mesh=mesh, seq_axis=cfg.seq_axis,
                                       params=cell_p)
    if cfg.complex_state_params:
        states = states.real
    if cfg.cell == "lstm":
        states = variants.lstm_readout(cell_p, states, feats[3])
    return jnp.swapaxes(states, 0, 1), iters


def _solve_block(cfg: LrcSSMConfig, cell_p: Params, hn: jax.Array
                 ) -> Tuple[jax.Array, jax.Array]:
    """Solve one block's cell over the batch: (B, T, H) -> ((B, T, S), iters
    scalar). Dispatches to the sequence-parallel solver when configured."""
    mesh = _seq_shard_mesh(cfg, hn.shape[1])
    if mesh is not None:
        return _solve_cell_seq_sharded(cfg, cell_p, hn, mesh)
    states, iters = jax.vmap(lambda seq: _solve_cell(cfg, cell_p, seq))(hn)
    return states, jnp.max(iters)


def apply_lrcssm(cfg: LrcSSMConfig, p: Params, x: jax.Array,
                 return_iters: bool = False):
    """Forward pass. x: (B, T, p) -> logits (B, n_classes)."""
    B, T, _ = x.shape
    if cfg.include_time:
        tch = jnp.broadcast_to(jnp.linspace(0.0, 1.0, T)[None, :, None],
                               (B, T, 1)).astype(x.dtype)
        x = jnp.concatenate([x, tch], axis=-1)

    h = nn.dense(p["encoder"], x)
    h = nn.layernorm(p["pre_norm"], h)

    iters_acc = []
    for blk in p["blocks"]:
        hn = nn.layernorm(blk["norm"], h)
        states, iters = _solve_block(cfg, blk["cell"], hn)
        iters_acc.append(iters)
        h = h + nn.mlp(blk["mlp"], states)

    h = nn.layernorm(p["post_norm"], h)
    if cfg.pool == "mean":
        pooled = jnp.mean(h, axis=1)
    else:
        pooled = h[:, -1]
    logits = nn.dense(p["decoder"], pooled)
    if return_iters:
        return logits, jnp.stack(iters_acc)
    return logits


def apply_lrcssm_regression(cfg: LrcSSMConfig, p: Params, x: jax.Array):
    """Per-sequence scalar regression head (PPG-DaLiA, Table 7)."""
    B, T, _ = x.shape
    h = nn.dense(p["encoder"], x)
    h = nn.layernorm(p["pre_norm"], h)
    for blk in p["blocks"]:
        hn = nn.layernorm(blk["norm"], h)
        states, _ = _solve_block(cfg, blk["cell"], hn)
        h = h + nn.mlp(blk["mlp"], states)
    h = nn.layernorm(p["post_norm"], h)
    return nn.dense(p["decoder"], jnp.mean(h, axis=1))[..., 0]

"""LrcSSM block architecture (Figure 1) and the sequence-classification model.

    input (B, T, p)
      -> input encoder (dense p -> H) -> pre-norm
      -> [ LrcSSM block ] x L:
             norm -> nonlinear SSM core (DEER-parallel solve, state dim S)
                  -> MLP (S -> H) -> + skip
      -> post-norm -> decoder (mean-pool -> classes | per-step regression)

The SSM core is selectable: "lrc" (the paper's model), "stc", "gru", "mgu",
"lstm" (Appendix D variants) — all solved with the same exact-diagonal DEER
solver, or "elk" solver, or "sequential" (oracle; O(T) depth) for parity
tests and the runtime benchmark (Table 6 comparison).

Long-context scaling — the block picks the fastest applicable solver tier
(sharded-fused > fused > sharded-lax > replicated):

  1. sharded-fused   (kernels/lrc_deer): the fused Pallas Newton iteration
     on a local T/P time slice per device, cross-shard prefix fixup between
     kernel invocations; backward = the fused implicit-adjoint kernel
     composed through the same fixup seam in reverse. Requires ``fused`` +
     ``seq_axis`` + an active mesh + the plain-lrc cell form.
  2. fused           (kernels/lrc_deer megakernel): the WHOLE K-iteration
     Newton solve in one Pallas launch, trajectory + Newton carry
     VMEM-resident across iterations (~3 HBM (T,D)-streams per solve);
     same fused-adjoint backward. Requires ``fused`` + the plain-lrc cell
     form; no mesh needed.
  3. sharded-lax     (core/deer_sharded.py / core/elk_sharded.py): the
     whole Newton/ELK solve on time shards — per-device trajectory memory
     O(T/P * D) instead of O(T * D). Requires ``seq_axis`` + an active
     mesh; differentiable (unroll or implicit; the implicit backward uses
     the fused adjoint KERNEL via ``fused_adjoint`` when the cell is in
     the packed-lrc form).
  4. replicated      (core/deer.py / core/elk.py, vmapped over batch).

Kernel tiers run compiled on TPU and in interpret mode elsewhere
(``kernel_interpret`` overrides the auto-detection); their tiling defaults
to the measured/analytic sweep in ``kernels/autotune.py``.  NOTE the tier
order is throughput-ranked: when a fused shard layout is non-viable but
the cell form qualifies, tier 2 replicates the trajectory (single-device
memory bound) rather than falling to the sharded-lax tier — set
``fused=False`` to prefer trajectory sharding over kernel fusion for
memory-bound shapes.

``seq_axis`` may be a mesh-axis name or a TUPLE of names (time sharded over
the flattened product axis — e.g. ("data", "model") engages the whole mesh
for a batch=1 long-sequence cell). Any tier falls back to the next when its
preconditions (mesh axes present, T divisible by the shard count) fail.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import nn
from repro.core import variants
from repro.core.deer import DeerConfig, deer_residual, deer_solve
from repro.core.elk import ElkConfig, elk_solve
from repro.core.lrc import (LrcCellConfig, init_lrc_params, input_features,
                            lrc_step, lrc_sequential)

Params = Dict[str, Any]


class SolveReport(NamedTuple):
    """Per-block solver health, computed ON DEVICE alongside the forward
    pass (``apply_lrcssm(..., return_report=True)``).

    ``iters``: (n_blocks,) Newton/ELK trip counts (= max_iters in fixed
    mode). ``residual``: (n_blocks,) max-norm fixed-point defect
    max_t |x_t - F(x_{t-1})| recomputed from the returned trajectory — 0
    where the check does not apply (sequential solver, lstm readout,
    complex states). ``diverged``: (n_blocks,) bool — True when a
    TOL-MODE solve exhausted its iteration cap with the residual still
    above tol, i.e. the ladder handed back a max-K trajectory that never
    converged. Callers route a True here up as a degradation event
    (tools/chaos_suite.py "solver_divergence") instead of silently using
    the output; in fixed mode the flag is constant False (fixed-K output
    is the documented contract there)."""
    iters: jax.Array
    residual: jax.Array
    diverged: jax.Array


@dataclasses.dataclass(frozen=True)
class LrcSSMConfig:
    d_input: int                 # raw input channels p
    d_hidden: int = 64           # encoder width H ("hidden dimension")
    d_state: int = 64            # SSM state width S ("state-space dimension")
    n_blocks: int = 4
    n_classes: int = 2
    cell: str = "lrc"            # lrc | stc | gru | mgu | lstm
    solver: str = "deer"         # deer | elk | sequential
    deer: DeerConfig = DeerConfig()
    elk: ElkConfig = ElkConfig()
    dt: float = 1.0
    rho: Optional[float] = None
    state_dependent_a: bool = True
    state_dependent_b: bool = True
    complex_state_params: bool = False
    pool: str = "mean"           # mean | last  (classification readout)
    param_dtype: Any = jnp.float32
    include_time: bool = False   # append normalised time channel
    # sequence-parallel solve (core/deer_sharded.py, core/elk_sharded.py):
    # shard the time axis of the Newton/ELK solve over this mesh axis — a
    # name or a tuple of names (time over the flattened product axis). None
    # = replicated solver. Takes effect for solver="deer" | "elk" under an
    # active mesh containing the axes; otherwise falls back to the vmapped
    # replicated path.
    seq_axis: Optional[Any] = None
    # fused-kernel tiers (kernels/lrc_deer): drive the DEER solve with the
    # fused Pallas kernels (sharded-fused > fused megakernel > sharded-lax
    # > replicated). Honoured only for the plain lrc cell (solver="deer",
    # mode="fixed", no rho/damping/jac_clip, real params, both
    # state-dependency flags). Differentiable: the backward pass is the
    # fused implicit-adjoint kernel (IFT gradient at the fixed point —
    # exact at convergence regardless of DeerConfig.grad).
    fused: bool = False
    # backward-pass hook for the SHARDED-LAX tier: replace the implicit
    # adjoint's jvp + reverse-scan segment with the fused adjoint kernel
    # when the cell is in the packed-lrc form (grad="implicit" only).
    fused_adjoint: bool = True
    # Pallas execution mode: None = auto (compiled on TPU, interpreter on
    # CPU hosts); bool forces it. Threaded to every kernel call site.
    kernel_interpret: Optional[bool] = None
    # HBM stream dtype for the fused tiers ("bf16" | "fp8" | None = fp32):
    # s_u/eps_u and the trajectory move through HBM narrow while VMEM
    # accumulation stays fp32 (distributed/precision.py PrecisionPolicy.
    # kernel_io is the serve-side source of this knob). Only the fused
    # Pallas tiers honour it — the lax tiers stream whatever dtype the
    # activations carry.
    kernel_io: Optional[str] = None
    # speculative-decoding DRAFT depth: when > 0 (and below the solver's
    # max_iters), ``apply_lrcssm(..., draft=True)`` truncates the Newton /
    # ELK ladder to this many iterations — a cheap early-exit forward
    # whose output is only ever used as a draft to be verified by the
    # full-depth solve, so the truncation is lossless end-to-end.
    draft_iters: int = 0


def _cell_cfg(cfg: LrcSSMConfig):
    if cfg.cell == "lrc":
        return LrcCellConfig(
            d_input=cfg.d_hidden, d_state=cfg.d_state, dt=cfg.dt, rho=cfg.rho,
            state_dependent_a=cfg.state_dependent_a,
            state_dependent_b=cfg.state_dependent_b,
            complex_state_params=cfg.complex_state_params,
            param_dtype=cfg.param_dtype)
    return variants.CellConfig(d_input=cfg.d_hidden, d_state=cfg.d_state,
                               dt=cfg.dt, param_dtype=cfg.param_dtype)


def init_lrcssm(cfg: LrcSSMConfig, key: jax.Array) -> Params:
    keys = jax.random.split(key, 3 + cfg.n_blocks)
    d_in = cfg.d_input + (1 if cfg.include_time else 0)
    ccfg = _cell_cfg(cfg)
    p: Params = {
        "encoder": nn.dense_init(keys[0], d_in, cfg.d_hidden, cfg.param_dtype),
        "pre_norm": nn.layernorm_init(cfg.d_hidden, cfg.param_dtype),
        "post_norm": nn.layernorm_init(cfg.d_hidden, cfg.param_dtype),
        "decoder": nn.dense_init(keys[1], cfg.d_hidden, cfg.n_classes,
                                 cfg.param_dtype),
        "blocks": [],
    }
    for i in range(cfg.n_blocks):
        bk = jax.random.split(keys[3 + i], 3)
        if cfg.cell == "lrc":
            cell = init_lrc_params(ccfg, bk[0])
        else:
            cell = variants.CELLS[cfg.cell][0](ccfg, bk[0])
        p["blocks"].append({
            "norm": nn.layernorm_init(cfg.d_hidden, cfg.param_dtype),
            "cell": cell,
            "mlp": nn.mlp_init(bk[1], cfg.d_state, cfg.d_hidden, cfg.d_hidden,
                               cfg.param_dtype),
        })
    return p


def _solve_cell(cfg: LrcSSMConfig, cell_p: Params, h: jax.Array
                ) -> Tuple[jax.Array, jax.Array]:
    """Run the nonlinear SSM over one sequence h: (T, H) -> states (T, S)."""
    ccfg = _cell_cfg(cfg)
    T = h.shape[0]

    if cfg.cell == "lrc":
        feats = input_features(cell_p, h)
        step = lambda x, fs, cp: lrc_step(cp, ccfg, x, *fs)
        x0 = jnp.zeros((cfg.d_state,),
                       ccfg.state_dtype if cfg.complex_state_params else h.dtype)
        if cfg.solver == "sequential":
            return lrc_sequential(cell_p, ccfg, h), jnp.asarray(T, jnp.int32)
    else:
        _, feat_fn, step_fn = variants.CELLS[cfg.cell]
        feats = feat_fn(cell_p, h)
        step = lambda x, fs, cp: step_fn(cp, ccfg, x, *fs)
        x0 = jnp.zeros((cfg.d_state,), h.dtype)
        if cfg.solver == "sequential":
            return (variants.sequential(cfg.cell, cell_p, ccfg, h),
                    jnp.asarray(T, jnp.int32))

    if cfg.solver == "elk":
        states, iters = elk_solve(step, feats, x0, T, cfg.elk, params=cell_p)
    else:
        states, iters = deer_solve(step, feats, x0, T, cfg.deer,
                                   params=cell_p)
    if cfg.complex_state_params:
        states = states.real
    if cfg.cell == "lstm":
        states = variants.lstm_readout(cell_p, states, feats[3])
    return states, iters


def _with_policy_seq_axis(cfg: LrcSSMConfig) -> LrcSSMConfig:
    """``cfg.seq_axis`` (the legacy per-block spelling) wins when set;
    otherwise the ambient ShardingPolicy's ``seq_axis`` applies — the one
    policy object configures sequence parallelism for every block."""
    if cfg.seq_axis is not None:
        return cfg
    from repro.distributed.sharding import current_policy
    policy = current_policy()
    if policy is None or policy.seq_axis is None:
        return cfg
    return dataclasses.replace(cfg, seq_axis=policy.seq_axis)


def _seq_shard_mesh(cfg: LrcSSMConfig, T: int):
    """The active mesh when the sequence-parallel solve applies, else None."""
    if cfg.seq_axis is None or cfg.solver not in ("deer", "elk"):
        return None
    from repro.core.deer_sharded import n_seq_shards
    from repro.distributed.sharding import current_mesh, in_manual_body
    if in_manual_body():
        # inside the fully-manual explicit seam: already per-device, the
        # solver must not open a nested shard_map
        return None
    mesh = current_mesh()
    if mesh is None:
        return None
    n = n_seq_shards(mesh, cfg.seq_axis)
    if n == 0 or T % n != 0:
        return None
    return mesh


def _fused_applicable(cfg: LrcSSMConfig) -> bool:
    """The fused Pallas tiers cover exactly the kernel's closed-form cell:
    plain real-parameter lrc with both state-dependency flags, fixed-count
    undamped Newton."""
    d = cfg.deer
    return (cfg.fused and _lrc_kernel_form(cfg)
            and d.mode == "fixed" and d.damping == 1.0 and d.jac_clip is None)


def _lrc_kernel_form(cfg: LrcSSMConfig) -> bool:
    """True when the cell's step function is the packed-lrc closed form the
    Pallas kernels implement (the fused-adjoint precondition)."""
    return (cfg.cell == "lrc" and cfg.solver == "deer"
            and cfg.rho is None and cfg.state_dependent_a
            and cfg.state_dependent_b and not cfg.complex_state_params)


def _fold_cell_inputs(cfg: LrcSSMConfig, cell_p: Params, hn: jax.Array):
    """(B, T, H) -> the kernels' folded (T, B*S) inputs: input features in
    time-major layout, then the shared batch-into-channel fold
    (``ops.fold_channel_batch``)."""
    from repro.kernels.lrc_deer.ops import fold_channel_batch
    B, T, _ = hn.shape
    hT = jnp.swapaxes(hn, 0, 1)                       # (T, B, H)
    s_u, eps_u = input_features(cell_p, hT)           # (T, B, S)
    suf, euf, pp, x0 = fold_channel_batch(s_u, eps_u, cell_p)
    return suf, euf, pp, x0.astype(hn.dtype), B, T, cfg.d_state


def _solve_cell_fused_sharded(cfg: LrcSSMConfig, cell_p: Params,
                              hn: jax.Array, mesh
                              ) -> Tuple[jax.Array, jax.Array]:
    """Sharded-fused tier: (B, T, H) -> (B, T, S) with the fused Pallas
    Newton iteration on time shards (fused-adjoint backward through the
    same cross-shard fixup seam)."""
    from repro.kernels.lrc_deer.ops import sharded_lrc_deer_solve
    s_u, eps_u, pp, x0, B, T, S = _fold_cell_inputs(cfg, cell_p, hn)
    states = sharded_lrc_deer_solve(
        s_u, eps_u, pp, x0, mesh=mesh, seq_axis=cfg.seq_axis,
        n_iters=cfg.deer.max_iters, dt=cfg.dt,
        interpret=cfg.kernel_interpret, io_dtype=cfg.kernel_io)
    states = jnp.swapaxes(states.reshape(T, B, S), 0, 1)
    return states, jnp.asarray(cfg.deer.max_iters, jnp.int32)


def _solve_cell_fused(cfg: LrcSSMConfig, cell_p: Params, hn: jax.Array
                      ) -> Tuple[jax.Array, jax.Array]:
    """Fused (replicated megakernel) tier: the whole K-iteration Newton
    solve in ONE Pallas launch, trajectory VMEM-resident across
    iterations; autotuned tiling; fused-adjoint backward."""
    from repro.kernels.lrc_deer.ops import lrc_deer_solve
    s_u, eps_u, pp, x0, B, T, S = _fold_cell_inputs(cfg, cell_p, hn)
    states = lrc_deer_solve(
        s_u, eps_u, pp, x0, n_iters=cfg.deer.max_iters, dt=cfg.dt,
        interpret=cfg.kernel_interpret, io_dtype=cfg.kernel_io)
    states = jnp.swapaxes(states.reshape(T, B, S), 0, 1)
    return states, jnp.asarray(cfg.deer.max_iters, jnp.int32)


def _solve_cell_seq_sharded(cfg: LrcSSMConfig, cell_p: Params, hn: jax.Array,
                            mesh) -> Tuple[jax.Array, jax.Array]:
    """Batched sequence-parallel solve: hn (B, T, H) -> states (B, T, S).

    The batch rides along in the trailing dims ((T, B, ·) layout — every
    cell step is elementwise/matmul-on-last-dim, so the solver is oblivious
    to it), and the TIME axis is sharded over cfg.seq_axis for the whole
    Newton (solver="deer") or ELK (solver="elk") iteration (per-device
    trajectory (T/P, B, S))."""
    from repro.core.deer_sharded import sharded_deer_solve
    from repro.core.elk_sharded import sharded_elk_solve
    ccfg = _cell_cfg(cfg)
    hT = jnp.swapaxes(hn, 0, 1)                       # (T, B, H)
    T, B = hT.shape[0], hT.shape[1]

    if cfg.cell == "lrc":
        feats = input_features(cell_p, hT)
        step = lambda x, fs, cp: lrc_step(cp, ccfg, x, *fs)
        x0 = jnp.zeros((B, cfg.d_state),
                       ccfg.state_dtype if cfg.complex_state_params
                       else hn.dtype)
    else:
        _, feat_fn, step_fn = variants.CELLS[cfg.cell]
        feats = feat_fn(cell_p, hT)
        step = lambda x, fs, cp: step_fn(cp, ccfg, x, *fs)
        x0 = jnp.zeros((B, cfg.d_state), hn.dtype)

    if cfg.solver == "elk":
        states, iters = sharded_elk_solve(step, feats, x0, T, cfg.elk,
                                          mesh=mesh, seq_axis=cfg.seq_axis,
                                          params=cell_p)
    else:
        fused_scan = None
        if (cfg.fused_adjoint and cfg.deer.grad == "implicit"
                and _lrc_kernel_form(cfg)):
            from repro.kernels.lrc_deer.ops import make_fused_adjoint_scans
            _, fused_scan = make_fused_adjoint_scans(
                dt=cfg.dt, interpret=cfg.kernel_interpret)
        states, iters = sharded_deer_solve(step, feats, x0, T, cfg.deer,
                                           mesh=mesh, seq_axis=cfg.seq_axis,
                                           params=cell_p,
                                           fused_scan=fused_scan)
    if cfg.complex_state_params:
        states = states.real
    if cfg.cell == "lstm":
        states = variants.lstm_readout(cell_p, states, feats[3])
    return jnp.swapaxes(states, 0, 1), iters


def _solve_block(cfg: LrcSSMConfig, cell_p: Params, hn: jax.Array
                 ) -> Tuple[jax.Array, jax.Array]:
    """Solve one block's cell over the batch: (B, T, H) -> ((B, T, S), iters
    scalar). Tier order: sharded-fused > fused (replicated megakernel) >
    sharded-lax > replicated — a tier whose preconditions fail falls to
    the NEXT tier."""
    cfg = _with_policy_seq_axis(cfg)
    mesh = _seq_shard_mesh(cfg, hn.shape[1])
    if _fused_applicable(cfg):
        if mesh is not None:
            from repro.kernels.lrc_deer.ops import sharded_fused_viable
            # same (D, K) the solve will resolve its tiling with, so the
            # viability answer matches what actually runs
            if sharded_fused_viable(hn.shape[1], mesh, cfg.seq_axis,
                                    D=hn.shape[0] * cfg.d_state,
                                    n_iters=cfg.deer.max_iters):
                return _solve_cell_fused_sharded(cfg, cell_p, hn, mesh)
        return _solve_cell_fused(cfg, cell_p, hn)
    if mesh is not None:
        return _solve_cell_seq_sharded(cfg, cell_p, hn, mesh)
    states, iters = jax.vmap(lambda seq: _solve_cell(cfg, cell_p, seq))(hn)
    return states, jnp.max(iters)


def _residual_applies(cfg: LrcSSMConfig) -> bool:
    """Static (trace-time) gate for the residual diagnostic: the returned
    trajectory must BE the raw fixed-point iterate — sequential solves
    have no defect by construction, the lstm readout transforms states,
    and complex-state solves return ``.real`` projections."""
    return (cfg.solver in ("deer", "elk") and cfg.cell != "lstm"
            and not cfg.complex_state_params)


def _block_residual(cfg: LrcSSMConfig, cell_p: Params, hn: jax.Array,
                    states: jax.Array) -> jax.Array:
    """Max-norm fixed-point defect of one block's solve, over the batch:
    rebuilds the cell's step/features exactly as ``_solve_cell`` does and
    evaluates ``deer_residual`` per sequence. One extra step-function
    evaluation per block — only paid when a report is requested."""
    ccfg = _cell_cfg(cfg)
    if cfg.cell == "lrc":
        feat_fn = functools.partial(input_features, cell_p)
        step = lambda x, fs, cp: lrc_step(cp, ccfg, x, *fs)
    else:
        _, ffn, step_fn = variants.CELLS[cfg.cell]
        feat_fn = functools.partial(ffn, cell_p)
        step = lambda x, fs, cp: step_fn(cp, ccfg, x, *fs)

    def one(seq, st):
        x0 = jnp.zeros((cfg.d_state,), st.dtype)
        return deer_residual(step, feat_fn(seq), x0, st, params=cell_p)
    return jnp.max(jax.vmap(one)(hn, states))


def draft_config(cfg: LrcSSMConfig) -> LrcSSMConfig:
    """The early-exit DRAFT variant of ``cfg``: Newton/ELK ladders
    truncated to ``cfg.draft_iters`` (fixed mode — no tol early-outs to
    keep the draft cost deterministic). Identity when draft_iters is 0 or
    does not actually truncate."""
    di = cfg.draft_iters
    if di <= 0:
        return cfg
    reps = {}
    if di < cfg.deer.max_iters:
        reps["deer"] = dataclasses.replace(cfg.deer, max_iters=di,
                                           mode="fixed")
    if di < cfg.elk.max_iters:
        reps["elk"] = dataclasses.replace(cfg.elk, max_iters=di,
                                          mode="fixed")
    return dataclasses.replace(cfg, **reps) if reps else cfg


def apply_lrcssm(cfg: LrcSSMConfig, p: Params, x: jax.Array,
                 return_iters: bool = False, draft: bool = False,
                 return_report: bool = False):
    """Forward pass. x: (B, T, p) -> logits (B, n_classes).
    ``draft=True`` runs the ``draft_config`` truncated-solver variant.
    ``return_report=True`` returns (logits, :class:`SolveReport`) — the
    per-block iteration counts, fixed-point residuals, and tol-mode
    divergence flags, all device-side (no sync added to the forward).
    The flags are STATIC in shape: when the diagnostic does not apply
    (see ``_residual_applies``) the residual/diverged entries are
    constant zeros, so requesting a report never changes compile
    geometry across configs."""
    if draft:
        cfg = draft_config(cfg)
    B, T, _ = x.shape
    if cfg.include_time:
        tch = jnp.broadcast_to(jnp.linspace(0.0, 1.0, T)[None, :, None],
                               (B, T, 1)).astype(x.dtype)
        x = jnp.concatenate([x, tch], axis=-1)

    h = nn.dense(p["encoder"], x)
    h = nn.layernorm(p["pre_norm"], h)

    check = return_report and _residual_applies(cfg)
    tol_mode = ((cfg.elk.mode if cfg.solver == "elk" else cfg.deer.mode)
                == "tol")
    tol = cfg.elk.tol if cfg.solver == "elk" else cfg.deer.tol
    iters_acc = []
    res_acc = []
    for blk in p["blocks"]:
        hn = nn.layernorm(blk["norm"], h)
        states, iters = _solve_block(cfg, blk["cell"], hn)
        iters_acc.append(iters)
        if check:
            res_acc.append(_block_residual(cfg, blk["cell"], hn, states))
        elif return_report:
            res_acc.append(jnp.asarray(0.0, h.dtype))
        h = h + nn.mlp(blk["mlp"], states)

    h = nn.layernorm(p["post_norm"], h)
    if cfg.pool == "mean":
        pooled = jnp.mean(h, axis=1)
    else:
        pooled = h[:, -1]
    logits = nn.dense(p["decoder"], pooled)
    if return_report:
        residual = jnp.stack(res_acc)
        diverged = (residual > tol if (check and tol_mode)
                    else jnp.zeros((cfg.n_blocks,), bool))
        return logits, SolveReport(jnp.stack(iters_acc), residual, diverged)
    if return_iters:
        return logits, jnp.stack(iters_acc)
    return logits


def apply_lrcssm_regression(cfg: LrcSSMConfig, p: Params, x: jax.Array):
    """Per-sequence scalar regression head (PPG-DaLiA, Table 7)."""
    B, T, _ = x.shape
    h = nn.dense(p["encoder"], x)
    h = nn.layernorm(p["pre_norm"], h)
    for blk in p["blocks"]:
        hn = nn.layernorm(blk["norm"], h)
        states, _ = _solve_block(cfg, blk["cell"], hn)
        h = h + nn.mlp(blk["mlp"], states)
    h = nn.layernorm(p["post_norm"], h)
    return nn.dense(p["decoder"], jnp.mean(h, axis=1))[..., 0]

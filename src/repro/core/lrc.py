"""LrcSSM cell — the paper's primary contribution (Sec. 3.1, Eqs. 8-14).

Liquid-Resistance Liquid-Capacitance networks with an *inherently diagonal*
Jacobian: the state-dependent parts of the forget conductance f*, update
conductance z*, and elastance eps* depend only on the neuron's own state x_i
(self-loop synapses), while the input-dependent parts see the full input u.

Continuous dynamics (Eq. 11):

    dx_i/dt = -sigma(f*_i) sigma(eps*_i) x_i + tanh(z*_i) sigma(eps*_i) e_leak_i

Discretised with explicit Euler, step dt (Eq. 7):

    x_t = x_{t-1} + dt * dx(x_{t-1}, u_t)
        = lam(x_{t-1}, u_t) * x_{t-1} + beta(x_{t-1}, u_t)

with  lam = 1 - dt * sigma(f*) * sigma(eps*)   in (1 - dt, 1)   (dt <= 1 => lam in (0,1))
      beta = dt * tanh(z*) * sigma(eps*) * e_leak.

Because f*, z*, eps* are elementwise in x, the step function's Jacobian
d step / d x_{t-1} is diagonal BY CONSTRUCTION — this is what makes the DEER
Newton iteration exact (not quasi) and lets each iteration be a single
diagonal linear scan.

Key performance property exploited throughout: the input-dependent gate
features

    s_u   = sigma(u @ a_u + b_u)          (T, D)
    eps_u = u @ w_u + v_u                 (T, D)

do NOT change across Newton iterations, so they are computed once per
sequence (two matmuls) and every Newton iteration is purely elementwise
O(T*D) work + one scan. That is the property the fused Pallas kernel
(kernels/lrc_deer) exploits: HBM traffic per iteration is 2 reads + 1 write
of (T, D) instead of re-running projections.

Parameters follow the paper's naming; all are real by default with an
optional complex extension (Appendix E, Table 11) for the state-coupled set
{g_max_x, k_max_x, a_x, b_x}.

Stability (Appendix A.1): lam is optionally clamped to (0, rho], rho<1 via
``rho`` (tanh-clamp parametrisation), giving the formal gradient bound
|grad_{x_tau} L| <= rho^{T-tau} |grad_{x_T} L|.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


Params = Dict[str, jax.Array]


@dataclasses.dataclass(frozen=True)
class LrcCellConfig:
    d_input: int
    d_state: int
    dt: float = 1.0
    rho: Optional[float] = None          # spectral-radius clamp; None = raw Euler
    state_dependent_a: bool = True       # ablation Table 10: A(x,u) vs A(u)
    state_dependent_b: bool = True       # ablation Table 10: b(x,u) vs b(u)
    complex_state_params: bool = False   # ablation Table 11
    param_dtype: Any = jnp.float32

    @property
    def state_dtype(self):
        return jnp.complex64 if self.complex_state_params else self.param_dtype


def init_lrc_params(cfg: LrcCellConfig, key: jax.Array) -> Params:
    """Initialise per-cell parameters.

    Initialisation keeps gates in their linear regime (small weights) and the
    leak terms positive, matching the reference implementation's behaviour:
    lam starts near 1 - dt*sigma(0)*sigma(0) ~ 0.75 for dt=1 — comfortably
    contractive.
    """
    D, n = cfg.d_state, cfg.d_input
    ks = jax.random.split(key, 8)
    pdt = cfg.param_dtype
    sdt = cfg.state_dtype

    def dense(k, shape, scale):
        return (jax.random.normal(k, shape) * scale).astype(pdt)

    p = {
        # -- state-dependent (self-loop) parameters: all (D,) vectors --------
        "a_x": dense(ks[0], (D,), 1.0).astype(sdt),
        "b_x": jnp.zeros((D,), sdt),
        "g_max_x": dense(ks[1], (D,), 0.5).astype(sdt),
        "k_max_x": dense(ks[2], (D,), 0.5).astype(sdt),
        "w_x": dense(ks[3], (D,), 0.5),
        "v_x": jnp.zeros((D,), pdt),
        # -- input-dependent (cross-input) parameters -------------------------
        "a_u": dense(ks[4], (n, D), (1.0 / max(n, 1)) ** 0.5),
        "b_u": jnp.zeros((D,), pdt),
        "g_max_u": dense(ks[5], (D,), 0.5),
        "k_max_u": dense(ks[6], (D,), 0.5),
        "w_u": dense(ks[7], (n, D), (1.0 / max(n, 1)) ** 0.5),
        "v_u": jnp.zeros((D,), pdt),
        # -- leaks -------------------------------------------------------------
        "g_leak": jnp.full((D,), 0.1, pdt),
        "e_leak": jnp.ones((D,), pdt),
    }
    return p


def input_features(p: Params, u: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Precompute the input-dependent gate features (invariant across Newton
    iterations). u: (T, n) -> (s_u, eps_u) each (T, D)."""
    s_u = jax.nn.sigmoid(u @ p["a_u"] + p["b_u"])
    eps_u = u @ p["w_u"] + p["v_u"]
    return s_u, eps_u


def lrc_step(p: Params, cfg: LrcCellConfig, x_prev: jax.Array,
             s_u: jax.Array, eps_u: jax.Array) -> jax.Array:
    """One Euler step of Eq. 11: x_t = f(x_{t-1}, u_t).

    Elementwise over all axes; x_prev/s_u/eps_u broadcast together, typically
    (T, D) during DEER or (D,) during sequential decoding.
    """
    lam, beta = lrc_gates(p, cfg, x_prev, s_u, eps_u)
    return lam * x_prev + beta


def lrc_gates(p: Params, cfg: LrcCellConfig, x: jax.Array,
              s_u: jax.Array, eps_u: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Compute (lam, beta) of the affine-in-x_prev form of one Euler step.

    NOTE: lam, beta still depend (nonlinearly) on x — this is what makes the
    model nonlinear and requires the DEER fixed-point iteration.
    """
    if cfg.state_dependent_a or cfg.state_dependent_b:
        xs = x.real if (cfg.complex_state_params and not jnp.iscomplexobj(x)) else x
        s_x = jax.nn.sigmoid(p["a_x"] * xs + p["b_x"])
    else:
        s_x = 0.0

    if cfg.state_dependent_a:
        f = p["g_max_x"] * s_x + p["g_max_u"] * s_u + p["g_leak"]
        eps = p["w_x"] * _re(x) + p["v_x"] + eps_u
    else:
        f = p["g_max_u"] * s_u + p["g_leak"]
        eps = p["v_x"] + eps_u

    if cfg.state_dependent_b:
        z = p["k_max_x"] * s_x + p["k_max_u"] * s_u + p["g_leak"]
    else:
        z = p["k_max_u"] * s_u + p["g_leak"]

    sig_f = jax.nn.sigmoid(_re_c(f))
    sig_e = jax.nn.sigmoid(eps)
    tau_z = jnp.tanh(_re_c(z))

    lam = 1.0 - cfg.dt * sig_f * sig_e
    if cfg.rho is not None:
        # tanh-clamp parametrisation of Appendix A.1: |lam| <= rho < 1.
        lam = cfg.rho * jnp.tanh(lam / cfg.rho)
    beta = cfg.dt * tau_z * sig_e * p["e_leak"]
    return lam, beta


def _re(x):
    return x.real if jnp.iscomplexobj(x) else x


def _re_c(x):
    # complex-parameter ablation: gates of complex pre-activations act on the
    # real part (Table 11 setup); keeps lam real so stability analysis holds.
    return x.real if jnp.iscomplexobj(x) else x


def lrc_step_and_diag_jac(p: Params, cfg: LrcCellConfig, x_prev: jax.Array,
                          s_u: jax.Array, eps_u: jax.Array
                          ) -> Tuple[jax.Array, jax.Array]:
    """Return (f(x_prev), diag Jacobian df/dx_prev) — exact, via one jvp.

    Because the step is elementwise in x_prev, J is diagonal by construction
    and J @ ones == diag(J); a single jvp evaluates both the step and its
    exact diagonal derivative in one fused forward pass (cheaper than
    vmap(grad) and exactly what Algorithm 1 line 7 needs — line 8's DIAG() is
    a no-op for this model, the paper's central claim).
    """
    fn = lambda x: lrc_step(p, cfg, x, s_u, eps_u)
    ones = jnp.ones_like(x_prev)
    f, jac_diag = jax.jvp(fn, (x_prev,), (ones,))
    return f, jac_diag


def lrc_sequential(p: Params, cfg: LrcCellConfig, u: jax.Array,
                   x0: Optional[jax.Array] = None) -> jax.Array:
    """Ground-truth sequential rollout (O(T) depth). Oracle for DEER tests and
    the per-token path used in serving/decode (state is O(D))."""
    s_u, eps_u = input_features(p, u)
    D = cfg.d_state
    if x0 is None:
        x0 = jnp.zeros((D,), cfg.state_dtype if cfg.complex_state_params else u.dtype)

    def step(x, feats):
        su_t, eu_t = feats
        x_new = lrc_step(p, cfg, x, su_t, eu_t)
        return x_new, x_new

    _, xs = jax.lax.scan(step, x0, (s_u, eps_u))
    return xs

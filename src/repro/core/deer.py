"""DEER parallel solver for nonlinear diagonal recurrences (Algorithm 1).

Solves the fixed-point problem

    x_t = F(x_{t-1}, u_t),    t = 1..T

for an *elementwise-in-state* step function F (diagonal Jacobian by model
design — LrcSSM, and the Gru/Mgu/Lstm/Stc-SSM variants). Each Newton
iteration linearises F around the current trajectory guess and solves the
resulting diagonal linear recurrence with a parallel scan:

    J_t  = dF/dx |_{x_guess_{t-1}}           (diagonal, exact — one jvp)
    b_t  = F(x_guess_{t-1}) - J_t x_guess_{t-1}
    x    <- parallel_scan(J, b, x0)

Sequential depth per iteration: O(log T). The iteration is EXACT Newton (no
quasi-approximation) precisely because J is diagonal by construction
(paper Sec. 3).

Differentiation modes:
  * ``unroll``   — plain BPTT through K unrolled Newton iterations
                   (memory O(K*T*D)); faithful to the reference code.
  * ``implicit`` — custom_vjp via the implicit function theorem at the fixed
                   point. The adjoint is ITSELF a diagonal linear recurrence
                   run in reverse, solved with one more parallel scan.
                   Memory O(T*D), backward cost = 1 scan + 1 vjp — a
                   beyond-paper optimisation recorded in EXPERIMENTS.md §Perf.

Convergence control:
  * ``fixed``    — K iterations, lax.fori_loop (static; what the dry-run
                   lowers, and what a production TPU step uses).
  * ``tol``      — lax.while_loop on max|x_new - x| > tol with iteration cap
                   (paper Algorithm 1 / Figure 2 measurement mode).  The
                   reported n_iters is the while_loop trip count for BOTH
                   grad modes (grad="implicit" stays differentiable here —
                   the custom_vjp never differentiates through the loop).

Damping: optional trust-region-free step damping x <- (1-d) x + d x_new, and
optional clamping |J| <= rho for guaranteed-contractive iterations
(cheap stabilisation; full ELK lives in core/elk.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.scan import (chunked_diag_scan, diag_linear_scan,
                             residual_init)

# StepFn: (x_prev, feats[, params]) -> x_next, elementwise in x_prev.
# feats is an arbitrary pytree of per-timestep features, leading axis T.
# params (optional pytree) must be passed EXPLICITLY (not closed over) when
# gradients w.r.t. cell parameters are needed: the implicit-diff custom_vjp
# cannot differentiate closed-over values.
StepFn = Callable[..., jax.Array]


@dataclasses.dataclass(frozen=True)
class DeerConfig:
    max_iters: int = 12
    tol: float = 1e-6
    mode: str = "fixed"          # "fixed" | "tol"
    grad: str = "implicit"       # "implicit" | "unroll"
    damping: float = 1.0         # 1.0 = full Newton step
    jac_clip: Optional[float] = None   # clamp |J| for iteration stability
    scan_chunk: int = 0          # >0: use chunked (VMEM-schedule) scan
    unroll: bool = False         # unroll the Newton loop (exact-HLO mode)


def _shift_right(x: jax.Array, x0: jax.Array) -> jax.Array:
    """states[t-1] with states[-1] := x0. x: (T, ...), x0: (...)."""
    return jnp.concatenate([x0[None], x[:-1]], axis=0)


def _newton_iteration(step_fn: StepFn, feats, params, x0, states,
                      cfg: DeerConfig):
    shifted = _shift_right(states, x0)
    fn = lambda xs: step_fn(xs, feats, params)
    ones = jnp.ones_like(shifted)
    # One jvp = value + exact diagonal Jacobian (J @ 1 == diag(J)).
    f_s, jac = jax.jvp(fn, (shifted,), (ones,))
    if cfg.jac_clip is not None:
        jac = jnp.clip(jac, -cfg.jac_clip, cfg.jac_clip)
    b_s = f_s - jac * shifted
    if cfg.scan_chunk > 0:
        new_states = chunked_diag_scan(jac, b_s, x0, chunk=cfg.scan_chunk)
    else:
        new_states = diag_linear_scan(jac, b_s, x0)
    if cfg.damping != 1.0:
        new_states = (1.0 - cfg.damping) * states + cfg.damping * new_states
    return new_states


def deer_solve(step_fn: StepFn, feats, x0: jax.Array, T: int,
               cfg: DeerConfig = DeerConfig(),
               init_guess: Optional[jax.Array] = None,
               params=None, fused_scan=None) -> Tuple[jax.Array, jax.Array]:
    """Solve x_t = step_fn(x_{t-1}, feats_t[, params]) for the trajectory.

    Returns (states (T, ...), n_iters ()). Differentiable per cfg.grad —
    w.r.t. feats, x0 AND params (pass cell parameters via ``params``, not a
    closure, when using grad="implicit").  ``n_iters`` is reported
    consistently across modes: the iteration count the solve actually ran
    (``max_iters`` in "fixed" mode, the while_loop trip count in "tol"
    mode — for BOTH grad modes).

    ``fused_scan`` (grad="implicit" only): optional fused-adjoint hook
    ``(shifted_states, feats, params, gbar) -> g`` replacing the backward
    pass's jvp + reverse-scan segment with a fused kernel — see
    ``kernels.lrc_deer.ops.make_fused_adjoint_scans`` for the packed-lrc
    implementation.  Forward values are unaffected.
    """
    if params is None:
        orig = step_fn
        step_fn = lambda x, f, _p: orig(x, f)
        params = ()
    if init_guess is None:
        # Zero-state guess; iteration 1 then produces the "input-driven"
        # trajectory, which is already close for contractive models.
        init_guess = jnp.zeros((T,) + x0.shape, x0.dtype)

    if cfg.grad == "implicit":
        return _deer_fixed_point(step_fn, feats, params, x0, init_guess, cfg,
                                 fused_scan)
    return _deer_unrolled(step_fn, feats, params, x0, init_guess, cfg)


def _deer_unrolled(step_fn, feats, params, x0, init_guess, cfg: DeerConfig):
    if cfg.mode == "fixed":
        def body(_, st):
            return _newton_iteration(step_fn, feats, params, x0, st, cfg)
        states = jax.lax.fori_loop(0, cfg.max_iters, body, init_guess,
                                   unroll=cfg.unroll)
        return states, jnp.asarray(cfg.max_iters, jnp.int32)

    # tol mode: while_loop (not reverse-differentiable -> used for eval /
    # Figure 2 iteration counts; training uses "fixed" or implicit grad).
    def cond(carry):
        _, diff, it = carry
        return jnp.logical_and(diff > cfg.tol, it < cfg.max_iters)

    def body(carry):
        st, _, it = carry
        new = _newton_iteration(step_fn, feats, params, x0, st, cfg)
        diff = jnp.max(jnp.abs(new - st))
        return new, diff, it + 1

    states, _, iters = jax.lax.while_loop(
        cond, body, (init_guess, residual_init(init_guess.dtype),
                     jnp.asarray(0, jnp.int32)))
    return states, iters


# ---------------------------------------------------------------------------
# Implicit differentiation at the fixed point.
#
# At convergence, R(states; theta) = states - StepAll(states; theta) = 0 where
# StepAll(states)_t = F(shift(states)_t, feats_t). By the IFT,
#
#   dL/dtheta = - dL/dstates @ (dR/dstates)^{-1} @ dR/dtheta
#
# dR/dstates = I - M where M is the linear map v -> J .* shift(v) with J the
# (diagonal) per-step Jacobian at the solution. Solving
# g^T (I - M) = gbar^T is the REVERSED diagonal recurrence
#
#   g_t = gbar_t + J_{t+1} * g_{t+1},   g_T = gbar_T
#
# i.e. one more parallel scan (reverse=True). Then the theta/feats/x0
# cotangents follow from a single vjp through StepAll.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 5, 6))
def _deer_fixed_point(step_fn, feats, params, x0, init_guess,
                      cfg: DeerConfig, fused_scan):
    return _deer_unrolled(step_fn, feats, params, x0,
                          jax.lax.stop_gradient(init_guess), cfg)


def _dfp_fwd(step_fn, feats, params, x0, init_guess, cfg, fused_scan):
    out = _deer_fixed_point(step_fn, feats, params, x0, init_guess, cfg,
                            fused_scan)
    return out, (feats, params, x0, out[0])


def implicit_adjoint(step_fn, feats, params, x0, states, gbar,
                     fused_scan=None):
    """IFT adjoint of the fixed point x = F(shift(x)) at the converged
    ``states``. Returns (d_feats, d_params, d_x0).

    SHARED by the DEER and ELK replicated solvers: the ELK trust-region
    iteration converges to the same fixed-point equation (the smoother's
    observations y = x^prev become self-consistent at the solution), so the
    backward pass is identical.

    ``fused_scan``: optional hook ``(shifted, feats, params, gbar) -> g``
    computing the adjoint recurrence g_t = gbar_t + J_{t+1} g_{t+1} in one
    fused pass (gate recompute + exact diagonal J + reverse scan — the
    Pallas kernel in kernels/lrc_deer for packed-lrc cells).  None = the
    generic jvp + associative reverse scan below.
    """
    shifted = _shift_right(states, x0)

    if fused_scan is not None:
        g = fused_scan(shifted, feats, params, gbar)
    else:
        fn_of_x = lambda xs: step_fn(xs, feats, params)
        ones = jnp.ones_like(shifted)
        _, jac = jax.jvp(fn_of_x, (shifted,), (ones,))  # J_t = dF_t/dx_{t-1}

        # Adjoint recurrence (reverse scan): g_t = gbar_t + J_{t+1} g_{t+1}.
        jac_next = jnp.concatenate([jac[1:], jnp.zeros_like(jac[:1])], axis=0)
        g = diag_linear_scan(jac_next, gbar, None, reverse=True)

    # Cotangents into (feats, params, x0) via one vjp through the step
    # applied to the *converged* trajectory.
    def step_all(sh, ft, pr):
        return step_fn(sh, ft, pr)
    _, vjp = jax.vjp(step_all, shifted, feats, params)
    d_shifted, d_feats, d_params = vjp(g)
    d_x0 = d_shifted[0]           # shift puts x0 at slot 0
    return d_feats, d_params, d_x0


def _dfp_bwd(step_fn, cfg, fused_scan, res, gbar):
    feats, params, x0, states = res
    d_feats, d_params, d_x0 = implicit_adjoint(step_fn, feats, params, x0,
                                               states, gbar[0],
                                               fused_scan=fused_scan)
    d_init = jnp.zeros_like(states)  # init guess does not affect the solution
    return d_feats, d_params, d_x0, d_init


_deer_fixed_point.defvjp(_dfp_fwd, _dfp_bwd)


def deer_residual(step_fn: StepFn, feats, x0: jax.Array,
                  states: jax.Array, params=None) -> jax.Array:
    """max_t |x_t - F(x_{t-1})| — convergence diagnostic used by tests and
    the Figure 2 benchmark."""
    shifted = _shift_right(states, x0)
    if params is None:
        return jnp.max(jnp.abs(states - step_fn(shifted, feats)))
    return jnp.max(jnp.abs(states - step_fn(shifted, feats, params)))

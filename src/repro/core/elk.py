"""ELK: Levenberg-Marquardt-damped DEER via parallel Kalman smoothing.

Gonzalez et al. [8] stabilise the DEER Newton iteration by constraining each
update inside a trust region. The LM-damped linear subproblem

    min_{x_{1:T}}  sum_t || x_t - (J_t x_{t-1} + b_t) ||^2
                 + mu * sum_t || x_t - x_t^{prev} ||^2

is exactly MAP smoothing of the linear-Gaussian state-space model

    x_t = J_t x_{t-1} + b_t + w_t,   w_t ~ N(0, 1)
    y_t = x_t + v_t,                 v_t ~ N(0, 1/mu),   y_t := x_t^{prev}

so the damped Newton step is one parallel Kalman smoother pass — still
O(log T) sequential depth (Särkkä & García-Fernández associative-scan
filtering/smoothing). As mu -> 0 the observations become uninformative and
the update reduces to the exact DEER scan.

Because the LrcSSM Jacobian is diagonal, every hidden dimension is an
independent SCALAR smoothing problem: the 5-tuple filtering elements and
3-tuple smoothing elements below are elementwise over (T, D) — no D x D
algebra anywhere, which is what makes ELK O(T D) for this model family.

The paper's headline model does not need ELK (its exact diagonal Newton
iteration is contractive in practice); ELK is provided (a) as the faithful
baseline for the dense-Jacobian LRC (quasi-ELK, Table 9 ablation) and (b) as
a robustness fallback selectable per-layer (solver="elk").
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.deer import DeerConfig, StepFn, _shift_right, implicit_adjoint
from repro.core.scan import residual_init


# ---------------------------------------------------------------------------
# Scalar parallel Kalman filter (associative scan). Elements are
# (A, b, C, eta, J) per Särkkä & García-Fernández (2021), specialised to
# scalar state/obs with H = 1. All arrays are (T, ...) elementwise.
# ---------------------------------------------------------------------------

def _filter_combine(e1, e2):
    A1, b1, C1, eta1, J1 = e1
    A2, b2, C2, eta2, J2 = e2
    denom = 1.0 + C1 * J2
    A = A2 * A1 / denom
    b = A2 * (b1 + C1 * eta2) / denom + b2
    C = A2 * A2 * C1 / denom + C2
    eta = A1 * (eta2 - J2 * b1) / denom + eta1
    J = A1 * A1 * J2 / denom + J1
    return A, b, C, eta, J


def _smooth_combine(e1, e2):
    # elements (E, g, L): x_t | x_{t+1} ~ N(E x_{t+1} + g, L). Convention
    # matches the affine scan combine: e1 is applied FIRST, i.e. the result
    # is e2(e1(x)). In the reverse scan the left-fold accumulator (first arg)
    # holds the LATER-time suffix, which is exactly the map applied first
    # when walking x_end -> x_t.
    E1, g1, L1 = e1
    E2, g2, L2 = e2
    return E2 * E1, E2 * g1 + g2, E2 * E2 * L1 + L2


def kalman_smoother_parallel(F: jax.Array, c: jax.Array, q: jax.Array,
                             y: jax.Array, r: jax.Array,
                             m0: jax.Array, P0: jax.Array
                             ) -> Tuple[jax.Array, jax.Array]:
    """Parallel RTS smoother for T independent scalar chains.

    x_t = F_t x_{t-1} + c_t + w_t, w~N(0,q);  y_t = x_t + v_t, v~N(0,r_t).
    F, c, y, r: (T, ...); q scalar or (T, ...); m0, P0: (...).
    Returns (smoothed_means, smoothed_vars), each (T, ...).
    """
    q = jnp.broadcast_to(jnp.asarray(q, y.dtype), y.shape)
    r = jnp.broadcast_to(jnp.asarray(r, y.dtype), y.shape)
    # ---- filtering elements -------------------------------------------------
    S = q + r
    K = q / S
    A = (1.0 - K) * F
    b = c + K * (y - c)
    C = (1.0 - K) * q
    eta = F * (y - c) / S
    J = F * F / S

    # First element conditions on the prior (m0, P0).
    P1p = F[0] * F[0] * P0 + q[0]
    m1p = F[0] * m0 + c[0]
    S1 = P1p + r[0]
    K1 = P1p / S1
    A0 = jnp.zeros_like(A[0])
    b0 = m1p + K1 * (y[0] - m1p)
    C0 = (1.0 - K1) * P1p
    z0 = jnp.zeros_like(A[0])

    A = jnp.concatenate([A0[None], A[1:]], 0)
    b = jnp.concatenate([b0[None], b[1:]], 0)
    C = jnp.concatenate([C0[None], C[1:]], 0)
    eta = jnp.concatenate([z0[None], eta[1:]], 0)
    J = jnp.concatenate([z0[None], J[1:]], 0)

    fA, fb, fC, _, _ = jax.lax.associative_scan(
        _filter_combine, (A, b, C, eta, J), axis=0)
    m_f, P_f = fb, fC                           # filtered means/vars

    # ---- smoothing elements (reverse suffix scan) ---------------------------
    F_next = jnp.concatenate([F[1:], jnp.ones_like(F[:1])], 0)
    c_next = jnp.concatenate([c[1:], jnp.zeros_like(c[:1])], 0)
    q_next = jnp.concatenate([q[1:], jnp.ones_like(q[:1])], 0)
    Pp_next = F_next * F_next * P_f + q_next    # P_{t+1|t}
    E = P_f * F_next / Pp_next
    g = m_f - E * (F_next * m_f + c_next)
    L = P_f - E * E * Pp_next
    # last element: conditional == filtered marginal
    E = E.at[-1].set(0.0)
    g = g.at[-1].set(m_f[-1])
    L = L.at[-1].set(P_f[-1])

    _, ms, Ls = jax.lax.associative_scan(_smooth_combine, (E, g, L),
                                         axis=0, reverse=True)
    return ms, Ls


# ---------------------------------------------------------------------------
# ELK iteration / solver
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ElkConfig:
    max_iters: int = 16
    tol: float = 1e-6
    mode: str = "fixed"
    trust_mu: float = 0.1        # observation precision; 0 => pure DEER step
    grad: str = "unroll"         # "unroll" | "implicit" (IFT at fixed point)


def _elk_iteration(step_fn, feats, params, x0, states, cfg: ElkConfig):
    """One LM-damped Newton step = linearise + one parallel Kalman smoother
    pass. Shared by the replicated loops below; the sharded solver
    (core/elk_sharded.py) mirrors this body on time shards."""
    shifted = _shift_right(states, x0)
    fn = lambda xs: step_fn(xs, feats, params)
    ones = jnp.ones_like(shifted)
    f_s, jac = jax.jvp(fn, (shifted,), (ones,))
    b_s = f_s - jac * shifted
    q = jnp.ones_like(states)
    r = jnp.full_like(states, 1.0 / max(cfg.trust_mu, 1e-12))
    P0 = jnp.zeros_like(x0) + 1e-6
    ms, _ = kalman_smoother_parallel(jac, b_s, q, states, r, x0, P0)
    return ms


def _elk_unrolled(step_fn, feats, params, x0, init_guess, cfg: ElkConfig
                  ) -> Tuple[jax.Array, jax.Array]:
    if cfg.mode == "fixed":
        states = jax.lax.fori_loop(
            0, cfg.max_iters,
            lambda _, st: _elk_iteration(step_fn, feats, params, x0, st, cfg),
            init_guess)
        return states, jnp.asarray(cfg.max_iters, jnp.int32)

    def cond(carry):
        _, diff, it = carry
        return jnp.logical_and(diff > cfg.tol, it < cfg.max_iters)

    def body(carry):
        st, _, it = carry
        new = _elk_iteration(step_fn, feats, params, x0, st, cfg)
        return new, jnp.max(jnp.abs(new - st)), it + 1

    states, _, iters = jax.lax.while_loop(
        cond, body,
        (init_guess, residual_init(), jnp.asarray(0, jnp.int32)))
    return states, iters


# At convergence the smoother's observations y = x^prev are self-consistent
# and the residuals vanish, so states solve the SAME fixed-point equation
# x = F(shift(x)) as DEER — the implicit adjoint is shared (core/deer.py).

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 5))
def _elk_fixed_point(step_fn, feats, params, x0, init_guess, cfg: ElkConfig):
    states, _ = _elk_unrolled(step_fn, feats, params, x0,
                              jax.lax.stop_gradient(init_guess), cfg)
    return states


def _efp_fwd(step_fn, feats, params, x0, init_guess, cfg):
    states = _elk_fixed_point(step_fn, feats, params, x0, init_guess, cfg)
    return states, (feats, params, x0, states)


def _efp_bwd(step_fn, cfg, res, gbar):
    feats, params, x0, states = res
    d_feats, d_params, d_x0 = implicit_adjoint(step_fn, feats, params, x0,
                                               states, gbar)
    return d_feats, d_params, d_x0, jnp.zeros_like(states)


_elk_fixed_point.defvjp(_efp_fwd, _efp_bwd)


def elk_solve(step_fn: StepFn, feats, x0: jax.Array, T: int,
              cfg: ElkConfig = ElkConfig(),
              init_guess: Optional[jax.Array] = None,
              params=None) -> Tuple[jax.Array, jax.Array]:
    """Trust-region (LM/Kalman) variant of deer_solve. Same contract:
    returns (states (T, ...), n_iters ()), differentiable per ``cfg.grad``
    w.r.t. feats, x0 and params (pass cell parameters via ``params``, not a
    closure, when using grad="implicit")."""
    if params is None:
        orig = step_fn
        step_fn = lambda x, f, _p: orig(x, f)
        params = ()
    if init_guess is None:
        init_guess = jnp.zeros((T,) + x0.shape, x0.dtype)

    if cfg.grad == "implicit":
        states = _elk_fixed_point(step_fn, feats, params, x0, init_guess, cfg)
        return states, jnp.asarray(cfg.max_iters, jnp.int32)
    return _elk_unrolled(step_fn, feats, params, x0, init_guess, cfg)

"""Dense-Jacobian LRC baseline (LrcSSM-full, Table 9 ablation).

The ORIGINAL LRC of Farsang et al. [5]: every synapse (j -> i) carries its own
sigmoidal activation sigma(a_ji y_j + b_ji) weighted by g_ji^max, summed over
presynaptic neurons j — Eqs. (1)-(3) with full cross-state connectivity.

Its step-function Jacobian is DENSE, so exact DEER needs O(T D^2) memory and
O(T D^3) work (paper Sec. A.2) and does not scale; the scalable path is the
quasi approximation (Algorithm 1 line 8): extract diag(J) and run the same
diagonal scan. We extract the exact diagonal analytically (the j = i synapse
derivative) rather than materialising the D x D Jacobian — an O(T D)
extraction that makes the quasi baseline runnable at benchmark sizes.

This module exists to reproduce the paper's ablation claim: constraining the
Jacobian to be diagonal BY DESIGN (core/lrc.py) loses nothing vs. this dense
model solved with quasi-DEER/ELK (Table 9).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, jax.Array]


@dataclasses.dataclass(frozen=True)
class FullLrcConfig:
    d_input: int
    d_state: int
    dt: float = 1.0
    param_dtype: Any = jnp.float32


def init_full_lrc_params(cfg: FullLrcConfig, key) -> Params:
    D, n, pdt = cfg.d_state, cfg.d_input, cfg.param_dtype
    ks = jax.random.split(key, 8)

    def dense(k, shape, scale):
        return (jax.random.normal(k, shape) * scale).astype(pdt)

    m = D + n  # presynaptic = all states + all inputs (y = [x, u])
    return {
        "a": dense(ks[0], (m, D), (1.0 / m) ** 0.5),    # per-synapse slope
        "b": jnp.zeros((m, D), pdt),                     # per-synapse offset
        "g_max": dense(ks[1], (m, D), (1.0 / m) ** 0.5),
        "k_max": dense(ks[2], (m, D), (1.0 / m) ** 0.5),
        "w": dense(ks[3], (m, D), (1.0 / m) ** 0.5),     # elastance weights
        "v": jnp.zeros((D,), pdt),
        "g_leak": jnp.full((D,), 0.1, pdt),
        "e_leak": jnp.ones((D,), pdt),
    }


def _conductances(p: Params, x: jax.Array, u: jax.Array):
    """f_i = sum_j g_ji sigma(a_ji y_j + b_ji) + leak; y = [x, u].

    x: (..., D), u: (..., n). Per-synapse activations are (..., m, D)."""
    y = jnp.concatenate([x, u], axis=-1)                     # (..., m)
    act = jax.nn.sigmoid(y[..., :, None] * p["a"] + p["b"])  # (..., m, D)
    f = jnp.sum(p["g_max"] * act, axis=-2) + p["g_leak"]
    z = jnp.sum(p["k_max"] * act, axis=-2) + p["g_leak"]
    eps = y @ p["w"] + p["v"]
    return f, z, eps


def full_lrc_step(p: Params, cfg: FullLrcConfig, x_prev: jax.Array,
                  u_t: jax.Array) -> jax.Array:
    """One Euler step of the dense LRC (Eq. 6/7). Elementwise over batch."""
    f, z, eps = _conductances(p, x_prev, u_t)
    sig_f, sig_e, tau_z = jax.nn.sigmoid(f), jax.nn.sigmoid(eps), jnp.tanh(z)
    dx = (-sig_f * x_prev + tau_z * p["e_leak"]) * sig_e
    return x_prev + cfg.dt * dx


def full_lrc_diag_jac(p: Params, cfg: FullLrcConfig, x_prev: jax.Array,
                      u_t: jax.Array) -> jax.Array:
    """Exact DIAGONAL of the dense step Jacobian, analytically, O(D).

    d step_i / d x_i picks up: the explicit x_i factor, the i->i synapse in
    f and z, and the elastance's w_ii x_i term.
    """
    D = cfg.d_state
    f, z, eps = _conductances(p, x_prev, u_t)
    sig_f, sig_e, tau_z = jax.nn.sigmoid(f), jax.nn.sigmoid(eps), jnp.tanh(z)
    dsig_f = sig_f * (1 - sig_f)
    dsig_e = sig_e * (1 - sig_e)
    dtau_z = 1 - tau_z * tau_z

    # self-synapse activation derivative (j = i entries of the m x D blocks)
    a_ii = jnp.diagonal(p["a"][:D, :])           # (D,)
    b_ii = jnp.diagonal(p["b"][:D, :])
    g_ii = jnp.diagonal(p["g_max"][:D, :])
    k_ii = jnp.diagonal(p["k_max"][:D, :])
    w_ii = jnp.diagonal(p["w"][:D, :])
    act_ii = jax.nn.sigmoid(a_ii * x_prev + b_ii)
    dact_ii = act_ii * (1 - act_ii) * a_ii
    df_dx = g_ii * dact_ii                        # d f_i / d x_i
    dz_dx = k_ii * dact_ii
    deps_dx = w_ii

    core = -sig_f * x_prev + tau_z * p["e_leak"]
    ddx = (-dsig_f * df_dx * x_prev - sig_f
           + dtau_z * dz_dx * p["e_leak"]) * sig_e + core * dsig_e * deps_dx
    return 1.0 + cfg.dt * ddx


def full_lrc_sequential(p: Params, cfg: FullLrcConfig, u: jax.Array,
                        x0: Optional[jax.Array] = None) -> jax.Array:
    """Oracle rollout. u: (T, n)."""
    if x0 is None:
        x0 = jnp.zeros((cfg.d_state,), u.dtype)

    def step(x, u_t):
        x_new = full_lrc_step(p, cfg, x, u_t)
        return x_new, x_new

    _, xs = jax.lax.scan(step, x0, u)
    return xs


def quasi_deer_solve(p: Params, cfg: FullLrcConfig, u: jax.Array,
                     x0: Optional[jax.Array] = None, *, max_iters: int = 30,
                     tol: float = 1e-6) -> Tuple[jax.Array, jax.Array]:
    """quasi-DEER for the dense model: exact step + diagonal-of-dense-Jacobian
    linearisation + parallel scan (Algorithm 1 with quasi=True)."""
    from repro.core.scan import diag_linear_scan

    T = u.shape[0]
    if x0 is None:
        x0 = jnp.zeros((cfg.d_state,), u.dtype)
    states0 = jnp.zeros((T, cfg.d_state), u.dtype)

    def iteration(states):
        shifted = jnp.concatenate([x0[None], states[:-1]], axis=0)
        f_s = full_lrc_step(p, cfg, shifted, u)
        j_s = full_lrc_diag_jac(p, cfg, shifted, u)
        # quasi stabilisation: clamp the diagonal inside the unit ball
        j_s = jnp.clip(j_s, -0.999, 0.999)
        b_s = f_s - j_s * shifted
        return diag_linear_scan(j_s, b_s, x0)

    def cond(carry):
        _, diff, it = carry
        return jnp.logical_and(diff > tol, it < max_iters)

    def body(carry):
        st, _, it = carry
        new = iteration(st)
        return new, jnp.max(jnp.abs(new - st)), it + 1

    states, _, iters = jax.lax.while_loop(
        cond, body, (states0, jnp.asarray(jnp.inf, jnp.float32),
                     jnp.asarray(0, jnp.int32)))
    return states, iters

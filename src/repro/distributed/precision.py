"""Serve-time precision policy: quantized weights, state cache and kernel
HBM streams under ONE policy object.

The solver stack is HBM-stream-bound (see ``kernels/autotune.
solver_hbm_streams``), so bytes-per-element is the next multiplicative
lever: ``PrecisionPolicy`` carries per-leaf-group dtype rules for the three
serve-time tensor populations —

  * **weights**   — the resident parameter tree ``ServeEngine`` decodes
                    with: int8 (RTN, per-channel block scales — the same
                    symmetric round-to-nearest format ``distributed/
                    compression.py`` built for gradients), fp8
                    (e4m3 direct cast), or bf16 (cast).
  * **cache**     — ``serve/cache.StateCache`` slot state: quantized ON
                    SCATTER (admission / tick commit) and dequantized ON
                    GATHER (decode entry / eviction read), inside the same
                    jitted donated slot ops; the per-slot ``pos`` vector is
                    never touched.
  * **kernel_io** — the lrc_deer Pallas solver's HBM streams (``s_u``,
                    ``eps_u`` in, trajectory out) in bf16/fp8 while every
                    in-kernel accumulation stays fp32 VMEM (the kernels
                    already read refs through ``.astype(f32)``).

Accumulation is NEVER quantized: gates, Jacobians, scans and dequantized
matmuls run in fp32 (or bf16 when ``accum="bf16"`` relaxes the dequantized
WEIGHT compute dtype); int8/fp8 exist only at rest and on the wire.

Quantized leaves are ``QTensor`` pytree nodes (payload + optional block
scales), so quantized trees flow through ``jax.jit`` with donation exactly
like their fp32 counterparts. The int8 grid is IDEMPOTENT: re-encoding a
dequantized tensor reproduces the same payload bit-for-bit, which is what
keeps per-tick cache requantization from drifting and makes the
quantize-on-scatter/dequantize-on-gather round trip self-consistent (the
differential harness in tests/test_precision.py asserts both).

``quantize_roundtrip_rows`` is the tick-aligned state quantizer the lrc
mixer injects into its recurrence step when ``SSMConfig.state_quant`` is
set (serve engines set it for quantized caches): because one DEER Newton
iteration fixes at least one more timestep REGARDLESS of the Jacobian, the
k-token verify window stays EXACT under the quantized step function — the
property that keeps speculative decode token-identical to quantized greedy
decode (losslessness vs same-precision). The roundtrip carries an identity
JVP (straight-through estimator) so Newton keeps the true cell Jacobian.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.compression import (BLOCK, rtn_dequantize_blocks,
                                           rtn_quantize_blocks)

# payload dtypes per mode; fp8 is e4m3 (wide dynamic range, no inf encoding)
_PAYLOAD = {"int8": jnp.int8, "fp8": jnp.float8_e4m3fn, "bf16": jnp.bfloat16}
# e4m3 saturation bound for the direct-cast modes
_FP8_MAX = 448.0

WEIGHT_MODES = ("fp32", "bf16", "int8", "fp8")
CACHE_MODES = ("fp32", "bf16", "int8", "fp8")
KERNEL_IO_MODES = ("fp32", "bf16", "fp8")
KERNEL_IO_BYTES = {"fp32": 4, "bf16": 2, "fp8": 1}


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """Per-leaf-group serve-time dtype rules (see module docstring).

    ``block`` is the RTN scale granularity (one fp32 scale per ``block``
    int8 payload elements along each row's flattened trailing dims —
    ``compression.BLOCK`` by default, the gradient wire format).
    ``min_weight_elems`` keeps tiny leaves (norm scales, biases) in their
    master dtype: quantizing them saves nothing and costs accuracy.
    ``accum`` is the dtype dequantized WEIGHTS land in ("fp32" master copy
    semantics, "bf16" to halve on-chip width); cache leaves always
    dequantize back to their original dtype — recurrent-state fidelity is
    what the differential harness bounds. Kernel VMEM accumulation is fp32
    unconditionally.
    """
    weights: str = "fp32"
    cache: str = "fp32"
    kernel_io: str = "fp32"
    accum: str = "fp32"
    block: int = BLOCK
    min_weight_elems: int = 1024

    def __post_init__(self):
        for field, val, allowed in (("weights", self.weights, WEIGHT_MODES),
                                    ("cache", self.cache, CACHE_MODES),
                                    ("kernel_io", self.kernel_io,
                                     KERNEL_IO_MODES),
                                    ("accum", self.accum, ("fp32", "bf16"))):
            if val not in allowed:
                raise ValueError(f"PrecisionPolicy.{field}={val!r}: "
                                 f"expected one of {allowed}")
        if self.block < 1:
            raise ValueError(f"PrecisionPolicy.block={self.block}: must be "
                             ">= 1")

    # -- grammar ------------------------------------------------------------

    @classmethod
    def from_string(cls, spec: str) -> "PrecisionPolicy":
        """Parse the ``--precision`` grammar: a preset name (``fp32`` |
        ``bf16`` | ``int8`` | ``fp8``) or comma-separated ``key=value``
        overrides (``weights=int8,cache=fp8,kernel_io=bf16,block=128``).
        Presets set all three groups coherently — int8 payloads stream the
        kernels in bf16 (there is no int8 solver stream format)."""
        spec = spec.strip()
        presets = {
            "fp32": {},
            "bf16": dict(weights="bf16", cache="bf16", kernel_io="bf16"),
            "int8": dict(weights="int8", cache="int8", kernel_io="bf16"),
            "fp8": dict(weights="fp8", cache="fp8", kernel_io="fp8"),
        }
        if spec in presets:
            return cls(**presets[spec])
        kwargs = {}
        for part in spec.split(","):
            if not part.strip():
                continue
            if "=" not in part:
                raise ValueError(
                    f"precision spec {spec!r}: {part!r} is neither a preset "
                    f"({'|'.join(presets)}) nor a key=value override")
            k, v = (s.strip() for s in part.split("=", 1))
            if k in ("block", "min_weight_elems"):
                kwargs[k] = int(v)
            elif k in ("weights", "cache", "kernel_io", "accum"):
                kwargs[k] = v
            else:
                raise ValueError(f"precision spec {spec!r}: unknown key "
                                 f"{k!r}")
        return cls(**kwargs)

    # -- rule predicates ----------------------------------------------------

    @property
    def quantizes_weights(self) -> bool:
        return self.weights != "fp32"

    @property
    def quantizes_cache(self) -> bool:
        return self.cache != "fp32"

    @property
    def kernel_io_dtype(self) -> Optional[str]:
        """The lrc_deer HBM stream dtype override (None = native fp32)."""
        return None if self.kernel_io == "fp32" else self.kernel_io


# ---------------------------------------------------------------------------
# QTensor: a quantized leaf as a first-class pytree node
# ---------------------------------------------------------------------------

class QTensor:
    """A quantized array leaf: payload ``q`` (int8 / fp8 / bf16, the
    original logical shape) plus optional RTN block ``scale`` (int8 mode;
    shape ``q.shape[:lead] + (n_blocks,)`` — the leading ``lead`` axes are
    preserved so slot-row scatter/gather slices payload and scales with the
    same index arithmetic). ``mode``/``odtype``/``lead``/``block`` are
    static aux data (part of the pytree treedef), so jit caches key on
    them."""

    __slots__ = ("q", "scale", "mode", "odtype", "lead", "block")

    def __init__(self, q, scale, mode: str, odtype: str, lead: int,
                 block: int):
        self.q = q
        self.scale = scale
        self.mode = mode
        self.odtype = odtype
        self.lead = lead
        self.block = block

    @property
    def shape(self):
        return self.q.shape

    @property
    def dtype(self):
        return self.q.dtype

    @property
    def nbytes(self) -> int:
        n = self.q.size * self.q.dtype.itemsize
        if self.scale is not None:
            n += self.scale.size * self.scale.dtype.itemsize
        return n

    def __repr__(self):
        return (f"QTensor({self.mode}, shape={tuple(self.q.shape)}, "
                f"odtype={self.odtype}, lead={self.lead})")


jax.tree_util.register_pytree_with_keys(
    QTensor,
    lambda t: (((jax.tree_util.GetAttrKey("q"), t.q),
                (jax.tree_util.GetAttrKey("scale"), t.scale)),
               (t.mode, t.odtype, t.lead, t.block)),
    lambda aux, children: QTensor(children[0], children[1], *aux),
)


def _row_block_geometry(shape, lead: int, block: int) -> Tuple[int, int, int]:
    """(row elems n, block size bs, n_blocks nb) for flattening
    ``shape[lead:]`` into scale blocks (block clamps to the row size)."""
    n = 1
    for d in shape[lead:]:
        n *= int(d)
    bs = max(1, min(block, n))
    nb = -(-n // bs)
    return n, bs, nb


def quantize_leaf(x: jax.Array, mode: str, block: int = BLOCK,
                  lead: int = 0) -> QTensor:
    """Quantize one array leaf to a ``QTensor``.

    ``int8`` is symmetric RTN with one fp32 scale per ``block`` elements of
    each row's flattened trailing dims (``lead`` leading axes preserved) —
    the ``compression.py`` gradient wire format generalized to row-wise
    scales. ``fp8``/``bf16`` are direct casts (e4m3 saturated at ±448);
    e4m3's 4 exponent bits cover the O(1) state range without per-block
    scales, which is what makes the fp8 cache land exactly 4x fp32 bytes.
    """
    odtype = jnp.dtype(x.dtype).name
    if mode in ("bf16", "fp8"):
        xf = x.astype(jnp.float32)
        if mode == "fp8":
            xf = jnp.clip(xf, -_FP8_MAX, _FP8_MAX)
        return QTensor(xf.astype(_PAYLOAD[mode]), None, mode, odtype,
                       lead, block)
    if mode != "int8":
        raise ValueError(f"quantize_leaf: unknown mode {mode!r}")
    n, bs, nb = _row_block_geometry(x.shape, lead, block)
    rows = x.astype(jnp.float32).reshape(x.shape[:lead] + (n,))
    rows = jnp.pad(rows, [(0, 0)] * lead + [(0, nb * bs - n)])
    blocks = rows.reshape(x.shape[:lead] + (nb, bs))
    q, scale = rtn_quantize_blocks(blocks)
    q = q.reshape(x.shape[:lead] + (nb * bs,))[..., :n].reshape(x.shape)
    return QTensor(q, scale[..., 0], mode, odtype, lead, block)


def dequantize_leaf(t: QTensor) -> jax.Array:
    """Invert ``quantize_leaf`` onto the original dtype (int8 dequant
    accumulates ``q * scale`` in fp32)."""
    od = jnp.dtype(t.odtype)
    if t.scale is None:
        return t.q.astype(od)
    n, bs, nb = _row_block_geometry(t.q.shape, t.lead, t.block)
    rows = t.q.reshape(t.q.shape[:t.lead] + (n,))
    rows = jnp.pad(rows, [(0, 0)] * t.lead + [(0, nb * bs - n)])
    blocks = rows.reshape(t.q.shape[:t.lead] + (nb, bs))
    out = rtn_dequantize_blocks(blocks, t.scale[..., None])
    out = out.reshape(t.q.shape[:t.lead] + (nb * bs,))[..., :n]
    return out.reshape(t.q.shape).astype(od)


def is_quantized(x: Any) -> bool:
    return isinstance(x, QTensor)


def requantize_like(template: QTensor, x: jax.Array) -> QTensor:
    """Re-encode ``x`` with ``template``'s static rule (mode/lead/block).
    On already-grid-aligned values the int8 encode is exact (idempotent
    RTN), so per-tick cache recommits never drift."""
    return quantize_leaf(x.astype(jnp.dtype(template.odtype)),
                         template.mode, template.block, template.lead)


# ---------------------------------------------------------------------------
# tick-aligned state roundtrip (the lrc mixer's in-step quantizer)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_jvp, nondiff_argnums=(1, 2))
def quantize_roundtrip_rows(x: jax.Array, mode: str,
                            block: int = BLOCK) -> jax.Array:
    """Quantize-dequantize ``x`` per leading-axis row (``lead=1`` — the
    mixer's (B, ...) state layout, matching the cache's per-slot scale
    rows), returning values ON the storage grid so the subsequent
    scatter-encode is exact. Identity JVP (straight-through): DEER's
    Newton linearization sees the underlying cell Jacobian, keeping its
    convergence behavior; exactness on <= T-step windows holds regardless
    (one iteration fixes one more timestep for ANY step function)."""
    return dequantize_leaf(quantize_leaf(x, mode, block,
                                         lead=1)).astype(x.dtype)


@quantize_roundtrip_rows.defjvp
def _quantize_roundtrip_rows_jvp(mode, block, primals, tangents):
    (x,), (dx,) = primals, tangents
    return quantize_roundtrip_rows(x, mode, block), dx


# ---------------------------------------------------------------------------
# tree-level rules
# ---------------------------------------------------------------------------

def _is_float_leaf(x) -> bool:
    return (hasattr(x, "dtype")
            and jnp.issubdtype(jnp.dtype(x.dtype), jnp.floating))


def quantize_params(params, policy: PrecisionPolicy):
    """Apply the WEIGHT rule: float leaves with >= 2 dims and >=
    ``min_weight_elems`` elements become ``QTensor``s (int8: per-channel
    block scales along the last axis, ``lead = ndim - 1``); small leaves
    (norm scales, biases, scalars) keep the master dtype. Identity when
    the policy keeps weights fp32."""
    if not policy.quantizes_weights:
        return params

    def leaf(x):
        if (not _is_float_leaf(x) or x.ndim < 2
                or x.size < policy.min_weight_elems):
            return x
        return quantize_leaf(x, policy.weights, policy.block,
                             lead=x.ndim - 1)
    return jax.tree_util.tree_map(leaf, params)


def quantize_cache(cache, policy: PrecisionPolicy, batch_axis_fn):
    """Apply the CACHE rule to a resident slot cache: every float leaf
    becomes a ``QTensor`` whose scale rows preserve axes up to AND
    including the slot axis (``batch_axis_fn(path_str)``), so slot
    scatter/gather slices payload and scales identically. ``pos`` vectors
    (and any other integer leaf) are untouched."""
    if not policy.quantizes_cache:
        return cache
    from repro.distributed.sharding import _path_str

    def leaf(path, x):
        ps = _path_str(path)
        if ps.endswith("pos") or not _is_float_leaf(x):
            return x
        return quantize_leaf(x, policy.cache, policy.block,
                             lead=batch_axis_fn(ps) + 1)
    return jax.tree_util.tree_map_with_path(leaf, cache)


def dequantize_tree(tree):
    """Decode every ``QTensor`` leaf back to its original dtype; plain
    leaves pass through (identity on unquantized trees)."""
    return jax.tree_util.tree_map(
        lambda x: dequantize_leaf(x) if is_quantized(x) else x,
        tree, is_leaf=is_quantized)


def dequantize_weights(params, policy: Optional[PrecisionPolicy]):
    """Weight-tree decode honoring ``accum``: fp32 master semantics by
    default, bf16 when the policy relaxes the dequantized compute dtype."""
    out = dequantize_tree(params)
    if policy is not None and policy.accum == "bf16":
        out = jax.tree_util.tree_map(
            lambda x: (x.astype(jnp.bfloat16)
                       if _is_float_leaf(x)
                       and jnp.dtype(x.dtype) == jnp.float32 else x),
            out)
    return out


def requantize_tree(template, tree):
    """Re-encode ``tree`` under ``template``'s leaf rules: positions where
    the template holds a ``QTensor`` are re-quantized with that leaf's
    static rule, everything else passes through — the requantize-on-exit
    half of a quantized serve tick."""
    return jax.tree_util.tree_map(
        lambda t, x: requantize_like(t, x) if is_quantized(t) else x,
        template, tree, is_leaf=is_quantized)


def tree_state_bytes(tree) -> int:
    """Resident bytes of the FLOAT state in ``tree`` (QTensor payload +
    scales; integer bookkeeping like ``pos`` excluded) — the slot-capacity
    numerator/denominator in docs/serving.md."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree, is_leaf=is_quantized):
        if is_quantized(leaf):
            total += leaf.nbytes
        elif _is_float_leaf(leaf):
            total += leaf.size * jnp.dtype(leaf.dtype).itemsize
    return total

"""Version-portable distributed-execution layer.

``shard_map`` has moved twice in jax's public API:

  * jax <= 0.4.x / 0.5.x : ``jax.experimental.shard_map.shard_map`` with a
    ``check_rep=`` kwarg (replication checking);
  * jax >= 0.6           : ``jax.shard_map`` with the kwarg renamed to
    ``check_vma=`` (varying-manual-axes checking — same contract).

Every call site in this repo resolves ``shard_map`` — and the collectives it
composes with — through THIS module, so the rest of the codebase is version
agnostic. The contract exposed here:

    shard_map(f, mesh=..., in_specs=..., out_specs=..., check_vma=True)

``check_vma`` is translated to ``check_rep`` on old jax. ``mesh`` is
required (we never rely on the new-API ambient-mesh default: it does not
exist on 0.4.x).

The collectives re-exported below (``psum``, ``pmax``, ``pmean``,
``all_gather``, ``ppermute``, ``psum_scatter``, ``axis_index``) are stable
``jax.lax`` API across the supported range, but call sites import them from
here so the repo has exactly ONE distribution API surface — if a future jax
moves or renames any of them, this module is the single place to patch.

The partially-manual entry point (``shard_map(..., auto_axes=...)``) papers
over the second API drift: jax <= 0.5 spells "leave these axes to GSPMD" as
``auto=frozenset({...})`` while jax >= 0.6 inverts the parameter to
``axis_names={...}`` (the axes that ARE manual). Callers name the auto axes;
the shim translates by inspecting the installed signature.

Supported jax range: 0.4.30 — current (feature-detected at import time;
``HAS_NATIVE_SHARD_MAP`` records which branch was taken).
"""
from __future__ import annotations

import inspect
from typing import Any, Callable

import jax

HAS_NATIVE_SHARD_MAP = hasattr(jax, "shard_map")

if not HAS_NATIVE_SHARD_MAP:
    from jax.experimental.shard_map import shard_map as _experimental_shard_map
    _shard_map_impl = _experimental_shard_map
else:
    _shard_map_impl = jax.shard_map

_SHARD_MAP_PARAMS = frozenset(
    inspect.signature(_shard_map_impl).parameters)

# Whether partially-manual bodies may issue DATA-MOVING collectives
# (all_gather / psum_scatter / all_to_all) over their *manual* axes. On the
# 0.4.x line the XLA partitioner aborts on that mix ("Check failed:
# target.IsManualSubgroup() == sharding().IsManualSubgroup()"); elementwise
# collectives (psum/pmean/pmax) are fine. The explicit gradient seam
# therefore runs FULLY manual on every supported version — the partial-auto
# entry point below exists for read-mostly cells (and becomes fully usable
# on jax >= 0.6, where this flag flips to True).
PARTIAL_AUTO_DATA_COLLECTIVES_OK = HAS_NATIVE_SHARD_MAP


def shard_map(f: Callable, *, mesh, in_specs, out_specs,
              check_vma: bool = True, auto_axes=None,
              **kwargs: Any) -> Callable:
    """Map ``f`` over shards of the mesh — portable across jax versions.

    Args:
      f: per-shard function (sees local shards; collectives see mesh axes).
      mesh: jax.sharding.Mesh (required; no ambient-mesh default).
      in_specs / out_specs: PartitionSpec pytrees (prefix trees allowed).
      check_vma: enable replication/varying-axes checking (maps to
        ``check_rep`` on jax < 0.6). Pass False for bodies with data-dependent
        collectives inside lax control flow, where the checker is too strict.
      auto_axes: optional iterable of mesh-axis names the body does NOT
        handle manually — GSPMD keeps partitioning over them. Translated to
        ``auto=frozenset`` (jax <= 0.5) or the complementary ``axis_names=``
        set (jax >= 0.6). See ``PARTIAL_AUTO_DATA_COLLECTIVES_OK`` before
        issuing data-moving collectives from a partially-manual body.
    """
    # accept legacy spelling so downstream code written against either jax
    # API keeps working through this shim
    if "check_rep" in kwargs:
        check_vma = kwargs.pop("check_rep")
    if kwargs:
        raise TypeError(f"unsupported shard_map kwargs: {sorted(kwargs)}")
    extra: dict[str, Any] = {}
    if auto_axes:
        auto = frozenset(auto_axes)
        unknown = auto - set(mesh.axis_names)
        if unknown:
            raise ValueError(
                f"auto_axes {sorted(unknown)} not in mesh axes "
                f"{mesh.axis_names}")
        if "auto" in _SHARD_MAP_PARAMS:
            extra["auto"] = auto
        elif "axis_names" in _SHARD_MAP_PARAMS:
            # new API names the MANUAL axes instead — pass the complement
            extra["axis_names"] = set(mesh.axis_names) - auto
        else:  # pragma: no cover - no partial-manual support at all
            raise NotImplementedError(
                "installed jax shard_map supports neither auto= nor "
                "axis_names=; partially-manual lowering unavailable")
    if HAS_NATIVE_SHARD_MAP:
        return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs, check_vma=check_vma,
                               **extra)
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, check_rep=check_vma,
                           **extra)


# ---------------------------------------------------------------------------
# collectives — stable names, one import surface
# ---------------------------------------------------------------------------

psum = jax.lax.psum
pmax = jax.lax.pmax
pmin = jax.lax.pmin
pmean = jax.lax.pmean
all_gather = jax.lax.all_gather
ppermute = jax.lax.ppermute
psum_scatter = jax.lax.psum_scatter
all_to_all = jax.lax.all_to_all
axis_index = jax.lax.axis_index


def axis_size(mesh, axis) -> int:
    """Number of shards along ``axis`` (a mesh axis name or tuple of them)."""
    axes = axis if isinstance(axis, tuple) else (axis,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def axis_env_size(axis_name: str) -> int:
    """STATIC size of a bound mesh axis, queryable while tracing inside a
    shard_map body (no mesh object needed). jax >= 0.5 exposes
    ``jax.lax.axis_size``; the 0.4.x line only has the trace-time axis
    env."""
    if hasattr(jax.lax, "axis_size"):
        return int(jax.lax.axis_size(axis_name))
    from jax._src import core as _core  # 0.4.x fallback
    return int(_core.get_axis_env().axis_size(axis_name))


# ---------------------------------------------------------------------------
# compiled-executable introspection
# ---------------------------------------------------------------------------

def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` normalised across jax versions.

    The 0.4.x line returns a LIST with one properties-dict per program
    (which made every roofline launch/dryrun cell report status:"error"
    after compiling fine, when the caller assumed a dict); jax >= 0.5
    returns the dict directly (and may return None when XLA provides no
    analysis). Callers always get a plain dict — empty when the analysis is
    unavailable — so key lookups like ``cost.get("flops")`` work on every
    supported version.
    """
    try:
        cost = compiled.cost_analysis()
    except Exception:
        return {}
    if cost is None:
        return {}
    if isinstance(cost, (list, tuple)):
        return dict(cost[0]) if len(cost) else {}
    return dict(cost)

"""Version-portable distributed-execution layer.

``shard_map`` has moved twice in jax's public API:

  * jax <= 0.4.x / 0.5.x : ``jax.experimental.shard_map.shard_map`` with a
    ``check_rep=`` kwarg (replication checking);
  * jax >= 0.6           : ``jax.shard_map`` with the kwarg renamed to
    ``check_vma=`` (varying-manual-axes checking — same contract).

Every call site in this repo resolves ``shard_map`` — and the collectives it
composes with — through THIS module, so the rest of the codebase is version
agnostic. The contract exposed here:

    shard_map(f, mesh=..., in_specs=..., out_specs=..., check_vma=True)

``check_vma`` is translated to ``check_rep`` on old jax. ``mesh`` is
required (we never rely on the new-API ambient-mesh default: it does not
exist on 0.4.x).

The collectives re-exported below (``psum``, ``pmax``, ``pmean``,
``all_gather``, ``ppermute``, ``psum_scatter``, ``axis_index``) are stable
``jax.lax`` API across the supported range, but call sites import them from
here so the repo has exactly ONE distribution API surface — if a future jax
moves or renames any of them, this module is the single place to patch.

Supported jax range: 0.4.30 — current (feature-detected at import time;
``HAS_NATIVE_SHARD_MAP`` records which branch was taken).
"""
from __future__ import annotations

from typing import Any, Callable

import jax

HAS_NATIVE_SHARD_MAP = hasattr(jax, "shard_map")

if not HAS_NATIVE_SHARD_MAP:
    from jax.experimental.shard_map import shard_map as _experimental_shard_map


def shard_map(f: Callable, *, mesh, in_specs, out_specs,
              check_vma: bool = True, **kwargs: Any) -> Callable:
    """Map ``f`` over shards of the mesh — portable across jax versions.

    Args:
      f: per-shard function (sees local shards; collectives see mesh axes).
      mesh: jax.sharding.Mesh (required; no ambient-mesh default).
      in_specs / out_specs: PartitionSpec pytrees (prefix trees allowed).
      check_vma: enable replication/varying-axes checking (maps to
        ``check_rep`` on jax < 0.6). Pass False for bodies with data-dependent
        collectives inside lax control flow, where the checker is too strict.
    """
    # accept legacy spelling so downstream code written against either jax
    # API keeps working through this shim
    if "check_rep" in kwargs:
        check_vma = kwargs.pop("check_rep")
    if kwargs:
        raise TypeError(f"unsupported shard_map kwargs: {sorted(kwargs)}")
    if HAS_NATIVE_SHARD_MAP:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    return _experimental_shard_map(f, mesh=mesh, in_specs=in_specs,
                                   out_specs=out_specs, check_rep=check_vma)


# ---------------------------------------------------------------------------
# collectives — stable names, one import surface
# ---------------------------------------------------------------------------

psum = jax.lax.psum
pmax = jax.lax.pmax
pmin = jax.lax.pmin
pmean = jax.lax.pmean
all_gather = jax.lax.all_gather
ppermute = jax.lax.ppermute
psum_scatter = jax.lax.psum_scatter
axis_index = jax.lax.axis_index


def axis_size(mesh, axis) -> int:
    """Number of shards along ``axis`` (a mesh axis name or tuple of them)."""
    axes = axis if isinstance(axis, tuple) else (axis,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


# ---------------------------------------------------------------------------
# compiled-executable introspection
# ---------------------------------------------------------------------------

def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` normalised across jax versions.

    The 0.4.x line returns a LIST with one properties-dict per program
    (which made every roofline launch/dryrun cell report status:"error"
    after compiling fine, when the caller assumed a dict); jax >= 0.5
    returns the dict directly (and may return None when XLA provides no
    analysis). Callers always get a plain dict — empty when the analysis is
    unavailable — so key lookups like ``cost.get("flops")`` work on every
    supported version.
    """
    try:
        cost = compiled.cost_analysis()
    except Exception:
        return {}
    if cost is None:
        return {}
    if isinstance(cost, (list, tuple)):
        return dict(cost[0]) if len(cost) else {}
    return dict(cost)

"""Distributed runtime: mesh context, parameter/activation sharding rules,
sequence parallelism, compressed cross-pod collectives."""

"""Distributed runtime: version-portable shard_map/collectives (compat.py —
the ONLY place jax's shard_map is imported), mesh context,
parameter/activation sharding rules, sequence parallelism, compressed
cross-pod collectives."""

"""Sharding rules: parameter-tree path -> PartitionSpec, activation
constraints, and the mesh context.

Mesh axes (launch/mesh.py):
    single-pod : ("data", "model") = (16, 16)        — 256 chips
    multi-pod  : ("pod", "data", "model") = (2,16,16) — 512 chips

Parallelism mapping
  * DP   : batch over ("pod", "data")
  * FSDP : parameters ALSO sharded over "data" on their non-TP axis
           (ZeRO-3 style; GSPMD inserts the forward all-gathers). Optimizer
           state inherits it -> ZeRO comes free.
  * TP   : heads / d_ff / vocab / ssm-channel over "model".
  * EP   : MoE expert axis over "model".
  * SP   : long-context sequence sharding over "data"
           (core.scan.sharded_diag_scan + sequence-sharded decode attention).
  * "pod": pure DP across the DCN-connected pods; gradient all-reduce may be
           int8-compressed (distributed/compression.py).

Rules are longest-match on the flattened parameter path, so arch-specific
overrides can be layered on top of the generic table.
"""
from __future__ import annotations

import contextlib
import contextvars
import re
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: contextvars.ContextVar[Optional[Mesh]] = contextvars.ContextVar(
    "repro_mesh", default=None)
_STRATEGY: contextvars.ContextVar[str] = contextvars.ContextVar(
    "repro_strategy", default="megatron")
_MANUAL: contextvars.ContextVar[bool] = contextvars.ContextVar(
    "repro_manual_body", default=False)


@contextlib.contextmanager
def manual_body():
    """Mark that model code is being traced INSIDE a fully-manual shard_map
    body (the explicit gradient path, train/step.py). GSPMD activation
    constraints are meaningless there — every mesh axis is manual, and a
    staged with_sharding_constraint naming one fails at lowering — so
    ``shard_activation``/``constrain_batch_only`` become no-ops while this
    context is active (tracing is synchronous, so the contextvar scopes the
    staged ops exactly)."""
    token = _MANUAL.set(True)
    try:
        yield
    finally:
        _MANUAL.reset(token)


def in_manual_body() -> bool:
    """True while tracing inside a fully-manual shard_map body."""
    return _MANUAL.get()


@contextlib.contextmanager
def use_strategy(name: str):
    """Select the parameter/activation distribution strategy for code in
    this context: "megatron" | "fsdp" | "serve" | "ring" | "moe_rep" (see
    ``_apply_strategy`` and ArchConfig.sharding_strategy)."""
    token = _STRATEGY.set(name)
    try:
        yield name
    finally:
        _STRATEGY.reset(token)


def current_strategy() -> str:
    """The active distribution strategy name (default "megatron")."""
    return _STRATEGY.get()


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    """Install ``mesh`` as the ambient mesh (contextvar + jax Mesh
    context) — every sharding helper below reads it via current_mesh()."""
    token = _MESH.set(mesh)
    try:
        with mesh:              # jax.sharding.Mesh context manager
            yield mesh
    finally:
        _MESH.reset(token)


def current_mesh() -> Optional[Mesh]:
    """The ambient mesh installed by ``use_mesh`` (None outside)."""
    return _MESH.get()


def _axes(mesh: Mesh) -> Tuple[str, ...]:
    """The mesh's axis names as a tuple."""
    return tuple(mesh.axis_names)


def batch_axes(mesh: Mesh):
    """The data-parallel axes present in ``mesh`` (("pod", "data") order),
    or None when it has neither — the axes batches shard over."""
    return tuple(a for a in ("pod", "data") if a in _axes(mesh)) or None


def pod_axis(mesh: Mesh) -> Optional[str]:
    """The cross-pod (DCN) axis name if the mesh has one."""
    return "pod" if "pod" in _axes(mesh) else None


# ---------------------------------------------------------------------------
# pod-local specs (the explicit gradient path, train/step.py)
# ---------------------------------------------------------------------------
# In grad_reduce="explicit" mode the whole grad+update runs inside ONE
# shard_map over the DP axes: params/moments are replicated (pure DP), the
# batch is sharded over ("pod", "data") on its leading dim, and the
# error-feedback residual is sharded over "pod" on its LEADING pod dim
# (quantisation error is a per-pod quantity). These helpers are the spec
# side of that contract.

def replicated_specs(tree) -> Any:
    """P() for every leaf — explicit-mode params/moments (pure DP)."""
    return jax.tree_util.tree_map(lambda _: P(), tree)


def pod_local_batch_specs(batch, mesh: Mesh) -> Any:
    """Leading batch dim over the DP axes — STRICT: explicit mode shards
    manually, so non-divisible batches are a config error, not a silent
    replication fallback."""
    ba = batch_axes(mesh)
    n_dp = 1
    for a in (ba or ()):
        n_dp *= mesh.shape[a]

    def leaf_spec(path, leaf):
        nd = getattr(leaf, "ndim", 0)
        shape = getattr(leaf, "shape", ())
        if nd == 0 or ba is None:
            return P()
        if shape[0] % n_dp != 0:
            raise ValueError(
                f"grad_reduce='explicit' requires the batch dim to divide "
                f"the DP axes: leaf {_path_str(path)!r} has leading dim "
                f"{shape[0]}, mesh DP size {n_dp} ({ba})")
        return P(*([ba] + [None] * (nd - 1)))
    return jax.tree_util.tree_map_with_path(leaf_spec, batch)


def residual_specs(residual, mesh: Mesh, param_specs=None) -> Any:
    """Specs for the error-feedback residual tree: leading pod dim (see
    train/state.py), trailing dims replicated (explicit mode) or inheriting
    the parameter sharding rules when ``param_specs`` is given (the gspmd
    compressed path, where gradients stay param-sharded). The ONE place the
    residual layout rule lives — train/step.py calls this for both the
    state sharding and the shard_map in/out specs."""
    if param_specs is None:
        return jax.tree_util.tree_map(
            lambda r: P(*(["pod"] + [None] * (r.ndim - 1))), residual)
    return jax.tree_util.tree_map(
        lambda s, r: fit_spec(P(*(("pod",) + tuple(s))), r.shape, mesh),
        param_specs, residual)


# ---------------------------------------------------------------------------
# activation constraints (no-op outside a mesh context)
# ---------------------------------------------------------------------------

def _act_spec(mesh: Mesh, strategy: str, shape) -> P:
    """Activation PartitionSpec for ``shape`` under ``strategy`` (batch
    over the DP axes; fsdp spreads over the whole grid; ring also shards
    the time axis)."""
    ba = batch_axes(mesh) or ()
    if strategy == "moe_rep":
        strategy = "fsdp"
    if strategy == "fsdp":
        # batch over every axis (ZeRO-3 layout), cascading fallback
        for axes in ((*ba, "model"), ba, None):
            if axes is None:
                return P()
            prod = 1
            for a in axes:
                prod *= mesh.shape.get(a, 1)
            if shape and shape[0] % prod == 0:
                return P(axes if len(axes) > 1 else axes[0])
        return P()
    if strategy == "ring":
        # (B, T, D): batch over DP, TIME over model (sequence parallelism)
        return fit_spec(P(ba if ba else None, "model"), shape, mesh)
    return fit_spec(P(ba if ba else None), shape, mesh)


def constrain_batch_only(x: jax.Array) -> jax.Array:
    """Constrain a small per-step tensor to batch-only sharding (decode
    q/k/v): prevents the fused-qkv model-axis sharding from leaking into
    the cache layout."""
    mesh = current_mesh()
    if mesh is None or _MANUAL.get():
        return x
    ba = batch_axes(mesh)
    if ba is None:
        return x
    spec = fit_spec(P(ba, *([None] * (x.ndim - 1))), x.shape, mesh)
    try:
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    except ValueError:
        return x


def shard_activation(x: jax.Array, kind: str = "act") -> jax.Array:
    """Constrain an activation to the strategy's layout (no-op without a
    mesh, inside manual shard_map bodies, and on non-divisible shapes)."""
    mesh = current_mesh()
    if mesh is None or _MANUAL.get():
        return x
    spec = _act_spec(mesh, current_strategy(), getattr(x, "shape", ()))
    if spec == P(None) or spec == P():
        return x
    try:
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    except ValueError:
        return x


# ---------------------------------------------------------------------------
# parameter sharding rules
# ---------------------------------------------------------------------------
# Longest-regex-match table over '/'.joined tree paths. Specs written for the
# 2D ("data", "model") sub-mesh; the "pod" axis never shards parameters
# (pods are pure DP replicas).
#
# Convention per tensor (FSDP axis first where applicable). Leading scan/
# stack axes (layer groups, experts handled explicitly) are unsharded.

_PARAM_RULES = [
    # --- embeddings / head: vocab over model (TP), d_model over data (FSDP)
    (r"embed$",                 P("model", "data")),
    (r"lm_head$",               P("data", "model")),
    # --- attention
    (r"wqkv$",                  P("data", "model")),
    (r"wo$",                    P("model", "data")),
    # --- gated mlp
    (r"w_gate$",                P("data", "model")),
    (r"w_up$",                  P("data", "model")),
    (r"w_down$",                P("model", "data")),
    # --- plain mlp
    (r"fc1/w$",                 P("data", "model")),
    (r"fc1/b$",                 P("model")),
    (r"fc2/w$",                 P("model", "data")),
    (r"fc2/b$",                 P()),
    # --- moe (leading expert axis over model = EP)
    (r"moe/router$",            P(None, None)),
    (r"moe/w_gate$",            P("model", "data", None)),
    (r"moe/w_up$",              P("model", "data", None)),
    (r"moe/w_down$",            P("model", None, "data")),
    # --- mamba mixers: channel (d_inner) axis over model
    (r"mixer/in_proj/w$",       P("data", "model")),
    (r"mixer/out_proj/w$",      P("model", "data")),
    (r"mixer/x_proj/w$",        P("model", None)),
    (r"mixer/dt_proj/w$",       P(None, "model")),
    (r"mixer/dt_proj/b$",       P("model")),
    (r"mixer/conv_w$",          P(None, "model")),
    (r"mixer/conv_b$",          P("model")),
    (r"mixer/A_log$",           P("model")),
    (r"mixer/D$",               P("model")),
    (r"mixer/dt_bias$",         P("model")),
    (r"mixer/norm/scale$",      P("model")),
    # --- lrc mixer: d_inner over model (state dim is embarrassingly TP)
    (r"mixer/a_u$",             P("data", "model")),
    (r"mixer/w_u$",             P("data", "model")),
    (r"mixer/(a_x|b_x|b_u|v_u|v_x|g_max_x|k_max_x|g_max_u|k_max_u|w_x|g_leak|e_leak)$",
                                P("model")),
    # --- vlm projector
    (r"projector/fc1/w$",       P("data", "model")),
    (r"projector/fc2/w$",       P("model", "data")),
    # --- norms / everything 1-D: replicated
    (r"(scale|bias|b)$",        P()),
]


def fit_spec(spec: P, shape, mesh: Optional[Mesh]) -> P:
    """Drop sharding on any dimension whose size is not divisible by the
    product of its assigned mesh axes (vocab remainders, batch=1 long-context
    cells, odd expert counts), and drop axes the mesh does not have at all
    (the generic param rules name "data"/"model"; a pod-only DP mesh has
    neither). Keeps the rest of the spec intact — the shape-aware fallback
    every production sharding layer needs."""
    if mesh is None or spec is None:
        return spec
    sizes = dict(mesh.shape)
    out = []
    for i, entry in enumerate(tuple(spec)):
        if entry is None or i >= len(shape):
            out.append(entry)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        axes = tuple(a for a in axes if a in sizes)
        if not axes:
            out.append(None)
            continue
        prod = 1
        for a in axes:
            prod *= sizes[a]
        if shape[i] % prod != 0:
            out.append(None)
        else:
            out.append(axes if len(axes) > 1 else axes[0])
    return P(*out)


def _path_str(path) -> str:
    """Flatten a tree_util key path to the '/'-joined rule-lookup key."""
    parts = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            parts.append(str(k.idx))
        elif isinstance(k, jax.tree_util.GetAttrKey):
            parts.append(k.name)
        else:
            parts.append(str(k))
    return "/".join(parts)


def _apply_strategy(base: tuple, strategy: str, ndim: int) -> tuple:
    """Transform a megatron-rule spec for the other strategies."""
    if strategy == "megatron" or not base:
        return base
    if strategy == "fsdp":
        # ZeRO-3: shard the LAST sharded-able dim over the whole chip grid,
        # nothing else. GSPMD inserts per-layer weight all-gathers instead
        # of per-block activation all-reduces.
        out = [None] * len(base)
        out[-1] = ("data", "model")
        return tuple(out)
    if strategy == "serve":
        # weight-stationary: keep TP ("model"), drop FSDP ("data")
        return tuple(e if e == "model" else None for e in base)
    if strategy == "ring":
        # weights over "data" only; "model" is reserved for the time axis
        out = []
        for e in base:
            if e == "model":
                out.append("data")
            elif e == "data":
                out.append(None)
            else:
                out.append(e)
        return tuple(out)
    return base


def spec_for_param(path_str: str, ndim: int,
                   strategy: Optional[str] = None) -> P:
    """Look up the sharding spec; prepend Nones for leading stack axes."""
    strategy = strategy or current_strategy()
    if strategy == "moe_rep" and "moe/" in path_str:
        # tiny-expert MoE (granite d_ff=512): EP/TP moves more bytes than
        # the experts compute — REPLICATE expert weights, tokens stay put,
        # dispatch is chip-local (§Perf D5)
        return P()
    if strategy == "moe_rep":
        strategy = "fsdp"
    for pat, spec in _PARAM_RULES:
        if re.search(pat, path_str):
            base = _apply_strategy(tuple(spec), strategy, ndim)
            # A rule written for rank-k applies to rank-(k+s) stacked tensors.
            extra = ndim - len(base)
            if extra < 0:
                # e.g. rule P("data","model") on a 1-D bias: replicate.
                return P()
            return P(*([None] * extra + list(base)))
    return P()


def param_specs(params, mesh: Optional[Mesh] = None) -> Any:
    """PartitionSpec pytree matching ``params``. Leading scan axes detected
    by rank mismatch with the rule's spec length. With ``mesh``, specs are
    shape-fitted (divisibility fallback)."""
    def leaf_spec(path, leaf):
        spec = spec_for_param(_path_str(path), getattr(leaf, "ndim", 0))
        return fit_spec(spec, getattr(leaf, "shape", ()), mesh)
    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def param_shardings(mesh: Mesh, params) -> Any:
    """``param_specs`` materialised as NamedShardings on ``mesh``."""
    specs = param_specs(params, mesh)
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs)


def cache_specs(cache, mesh: Optional[Mesh] = None) -> Any:
    """Decode caches: KV rings are sharded (batch over "data", SEQUENCE over
    "model"). Sequence sharding makes decode attention TP-over-context
    (scores/outputs reduce with tiny (B,H)-sized collectives), keeps every
    full-size cache under HBM (internvl decode_32k: 412 GB total -> 1.6
    GB/chip), and — critically — keeps the per-step layout FIXED so GSPMD
    never reshards the whole cache (the C-hillclimb finding: mixed layouts
    cost a full-cache fp32 all-gather per step). SSM states: channels over
    "model". Batch=1 cells fall back via fit_spec.
    """
    sizes = dict(mesh.shape) if mesh is not None else {}

    def leaf_spec(path, leaf):
        ps = _path_str(path)
        nd = getattr(leaf, "ndim", 0)
        shape = getattr(leaf, "shape", ())
        if ps.endswith("pos"):
            return P()
        if re.search(r"(^|/)(k|v|ck|cv)$", ps) and nd >= 4:
            spec = [None] * (nd - 4) + ["data", "model", None, None]
            if (sizes and shape[nd - 4] % sizes.get("data", 1) != 0
                    and shape[nd - 3] % sizes.get("data", 1) == 0):
                # batch unshardable (long_500k): sequence over BOTH axes
                spec = [None] * (nd - 4) + [None, ("data", "model"),
                                            None, None]
            return fit_spec(P(*spec), shape, mesh)
        if re.search(r"ssm$", ps) and nd >= 3:
            return fit_spec(P(*([None] * (nd - 3) + ["data", "model", None])),
                            shape, mesh)
        if re.search(r"conv$", ps) and nd >= 3:
            return fit_spec(P(*([None] * (nd - 3) + ["data", None, "model"])),
                            shape, mesh)
        return P()
    return jax.tree_util.tree_map_with_path(leaf_spec, cache)


def batch_specs(batch, mesh: Mesh, seq_sharded: bool = False) -> Any:
    """Input batch: leading batch dim over DP axes (strategy-aware: fsdp
    spreads over the full chip grid; ring also shards the time dim over
    "model"), with divisibility fallback."""
    ba = batch_axes(mesh)
    strategy = current_strategy()

    def leaf_spec(path, leaf):
        nd = getattr(leaf, "ndim", 0)
        shape = getattr(leaf, "shape", ())
        if nd == 0:
            return P()
        if seq_sharded and nd >= 2:
            return fit_spec(P(None, "data"), shape, mesh)
        spec = _act_spec(mesh, strategy, shape)
        # tokens are (B, T); act spec may carry a time entry — keep at most
        # the first two entries, pad with None
        entries = list(tuple(spec))[:nd] + [None] * max(0, nd - len(tuple(spec)))
        return fit_spec(P(*entries), shape, mesh)
    return jax.tree_util.tree_map_with_path(leaf_spec, batch)

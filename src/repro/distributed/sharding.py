"""Sharding rules: parameter-tree path -> PartitionSpec, activation
constraints, and the mesh context.

Mesh axes (launch/mesh.py):
    single-pod : ("data", "model") = (16, 16)        — 256 chips
    multi-pod  : ("pod", "data", "model") = (2,16,16) — 512 chips

Parallelism mapping
  * DP   : batch over ("pod", "data")
  * FSDP : parameters ALSO sharded over "data" on their non-TP axis
           (ZeRO-3 style; GSPMD inserts the forward all-gathers). Optimizer
           state inherits it -> ZeRO comes free.
  * TP   : heads / d_ff / vocab / ssm-channel over "model".
  * EP   : MoE expert axis over "model".
  * SP   : long-context sequence sharding over "data"
           (core.scan.sharded_diag_scan + sequence-sharded decode attention).
  * "pod": pure DP across the DCN-connected pods; gradient all-reduce may be
           int8-compressed (distributed/compression.py).

Rules are longest-match on the flattened parameter path, so arch-specific
overrides can be layered on top of the generic table.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import functools
import re
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import compat

_MESH: contextvars.ContextVar[Optional[Mesh]] = contextvars.ContextVar(
    "repro_mesh", default=None)
_STRATEGY: contextvars.ContextVar[str] = contextvars.ContextVar(
    "repro_strategy", default="megatron")
_MANUAL: contextvars.ContextVar[bool] = contextvars.ContextVar(
    "repro_manual_body", default=False)


@contextlib.contextmanager
def manual_body():
    """Mark that model code is being traced INSIDE a fully-manual shard_map
    body (the explicit gradient path, train/step.py). GSPMD activation
    constraints are meaningless there — every mesh axis is manual, and a
    staged with_sharding_constraint naming one fails at lowering — so
    ``shard_activation``/``constrain_batch_only`` become no-ops while this
    context is active (tracing is synchronous, so the contextvar scopes the
    staged ops exactly)."""
    token = _MANUAL.set(True)
    try:
        yield
    finally:
        _MANUAL.reset(token)


def in_manual_body() -> bool:
    """True while tracing inside a fully-manual shard_map body."""
    return _MANUAL.get()


# ---------------------------------------------------------------------------
# manual tensor parallelism (the explicit gradient seam, train/step.py)
# ---------------------------------------------------------------------------
# Inside the fully-manual explicit-seam shard_map, GSPMD never sees the
# "model" axis — model code does its own tensor parallelism. The contract:
#
#   * the step body activates ``tp_region("model")`` when
#     TrainConfig.param_sharding selects a TP mode and the mesh has a
#     model axis of size > 1;
#   * each layer decides per parameter leaf whether it is actually split by
#     a SHAPE TEST (local_dim * tp_size == global_dim) — non-divisible or
#     overridden leaves fall back to replicated compute automatically;
#   * TP compute regions are bracketed by the megatron f/g seams below:
#     ``tp_region_in`` where a replicated activation enters column-parallel
#     compute, ``tp_region_out`` after the row-parallel matmul that closes
#     the region. ``tp_psum`` is the mid-region all-reduce whose cotangents
#     are rank-varying (row-parallel matmuls whose output is consumed
#     shard-wise, full-channel RMS statistics).
#
# The seams are custom_vjp so backward collectives are placed explicitly —
# native psum AD under ``check_rep=False`` does not account for
# rank-varying cotangents.

_TP_AXIS: contextvars.ContextVar[Optional[Tuple[str, int]]] = (
    contextvars.ContextVar("repro_tp_axis", default=None))


@contextlib.contextmanager
def tp_region(axis: Optional[str], size: int = 0):
    """Activate manual tensor-parallel compute over mesh axis ``axis`` for
    model code traced under this context (None = deactivate). ``size`` is
    the static TP degree; pass it when known (train/step.py does),
    otherwise it is read from the ambient mesh at ``tp_info`` time."""
    token = _TP_AXIS.set(None if axis is None else (axis, int(size)))
    try:
        yield
    finally:
        _TP_AXIS.reset(token)


def tp_info() -> Tuple[Optional[str], int]:
    """(axis_name, size) of the active manual-TP region, or (None, 1)
    outside one / when neither the region nor the ambient mesh can say
    how many shards the axis has."""
    got = _TP_AXIS.get()
    if got is None:
        return None, 1
    axis, size = got
    if size > 1:
        return axis, size
    mesh = current_mesh()
    if mesh is None or axis not in mesh.axis_names or mesh.shape[axis] <= 1:
        return None, 1
    return axis, mesh.shape[axis]


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def tp_region_in(x, axis):
    """Megatron "f" seam: identity forward, psum(axis) backward. Place
    where a REPLICATED activation enters a TP region — the backward psum
    folds each rank's partial input-gradient into the replicated total."""
    return x


def _tp_in_fwd(x, axis):
    """Forward of the "f" seam: identity, no residuals."""
    return x, None


def _tp_in_bwd(axis, _, g):
    """Backward of the "f" seam: psum the rank-partial input grads."""
    return (compat.psum(g, axis),)


tp_region_in.defvjp(_tp_in_fwd, _tp_in_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def tp_region_out(x, axis):
    """Megatron "g" seam: psum(axis) forward, identity backward. Place on
    the partial output of the row-parallel matmul that CLOSES a TP region —
    every rank then re-enters replicated compute with the full activation
    and its unchanged (replicated) cotangent."""
    return compat.psum(x, axis)


def _tp_out_fwd(x, axis):
    """Forward of the "g" seam: psum the row-parallel partial output."""
    return compat.psum(x, axis), None


def _tp_out_bwd(axis, _, g):
    """Backward of the "g" seam: identity (cotangent is replicated)."""
    return (g,)


tp_region_out.defvjp(_tp_out_fwd, _tp_out_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def tp_psum(x, axis):
    """Mid-region all-reduce: psum forward AND backward. For sums whose
    replicated result is consumed SHARD-WISE downstream (x_proj-style
    row-parallel matmuls feeding per-channel compute, full-width RMS
    statistics) — the cotangents are rank-varying, so the backward must
    fold them back into the replicated total."""
    return compat.psum(x, axis)


def _tp_psum_fwd(x, axis):
    """Forward of the mid-region all-reduce: psum."""
    return compat.psum(x, axis), None


def _tp_psum_bwd(axis, _, g):
    """Backward of the mid-region all-reduce: psum the rank-varying
    cotangents back into the replicated total."""
    return (compat.psum(g, axis),)


tp_psum.defvjp(_tp_psum_fwd, _tp_psum_bwd)


def tp_gather_weight(w, axis, dim):
    """All-gather a TP-sharded weight along ``dim`` for the packed-layout
    pattern (wqkv / mixer in_proj): gather the full matrix, then
    ``dynamic_slice`` the rank's segments at ``tp_index``-dependent
    offsets. The gather transposes to psum_scatter, so gradients for
    overlapping (shared) segments sum across ranks exactly."""
    return compat.all_gather(w, axis, axis=dim, tiled=True)


def tp_index(axis):
    """This rank's position along the TP mesh axis (traced scalar)."""
    return compat.axis_index(axis)


@contextlib.contextmanager
def use_strategy(name: str):
    """Select the parameter/activation distribution strategy for code in
    this context: "megatron" | "fsdp" | "serve" | "ring" | "moe_rep" (see
    ``_apply_strategy`` and ArchConfig.sharding_strategy)."""
    token = _STRATEGY.set(name)
    try:
        yield name
    finally:
        _STRATEGY.reset(token)


def current_strategy() -> str:
    """The active distribution strategy name (default "megatron")."""
    return _STRATEGY.get()


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    """Install ``mesh`` as the ambient mesh (contextvar + jax Mesh
    context) — every sharding helper below reads it via current_mesh()."""
    token = _MESH.set(mesh)
    try:
        with mesh:              # jax.sharding.Mesh context manager
            yield mesh
    finally:
        _MESH.reset(token)


def current_mesh() -> Optional[Mesh]:
    """The ambient mesh installed by ``use_mesh`` (None outside)."""
    return _MESH.get()


def _axes(mesh: Mesh) -> Tuple[str, ...]:
    """The mesh's axis names as a tuple."""
    return tuple(mesh.axis_names)


def batch_axes(mesh: Mesh):
    """The data-parallel axes present in ``mesh`` (("pod", "data") order),
    or None when it has neither — the axes batches shard over."""
    return tuple(a for a in ("pod", "data") if a in _axes(mesh)) or None


def pod_axis(mesh: Mesh) -> Optional[str]:
    """The cross-pod (DCN) axis name if the mesh has one."""
    return "pod" if "pod" in _axes(mesh) else None


# ---------------------------------------------------------------------------
# pod-local specs (the explicit gradient path, train/step.py)
# ---------------------------------------------------------------------------
# In grad_reduce="explicit" mode the whole grad+update runs inside ONE
# shard_map over the DP axes: params/moments are replicated (pure DP), the
# batch is sharded over ("pod", "data") on its leading dim, and the
# error-feedback residual is sharded over "pod" on its LEADING pod dim
# (quantisation error is a per-pod quantity). These helpers are the spec
# side of that contract.

def replicated_specs(tree) -> Any:
    """P() for every leaf — explicit-mode params/moments (pure DP)."""
    return jax.tree_util.tree_map(lambda _: P(), tree)


def pod_local_batch_specs(batch, mesh: Mesh) -> Any:
    """Leading batch dim over the DP axes — STRICT: explicit mode shards
    manually, so non-divisible batches are a config error, not a silent
    replication fallback."""
    ba = batch_axes(mesh)
    n_dp = 1
    for a in (ba or ()):
        n_dp *= mesh.shape[a]

    def leaf_spec(path, leaf):
        """Pod-local batch spec for one leaf (batch dim over DP axes)."""
        nd = getattr(leaf, "ndim", 0)
        shape = getattr(leaf, "shape", ())
        if nd == 0 or ba is None:
            return P()
        if shape[0] % n_dp != 0:
            raise ValueError(
                f"grad_reduce='explicit' requires the batch dim to divide "
                f"the DP axes: leaf {_path_str(path)!r} has leading dim "
                f"{shape[0]}, mesh DP size {n_dp} ({ba})")
        return P(*([ba] + [None] * (nd - 1)))
    return jax.tree_util.tree_map_with_path(leaf_spec, batch)


def residual_specs(residual, mesh: Mesh, param_specs=None) -> Any:
    """Specs for the error-feedback residual tree: leading pod dim (see
    train/state.py), trailing dims replicated (explicit mode) or inheriting
    the parameter sharding rules when ``param_specs`` is given (the gspmd
    compressed path, where gradients stay param-sharded). The ONE place the
    residual layout rule lives — train/step.py calls this for both the
    state sharding and the shard_map in/out specs."""
    if param_specs is None:
        return jax.tree_util.tree_map(
            lambda r: P(*(["pod"] + [None] * (r.ndim - 1))), residual)
    return jax.tree_util.tree_map(
        lambda s, r: fit_spec(P(*(("pod",) + tuple(s))), r.shape, mesh),
        param_specs, residual)


# ---------------------------------------------------------------------------
# activation constraints (no-op outside a mesh context)
# ---------------------------------------------------------------------------

def _act_spec(mesh: Mesh, strategy: str, shape) -> P:
    """Activation PartitionSpec for ``shape`` under ``strategy`` (batch
    over the DP axes; fsdp spreads over the whole grid; ring also shards
    the time axis)."""
    ba = batch_axes(mesh) or ()
    if strategy == "moe_rep":
        strategy = "fsdp"
    if strategy == "fsdp":
        # batch over every axis (ZeRO-3 layout), cascading fallback
        for axes in ((*ba, "model"), ba, None):
            if axes is None:
                return P()
            prod = 1
            for a in axes:
                prod *= mesh.shape.get(a, 1)
            if shape and shape[0] % prod == 0:
                return P(axes if len(axes) > 1 else axes[0])
        return P()
    if strategy == "ring":
        # (B, T, D): batch over DP, TIME over model (sequence parallelism)
        return fit_spec(P(ba if ba else None, "model"), shape, mesh)
    return fit_spec(P(ba if ba else None), shape, mesh)


def constrain_batch_only(x: jax.Array) -> jax.Array:
    """Constrain a small per-step tensor to batch-only sharding (decode
    q/k/v): prevents the fused-qkv model-axis sharding from leaking into
    the cache layout."""
    mesh = current_mesh()
    if mesh is None or _MANUAL.get():
        return x
    ba = batch_axes(mesh)
    if ba is None:
        return x
    spec = fit_spec(P(ba, *([None] * (x.ndim - 1))), x.shape, mesh)
    try:
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    except ValueError:
        return x


def shard_activation(x: jax.Array, kind: str = "act") -> jax.Array:
    """Constrain an activation to the strategy's layout (no-op without a
    mesh, inside manual shard_map bodies, and on non-divisible shapes)."""
    mesh = current_mesh()
    if mesh is None or _MANUAL.get():
        return x
    spec = _act_spec(mesh, current_strategy(), getattr(x, "shape", ()))
    if spec == P(None) or spec == P():
        return x
    try:
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    except ValueError:
        return x


# ---------------------------------------------------------------------------
# parameter sharding rules
# ---------------------------------------------------------------------------
# Longest-regex-match table over '/'.joined tree paths. Specs written for the
# 2D ("data", "model") sub-mesh; the "pod" axis never shards parameters
# (pods are pure DP replicas).
#
# Convention per tensor (FSDP axis first where applicable). Leading scan/
# stack axes (layer groups, experts handled explicitly) are unsharded.

_PARAM_RULES = [
    # --- embeddings / head: vocab over model (TP), d_model over data (FSDP)
    (r"embed$",                 P("model", "data")),
    (r"lm_head$",               P("data", "model")),
    # --- attention
    (r"wqkv$",                  P("data", "model")),
    (r"wo$",                    P("model", "data")),
    # --- gated mlp
    (r"w_gate$",                P("data", "model")),
    (r"w_up$",                  P("data", "model")),
    (r"w_down$",                P("model", "data")),
    # --- plain mlp
    (r"fc1/w$",                 P("data", "model")),
    (r"fc1/b$",                 P("model")),
    (r"fc2/w$",                 P("model", "data")),
    (r"fc2/b$",                 P()),
    # --- moe (leading expert axis over model = EP)
    (r"moe/router$",            P(None, None)),
    (r"moe/w_gate$",            P("model", "data", None)),
    (r"moe/w_up$",              P("model", "data", None)),
    (r"moe/w_down$",            P("model", None, "data")),
    # --- mamba mixers: channel (d_inner) axis over model
    (r"mixer/in_proj/w$",       P("data", "model")),
    (r"mixer/out_proj/w$",      P("model", "data")),
    (r"mixer/x_proj/w$",        P("model", None)),
    (r"mixer/dt_proj/w$",       P(None, "model")),
    (r"mixer/dt_proj/b$",       P("model")),
    (r"mixer/conv_w$",          P(None, "model")),
    (r"mixer/conv_b$",          P("model")),
    (r"mixer/A_log$",           P("model")),
    (r"mixer/D$",               P("model")),
    (r"mixer/dt_bias$",         P("model")),
    (r"mixer/norm/scale$",      P("model")),
    # --- lrc mixer: d_inner over model (state dim is embarrassingly TP)
    (r"mixer/a_u$",             P("data", "model")),
    (r"mixer/w_u$",             P("data", "model")),
    (r"mixer/(a_x|b_x|b_u|v_u|v_x|g_max_x|k_max_x|g_max_u|k_max_u|w_x|g_leak|e_leak)$",
                                P("model")),
    # --- vlm projector
    (r"projector/fc1/w$",       P("data", "model")),
    (r"projector/fc2/w$",       P("model", "data")),
    # --- norms / everything 1-D: replicated
    (r"(scale|bias|b)$",        P()),
]


def fit_spec(spec: P, shape, mesh: Optional[Mesh]) -> P:
    """Drop sharding on any dimension whose size is not divisible by the
    product of its assigned mesh axes (vocab remainders, batch=1 long-context
    cells, odd expert counts), and drop axes the mesh does not have at all
    (the generic param rules name "data"/"model"; a pod-only DP mesh has
    neither). Keeps the rest of the spec intact — the shape-aware fallback
    every production sharding layer needs."""
    if mesh is None or spec is None:
        return spec
    sizes = dict(mesh.shape)
    out = []
    for i, entry in enumerate(tuple(spec)):
        if entry is None or i >= len(shape):
            out.append(entry)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        axes = tuple(a for a in axes if a in sizes)
        if not axes:
            out.append(None)
            continue
        prod = 1
        for a in axes:
            prod *= sizes[a]
        if shape[i] % prod != 0:
            out.append(None)
        else:
            out.append(axes if len(axes) > 1 else axes[0])
    return P(*out)


def make_spec(*entries) -> P:
    """The sanctioned ``PartitionSpec`` constructor for call sites outside
    this module and train/step.py. tools/repro_lint enforces that every
    other module builds specs through here (or the higher-level helpers),
    so the axis-name vocabulary stays reviewable in one place."""
    return P(*entries)


def _path_str(path) -> str:
    """Flatten a tree_util key path to the '/'-joined rule-lookup key."""
    parts = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            parts.append(str(k.idx))
        elif isinstance(k, jax.tree_util.GetAttrKey):
            parts.append(k.name)
        else:
            parts.append(str(k))
    return "/".join(parts)


def _apply_strategy(base: tuple, strategy: str, ndim: int) -> tuple:
    """Transform a megatron-rule spec for the other strategies."""
    if strategy == "megatron" or not base:
        return base
    if strategy == "fsdp":
        # ZeRO-3: shard the LAST sharded-able dim over the whole chip grid,
        # nothing else. GSPMD inserts per-layer weight all-gathers instead
        # of per-block activation all-reduces.
        out = [None] * len(base)
        out[-1] = ("data", "model")
        return tuple(out)
    if strategy == "serve":
        # weight-stationary: keep TP ("model"), drop FSDP ("data")
        return tuple(e if e == "model" else None for e in base)
    if strategy == "ring":
        # weights over "data" only; "model" is reserved for the time axis
        out = []
        for e in base:
            if e == "model":
                out.append("data")
            elif e == "data":
                out.append(None)
            else:
                out.append(e)
        return tuple(out)
    return base


def spec_for_param(path_str: str, ndim: int,
                   strategy: Optional[str] = None) -> P:
    """Look up the sharding spec; prepend Nones for leading stack axes."""
    strategy = strategy or current_strategy()
    if strategy == "moe_rep" and "moe/" in path_str:
        # tiny-expert MoE (granite d_ff=512): EP/TP moves more bytes than
        # the experts compute — REPLICATE expert weights, tokens stay put,
        # dispatch is chip-local (§Perf D5)
        return P()
    if strategy == "moe_rep":
        strategy = "fsdp"
    for pat, spec in _PARAM_RULES:
        if re.search(pat, path_str):
            base = _apply_strategy(tuple(spec), strategy, ndim)
            # A rule written for rank-k applies to rank-(k+s) stacked tensors.
            extra = ndim - len(base)
            if extra < 0:
                # e.g. rule P("data","model") on a 1-D bias: replicate.
                return P()
            return P(*([None] * extra + list(base)))
    return P()


def param_specs(params, mesh: Optional[Mesh] = None) -> Any:
    """PartitionSpec pytree matching ``params``. Leading scan axes detected
    by rank mismatch with the rule's spec length. With ``mesh``, specs are
    shape-fitted (divisibility fallback)."""
    def leaf_spec(path, leaf):
        """Strategy-table spec for one parameter leaf."""
        spec = spec_for_param(_path_str(path), getattr(leaf, "ndim", 0))
        return fit_spec(spec, getattr(leaf, "shape", ()), mesh)
    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def param_shardings(mesh: Mesh, params) -> Any:
    """``param_specs`` materialised as NamedShardings on ``mesh``."""
    specs = param_specs(params, mesh)
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs)


# ---------------------------------------------------------------------------
# explicit-seam parameter sharding (TrainConfig.param_sharding)
# ---------------------------------------------------------------------------
# The explicit gradient path keeps every TrainState leaf at its GLOBAL
# logical shape; only these specs change per mode, and the step's shard_map
# in_specs do the slicing. That is what makes checkpoints elastic across
# mesh shape AND TP degree: a restore never depends on how the previous run
# was sharded.

# Vocab-parallel embedding / lm_head, expert-parallel MoE and the VLM
# frontend projector have no manual compute path inside the explicit seam —
# force them replicated under the TP modes (their grads come out replicated
# across "model" for free, since every model rank traces the identical
# compute on them).
_TP_REPLICATED_OVERRIDES = (r"embed$", r"lm_head$", r"(^|/)moe/",
                            r"(^|/)projector/")

_EXPLICIT_MODES = ("replicated", "fsdp", "tp", "tp_fsdp")

# param_sharding mode -> the _apply_strategy transform that yields its base
# spec table: "fsdp" shards the last divisible dim over the whole
# ("data", "model") grid; "tp" (via the weight-stationary "serve"
# transform) keeps only the "model" entries; "tp_fsdp" uses the megatron
# table as-is — its "data" entries become FSDP gather axes on the seam, its
# "model" entries stay TP-local.
_MODE_STRATEGY = {"fsdp": "fsdp", "tp": "serve", "tp_fsdp": "megatron"}


def explicit_param_specs(params, mesh: Mesh, mode: str,
                         replicate: Tuple[str, ...] = ()) -> Any:
    """Per-leaf PartitionSpecs for the explicit seam's parameter sharding.

    Args:
      params: parameter pytree (leaves need .shape/.ndim — abstract ok).
      mesh: the step mesh (axes fitted/divisibility-checked against it).
      mode: TrainConfig.param_sharding — "replicated" | "fsdp" | "tp" |
        "tp_fsdp".
      replicate: extra regex patterns forced to P() — the step factory
        passes the model's packed-layout divisibility overrides (e.g. heads
        not divisible by the TP degree) so specs never promise a layout the
        model's manual-TP branches cannot compute.
    """
    if mode not in _EXPLICIT_MODES:
        raise ValueError(
            f"param_sharding={mode!r} not in {_EXPLICIT_MODES}")
    if mode == "replicated":
        return replicated_specs(params)
    strategy = _MODE_STRATEGY[mode]
    overrides = replicate + (
        _TP_REPLICATED_OVERRIDES if mode in ("tp", "tp_fsdp") else ())

    def leaf_spec(path, leaf):
        """Explicit-seam spec for one leaf (mode table + overrides)."""
        ps = _path_str(path)
        nd = getattr(leaf, "ndim", 0)
        shape = getattr(leaf, "shape", ())
        for pat in overrides:
            if re.search(pat, ps):
                return P()
        base = spec_for_param(ps, nd, strategy=strategy)
        return fit_spec(base, shape, mesh)
    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def spec_gather_axes(spec: P, fsdp_axes: Tuple[str, ...]):
    """(dim, axes) of the FSDP gather placement a leaf spec encodes: the
    first dimension whose entry names only axes from ``fsdp_axes``, or
    (None, ()) for leaves the seam does not gather (TP-local / replicated).
    The step gathers params over exactly these axes before the microbatch
    loop and reduce-scatters grads back over them after it."""
    for dim, entry in enumerate(tuple(spec)):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        if all(a in fsdp_axes for a in axes):
            return dim, tuple(axes)
    return None, ()


def cache_specs(cache, mesh: Optional[Mesh] = None) -> Any:
    """Decode caches: KV rings are sharded (batch over "data", SEQUENCE over
    "model"). Sequence sharding makes decode attention TP-over-context
    (scores/outputs reduce with tiny (B,H)-sized collectives), keeps every
    full-size cache under HBM (internvl decode_32k: 412 GB total -> 1.6
    GB/chip), and — critically — keeps the per-step layout FIXED so GSPMD
    never reshards the whole cache (the C-hillclimb finding: mixed layouts
    cost a full-cache fp32 all-gather per step). SSM states: channels over
    "model". Batch=1 cells fall back via fit_spec.
    """
    sizes = dict(mesh.shape) if mesh is not None else {}

    def leaf_spec(path, leaf):
        """Serve-cache spec for one leaf (slots over \"data\")."""
        ps = _path_str(path)
        nd = getattr(leaf, "ndim", 0)
        shape = getattr(leaf, "shape", ())
        if ps.endswith("pos"):
            return P()
        if re.search(r"(^|/)(k|v|ck|cv)$", ps) and nd >= 4:
            spec = [None] * (nd - 4) + ["data", "model", None, None]
            if (sizes and shape[nd - 4] % sizes.get("data", 1) != 0
                    and shape[nd - 3] % sizes.get("data", 1) == 0):
                # batch unshardable (long_500k): sequence over BOTH axes
                spec = [None] * (nd - 4) + [None, ("data", "model"),
                                            None, None]
            return fit_spec(P(*spec), shape, mesh)
        if re.search(r"ssm$", ps) and nd >= 3:
            return fit_spec(P(*([None] * (nd - 3) + ["data", "model", None])),
                            shape, mesh)
        if re.search(r"conv$", ps) and nd >= 3:
            return fit_spec(P(*([None] * (nd - 3) + ["data", None, "model"])),
                            shape, mesh)
        return P()
    return jax.tree_util.tree_map_with_path(leaf_spec, cache)


class ShardingRule:
    """Ordered (regex, PartitionSpec) table applied to a pytree by path —
    the scalax ``TreePathShardingRule`` shape. First match wins; a rule
    written for rank-k applies to rank-(k+s) stacked tensors (leading axes
    replicate); unmatched leaves replicate."""

    def __init__(self, *rules: Tuple[str, P]):
        self.rules = tuple(rules)

    def spec_for(self, path_str: str, ndim: int) -> P:
        """First matching rule's spec, left-padded with None to ``ndim``
        (stacked leading axes replicate); P() when nothing matches."""
        for pat, spec in self.rules:
            if re.search(pat, path_str):
                base = tuple(spec)
                extra = ndim - len(base)
                if extra < 0:
                    return P()
                return P(*([None] * extra + list(base)))
        return P()

    def apply(self, tree, mesh: Optional[Mesh] = None) -> Any:
        """Per-leaf specs for ``tree``, divisibility-fitted to ``mesh``."""
        def leaf(path, x):
            s = self.spec_for(_path_str(path), getattr(x, "ndim", 0))
            return fit_spec(s, getattr(x, "shape", ()), mesh)
        return jax.tree_util.tree_map_with_path(leaf, tree)


#: The repo's megatron parameter table as a ShardingRule (read-only view —
#: strategy transforms still go through ``spec_for_param``).
DEFAULT_PARAM_RULE = ShardingRule(*_PARAM_RULES)


# ---------------------------------------------------------------------------
# ShardingPolicy — the one public sharding surface
# ---------------------------------------------------------------------------

_POLICY: contextvars.ContextVar[Optional["ShardingPolicy"]] = (
    contextvars.ContextVar("repro_policy", default=None))

_CANONICAL_AXES = ("pod", "data", "model")


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """One object answering every "how is this run distributed?" question:
    mesh shape, which axis does DP / FSDP / TP / sequence parallelism,
    gradient-reduction ownership, and wire compression.

    Replaces the scattered legacy spellings — ``LrcSSMConfig.seq_axis``,
    ``SSMConfig.seq_shard``, ``TrainConfig.grad_reduce`` /
    ``grad_compression`` / ``param_sharding``, and the free-form
    ``--mesh`` / ``--strategy`` CLI strings — all of which keep working as
    deprecation aliases that construct one of these (``from_legacy``,
    ``from_train_config``).

    Consumed by ``train/step.py::make_step``, ``train/loop.py::Trainer``,
    ``serve/engine.py::ServeEngine`` and ``core/block.py`` (ambient
    ``seq_axis`` fallback via ``current_policy``).
    """
    mesh_shape: Optional[Tuple[int, ...]] = None
    mesh_axes: Optional[Tuple[str, ...]] = None
    dp_axes: Tuple[str, ...] = ("pod", "data")
    fsdp_axes: Tuple[str, ...] = ()
    tp_axis: Optional[str] = None
    seq_axis: Optional[str] = None
    strategy: str = "megatron"          # gspmd param-rule strategy
    grad_reduce: str = "gspmd"          # "gspmd" | "explicit"
    grad_compression: str = "none"      # "none" | "int8"

    # -- derived -----------------------------------------------------------
    @property
    def param_sharding(self) -> str:
        """The explicit-seam parameter mode the axis assignment encodes."""
        if self.tp_axis and self.fsdp_axes:
            return "tp_fsdp"
        if self.tp_axis:
            return "tp"
        if self.fsdp_axes:
            return "fsdp"
        return "replicated"

    def build_mesh(self) -> Optional[Mesh]:
        """Materialise the policy's mesh (None when no shape was given —
        callers fall back to the ambient mesh)."""
        if self.mesh_shape is None:
            return None
        axes = self.mesh_axes or _CANONICAL_AXES[-len(self.mesh_shape):]
        return jax.make_mesh(tuple(self.mesh_shape), tuple(axes))

    def with_mesh(self, mesh: Mesh) -> "ShardingPolicy":
        """Policy with mesh shape/axes recorded from a built Mesh."""
        return dataclasses.replace(
            self, mesh_shape=tuple(mesh.shape[a] for a in mesh.axis_names),
            mesh_axes=tuple(mesh.axis_names))

    def train_overrides(self) -> Dict[str, Any]:
        """kwargs for ``dataclasses.replace(TrainConfig, ...)`` — the
        policy fields TrainConfig mirrors."""
        return {"grad_reduce": self.grad_reduce,
                "grad_compression": self.grad_compression,
                "param_sharding": self.param_sharding}

    def apply_to(self, tcfg):
        """A TrainConfig updated to this policy's training fields."""
        return dataclasses.replace(tcfg, **self.train_overrides())

    def param_specs(self, params, mesh: Optional[Mesh] = None) -> Any:
        """Parameter specs under this policy: explicit mode uses the
        seam's per-mode table, gspmd mode the strategy rules."""
        if self.grad_reduce == "explicit":
            if mesh is None:
                mesh = self.build_mesh() or current_mesh()
            return explicit_param_specs(params, mesh, self.param_sharding)
        with use_strategy(self.strategy):
            return param_specs(params, mesh)

    # -- constructors ------------------------------------------------------
    @classmethod
    def from_train_config(cls, tcfg, mesh: Optional[Mesh] = None,
                          strategy: Optional[str] = None,
                          seq_axis: Optional[str] = None
                          ) -> "ShardingPolicy":
        """Deprecation alias: lift the legacy TrainConfig spellings
        (grad_reduce / grad_compression / param_sharding) into a policy."""
        mode = getattr(tcfg, "param_sharding", "replicated")
        tp = "model" if mode in ("tp", "tp_fsdp") else None
        fsdp = {"fsdp": ("data", "model"),
                "tp_fsdp": ("data",)}.get(mode, ())
        policy = cls(tp_axis=tp, fsdp_axes=fsdp,
                     strategy=strategy or current_strategy(),
                     seq_axis=seq_axis,
                     grad_reduce=tcfg.grad_reduce,
                     grad_compression=tcfg.grad_compression)
        return policy.with_mesh(mesh) if mesh is not None else policy

    @classmethod
    def from_legacy(cls, *, mesh_shape=None, mesh_axes=None,
                    strategy: str = "megatron",
                    grad_reduce: str = "gspmd",
                    grad_compression: str = "none",
                    param_sharding: str = "replicated",
                    seq_shard: bool = False,
                    seq_axis: Optional[str] = None) -> "ShardingPolicy":
        """Deprecation alias over ALL the old spellings in one call —
        ``SSMConfig.seq_shard`` maps to ``seq_axis="data"`` (the axis the
        sequence-sharded solver always used)."""
        if param_sharding not in _EXPLICIT_MODES:
            raise ValueError(
                f"param_sharding={param_sharding!r} not in {_EXPLICIT_MODES}")
        tp = "model" if param_sharding in ("tp", "tp_fsdp") else None
        fsdp = {"fsdp": ("data", "model"),
                "tp_fsdp": ("data",)}.get(param_sharding, ())
        return cls(mesh_shape=tuple(mesh_shape) if mesh_shape else None,
                   mesh_axes=tuple(mesh_axes) if mesh_axes else None,
                   tp_axis=tp, fsdp_axes=fsdp, strategy=strategy,
                   seq_axis=seq_axis or ("data" if seq_shard else None),
                   grad_reduce=grad_reduce,
                   grad_compression=grad_compression)

    @classmethod
    def from_string(cls, s: Optional[str]) -> "ShardingPolicy":
        """Parse the ``--policy`` CLI flag: comma-separated key=value
        pairs. Keys: ``params`` (replicated|fsdp|tp|tp_fsdp — sets the
        tp/fsdp axis assignment in one word), ``grad_reduce`` (or
        ``reduce``), ``compression``, ``strategy``, ``seq`` (axis name or
        "none"), ``tp`` / ``fsdp`` / ``dp`` (explicit axis assignment,
        "+"-joined for multi-axis). Empty/None -> default policy."""
        policy = cls()
        if not s:
            return policy
        fields: Dict[str, Any] = {}
        for pair in s.split(","):
            pair = pair.strip()
            if not pair:
                continue
            if "=" not in pair:
                raise ValueError(f"--policy entry {pair!r} is not key=value")
            key, val = (t.strip() for t in pair.split("=", 1))
            if key in ("params", "param_sharding"):
                legacy = cls.from_legacy(param_sharding=val)
                fields["tp_axis"] = legacy.tp_axis
                fields["fsdp_axes"] = legacy.fsdp_axes
            elif key in ("grad_reduce", "reduce"):
                fields["grad_reduce"] = val
            elif key in ("compression", "grad_compression"):
                fields["grad_compression"] = val
            elif key == "strategy":
                fields["strategy"] = val
            elif key in ("seq", "seq_axis"):
                fields["seq_axis"] = None if val == "none" else val
            elif key == "tp":
                fields["tp_axis"] = None if val == "none" else val
            elif key == "fsdp":
                fields["fsdp_axes"] = tuple(
                    a for a in val.split("+") if a and a != "none")
            elif key == "dp":
                fields["dp_axes"] = tuple(a for a in val.split("+") if a)
            else:
                raise ValueError(f"unknown --policy key {key!r}")
        return dataclasses.replace(policy, **fields)


@contextlib.contextmanager
def use_policy(policy: ShardingPolicy):
    """Install ``policy`` as the ambient sharding policy (and its mesh,
    when it carries one) for code in this context."""
    token = _POLICY.set(policy)
    try:
        mesh = policy.build_mesh()
        if mesh is not None:
            with use_mesh(mesh), use_strategy(policy.strategy):
                yield policy
        else:
            with use_strategy(policy.strategy):
                yield policy
    finally:
        _POLICY.reset(token)


def current_policy() -> Optional[ShardingPolicy]:
    """The ambient ShardingPolicy installed by ``use_policy`` (None
    outside one)."""
    return _POLICY.get()


def batch_specs(batch, mesh: Mesh, seq_sharded: bool = False) -> Any:
    """Input batch: leading batch dim over DP axes (strategy-aware: fsdp
    spreads over the full chip grid; ring also shards the time dim over
    "model"), with divisibility fallback."""
    ba = batch_axes(mesh)
    strategy = current_strategy()

    def leaf_spec(path, leaf):
        """Global-batch spec for one leaf (batch dim over DP axes)."""
        nd = getattr(leaf, "ndim", 0)
        shape = getattr(leaf, "shape", ())
        if nd == 0:
            return P()
        if seq_sharded and nd >= 2:
            return fit_spec(P(None, "data"), shape, mesh)
        spec = _act_spec(mesh, strategy, shape)
        # tokens are (B, T); act spec may carry a time entry — keep at most
        # the first two entries, pad with None
        entries = list(tuple(spec))[:nd] + [None] * max(0, nd - len(tuple(spec)))
        return fit_spec(P(*entries), shape, mesh)
    return jax.tree_util.tree_map_with_path(leaf_spec, batch)

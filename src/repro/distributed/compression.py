"""Gradient compression for cross-pod (DCN) all-reduce.

At 2+ pods the "pod" axis rides the data-center network (~25 GB/s per host
vs ~50 GB/s/link ICI intra-pod), so the cross-pod gradient all-reduce is the
straggler term in the collective roofline. We compress it: per-tensor-block
int8 quantisation with stochastic-free symmetric scaling and ERROR FEEDBACK
(the quantisation residual is added back into the next step's gradient), the
standard trick that keeps SGD/Adam convergence unaffected.

The residual is a FIRST-CLASS pytree: ``compressed_psum`` takes the incoming
residual (one leaf per gradient leaf) and returns the updated one; the train
engine threads it through ``train/state.TrainState`` so quantisation error
is accumulated-and-corrected across steps (and checkpointed/restored like
optimizer moments). Pass ``error_feedback=False`` to zero it every step —
the round-to-nearest ablation the convergence tests contrast against.

Usage inside the shard_map'd explicit train step (train/step.py when
cfg.grad_reduce == "explicit" and cfg.grad_compression == "int8"):

    g_pod    = grads pmean'd over ("data",)          # intra-pod, fp32 ICI
    g_global, new_residual = compressed_psum(g_pod, "pod", residual)

Exactness note: compression is OPT-IN and OFF for the paper-faithful
baseline; the bytes-on-wire accounting below (``reduction_wire_bytes``) is
what benchmarks/grad_compression.py reports.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import compat

BLOCK = 256

# wire-format overhead: one fp32 scale per BLOCK int8 payload bytes
_SCALE_OVERHEAD = 4.0 / BLOCK


def rtn_quantize_blocks(blocks: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """The shared RTN core: symmetric round-to-nearest int8 over the LAST
    axis, one fp32 scale per block row. ``blocks`` (..., bs) float ->
    (q int8 same shape, scale (..., 1) f32). The amax element of every
    block lands exactly on ±127, which makes the grid idempotent:
    re-encoding a dequantised block reproduces the payload bit-for-bit —
    the property ``distributed/precision.py`` leans on for stable
    quantize-on-scatter / dequantize-on-gather cache round trips."""
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12))
    return jnp.clip(q, -127.0, 127.0).astype(jnp.int8), scale


def rtn_dequantize_blocks(q: jax.Array, scale: jax.Array) -> jax.Array:
    """Invert ``rtn_quantize_blocks``: fp32 ``q * scale``."""
    return q.astype(jnp.float32) * scale


def _quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-block int8. x: any shape -> (q int8, scales f32)."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    return rtn_quantize_blocks(flat.reshape(-1, BLOCK))


def _dequantize_int8(q: jax.Array, scale: jax.Array, shape, size
                     ) -> jax.Array:
    flat = rtn_dequantize_blocks(q, scale).reshape(-1)[:size]
    return flat.reshape(shape)


def quantize_roundtrip(x: jax.Array) -> jax.Array:
    q, s = _quantize_int8(x)
    return _dequantize_int8(q, s, x.shape, x.size)


def zeros_residual(tree, dtype=jnp.float32):
    """Fresh (all-zero) error-feedback residual matching ``tree``."""
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, dtype), tree)


def compressed_psum(tree, axis_name: str, error_state=None,
                    error_feedback: bool = True,
                    axis_size: int = 0):
    """int8-compressed all-reduce(mean) over ``axis_name`` with error
    feedback. Returns (reduced tree, new error_state).

    Wire format: quantised REDUCE-SCATTER + ALL-GATHER (two int8 stages).
    Each rank splits its gradient into P chunks, quantises them, and
    exchanges chunk j with rank j over one ``all_to_all`` (the
    reduce-scatter stage, (P-1)/P of the payload on the wire); every rank
    dequantises and sums the P copies of its own chunk, REQUANTISES the
    sum, and an int8 all-gather of the summed chunks (another (P-1)/P)
    reconstructs the total. Per-device wire is therefore
    ~2·(P-1)/P·(1+4/BLOCK) bytes per element — a ~3.9x saving over the
    fp32 ring all-reduce at ANY pod count, where the previous
    full-payload all-gather format degraded past P ≈ 8 (see
    ``reduction_wire_bytes``).

    Error feedback is EXACT for the two-stage format: each rank keeps its
    own stage-1 quantisation error on all P chunks, plus the stage-2
    requantisation error on the one chunk it owns — summed over ranks,
    the residuals account for every bit the wire dropped.

    ``error_state`` leaves may be any float dtype (fp32 default, bf16 to
    halve residual HBM); accumulation happens in fp32 and the new residual
    is cast back to the incoming dtype. With ``error_feedback=False`` the
    incoming residual is ignored and the returned one is all zeros —
    per-step round-to-nearest, the ablation baseline.

    ``axis_size`` is the static size of ``axis_name`` (the chunk split
    needs it at trace time); pass it when known (train/step.py does),
    otherwise it is read from the ambient shard_map axis env.

    Leaves smaller than ``P * BLOCK`` use a shrunk block size
    ``ceil(n/P)`` so every chunk carries real payload with its own scale
    (per-element scale overhead is higher there, but only for leaves
    whose wire cost is negligible anyway — ``reduction_wire_bytes``
    keeps the 4/BLOCK figure).
    """
    if error_state is None:
        error_state = zeros_residual(tree)
    P = int(axis_size) or compat.axis_env_size(axis_name)

    def one(g, err):
        g32 = g.astype(jnp.float32)
        if error_feedback:
            g32 = g32 + err.astype(jnp.float32)
        n = g32.size
        # block size shrinks for leaves smaller than P*BLOCK so every
        # chunk holds real payload with its own scale — otherwise a tiny
        # leaf lands entirely in chunk 0 as ONE block and a single
        # outlier coordinate sets the scale for the whole leaf
        bs = min(BLOCK, max(1, -(-n // P)))
        flat = jnp.pad(g32.reshape(-1), (0, (-n) % (P * bs)))
        blocks = flat.reshape(P, -1, bs)             # (P, nb, bs)
        q1, s1 = rtn_quantize_blocks(blocks)
        # stage 1 (reduce-scatter): chunk j of every rank -> rank j
        q1_x = compat.all_to_all(q1, axis_name, split_axis=0, concat_axis=0)
        s1_x = compat.all_to_all(s1, axis_name, split_axis=0, concat_axis=0)
        chunk_sum = jnp.sum(rtn_dequantize_blocks(q1_x, s1_x), axis=0)
        # stage 2 (all-gather): requantise the summed chunk, share it
        q2, s2 = rtn_quantize_blocks(chunk_sum)
        q2_all = compat.all_gather(q2, axis_name)    # (P, nb, BLOCK) int8
        s2_all = compat.all_gather(s2, axis_name)    # (P, nb, 1) f32
        total = rtn_dequantize_blocks(q2_all, s2_all).reshape(-1)[:n]
        out = (total / P).reshape(g32.shape).astype(g.dtype)
        if not error_feedback:
            return out, jnp.zeros(g32.shape, err.dtype)
        # exact residual: own stage-1 error on all chunks + stage-2 error
        # on the chunk this rank owns
        err1 = blocks - rtn_dequantize_blocks(q1, s1)
        err2 = chunk_sum - rtn_dequantize_blocks(q2, s2)
        owner = (jnp.arange(P) == compat.axis_index(axis_name))
        r_blocks = err1 + owner.astype(jnp.float32)[:, None, None] * err2
        new_err = r_blocks.reshape(-1)[:n].reshape(g32.shape)
        return out, new_err.astype(err.dtype)

    flat, treedef = jax.tree_util.tree_flatten(tree)
    flat_err = treedef.flatten_up_to(error_state)
    out = [one(g, e) for g, e in zip(flat, flat_err)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


# ---------------------------------------------------------------------------
# bytes-on-wire accounting
# ---------------------------------------------------------------------------

def tree_elems(tree) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))


def reduction_wire_bytes(tree, axis_size: int, mode: str) -> int:
    """Per-device bytes RECEIVED over the reduced axis for ONE gradient
    reduction of ``tree`` across ``axis_size`` participants.

    Modes:
      * ``"fp32_allreduce"``  — GSPMD's ring all-reduce: each device
        receives 2·(P-1)/P · 4 bytes per element (reduce-scatter +
        all-gather halves).
      * ``"int8_rsag"``       — what ``compressed_psum`` lowers to:
        quantised reduce-scatter (all_to_all, (P-1)/P of the int8 payload
        + fp32 per-block scales) + int8 all-gather of the requantised
        chunk sums (another (P-1)/P), i.e. 2·(P-1)/P · (1 + 4/BLOCK)
        bytes per element — the ~3.9x saving over fp32 holds at ANY P.
      * ``"int8_allgather"``  — the RETIRED full-payload format, kept for
        the accounting comparison: (P-1) · (1 + 4/BLOCK) bytes per
        element, which loses to fp32 beyond P ≈ 8 (the bug the rsag
        format fixes).
    """
    n = tree_elems(tree)
    P = int(axis_size)
    if P <= 1:
        return 0
    if mode == "fp32_allreduce":
        return int(round(2 * (P - 1) / P * 4 * n))
    if mode == "int8_rsag":
        return int(round(2 * (P - 1) / P * (1.0 + _SCALE_OVERHEAD) * n))
    if mode == "int8_allgather":
        return int(round((P - 1) * (1.0 + _SCALE_OVERHEAD) * n))
    raise ValueError(f"unknown wire mode: {mode!r}")


def compression_error(x: jax.Array) -> jax.Array:
    """Relative L2 quantisation error (diagnostics / tests)."""
    rt = quantize_roundtrip(x)
    return jnp.linalg.norm(x - rt) / jnp.maximum(jnp.linalg.norm(x), 1e-12)

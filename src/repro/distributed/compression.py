"""Gradient compression for cross-pod (DCN) all-reduce.

At 2+ pods the "pod" axis rides the data-center network (~25 GB/s per host
vs ~50 GB/s/link ICI intra-pod), so the cross-pod gradient all-reduce is the
straggler term in the collective roofline. We compress it: per-tensor-block
int8 quantisation with stochastic-free symmetric scaling and ERROR FEEDBACK
(the quantisation residual is added back into the next step's gradient), the
standard trick that keeps SGD/Adam convergence unaffected.

Usage inside a shard_map'd train step (distributed/train_step when
multi_pod and cfg.grad_compression == "int8"):

    g_local  = grads averaged over ("data",) via psum
    g_global = compressed_psum(g_local, "pod", error_state)

Exactness note: compression is OPT-IN and OFF for the paper-faithful
baseline; EXPERIMENTS.md §Perf records the collective-bytes delta (4x on
the pod axis) and the quantisation error statistics.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import compat

BLOCK = 256


def _quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-block int8. x: any shape -> (q int8, scales f32)."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return q, scale


def _dequantize_int8(q: jax.Array, scale: jax.Array, shape, size
                     ) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)[:size]
    return flat.reshape(shape)


def quantize_roundtrip(x: jax.Array) -> jax.Array:
    q, s = _quantize_int8(x)
    return _dequantize_int8(q, s, x.shape, x.size)


def compressed_psum(tree, axis_name: str, error_state=None):
    """int8-compressed all-reduce(mean) over ``axis_name`` with error
    feedback. Returns (reduced tree, new error_state)."""
    if error_state is None:
        error_state = jax.tree_util.tree_map(
            lambda g: jnp.zeros(g.shape, jnp.float32), tree)

    def one(g, err):
        g32 = g.astype(jnp.float32) + err
        q, s = _quantize_int8(g32)
        deq = _dequantize_int8(q, s, g32.shape, g32.size)
        new_err = g32 - deq                      # error feedback residual
        # WIRE FORMAT: int8 payload + per-block fp32 scales (1/256 overhead).
        # all_gather keeps the transferred bytes at 1/4 of an fp32 psum;
        # each pod dequantises and reduces locally.
        q_all = compat.all_gather(q, axis_name)           # (P, blocks, BLOCK) int8
        s_all = compat.all_gather(s, axis_name)           # (P, blocks, 1) f32
        P = q_all.shape[0]
        deq_sum = jnp.sum(q_all.astype(jnp.float32) * s_all, axis=0)
        flat = deq_sum.reshape(-1)[:g32.size].reshape(g32.shape)
        return (flat / P).astype(g.dtype), new_err

    flat, treedef = jax.tree_util.tree_flatten(tree)
    flat_err = treedef.flatten_up_to(error_state)
    out = [one(g, e) for g, e in zip(flat, flat_err)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def compression_error(x: jax.Array) -> jax.Array:
    """Relative L2 quantisation error (diagnostics / tests)."""
    rt = quantize_roundtrip(x)
    return jnp.linalg.norm(x - rt) / jnp.maximum(jnp.linalg.norm(x), 1e-12)

"""Gradient compression for cross-pod (DCN) all-reduce.

At 2+ pods the "pod" axis rides the data-center network (~25 GB/s per host
vs ~50 GB/s/link ICI intra-pod), so the cross-pod gradient all-reduce is the
straggler term in the collective roofline. We compress it: per-tensor-block
int8 quantisation with stochastic-free symmetric scaling and ERROR FEEDBACK
(the quantisation residual is added back into the next step's gradient), the
standard trick that keeps SGD/Adam convergence unaffected.

The residual is a FIRST-CLASS pytree: ``compressed_psum`` takes the incoming
residual (one leaf per gradient leaf) and returns the updated one; the train
engine threads it through ``train/state.TrainState`` so quantisation error
is accumulated-and-corrected across steps (and checkpointed/restored like
optimizer moments). Pass ``error_feedback=False`` to zero it every step —
the round-to-nearest ablation the convergence tests contrast against.

Usage inside the shard_map'd explicit train step (train/step.py when
cfg.grad_reduce == "explicit" and cfg.grad_compression == "int8"):

    g_pod    = grads pmean'd over ("data",)          # intra-pod, fp32 ICI
    g_global, new_residual = compressed_psum(g_pod, "pod", residual)

Exactness note: compression is OPT-IN and OFF for the paper-faithful
baseline; the bytes-on-wire accounting below (``reduction_wire_bytes``) is
what benchmarks/grad_compression.py reports.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import compat

BLOCK = 256

# wire-format overhead: one fp32 scale per BLOCK int8 payload bytes
_SCALE_OVERHEAD = 4.0 / BLOCK


def _quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-block int8. x: any shape -> (q int8, scales f32)."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return q, scale


def _dequantize_int8(q: jax.Array, scale: jax.Array, shape, size
                     ) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)[:size]
    return flat.reshape(shape)


def quantize_roundtrip(x: jax.Array) -> jax.Array:
    q, s = _quantize_int8(x)
    return _dequantize_int8(q, s, x.shape, x.size)


def zeros_residual(tree, dtype=jnp.float32):
    """Fresh (all-zero) error-feedback residual matching ``tree``."""
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, dtype), tree)


def compressed_psum(tree, axis_name: str, error_state=None,
                    error_feedback: bool = True):
    """int8-compressed all-reduce(mean) over ``axis_name`` with error
    feedback. Returns (reduced tree, new error_state).

    ``error_state`` leaves may be any float dtype (fp32 default, bf16 to
    halve residual HBM); accumulation happens in fp32 and the new residual
    is cast back to the incoming dtype. With ``error_feedback=False`` the
    incoming residual is ignored and the returned one is all zeros —
    per-step round-to-nearest, the ablation baseline.
    """
    if error_state is None:
        error_state = zeros_residual(tree)

    def one(g, err):
        g32 = g.astype(jnp.float32)
        if error_feedback:
            g32 = g32 + err.astype(jnp.float32)
        q, s = _quantize_int8(g32)
        deq = _dequantize_int8(q, s, g32.shape, g32.size)
        # error feedback residual (zeroed in the round-to-nearest ablation)
        new_err = (g32 - deq if error_feedback
                   else jnp.zeros_like(g32)).astype(err.dtype)
        # WIRE FORMAT: int8 payload + per-block fp32 scales (1/256 overhead).
        # all_gather keeps the transferred bytes at ~1/4 of an fp32 psum at
        # the production pod count (see reduction_wire_bytes); each pod
        # dequantises and reduces locally.
        q_all = compat.all_gather(q, axis_name)           # (P, blocks, BLOCK) int8
        s_all = compat.all_gather(s, axis_name)           # (P, blocks, 1) f32
        P = q_all.shape[0]
        deq_sum = jnp.sum(q_all.astype(jnp.float32) * s_all, axis=0)
        flat = deq_sum.reshape(-1)[:g32.size].reshape(g32.shape)
        return (flat / P).astype(g.dtype), new_err

    flat, treedef = jax.tree_util.tree_flatten(tree)
    flat_err = treedef.flatten_up_to(error_state)
    out = [one(g, e) for g, e in zip(flat, flat_err)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


# ---------------------------------------------------------------------------
# bytes-on-wire accounting
# ---------------------------------------------------------------------------

def tree_elems(tree) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))


def reduction_wire_bytes(tree, axis_size: int, mode: str) -> int:
    """Per-device bytes RECEIVED over the reduced axis for ONE gradient
    reduction of ``tree`` across ``axis_size`` participants.

    Modes (matching what the two train-step paths actually lower to):
      * ``"fp32_allreduce"``  — GSPMD's ring all-reduce: each device
        receives 2·(P-1)/P · 4 bytes per element (reduce-scatter +
        all-gather halves).
      * ``"int8_allgather"``  — the compressed path: each device receives
        the (P-1) other pods' full int8 payload + fp32 per-block scales,
        i.e. (P-1) · (1 + 4/BLOCK) bytes per element.

    The all-gather format wins below P ≈ 8 (at the production pod count
    P=2 it is ~3.9x fewer bytes); beyond that a quantised
    reduce-scatter+all-gather is needed — ROADMAP item.
    """
    n = tree_elems(tree)
    P = int(axis_size)
    if P <= 1:
        return 0
    if mode == "fp32_allreduce":
        return int(round(2 * (P - 1) / P * 4 * n))
    if mode == "int8_allgather":
        return int(round((P - 1) * (1.0 + _SCALE_OVERHEAD) * n))
    raise ValueError(f"unknown wire mode: {mode!r}")


def compression_error(x: jax.Array) -> jax.Array:
    """Relative L2 quantisation error (diagnostics / tests)."""
    rt = quantize_roundtrip(x)
    return jnp.linalg.norm(x - rt) / jnp.maximum(jnp.linalg.norm(x), 1e-12)

"""AdamW + schedules + clipping, pure JAX (no optax).

Mixed-precision discipline: compute/grads arrive in the compute dtype
(bf16 at scale); the optimizer keeps fp32 master params and fp32 (m, v)
moments. ZeRO-1/3 sharding of this state comes from the parameter sharding
rules (distributed/sharding.py) — (m, v, master) inherit each parameter's
PartitionSpec, which already spreads them over both mesh axes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.config import TrainConfig


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any
    master: Any        # fp32 master copy (authoritative)


def adamw_init(params) -> AdamWState:
    # copy=True: master must never alias params (both are donated by the
    # train step; aliased buffers trip "donate the same buffer twice")
    f32 = lambda x: jnp.array(x, dtype=jnp.float32, copy=True)
    zeros = lambda x: jnp.zeros(x.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree_util.tree_map(zeros, params),
        v=jax.tree_util.tree_map(zeros, params),
        master=jax.tree_util.tree_map(f32, params),
    )


def cosine_schedule(cfg: TrainConfig):
    def lr_fn(step):
        step = step.astype(jnp.float32)
        warm = cfg.learning_rate * step / max(cfg.warmup_steps, 1)
        prog = jnp.clip((step - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        cos = 0.5 * cfg.learning_rate * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < cfg.warmup_steps, warm, cos)
    return lr_fn


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def adamw_apply(cfg: TrainConfig, grads, step, m, v, master, params,
                grad_norm=None) -> Tuple[Any, Any, Any, Any, dict]:
    """Core AdamW on PRE-REDUCED gradients.

    ``grads`` must already be the global (cross-replica) mean — this
    function never inserts a collective, so it composes with both gradient
    reduction modes (GSPMD-implicit and the explicit shard_map'd pod
    reduction in train/step.py). ``step`` is the POST-increment step count
    (TrainState owns the counter). When the caller holds gradient SHARDS
    (explicit-seam FSDP/TP), the local ``global_norm`` would be wrong — it
    precomputes the true norm (with its own collective, outside this
    function) and passes it as ``grad_norm``; clipping then uses that value
    verbatim. Returns ``(new_params, new_m, new_v, new_master, metrics)``.
    """
    if grad_norm is None:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = grad_norm
        scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree_util.tree_map(
            lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads)
    lr = cosine_schedule(cfg)(step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, master):
        g = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        mh = m_new / bc1
        vh = v_new / bc2
        delta = mh / (jnp.sqrt(vh) + 1e-8) + cfg.weight_decay * master
        master_new = master - lr * delta
        return m_new, v_new, master_new

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(m)
    flat_v = treedef.flatten_up_to(v)
    flat_ma = treedef.flatten_up_to(master)
    out = [upd(g, m_, v_, ma) for g, m_, v_, ma in
           zip(flat_g, flat_m, flat_v, flat_ma)]
    new_m = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    new_master = treedef.unflatten([o[2] for o in out])

    flat_p = treedef.flatten_up_to(params)
    new_params = treedef.unflatten(
        [ma.astype(p.dtype) for ma, p in
         zip([o[2] for o in out], flat_p)])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_m, new_v, new_master, metrics


def adamw_update(cfg: TrainConfig, grads, state: AdamWState, params
                 ) -> Tuple[Any, AdamWState, dict]:
    """Standalone-AdamWState convenience wrapper over ``adamw_apply`` (the
    simple single-device trainers: examples, classifier benchmarks). The
    production train step absorbs this state into train/state.TrainState
    and calls ``adamw_apply`` directly."""
    step = state.step + 1
    new_params, new_m, new_v, new_master, metrics = adamw_apply(
        cfg, grads, step, state.m, state.v, state.master, params)
    return new_params, AdamWState(step, new_m, new_v, new_master), metrics

from repro.optim.adamw import (AdamWState, adamw_apply, adamw_init,
                               adamw_update, cosine_schedule, global_norm,
                               clip_by_global_norm)

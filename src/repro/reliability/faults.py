"""Deterministic fault injection: a seeded, declarative schedule of
faults driven through the stack's EXISTING seams.

Injection points (none of them inside a jitted hot path — a fault-plan
run compiles byte-identically to a clean run):

  * input batches   — :class:`FaultySource` wraps any ``batch_at``-style
    source (``data/pipeline.py``) and plants NaN/inf into the scheduled
    steps' batches. Because the wrapper is itself a pure function of
    (seed, step), the determinism contract survives: a resumed job
    replays the SAME faults.
  * preemption      — the trainer polls ``plan.fires("preempt", step)``
    and routes through its existing ``Trainer.preempt`` SIGTERM seam.
  * checkpoints     — :func:`corrupt_checkpoint` truncates or bit-flips a
    published step's array payload on disk (what a torn write or bad DMA
    leaves behind).
  * serve slots     — :func:`corrupt_slot` overwrites one slot's resident
    state rows with NaN (or scrambles its ``pos``) between engine ticks,
    host-driven device ops outside jit.
  * admission       — the serve engine polls ``fires("serve_stall", tick)``
    and skips admission for the scheduled ticks (a wedged upstream queue).

``tools/chaos_suite.py`` composes these into named end-to-end scenarios.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Dict, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault.

    ``kind`` is the taxonomy key ("nan_batch" | "inf_batch" | "preempt" |
    "serve_stall" | ...); ``step`` the step/tick it fires at; ``until``
    (inclusive) extends it over a range — a stall is naturally a window,
    a preemption a point. ``frac`` scales how much of the target the
    fault touches (fraction of batch entries NaN'd)."""
    kind: str
    step: int
    until: Optional[int] = None
    frac: float = 0.05

    def covers(self, step: int) -> bool:
        """Whether this spec is live at ``step``."""
        hi = self.until if self.until is not None else self.step
        return self.step <= step <= hi


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded, declarative fault schedule.

    The plan is pure data: WHERE faults land is the spec list, WHAT random
    choices a fault makes (which batch entries to NaN, which byte to
    flip) derive from ``rng(kind, step)`` — a fresh generator keyed on
    (seed, kind, step), so two runs of the same plan inject identically
    and a resumed run replays the tail of the schedule exactly."""
    seed: int = 0
    faults: Tuple[FaultSpec, ...] = ()

    def fires(self, kind: str, step: int) -> bool:
        """Whether any fault of ``kind`` is live at ``step``."""
        return any(f.kind == kind and f.covers(step) for f in self.faults)

    def spec(self, kind: str, step: int) -> Optional[FaultSpec]:
        """The first live spec of ``kind`` at ``step`` (None = clean)."""
        for f in self.faults:
            if f.kind == kind and f.covers(step):
                return f
        return None

    def rng(self, kind: str, step: int) -> np.random.Generator:
        """Deterministic per-(kind, step) generator for fault internals.
        Keyed on a stable (process-independent) digest of ``kind``."""
        import zlib
        return np.random.default_rng(
            (self.seed, zlib.crc32(kind.encode()), step))


class FaultySource:
    """Wrap a ``batch_at(step)`` data source, planting non-finite values
    into the steps a :class:`FaultPlan` schedules ("nan_batch" /
    "inf_batch" kinds).

    Only float leaves are touched (token/label integer tensors pass
    through — a NaN there is unrepresentable); ``frac`` of each float
    leaf's entries are overwritten at plan-seeded positions. Supports the
    same iterator protocol as the wrapped source, so it drops into
    ``Trainer.fit`` either way."""

    def __init__(self, source, plan: FaultPlan):
        self.source = source
        self.plan = plan
        self.injected_steps = []     # host-side audit log

    def batch_at(self, step: int):
        """The wrapped source's batch, with scheduled faults applied."""
        batch = self.source.batch_at(step)
        spec = self.plan.spec("nan_batch", step) \
            or self.plan.spec("inf_batch", step)
        if spec is None:
            return batch
        import jax
        import jax.numpy as jnp
        rng = self.plan.rng(spec.kind, step)
        bad = jnp.nan if spec.kind == "nan_batch" else jnp.inf

        def poison(x):
            if not hasattr(x, "dtype") or x.dtype.kind != "f":
                return x
            flat = np.asarray(x).reshape(-1).copy()
            n = max(1, int(spec.frac * flat.size))
            idx = rng.choice(flat.size, size=n, replace=False)
            flat[idx] = bad
            return jnp.asarray(flat.reshape(x.shape), x.dtype)

        out = jax.tree_util.tree_map(poison, batch)
        self.injected_steps.append(step)
        return out

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def corrupt_checkpoint(directory: str, step: int, mode: str = "truncate",
                       seed: int = 0) -> str:
    """Damage a PUBLISHED checkpoint's array payload on disk.

    ``mode="truncate"`` cuts ``arrays.npz`` to half its length (a torn
    write that beat the atomic-rename protocol — e.g. the filesystem
    itself lost tail pages); ``mode="bitflip"`` flips one seeded bit in
    the payload (corruption the npz container may still happily parse —
    exactly what the manifest checksums exist to catch). Returns the
    damaged file's path."""
    path = os.path.join(directory, f"step_{step}", "arrays.npz")
    with open(path, "rb") as f:
        blob = bytearray(f.read())
    if mode == "truncate":
        blob = blob[:max(1, len(blob) // 2)]
    elif mode == "bitflip":
        rng = np.random.default_rng((seed, step))
        # flip inside the payload body, past the zip local-file headers:
        # a header flip would just make np.load raise (the easy case)
        pos = int(rng.integers(len(blob) // 4, len(blob) // 2))
        blob[pos] ^= 1 << int(rng.integers(8))
    else:
        raise ValueError(f"unknown corruption mode: {mode!r}")
    with open(path, "wb") as f:
        f.write(bytes(blob))
    return path


def corrupt_slot(engine, slot: int, mode: str = "nan") -> None:
    """Corrupt one serve slot's device-resident state between ticks.

    ``mode="nan"`` overwrites the slot's row in every float cache leaf
    with NaN (bad DMA / bit-rot in HBM); ``mode="pos"`` scrambles the
    slot's sequence position (bookkeeping corruption — the state is
    finite but WRONG). Host-driven functional updates outside any jit;
    the engine's watchdog is expected to detect and quarantine."""
    import jax
    import jax.numpy as jnp

    from repro.distributed.precision import is_quantized
    from repro.distributed.sharding import _path_str
    from repro.serve.cache import batch_axis_for

    cache = engine.cache.cache
    if mode == "pos":
        pos = cache["pos"]
        cache = dict(cache)
        cache["pos"] = pos.at[slot].add(jnp.asarray(7, pos.dtype))
        engine.cache.cache = cache
        return
    if mode != "nan":
        raise ValueError(f"unknown slot corruption mode: {mode!r}")

    def poison(path, leaf):
        ps = _path_str(path)
        if ps.rsplit("/", 1)[-1] == "pos":
            return leaf
        if is_quantized(leaf):
            # poison the scales (float side of the QTensor); int payloads
            # cannot hold NaN
            if leaf.scale is None:
                return leaf
            ax = batch_axis_for(ps)
            idx = (slice(None),) * ax + (slot,)
            return type(leaf)(leaf.q, leaf.scale.at[idx].set(jnp.nan),
                              leaf.mode, leaf.odtype, leaf.lead, leaf.block)
        if not hasattr(leaf, "dtype") or leaf.dtype.kind != "f":
            return leaf
        ax = batch_axis_for(ps)
        idx = (slice(None),) * ax + (slot,)
        return leaf.at[idx].set(jnp.nan)

    engine.cache.cache = jax.tree_util.tree_map_with_path(
        poison, cache, is_leaf=is_quantized)

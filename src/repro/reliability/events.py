"""Degradation events: the structured record of "something failed and the
system declared it" — the alternative to silent max-iteration output,
swallowed exceptions, or a wedged queue.

Guardrails (serve watchdog, spec auto-disable, solver divergence
detection, trainer rollback) append :class:`DegradationEvent` rows to an
:class:`EventLog`; the chaos suite's acceptance criterion is that every
injected fault ends either fully recovered or with a matching event in
the log — never neither.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional


@dataclasses.dataclass(frozen=True)
class DegradationEvent:
    """One declared degradation: ``kind`` is the taxonomy key
    (docs/reliability.md), ``where`` the subsystem coordinate (slot id,
    train step, block index...), ``detail`` free-form context. ``t`` is
    the host wall-clock stamp."""
    kind: str
    where: Any = None
    detail: Dict[str, Any] = dataclasses.field(default_factory=dict)
    t: float = dataclasses.field(default_factory=time.time)

    def to_json(self) -> Dict[str, Any]:
        """JSON-serialisable row for chaos/bench reports."""
        return {"kind": self.kind, "where": self.where,
                "detail": {k: v for k, v in self.detail.items()},
                "t": self.t}


class EventLog:
    """Append-only event record with per-kind counters.

    Host-side bookkeeping only — emitting an event never touches device
    state, so guardrails can log from anywhere outside jit."""

    def __init__(self, log_fn=None):
        self.events: List[DegradationEvent] = []
        self.counts: Dict[str, int] = {}
        self._log_fn = log_fn

    def emit(self, kind: str, where: Any = None,
             **detail: Any) -> DegradationEvent:
        """Record one event; returns it (callers may enrich/raise)."""
        ev = DegradationEvent(kind=kind, where=where, detail=detail)
        self.events.append(ev)
        self.counts[kind] = self.counts.get(kind, 0) + 1
        if self._log_fn is not None:
            self._log_fn(f"[degraded] {kind} @ {where}: {detail}")
        return ev

    def count(self, kind: str) -> int:
        """Number of events of ``kind`` emitted so far."""
        return self.counts.get(kind, 0)

    def of_kind(self, kind: str) -> List[DegradationEvent]:
        """All events of ``kind``, in emission order."""
        return [e for e in self.events if e.kind == kind]

    def to_json(self) -> List[Dict[str, Any]]:
        """The whole log as JSON rows (chaos-suite report format)."""
        return [e.to_json() for e in self.events]

"""Failure-domain machinery: deterministic fault injection, degradation
events, and the helpers the guardrails in train/, serve/ and checkpoint/
hang off.

Three layers (docs/reliability.md is the narrative):

  * ``faults``  — :class:`FaultPlan`, a seeded declarative schedule of
    faults injected through the EXISTING seams (the data pipeline's
    ``batch_at`` purity, the trainer's ``preempt`` hook, checkpoint files
    on disk, serve slot state between ticks) — never inside jitted hot
    paths, so a plan-carrying run compiles byte-identically to a clean
    one.
  * ``events``  — :class:`DegradationEvent` / :class:`EventLog`, the
    structured "declared degraded state" record every guardrail emits
    instead of failing silently.
  * the guardrails themselves live with their subsystems
    (``train/guard.py``, ``checkpoint/manager.py`` checksums,
    ``serve/engine.py`` watchdog/deadlines/backpressure) — this package
    only injects and records.

``tools/chaos_suite.py`` drives named end-to-end scenarios over all of it.
"""
from repro.reliability.events import DegradationEvent, EventLog
from repro.reliability.faults import (FaultPlan, FaultSpec, FaultySource,
                                      corrupt_checkpoint, corrupt_slot)

__all__ = [
    "DegradationEvent", "EventLog", "FaultPlan", "FaultSpec",
    "FaultySource", "corrupt_checkpoint", "corrupt_slot",
]

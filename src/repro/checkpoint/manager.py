"""Checkpointing: sharded save/restore, async writer, elastic resharding.

Fault-tolerance contract for the 1000+-node deployment:
  * SAVE: every process writes only its addressable shards
    (``fully_replicated_host_local`` is never assumed); one .npz per leaf
    chunk + a msgpack manifest with the tree structure, PartitionSpecs,
    step, and mesh shape. Writes go to a temp dir + atomic rename, so a
    preemption mid-save never corrupts the latest-good checkpoint.
  * RESTORE: the manifest's specs are re-resolved against the CURRENT mesh,
    so a job restarted on a different topology (elastic scaling: fewer/more
    pods, reshaped mesh) reshards transparently — arrays are loaded as host
    buffers and re-placed with jax.device_put under the new NamedSharding.
  * ASYNC: save() snapshots to host RAM (device_get) synchronously — the
    step loop is blocked only for the copy — and a daemon thread does the
    serialisation/IO. ``wait()`` drains pending writes (called before exit
    and before any restore).

  * VERIFY: the manifest carries a crc32 per array chunk; ``verify_step``
    re-reads a published step and checks payload integrity, and
    ``restore(step=None)`` walks steps newest -> oldest to the first
    VERIFIED one — a corrupt/truncated latest checkpoint (torn write
    below the rename, bit-rot) degrades to the previous good step instead
    of raising. An EXPLICIT ``restore(step=N)`` still raises on
    corruption (the caller asked for that step, silently substituting
    another would be worse). ``_gc`` also sweeps orphaned ``.tmp_step_*``
    dirs left by a kill mid-save.

On this CPU container the same code runs with a 1-device mesh; the
multi-device path is exercised by tests/test_distributed.py in a
subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}

    def rec(prefix, node):
        if isinstance(node, dict):
            for k, v in node.items():
                rec(f"{prefix}/{k}" if prefix else str(k), v)
            if not node:
                # leafless containers must still round-trip: TrainState
                # carries residual={} when error feedback is disabled, and
                # the tuple rebuild on restore indexes EVERY field.
                flat.setdefault("__lists__", {})[prefix] = ("dict", 0)
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                rec(f"{prefix}/{i}", v)
            flat.setdefault("__lists__", {})[prefix] = (
                "tuple" if isinstance(node, tuple) else "list", len(node))
        else:
            flat[prefix] = node
    rec("", tree)
    return flat


def _unflatten(flat: Dict[str, Any]):
    lists = flat.pop("__lists__", {})
    root: Dict[str, Any] = {}
    for path, val in flat.items():
        parts = path.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    # seed empty containers (e.g. tail: []) that carry no leaves
    for prefix in lists:
        parts = prefix.split("/") if prefix else []
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        if parts:
            node.setdefault(parts[-1], {})

    def fix(node, prefix=""):
        if isinstance(node, dict):
            out = {k: fix(v, f"{prefix}/{k}" if prefix else k)
                   for k, v in node.items()}
            if prefix in lists:
                kind, n = lists[prefix]
                if kind == "dict":          # leafless container marker
                    return out
                seq = [out[str(i)] for i in range(n)]
                return tuple(seq) if kind == "tuple" else seq
            return out
        return node
    return fix(root)


class CheckpointManager:
    def __init__(self, directory: str, max_to_keep: int = 3,
                 async_save: bool = True):
        self.dir = directory
        self.max_to_keep = max_to_keep
        self.async_save = async_save
        self._pending: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree, extra: Optional[Dict] = None):
        self.wait()
        flat = _flatten(tree)
        lists = flat.pop("__lists__", {})
        # synchronous device->host snapshot (cheap relative to serialisation)
        host = {}
        meta = {"step": int(step), "lists": {k: list(v) for k, v in lists.items()},
                "extra": extra or {}, "time": time.time(),
                "n_devices": jax.device_count()}
        meta["dtypes"] = {}
        for k, v in flat.items():
            if isinstance(v, jax.Array) or isinstance(v, np.ndarray):
                arr = np.asarray(jax.device_get(v))
                if arr.dtype.kind not in "fiub" or arr.dtype.itemsize == 0:
                    # non-native dtypes (bfloat16 via ml_dtypes): store as
                    # fp32 payload + original dtype name in the manifest
                    meta["dtypes"][k] = str(arr.dtype)
                    arr = arr.astype(np.float32)
                host[k] = arr
            else:
                meta.setdefault("scalars", {})[k] = v

        def write():
            tmp = os.path.join(self.dir, f".tmp_step_{step}")
            final = os.path.join(self.dir, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            # per-array integrity checksums, computed in the writer thread
            # (off the step loop) over the exact bytes being serialised —
            # verify_step/restore(None) check them on the way back in
            meta["checksums"] = {k: zlib.crc32(np.ascontiguousarray(v)
                                               .tobytes())
                                 for k, v in host.items()}
            np.savez(os.path.join(tmp, "arrays.npz"),
                     **{k.replace("/", "|"): v for k, v in host.items()})
            with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
                f.write(msgpack.packb(meta, use_bin_type=True))
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)            # atomic publish
            self._gc()

        if self.async_save:
            self._pending = threading.Thread(target=write, daemon=True)
            self._pending.start()
        else:
            write()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.max_to_keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)
        # orphaned temp dirs from a kill between makedirs and the atomic
        # rename: invisible to all_steps/restore (the "." prefix), but
        # they'd accumulate forever. Safe to sweep here — saves are
        # serialised (save() joins the previous writer first), so the only
        # live tmp dir belongs to THIS write, which renamed before _gc ran.
        for name in os.listdir(self.dir):
            if name.startswith(".tmp_step_"):
                shutil.rmtree(os.path.join(self.dir, name),
                              ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def verify_step(self, step: int) -> bool:
        """Integrity check of a PUBLISHED step: manifest parses, the array
        payload loads, every manifest key is present, and (when the
        manifest carries checksums — every checkpoint written since the
        reliability PR does) each array's crc32 matches. Checkpoints from
        older manifests verify on loadability alone."""
        path = os.path.join(self.dir, f"step_{step}")
        try:
            with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
                meta = msgpack.unpackb(f.read(), raw=False)
            data = np.load(os.path.join(path, "arrays.npz"))
            sums = meta.get("checksums", {})
            files = {k.replace("|", "/"): k for k in data.files}
            for key, want in sums.items():
                if key not in files:
                    return False
                arr = data[files[key]]
                if zlib.crc32(np.ascontiguousarray(arr).tobytes()) != want:
                    return False
            if not sums:
                for k in data.files:
                    data[k]                   # force-decompress each chunk
            return True
        except Exception:
            # any parse/IO failure IS the verdict here — this is the one
            # sanctioned broad handler on the restore path
            # repro-lint: disable=bare-except
            return False

    def latest_verified_step(self) -> Optional[int]:
        """Newest step that passes ``verify_step`` (None when none do)."""
        for s in reversed(self.all_steps()):
            if self.verify_step(s):
                return s
        return None

    def restore(self, step: Optional[int] = None, mesh=None, specs=None,
                target=None) -> Tuple[int, Any, Dict]:
        """Load a checkpoint; optionally re-place against ``mesh``/``specs``
        (elastic reshard). ``target`` provides dtypes to cast to.

        ``step=None`` restores the newest VERIFIED step (checksum check —
        a corrupt latest checkpoint falls back to the previous good one);
        an explicit ``step`` is loaded as-asked and raises on damage."""
        self.wait()
        if step is None:
            step = self.latest_verified_step()
        if step is None:
            raise FileNotFoundError(
                f"no restorable (verified) checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
            meta = msgpack.unpackb(f.read(), raw=False)
        data = np.load(os.path.join(path, "arrays.npz"))
        dtypes = meta.get("dtypes", {})
        flat = {}
        for k in data.files:
            key = k.replace("|", "/")
            arr = data[k]
            if key in dtypes:
                arr = jnp.asarray(arr).astype(jnp.dtype(dtypes[key]))
            flat[key] = arr
        flat.update(meta.get("scalars", {}))
        flat["__lists__"] = {k: tuple(v) for k, v in meta["lists"].items()}
        tree = _unflatten(flat)
        if target is not None:
            # conform container types (NamedTuples round-trip as tuples):
            # leaf ORDER is structure-stable, so rebuild on target's treedef
            leaves = jax.tree_util.tree_leaves(tree)
            tree = jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(target), leaves)
            tree = jax.tree_util.tree_map(
                lambda ref, x: jnp.asarray(x).astype(ref.dtype)
                if hasattr(ref, "dtype") else x, target, tree)
        if mesh is not None and specs is not None:
            from jax.sharding import NamedSharding
            tree = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(
                    jnp.asarray(x), NamedSharding(mesh, s)), tree, specs)
        return meta["step"], tree, meta.get("extra", {})

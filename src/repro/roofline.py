"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), all in seconds-per-step on TPU v5e:

    compute    = HLO_FLOPs            / (chips * 197e12  bf16 FLOP/s)
    memory     = HLO_bytes_accessed   / (chips * 819e9   B/s HBM)
    collective = collective_bytes     / (chips * n_links * 50e9 B/s link)

HLO_FLOPs / bytes come from compiled.cost_analysis(). collective_bytes is
NOT in cost_analysis: the optimized-HLO collective inventory
(``repro.contracts.collective_bytes_from_hlo`` — the introspection
primitives live in the declarative contracts module and are re-exported
here) sums the operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute op, attributing each
op's bytes to the devices in its replica groups. MODEL_FLOPS = 6*N*D
(dense) or 6*N_active*D (MoE) gives the useful-compute ratio that exposes
remat/dispatch waste.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import numpy as np

from repro.config import HW, ArchConfig, ShapeConfig
# Back-compat re-exports: these moved to repro.contracts (the declarative
# lowering-contract API built on top of them); existing imports from
# repro.roofline keep working.
from repro.contracts import (collective_bytes_from_hlo,   # noqa: F401
                             collective_ops_from_hlo,
                             sequential_loop_lengths)
from repro.distributed import compat


def model_flops(arch: ArchConfig, shape: ShapeConfig,
                n_params: Optional[int] = None) -> float:
    """6*N*D (training) or 2*N*D (inference fwd) with N = active params."""
    N = n_params if n_params is not None else active_param_count(arch)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * N * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * N * tokens        # train_step lowered (fwd+bwd)
    tokens = shape.global_batch * 1    # decode: one token per sequence
    return 2.0 * N * tokens


def param_count(arch: ArchConfig) -> int:
    """Analytic total parameter count (no allocation)."""
    d, L, V = arch.d_model, arch.n_layers, arch.vocab
    H, K, hd = arch.n_heads, arch.n_kv_heads, arch.resolved_head_dim
    total = V * d                        # embed
    if not arch.tie_embeddings:
        total += d * V
    if arch.frontend_dim:
        total += arch.frontend_dim * 2 * d + 2 * d * d  # projector mlp
    per_attn = d * (H + 2 * K) * hd + H * hd * d
    if arch.moe is not None:
        per_ffn = d * arch.moe.n_experts + 3 * arch.moe.n_experts * d * arch.d_ff
    elif arch.act in ("silu", "gelu_tanh"):
        per_ffn = 3 * d * arch.d_ff
    else:
        per_ffn = 2 * d * arch.d_ff
    if arch.family == "audio":
        enc = arch.enc_layers * (2 * per_attn / 2 + 2 * d * arch.d_ff)
        dec = L * (2 * (d * H * hd + 2 * d * H * hd // 1) + 2 * d * arch.d_ff)
        return int(total + enc + dec)
    if arch.ssm is not None and arch.family in ("ssm", "hybrid"):
        di = arch.ssm.expand * d
        if arch.ssm.kind == "mamba1":
            dt_rank = max(1, -(-d // 16))
            per_ssm = (d * 2 * di + 4 * di + di * (dt_rank + 2 * arch.ssm.d_state)
                       + dt_rank * di + 2 * di + di * d)
        else:
            Hh = arch.ssm.n_heads or di // arch.ssm.head_dim
            per_ssm = (d * (2 * di + 2 * arch.ssm.d_state + Hh)
                       + 4 * (di + 2 * arch.ssm.d_state) + 3 * Hh + di
                       + di * d)
        if arch.hybrid_period:
            n_sh = 1                      # one SHARED attn block
            return int(total + L * per_ssm + n_sh * (per_attn + per_ffn))
        return int(total + L * per_ssm)
    return int(total + L * (per_attn + per_ffn))


def active_param_count(arch: ArchConfig) -> int:
    """Active (per-token) params: MoE counts top_k of n_experts."""
    total = param_count(arch)
    if arch.moe is not None:
        expert_p = arch.n_layers * 3 * arch.moe.n_experts * arch.d_model * arch.d_ff
        active_e = expert_p * arch.moe.top_k / arch.moe.n_experts
        return int(total - expert_p + active_e)
    return total


# ---------------------------------------------------------------------------
# analytic HBM-traffic model (XLA:CPU "bytes accessed" is fusion-naive and
# overcounts by ~50x; this napkin model is the roofline memory term)
# ---------------------------------------------------------------------------

def analytic_hbm_bytes_per_chip(arch: ArchConfig, shape: ShapeConfig,
                                chips: int) -> Dict[str, float]:
    """Per-chip HBM bytes for one step, assuming TPU-typical fusion:
    every major tensor is read/written once per producing/consuming fusion.

    train: params 3 reads (fwd, bwd, opt) + 1 write (bf16) and fp32
           opt-state m/v/master read+write (24 B/param);
           activations: one (B,T,D) residual stream saved per layer
           (remat "nothing_saveable": boundaries only) — written fwd,
           read bwd, plus ~2x recompute internal streaming;
           attention KV streaming: k,v re-read once per q-block sweep;
           logits/lm_head activations at the loss.
    decode: params read once + full KV/state cache read + small writes.
    """
    P = float(param_count(arch))
    d, L, V = arch.d_model, arch.n_layers, arch.vocab
    B, T = shape.global_batch, shape.seq_len
    act_b = 2.0  # bf16

    if shape.kind in ("train", "prefill"):
        param_traffic = P * (2 + 2 + 2) + P * 4 * 6   # bf16 fwd/bwd/write + f32 opt rw
        tokens = float(B) * T
        # residual-stream checkpoints + internal recompute streams (~4 passes)
        act_traffic = L * tokens * d * act_b * 4.0
        if arch.ssm is not None and arch.family in ("ssm", "hybrid"):
            di = arch.ssm.expand * d
            # mixer streams: in_proj outs, conv, scan lam/beta/state chunks
            act_traffic += L * tokens * di * act_b * 6.0
        kv_heads = max(arch.n_kv_heads, 0)
        hd = arch.resolved_head_dim
        n_attn = (L if arch.ssm is None else
                  (L // arch.hybrid_period if arch.hybrid_period else 0))
        if n_attn and kv_heads:
            kv_chunk = 1024.0
            sweeps = max(T / kv_chunk / 2.0, 1.0)   # causal ~half
            att_traffic = n_attn * tokens * kv_heads * hd * act_b * 2 * sweeps
        else:
            att_traffic = 0.0
        loss_traffic = tokens * V * act_b           # logits write (chunked read~write)
        total = param_traffic + act_traffic + att_traffic + loss_traffic
        return {"total": total / chips,
                "params": param_traffic / chips,
                "activations": act_traffic / chips,
                "attention_kv": att_traffic / chips,
                "loss": loss_traffic / chips}

    # decode: one token
    param_traffic = P * 2.0
    kv_heads = max(arch.n_kv_heads, 0)
    hd = arch.resolved_head_dim
    cache_traffic = 0.0
    if arch.family == "audio":
        n_full, n_local, window = L, 0, 0
    elif arch.window_pattern is not None:
        per = arch.window_pattern[1]
        n_full = L // (per + 1)
        n_local = L - n_full
        window = arch.window_pattern[0]
    elif arch.ssm is not None:
        n_full = (L // arch.hybrid_period) if arch.hybrid_period else 0
        n_local, window = 0, 0
        di = arch.ssm.expand * d
        N = arch.ssm.d_state
        cache_traffic += L * float(B) * di * N * 4 * 2   # ssm state rw f32
    else:
        n_full, n_local, window = L, 0, 0
    if kv_heads:
        cache_traffic += n_full * float(B) * T * kv_heads * hd * act_b * 2
        if n_local:
            cache_traffic += n_local * float(B) * min(T, window) * kv_heads * hd * act_b * 2
    logits_traffic = float(B) * V * act_b
    total = param_traffic + cache_traffic + logits_traffic
    return {"total": total / chips, "params": param_traffic / chips,
            "kv_cache": cache_traffic / chips, "logits": logits_traffic / chips}


def analyze_compiled(arch: ArchConfig, shape: ShapeConfig, mesh,
                     lowered, compiled) -> Dict[str, Any]:
    chips = 1
    for v in mesh.shape.values():
        chips *= v

    cost = compat.cost_analysis(compiled)   # dict on EVERY supported jax
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))

    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes",
                                  getattr(mem, "temp_size_in_bytes", 0)),
        }
    except Exception:
        mem_info = {}

    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo)     # already wire-factored
    coll_wire = sum(coll.values())

    # NOTE on per-chip semantics: cost_analysis flops on an SPMD module are
    # per-program (per-device) in XLA:CPU. The assignment's collective term
    # is collective_bytes / (chips * link_bw); with per-chip bytes the chip
    # factor cancels — we use the conservative single-link 50 GB/s figure
    # (v5e has 4 ICI links; best case divides this by 4).
    compute_s = flops / HW.peak_flops_bf16
    memory_s = bytes_accessed / HW.hbm_bw
    collective_s = coll_wire / HW.ici_bw

    mf = model_flops(arch, shape)
    useful_ratio = mf / max(flops * chips, 1.0)

    amem = analytic_hbm_bytes_per_chip(arch, shape, chips)
    memory_s_analytic = amem["total"] / HW.hbm_bw

    dom = max((("compute", compute_s), ("memory", memory_s_analytic),
               ("collective", collective_s)), key=lambda kv: kv[1])

    return {
        "arch": arch.name, "shape": shape.name,
        "mesh": dict(mesh.shape),
        "hlo_flops_per_chip": flops,
        "hlo_bytes_per_chip": bytes_accessed,
        "analytic_hbm_bytes_per_chip": amem["total"],
        "analytic_hbm_breakdown": amem,
        "collective_bytes_per_chip": coll_wire,
        "collective_breakdown": coll,
        "compute_s": compute_s,
        "memory_s_xla": memory_s,
        "memory_s": memory_s_analytic,
        "collective_s": collective_s,
        "dominant": dom[0],
        "model_flops": mf,
        "useful_flops_ratio": useful_ratio,
        "roofline_bound_s": max(compute_s, memory_s_analytic, collective_s),
        **mem_info,
    }

"""Minimal functional NN primitives shared framework-wide.

No flax/haiku dependency: parameters are plain nested dicts of jax.Arrays,
initialisers are explicit, and apply functions are pure. This keeps every
layer trivially compatible with pjit/shard_map sharding rules (dict path ->
PartitionSpec matching in distributed/sharding.py).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# initialisers
# ---------------------------------------------------------------------------

def lecun_normal(key, shape, dtype=jnp.float32, fan_in: Optional[int] = None):
    fan = fan_in if fan_in is not None else shape[0]
    return (jax.random.normal(key, shape) * (1.0 / max(fan, 1)) ** 0.5).astype(dtype)


def zeros(shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype)


def ones(shape, dtype=jnp.float32):
    return jnp.ones(shape, dtype)


# ---------------------------------------------------------------------------
# dense / norm / mlp
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32,
               bias: bool = True) -> Params:
    p = {"w": lecun_normal(key, (d_in, d_out), dtype)}
    if bias:
        p["b"] = zeros((d_out,), dtype)
    return p


def dense(p: Params, x: jax.Array) -> jax.Array:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def layernorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": ones((d,), dtype), "bias": zeros((d,), dtype)}


def layernorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def rmsnorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": ones((d,), dtype)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    # compute the reduction in fp32 for bf16 activations (numerics at scale)
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def mlp_init(key, d_in: int, d_hidden: int, d_out: int,
             dtype=jnp.float32) -> Params:
    k1, k2 = jax.random.split(key)
    return {"fc1": dense_init(k1, d_in, d_hidden, dtype),
            "fc2": dense_init(k2, d_hidden, d_out, dtype)}


def mlp(p: Params, x: jax.Array, act=jax.nn.gelu) -> jax.Array:
    return dense(p["fc2"], act(dense(p["fc1"], x)))


def squared_relu(x: jax.Array) -> jax.Array:
    """Nemotron-4's activation: relu(x)^2."""
    r = jax.nn.relu(x)
    return r * r


ACTIVATIONS = {
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "relu": jax.nn.relu,
    "squared_relu": squared_relu,
    "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True),
}


def cast_tree(tree, dtype):
    """Cast floating-point leaves to the compute dtype (mixed-precision
    entry point: master params stay fp32 in the optimizer)."""
    def cast(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree_util.tree_map(cast, tree)


def count_params(tree) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(tree)
               if hasattr(x, "size"))

"""Distributed train/serve steps: the functions the dry-run lowers and the
trainer executes.

``make_train_step``  — value_and_grad -> (clip, AdamW) with:
    * microbatched gradient accumulation (lax.scan over microbatches) so
      global_batch=256 never has to fit at once;
    * bf16 compute, fp32 master/moments (optim/adamw.py);
    * optional int8-compressed cross-pod gradient all-reduce
      (distributed/compression.py) under shard_map on the "pod" axis
      (wire-format/numerics harness for now — see _compressed_pod_allreduce
      for the honest scope);
    * donate_argnums on (params, opt_state) — buffers update in place.

``make_serve_step``  — one-token decode against sharded caches.

Sharding: in_shardings/out_shardings come from distributed/sharding.py rules;
the "pod" axis is pure DP (GSPMD inserts the cross-pod grad all-reduce
automatically in the uncompressed path).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ArchConfig, TrainConfig
from repro.distributed import compat
from repro.distributed import sharding as shd
from repro.models import Model
from repro.optim.adamw import AdamWState, adamw_init, adamw_update


def _compressed_pod_allreduce(grads, mesh: Mesh):
    """Explicit int8-compressed gradient mean over the cross-pod DP axis
    (distributed/compression.py wire format under a version-portable
    shard_map). Opt-in via ``TrainConfig.grad_compression``.

    SCOPE (honest): at this call site the gradients are ALREADY globally
    reduced by GSPMD (value_and_grad over the pod-sharded batch), so this
    pass exercises the compressed wire format and its numerics — the
    round-trip quantisation the real link would apply — WITHOUT yet
    removing GSPMD's own fp32 pod all-reduce. Making the compression
    actually replace that collective requires computing grads pod-locally
    (shard_map the grad computation over "pod", psum over "data" only) —
    tracked as a ROADMAP open item. The error-feedback residual returned
    by compressed_psum is likewise dropped here (threading it through the
    optimizer state is part of the same open item), so quantisation error
    is per-step round-to-nearest, not accumulated-and-corrected.
    """
    from repro.distributed.compression import compressed_psum
    pspecs = shd.param_specs(grads, mesh)

    def local(g):
        red, _ = compressed_psum(g, "pod")
        return red

    return compat.shard_map(local, mesh=mesh, in_specs=(pspecs,),
                            out_specs=pspecs, check_vma=False)(grads)


def make_train_step(model: Model, tcfg: TrainConfig
                    ) -> Callable[[Any, AdamWState, Dict], Tuple]:
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    Pure function of its inputs — jit/pjit at the call site with shardings.
    """

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def compute_grads(params, batch):
        if tcfg.microbatch and tcfg.microbatch < batch["tokens"].shape[0]:
            B = batch["tokens"].shape[0]
            n_micro = B // tcfg.microbatch
            mb = jax.tree_util.tree_map(
                lambda x: x.reshape((n_micro, tcfg.microbatch) + x.shape[1:]),
                batch)

            def micro(acc, b):
                l, g = jax.value_and_grad(loss_fn)(params, b)
                acc_l, acc_g = acc
                return (acc_l + l,
                        jax.tree_util.tree_map(jnp.add, acc_g, g)), None

            zero_g = jax.tree_util.tree_map(
                lambda x: jnp.zeros(x.shape, jnp.float32), params)
            (tot_l, tot_g), _ = jax.lax.scan(
                micro, (jnp.float32(0), zero_g), mb)
            inv = 1.0 / n_micro
            return tot_l * inv, jax.tree_util.tree_map(
                lambda g: g * inv, tot_g)
        return jax.value_and_grad(loss_fn)(params, batch)

    def train_step(params, opt_state: AdamWState, batch):
        loss, grads = compute_grads(params, batch)
        if tcfg.grad_compression == "int8":
            mesh = shd.current_mesh()
            if mesh is not None and "pod" in mesh.axis_names:
                grads = _compressed_pod_allreduce(grads, mesh)
        if tcfg.shard_grads:
            mesh = shd.current_mesh()
            if mesh is not None:
                pspecs = shd.param_specs(params, mesh)
                grads = jax.tree_util.tree_map(
                    lambda g, s: jax.lax.with_sharding_constraint(
                        g, NamedSharding(mesh, s)), grads, pspecs)
        new_params, new_opt, metrics = adamw_update(
            tcfg, grads, opt_state, params)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step


def make_eval_step(model: Model):
    def eval_step(params, batch):
        return model.loss(params, batch)
    return eval_step


def make_serve_step(model: Model):
    def serve_step(params, tokens, cache):
        logits, new_cache = model.decode_step(params, tokens, cache)
        next_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return next_tok, logits, new_cache
    return serve_step


# ---------------------------------------------------------------------------
# jit wiring with explicit shardings (used by trainer and dryrun)
# ---------------------------------------------------------------------------

def jit_train_step(model: Model, tcfg: TrainConfig, mesh: Mesh, params,
                   batch_like, donate: bool = True):
    step = make_train_step(model, tcfg)
    pspecs = shd.param_specs(params, mesh)
    pshard = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), pspecs)
    opt_shard = AdamWState(NamedSharding(mesh, P()), pshard, pshard, pshard)
    bshard = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        shd.batch_specs(batch_like, mesh))
    metric_shard = NamedSharding(mesh, P())
    return jax.jit(
        step,
        in_shardings=(pshard, opt_shard, bshard),
        out_shardings=(pshard, opt_shard,
                       {"loss": metric_shard, "grad_norm": metric_shard,
                        "lr": metric_shard}),
        donate_argnums=(0, 1) if donate else (),
    )


def jit_serve_step(model: Model, mesh: Mesh, params, cache_like,
                   batch_size: int = 0):
    step = make_serve_step(model)
    pspecs = shd.param_specs(params, mesh)
    pshard = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), pspecs)
    cshard = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), shd.cache_specs(cache_like, mesh))
    bshape = (batch_size or 1, 1)
    tok_shard = NamedSharding(mesh, shd.fit_spec(
        P(shd.batch_axes(mesh)), bshape, mesh))
    logit_shard = NamedSharding(mesh, shd.fit_spec(
        P(shd.batch_axes(mesh), None, "model"), bshape + (0,), mesh))
    return jax.jit(
        step,
        in_shardings=(pshard, tok_shard, cshard),
        out_shardings=(tok_shard, logit_shard, cshard),
        donate_argnums=(2,),
    )

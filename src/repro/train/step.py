"""The training engine's step factory: ONE place where train/eval/serve
steps are built, jit-wired, and sharded.

``make_step(model, mode, tcfg, mesh)`` returns the pure step function:

    train : (TrainState, batch) -> (TrainState, metrics)
    eval  : (params, batch)     -> loss
    serve : (params, tokens, cache) -> (next_tok, logits, cache)

``jit_step`` adds the jit wiring (in/out shardings, donation) for the same
three modes — sharding rules for the whole engine live in this module and
nowhere else (``train_state_specs`` below). The legacy entry points
(``make_train_step`` / ``make_eval_step`` / ``make_serve_step`` /
``jit_train_step`` / ``jit_serve_step``) are thin aliases over the factory.

Gradient-reduction modes (TrainConfig.grad_reduce):

  * ``"gspmd"``  — value_and_grad over the globally sharded batch; XLA owns
    the DP all-reduce (fp32, over ("pod", "data")). With
    ``grad_compression="int8"`` the compressed wire format is exercised on
    top of already-reduced gradients (numerics harness; the fp32 pod
    all-reduce still happens), with the error-feedback residual threaded
    through TrainState.
  * ``"explicit"`` — the POD-LOCAL path: the whole grad+update runs inside
    one shard_map over the mesh. Gradients are computed per-device,
    pmean'd over "data" only (intra-pod ICI), then ONE explicit cross-pod
    reduction: fp32 pmean, or ``compressed_psum`` (int8 payload + fp32
    per-block scales on the wire) with the per-pod error-feedback residual
    carried in TrainState. GSPMD's implicit fp32 pod all-reduce does not
    exist in the lowered HLO — asserted by compiled-text inspection in
    tests/test_train_engine.py. Contract: pure-DP parameters (replicated);
    composing explicit reduction with TP/FSDP via partially-manual
    shard_map is a ROADMAP item.

Microbatch gradient accumulation (lax.scan over microbatches) applies in
both modes; a batch that does not divide evenly is a hard factory/trace
time ``ValueError`` — never a silent truncation.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import TrainConfig
from repro.distributed import compat
from repro.distributed import sharding as shd
from repro.models import Model
from repro.optim.adamw import adamw_apply
from repro.train.state import TrainState, train_state_init  # re-export


# ---------------------------------------------------------------------------
# gradient computation (shared by both reduction modes)
# ---------------------------------------------------------------------------

def _check_microbatch(B: int, tcfg: TrainConfig, where: str = "batch"):
    """Silent-truncation guard: ``B // microbatch`` used to drop the
    remainder on non-divisible batches."""
    if tcfg.microbatch and tcfg.microbatch < B and B % tcfg.microbatch != 0:
        raise ValueError(
            f"microbatch={tcfg.microbatch} does not divide the {where} size "
            f"{B}: gradient accumulation would silently drop the last "
            f"{B % tcfg.microbatch} examples. Pick a divisor of {B} (or 0 "
            f"to disable accumulation).")


def _compute_grads(model: Model, tcfg: TrainConfig, params, batch):
    """value_and_grad of the model loss, with lax.scan gradient
    accumulation over microbatches when ``tcfg.microbatch`` divides the
    (per-device) batch — shared by both reduction modes."""
    def loss_fn(p, b):
        return model.loss(p, b)

    B = batch["tokens"].shape[0]
    if tcfg.microbatch and tcfg.microbatch < B:
        _check_microbatch(B, tcfg)
        n_micro = B // tcfg.microbatch
        mb = jax.tree_util.tree_map(
            lambda x: x.reshape((n_micro, tcfg.microbatch) + x.shape[1:]),
            batch)

        def micro(acc, b):
            l, g = jax.value_and_grad(loss_fn)(params, b)
            acc_l, acc_g = acc
            return (acc_l + l,
                    jax.tree_util.tree_map(jnp.add, acc_g, g)), None

        zero_g = jax.tree_util.tree_map(
            lambda x: jnp.zeros(x.shape, jnp.float32), params)
        (tot_l, tot_g), _ = jax.lax.scan(
            micro, (jnp.float32(0), zero_g), mb)
        inv = 1.0 / n_micro
        return tot_l * inv, jax.tree_util.tree_map(
            lambda g: g * inv, tot_g)
    return jax.value_and_grad(loss_fn)(params, batch)


# ---------------------------------------------------------------------------
# cross-pod reduction helpers
# ---------------------------------------------------------------------------

def _squeeze_pod(residual):
    """(1, *shape) local residual slice -> (*shape) (and back, below)."""
    return jax.tree_util.tree_map(lambda r: r[0], residual)


def _unsqueeze_pod(residual):
    """Inverse of ``_squeeze_pod``: restore the leading pod dim."""
    return jax.tree_util.tree_map(lambda r: r[None], residual)


def _compressed_pod_allreduce(grads, residual, mesh: Mesh,
                              tcfg: TrainConfig):
    """GSPMD-path int8 compressed mean over "pod" (wire-format harness on
    already-reduced gradients — the honest scope note lives in the module
    docstring; the real byte saving is the explicit path). The residual IS
    threaded (first-class pytree in/out), so even this path is
    accumulate-and-correct rather than round-to-nearest."""
    from repro.distributed.compression import compressed_psum
    pspecs = shd.param_specs(grads, mesh)
    rspecs = shd.residual_specs(residual, mesh, param_specs=pspecs)

    def local(g, r):
        red, new_r = compressed_psum(
            g, "pod", _squeeze_pod(r), error_feedback=tcfg.error_feedback)
        return red, _unsqueeze_pod(new_r)

    return compat.shard_map(local, mesh=mesh, in_specs=(pspecs, rspecs),
                            out_specs=(pspecs, rspecs),
                            check_vma=False)(grads, residual)


# ---------------------------------------------------------------------------
# the factory
# ---------------------------------------------------------------------------

def make_step(model: Model, mode: str, tcfg: Optional[TrainConfig] = None,
              mesh: Optional[Mesh] = None) -> Callable:
    """Build the pure step function for ``mode`` in
    ``("train", "eval", "serve")``. ``tcfg`` is required for train;
    ``mesh`` is required for the explicit-reduction train path (the
    shard_map is constructed at factory time)."""
    if mode == "eval":
        def eval_step(params, batch):
            return model.loss(params, batch)
        return eval_step

    if mode == "serve":
        def serve_step(params, tokens, cache):
            logits, new_cache = model.decode_step(params, tokens, cache)
            next_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            return next_tok, logits, new_cache
        return serve_step

    if mode != "train":
        raise ValueError(f"unknown step mode: {mode!r}")
    assert tcfg is not None, "train mode requires a TrainConfig"
    if tcfg.grad_reduce == "explicit":
        if mesh is None:
            mesh = shd.current_mesh()
        if mesh is None:
            raise ValueError("grad_reduce='explicit' requires a mesh at "
                             "factory time (the shard_map is built here)")
        return _make_explicit_train_step(model, tcfg, mesh)
    if tcfg.grad_reduce != "gspmd":
        raise ValueError(f"unknown grad_reduce mode: {tcfg.grad_reduce!r}")
    return _make_gspmd_train_step(model, tcfg, mesh)


def _make_gspmd_train_step(model: Model, tcfg: TrainConfig,
                           mesh: Optional[Mesh]):
    """The GSPMD-owned reduction path: XLA inserts the DP all-reduce;
    optional int8 wire-format harness over the pod axis."""
    def train_step(state: TrainState, batch):
        loss, grads = _compute_grads(model, tcfg, state.params, batch)
        new_residual = state.residual
        m = mesh if mesh is not None else shd.current_mesh()
        if tcfg.grad_compression == "int8" and m is not None \
                and "pod" in m.axis_names \
                and jax.tree_util.tree_leaves(state.residual):
            grads, new_residual = _compressed_pod_allreduce(
                grads, state.residual, m, tcfg)
        if tcfg.shard_grads and m is not None:
            pspecs = shd.param_specs(state.params, m)
            grads = jax.tree_util.tree_map(
                lambda g, s: jax.lax.with_sharding_constraint(
                    g, NamedSharding(m, s)), grads, pspecs)
        step = state.step + 1
        new_params, new_m, new_v, new_master, metrics = adamw_apply(
            tcfg, grads, step, state.m, state.v, state.master, state.params)
        metrics["loss"] = loss
        return TrainState(step, new_params, new_m, new_v, new_master,
                          new_residual), metrics
    return train_step


def _make_explicit_train_step(model: Model, tcfg: TrainConfig, mesh: Mesh):
    """Pod-local gradient engine: the WHOLE step under one shard_map.

    Per-device body: local grads -> pmean over "data" (intra-pod) -> ONE
    cross-pod reduction (fp32 pmean or int8 compressed_psum with
    error-feedback residual) -> replicated AdamW update. Any "model" axis
    in the mesh carries redundant replicas (pure-DP contract)."""
    from repro.distributed.compression import compressed_psum
    has_pod = "pod" in mesh.axis_names
    has_data = "data" in mesh.axis_names
    ba = shd.batch_axes(mesh)
    int8 = tcfg.grad_compression == "int8" and has_pod

    def body(state: TrainState, batch):
        # every mesh axis is manual here: GSPMD activation constraints in
        # the model are meaningless and must not be staged
        with shd.manual_body():
            loss, grads = _compute_grads(model, tcfg, state.params, batch)
        if has_data:
            loss = compat.pmean(loss, "data")
            grads = compat.pmean(grads, "data")
        new_residual = state.residual
        if has_pod:
            loss = compat.pmean(loss, "pod")
            if int8:
                if not jax.tree_util.tree_leaves(state.residual):
                    raise ValueError(
                        "grad_compression='int8' with grad_reduce="
                        "'explicit' needs the error-feedback residual in "
                        "TrainState — build it with train_state_init("
                        "params, tcfg, mesh) so the mesh's pod axis is "
                        "known at init time")
                grads, new_res = compressed_psum(
                    grads, "pod", _squeeze_pod(state.residual),
                    error_feedback=tcfg.error_feedback)
                new_residual = _unsqueeze_pod(new_res)
            else:
                grads = compat.pmean(grads, "pod")
        step = state.step + 1
        new_params, new_m, new_v, new_master, metrics = adamw_apply(
            tcfg, grads, step, state.m, state.v, state.master, state.params)
        metrics["loss"] = loss
        return TrainState(step, new_params, new_m, new_v, new_master,
                          new_residual), metrics

    # prefix specs: replicated state except the pod-sharded residual;
    # batch over the DP axes on the leading dim; replicated metrics.
    state_specs = TrainState(step=P(), params=P(), m=P(), v=P(),
                             master=P(), residual=P("pod"))
    batch_spec = P(ba) if ba else P()
    return compat.shard_map(
        body, mesh=mesh,
        in_specs=(state_specs, batch_spec),
        out_specs=(state_specs, P()),
        check_vma=False)


# ---------------------------------------------------------------------------
# sharding rules + jit wiring — the ONE place they live
# ---------------------------------------------------------------------------

def train_state_specs(state_like: TrainState, mesh: Mesh,
                      tcfg: TrainConfig) -> TrainState:
    """PartitionSpec pytree for a TrainState under ``tcfg.grad_reduce``.

    gspmd    : params/moments/master inherit the parameter sharding rules
               (ZeRO comes free), residual = P("pod", *param_spec).
    explicit : pure DP — everything replicated except the residual's
               leading pod dim (the shard_map body owns the collectives).
    """
    if tcfg.grad_reduce == "explicit":
        rep = shd.replicated_specs(state_like.params)
        return TrainState(
            step=P(), params=rep, m=rep, v=rep, master=rep,
            residual=shd.residual_specs(state_like.residual, mesh))
    pspecs = shd.param_specs(state_like.params, mesh)
    if jax.tree_util.tree_leaves(state_like.residual):
        rspecs = shd.residual_specs(state_like.residual, mesh,
                                    param_specs=pspecs)
    else:
        rspecs = state_like.residual      # {} — no residual state
    return TrainState(step=P(), params=pspecs, m=pspecs, v=pspecs,
                      master=pspecs, residual=rspecs)


def jit_step(model: Model, mode: str, mesh: Mesh, *,
             tcfg: Optional[TrainConfig] = None,
             state_like: Optional[TrainState] = None,
             batch_like=None, cache_like=None, params_like=None,
             batch_size: int = 0, donate: bool = True):
    """jit wiring with explicit shardings for all three step modes."""
    ns = lambda tree: jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree)

    if mode == "train":
        assert tcfg is not None and state_like is not None \
            and batch_like is not None
        # factory-time microbatch guard (satellite: no silent truncation)
        B = batch_like["tokens"].shape[0]
        if tcfg.grad_reduce == "explicit":
            ba = shd.batch_axes(mesh) or ()
            n_dp = 1
            for a in ba:
                n_dp *= mesh.shape[a]
            _check_microbatch(B // max(n_dp, 1), tcfg, where="per-device batch")
            bspecs = shd.pod_local_batch_specs(batch_like, mesh)
        else:
            _check_microbatch(B, tcfg)
            bspecs = shd.batch_specs(batch_like, mesh)
        step = make_step(model, "train", tcfg, mesh)
        sspecs = train_state_specs(state_like, mesh, tcfg)
        mshard = NamedSharding(mesh, P())
        return jax.jit(
            step,
            in_shardings=(ns(sspecs), ns(bspecs)),
            out_shardings=(ns(sspecs),
                           {"loss": mshard, "grad_norm": mshard,
                            "lr": mshard}),
            donate_argnums=(0,) if donate else (),
        )

    if mode == "eval":
        assert batch_like is not None and params_like is not None
        step = make_step(model, "eval")
        pshard = ns(shd.param_specs(params_like, mesh))
        bshard = ns(shd.batch_specs(batch_like, mesh))
        return jax.jit(step, in_shardings=(pshard, bshard),
                       out_shardings=NamedSharding(mesh, P()))

    if mode == "serve":
        assert params_like is not None and cache_like is not None
        step = make_step(model, "serve")
        pshard = ns(shd.param_specs(params_like, mesh))
        cshard = ns(shd.cache_specs(cache_like, mesh))
        bshape = (batch_size or 1, 1)
        tok_shard = NamedSharding(mesh, shd.fit_spec(
            P(shd.batch_axes(mesh)), bshape, mesh))
        logit_shard = NamedSharding(mesh, shd.fit_spec(
            P(shd.batch_axes(mesh), None, "model"), bshape + (0,), mesh))
        return jax.jit(
            step,
            in_shardings=(pshard, tok_shard, cshard),
            out_shardings=(tok_shard, logit_shard, cshard),
            donate_argnums=(2,),
        )

    raise ValueError(f"unknown step mode: {mode!r}")


# ---------------------------------------------------------------------------
# legacy-named entry points (aliases over the factory)
# ---------------------------------------------------------------------------

def make_train_step(model: Model, tcfg: TrainConfig,
                    mesh: Optional[Mesh] = None
                    ) -> Callable[[TrainState, Dict], Tuple]:
    """Legacy alias: ``make_step(model, "train", ...)``."""
    return make_step(model, "train", tcfg, mesh)


def make_eval_step(model: Model):
    """Legacy alias: ``make_step(model, "eval")``."""
    return make_step(model, "eval")


def make_serve_step(model: Model):
    """Legacy alias: ``make_step(model, "serve")`` — the greedy decode
    tick the serving engine (serve/decode.py) jit-wires."""
    return make_step(model, "serve")


def jit_train_step(model: Model, tcfg: TrainConfig, mesh: Mesh,
                   state_like: TrainState, batch_like,
                   donate: bool = True):
    """Legacy alias: ``jit_step(model, "train", ...)``."""
    return jit_step(model, "train", mesh, tcfg=tcfg, state_like=state_like,
                    batch_like=batch_like, donate=donate)


def jit_serve_step(model: Model, mesh: Mesh, params, cache_like,
                   batch_size: int = 0):
    """Legacy alias: ``jit_step(model, "serve", ...)``."""
    return jit_step(model, "serve", mesh, params_like=params,
                    cache_like=cache_like, batch_size=batch_size)

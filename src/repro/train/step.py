"""The training engine's step factory: ONE place where train/eval/serve
steps are built, jit-wired, and sharded.

``make_step(model, mode, tcfg, mesh)`` returns the pure step function:

    train : (TrainState, batch) -> (TrainState, metrics)
    eval  : (params, batch)     -> loss
    serve : (params, tokens, cache) -> (next_tok, logits, cache)
    verify: (params, window, cache) -> (y, acc, cache)   # speculative

``jit_step`` adds the jit wiring (in/out shardings, donation) for the same
three modes — sharding rules for the whole engine live in this module and
nowhere else (``train_state_specs`` below). The legacy entry points
(``make_train_step`` / ``make_eval_step`` / ``make_serve_step`` /
``jit_train_step`` / ``jit_serve_step``) are thin aliases over the factory.

Gradient-reduction modes (TrainConfig.grad_reduce):

  * ``"gspmd"``  — value_and_grad over the globally sharded batch; XLA owns
    the DP all-reduce (fp32, over ("pod", "data")). With
    ``grad_compression="int8"`` the compressed wire format is exercised on
    top of already-reduced gradients (numerics harness; the fp32 pod
    all-reduce still happens), with the error-feedback residual threaded
    through TrainState.
  * ``"explicit"`` — the POD-LOCAL path: the whole grad+update runs inside
    one FULLY-MANUAL shard_map over the mesh. Gradients are computed
    per-device, reduced over "data" (intra-pod ICI), then ONE explicit
    cross-pod reduction: fp32 pmean, or ``compressed_psum`` (int8 payload +
    fp32 per-block scales on the wire) with the per-pod error-feedback
    residual carried in TrainState. GSPMD's implicit fp32 pod all-reduce
    does not exist in the lowered HLO — asserted by compiled-text
    inspection in tests/test_train_engine.py.

    Parameter layout inside the seam (TrainConfig.param_sharding, usually
    set through ``distributed.sharding.ShardingPolicy``):

      - ``"replicated"`` — pure DP (the original contract);
      - ``"fsdp"``       — TrainState leaves keep their GLOBAL logical
        shapes, the shard_map in_specs slice them over the
        ("data", "model") grid; the body all-gathers each sharded leaf
        ONCE (before the microbatch scan) and folds the gradient
        reduce-scatter into the same seam that already owns the data
        reduction — so checkpoints stay elastic across mesh shape;
      - ``"tp"``         — "model"-axis tensor parallelism: megatron
        f/g seams live in the MODEL code (sharding.tp_region_in/_out),
        selected per leaf by shape test under ``sharding.tp_region``;
      - ``"tp_fsdp"``    — both: megatron-table "data" entries are FSDP
        gather axes on the seam, "model" entries stay TP-local (3D
        parallelism: pod DP x data FSDP x model TP).

    Every mode runs fully-manual: on the jax 0.4.x line the XLA partitioner
    rejects data-moving collectives (all_gather/psum_scatter) over manual
    axes of a PARTIALLY-manual shard_map — see
    ``compat.PARTIAL_AUTO_DATA_COLLECTIVES_OK``.

Microbatch gradient accumulation (lax.scan over microbatches) applies in
both modes; a batch that does not divide evenly is a hard factory/trace
time ``ValueError`` — never a silent truncation.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import TrainConfig
from repro.distributed import compat
from repro.distributed import sharding as shd
from repro.models import Model
from repro.optim.adamw import adamw_apply
from repro.train.state import TrainState, train_state_init  # re-export


# ---------------------------------------------------------------------------
# gradient computation (shared by both reduction modes)
# ---------------------------------------------------------------------------

def _check_microbatch(B: int, tcfg: TrainConfig, where: str = "batch"):
    """Silent-truncation guard: ``B // microbatch`` used to drop the
    remainder on non-divisible batches."""
    if tcfg.microbatch and tcfg.microbatch < B and B % tcfg.microbatch != 0:
        raise ValueError(
            f"microbatch={tcfg.microbatch} does not divide the {where} size "
            f"{B}: gradient accumulation would silently drop the last "
            f"{B % tcfg.microbatch} examples. Pick a divisor of {B} (or 0 "
            f"to disable accumulation).")


def _compute_grads(model: Model, tcfg: TrainConfig, params, batch):
    """value_and_grad of the model loss, with lax.scan gradient
    accumulation over microbatches when ``tcfg.microbatch`` divides the
    (per-device) batch — shared by both reduction modes."""
    def loss_fn(p, b):
        return model.loss(p, b)

    B = batch["tokens"].shape[0]
    if tcfg.microbatch and tcfg.microbatch < B:
        _check_microbatch(B, tcfg)
        n_micro = B // tcfg.microbatch
        mb = jax.tree_util.tree_map(
            lambda x: x.reshape((n_micro, tcfg.microbatch) + x.shape[1:]),
            batch)

        def micro(acc, b):
            l, g = jax.value_and_grad(loss_fn)(params, b)
            acc_l, acc_g = acc
            return (acc_l + l,
                    jax.tree_util.tree_map(jnp.add, acc_g, g)), None

        zero_g = jax.tree_util.tree_map(
            lambda x: jnp.zeros(x.shape, jnp.float32), params)
        (tot_l, tot_g), _ = jax.lax.scan(
            micro, (jnp.float32(0), zero_g), mb)
        inv = 1.0 / n_micro
        return tot_l * inv, jax.tree_util.tree_map(
            lambda g: g * inv, tot_g)
    return jax.value_and_grad(loss_fn)(params, batch)


# ---------------------------------------------------------------------------
# cross-pod reduction helpers
# ---------------------------------------------------------------------------

def _squeeze_pod(residual):
    """(1, *shape) local residual slice -> (*shape) (and back, below)."""
    return jax.tree_util.tree_map(lambda r: r[0], residual)


def _unsqueeze_pod(residual):
    """Inverse of ``_squeeze_pod``: restore the leading pod dim."""
    return jax.tree_util.tree_map(lambda r: r[None], residual)


def _compressed_pod_allreduce(grads, residual, mesh: Mesh,
                              tcfg: TrainConfig):
    """GSPMD-path int8 compressed mean over "pod" (wire-format harness on
    already-reduced gradients — the honest scope note lives in the module
    docstring; the real byte saving is the explicit path). The residual IS
    threaded (first-class pytree in/out), so even this path is
    accumulate-and-correct rather than round-to-nearest."""
    from repro.distributed.compression import compressed_psum
    pspecs = shd.param_specs(grads, mesh)
    rspecs = shd.residual_specs(residual, mesh, param_specs=pspecs)

    def local(g, r):
        red, new_r = compressed_psum(
            g, "pod", _squeeze_pod(r), error_feedback=tcfg.error_feedback,
            axis_size=dict(mesh.shape).get("pod", 1))
        return red, _unsqueeze_pod(new_r)

    return compat.shard_map(local, mesh=mesh, in_specs=(pspecs, rspecs),
                            out_specs=(pspecs, rspecs),
                            check_vma=False)(grads, residual)


# ---------------------------------------------------------------------------
# non-finite guard (train/guard.py semantics, shared by both modes)
# ---------------------------------------------------------------------------

def _guard_commit(tcfg: TrainConfig, old: TrainState, new: TrainState,
                  loss, grads, metrics, reduce_ok=None):
    """Fold the all-finite guard into the step's commit: with
    ``tcfg.guard_nonfinite`` the updated params/moments/master/residual
    are where-selected back to their pre-step values on a non-finite
    loss/grad (``step`` still advances — LR schedule and data cursor stay
    aligned with a clean run), and the device-side verdict rides
    ``metrics["all_finite"]``. Guard off: the flag is a constant True so
    the metrics pytree (and jit out_shardings) stay static.

    ``reduce_ok`` (explicit seam only): collective AND of the verdict
    across the manual mesh axes — FSDP-mode gradients are SHARDS, so a
    NaN landing in one device's rows must still veto the commit
    everywhere."""
    from repro.train.guard import all_finite, select_step
    if not tcfg.guard_nonfinite:
        metrics["all_finite"] = jnp.asarray(True)
        return new, metrics
    ok = all_finite(loss, grads)
    if reduce_ok is not None:
        ok = reduce_ok(ok)
    metrics["all_finite"] = ok
    guarded = TrainState(
        new.step,
        select_step(ok, new.params, old.params),
        select_step(ok, new.m, old.m),
        select_step(ok, new.v, old.v),
        select_step(ok, new.master, old.master),
        select_step(ok, new.residual, old.residual))
    return guarded, metrics


# ---------------------------------------------------------------------------
# the factory
# ---------------------------------------------------------------------------

def make_step(model: Model, mode: str, tcfg: Optional[TrainConfig] = None,
              mesh: Optional[Mesh] = None,
              policy: Optional[shd.ShardingPolicy] = None,
              draft_iters: Optional[int] = None) -> Callable:
    """Build the pure step function for ``mode`` in
    ``("train", "eval", "serve", "verify")``. ``tcfg`` is required for
    train; ``mesh`` is required for the explicit-reduction train path (the
    shard_map is constructed at factory time). ``policy`` (a
    ``distributed.sharding.ShardingPolicy``) overrides the legacy
    TrainConfig sharding fields and supplies the mesh when it carries
    one. ``draft_iters`` (verify mode only) fuses the early-exit DRAFT
    forward into the verify step — one dispatch drafts then verifies."""
    if policy is not None:
        if mesh is None:
            mesh = policy.build_mesh() or shd.current_mesh()
        if tcfg is not None:
            tcfg = policy.apply_to(tcfg)
    if mode == "eval":
        def eval_step(params, batch):
            return model.loss(params, batch)
        return eval_step

    if mode == "serve":
        def serve_step(params, tokens, cache):
            logits, new_cache = model.decode_step(params, tokens, cache)
            next_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            return next_tok, logits, new_cache
        return serve_step

    if mode == "verify":
        # speculative-decoding verify tick: one parallel (B, k)-window
        # forward for ALL slots, greedy accept of the longest matching
        # prefix, masked commit. window[:, 0] is the last verified token,
        # window[:, 1:] the drafts; y[:, i] is the greedy continuation of
        # window[:, :i+1], so acc counts 1 (the guaranteed continuation of
        # the verified prefix) + the run of drafts that match it. Rejected
        # tail state is never written — rollback is free and bit-exact.
        if model.spec_forward is None:
            raise ValueError(
                f"model family {model.arch.family!r} has no speculative "
                "verify seam (spec_forward is None)")

        def verify_step(params, window, cache):
            if draft_iters is not None:
                # fused draft: refine the window with the truncated-ladder
                # forward FIRST (read-only), then verify the refined
                # drafts at full depth — one dispatch for both
                dlog, _ = model.spec_forward(params, window, cache,
                                             solver_iters=draft_iters)
                dy = jnp.argmax(dlog, axis=-1).astype(jnp.int32)
                window = jnp.concatenate([window[:, :1], dy[:, :-1]],
                                         axis=1)
            logits, staged = model.spec_forward(params, window, cache)
            y = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            match = (window[:, 1:] == y[:, :-1]).astype(jnp.int32)
            acc = 1 + jnp.sum(jnp.cumprod(match, axis=1), axis=1)
            return y, acc, model.spec_commit(cache, staged, acc)
        return verify_step

    if mode != "train":
        raise ValueError(f"unknown step mode: {mode!r}")
    assert tcfg is not None, "train mode requires a TrainConfig"
    if tcfg.grad_reduce == "explicit":
        if mesh is None:
            mesh = shd.current_mesh()
        if mesh is None:
            raise ValueError("grad_reduce='explicit' requires a mesh at "
                             "factory time (the shard_map is built here)")
        return _make_explicit_train_step(model, tcfg, mesh)
    if tcfg.grad_reduce != "gspmd":
        raise ValueError(f"unknown grad_reduce mode: {tcfg.grad_reduce!r}")
    return _make_gspmd_train_step(model, tcfg, mesh)


def _make_gspmd_train_step(model: Model, tcfg: TrainConfig,
                           mesh: Optional[Mesh]):
    """The GSPMD-owned reduction path: XLA inserts the DP all-reduce;
    optional int8 wire-format harness over the pod axis."""
    def train_step(state: TrainState, batch):
        loss, grads = _compute_grads(model, tcfg, state.params, batch)
        new_residual = state.residual
        m = mesh if mesh is not None else shd.current_mesh()
        if tcfg.grad_compression == "int8" and m is not None \
                and "pod" in m.axis_names \
                and jax.tree_util.tree_leaves(state.residual):
            grads, new_residual = _compressed_pod_allreduce(
                grads, state.residual, m, tcfg)
        if tcfg.shard_grads and m is not None:
            pspecs = shd.param_specs(state.params, m)
            grads = jax.tree_util.tree_map(
                lambda g, s: jax.lax.with_sharding_constraint(
                    g, NamedSharding(m, s)), grads, pspecs)
        step = state.step + 1
        new_params, new_m, new_v, new_master, metrics = adamw_apply(
            tcfg, grads, step, state.m, state.v, state.master, state.params)
        metrics["loss"] = loss
        new_state = TrainState(step, new_params, new_m, new_v, new_master,
                               new_residual)
        return _guard_commit(tcfg, state, new_state, loss, grads, metrics)
    return train_step


def _tp_layout_overrides(model: Model, mesh: Mesh,
                         tcfg: TrainConfig) -> Tuple[str, ...]:
    """Regex patterns of parameters that CANNOT be TP-sharded for this
    model (packed layouts whose segment structure does not divide by the
    TP degree) — forced replicated so the specs never promise a layout the
    model's manual-TP branches cannot compute."""
    mode = getattr(tcfg, "param_sharding", "replicated")
    if mode not in ("tp", "tp_fsdp"):
        return ()
    m = mesh.shape.get("model", 1)
    if m <= 1:
        return ()
    from repro.models.lm import tp_unsupported_patterns
    return tp_unsupported_patterns(model.arch, m)


def _explicit_state_specs(state_like: TrainState, mesh: Mesh,
                          tcfg: TrainConfig,
                          replicate: Tuple[str, ...] = ()) -> TrainState:
    """Per-leaf TrainState specs for the explicit seam under
    ``tcfg.param_sharding`` — params/m/v/master share the parameter specs
    (ZeRO for the sharded modes comes free: the optimizer runs leaf-wise
    on whatever shard the in_specs carve out), the residual keeps its
    leading pod dim over the param layout."""
    mode = getattr(tcfg, "param_sharding", "replicated")
    pspecs = shd.explicit_param_specs(state_like.params, mesh, mode,
                                      replicate=replicate)
    if jax.tree_util.tree_leaves(state_like.residual):
        rspecs = shd.residual_specs(
            state_like.residual, mesh,
            param_specs=None if mode == "replicated" else pspecs)
    else:
        rspecs = state_like.residual      # {} — no residual state
    return TrainState(step=P(), params=pspecs, m=pspecs, v=pspecs,
                      master=pspecs, residual=rspecs)


def _make_explicit_train_step(model: Model, tcfg: TrainConfig, mesh: Mesh):
    """Pod-local gradient engine: the WHOLE step under one fully-manual
    shard_map.

    Per-device body: (FSDP modes) all-gather the sharded parameter leaves
    ONCE — before the microbatch loop — then local grads under
    ``manual_body`` (+ ``tp_region`` for the TP modes), gradient reduction
    over "data" (reduce-scatter back onto the FSDP shards, pmean for
    everything else), then ONE cross-pod reduction (fp32 pmean or int8
    compressed_psum with error-feedback residual), then the leaf-wise
    AdamW update on whatever shard this device owns."""
    from repro.distributed.compression import compressed_psum
    has_pod = "pod" in mesh.axis_names
    has_data = "data" in mesh.axis_names
    int8 = tcfg.grad_compression == "int8" and has_pod
    n_pod = dict(mesh.shape).get("pod", 1)
    mode = getattr(tcfg, "param_sharding", "replicated")
    if mode not in shd._EXPLICIT_MODES:
        raise ValueError(f"unknown param_sharding mode: {mode!r}")
    sizes = dict(mesh.shape)
    tp_m = sizes.get("model", 1)
    tp_ax = "model" if (mode in ("tp", "tp_fsdp") and tp_m > 1) else None
    fsdp_axes = {"fsdp": ("data", "model"),
                 "tp_fsdp": ("data",)}.get(mode, ())
    fsdp_axes = tuple(a for a in fsdp_axes if a in mesh.axis_names)
    # grad-norm reduction axes: every manual non-pod axis (grads are
    # already pod-replicated when the norm is taken)
    norm_axes = tuple(a for a in ("data", "model") if a in mesh.axis_names)
    replicate = _tp_layout_overrides(model, mesh, tcfg)

    def step(state: TrainState, batch):
        pspecs = shd.explicit_param_specs(state.params, mesh, mode,
                                          replicate=replicate)
        sspecs = _explicit_state_specs(state, mesh, tcfg,
                                       replicate=replicate)
        bspecs = shd.pod_local_batch_specs(batch, mesh)
        flat_specs, _ = jax.tree_util.tree_flatten(
            pspecs, is_leaf=lambda x: isinstance(x, P))
        # (dim, axes) FSDP gather placement per leaf — static python data
        ginfo = [shd.spec_gather_axes(s, fsdp_axes) for s in flat_specs]

        def body(state: TrainState, batch):
            flat_p, tdef = jax.tree_util.tree_flatten(state.params)
            # FSDP: gather sharded leaves ONCE, outside the microbatch
            # scan — the contract suite asserts no gather re-appears in
            # any HLO loop body
            full = [compat.all_gather(p, axes, axis=dim, tiled=True)
                    if axes else p
                    for p, (dim, axes) in zip(flat_p, ginfo)]
            params_full = tdef.unflatten(full)
            # every mesh axis is manual here: GSPMD activation constraints
            # in the model are meaningless and must not be staged
            with shd.manual_body(), shd.tp_region(tp_ax, tp_m):
                loss, grads = _compute_grads(model, tcfg, params_full,
                                             batch)
            if has_data:
                loss = compat.pmean(loss, "data")
            flat_g = tdef.flatten_up_to(grads)
            red = []
            for g, (dim, axes) in zip(flat_g, ginfo):
                if axes:
                    # reduce-scatter IS the data reduction for this leaf:
                    # sum over the gather group, shard, normalise by the
                    # group size (replicated-model copies in "fsdp" mode
                    # fold into the same factor)
                    gsz = 1
                    for a in axes:
                        gsz *= sizes[a]
                    red.append(compat.psum_scatter(
                        g, axes, scatter_dimension=dim, tiled=True) / gsz)
                elif has_data:
                    red.append(compat.pmean(g, "data"))
                else:
                    red.append(g)
            grads = tdef.unflatten(red)
            new_residual = state.residual
            if has_pod:
                loss = compat.pmean(loss, "pod")
                if int8:
                    if not jax.tree_util.tree_leaves(state.residual):
                        raise ValueError(
                            "grad_compression='int8' with grad_reduce="
                            "'explicit' needs the error-feedback residual "
                            "in TrainState — build it with train_state_"
                            "init(params, tcfg, mesh) so the mesh's pod "
                            "axis is known at init time")
                    grads, new_res = compressed_psum(
                        grads, "pod", _squeeze_pod(state.residual),
                        error_feedback=tcfg.error_feedback,
                        axis_size=n_pod)
                    new_residual = _unsqueeze_pod(new_res)
                else:
                    grads = compat.pmean(grads, "pod")
            step_no = state.step + 1
            if mode == "replicated":
                new_params, new_m, new_v, new_master, metrics = adamw_apply(
                    tcfg, grads, step_no, state.m, state.v, state.master,
                    state.params)
            else:
                # grads are SHARDS here — the local sq-norm misses other
                # ranks' shards and over-counts replicated leaves. Exact
                # global norm: per-leaf local sq / replication factor,
                # psum'd over the manual non-pod axes.
                contrib = jnp.float32(0)
                for g, s in zip(tdef.flatten_up_to(grads), flat_specs):
                    leaf_axes = set()
                    for entry in tuple(s):
                        if entry is None:
                            continue
                        leaf_axes.update(
                            entry if isinstance(entry, tuple) else (entry,))
                    rf = 1
                    for a in norm_axes:
                        if a not in leaf_axes:
                            rf *= sizes[a]
                    contrib = contrib + jnp.sum(
                        jnp.square(g.astype(jnp.float32))) / rf
                if norm_axes:
                    contrib = compat.psum(contrib, norm_axes)
                gnorm = jnp.sqrt(contrib)
                new_params, new_m, new_v, new_master, metrics = adamw_apply(
                    tcfg, grads, step_no, state.m, state.v, state.master,
                    state.params, grad_norm=gnorm)
            metrics["loss"] = loss
            # verdict agreement: sharded-mode grads are per-device rows,
            # so AND the flag over every manual axis (pmin on {0,1})
            reduce_ok = None
            if mesh.axis_names:
                all_ax = tuple(mesh.axis_names)
                reduce_ok = lambda ok: compat.pmin(
                    ok.astype(jnp.float32), all_ax) > 0.5
            return _guard_commit(
                tcfg, state, TrainState(step_no, new_params, new_m, new_v,
                                        new_master, new_residual),
                loss, grads, metrics, reduce_ok=reduce_ok)

        return compat.shard_map(
            body, mesh=mesh,
            in_specs=(sspecs, bspecs),
            out_specs=(sspecs, P()),
            check_vma=False)(state, batch)

    return step


# ---------------------------------------------------------------------------
# sharding rules + jit wiring — the ONE place they live
# ---------------------------------------------------------------------------

def train_state_specs(state_like: TrainState, mesh: Mesh,
                      tcfg: TrainConfig,
                      replicate: Tuple[str, ...] = ()) -> TrainState:
    """PartitionSpec pytree for a TrainState under ``tcfg.grad_reduce``.

    gspmd    : params/moments/master inherit the parameter sharding rules
               (ZeRO comes free), residual = P("pod", *param_spec).
    explicit : per-leaf specs from ``tcfg.param_sharding`` — replicated
               (pure DP), fsdp, tp or tp_fsdp; leaves keep GLOBAL logical
               shapes in all modes, so checkpoints restore elastically
               across mesh shape and TP degree. ``replicate`` carries the
               model's packed-layout overrides (``_tp_layout_overrides``).
    """
    if tcfg.grad_reduce == "explicit":
        return _explicit_state_specs(state_like, mesh, tcfg,
                                     replicate=replicate)
    pspecs = shd.param_specs(state_like.params, mesh)
    if jax.tree_util.tree_leaves(state_like.residual):
        rspecs = shd.residual_specs(state_like.residual, mesh,
                                    param_specs=pspecs)
    else:
        rspecs = state_like.residual      # {} — no residual state
    return TrainState(step=P(), params=pspecs, m=pspecs, v=pspecs,
                      master=pspecs, residual=rspecs)


def jit_step(model: Model, mode: str, mesh: Mesh, *,
             tcfg: Optional[TrainConfig] = None,
             state_like: Optional[TrainState] = None,
             batch_like=None, cache_like=None, params_like=None,
             batch_size: int = 0, donate: bool = True, spec_k: int = 2,
             spec_draft_iters: Optional[int] = None,
             policy: Optional[shd.ShardingPolicy] = None):
    """jit wiring with explicit shardings for all step modes
    (train/eval/serve/verify — ``spec_k`` is the speculative window
    length for verify mode, ``spec_draft_iters`` fuses the draft forward
    into the verify dispatch)."""
    ns = lambda tree: jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))

    if mode == "train":
        assert tcfg is not None and state_like is not None \
            and batch_like is not None
        if policy is not None:
            tcfg = policy.apply_to(tcfg)
        # factory-time microbatch guard (satellite: no silent truncation)
        B = batch_like["tokens"].shape[0]
        if tcfg.grad_reduce == "explicit":
            ba = shd.batch_axes(mesh) or ()
            n_dp = 1
            for a in ba:
                n_dp *= mesh.shape[a]
            _check_microbatch(B // max(n_dp, 1), tcfg, where="per-device batch")
            bspecs = shd.pod_local_batch_specs(batch_like, mesh)
        else:
            _check_microbatch(B, tcfg)
            bspecs = shd.batch_specs(batch_like, mesh)
        step = make_step(model, "train", tcfg, mesh)
        sspecs = train_state_specs(
            state_like, mesh, tcfg,
            replicate=_tp_layout_overrides(model, mesh, tcfg))
        mshard = NamedSharding(mesh, P())
        return jax.jit(
            step,
            in_shardings=(ns(sspecs), ns(bspecs)),
            out_shardings=(ns(sspecs),
                           {"loss": mshard, "grad_norm": mshard,
                            "lr": mshard, "all_finite": mshard}),
            donate_argnums=(0,) if donate else (),
        )

    if mode == "eval":
        assert batch_like is not None and params_like is not None
        step = make_step(model, "eval")
        pshard = ns(shd.param_specs(params_like, mesh))
        bshard = ns(shd.batch_specs(batch_like, mesh))
        return jax.jit(step, in_shardings=(pshard, bshard),
                       out_shardings=NamedSharding(mesh, P()))

    if mode == "serve":
        assert params_like is not None and cache_like is not None
        step = make_step(model, "serve")
        pshard = ns(shd.param_specs(params_like, mesh))
        cshard = ns(shd.cache_specs(cache_like, mesh))
        bshape = (batch_size or 1, 1)
        tok_shard = NamedSharding(mesh, shd.fit_spec(
            P(shd.batch_axes(mesh)), bshape, mesh))
        logit_shard = NamedSharding(mesh, shd.fit_spec(
            P(shd.batch_axes(mesh), None, "model"), bshape + (0,), mesh))
        return jax.jit(
            step,
            in_shardings=(pshard, tok_shard, cshard),
            out_shardings=(tok_shard, logit_shard, cshard),
            donate_argnums=(2,),
        )

    if mode == "verify":
        assert params_like is not None and cache_like is not None
        step = make_step(model, "verify", draft_iters=spec_draft_iters)
        B = batch_size or 1
        pshard = ns(shd.param_specs(params_like, mesh))
        cshard = ns(shd.cache_specs(cache_like, mesh))
        bshape = (B, spec_k)
        win_shard = NamedSharding(mesh, shd.fit_spec(
            P(shd.batch_axes(mesh)), bshape, mesh))
        acc_shard = NamedSharding(mesh, shd.fit_spec(
            P(shd.batch_axes(mesh)), (B,), mesh))
        return jax.jit(
            step,
            in_shardings=(pshard, win_shard, cshard),
            out_shardings=(win_shard, acc_shard, cshard),
            donate_argnums=(2,),
        )

    raise ValueError(f"unknown step mode: {mode!r}")


# ---------------------------------------------------------------------------
# legacy-named entry points (aliases over the factory)
# ---------------------------------------------------------------------------

def make_train_step(model: Model, tcfg: TrainConfig,
                    mesh: Optional[Mesh] = None
                    ) -> Callable[[TrainState, Dict], Tuple]:
    """Legacy alias: ``make_step(model, "train", ...)``."""
    return make_step(model, "train", tcfg, mesh)


def make_eval_step(model: Model):
    """Legacy alias: ``make_step(model, "eval")``."""
    return make_step(model, "eval")


def make_serve_step(model: Model):
    """Legacy alias: ``make_step(model, "serve")`` — the greedy decode
    tick the serving engine (serve/decode.py) jit-wires."""
    return make_step(model, "serve")


def jit_train_step(model: Model, tcfg: TrainConfig, mesh: Mesh,
                   state_like: TrainState, batch_like,
                   donate: bool = True):
    """Legacy alias: ``jit_step(model, "train", ...)``."""
    return jit_step(model, "train", mesh, tcfg=tcfg, state_like=state_like,
                    batch_like=batch_like, donate=donate)


def jit_serve_step(model: Model, mesh: Mesh, params, cache_like,
                   batch_size: int = 0):
    """Legacy alias: ``jit_step(model, "serve", ...)``."""
    return jit_step(model, "serve", mesh, params_like=params,
                    cache_like=cache_like, batch_size=batch_size)

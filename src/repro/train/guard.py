"""Training guardrails: device-side all-finite predicate + skip-bad-step
select, folded into the step function.

The contract mirrors the loss-metric design (train/loop.py): the
predicate is computed ON DEVICE inside the jitted step and rides the
metrics dict (``metrics["all_finite"]``) next to the device-side loss —
there is NO per-step host sync. The trainer materialises the flag only at
its existing log/checkpoint cadence, which bounds guard DETECTION latency
at ``log_every`` steps while keeping the step loop free-running.

Semantics when ``TrainConfig.guard_nonfinite`` is on:

  * the predicate is ``isfinite(loss) AND all(isfinite(g))`` over the
    REDUCED gradients — non-finite values propagate through the sum-based
    data/pod reductions, so every device sees the same verdict without an
    extra collective;
  * a bad step is SKIPPED on device: params/moments/master/residual are
    ``where``-selected back to their pre-step values, but ``step`` still
    advances — the LR schedule and the (step-indexed) data cursor stay
    aligned with a clean run, so a skipped step consumes its batch and
    moves on;
  * after ``guard_rollback_after`` CONSECUTIVE bad steps the trainer
    restores the newest VERIFIED checkpoint (checkpoint/manager.py
    checksums) and replays from there (requires a ``batch_at``-style
    step-indexed data source to replay the same batches).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def all_finite(loss, grads) -> jax.Array:
    """Device-side scalar bool: loss and every gradient leaf are finite.

    ``jnp.isfinite`` rejects both NaN and +-inf, so an overflowed fp16
    gradient and a NaN'd batch hit the same guard."""
    ok = jnp.all(jnp.isfinite(loss))
    for g in jax.tree_util.tree_leaves(grads):
        ok = jnp.logical_and(ok, jnp.all(jnp.isfinite(g)))
    return ok


def select_step(ok: jax.Array, new_tree, old_tree):
    """Per-leaf ``where(ok, new, old)`` — the skip-bad-step commit gate.

    Applied to the updated params/moments/master/residual so a non-finite
    step leaves optimizer state bit-identical to before the step. Runs
    inside the jitted step (both reduction modes), so the skip costs one
    fused select, not a host round-trip."""
    return jax.tree_util.tree_map(
        lambda n, o: jnp.where(ok, n, o), new_tree, old_tree)

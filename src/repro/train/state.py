"""TrainState: the single pytree the training engine owns.

One NamedTuple carries everything a train step reads and writes — params,
the AdamW moments + fp32 master copy (previously a separate ``AdamWState``),
the step counter, and the error-feedback RESIDUAL tree for the int8
compressed gradient path. Folding the residual into the state is what turns
per-step round-to-nearest quantisation into accumulated-and-corrected error
feedback: the residual survives across steps, checkpoints, and elastic
restarts exactly like the optimizer moments do.

Residual layout: one leaf per parameter leaf with a LEADING POD dimension —
shape ``(n_pod, *param.shape)`` sharded ``P("pod", ...)`` — because the
quantisation error is a per-pod quantity (each pod quantises its own local
gradient). On meshes without a "pod" axis, or when compression is off, the
residual is an empty dict (zero leaves; checkpoint/manager.py round-trips
empty containers).

Sharding rules and jit wiring for this state live in train/step.py
(``train_state_specs`` / ``jit_step``) — exactly one place.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.config import TrainConfig


class TrainState(NamedTuple):
    step: jax.Array      # int32 scalar, post-increment count of applied steps
    params: Any          # compute-dtype params (what the model applies)
    m: Any               # fp32 first moment
    v: Any               # fp32 second moment
    master: Any          # fp32 master copy (authoritative)
    residual: Any        # error-feedback residual, {} when disabled


def residual_dtype(tcfg: TrainConfig):
    return jnp.bfloat16 if tcfg.residual_dtype == "bfloat16" else jnp.float32


def _wants_residual(tcfg: TrainConfig, mesh) -> bool:
    return (tcfg.grad_compression == "int8" and mesh is not None
            and "pod" in mesh.axis_names)


def init_residual(params, tcfg: TrainConfig, mesh) -> Any:
    """Zero residual tree: (n_pod, *leaf.shape) per param leaf, or {}."""
    if not _wants_residual(tcfg, mesh):
        return {}
    n_pod = mesh.shape["pod"]
    dt = residual_dtype(tcfg)
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros((n_pod,) + p.shape, dt), params)


def train_state_init(params, tcfg: TrainConfig, mesh=None) -> TrainState:
    """Fresh TrainState. ``mesh`` (optional) decides the residual layout."""
    # copy=True: master must never alias params (both are donated by the
    # train step; aliased buffers trip "donate the same buffer twice")
    f32 = lambda x: jnp.array(x, dtype=jnp.float32, copy=True)
    zeros = lambda x: jnp.zeros(x.shape, jnp.float32)
    return TrainState(
        step=jnp.zeros((), jnp.int32),
        params=params,
        m=jax.tree_util.tree_map(zeros, params),
        v=jax.tree_util.tree_map(zeros, params),
        master=jax.tree_util.tree_map(f32, params),
        residual=init_residual(params, tcfg, mesh),
    )


def abstract_train_state(params_shapes, tcfg: TrainConfig, mesh=None
                         ) -> TrainState:
    """ShapeDtypeStruct TrainState for lowering (launch/dryrun.py)."""
    f32 = lambda x: jax.ShapeDtypeStruct(x.shape, jnp.float32)
    if _wants_residual(tcfg, mesh):
        n_pod = mesh.shape["pod"]
        dt = residual_dtype(tcfg)
        residual = jax.tree_util.tree_map(
            lambda p: jax.ShapeDtypeStruct((n_pod,) + p.shape, dt),
            params_shapes)
    else:
        residual = {}
    return TrainState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        params=params_shapes,
        m=jax.tree_util.tree_map(f32, params_shapes),
        v=jax.tree_util.tree_map(f32, params_shapes),
        master=jax.tree_util.tree_map(f32, params_shapes),
        residual=residual,
    )

"""Fault-tolerant training loop.

Production behaviours implemented and unit-tested on this container:
  * checkpoint/restart: periodic async checkpoints (params + optimizer +
    data cursor); on startup the trainer auto-resumes from the latest-good
    checkpoint, including MID-EPOCH data position (the pipeline is a pure
    function of step).
  * elastic restart: restore re-resolves sharding specs against the current
    mesh, so the same checkpoint restarts on a different device count /
    mesh shape (tests/test_distributed.py exercises 8 -> 4 devices).
  * straggler watchdog: per-step wall-times feed an EWMA; steps slower than
    ``straggler_factor`` x EWMA are logged with the step payload so an
    external orchestrator can evict the slow host. (On real multi-host TPU
    the same hook reads per-host step barriers.)
  * preemption safety: SIGTERM triggers a final synchronous checkpoint
    before exit (simulated in tests by calling .preempt()).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.config import TrainConfig
from repro.distributed import sharding as shd
from repro.optim.adamw import adamw_init


@dataclasses.dataclass
class StepStats:
    step: int
    loss: float
    wall: float
    straggler: bool


class Trainer:
    def __init__(self, model, tcfg: TrainConfig, mesh, params=None,
                 straggler_factor: float = 3.0, log_every: int = 10,
                 log_fn: Callable[[str], None] = print):
        from repro.train.step import jit_train_step
        self.model = model
        self.tcfg = tcfg
        self.mesh = mesh
        self.log_fn = log_fn
        self.straggler_factor = straggler_factor
        self.log_every = log_every
        if params is None:
            params = model.init(jax.random.PRNGKey(tcfg.seed))
        self.params = params
        self.opt_state = adamw_init(params)
        self.step = 0
        self.ckpt = CheckpointManager(tcfg.checkpoint_dir,
                                      async_save=tcfg.async_checkpoint)
        self._jit_step = None
        self._ewma: Optional[float] = None
        self.history: List[StepStats] = []
        self._preempted = False

    # -- fault tolerance ------------------------------------------------------

    def maybe_resume(self) -> bool:
        latest = self.ckpt.latest_step()
        if latest is None:
            return False
        tree = {"params": self.params, "opt": self.opt_state}
        specs = {"params": shd.param_specs(self.params, self.mesh),
                 "opt": jax.tree_util.tree_map(
                     lambda _: None, self.opt_state)}
        # optimizer state inherits parameter specs
        pspec = shd.param_specs(self.params, self.mesh)
        from repro.optim.adamw import AdamWState
        from jax.sharding import PartitionSpec as P
        specs["opt"] = AdamWState(P(), pspec, pspec, pspec)
        step, restored, extra = self.ckpt.restore(
            latest, mesh=self.mesh, specs=specs, target=tree)
        self.params = restored["params"]
        self.opt_state = restored["opt"]
        self.step = step
        self.log_fn(f"[trainer] resumed from step {step} "
                    f"(mesh={tuple(self.mesh.shape.values())})")
        return True

    def checkpoint(self, sync: bool = False):
        was_async = self.ckpt.async_save
        if sync:
            self.ckpt.async_save = False
        self.ckpt.save(self.step, {"params": self.params,
                                   "opt": self.opt_state},
                       extra={"step": self.step})
        self.ckpt.async_save = was_async

    def preempt(self):
        """SIGTERM path: final sync checkpoint."""
        self._preempted = True
        self.checkpoint(sync=True)

    # -- main loop ------------------------------------------------------------

    def fit(self, data: Iterator[Dict], n_steps: int) -> List[StepStats]:
        from repro.train.step import jit_train_step
        with shd.use_mesh(self.mesh):
            it = iter(data)
            first_batch = next(it)
            if self._jit_step is None:
                self._jit_step = jit_train_step(
                    self.model, self.tcfg, self.mesh, self.params,
                    first_batch)
            batch = first_batch
            target = self.step + n_steps
            while self.step < target and not self._preempted:
                t0 = time.perf_counter()
                self.params, self.opt_state, metrics = self._jit_step(
                    self.params, self.opt_state, batch)
                loss = float(metrics["loss"])
                wall = time.perf_counter() - t0
                self.step += 1
                straggler = False
                if self._ewma is None:
                    self._ewma = wall
                elif self.step > 2:          # skip compile step
                    straggler = wall > self.straggler_factor * self._ewma
                    if straggler:
                        self.log_fn(f"[watchdog] step {self.step} took "
                                    f"{wall:.3f}s vs EWMA {self._ewma:.3f}s "
                                    "— straggler flagged")
                    self._ewma = 0.9 * self._ewma + 0.1 * wall
                self.history.append(StepStats(self.step, loss, wall,
                                              straggler))
                if self.step % self.log_every == 0:
                    self.log_fn(f"[trainer] step {self.step} "
                                f"loss {loss:.4f} {wall*1e3:.1f} ms")
                if self.tcfg.checkpoint_every and \
                        self.step % self.tcfg.checkpoint_every == 0:
                    self.checkpoint()
                if self.step < target:
                    batch = next(it)
            self.ckpt.wait()
        return self.history

"""Fault-tolerant training loop.

Production behaviours implemented and unit-tested on this container:
  * checkpoint/restart: periodic async checkpoints of the FULL TrainState
    (params + AdamW moments + step + error-feedback residual) plus the data
    cursor; on startup the trainer auto-resumes from the latest-good
    checkpoint, including MID-EPOCH data position (the pipeline is a pure
    function of step).
  * elastic restart: restore re-resolves sharding specs against the current
    mesh, so the same checkpoint restarts on a different device count /
    mesh shape (tests/test_distributed.py exercises 8 -> 4 devices;
    tests/test_train_engine.py does the same including the per-pod
    residual tree).
  * straggler watchdog: per-step wall-times feed an EWMA; steps slower than
    ``straggler_factor`` x EWMA are logged with the step payload so an
    external orchestrator can evict the slow host. (On real multi-host TPU
    the same hook reads per-host step barriers.)
  * preemption safety: SIGTERM triggers a final synchronous checkpoint
    before exit (simulated in tests by calling .preempt()).
  * NO per-step host sync: metrics stay device-side (``StepStats.loss``
    holds the jax scalar) and are only materialised on ``log_every`` /
    checkpoint steps — the step loop dispatches ahead of the device
    instead of blocking on ``float(loss)`` every iteration.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.config import TrainConfig
from repro.distributed import sharding as shd
from repro.train.state import TrainState, train_state_init


@dataclasses.dataclass
class StepStats:
    step: int
    loss: Any        # device-side jax scalar until materialised (lazy)
    wall: float
    straggler: bool

    @property
    def loss_value(self) -> float:
        return float(self.loss)


class Trainer:
    def __init__(self, model, tcfg: TrainConfig, mesh=None, params=None,
                 straggler_factor: float = 3.0, log_every: int = 10,
                 log_fn: Callable[[str], None] = print,
                 policy: Optional[shd.ShardingPolicy] = None):
        if policy is not None:
            tcfg = policy.apply_to(tcfg)
            if mesh is None:
                mesh = policy.build_mesh()
        if mesh is None:
            raise ValueError("Trainer needs a mesh (directly or via a "
                             "policy carrying mesh_shape)")
        self.model = model
        self.tcfg = tcfg
        self.mesh = mesh
        self.policy = policy
        self.log_fn = log_fn
        self.straggler_factor = straggler_factor
        self.log_every = log_every
        if params is None:
            params = model.init(jax.random.PRNGKey(tcfg.seed))
        self.state = train_state_init(params, tcfg, mesh)
        self.step = 0
        self.ckpt = CheckpointManager(tcfg.checkpoint_dir,
                                      async_save=tcfg.async_checkpoint)
        self._jit_step = None
        self._ewma: Optional[float] = None
        self.history: List[StepStats] = []
        self._preempted = False

    # TrainState views (the state pytree is authoritative)

    @property
    def params(self):
        return self.state.params

    # -- fault tolerance ------------------------------------------------------

    def maybe_resume(self) -> bool:
        latest = self.ckpt.latest_step()
        if latest is None:
            return False
        from repro.train.step import _tp_layout_overrides, train_state_specs
        specs = train_state_specs(
            self.state, self.mesh, self.tcfg,
            replicate=_tp_layout_overrides(self.model, self.mesh,
                                           self.tcfg))
        step, restored, extra = self.ckpt.restore(
            latest, mesh=self.mesh, specs={"state": specs},
            target={"state": self.state})
        self.state = restored["state"]
        self.step = step
        self.log_fn(f"[trainer] resumed from step {step} "
                    f"(mesh={tuple(self.mesh.shape.values())})")
        return True

    def checkpoint(self, sync: bool = False):
        was_async = self.ckpt.async_save
        if sync:
            self.ckpt.async_save = False
        self.ckpt.save(self.step, {"state": self.state},
                       extra={"step": self.step})
        self.ckpt.async_save = was_async

    def preempt(self):
        """SIGTERM path: final sync checkpoint."""
        self._preempted = True
        self.checkpoint(sync=True)

    # -- main loop ------------------------------------------------------------

    def fit(self, data: Iterator[Dict], n_steps: int) -> List[StepStats]:
        from repro.train.step import jit_train_step
        with shd.use_mesh(self.mesh):
            it = iter(data)
            first_batch = next(it)
            if self._jit_step is None:
                self._jit_step = jit_train_step(
                    self.model, self.tcfg, self.mesh, self.state,
                    first_batch)
            batch = first_batch
            target = self.step + n_steps
            while self.step < target and not self._preempted:
                t0 = time.perf_counter()
                self.state, metrics = self._jit_step(self.state, batch)
                self.step += 1
                loss = metrics["loss"]      # device-side; NOT materialised
                # wall measures dispatch (plus any queue backpressure) on
                # EVERY step, never the log-step sync below — otherwise each
                # log_every-th step would absorb the queued backlog and trip
                # the watchdog while real stragglers hide in dispatch-time
                # steps. Persistent device slowness still surfaces here:
                # once the dispatch queue fills, dispatch blocks on it.
                wall = time.perf_counter() - t0
                log_step = self.step % self.log_every == 0
                ckpt_step = bool(self.tcfg.checkpoint_every) and \
                    self.step % self.tcfg.checkpoint_every == 0
                if log_step or ckpt_step:
                    # the only host syncs in the loop (log/ckpt cadence,
                    # never per step)  # repro-lint: disable=host-sync
                    loss = float(jax.block_until_ready(loss))
                    self._materialise_history()
                straggler = False
                if self._ewma is None:
                    self._ewma = wall
                elif self.step > 2:          # skip compile step
                    straggler = wall > self.straggler_factor * self._ewma
                    if straggler:
                        self.log_fn(f"[watchdog] step {self.step} took "
                                    f"{wall:.3f}s vs EWMA {self._ewma:.3f}s "
                                    "— straggler flagged")
                    self._ewma = 0.9 * self._ewma + 0.1 * wall
                self.history.append(StepStats(self.step, loss, wall,
                                              straggler))
                if log_step:
                    self.log_fn(f"[trainer] step {self.step} "
                                f"loss {loss:.4f} {wall*1e3:.1f} ms")
                if ckpt_step:
                    self.checkpoint()
                if self.step < target:
                    batch = next(it)
            self.ckpt.wait()
            self._materialise_history()
        return self.history

    def _materialise_history(self):
        """Backfill device-side StepStats losses into plain floats. Called
        right after a host sync (device work is done — conversions are
        cheap host copies), so ``history`` never pins more than
        ``log_every`` device buffers."""
        for st in reversed(self.history):
            if isinstance(st.loss, float):
                break
            st.loss = float(st.loss)

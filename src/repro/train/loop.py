"""Fault-tolerant training loop.

Production behaviours implemented and unit-tested on this container:
  * checkpoint/restart: periodic async checkpoints of the FULL TrainState
    (params + AdamW moments + step + error-feedback residual) plus the data
    cursor; on startup the trainer auto-resumes from the latest-good
    checkpoint, including MID-EPOCH data position (the pipeline is a pure
    function of step).
  * elastic restart: restore re-resolves sharding specs against the current
    mesh, so the same checkpoint restarts on a different device count /
    mesh shape (tests/test_distributed.py exercises 8 -> 4 devices;
    tests/test_train_engine.py does the same including the per-pod
    residual tree).
  * straggler watchdog: per-step wall-times feed an EWMA; steps slower than
    ``straggler_factor`` x EWMA are logged with the step payload so an
    external orchestrator can evict the slow host. (On real multi-host TPU
    the same hook reads per-host step barriers.)
  * preemption safety: SIGTERM triggers a final synchronous checkpoint
    before exit (simulated in tests by calling .preempt()).
  * NO per-step host sync: metrics stay device-side (``StepStats.loss``
    holds the jax scalar) and are only materialised on ``log_every`` /
    checkpoint steps — the step loop dispatches ahead of the device
    instead of blocking on ``float(loss)`` every iteration.
  * non-finite guardrails (train/guard.py, ``TrainConfig.guard_nonfinite``):
    the step's device-side all-finite verdict rides ``StepStats.ok`` the
    same lazy way the loss does; bad steps are skipped ON DEVICE, counted
    here at sync cadence, and ``guard_rollback_after`` consecutive bad
    steps trigger a restore of the newest VERIFIED checkpoint. Rollback
    replays the same step-indexed batches (``batch_at`` data protocol),
    and a barrier prevents a deterministic bad window from rolling back
    in a loop: one rollback per distinct restore point, then skip-through.
  * deterministic fault injection (reliability/faults.py): a ``faults``
    FaultPlan makes the loop poll ``fires("preempt", step)`` — the chaos
    suite's simulated SIGTERM, routed through the same ``preempt()`` seam.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.config import TrainConfig
from repro.distributed import sharding as shd
from repro.train.state import TrainState, train_state_init


@dataclasses.dataclass
class StepStats:
    step: int
    loss: Any        # device-side jax scalar until materialised (lazy)
    wall: float
    straggler: bool
    ok: Any = True   # device-side all-finite verdict (lazy, like loss)

    @property
    def loss_value(self) -> float:
        return float(self.loss)


class Trainer:
    def __init__(self, model, tcfg: TrainConfig, mesh=None, params=None,
                 straggler_factor: float = 3.0, log_every: int = 10,
                 log_fn: Callable[[str], None] = print,
                 policy: Optional[shd.ShardingPolicy] = None,
                 faults=None):
        if policy is not None:
            tcfg = policy.apply_to(tcfg)
            if mesh is None:
                mesh = policy.build_mesh()
        if mesh is None:
            raise ValueError("Trainer needs a mesh (directly or via a "
                             "policy carrying mesh_shape)")
        self.model = model
        self.tcfg = tcfg
        self.mesh = mesh
        self.policy = policy
        self.log_fn = log_fn
        self.straggler_factor = straggler_factor
        self.log_every = log_every
        if params is None:
            params = model.init(jax.random.PRNGKey(tcfg.seed))
        self.state = train_state_init(params, tcfg, mesh)
        self.step = 0
        self.ckpt = CheckpointManager(tcfg.checkpoint_dir,
                                      async_save=tcfg.async_checkpoint)
        self._jit_step = None
        self._ewma: Optional[float] = None
        self.history: List[StepStats] = []
        self._preempted = False
        # fault injection + guardrail bookkeeping (reliability PR): the
        # FaultPlan drives simulated preemptions through preempt(); the
        # guard counters are updated at sync cadence from StepStats.ok
        self.faults = faults
        self.skipped_steps = 0
        self.rollbacks = 0
        self._bad_streak = 0
        self._guard_scanned = 0       # history index the guard has read
        self._rollback_barrier: Optional[int] = None

    # TrainState views (the state pytree is authoritative)

    @property
    def params(self):
        return self.state.params

    # -- fault tolerance ------------------------------------------------------

    def _restore(self, step: Optional[int] = None) -> int:
        """Restore TrainState from a checkpoint against the current mesh.
        ``step=None`` picks the newest VERIFIED step (checksum manifest),
        so auto-resume and rollback both survive a corrupt/truncated
        latest checkpoint. Raises FileNotFoundError when nothing
        restorable exists."""
        from repro.train.step import _tp_layout_overrides, train_state_specs
        specs = train_state_specs(
            self.state, self.mesh, self.tcfg,
            replicate=_tp_layout_overrides(self.model, self.mesh,
                                           self.tcfg))
        step, restored, _ = self.ckpt.restore(
            step, mesh=self.mesh, specs={"state": specs},
            target={"state": self.state})
        self.state = restored["state"]
        self.step = step
        return step

    def maybe_resume(self) -> bool:
        if self.ckpt.latest_step() is None:
            return False
        try:
            step = self._restore(None)
        except FileNotFoundError:
            self.log_fn("[trainer] checkpoints present but none verified "
                        "— starting fresh")
            return False
        self.log_fn(f"[trainer] resumed from step {step} "
                    f"(mesh={tuple(self.mesh.shape.values())})")
        return True

    def checkpoint(self, sync: bool = False):
        was_async = self.ckpt.async_save
        if sync:
            self.ckpt.async_save = False
        self.ckpt.save(self.step, {"state": self.state},
                       extra={"step": self.step})
        self.ckpt.async_save = was_async

    def preempt(self):
        """SIGTERM path: final sync checkpoint."""
        self._preempted = True
        self.checkpoint(sync=True)

    # -- guardrails -----------------------------------------------------------

    def _account_guard(self):
        """Consume materialised ``StepStats.ok`` flags: count skipped
        steps, track the consecutive-bad streak, and trigger rollback
        after ``guard_rollback_after`` consecutive bad steps. Runs at the
        loop's sync cadence, so detection latency is bounded by
        ``log_every`` — the price of keeping the step loop sync-free."""
        K = self.tcfg.guard_rollback_after
        while self._guard_scanned < len(self.history):
            st = self.history[self._guard_scanned]
            if not isinstance(st.ok, bool):
                break                        # not materialised yet
            self._guard_scanned += 1
            if st.ok:
                self._bad_streak = 0
            else:
                self.skipped_steps += 1
                self._bad_streak += 1
                self.log_fn(f"[guard] step {st.step} non-finite — skipped "
                            f"(streak {self._bad_streak})")
                if K and self._bad_streak >= K:
                    self._maybe_rollback()

    def _maybe_rollback(self):
        """Roll back to the newest verified checkpoint — at most ONCE per
        distinct restore point (the barrier): a deterministic bad window
        replays identically after restore, so a second rollback to the
        same step would livelock; instead the trainer skips through."""
        self._bad_streak = 0
        self.ckpt.wait()
        cand = self.ckpt.latest_verified_step()
        if cand is None:
            self.log_fn("[guard] rollback requested but no verified "
                        "checkpoint exists — continuing (skip-only)")
            return
        if cand == self._rollback_barrier:
            self.log_fn(f"[guard] already rolled back to step {cand} once "
                        "— skipping through the bad window instead")
            return
        self._restore(cand)
        self._rollback_barrier = cand
        self.rollbacks += 1
        self._guard_scanned = len(self.history)
        self.log_fn(f"[guard] rolled back to verified step {cand} after "
                    "consecutive non-finite steps")

    # -- main loop ------------------------------------------------------------

    def fit(self, data, n_steps: int) -> List[StepStats]:
        """Run ``n_steps`` steps (to absolute step ``start + n_steps``).

        ``data`` is either an iterator/iterable of batches (legacy) or a
        STEP-INDEXED source exposing ``batch_at(step)`` (data/pipeline.py
        contract). The indexed form is what makes preempt-resume
        bit-exact and guard rollback replayable — the loop asks for
        ``batch_at(self.step)`` so a restored step re-reads its exact
        batch; an iterator cannot rewind, so rollback with iterator data
        keeps consuming forward (logged when it happens)."""
        from repro.train.step import jit_train_step
        with shd.use_mesh(self.mesh):
            if hasattr(data, "batch_at"):
                get_batch = data.batch_at
            else:
                it = iter(data)
                get_batch = lambda _step: next(it)
                if self.tcfg.guard_rollback_after:
                    self.log_fn("[guard] warning: iterator data cannot "
                                "replay after rollback — pass a batch_at "
                                "source for exact replay")
            batch = get_batch(self.step)
            if self._jit_step is None:
                self._jit_step = jit_train_step(
                    self.model, self.tcfg, self.mesh, self.state, batch)
            target = self.step + n_steps
            while self.step < target and not self._preempted:
                if self.faults is not None and \
                        self.faults.fires("preempt", self.step):
                    # simulated SIGTERM: the same seam a real orchestrator
                    # kill hits — sync checkpoint, loop exit
                    self.preempt()
                    break
                t0 = time.perf_counter()
                self.state, metrics = self._jit_step(self.state, batch)
                self.step += 1
                loss = metrics["loss"]      # device-side; NOT materialised
                ok = metrics.get("all_finite", True)   # device-side too
                # wall measures dispatch (plus any queue backpressure) on
                # EVERY step, never the log-step sync below — otherwise each
                # log_every-th step would absorb the queued backlog and trip
                # the watchdog while real stragglers hide in dispatch-time
                # steps. Persistent device slowness still surfaces here:
                # once the dispatch queue fills, dispatch blocks on it.
                wall = time.perf_counter() - t0
                log_step = self.step % self.log_every == 0
                ckpt_step = bool(self.tcfg.checkpoint_every) and \
                    self.step % self.tcfg.checkpoint_every == 0
                if log_step or ckpt_step:
                    # the only host syncs in the loop (log/ckpt cadence,
                    # never per step)  # repro-lint: disable=host-sync
                    loss = float(jax.block_until_ready(loss))
                    ok = bool(ok)
                    self._materialise_history()
                straggler = False
                if self._ewma is None:
                    self._ewma = wall
                elif self.step > 2:          # skip compile step
                    straggler = wall > self.straggler_factor * self._ewma
                    if straggler:
                        self.log_fn(f"[watchdog] step {self.step} took "
                                    f"{wall:.3f}s vs EWMA {self._ewma:.3f}s "
                                    "— straggler flagged")
                    self._ewma = 0.9 * self._ewma + 0.1 * wall
                self.history.append(StepStats(self.step, loss, wall,
                                              straggler, ok))
                if log_step or ckpt_step:
                    self._account_guard()
                if log_step:
                    self.log_fn(f"[trainer] step {self.step} "
                                f"loss {loss:.4f} {wall*1e3:.1f} ms")
                if ckpt_step:
                    self.checkpoint()
                if self.step < target:
                    # after a rollback self.step moved backwards: the
                    # indexed source re-serves the restored step's batch
                    batch = get_batch(self.step)
            self.ckpt.wait()
            self._materialise_history()
            self._account_guard()
        return self.history

    def _materialise_history(self):
        """Backfill device-side StepStats losses (and guard flags) into
        plain host values. Called right after a host sync (device work is
        done — conversions are cheap host copies), so ``history`` never
        pins more than ``log_every`` device buffers."""
        for st in reversed(self.history):
            if isinstance(st.loss, float):
                break
            st.loss = float(st.loss)
            st.ok = bool(st.ok)

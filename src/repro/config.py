"""Framework configuration system.

Every assigned architecture is an ``ArchConfig`` (src/repro/configs/<id>.py);
shapes are ``ShapeConfig``; meshes are ``MeshConfig``. All are plain frozen
dataclasses so configs are hashable, printable, and diffable in logs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    # capacity factor for the dense-dispatch einsum path (dry-run exactness:
    # the top-k one-hot combine is mathematically exact; capacity applies to
    # the EP all-to-all path)
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # pad the expert-stacked weights to this count for even EP sharding
    # (e.g. 40 experts -> 48 on a 16-way model axis); 0 = no padding.
    # Padded experts receive zero routing weight — mathematically inert.
    pad_to: int = 0
    # production dispatch path: "einsum" (GShard one-hot) | "gather"
    # (scatter/gather, FLOP-honest) | "dense" (exact, smoke tests)
    dispatch: str = "einsum"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Selective-SSM mixer parameters (Mamba-1 / Mamba-2 / LrcSSM mixer)."""
    kind: str = "mamba1"          # mamba1 | mamba2 | lrc
    d_state: int = 16             # per-channel state size (N)
    d_conv: int = 4               # depthwise conv width
    expand: int = 2               # d_inner = expand * d_model
    n_heads: int = 0              # mamba2 heads (0 = d_inner//64)
    head_dim: int = 64            # mamba2
    chunk: int = 256              # scan chunk (VMEM schedule)
    deer_iters: int = 8           # lrc mixer Newton iterations (fixed mode)
    # speculative-decoding DRAFT depth: early-exit Newton iteration count
    # for the cheap draft forward on the verify seam (serve engine /
    # mixers solver_iters). Must be < deer_iters to be a draft; the
    # verify pass always runs the full ladder, so truncation here never
    # affects emitted tokens — only the accept rate.
    draft_iters: int = 2
    # sequence-parallel DEER for the lrc mixer: shard the Newton solve's
    # time axis over the "model" mesh axis (core/deer_sharded.py) instead
    # of replicating the (T, d_inner) trajectory per device. When the batch
    # cannot shard over the DP axes (batch=1 long-sequence cells, e.g.
    # long_500k), the time axis is sharded over ("data", "model") so the
    # whole mesh still participates. Falls back to the replicated solver
    # when no mesh / non-divisible T.
    seq_shard: bool = False
    # fused Pallas tier for the lrc mixer (kernels/lrc_deer): route the
    # full-sequence / prefill / training DEER solve through the
    # whole-Newton megakernel (one kernel launch for all deer_iters
    # iterations, autotuned tiling, fused implicit-adjoint backward) —
    # sharded over the time axis when seq_shard applies, replicated
    # otherwise. Decode (T == 1) is unaffected. Disabled under exact_hlo.
    fused: bool = False
    # serve-time state-cache quantisation (distributed/precision.py): when
    # set ("int8" | "fp8" | "bf16"), the lrc mixer quantize-roundtrips the
    # recurrent state EVERY tick inside the step function, so decode,
    # prefill and the speculative-verify DEER window all walk the SAME
    # storage-grid trajectory — what keeps spec decode token-identical to
    # quantized greedy. Normally injected by ServeEngine from its
    # PrecisionPolicy rather than set by hand. None = full-precision state.
    state_quant: Optional[str] = None
    state_quant_block: int = 256  # RTN scale granularity (int8 mode)
    # lrc_deer solver HBM stream dtype ("bf16" | "fp8"): s_u / eps_u inputs
    # and the trajectory output move through HBM in this dtype while every
    # VMEM accumulation stays fp32 (kernels read refs through .astype(f32)).
    # Threaded through kernels/autotune.py VMEM budgeting. None = fp32.
    kernel_io: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 => d_model // n_heads
    act: str = "gelu"             # ffn activation
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    rope_theta: float = 10000.0
    # attention pattern: every layer full attention unless window_pattern set.
    # window_pattern = (local_window, n_local_per_global) e.g. gemma3 (1024, 5)
    window_pattern: Optional[Tuple[int, int]] = None
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): 1 shared attention block every `hybrid_period` ssm layers
    hybrid_period: int = 0
    # enc-dec (whisper): encoder layers with full self-attn + decoder w/ cross
    enc_layers: int = 0
    enc_seq: int = 0              # encoder input frames (stub frontend)
    # vlm: projector from frontend embedding dim
    frontend_dim: int = 0
    frontend_tokens: int = 0
    # sequence mixer override: "attn" (arch default) | "lrc" (paper technique)
    seq_mixer: str = "arch"
    # distribution strategy (distributed/sharding.py):
    #   megatron — TP over "model" (activations all-reduced per block),
    #              params FSDP over "data"           [baseline]
    #   fsdp     — ZeRO-3: params sharded over (data x model) on their last
    #              dim, batch over every axis; zero activation collectives
    #   serve    — weight-stationary decode: params TP over "model" only,
    #              batch/caches over "data"
    #   ring     — sequence parallelism: activations sharded over "model"
    #              on the time axis, weights over "data"; attention runs as
    #              a shard_map ring (attn_impl="ring")
    sharding_strategy: str = "megatron"
    attn_impl: str = "default"    # default | ring
    # sub-quadratic? (governs long_500k applicability)
    subquadratic: bool = False
    dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.float32   # master copy dtype
    remat: str = "layer"          # none | layer | full
    scan_layers: bool = True      # lax.scan over layer stack (compile-time)
    # exact-HLO measurement mode (roofline only): no interior loops so
    # cost_analysis / collective parsing count every op exactly once —
    # single-block attention, unchunked loss, associative (non-chunked)
    # ssm scans, unrolled DEER iterations. NOT the production config.
    exact_hlo: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    grad_clip: float = 1.0
    microbatch: int = 0           # 0 = no gradient accumulation
    seed: int = 0
    checkpoint_every: int = 200
    checkpoint_dir: str = "/tmp/repro_ckpt"
    async_checkpoint: bool = True
    grad_compression: str = "none"   # none | int8  (cross-pod all-reduce)
    # who owns the cross-pod gradient collective (train/step.py):
    #   gspmd    — value_and_grad over the globally sharded batch; XLA
    #              inserts the (fp32) DP all-reduce. int8 compression on
    #              this path is a wire-format harness only: it re-reduces
    #              already-reduced gradients.
    #   explicit — shard_map the whole grad+update over the DP axes:
    #              grads are computed pod-locally, psum'd over "data" only,
    #              then ONE explicit cross-pod reduction (fp32 psum, or
    #              compressed_psum with the error-feedback residual threaded
    #              through TrainState). No implicit fp32 pod all-reduce
    #              appears in the lowered HLO. Parameter layout inside the
    #              seam is selected by ``param_sharding`` below.
    grad_reduce: str = "gspmd"       # gspmd | explicit
    # explicit-seam parameter layout (ignored on the gspmd path):
    #   replicated — pure DP, every device holds full params;
    #   fsdp       — params/opt-state sharded over the ("data", "model")
    #                grid; the seam all-gathers params ONCE before the
    #                microbatch loop and reduce-scatters grads back;
    #   tp         — "model"-axis tensor parallelism with manual megatron
    #                seams in the model code (fully-manual shard_map);
    #   tp_fsdp    — megatron table: "model" entries TP-local, "data"
    #                entries gathered/scattered on the seam (3D parallel).
    # Prefer setting this through distributed.sharding.ShardingPolicy.
    param_sharding: str = "replicated"  # replicated | fsdp | tp | tp_fsdp
    # error-feedback residual (int8 path): accumulated quantisation error,
    # carried across steps in TrainState. "float32" | "bfloat16".
    residual_dtype: str = "float32"
    # ablation knob: disable error feedback (per-step round-to-nearest).
    # Exists so tests/benchmarks can show WHY the residual matters.
    error_feedback: bool = True
    # training guardrails (train/guard.py): fold a device-side
    # all-finite(loss, grads) predicate into the step and SKIP bad steps
    # on device (params/opt-state where-selected back; step still
    # advances so the LR schedule / data cursor stay aligned). The flag
    # rides metrics["all_finite"] next to the device-side loss — no
    # per-step host sync; the trainer reads it at log/ckpt cadence.
    guard_nonfinite: bool = False
    # after this many CONSECUTIVE bad steps, roll back to the newest
    # VERIFIED checkpoint (manifest checksums) and replay. 0 = skip-only,
    # never roll back. Detection latency is bounded by the trainer's
    # log_every (the flag is read at sync points only).
    guard_rollback_after: int = 0
    zero_opt_state: bool = True      # shard opt state over data axis (ZeRO-1)
    # constrain grads to the param sharding immediately after value_and_grad
    # so GSPMD lowers the DP reduction as reduce-scatter (half the wire of
    # the all-reduce it otherwise emits). §Perf iteration A4.
    shard_grads: bool = False


# hardware model for roofline (TPU v5e)
@dataclasses.dataclass(frozen=True)
class HWConfig:
    peak_flops_bf16: float = 197e12   # per chip
    hbm_bw: float = 819e9             # bytes/s per chip
    ici_bw: float = 50e9              # bytes/s per link
    hbm_bytes: float = 16e9           # capacity per chip
    vmem_bytes: float = 128e6


HW = HWConfig()

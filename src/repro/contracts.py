"""Declarative lowering contracts: structural assertions on what a jax
function COMPILES TO, checked per commit instead of per incident.

The codebase's scaling claims are contracts on the lowered artifact, not
on Python source:

  * serve prefill lowers with NO sequential loop of prompt length
    (the parallel-prefill acceptance check, tests/test_serve.py);
  * the explicit-int8 gradient path emits NO gradient-sized fp32
    cross-pod collective (tests/test_train_engine.py);
  * the whole-Newton megakernel moves a bounded number of (T, D)-sized
    HBM streams per solve (benchmarks/kernels.py).

This module gives those assertions one API. The low-level introspection
primitives — ``sequential_loop_lengths`` (jaxpr scan/while walker) and
``collective_ops_from_hlo`` / ``collective_bytes_from_hlo`` (optimized-HLO
collective inventory with ring wire accounting) — live here and are
re-exported by ``repro.roofline`` for its roofline model. On top of them,
``check_lowering(fn, args, ...)`` evaluates a declarative contract and
returns STRUCTURED violations (never asserts itself), so tests,
benchmarks and the CI contract suite (tools/contract_suite.py) all share
one vocabulary and one JSON shape.

The companion source-level layer is the AST rule engine in
``tools/repro_lint`` (compat-collective routing, host-sync detection,
...); docs/static_analysis.md documents both.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Set, Union

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}


# ---------------------------------------------------------------------------
# structured violations
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Violation:
    """One structured contract violation.

    ``contract`` names the clause that fired (``"sequential-loop"``,
    ``"unbounded-loop"``, ``"forbidden-collective"``,
    ``"collective-bytes"``, ``"stream-budget"``, ``"lowering-error"``);
    ``message`` is the human line; ``detail`` carries the machine-readable
    evidence (loop length, the offending HLO op record, byte counts...).
    """
    contract: str
    message: str
    detail: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_json(self) -> Dict[str, Any]:
        """Plain-dict form for JSON reports."""
        return {"contract": self.contract, "message": self.message,
                "detail": self.detail}


@dataclasses.dataclass
class LoweringReport:
    """Result of ``check_lowering``: the evidence plus any violations.

    ``loop_lengths`` / ``collectives`` / ``collective_wire_bytes`` are
    populated only for the clauses the contract actually requested (e.g. a
    loops-only contract never compiles the function).
    """
    violations: List[Violation]
    loop_lengths: Optional[Set[int]] = None
    collectives: Optional[List[Dict[str, Any]]] = None
    collective_wire_bytes: Optional[Dict[str, int]] = None

    @property
    def ok(self) -> bool:
        """True when every requested contract clause held."""
        return not self.violations

    def to_json(self) -> Dict[str, Any]:
        """JSON-serialisable form (sets become sorted lists)."""
        return {
            "ok": self.ok,
            "violations": [v.to_json() for v in self.violations],
            "loop_lengths": (sorted(self.loop_lengths)
                             if self.loop_lengths is not None else None),
            "collectives": self.collectives,
            "collective_wire_bytes": self.collective_wire_bytes,
        }


# ---------------------------------------------------------------------------
# jaxpr-level sequential-depth introspection
# ---------------------------------------------------------------------------

def sequential_loop_lengths(fn, *args) -> set:
    """Trip counts of every ``lax.scan`` in ``fn``'s jaxpr, recursively
    (scan bodies, pjit calls, cond branches, custom-vjp wrappers, ...).
    Unbounded ``lax.while_loop``s are recorded as ``-1``.

    This is the parallel-prefill acceptance check, asserted at the jaxpr
    level where loop trip counts are structural: a token-by-token prefill
    would show up as a scan of length T, while the parallel solver paths
    lower to associative scans (log-depth slices, no scan primitive) plus
    short carries — Newton iterations, scan-chunk carries, layer groups —
    whose lengths are all independent of T.
    """
    import jax

    out: set = set()

    def walk(jaxpr):
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "scan":
                out.add(int(eqn.params["length"]))
            elif eqn.primitive.name == "while":
                out.add(-1)
            for v in eqn.params.values():
                for sub in _jaxprs_in(v):
                    walk(sub)

    def _jaxprs_in(v):
        core = jax.core
        if isinstance(v, core.ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, core.Jaxpr):
            yield v
        elif isinstance(v, (tuple, list)):
            for item in v:
                yield from _jaxprs_in(item)

    walk(jax.make_jaxpr(fn)(*args).jaxpr)
    return out


# ---------------------------------------------------------------------------
# optimized-HLO collective inventory
# ---------------------------------------------------------------------------

_COLLECTIVE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*"
    r"(\((?:[^)]*)\)|[\w\[\],{}]+)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute|"
    r"all-gather-start|all-reduce-start|collective-permute-start)"
    r"\b(.*)$",
    re.MULTILINE)

_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")

#: HLO computation header — '%name (args) -> type {' (optionally ENTRY;
#: the arg list may nest parens for tuple-shaped params)
_COMPUTATION_RE = re.compile(
    r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->[^{\n]*\{",
    re.MULTILINE)


def _hlo_regions(hlo_text: str) -> Dict[str, "tuple[int, int]"]:
    """Computation name -> (start, end) text span, in file order."""
    headers = list(_COMPUTATION_RE.finditer(hlo_text))
    regions: Dict[str, "tuple[int, int]"] = {}
    for i, m in enumerate(headers):
        end = headers[i + 1].start() if i + 1 < len(headers) \
            else len(hlo_text)
        regions[m.group(1)] = (m.start(), end)
    return regions


def while_loop_computations(hlo_text: str) -> Set[str]:
    """Names of every computation reachable from a ``while`` op's body or
    condition (transitively through ``to_apply=`` / ``calls=``) — the HLO
    regions that execute once PER LOOP ITERATION. The FSDP seam contract
    asserts its full-parameter all-gathers are NOT in here: gather once
    before the microbatch loop, not once per microbatch."""
    regions = _hlo_regions(hlo_text)
    roots = {m.group(1) for m in
             re.finditer(r"(?:body|condition)=%?([\w.\-]+)", hlo_text)}
    seen: Set[str] = set()
    stack = list(roots)
    while stack:
        name = stack.pop()
        span = regions.get(name)
        if name in seen or span is None:
            continue
        seen.add(name)
        for m in re.finditer(r"(?:to_apply|calls)=%?([\w.\-]+)",
                             hlo_text[span[0]:span[1]]):
            stack.append(m.group(1))
    return seen


def _group_size(line_rest: str) -> int:
    m = _GROUPS_IOTA_RE.search(line_rest)
    if m:
        return int(m.group(2))            # [n_groups, group_size]<=[total]
    m = _GROUPS_BRACE_RE.search(line_rest)
    if m:
        return m.group(1).count(",") + 1
    return 1

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of one HLO shape string like 'bf16[128,1024]{1,0}' or a
    tuple '(f32[2,4], u32[])'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_PARAM_RE = re.compile(
    r"%?[\w.\-]+\s*=\s*(\w+)\[([\d,]*)\][^=\n]*?\bparameter\((\d+)\)")


def hlo_parameter_tensors(hlo_text: str) -> List[Dict[str, Any]]:
    """Every ``parameter`` declaration of the ENTRY computation: one
    record per tensor, ``{dtype, elems, bytes, index}``. Tuple-shaped
    entry parameters expand to one ``parameter`` line per leaf in the
    lowered text, so this is a per-LEAF inventory of what the compiled
    function actually TAKES — its resident at-rest buffers — which is
    what the quantized-decode contract asserts on: an int8-cache decode
    step must declare NO cache-sized f32 entry parameter (the narrow
    wire format, not a dequantized shadow, is what crosses the call
    boundary), while the fp32-cache control MUST declare one. Fusion /
    while-body computations also spell their operands as ``parameter``
    lines — those are transient values, not resident buffers, and are
    excluded by scoping the scan to the ENTRY region."""
    m_entry = re.search(r"^ENTRY\b.*\{", hlo_text, re.MULTILINE)
    if m_entry:
        m_end = re.search(r"^\}", hlo_text[m_entry.end():], re.MULTILINE)
        end = (m_entry.end() + m_end.start()) if m_end else len(hlo_text)
        hlo_text = hlo_text[m_entry.start():end]
    out: List[Dict[str, Any]] = []
    for m in _PARAM_RE.finditer(hlo_text):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        out.append({"dtype": dt, "elems": n,
                    "bytes": n * _DTYPE_BYTES.get(dt, 1),
                    "index": int(m.group(3))})
    return out


def collective_ops_from_hlo(hlo_text: str):
    """Per-OP collective inventory from optimized HLO text: one record per
    (component of a) collective result, ``{kind, dtype, elems, bytes,
    group}``. This is what the pod-local gradient tests assert on — e.g.
    "the compressed explicit path lowers NO fp32 all-reduce/all-gather
    larger than N elements" (tests/test_train_engine.py) — and what
    benchmarks/grad_compression.py reports next to the analytic
    ``reduction_wire_bytes`` accounting.

    Each record also carries its HLO computation ``region`` and an
    ``in_loop`` flag (the region is reachable from a ``while`` body —
    i.e. the op executes once per loop iteration), so contracts can
    forbid collectives specifically inside loop bodies."""
    regions = _hlo_regions(hlo_text)
    loop_comps = while_loop_computations(hlo_text)
    spans = sorted((s, e, name) for name, (s, e) in regions.items())

    def region_at(pos: int) -> Optional[str]:
        name = None
        for s, _e, n in spans:
            if s <= pos:
                name = n
            else:
                break
        return name

    ops = []
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        shape_str, kind, rest = m.group(1), m.group(2), m.group(3)
        kind = kind.replace("-start", "")
        g = max(_group_size(rest), 1)
        region = region_at(m.start())
        for sm in _SHAPE_RE.finditer(shape_str):
            dt, dims = sm.group(1), sm.group(2)
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            if dims:
                for d in dims.split(","):
                    if d:
                        n *= int(d)
            ops.append({"kind": kind, "dtype": dt, "elems": n,
                        "bytes": n * _DTYPE_BYTES[dt], "group": g,
                        "region": region,
                        "in_loop": region in loop_comps})
    return ops


def ring_wire_bytes(op: Dict[str, Any]) -> float:
    """Per-device wire bytes for ONE collective-op record (ring-algorithm
    accounting; group size g from the op's replica_groups):

      all-gather         : bytes * (g-1)/g      (bytes = gathered tensor)
      all-reduce         : 2 * bytes * (g-1)/g
      reduce-scatter     : bytes * (g-1)        (bytes = 1/g of input)
      all-to-all         : bytes * (g-1)/g
      collective-permute : bytes
    """
    g = max(op["group"], 1)
    if op["kind"] == "all-reduce":
        return 2 * op["bytes"] * (g - 1) / g
    if op["kind"] == "reduce-scatter":
        return op["bytes"] * (g - 1)
    if op["kind"] == "collective-permute":
        return float(op["bytes"])
    return op["bytes"] * (g - 1) / g          # all-gather, all-to-all


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Per-chip WIRE bytes per collective kind from the optimized HLO
    (``ring_wire_bytes`` accounting summed over the op inventory)."""
    out: Dict[str, int] = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        shape_str, kind, rest = m.group(1), m.group(2), m.group(3)
        kind = kind.replace("-start", "")
        op = {"kind": kind, "bytes": _shape_bytes(shape_str),
              "group": max(_group_size(rest), 1)}
        out[kind] = out.get(kind, 0) + int(ring_wire_bytes(op))
    return out


# ---------------------------------------------------------------------------
# declarative contract clauses
# ---------------------------------------------------------------------------

def _as_lengths(spec: Union[int, Iterable[int]]) -> Set[int]:
    if isinstance(spec, int):
        return {int(spec)}
    return {int(s) for s in spec}


def check_jaxpr_loops(fn, args: Sequence[Any], *,
                      forbid_lengths: Union[int, Iterable[int]] = (),
                      forbid_unbounded: bool = True,
                      ) -> "tuple[Set[int], List[Violation]]":
    """Loop clause: trace ``fn(*args)`` and flag forbidden scan trip
    counts. ``forbid_lengths`` is one length (typically the sequence
    length T) or an iterable; ``forbid_unbounded`` also flags
    ``lax.while_loop``s (recorded as length -1 — data-dependent trip
    counts can hide a sequential sweep from the length check).
    Returns ``(all observed lengths, violations)``."""
    lens = sequential_loop_lengths(fn, *args)
    bad = _as_lengths(forbid_lengths)
    violations = [
        Violation("sequential-loop",
                  f"jaxpr contains a sequential loop of forbidden length {L}",
                  {"length": L, "observed_lengths": sorted(lens)})
        for L in sorted(bad & lens)]
    if forbid_unbounded and -1 in lens:
        violations.append(Violation(
            "unbounded-loop",
            "jaxpr contains an unbounded while_loop (length -1)",
            {"observed_lengths": sorted(lens)}))
    return lens, violations


def _op_matches(op: Dict[str, Any], spec: Dict[str, Any]) -> bool:
    """True when ``op`` (a collective_ops_from_hlo record) matches every
    constraint in ``spec``: {kind?, dtype?, min_elems?, min_bytes?,
    min_group?, in_loop?}."""
    if "kind" in spec and op["kind"] != spec["kind"]:
        return False
    if "in_loop" in spec and bool(op.get("in_loop")) != bool(spec["in_loop"]):
        return False
    if "dtype" in spec and op["dtype"] != spec["dtype"]:
        return False
    if "min_elems" in spec and op["elems"] <= spec["min_elems"]:
        return False
    if "min_bytes" in spec and op["bytes"] <= spec["min_bytes"]:
        return False
    if "min_group" in spec and op["group"] < spec["min_group"]:
        return False
    return True


def check_hlo_collectives(hlo_text: str, *,
                          forbid: Optional[Sequence[Dict[str, Any]]] = None,
                          max_wire_bytes: Optional[Union[int, Dict[str, int]]]
                          = None,
                          ) -> "tuple[List[Dict[str, Any]], List[Violation]]":
    """Collective clause, on ALREADY-COMPILED optimized HLO text.

    ``forbid`` is a list of match specs — an op violates when it matches
    every key of any spec. E.g. the pod-local gradient contract
    "no gradient-sized fp32 collective" is
    ``forbid=[{"dtype": "f32", "min_elems": 16384}]``.

    ``max_wire_bytes`` caps ring-accounted wire bytes: an int caps the
    total across kinds, a dict caps per kind (``{"all-reduce": 0}``
    forbids all-reduce entirely).

    Returns ``(op inventory, violations)``.
    """
    ops = collective_ops_from_hlo(hlo_text)
    violations: List[Violation] = []
    for spec in (forbid or []):
        for op in ops:
            if _op_matches(op, spec):
                violations.append(Violation(
                    "forbidden-collective",
                    f"HLO lowers a forbidden collective: {op['kind']} "
                    f"{op['dtype']}[{op['elems']}] group={op['group']}",
                    {"op": op, "spec": spec}))
    if max_wire_bytes is not None:
        wire: Dict[str, int] = {}
        for op in ops:
            wire[op["kind"]] = wire.get(op["kind"], 0) \
                + int(ring_wire_bytes(op))
        if isinstance(max_wire_bytes, dict):
            for kind, cap in max_wire_bytes.items():
                got = wire.get(kind, 0)
                if got > cap:
                    violations.append(Violation(
                        "collective-bytes",
                        f"{kind} wire bytes {got} exceed cap {cap}",
                        {"kind": kind, "wire_bytes": got, "cap": cap}))
        else:
            total = sum(wire.values())
            if total > max_wire_bytes:
                violations.append(Violation(
                    "collective-bytes",
                    f"total collective wire bytes {total} exceed cap "
                    f"{max_wire_bytes}",
                    {"wire_bytes": total, "cap": int(max_wire_bytes),
                     "per_kind": wire}))
    return ops, violations


def check_lowering(fn: Callable, args: Sequence[Any], *,
                   forbid_sequential_loop_over:
                   Optional[Union[int, Iterable[int]]] = None,
                   allow_unbounded_loops: bool = False,
                   forbid_collectives:
                   Optional[Sequence[Dict[str, Any]]] = None,
                   max_collective_bytes:
                   Optional[Union[int, Dict[str, int]]] = None,
                   hlo_text: Optional[str] = None,
                   ) -> LoweringReport:
    """Evaluate a declarative lowering contract against ``fn(*args)``.

    Clauses (any subset; only the requested artifacts are produced):

      forbid_sequential_loop_over=T   no ``lax.scan`` of trip count T (or
                                      any length in an iterable) in the
                                      jaxpr; unbounded while_loops also
                                      violate unless
                                      ``allow_unbounded_loops=True``.
      forbid_collectives=[spec, ...]  no collective op in the OPTIMIZED
                                      HLO matching a spec ({kind?, dtype?,
                                      min_elems?, min_bytes?, min_group?}).
      max_collective_bytes=N | {kind: N}
                                      ring-accounted wire-byte cap.

    The collective clauses need compiled HLO: ``fn`` is jitted and
    compiled unless ``hlo_text`` is supplied (pass it when the caller
    already holds ``compiled.as_text()`` — e.g. a train step built under a
    mesh context). Lowering failures surface as a ``lowering-error``
    violation rather than raising, so contract suites can report them.

    Returns a :class:`LoweringReport`; callers assert ``report.ok`` and
    get structured ``report.violations`` on failure.
    """
    violations: List[Violation] = []
    lens: Optional[Set[int]] = None
    ops: Optional[List[Dict[str, Any]]] = None
    wire: Optional[Dict[str, int]] = None

    if forbid_sequential_loop_over is not None:
        try:
            lens, loop_v = check_jaxpr_loops(
                fn, args, forbid_lengths=forbid_sequential_loop_over,
                forbid_unbounded=not allow_unbounded_loops)
            violations += loop_v
        except Exception as e:                    # pragma: no cover - env
            violations.append(Violation(
                "lowering-error", f"jaxpr tracing failed: {e!r}",
                {"stage": "trace"}))

    if forbid_collectives is not None or max_collective_bytes is not None:
        try:
            if hlo_text is None:
                import jax
                hlo_text = jax.jit(fn).lower(*args).compile().as_text()
            wire = collective_bytes_from_hlo(hlo_text)
            ops, coll_v = check_hlo_collectives(
                hlo_text, forbid=forbid_collectives,
                max_wire_bytes=max_collective_bytes)
            violations += coll_v
        except Exception as e:
            violations.append(Violation(
                "lowering-error", f"compilation failed: {e!r}",
                {"stage": "compile"}))

    return LoweringReport(violations=violations, loop_lengths=lens,
                          collectives=ops, collective_wire_bytes=wire)


# ---------------------------------------------------------------------------
# kernel HBM-stream budget (the benchmarks/kernels.py acceptance criterion)
# ---------------------------------------------------------------------------

def check_stream_budget(n_iters: int, impl: str, *,
                        baseline: Optional[str] = None,
                        min_ratio: Optional[float] = None,
                        max_streams: Optional[float] = None,
                        ) -> LoweringReport:
    """HBM-stream clause over the ANALYTIC kernel-schedule roofline
    (``kernels.autotune.solver_hbm_streams``): how many (T, D)-sized HBM
    streams a K-iteration solve moves.

    ``max_streams`` caps ``impl``'s stream count; ``min_ratio`` (with
    ``baseline``) demands ``streams(baseline) / streams(impl) >=
    min_ratio`` — the megakernel's interpret-host acceptance bar
    (>= 2.5x fewer streams than the per-iteration kernel). The counts are
    schedule properties, hardware-independent; wall-clock is the measured
    companion signal recorded next to this check in BENCH_kernels.json.
    """
    from repro.kernels.autotune import solver_hbm_streams

    streams = solver_hbm_streams(n_iters, impl)
    detail: Dict[str, Any] = {"impl": impl, "n_iters": n_iters,
                              "streams": streams}
    violations: List[Violation] = []
    if max_streams is not None and streams > max_streams:
        violations.append(Violation(
            "stream-budget",
            f"{impl} moves {streams:.1f} (T,D) HBM streams "
            f"> budget {max_streams}",
            dict(detail, budget=max_streams)))
    if min_ratio is not None:
        if baseline is None:
            raise ValueError("min_ratio requires a baseline impl")
        base = solver_hbm_streams(n_iters, baseline)
        ratio = base / max(streams, 1e-12)
        detail.update(baseline=baseline, baseline_streams=base, ratio=ratio)
        if ratio < min_ratio:
            violations.append(Violation(
                "stream-budget",
                f"stream ratio {baseline}/{impl} = {ratio:.2f} "
                f"< required {min_ratio}",
                dict(detail, required_ratio=min_ratio)))
    return LoweringReport(violations=violations)

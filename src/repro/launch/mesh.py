"""Production mesh construction.

A FUNCTION (not module-level constant) so importing never touches jax device
state. The dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512
BEFORE any jax import; smoke tests and benchmarks see the real single CPU
device.

Topology mapping (TPU v5e):
  single-pod : (16, 16) ("data", "model") — 256 chips, 2D ICI torus; "model"
               placed innermost so TP collectives ride the fastest ICI loop.
  multi-pod  : (2, 16, 16) ("pod", "data", "model") — 512 chips; the "pod"
               axis crosses DCN and carries only DP gradient all-reduce
               (optionally int8-compressed).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def parse_mesh_spec(spec: str) -> Mesh:
    """THE ``--mesh`` grammar, shared by every launcher (train / serve /
    dryrun): "x"-separated dim sizes, axis names assigned right-aligned
    from the canonical ("pod", "data", "model") order.

        "8"      -> (8,)       ("model",)
        "1x4"    -> (1, 4)     ("data", "model")
        "2x16x16"-> (2,16,16)  ("pod", "data", "model")

    The pod axis only exists when three dims are given — exactly the
    spelling that engages the cross-pod explicit-gradient engine."""
    try:
        dims = tuple(int(x) for x in spec.lower().split("x"))
    except ValueError:
        raise ValueError(f"--mesh {spec!r}: expected INTxINT[xINT]")
    if not 1 <= len(dims) <= 3 or any(d < 1 for d in dims):
        raise ValueError(f"--mesh {spec!r}: 1-3 positive dims required "
                         "(DATAxMODEL or PODxDATAxMODEL)")
    axes = ("pod", "data", "model")[-len(dims):]
    return jax.make_mesh(dims, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over however many devices exist (tests / examples)."""
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_chip_count(mesh: Mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n

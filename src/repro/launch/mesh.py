"""Production mesh construction.

A FUNCTION (not module-level constant) so importing never touches jax device
state. The dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512
BEFORE any jax import; smoke tests and benchmarks see the real single CPU
device.

Topology mapping (TPU v5e):
  single-pod : (16, 16) ("data", "model") — 256 chips, 2D ICI torus; "model"
               placed innermost so TP collectives ride the fastest ICI loop.
  multi-pod  : (2, 16, 16) ("pod", "data", "model") — 512 chips; the "pod"
               axis crosses DCN and carries only DP gradient all-reduce
               (optionally int8-compressed).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over however many devices exist (tests / examples)."""
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_chip_count(mesh: Mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n

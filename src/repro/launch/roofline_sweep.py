import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Roofline sweep driver: exact per-step FLOPs and collective bytes for the
FULL-depth models via depth extrapolation.

Method: cost_analysis() counts while-loop bodies once, so the production
scan-over-layers lowering undercounts depth-linear work. Instead we lower
the model in exact-HLO mode (no interior loops: unrolled layers, one-block
attention, unchunked loss/scans) at TWO reduced depths g1 < g2 layer-groups
and extrapolate linearly to the full depth:

    per_layer = (X(g2) - X(g1)) / (g2 - g1) / group_size
    X(full)   = X(g2) + per_layer * (L_full - L(g2))

Exactness: the layer stack is homogeneous at group granularity (the whole
point of the group plan), embeddings/loss/optimizer are depth-independent
(land in the intercept), and SPMD partitioning is per-layer identical —
so linearity in depth holds exactly for FLOPs and collective bytes.

The production-config lowering (scan/chunked) is ALSO compiled per cell —
that is the runnability proof + memory_analysis (HBM fit) source. Records
merge both.
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax

from repro.config import HW, SHAPES
from repro.configs import get_config, list_archs
from repro.launch import specs as specs_lib
from repro.launch.dryrun import lower_cell
from repro.models.lm import layer_plan


def reduced_depth_overrides(arch, n_groups: int) -> Dict[str, Any]:
    """ArchConfig overrides that keep group structure + tail but reduce the
    number of scan groups to ``n_groups``."""
    plan = layer_plan(arch) if arch.family != "audio" else None
    ov: Dict[str, Any] = {"exact_hlo": True, "scan_layers": False}
    if arch.family == "audio":
        ov["n_layers"] = n_groups
        ov["enc_layers"] = n_groups
        return ov
    gsize = len(plan.group)
    ov["n_layers"] = n_groups * gsize + len(plan.tail)
    return ov


def extrapolate(rec1: Dict, rec2: Dict, g1: int, g2: int, g_full: int,
                keys=("hlo_flops_per_chip", "collective_bytes_per_chip")
                ) -> Dict[str, float]:
    out = {}
    for k in keys:
        x1, x2 = rec1[k], rec2[k]
        per_group = (x2 - x1) / (g2 - g1)
        out[k] = x2 + per_group * (g_full - g2)
        out[k + "_per_group"] = per_group
    # collective breakdown extrapolated per kind
    bd = {}
    kinds = set(rec1["collective_breakdown"]) | set(rec2["collective_breakdown"])
    for kind in kinds:
        x1 = rec1["collective_breakdown"].get(kind, 0)
        x2 = rec2["collective_breakdown"].get(kind, 0)
        bd[kind] = max(0.0, x2 + (x2 - x1) / (g2 - g1) * (g_full - g2))
    out["collective_breakdown"] = bd
    return out


def roofline_cell(arch_name: str, shape_name: str,
                  extra_overrides: Optional[Dict[str, Any]] = None,
                  g_pair: Tuple[int, int] = (1, 2),
                  production: bool = True) -> Dict[str, Any]:
    from repro.launch.dryrun import apply_overrides
    arch = get_config(arch_name)
    if extra_overrides:
        # train_* keys are routed to TrainConfig by lower_cell; only the
        # arch-level keys participate in the local ArchConfig replace
        arch_only = {k: v for k, v in extra_overrides.items()
                     if not k.startswith("train_")}
        arch = apply_overrides(arch, arch_only)
    shape = SHAPES[shape_name]
    ok, why = specs_lib.cell_is_applicable(arch, shape)
    if not ok:
        return {"arch": arch.name, "shape": shape_name, "status": "skipped",
                "reason": why}

    plan = layer_plan(arch) if arch.family != "audio" else None
    g_full = (arch.n_layers if arch.family == "audio"
              else plan.n_groups)
    g1, g2 = g_pair
    base_ov = dict(extra_overrides or {})

    ov1 = {**base_ov, **reduced_depth_overrides(arch, g1)}
    ov2 = {**base_ov, **reduced_depth_overrides(arch, g2)}
    rec1 = lower_cell(arch_name, shape_name, arch_overrides=ov1)
    rec2 = lower_cell(arch_name, shape_name, arch_overrides=ov2)
    if rec1.get("status") != "ok" or rec2.get("status") != "ok":
        return {"arch": arch.name, "shape": shape_name, "status": "error",
                "error": f"reduced-depth lowering failed: {rec1} / {rec2}"}

    ext = extrapolate(rec1, rec2, g1, g2, g_full)
    flops = ext["hlo_flops_per_chip"]
    coll = ext["collective_bytes_per_chip"]
    chips = rec2["chips"]

    # full-arch analytic terms (the reduced-depth records carry reduced-L
    # params; never use theirs)
    from repro.roofline import analytic_hbm_bytes_per_chip, model_flops
    amem = analytic_hbm_bytes_per_chip(arch, shape, chips)
    mf = model_flops(arch, shape)

    rec: Dict[str, Any] = {
        "arch": arch.name, "shape": shape_name, "status": "ok",
        "chips": chips, "mesh": rec2["mesh"],
        "hlo_flops_per_chip": flops,
        "collective_bytes_per_chip": coll,
        "collective_breakdown": ext["collective_breakdown"],
        "model_flops": mf,
        "analytic_hbm_bytes_per_chip": amem["total"],
        "analytic_hbm_breakdown": amem,
        "extrapolation": {"g1": g1, "g2": g2, "g_full": g_full,
                          "flops_g1": rec1["hlo_flops_per_chip"],
                          "flops_g2": rec2["hlo_flops_per_chip"],
                          "coll_g1": rec1["collective_bytes_per_chip"],
                          "coll_g2": rec2["collective_bytes_per_chip"]},
    }
    rec["compute_s"] = flops / HW.peak_flops_bf16
    rec["memory_s"] = rec["analytic_hbm_bytes_per_chip"] / HW.hbm_bw
    rec["collective_s"] = coll / HW.ici_bw
    dom = max((("compute", rec["compute_s"]), ("memory", rec["memory_s"]),
               ("collective", rec["collective_s"])), key=lambda kv: kv[1])
    rec["dominant"] = dom[0]
    rec["roofline_bound_s"] = dom[1]
    rec["useful_flops_ratio"] = rec["model_flops"] / max(flops * chips, 1.0)
    rec["roofline_fraction"] = (
        rec["model_flops"] / HW.peak_flops_bf16 / chips
        / max(rec["roofline_bound_s"], 1e-12))

    if production:
        # production-config compile: runnability proof + HBM-fit numbers
        prod = lower_cell(arch_name, shape_name,
                          arch_overrides=base_ov or None)
        if prod.get("status") == "ok":
            rec["production"] = {
                k: prod[k] for k in ("compile_s", "argument_bytes",
                                     "output_bytes", "temp_bytes",
                                     "peak_bytes")
                if k in prod}
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-production", action="store_true")
    ap.add_argument("--override", type=str, default=None)
    ap.add_argument("--variant", type=str, default="baseline",
                    help="label recorded with the result (perf iterations)")
    ap.add_argument("--out", type=str, default=None)
    args = ap.parse_args()

    cells = ([(a, s) for a in list_archs() for s in SHAPES]
             if args.all else [(args.arch.replace("-", "_"), args.shape)])
    overrides = json.loads(args.override) if args.override else None

    failures = 0
    for a, s in cells:
        t0 = time.time()
        try:
            rec = roofline_cell(a, s, extra_overrides=overrides,
                                production=not args.no_production)
        except Exception as e:
            traceback.print_exc()
            rec = {"arch": a, "shape": s, "status": "error",
                   "error": f"{type(e).__name__}: {e}"}
            failures += 1
        rec["variant"] = args.variant
        rec["overrides"] = overrides
        rec["wall_s"] = round(time.time() - t0, 1)
        print(json.dumps(rec))
        sys.stdout.flush()
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()

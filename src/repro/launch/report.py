"""Render EXPERIMENTS.md tables from the results/*.jsonl sweep records.

    PYTHONPATH=src python -m repro.launch.report [--results results/]
"""
import argparse
import json
import os
from collections import defaultdict
from typing import Dict, List


def load(path: str, dedupe: bool = True) -> List[Dict]:
    if not os.path.exists(path):
        return []
    out, recs = [], {}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            r = json.loads(line)
            out.append(r)
            recs[(r.get("arch", "?").replace("_", "-"), r.get("shape"))] = r
    return list(recs.values()) if dedupe else out


def fmt_bytes(x) -> str:
    if x is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(x) < 1024 or unit == "TB":
            return f"{x:.1f}{unit}"
        x /= 1024
    return f"{x:.1f}TB"


def fmt_s(x) -> str:
    if x is None:
        return "-"
    if x >= 0.1:
        return f"{x:.2f}s"
    if x >= 1e-4:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.0f}us"


def dryrun_table(recs: List[Dict], fixed: List[Dict]) -> str:
    merged = {(r["arch"].replace("_", "-"), r["shape"]): r for r in recs}
    for r in fixed:
        merged[(r["arch"].replace("_", "-"), r["shape"])] = r
    lines = ["| arch | shape | status | peak bytes/dev | compile | collectives/chip |",
             "|---|---|---|---|---|---|"]
    for (a, s), r in sorted(merged.items()):
        if r["status"] == "ok":
            lines.append(
                f"| {a} | {s} | ok | {fmt_bytes(r.get('peak_bytes'))} | "
                f"{r.get('compile_s', 0):.0f}s | "
                f"{fmt_bytes(r.get('collective_bytes_per_chip'))} |")
        elif r["status"] == "skipped":
            lines.append(f"| {a} | {s} | SKIP (full-attention @512k) | - | - | - |")
        else:
            lines.append(f"| {a} | {s} | **{r['status']}** | - | - | - |")
    return "\n".join(lines)


def roofline_table(recs: List[Dict]) -> str:
    lines = ["| arch | shape | compute | memory | collective | dominant | "
             "useful/HLO | roofline frac |",
             "|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r["status"] != "ok":
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
            f"**{r['dominant']}** | {r['useful_flops_ratio']:.2f} | "
            f"{r.get('roofline_fraction', 0):.4f} |")
    return "\n".join(lines)


def hillclimb_table(recs: List[Dict]) -> str:
    lines = ["| cell | variant | compute | memory | collective | dominant | "
             "roofline frac | bound |",
             "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("status") != "ok":
            lines.append(f"| {r.get('arch')}/{r.get('shape')} | "
                         f"{r.get('variant','?')} | - | - | - | ERROR | - | - |")
            continue
        lines.append(
            f"| {r['arch']}/{r['shape']} | {r.get('variant', 'baseline')} | "
            f"{fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} | "
            f"{fmt_s(r['collective_s'])} | {r['dominant']} | "
            f"{r.get('roofline_fraction', 0):.4f} | "
            f"{fmt_s(r.get('roofline_bound_s'))} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="results")
    args = ap.parse_args()
    R = args.results

    print("## §Dry-run — single-pod (16x16 = 256 chips)\n")
    print(dryrun_table(load(f"{R}/dryrun_single_pod.jsonl"),
                       load(f"{R}/dryrun_single_pod_fixed.jsonl")))
    print("\n## §Dry-run — multi-pod (2x16x16 = 512 chips)\n")
    print(dryrun_table(load(f"{R}/dryrun_multi_pod.jsonl"), []))
    print("\n## §Roofline — baseline (megatron strategy, per-chip, v5e "
          "constants)\n")
    print(roofline_table(load(f"{R}/roofline.jsonl")))
    print("\n## §Perf — hillclimb iterations\n")
    print(hillclimb_table(load(f"{R}/hillclimb.jsonl", dedupe=False)))


if __name__ == "__main__":
    main()

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent for every
(architecture x input shape x mesh) cell without hardware.

For each cell:
    with mesh:
        lowered  = jax.jit(step, in_shardings=..., out_shardings=...)\
                      .lower(*abstract_inputs)
        compiled = lowered.compile()
        memory_analysis / cost_analysis / collective byte parse

``train``/``prefill`` shapes lower train_step; ``decode`` shapes lower
serve_step (one token against seq_len caches). Everything is abstract
(jax.eval_shape + ShapeDtypeStruct) — no arrays are ever allocated at full
size on this CPU host.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch starcoder2-3b \
        --shape train_4k [--multi-pod] [--out results.jsonl]
    PYTHONPATH=src python -m repro.launch.dryrun --all   # full 40-cell sweep
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax

from repro.config import SHAPES, TrainConfig
from repro.configs import get_config, list_archs
from repro.distributed import sharding as shd
from repro.launch import specs as specs_lib
from repro.launch.mesh import (make_production_mesh, mesh_chip_count,
                               parse_mesh_spec)
from repro.models import build_model
from repro.roofline import analyze_compiled   # collective parse + 3 terms
from repro.train.state import abstract_train_state
from repro.train.step import jit_step


def _abstract_params(model):
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def apply_overrides(arch, ov: Dict[str, Any]):
    """dataclasses.replace with nested SSMConfig support: keys prefixed
    ``ssm_`` update the mixer config (e.g. {"ssm_kind": "lrc"})."""
    from repro.config import SSMConfig
    ov = dict(ov)
    ssm_ov = {k[4:]: ov.pop(k) for k in list(ov) if k.startswith("ssm_")}
    moe_ov = {k[4:]: ov.pop(k) for k in list(ov) if k.startswith("moe_")}
    if ssm_ov:
        base = arch.ssm or SSMConfig()
        arch = dataclasses.replace(arch, ssm=dataclasses.replace(base,
                                                                 **ssm_ov))
    if moe_ov and arch.moe is not None:
        arch = dataclasses.replace(
            arch, moe=dataclasses.replace(arch.moe, **moe_ov))
    return dataclasses.replace(arch, **ov) if ov else arch


def lower_cell(arch_name: str, shape_name: str, multi_pod: bool = False,
               arch_overrides: Optional[Dict[str, Any]] = None,
               tcfg: Optional[TrainConfig] = None,
               mesh_spec: Optional[str] = None,
               policy: Optional[shd.ShardingPolicy] = None
               ) -> Dict[str, Any]:
    """Lower + compile one cell; return the roofline record.

    ``mesh_spec`` overrides the production mesh with the unified --mesh
    grammar; ``policy`` routes the cell through a ShardingPolicy (e.g.
    params=tp_fsdp,reduce=explicit lowers the explicit-seam TP/FSDP
    train step instead of the gspmd baseline)."""
    arch = get_config(arch_name)
    if arch_overrides:
        arch_overrides = dict(arch_overrides)
        # reserved keys routed to TrainConfig
        tkeys = {k: arch_overrides.pop(k) for k in list(arch_overrides)
                 if k.startswith("train_")}
        if tkeys and tcfg is None:
            tcfg = TrainConfig(**{k[6:]: v for k, v in tkeys.items()})
        arch = apply_overrides(arch, arch_overrides)
    shape = SHAPES[shape_name]
    ok, why = specs_lib.cell_is_applicable(arch, shape)
    if not ok:
        return {"arch": arch.name, "shape": shape_name, "status": "skipped",
                "reason": why}

    mesh = (parse_mesh_spec(mesh_spec) if mesh_spec
            else make_production_mesh(multi_pod=multi_pod))
    chips = mesh_chip_count(mesh)
    # MoE production dispatch per config (einsum | gather).
    model = build_model(arch,
                        moe_path=arch.moe.dispatch if arch.moe else "dense")
    tcfg = tcfg or TrainConfig(microbatch=0)
    if policy is not None:
        tcfg = policy.apply_to(tcfg)
    t0 = time.time()

    strategy = (policy.strategy if policy is not None
                and policy.strategy != "megatron" else arch.sharding_strategy)
    with shd.use_mesh(mesh), shd.use_strategy(strategy):
        params_s = _abstract_params(model)

        if shape.kind in ("train", "prefill"):
            batch_s = specs_lib.train_input_specs(arch, shape)
            state_s = abstract_train_state(params_s, tcfg, mesh)
            jitted = jit_step(model, "train", mesh, tcfg=tcfg,
                              state_like=state_s, batch_like=batch_s)
            lowered = jitted.lower(state_s, batch_s)
        else:  # decode
            cache_s = jax.eval_shape(
                lambda p: model.init_cache(p, shape.global_batch,
                                           shape.seq_len), params_s)
            jitted = jit_step(model, "serve", mesh, params_like=params_s,
                              cache_like=cache_s,
                              batch_size=shape.global_batch)
            tok_s = specs_lib.decode_token_specs(arch, shape)
            lowered = jitted.lower(params_s, tok_s, cache_s)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    record = analyze_compiled(arch, shape, mesh, lowered, compiled)
    record.update({
        "status": "ok", "multi_pod": multi_pod, "chips": chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "grad_reduce": tcfg.grad_reduce,
        "param_sharding": tcfg.param_sharding,
    })
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None,
                    choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="full sweep: every (arch x shape), single-pod")
    ap.add_argument("--out", type=str, default=None,
                    help="append JSONL records here")
    ap.add_argument("--override", type=str, default=None,
                    help="JSON dict of ArchConfig overrides (perf iterations)")
    ap.add_argument("--mesh", type=str, default=None,
                    help="unified mesh grammar (e.g. 2x16x16) overriding "
                         "the production mesh")
    ap.add_argument("--policy", type=str, default=None,
                    help="unified ShardingPolicy spelling — e.g. "
                         "params=tp_fsdp,reduce=explicit lowers the "
                         "explicit-seam TP/FSDP cell")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in list_archs():
            for s in SHAPES:
                cells.append((a, s, False))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch.replace("-", "_"), args.shape,
                      args.multi_pod))

    overrides = json.loads(args.override) if args.override else None
    policy = (shd.ShardingPolicy.from_string(args.policy)
              if args.policy else None)
    failures = 0
    for arch_name, shape_name, mp in cells:
        try:
            rec = lower_cell(arch_name, shape_name, multi_pod=mp,
                             arch_overrides=overrides, mesh_spec=args.mesh,
                             policy=policy)
        except Exception as e:  # a failure here is a bug in the system
            traceback.print_exc()
            rec = {"arch": arch_name, "shape": shape_name, "status": "error",
                   "multi_pod": mp, "error": f"{type(e).__name__}: {e}"}
            failures += 1
        print(json.dumps(rec))
        sys.stdout.flush()
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()

"""Production serving launcher: the continuous-batching engine over a mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b \
        --reduced --requests 16 --slots 8 --prefill-chunk 32 --mesh 1x1

``--mesh DxM`` (data x model, the serve-strategy spelling: weights TP over
"model", slots/caches over "data") or ``--mesh PxDxM`` to include a pod
axis. Prints tokens/s plus p50/p99 per-token decode latency — the same
numbers ``benchmarks/serve.py`` records as ``BENCH_serve.json``.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced
from repro.distributed import sharding as shd
from repro.launch.mesh import parse_mesh_spec
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine


def main():
    """Parse CLI flags, stand up the engine, serve synthetic requests."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prefill-chunk", type=int, default=32)
    ap.add_argument("--mesh", default="1x1",
                    help="DxM or PxDxM mesh spelling (e.g. 1x4, 2x8x2)")
    ap.add_argument("--policy", default=None,
                    help="unified ShardingPolicy spelling (key=value,"
                         "comma-separated) — default: strategy=serve")
    ap.add_argument("--stream", action="store_true",
                    help="print tokens as the engine streams them")
    args = ap.parse_args()

    name = args.arch.replace("-", "_")
    arch = get_reduced(name) if args.reduced else get_config(name)
    arch = dataclasses.replace(arch, sharding_strategy="serve")
    model = build_model(arch)
    mesh = parse_mesh_spec(args.mesh)
    if args.policy:
        policy = shd.ShardingPolicy.from_string(args.policy).with_mesh(mesh)
    else:
        policy = shd.ShardingPolicy(strategy="serve").with_mesh(mesh)

    stream = None
    if args.stream:
        stream = lambda uid, tok, done: print(
            f"  [stream] req {uid} -> {tok}{' <done>' if done else ''}")

    with shd.use_policy(policy):
        params = model.init(jax.random.PRNGKey(0))
        engine = ServeEngine(model, params, batch_slots=args.slots,
                             max_seq=args.max_seq,
                             prefill_chunk=args.prefill_chunk, mesh=mesh,
                             policy=policy)
        rng = np.random.default_rng(0)
        reqs = [Request(uid=i,
                        prompt=rng.integers(0, arch.vocab,
                                            size=args.prompt_len)
                        .astype(np.int32),
                        max_new_tokens=args.max_new, on_token=stream)
                for i in range(args.requests)]
        for r in reqs:
            engine.submit(r)
        t0 = time.perf_counter()
        engine.run_until_drained()
        wall = time.perf_counter() - t0

    toks = sum(len(r.out_tokens) for r in reqs)
    lat = engine.latency_percentiles()
    print(f"[serve] {arch.name}: {sum(r.done for r in reqs)}/{len(reqs)} "
          f"requests, {toks} tokens, {toks/max(wall,1e-9):.1f} tok/s, "
          f"{args.slots} slots, chunk={args.prefill_chunk}, "
          f"mesh={dict(mesh.shape)}")
    if lat:
        print(f"[serve] per-token latency: "
              f"p50={lat.get('decode_p50_s', 0)*1e3:.2f}ms "
              f"p99={lat.get('decode_p99_s', 0)*1e3:.2f}ms "
              f"(prefill p50={lat.get('prefill_p50_s', 0)*1e3:.2f}ms)")


if __name__ == "__main__":
    main()

"""Production serving launcher: the continuous-batching engine over a mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b \
        --reduced --requests 16 --slots 8 --prefill-chunk 32 --mesh 1x1

``--mesh DxM`` (data x model, the serve-strategy spelling: weights TP over
"model", slots/caches over "data") or ``--mesh PxDxM`` to include a pod
axis. Prints tokens/s plus p50/p99 per-token decode latency — the same
numbers ``benchmarks/serve.py`` records as ``BENCH_serve.json``.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced
from repro.distributed import sharding as shd
from repro.launch.mesh import parse_mesh_spec
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine, SpecConfig
from repro.serve.scheduler import SLOConfig, SLOScheduler


def main():
    """Parse CLI flags, stand up the engine, serve synthetic requests."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prefill-chunk", type=int, default=32)
    ap.add_argument("--mesh", default="1x1",
                    help="DxM or PxDxM mesh spelling (e.g. 1x4, 2x8x2)")
    ap.add_argument("--policy", default=None,
                    help="unified ShardingPolicy spelling (key=value,"
                         "comma-separated) — default: strategy=serve")
    ap.add_argument("--stream", action="store_true",
                    help="print tokens as the engine streams them")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative verify-window length (0 = plain "
                         "single-token decode)")
    ap.add_argument("--draft", default="reuse", choices=["reuse", "solve"],
                    help="draft source: reuse verified leftovers (free) "
                         "or an early-exit truncated-Newton forward")
    ap.add_argument("--draft-iters", type=int, default=0,
                    help="Newton depth of the solve-draft forward "
                         "(default: arch.ssm.draft_iters)")
    ap.add_argument("--slo-ms", type=float, default=0.0,
                    help="decode p50 SLO in ms — pauses admission while "
                         "decode is over target (0 = always admit)")
    ap.add_argument("--prefill-budget", type=int, default=1,
                    help="max batched admission launches per tick")
    ap.add_argument("--precision", default=None,
                    help="serve PrecisionPolicy spec (docs/precision.md): "
                         "a preset (fp32|bf16|int8|fp8) or key=value "
                         "overrides, e.g. weights=int8,cache=fp8,"
                         "kernel_io=bf16. Quantized policies run "
                         "single-device (no mesh composition yet).")
    args = ap.parse_args()

    name = args.arch.replace("-", "_")
    arch = get_reduced(name) if args.reduced else get_config(name)
    arch = dataclasses.replace(arch, sharding_strategy="serve")
    mesh = parse_mesh_spec(args.mesh)

    precision = None
    if args.precision:
        from repro.distributed.precision import PrecisionPolicy
        precision = PrecisionPolicy.from_string(args.precision)
        if arch.ssm is not None and arch.ssm.kind == "lrc":
            # prefill's fused Pallas tiers stream narrow when the policy
            # asks for it (state_quant is injected by the engine itself)
            arch = dataclasses.replace(arch, ssm=dataclasses.replace(
                arch.ssm, kernel_io=precision.kernel_io_dtype))
        if ((precision.quantizes_weights or precision.quantizes_cache)
                and mesh.size > 1):
            ap.error("--precision with int8/fp8/bf16 weights or cache does "
                     "not compose with a multi-device mesh yet; use "
                     "--mesh 1x1")

    model = build_model(arch)
    if args.policy:
        policy = shd.ShardingPolicy.from_string(args.policy).with_mesh(mesh)
    else:
        policy = shd.ShardingPolicy(strategy="serve").with_mesh(mesh)
    quantized = precision is not None and (precision.quantizes_weights
                                           or precision.quantizes_cache)

    stream = None
    if args.stream:
        stream = lambda uid, tok, done: print(
            f"  [stream] req {uid} -> {tok}{' <done>' if done else ''}")

    spec = None
    if args.spec_k:
        di = args.draft_iters or getattr(arch.ssm, "draft_iters", 2)
        spec = SpecConfig(k=args.spec_k, draft=args.draft, draft_iters=di)

    with shd.use_policy(policy):
        params = model.init(jax.random.PRNGKey(0))
        engine = ServeEngine(model, params, batch_slots=args.slots,
                             max_seq=args.max_seq,
                             prefill_chunk=args.prefill_chunk,
                             mesh=None if quantized else mesh,
                             policy=None if quantized else policy,
                             spec=spec, precision=precision)
        sched = SLOScheduler(engine, SLOConfig(
            decode_slo_ms=args.slo_ms,
            prefill_budget=args.prefill_budget))
        rng = np.random.default_rng(0)
        reqs = [Request(uid=i,
                        prompt=rng.integers(0, arch.vocab,
                                            size=args.prompt_len)
                        .astype(np.int32),
                        max_new_tokens=args.max_new, on_token=stream)
                for i in range(args.requests)]
        for r in reqs:
            sched.submit(r)
        t0 = time.perf_counter()
        sched.run_until_drained()
        wall = time.perf_counter() - t0

    toks = sum(len(r.out_tokens) for r in reqs)
    stats = sched.stats()
    print(f"[serve] {arch.name}: {sum(r.done for r in reqs)}/{len(reqs)} "
          f"requests, {toks} tokens, {toks/max(wall,1e-9):.1f} tok/s, "
          f"{args.slots} slots, chunk={args.prefill_chunk}, "
          f"mesh={dict(mesh.shape)}")
    if precision is not None:
        print(f"[serve] precision: weights={precision.weights} "
              f"cache={precision.cache} kernel_io={precision.kernel_io} "
              f"accum={precision.accum} block={precision.block} — "
              f"state cache {engine.state_cache_bytes()/2**20:.2f} MiB "
              f"resident")
    if stats:
        print(f"[serve] per-token latency: "
              f"p50={stats.get('decode_p50_s', 0)*1e3:.2f}ms "
              f"p99={stats.get('decode_p99_s', 0)*1e3:.2f}ms "
              f"(prefill p50={stats.get('prefill_p50_s', 0)*1e3:.2f}ms)")
    if spec is not None:
        ss = engine.spec_stats
        print(f"[serve] speculative k={spec.k} ({spec.draft}): "
              f"accept_rate={stats.get('accept_rate', 0.0):.2f} "
              f"draft={ss['draft_tokens']} "
              f"accepted={ss['accepted_tokens']} "
              f"verify_calls={ss['verify_calls']} "
              f"emitted={ss['emitted_tokens']}")
    print(f"[serve] scheduler: "
          f"queue_depth p50={stats.get('queue_depth_p50', 0):.0f} "
          f"max={stats.get('queue_depth_max', 0):.0f}, "
          f"admit_wait p50={stats.get('admit_wait_p50_s', 0)*1e3:.1f}ms "
          f"p99={stats.get('admit_wait_p99_s', 0)*1e3:.1f}ms, "
          f"slo_ms={args.slo_ms or 'off'}")


if __name__ == "__main__":
    main()

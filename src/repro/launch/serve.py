"""Production serving launcher: continuous-batching engine over a mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b \
        --reduced --requests 8 --slots 4
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced
from repro.distributed import sharding as shd
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--mesh", default="1x1")
    args = ap.parse_args()

    name = args.arch.replace("-", "_")
    arch = get_reduced(name) if args.reduced else get_config(name)
    arch = dataclasses.replace(arch, sharding_strategy="serve")
    model = build_model(arch)
    d, m = (int(x) for x in args.mesh.split("x"))
    mesh = jax.make_mesh((d, m), ("data", "model"))

    with shd.use_mesh(mesh), shd.use_strategy("serve"):
        params = model.init(jax.random.PRNGKey(0))
        engine = ServeEngine(model, params, batch_slots=args.slots,
                             max_seq=args.max_seq)
        rng = np.random.default_rng(0)
        reqs = [Request(uid=i,
                        prompt=rng.integers(0, arch.vocab, size=4)
                        .astype(np.int32),
                        max_new_tokens=args.max_new)
                for i in range(args.requests)]
        for r in reqs:
            engine.submit(r)
        t0 = time.perf_counter()
        ticks = 0
        while (engine.queue or any(engine.active)) and ticks < 10_000:
            engine.step()
            ticks += 1
        wall = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in reqs)
    print(f"[serve] {arch.name}: {sum(r.done for r in reqs)}/{len(reqs)} "
          f"requests, {toks} tokens, {toks/max(wall,1e-9):.1f} tok/s, "
          f"{args.slots} slots, mesh={dict(mesh.shape)}")


if __name__ == "__main__":
    main()

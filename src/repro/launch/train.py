"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch starcoder2-3b \
        --reduced --steps 100 --mesh 1x1 [--resume] [--strategy fsdp]

On a real TPU slice the same entry point runs with --mesh 16x16 (and
jax.distributed.initialize handles multi-host); on this CPU container use
--mesh 1x1 with --reduced configs. All fault-tolerance behaviour
(checkpoint/resume/straggler watchdog) is active either way.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro import nn
from repro.config import TrainConfig
from repro.configs import get_config, get_reduced
from repro.data.pipeline import TokenTaskSource
from repro.distributed import sharding as shd
from repro.models import build_model
from repro.train.loop import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--mesh", default="1x1",
                    help="DATAxMODEL (e.g. 16x16) or PODxDATAxMODEL "
                         "(e.g. 2x16x16 — engages the pod axis)")
    ap.add_argument("--strategy", default="megatron",
                    choices=["megatron", "fsdp", "serve", "ring", "moe_rep"])
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--grad-reduce", default="gspmd",
                    choices=["gspmd", "explicit"],
                    help="who owns the cross-pod gradient collective: XLA "
                         "(gspmd) or the shard_map'd pod-local engine")
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "int8"],
                    help="int8-compress the cross-pod gradient reduction "
                         "(error-feedback residual carried in TrainState)")
    ap.add_argument("--residual-dtype", default="float32",
                    choices=["float32", "bfloat16"])
    args = ap.parse_args()

    name = args.arch.replace("-", "_")
    arch = get_reduced(name) if args.reduced else get_config(name)
    arch = dataclasses.replace(arch, sharding_strategy=args.strategy)
    model = build_model(arch)

    mesh_dims = tuple(int(x) for x in args.mesh.split("x"))
    # PODxDATAxMODEL engages the pod-local gradient engine; DATAxMODEL is
    # the single-pod layout.
    axes = ("pod", "data", "model") if len(mesh_dims) == 3 \
        else ("data", "model")
    mesh = jax.make_mesh(mesh_dims, axes)
    tcfg = TrainConfig(learning_rate=args.lr, warmup_steps=20,
                       total_steps=args.steps, microbatch=args.microbatch,
                       checkpoint_every=args.ckpt_every,
                       checkpoint_dir=args.ckpt_dir,
                       grad_reduce=args.grad_reduce,
                       grad_compression=args.grad_compression,
                       residual_dtype=args.residual_dtype)

    with shd.use_strategy(args.strategy):
        trainer = Trainer(model, tcfg, mesh)
        print(f"[launch] {arch.name} params="
              f"{nn.count_params(trainer.params)/1e6:.1f}M "
              f"mesh={dict(mesh.shape)} strategy={args.strategy}")
        if args.resume:
            trainer.maybe_resume()
        data = TokenTaskSource(vocab=arch.vocab, seq_len=args.seq,
                               batch=args.batch, seed=tcfg.seed)
        hist = trainer.fit(iter(data), n_steps=args.steps)
        trainer.checkpoint(sync=True)
    print(f"[launch] done: step {trainer.step} "
          f"loss {hist[0].loss_value:.3f} -> {hist[-1].loss_value:.3f}; "
          f"stragglers={sum(h.straggler for h in hist)}")


if __name__ == "__main__":
    main()

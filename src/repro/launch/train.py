"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch starcoder2-3b \
        --reduced --steps 100 --mesh 1x1 [--resume] [--strategy fsdp]

On a real TPU slice the same entry point runs with --mesh 16x16 (and
jax.distributed.initialize handles multi-host); on this CPU container use
--mesh 1x1 with --reduced configs. All fault-tolerance behaviour
(checkpoint/resume/straggler watchdog) is active either way.
"""
from __future__ import annotations

import argparse
import dataclasses

from repro import nn
from repro.config import TrainConfig
from repro.configs import get_config, get_reduced
from repro.data.pipeline import TokenTaskSource
from repro.distributed import sharding as shd
from repro.launch.mesh import parse_mesh_spec
from repro.models import build_model
from repro.train.loop import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--mesh", default="1x1",
                    help="DATAxMODEL (e.g. 16x16) or PODxDATAxMODEL "
                         "(e.g. 2x16x16 — engages the pod axis)")
    ap.add_argument("--strategy", default="megatron",
                    choices=["megatron", "fsdp", "serve", "ring", "moe_rep"])
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--grad-reduce", default="gspmd",
                    choices=["gspmd", "explicit"],
                    help="who owns the cross-pod gradient collective: XLA "
                         "(gspmd) or the shard_map'd pod-local engine")
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "int8"],
                    help="int8-compress the cross-pod gradient reduction "
                         "(error-feedback residual carried in TrainState)")
    ap.add_argument("--residual-dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--param-sharding", default="replicated",
                    choices=["replicated", "fsdp", "tp", "tp_fsdp"],
                    help="explicit-seam parameter layout (needs "
                         "--grad-reduce explicit for the sharded modes)")
    ap.add_argument("--policy", default=None,
                    help="unified ShardingPolicy spelling (key=value,"
                         "comma-separated: params=tp_fsdp,reduce=explicit,"
                         "compression=int8,seq=data,...) — overrides the "
                         "individual legacy flags above")
    args = ap.parse_args()

    name = args.arch.replace("-", "_")
    arch = get_reduced(name) if args.reduced else get_config(name)
    arch = dataclasses.replace(arch, sharding_strategy=args.strategy)
    model = build_model(arch)

    mesh = parse_mesh_spec(args.mesh)
    tcfg = TrainConfig(learning_rate=args.lr, warmup_steps=20,
                       total_steps=args.steps, microbatch=args.microbatch,
                       checkpoint_every=args.ckpt_every,
                       checkpoint_dir=args.ckpt_dir,
                       grad_reduce=args.grad_reduce,
                       grad_compression=args.grad_compression,
                       param_sharding=args.param_sharding,
                       residual_dtype=args.residual_dtype)

    if args.policy:
        policy = shd.ShardingPolicy.from_string(args.policy).with_mesh(mesh)
    else:
        policy = shd.ShardingPolicy.from_train_config(
            tcfg, mesh=mesh, strategy=args.strategy)
    tcfg = policy.apply_to(tcfg)

    with shd.use_policy(policy):
        trainer = Trainer(model, tcfg, mesh, policy=policy)
        print(f"[launch] {arch.name} params="
              f"{nn.count_params(trainer.params)/1e6:.1f}M "
              f"mesh={dict(mesh.shape)} strategy={policy.strategy} "
              f"params_layout={policy.param_sharding}")
        if args.resume:
            trainer.maybe_resume()
        data = TokenTaskSource(vocab=arch.vocab, seq_len=args.seq,
                               batch=args.batch, seed=tcfg.seed)
        hist = trainer.fit(iter(data), n_steps=args.steps)
        trainer.checkpoint(sync=True)
    print(f"[launch] done: step {trainer.step} "
          f"loss {hist[0].loss_value:.3f} -> {hist[-1].loss_value:.3f}; "
          f"stragglers={sum(h.straggler for h in hist)}")


if __name__ == "__main__":
    main()

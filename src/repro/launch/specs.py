"""Input specifications per (architecture x shape).

``input_specs(arch, shape)``  -> pytree of jax.ShapeDtypeStruct — the shapes
the dry-run lowers against (weak-type-correct, shardable, no allocation).
``make_batch(arch, shape, key)`` -> concrete arrays of the same structure
for smoke tests / real training at reduced scale.

Conventions (assignment):
  * train shapes   -> train_step inputs {tokens, labels, ...frontend stubs}
  * prefill shapes -> the same forward (teacher-forced logits over seq_len)
  * decode shapes  -> serve_step inputs: one new token + caches of seq_len
  * [vlm]/[audio]: the modality frontend is a STUB — patch/frame embeddings
    arrive precomputed.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ArchConfig, ShapeConfig


def train_input_specs(arch: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, T = shape.global_batch, shape.seq_len
    specs: Dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((B, T), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, T), jnp.int32),
    }
    if arch.family == "vlm":
        specs["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, arch.frontend_tokens, arch.frontend_dim), jnp.float32)
    if arch.family == "audio":
        specs["frames"] = jax.ShapeDtypeStruct(
            (B, arch.enc_seq, arch.frontend_dim), jnp.float32)
    return specs


def decode_token_specs(arch: ArchConfig, shape: ShapeConfig):
    B = shape.global_batch
    return jax.ShapeDtypeStruct((B, 1), jnp.int32)


def make_batch(arch: ArchConfig, shape: ShapeConfig, key: jax.Array,
               batch_override: int = 0, seq_override: int = 0
               ) -> Dict[str, jax.Array]:
    B = batch_override or shape.global_batch
    T = seq_override or shape.seq_len
    k1, k2, k3 = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(k1, (B, T), 0, arch.vocab, jnp.int32),
    }
    batch["labels"] = jnp.pad(batch["tokens"][:, 1:], ((0, 0), (0, 1)),
                              constant_values=-1)
    if arch.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            k2, (B, arch.frontend_tokens, arch.frontend_dim), jnp.float32)
        # image positions carry no LM label
        batch["labels"] = batch["labels"].at[:, :arch.frontend_tokens].set(-1)
    if arch.family == "audio":
        batch["frames"] = jax.random.normal(
            k3, (B, arch.enc_seq, arch.frontend_dim), jnp.float32)
    return batch


def cell_is_applicable(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """The 40-cell applicability matrix (skips recorded in DESIGN.md)."""
    if shape.name == "long_500k" and not arch.subquadratic:
        return False, ("full-attention arch: 512k decode needs a full KV "
                       "cache per layer and quadratic-prefill context; "
                       "skipped per assignment (sub-quadratic archs only)")
    return True, ""

"""Unified decoder-only LM covering the dense / moe / ssm / hybrid / vlm
assigned architectures. (Enc-dec audio lives in models/encdec.py.)

Design notes (MaxText-style, compile-time-aware):
  * scan-over-layers: identical layer groups are stacked on a leading axis
    and iterated with jax.lax.scan — HLO size is O(1) in depth, which keeps
    the 512-device SPMD compile of 26B-parameter graphs tractable.
  * heterogeneous patterns (gemma3 5-local:1-global, zamba2 shared-attention
    interleave) are expressed as a GROUP of layers that IS homogeneous at the
    group level; trailing non-multiple layers are unrolled.
  * remat: each group body is wrapped in jax.checkpoint(nothing_saveable),
    so backward recomputes inside a group and only group-boundary activations
    are live — the activation-memory term in the §Roofline analysis.
  * losses are computed in sequence chunks so the (B, T, V) logits tensor for
    a 256k vocab never materialises at once.

Cache contract for decode (serve_step): every layer's recurrent state is
stacked on the layer axis and carried through the same scan.

Distribution: all shard_map/collective call sites (the sequence-sharded
decode path via models/attention.py, ring attention, the local-MoE
dispatch, and the lrc mixer's optional sequence-parallel DEER solve —
``SSMConfig.seq_shard``) resolve through distributed/compat.py, so the LM
runs unmodified across the supported jax version range.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import nn
from repro.config import ArchConfig
from repro.distributed.sharding import (_path_str, in_manual_body,
                                        shard_activation, tp_gather_weight,
                                        tp_index, tp_info, tp_region_in,
                                        tp_region_out)
from repro.models import attention as attn_lib
from repro.models import mixers, moe as moe_lib

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# manual tensor parallelism (explicit gradient seam)
# ---------------------------------------------------------------------------

def tp_unsupported_patterns(arch: ArchConfig, m: int) -> Tuple[str, ...]:
    """Parameter-path regexes the manual-TP branches cannot shard at TP
    degree ``m`` — consumed by train/step.py so the explicit-seam specs
    force those leaves replicated. The model code's shape tests then see
    full weights and take the replicated path automatically: specs and
    compute can never disagree.

    Covers packed layouts whose segment structure does not divide by ``m``
    (attention heads, mamba2 head count / conv channels) and layouts with
    no TP branch at all (mamba1's (d_inner, N) ``A_log``, which instead
    stays replicated and is sliced inside the mixer's TP branch; the whole
    enc-dec audio family)."""
    if m <= 1:
        return ()
    if arch.family == "audio":
        return (r".*",)
    pats = []
    H, K = arch.n_heads, arch.n_kv_heads
    if H % m or K % m:
        pats += [r"wqkv$", r"wo$"]
    if arch.d_ff % m:
        pats += [r"w_gate$", r"w_up$", r"w_down$", r"fc1/", r"fc2/"]
    if arch.ssm is not None:
        d_inner = arch.ssm.expand * arch.d_model
        bad = d_inner % m != 0
        if arch.ssm.kind == "mamba2":
            _, H2, _, N2, _ = mixers.mamba2_dims(arch)
            bad = bad or H2 % m != 0 or (d_inner + 2 * N2) % m != 0
        if bad:
            pats.append(r"mixer/")
        elif arch.ssm.kind == "mamba1":
            pats.append(r"mixer/A_log$")
    return tuple(pats)


# ---------------------------------------------------------------------------
# layer init
# ---------------------------------------------------------------------------

def padded_vocab(arch: ArchConfig) -> int:
    """Vocab rounded up to a TP-friendly multiple (Megatron-style padding):
    49155 -> 49408 etc. Padded logit columns are masked to -inf."""
    return -(-arch.vocab // 256) * 256


def _mask_padded_logits(logits: jax.Array, vocab: int) -> jax.Array:
    Vp = logits.shape[-1]
    if Vp == vocab:
        return logits
    ids = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    return jnp.where(ids < vocab, logits, jnp.asarray(-1e9, logits.dtype))


def _norm_init(arch: ArchConfig, d: int):
    return (nn.rmsnorm_init(d, arch.param_dtype) if arch.norm == "rmsnorm"
            else nn.layernorm_init(d, arch.param_dtype))


def _norm(arch: ArchConfig, p, x):
    return nn.rmsnorm(p, x) if arch.norm == "rmsnorm" else nn.layernorm(p, x)


def attn_block_init(arch: ArchConfig, key) -> Params:
    d, H, K, hd = arch.d_model, arch.n_heads, arch.n_kv_heads, arch.resolved_head_dim
    ks = jax.random.split(key, 6)
    pdt = arch.param_dtype
    p = {
        "norm1": _norm_init(arch, d),
        "wqkv": nn.lecun_normal(ks[0], (d, (H + 2 * K) * hd), pdt),
        "wo": nn.lecun_normal(ks[1], (H * hd, d), pdt, fan_in=H * hd),
        "norm2": _norm_init(arch, d),
    }
    if arch.moe is not None:
        p["moe"] = moe_lib.moe_init(arch, ks[2])
    elif arch.act in ("silu", "gelu_tanh"):  # gated (SwiGLU / GeGLU) archs
        p["w_gate"] = nn.lecun_normal(ks[2], (d, arch.d_ff), pdt)
        p["w_up"] = nn.lecun_normal(ks[3], (d, arch.d_ff), pdt)
        p["w_down"] = nn.lecun_normal(ks[4], (arch.d_ff, d), pdt,
                                      fan_in=arch.d_ff)
    else:                                     # plain MLP (gelu / squared-relu)
        p["fc1"] = nn.dense_init(ks[2], d, arch.d_ff, pdt)
        p["fc2"] = nn.dense_init(ks[3], arch.d_ff, d, pdt)
    return p


def _ffn(arch: ArchConfig, p: Params, x: jax.Array,
         moe_path: str = "dense") -> jax.Array:
    act = nn.ACTIVATIONS[arch.act]
    if arch.moe is not None:
        return moe_lib.moe_apply(p["moe"], arch, x, path=moe_path)
    tp_ax, tp_m = tp_info()
    if "w_gate" in p:
        if (tp_ax is not None
                and p["w_gate"].shape[1] * tp_m == arch.d_ff
                and p["w_down"].shape[0] * tp_m == arch.d_ff):
            # megatron column/row split: gate+up columns, down rows
            xt = tp_region_in(x, tp_ax)
            a = act(xt @ p["w_gate"]) * (xt @ p["w_up"])
            return tp_region_out(a @ p["w_down"], tp_ax)
        return (act(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    if (tp_ax is not None
            and p["fc1"]["w"].shape[1] * tp_m == arch.d_ff
            and p["fc2"]["w"].shape[0] * tp_m == arch.d_ff):
        xt = tp_region_in(x, tp_ax)
        hcol = act(xt @ p["fc1"]["w"] + p["fc1"]["b"])
        # fc2 bias is replicated: add it AFTER the closing psum, once
        return tp_region_out(hcol @ p["fc2"]["w"], tp_ax) + p["fc2"]["b"]
    return nn.dense(p["fc2"], act(nn.dense(p["fc1"], x)))


# ---------------------------------------------------------------------------
# layer apply — full-sequence mode
# ---------------------------------------------------------------------------

def attn_block_apply(arch: ArchConfig, p: Params, h: jax.Array, *,
                     window: Optional[int], positions: jax.Array,
                     moe_path: str = "dense") -> jax.Array:
    B, T, d = h.shape
    H, K, hd = arch.n_heads, arch.n_kv_heads, arch.resolved_head_dim
    hn = _norm(arch, p["norm1"], h)
    tp_ax, tp_m = tp_info()
    tp = (tp_ax is not None
          and p["wqkv"].shape[1] * tp_m == (H + 2 * K) * hd
          and p["wo"].shape[0] * tp_m == H * hd
          and H % tp_m == 0 and K % tp_m == 0)
    if tp:
        # column-parallel qkv over heads: the packed [q|k|v] layout does
        # not slice contiguously per rank, so gather the weight and cut
        # this rank's head block out of each segment (the gather's
        # psum_scatter transpose keeps the gradients exact)
        hn = tp_region_in(hn, tp_ax)
        wf = tp_gather_weight(p["wqkv"].astype(h.dtype), tp_ax, 1)
        r = tp_index(tp_ax)
        H_l, K_l = H // tp_m, K // tp_m
        q = hn @ jax.lax.dynamic_slice_in_dim(wf, r * H_l * hd,
                                              H_l * hd, 1)
        k = hn @ jax.lax.dynamic_slice_in_dim(wf, H * hd + r * K_l * hd,
                                              K_l * hd, 1)
        v = hn @ jax.lax.dynamic_slice_in_dim(
            wf, (H + K) * hd + r * K_l * hd, K_l * hd, 1)
        H, K = H_l, K_l
    else:
        qkv = (hn @ p["wqkv"].astype(h.dtype))
        q, k, v = jnp.split(qkv, [H * hd, (H + K) * hd], axis=-1)
    q = q.reshape(B, T, H, hd)
    k = k.reshape(B, T, K, hd)
    v = v.reshape(B, T, K, hd)
    if arch.rope_theta > 0:
        q = attn_lib.apply_rope(q, positions, arch.rope_theta)
        k = attn_lib.apply_rope(k, positions, arch.rope_theta)
    from repro.distributed.sharding import current_mesh
    mesh = current_mesh()
    if (arch.attn_impl == "ring" and window is None and mesh is not None
            and "model" in mesh.axis_names and not in_manual_body()):
        o = attn_lib.ring_attention(q, k, v, mesh=mesh, causal=True)
    else:
        kv_chunk = T if arch.exact_hlo else 1024
        o = attn_lib.attention(q, k, v, causal=True, window=window,
                               kv_chunk=kv_chunk)
    o = o.reshape(B, T, H * hd) @ p["wo"].astype(h.dtype)
    if tp:
        o = tp_region_out(o, tp_ax)
    h = h + shard_activation(o, "act")
    hn = _norm(arch, p["norm2"], h)
    h = h + shard_activation(_ffn(arch, p, hn, moe_path), "act")
    return h


def mixer_block_init(arch: ArchConfig, key) -> Params:
    kind = arch.ssm.kind
    k1, k2 = jax.random.split(key)
    return {"norm": _norm_init(arch, arch.d_model),
            "mixer": mixers.MIXERS[kind][0](arch, k1)}


def mixer_block_apply(arch: ArchConfig, p: Params, h: jax.Array,
                      state: Optional[Dict] = None, prefill_len=None,
                      return_traj: bool = False, solver_iters=None):
    kind = arch.ssm.kind
    hn = _norm(arch, p["norm"], h)
    out, new_state = mixers.MIXERS[kind][1](p["mixer"], arch, hn, state,
                                            prefill_len=prefill_len,
                                            return_traj=return_traj,
                                            solver_iters=solver_iters)
    return h + shard_activation(out, "act"), new_state


# ---------------------------------------------------------------------------
# group pattern resolution
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerPlan:
    """Static description of the repeating layer group + trailing layers."""
    group: Tuple[str, ...]       # e.g. ("local",)*5 + ("global",) or ("ssm",)
    n_groups: int
    tail: Tuple[str, ...]        # unrolled remainder
    shared_attn: bool = False    # zamba2: shared block applied after "ssm_sh"


def layer_plan(arch: ArchConfig) -> LayerPlan:
    L = arch.n_layers
    if arch.family in ("ssm",) or (arch.seq_mixer == "lrc" and arch.ssm):
        if arch.hybrid_period:
            g = ("ssm",) * (arch.hybrid_period - 1) + ("ssm_sh",)
            n, r = divmod(L, arch.hybrid_period)
            return LayerPlan(g, n, ("ssm",) * r, shared_attn=True)
        return LayerPlan(("ssm",), L, ())
    if arch.family == "hybrid":
        g = ("ssm",) * (arch.hybrid_period - 1) + ("ssm_sh",)
        n, r = divmod(L, arch.hybrid_period)
        return LayerPlan(g, n, ("ssm",) * r, shared_attn=True)
    if arch.window_pattern is not None:
        _, per = arch.window_pattern
        g = ("local",) * per + ("global",)
        n, r = divmod(L, per + 1)
        return LayerPlan(g, n, ("local",) * r)
    return LayerPlan(("full",), L, ())


def _layer_init(arch: ArchConfig, kind: str, key) -> Params:
    if kind in ("ssm", "ssm_sh"):
        return mixer_block_init(arch, key)
    return attn_block_init(arch, key)


def _window_for(arch: ArchConfig, kind: str) -> Optional[int]:
    if kind == "local" and arch.window_pattern is not None:
        return arch.window_pattern[0]
    return None


# ---------------------------------------------------------------------------
# model init
# ---------------------------------------------------------------------------

def init_lm(arch: ArchConfig, key: jax.Array) -> Params:
    plan = layer_plan(arch)
    n_keys = 4 + len(plan.tail) + 1
    ks = jax.random.split(key, n_keys)
    pdt = arch.param_dtype
    scale = (1.0 / arch.d_model) ** 0.5
    Vp = padded_vocab(arch)
    p: Params = {
        "embed": (jax.random.normal(ks[0], (Vp, arch.d_model))
                  * scale).astype(pdt),
        "final_norm": _norm_init(arch, arch.d_model),
    }
    if not arch.tie_embeddings:
        p["lm_head"] = nn.lecun_normal(ks[1], (arch.d_model, Vp), pdt)
    if arch.frontend_dim:
        p["projector"] = nn.mlp_init(ks[2], arch.frontend_dim,
                                     arch.d_model * 2, arch.d_model, pdt)

    # stacked group params via vmapped init
    gkeys = jax.random.split(ks[3], max(plan.n_groups, 1))

    def group_init(gk):
        lkeys = jax.random.split(gk, len(plan.group))
        return [_layer_init(arch, kind, lk)
                for kind, lk in zip(plan.group, lkeys)]

    if plan.n_groups > 0:
        p["groups"] = jax.vmap(group_init)(gkeys)
    p["tail"] = [_layer_init(arch, kind, ks[4 + i])
                 for i, kind in enumerate(plan.tail)]
    if plan.shared_attn:
        p["shared_attn"] = attn_block_init(arch, ks[-1])
    return p


# ---------------------------------------------------------------------------
# forward (train / prefill logits)
# ---------------------------------------------------------------------------

def _embed_inputs(arch: ArchConfig, p: Params, batch: Dict) -> jax.Array:
    tok_emb = jnp.take(p["embed"], batch["tokens"], axis=0).astype(arch.dtype)
    if arch.frontend_dim and "patch_embeds" in batch:
        # VLM: projected frontend embeddings replace the leading positions.
        pe = nn.mlp(p["projector"], batch["patch_embeds"].astype(arch.dtype))
        n_img = pe.shape[1]
        tok_emb = jnp.concatenate([pe, tok_emb[:, n_img:]], axis=1)
    return shard_activation(tok_emb, "act")


def _apply_layer(arch: ArchConfig, kind: str, lp: Params, h: jax.Array,
                 positions: jax.Array, shared_p: Optional[Params],
                 moe_path: str) -> jax.Array:
    if kind in ("ssm", "ssm_sh"):
        h, _ = mixer_block_apply(arch, lp, h)
        if kind == "ssm_sh" and shared_p is not None:
            h = attn_block_apply(arch, shared_p, h, window=None,
                                 positions=positions, moe_path=moe_path)
        return h
    return attn_block_apply(arch, lp, h, window=_window_for(arch, kind),
                            positions=positions, moe_path=moe_path)


def apply_lm(arch: ArchConfig, p: Params, batch: Dict,
             moe_path: str = "dense") -> jax.Array:
    """batch {tokens (B,T), [patch_embeds]} -> final hidden states (B,T,D)."""
    plan = layer_plan(arch)
    p = nn.cast_tree(p, arch.dtype)
    h = _embed_inputs(arch, p, batch)
    B, T, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    shared_p = p.get("shared_attn")

    def group_body(h, group_params):
        for kind, lp in zip(plan.group, group_params):
            h = _apply_layer(arch, kind, lp, h, positions, shared_p, moe_path)
        return h, None

    body = group_body
    if arch.remat == "layer" and plan.n_groups > 0:
        body = jax.checkpoint(
            group_body, policy=jax.checkpoint_policies.nothing_saveable)

    if plan.n_groups > 0:
        if arch.scan_layers:
            h, _ = jax.lax.scan(body, h, p["groups"])
        else:
            for gi in range(plan.n_groups):
                gp = jax.tree_util.tree_map(lambda x: x[gi], p["groups"])
                h, _ = body(h, gp)
    for kind, lp in zip(plan.tail, p["tail"]):
        h = _apply_layer(arch, kind, lp, h, positions, shared_p, moe_path)
    return _norm(arch, p["final_norm"], h)


def logits_fn(arch: ArchConfig, p: Params, h: jax.Array) -> jax.Array:
    head = p["embed"].T if arch.tie_embeddings else p["lm_head"]
    return _mask_padded_logits(h @ head.astype(h.dtype), arch.vocab)


def lm_loss(arch: ArchConfig, p: Params, batch: Dict,
            moe_path: str = "dense", loss_chunk: int = 1024) -> jax.Array:
    """Next-token cross-entropy, computed in sequence chunks so the
    (B, T, vocab) logits never materialise (vocab up to 262k)."""
    h = apply_lm(arch, p, batch, moe_path=moe_path)
    labels = batch.get("labels")
    if labels is None:
        labels = jnp.pad(batch["tokens"][:, 1:], ((0, 0), (0, 1)),
                         constant_values=-1)
    B, T, D = h.shape
    if arch.exact_hlo:
        loss_chunk = T
    n_chunks = max(T // loss_chunk, 1)
    hc = h[:, :n_chunks * loss_chunk].reshape(B, n_chunks, -1, D)
    lc = labels[:, :n_chunks * loss_chunk].reshape(B, n_chunks, -1)
    head = (p["embed"].T if arch.tie_embeddings else p["lm_head"]).astype(h.dtype)

    def chunk_loss(carry, xs):
        hck, lck = xs                       # (B, C, D), (B, C)
        logits = _mask_padded_logits((hck @ head).astype(jnp.float32),
                                     arch.vocab)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lck, 0)[..., None], axis=-1)[..., 0]
        mask = (lck >= 0).astype(jnp.float32)
        nll = (logz - gold) * mask
        return (carry[0] + jnp.sum(nll), carry[1] + jnp.sum(mask)), None

    (tot, cnt), _ = jax.lax.scan(
        chunk_loss, (jnp.float32(0), jnp.float32(0)),
        (hc.swapaxes(0, 1), lc.swapaxes(0, 1)))
    return tot / jnp.maximum(cnt, 1.0)


# ---------------------------------------------------------------------------
# decode (serve_step)
# ---------------------------------------------------------------------------

def init_cache(arch: ArchConfig, batch: int, max_seq: int) -> Dict:
    """Per-layer decode state, stacked on the leading layer/group axes.

    Attention layers get (k, v) rings; local layers allocate only the window
    (a long_500k memory win); ssm layers get O(D) recurrent state.
    """
    plan = layer_plan(arch)
    K, hd = arch.n_kv_heads, arch.resolved_head_dim

    def layer_cache(kind):
        if kind in ("ssm", "ssm_sh"):
            return mixers.MIXERS[arch.ssm.kind][2](arch, batch)
        window = _window_for(arch, kind)
        S = min(max_seq, window) if window else max_seq
        return {"k": jnp.zeros((batch, S, K, hd), arch.dtype),
                "v": jnp.zeros((batch, S, K, hd), arch.dtype)}

    def group_cache(_):
        return [layer_cache(kind) for kind in plan.group]

    cache: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    if plan.n_groups > 0:
        cache["groups"] = jax.vmap(group_cache)(jnp.arange(plan.n_groups))
    cache["tail"] = [layer_cache(kind) for kind in plan.tail]
    if plan.shared_attn:
        cache["shared"] = [
            {"k": jnp.zeros((batch, max_seq, K, hd), arch.dtype),
             "v": jnp.zeros((batch, max_seq, K, hd), arch.dtype)}
            for _ in range(plan.n_groups + sum(k == "ssm_sh" for k in plan.tail))]
    return cache


def _attn_decode(arch: ArchConfig, lp: Params, h: jax.Array, cache_l: Dict,
                 pos: jax.Array, window: Optional[int]):
    """One-token decode through an attention layer.

    ``pos`` may be a scalar (whole batch at one position — the training-eval
    / dry-run shape) or a (B,) vector (continuous-batching serve: every slot
    at its own position; per-row cache writes, no sequence-sharded path).
    """
    B = h.shape[0]
    H, K, hd = arch.n_heads, arch.n_kv_heads, arch.resolved_head_dim
    per_slot = jnp.ndim(pos) > 0
    hn = _norm(arch, lp["norm1"], h)
    qkv = hn @ lp["wqkv"].astype(h.dtype)
    q, k, v = jnp.split(qkv, [H * hd, (H + K) * hd], axis=-1)
    q = q.reshape(B, 1, H, hd)
    k = k.reshape(B, 1, K, hd)
    v = v.reshape(B, 1, K, hd)
    positions = pos[:, None] if per_slot else jnp.full((B, 1), pos)
    if arch.rope_theta > 0:
        q = attn_lib.apply_rope(q, positions, arch.rope_theta)
        k = attn_lib.apply_rope(k, positions, arch.rope_theta)
    # keep the per-step tensors batch-sharded only, so the cache layout is
    # step-invariant (no whole-cache resharding — §Perf C finding)
    from repro.distributed.sharding import constrain_batch_only
    q, k, v = (constrain_batch_only(t) for t in (q, k, v))
    S = cache_l["k"].shape[1]
    slot = (pos % S) if window else pos
    # ring semantics for windowed layers: all S slots valid once pos >= S
    eff_len = jnp.minimum(pos + 1, S) if window else pos + 1
    seq_axes = None
    if not per_slot and not in_manual_body():
        from repro.distributed.sharding import current_mesh
        mesh = current_mesh()
        if mesh is not None and "model" in mesh.axis_names:
            if B % mesh.shape.get("data", 1) == 0 and \
                    S % mesh.shape["model"] == 0:
                seq_axes = "model"
            elif S % (mesh.shape.get("data", 1) * mesh.shape["model"]) == 0:
                seq_axes = ("data", "model")   # batch=1 long-context cells
    if per_slot:
        kc, vc = attn_lib.update_kv_cache_rows(cache_l["k"], cache_l["v"],
                                               k, v, slot)
        o = attn_lib.decode_attention(q, kc, vc, eff_len, window=None)
    elif seq_axes is not None:
        # sequence-sharded cache: manual shard_map decode (tiny collectives)
        o, kc, vc = attn_lib.sharded_decode_attention(
            q, cache_l["k"], cache_l["v"], k, v, slot, eff_len, mesh=mesh,
            axis=seq_axes)
    else:
        kc, vc = attn_lib.update_kv_cache(cache_l["k"], cache_l["v"], k, v,
                                          slot)
        o = attn_lib.decode_attention(q, kc, vc, eff_len, window=None)
    o = o.reshape(B, 1, H * hd) @ lp["wo"].astype(h.dtype)
    h = h + o
    hn = _norm(arch, lp["norm2"], h)
    h = h + _ffn(arch, lp, hn)
    return h, {**cache_l, "k": kc, "v": vc}


def _attn_prefill(arch: ArchConfig, lp: Params, h: jax.Array, cache_l: Dict,
                  pos: jax.Array, window: Optional[int], length=None):
    """T-token chunk prefill through an attention layer: the chunk occupies
    absolute positions ``pos..pos+T-1``; k/v land in the cache; attention is
    causal over cache + chunk (full layers) or the ring window (local
    layers). Right-padded garbage beyond the valid length is masked by
    causality for every valid query and overwritten by later writes."""
    B, T, _ = h.shape
    H, K, hd = arch.n_heads, arch.n_kv_heads, arch.resolved_head_dim
    hn = _norm(arch, lp["norm1"], h)
    qkv = hn @ lp["wqkv"].astype(h.dtype)
    q, k, v = jnp.split(qkv, [H * hd, (H + K) * hd], axis=-1)
    q = q.reshape(B, T, H, hd)
    k = k.reshape(B, T, K, hd)
    v = v.reshape(B, T, K, hd)
    positions = jnp.broadcast_to(pos + jnp.arange(T)[None], (B, T))
    if arch.rope_theta > 0:
        q = attn_lib.apply_rope(q, positions, arch.rope_theta)
        k = attn_lib.apply_rope(k, positions, arch.rope_theta)
    if window:
        o, kc, vc = attn_lib.prefill_ring_attention(
            q, cache_l["k"], cache_l["v"], k, v, pos, length)
    else:
        o, kc, vc = attn_lib.prefill_full_attention(
            q, cache_l["k"], cache_l["v"], k, v, pos,
            kv_chunk=cache_l["k"].shape[1] if arch.exact_hlo else 1024)
    o = o.reshape(B, T, H * hd) @ lp["wo"].astype(h.dtype)
    h = h + o
    hn = _norm(arch, lp["norm2"], h)
    h = h + _ffn(arch, lp, hn)
    return h, {**cache_l, "k": kc, "v": vc}


def _walk_cached_layers(arch: ArchConfig, p: Params, cache: Dict,
                        h: jax.Array, apply_layer) -> Tuple[jax.Array, Dict]:
    """Thread ``h`` and the per-layer decode cache through the layer plan —
    scan-over-groups or unrolled — mirroring apply_lm's group structure.

    ``apply_layer(kind, lp, h, cache_l, shared_cache) -> (h, new_cache_l,
    new_shared_cache)`` is the per-layer body; decode_step (one token) and
    prefill (a parallel chunk) both plug into this single walker, so the
    cache-threading topology exists exactly once. Returns ``(h, new_cache)``
    carrying every cache key except "pos" — position bookkeeping belongs to
    the caller."""
    plan = layer_plan(arch)
    shared_idx = 0

    new_cache: Dict[str, Any] = {}
    if plan.n_groups > 0 and not arch.scan_layers:
        # unrolled path (exact-HLO measurement mode)
        tm = jax.tree_util.tree_map
        new_group_list = []
        new_shared_list = list(cache.get("shared", []))
        for gi in range(plan.n_groups):
            gp = tm(lambda x: x[gi], p["groups"])
            gc = tm(lambda x: x[gi], cache["groups"])
            sc = cache["shared"][gi] if plan.shared_attn else None
            new_gc = []
            for i, kind in enumerate(plan.group):
                h, ncl, sc = apply_layer(kind, gp[i], h, gc[i], sc)
                new_gc.append(ncl)
            if plan.shared_attn:
                new_shared_list[gi] = sc
            new_group_list.append(new_gc)
        new_cache["groups"] = tm(lambda *xs: jnp.stack(xs), *new_group_list) \
            if plan.n_groups > 1 else tm(lambda x: x[None], new_group_list[0])
        if plan.shared_attn:
            new_cache["shared"] = new_shared_list
        shared_idx = plan.n_groups
    elif plan.n_groups > 0:
        def group_body(h, xs):
            if plan.shared_attn:
                gp, gc, sc = xs
            else:
                (gp, gc), sc = xs, None
            new_gc = []
            for i, kind in enumerate(plan.group):
                h, ncl, sc = apply_layer(kind, gp[i], h, gc[i], sc)
                new_gc.append(ncl)
            return h, (new_gc, sc) if plan.shared_attn else new_gc

        if plan.shared_attn:
            sc_stacked = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *cache["shared"][:plan.n_groups]) \
                if plan.n_groups > 1 else jax.tree_util.tree_map(
                    lambda x: x[None], cache["shared"][0])
            h, (new_groups, new_sc) = jax.lax.scan(
                group_body, h, (p["groups"], cache["groups"], sc_stacked))
            new_cache["groups"] = new_groups
            new_cache["shared"] = [
                jax.tree_util.tree_map(lambda x: x[i], new_sc)
                for i in range(plan.n_groups)]
            shared_idx = plan.n_groups
        else:
            h, new_groups = jax.lax.scan(
                group_body, h, (p["groups"], cache["groups"]))
            new_cache["groups"] = new_groups

    new_tail = []
    for kind, lp, cl in zip(plan.tail, p["tail"], cache["tail"]):
        sc = (cache["shared"][shared_idx]
              if (kind == "ssm_sh" and plan.shared_attn) else None)
        h, ncl, sc = apply_layer(kind, lp, h, cl, sc)
        if kind == "ssm_sh" and plan.shared_attn:
            new_cache.setdefault("shared", list(cache["shared"]))[shared_idx] = sc
            shared_idx += 1
        new_tail.append(ncl)
    new_cache["tail"] = new_tail
    if plan.shared_attn and "shared" not in new_cache:
        new_cache["shared"] = cache["shared"]
    return h, new_cache


def decode_step(arch: ArchConfig, p: Params, tokens: jax.Array, cache: Dict,
                ) -> Tuple[jax.Array, Dict]:
    """One-token decode: tokens (B, 1) -> (logits (B, 1, V), new cache).

    ``cache["pos"]`` may be a scalar (the whole batch at one position — the
    training-eval / dry-run shape) or a (B,) vector (continuous-batching
    serve: every slot at its own position)."""
    p = nn.cast_tree(p, arch.dtype)
    pos = cache["pos"]
    h = jnp.take(p["embed"], tokens, axis=0).astype(arch.dtype)
    shared_p = p.get("shared_attn")

    def apply_decode_layer(kind, lp, h, cl, shared_cache):
        if kind in ("ssm", "ssm_sh"):
            h, new_cl = mixer_block_apply(
                arch, lp, h[:, None] if h.ndim == 2 else h, cl)
            if kind == "ssm_sh" and shared_p is not None:
                h, shared_cache = _attn_decode(arch, shared_p, h,
                                               shared_cache, pos, None)
            return h, new_cl, shared_cache
        h, new_cl = _attn_decode(arch, lp, h, cl, pos,
                                 _window_for(arch, kind))
        return h, new_cl, shared_cache

    h, new_cache = _walk_cached_layers(arch, p, cache, h, apply_decode_layer)
    new_cache["pos"] = pos + 1
    h = _norm(arch, p["final_norm"], h)
    return logits_fn(arch, p, h), new_cache


def prefill(arch: ArchConfig, p: Params, tokens: jax.Array, cache: Dict,
            length=None) -> Tuple[jax.Array, Dict]:
    """PARALLEL chunk prefill: tokens (B, T) at absolute positions
    ``cache["pos"]..pos+T-1`` -> (logits (B, T, V), new cache at pos+length).

    The whole chunk lowers through the full-sequence parallel paths — the
    DEER/ELK solver cascade for lrc mixers (sequence-sharded when
    ``arch.ssm.seq_shard`` and a mesh is active), associative selective
    scans for mamba mixers, causal flash attention against the cache for
    attention layers — never a length-T sequential scan. This is the
    scan-for-prefill half of the serving engine; decode_step is the
    O(D)-state recurrence half.

    ``length`` (<= T, default T) is the VALID prompt length inside a
    right-padded chunk: recurrent states are taken at ``length - 1``, and
    ``new_cache["pos"] = pos + length``, so padding never leaks into the
    carried state (attention garbage beyond ``length`` is masked by
    causality and overwritten by later writes at the same positions).
    ``length`` may be a (B,) vector — the BATCHED multi-request admission
    shape: rows are different requests sharing one parallel prefill call,
    each with its own valid length. Because a vector length makes the
    output ``pos`` a vector too, a vector-length call must be the FINAL
    chunk of its feed (interior chunks of a same-chunk-count admission
    bucket are fully valid, so they pass scalar length and keep ``pos``
    scalar). Requires a scalar input ``cache["pos"]`` (fragments are
    scattered into the batched serve cache afterwards)."""
    p = nn.cast_tree(p, arch.dtype)
    pos = cache["pos"]
    T = tokens.shape[1]
    L = T if length is None else length
    h = jnp.take(p["embed"], tokens, axis=0).astype(arch.dtype)
    shared_p = p.get("shared_attn")

    def apply_prefill_layer(kind, lp, h, cl, shared_cache):
        if kind in ("ssm", "ssm_sh"):
            h, new_cl = mixer_block_apply(arch, lp, h, cl, prefill_len=L)
            if kind == "ssm_sh" and shared_p is not None:
                h, shared_cache = _attn_prefill(arch, shared_p, h,
                                                shared_cache, pos, None,
                                                length=L)
            return h, new_cl, shared_cache
        h, new_cl = _attn_prefill(arch, lp, h, cl, pos,
                                  _window_for(arch, kind), length=L)
        return h, new_cl, shared_cache

    h, new_cache = _walk_cached_layers(arch, p, cache, h, apply_prefill_layer)
    new_cache["pos"] = pos + L
    h = _norm(arch, p["final_norm"], h)
    return logits_fn(arch, p, h), new_cache


# ---------------------------------------------------------------------------
# speculative decoding: the verify seam (read-only forward + masked commit)
# ---------------------------------------------------------------------------

def _attn_spec(arch: ArchConfig, lp: Params, h: jax.Array, cache_l: Dict,
               pos: jax.Array, window: Optional[int]):
    """k-token speculative-verify pass through an attention layer: attends
    the resident cache READ-ONLY (``attn_lib.spec_window_attention``) and
    stages the window's own k/v as the layer artifact — ``spec_commit``
    writes only the accepted prefix into the ring afterwards. ``pos`` is
    the per-slot (B,) position vector."""
    B, T, _ = h.shape
    H, K, hd = arch.n_heads, arch.n_kv_heads, arch.resolved_head_dim
    hn = _norm(arch, lp["norm1"], h)
    qkv = hn @ lp["wqkv"].astype(h.dtype)
    q, k, v = jnp.split(qkv, [H * hd, (H + K) * hd], axis=-1)
    q = q.reshape(B, T, H, hd)
    k = k.reshape(B, T, K, hd)
    v = v.reshape(B, T, K, hd)
    positions = pos[:, None] + jnp.arange(T)[None]
    if arch.rope_theta > 0:
        q = attn_lib.apply_rope(q, positions, arch.rope_theta)
        k = attn_lib.apply_rope(k, positions, arch.rope_theta)
    o = attn_lib.spec_window_attention(q, cache_l["k"], cache_l["v"], k, v,
                                       pos, ring=window is not None)
    o = o.reshape(B, T, H * hd) @ lp["wo"].astype(h.dtype)
    h = h + o
    hn = _norm(arch, lp["norm2"], h)
    h = h + _ffn(arch, lp, hn)
    return h, {"k": k, "v": v}


def spec_forward(arch: ArchConfig, p: Params, tokens: jax.Array, cache: Dict,
                 solver_iters=None) -> Tuple[jax.Array, Dict]:
    """Speculative-verify forward: a (B, k) token window for EVERY serve
    slot at its own position (``cache["pos"]``: (B,) vector), run through
    the SAME parallel paths as ``prefill`` — DEER solve for lrc mixers,
    associative scans for mamba, window attention against the resident
    cache — WITHOUT committing any state.

    Returns ``(logits (B, k, V), staged)`` where ``staged`` mirrors the
    cache topology but carries per-layer WINDOW artifacts instead of
    committed state: full (B, k, ...) state trajectories for mixer layers
    (plus the (B, k+W-1, C) conv input stream), and the window's own
    (B, k, K, hd) k/v for attention layers. The accepted prefix length
    depends on the FINAL logits, so the commit cannot happen layer by
    layer — ``spec_commit(cache, staged, acc)`` performs it post-hoc,
    which is also what makes rollback free (rejected suffixes are simply
    never written). ``solver_iters`` caps the lrc mixers' Newton depth —
    the early-exit DRAFT configuration of this same function; the verify
    pass leaves it None (full depth). Requires k >= 2 (the mixers' T > 1
    prefill-mode dispatch) and k <= every attention ring size."""
    p = nn.cast_tree(p, arch.dtype)
    pos = jnp.asarray(cache["pos"], jnp.int32)
    if pos.ndim == 0:
        pos = jnp.full((tokens.shape[0],), pos)
    h = jnp.take(p["embed"], tokens, axis=0).astype(arch.dtype)
    shared_p = p.get("shared_attn")

    def apply_spec_layer(kind, lp, h, cl, shared_cache):
        if kind in ("ssm", "ssm_sh"):
            h, st = mixer_block_apply(arch, lp, h, cl, return_traj=True,
                                      solver_iters=solver_iters)
            if kind == "ssm_sh" and shared_p is not None:
                h, shared_cache = _attn_spec(arch, shared_p, h,
                                             shared_cache, pos, None)
            return h, st, shared_cache
        h, st = _attn_spec(arch, lp, h, cl, pos, _window_for(arch, kind))
        return h, st, shared_cache

    h, staged = _walk_cached_layers(arch, p, cache, h, apply_spec_layer)
    h = _norm(arch, p["final_norm"], h)
    return logits_fn(arch, p, h), staged


def _gather_time_window(new: jax.Array, ba: int, start: jax.Array,
                        width: int) -> jax.Array:
    """``new[..., start_b : start_b + width, ...]`` along the time axis
    ``ba + 1``, with a per-row (B,) ``start`` (batch axis ``ba``)."""
    ta = ba + 1
    bshape = [1] * new.ndim
    bshape[ba] = new.shape[ba]
    rshape = [1] * new.ndim
    rshape[ta] = width
    idx = start.reshape(bshape) + jnp.arange(width).reshape(rshape)
    idx = jnp.broadcast_to(idx, new.shape[:ta] + (width,) + new.shape[ta + 1:])
    return jnp.take_along_axis(new, idx, axis=ta)


def _commit_kv_rows(old: jax.Array, new: jax.Array, ba: int,
                    pos: jax.Array, acc: jax.Array) -> jax.Array:
    """Write window rows ``i < acc[b]`` of ``new`` at ring slots
    ``(pos[b] + i) % S`` of ``old``; rows at or beyond the accept boundary
    keep their pre-verify values BIT-EXACTLY (they are never touched) —
    the rollback guarantee. ``ba``: batch axis (1 under stacked groups)."""
    S = old.shape[ba + 1]
    kwin = new.shape[ba + 1]
    bidx = jnp.arange(old.shape[ba])
    cur = old
    for i in range(kwin):
        slots = jnp.mod(pos + i, S)
        take = i < acc                                       # (B,) bool
        if ba == 0:
            rows = cur[bidx, slots]                          # (B,K,hd)
            vals = jnp.where(take[:, None, None],
                             new[:, i].astype(cur.dtype), rows)
            cur = cur.at[bidx, slots].set(vals)
        else:
            rows = cur[:, bidx, slots]                       # (G,B,K,hd)
            vals = jnp.where(take[None, :, None, None],
                             new[:, :, i].astype(cur.dtype), rows)
            cur = cur.at[:, bidx, slots].set(vals)
    return cur


def spec_commit(arch: ArchConfig, cache: Dict, staged: Dict,
                acc: jax.Array) -> Dict:
    """Commit a verified window's ACCEPTED prefix into the serve cache and
    roll back the rejected tail, in place on device.

    ``acc`` (B,) in [1, k] is the per-slot count of consumed window tokens
    (longest matching draft prefix + 1). Per staged leaf, keyed by its
    cache-path name:

      * ``ssm``  — the mixer state jumps to trajectory position acc-1;
      * ``conv`` — the buffer is the input stream's [acc, acc+W-1) slice
        (the last W-1 raw inputs after consuming acc tokens);
      * ``k``/``v`` — window rows i < acc land at ring slots (pos+i) % S;
        rows beyond keep their pre-verify bits (never written).

    Staged leaves whose shape equals the resident leaf are pass-throughs
    (untouched shared-attention entries the walker copied verbatim).
    ``cache["pos"]`` advances by acc. Dropping the rejected suffix is the
    WHOLE rollback: nothing speculative ever reached the cache."""
    pos = jnp.asarray(cache["pos"], jnp.int32)
    acc = jnp.asarray(acc, jnp.int32)
    if pos.ndim == 0:
        pos = jnp.full(acc.shape, pos)

    def leaf(path, old, new):
        ps = _path_str(path)
        ba = 1 if ps.startswith("groups") else 0
        name = ps.rsplit("/", 1)[-1]
        if new.shape == old.shape:
            return new                       # untouched pass-through
        if name == "ssm":
            got = _gather_time_window(new, ba, acc - 1, 1)
            return jnp.squeeze(got, axis=ba + 1).astype(old.dtype)
        if name == "conv":
            width = old.shape[ba + 1]        # W - 1
            return _gather_time_window(new, ba, acc, width).astype(old.dtype)
        if name in ("k", "v"):
            return _commit_kv_rows(old, new, ba, pos, acc)
        raise ValueError(f"spec_commit: unrecognised staged leaf {ps!r} "
                         f"with shape {new.shape} vs cache {old.shape}")

    body = {key: cache[key] for key in staged}
    committed = jax.tree_util.tree_map_with_path(leaf, body, staged)
    new_cache = dict(cache)
    new_cache.update(committed)
    new_cache["pos"] = pos + acc
    return new_cache

"""Mixture-of-Experts FFN (granite-moe family): top-k routing with two
dispatch paths.

``dense`` dispatch (default for correctness tests): compute every expert for
every token and combine with the top-k gate weights — mathematically exact,
FLOP cost n_experts/top_k above ideal. Used at smoke-test scale.

``einsum`` dispatch (dry-run / production path): GShard/Switch-style capacity
dispatch. One-hot dispatch tensors contract tokens into per-expert buffers of
capacity C = ceil(tokens_per_device * top_k / E * capacity_factor); with the
experts sharded over the "model" mesh axis, GSPMD lowers the dispatch einsum
into the canonical all-to-all pattern. Overflowing tokens are dropped
(standard capacity semantics) — exactness at the model level is preserved by
the residual connection.

EP sharding contract (distributed/sharding.py): expert-stacked weights have
leading axis E sharded over "model"; router weights replicated.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro import nn
from repro.config import ArchConfig

Params = Dict[str, Any]


def padded_experts(arch: ArchConfig) -> int:
    return max(arch.moe.pad_to, arch.moe.n_experts)


def moe_init(arch: ArchConfig, key) -> Params:
    E = padded_experts(arch)
    d, f = arch.d_model, arch.d_ff
    ks = jax.random.split(key, 4)
    pdt = arch.param_dtype
    s_in = (1.0 / d) ** 0.5
    s_out = (1.0 / f) ** 0.5
    return {
        "router": nn.lecun_normal(ks[0], (d, E), pdt),
        # gated (SwiGLU) experts, stacked on leading expert axis
        "w_gate": (jax.random.normal(ks[1], (E, d, f)) * s_in).astype(pdt),
        "w_up": (jax.random.normal(ks[2], (E, d, f)) * s_in).astype(pdt),
        "w_down": (jax.random.normal(ks[3], (E, f, d)) * s_out).astype(pdt),
    }


def _router(p: Params, arch: ArchConfig, h: jax.Array):
    """h: (B, T, d) -> (weights (B,T,k), idx (B,T,k), probs (B,T,E))."""
    k = arch.moe.top_k
    logits = (h.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, k)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    return w.astype(h.dtype), idx, probs


def moe_apply_dense(p: Params, arch: ArchConfig, h: jax.Array) -> jax.Array:
    """Exact dense-compute dispatch: every expert on every token."""
    E = padded_experts(arch)     # router only emits idx < n_experts
    w, idx, _ = _router(p, arch, h)
    # (B,T,E,f) for all experts
    gate = jnp.einsum("btd,edf->btef", h, p["w_gate"])
    up = jnp.einsum("btd,edf->btef", h, p["w_up"])
    act = jax.nn.silu(gate) * up
    out_e = jnp.einsum("btef,efd->bted", act, p["w_down"])
    combine = jnp.sum(
        jax.nn.one_hot(idx, E, dtype=h.dtype) * w[..., None], axis=2)  # (B,T,E)
    return jnp.einsum("bte,bted->btd", combine, out_e)


def moe_apply_einsum(p: Params, arch: ArchConfig, h: jax.Array) -> jax.Array:
    """Capacity-based einsum dispatch (GShard). Token-major layout."""
    B, T, d = h.shape
    E, k = padded_experts(arch), arch.moe.top_k
    cap = int(T * k / E * arch.moe.capacity_factor) + 1

    w, idx, _ = _router(p, arch, h)                      # (B,T,k)
    # position of each (token, slot) within its expert's buffer
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.int32)     # (B,T,k,E)
    flat = onehot.reshape(B, T * k, E)
    pos = jnp.cumsum(flat, axis=1) - 1                   # (B,T*k,E)
    pos = jnp.sum(pos * flat, axis=-1).reshape(B, T, k)  # slot position
    keep = pos < cap

    disp = (jax.nn.one_hot(idx, E, dtype=h.dtype)[..., :, None]
            * jax.nn.one_hot(pos, cap, dtype=h.dtype)[..., None, :]
            )                                            # (B,T,k,E,cap)
    disp = disp * keep[..., None, None].astype(h.dtype)
    comb = disp * w[..., None, None]                     # gate-weighted

    disp_bt = jnp.sum(disp, axis=2)                      # (B,T,E,cap)
    x_e = jnp.einsum("btec,btd->ebcd", disp_bt, h)       # (E,B,cap,d)
    gate = jnp.einsum("ebcd,edf->ebcf", x_e, p["w_gate"])
    up = jnp.einsum("ebcd,edf->ebcf", x_e, p["w_up"])
    act = jax.nn.silu(gate) * up
    y_e = jnp.einsum("ebcf,efd->ebcd", act, p["w_down"])
    comb_bt = jnp.sum(comb, axis=2)                      # (B,T,E,cap)
    return jnp.einsum("btec,ebcd->btd", comb_bt, y_e)


def moe_apply_gather(p: Params, arch: ArchConfig, h: jax.Array) -> jax.Array:
    """Scatter/gather capacity dispatch — the FLOP-honest production path.

    The one-hot einsum dispatch costs B*T*E*C*d MAC flops (pure index work
    disguised as matmuls; it dominated the compute roofline term of the MoE
    prefill cells by ~50x). Here tokens are scattered into the per-expert
    (E, C, d) buffers with scatter-add (0 flops, bytes = data moved), run
    through the batched expert matmuls (identical FLOPs to the ideal), and
    gathered back with the top-k gate weights. Semantics identical to
    moe_apply_einsum (same capacity drops).
    """
    B, T, d = h.shape
    E, k = padded_experts(arch), arch.moe.top_k
    cap = int(T * k / E * arch.moe.capacity_factor) + 1

    w, idx, _ = _router(p, arch, h)                      # (B,T,k)
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.int32)     # (B,T,k,E)
    flat = onehot.reshape(B, T * k, E)
    pos = jnp.cumsum(flat, axis=1) - 1
    pos = jnp.sum(pos * flat, axis=-1).reshape(B, T, k)  # slot within expert
    keep = pos < cap
    pos_c = jnp.minimum(pos, cap - 1)

    def per_batch(hb, idxb, posb, keepb, wb):
        # scatter tokens into (E, cap, d)
        buf = jnp.zeros((E, cap, d), hb.dtype)
        tok = jnp.repeat(hb, k, axis=0).reshape(T, k, d)
        tok = tok * keepb[..., None].astype(hb.dtype)
        buf = buf.at[idxb.reshape(-1), posb.reshape(-1)].add(
            tok.reshape(-1, d))
        gate = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
        up = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
        y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(gate) * up, p["w_down"])
        # gather back per (token, slot), weight, and sum slots
        out = y[idxb.reshape(-1), posb.reshape(-1)].reshape(T, k, d)
        out = out * (wb * keepb.astype(wb.dtype))[..., None]
        return jnp.sum(out, axis=1)

    return jax.vmap(per_batch)(h, idx, pos_c, keep, w)


def moe_apply_local(p: Params, arch: ArchConfig, h: jax.Array) -> jax.Array:
    """Fully-local MoE: shard_map over the DP axes with REPLICATED expert
    weights — tokens never leave their chip, the dispatch bookkeeping
    (one-hot cumsum slot positions) is computed on the local T*k only, and
    the MoE block contributes ZERO collectives (backward psums the
    replicated expert grads once).

    Wins when experts are small (granite d_ff=512: whole expert stack =
    226 MB/layer bf16) — EP would move orders of magnitude more activation
    bytes than the expert weights occupy. §Perf D7.
    """
    from repro.distributed import compat
    from repro.distributed.sharding import (batch_axes, current_mesh,
                                            in_manual_body)
    from repro.distributed.sharding import make_spec as P_
    mesh = current_mesh()
    if mesh is None or in_manual_body():
        # already inside a fully-manual shard_map (explicit gradient seam):
        # tokens are per-device by construction, dispatch locally
        return moe_apply_gather(p, arch, h)
    ba = batch_axes(mesh)
    if ba is None:
        return moe_apply_gather(p, arch, h)
    if h.shape[0] % compat.axis_size(mesh, ba) != 0:
        return moe_apply_gather(p, arch, h)

    # tokens additionally sharded over "model": the dispatch is local per
    # (batch, T-chunk) so the full chip grid works the experts; capacity
    # applies per chunk (same statistics, chunk-local drops)
    seq_ax = ("model" if "model" in mesh.axis_names
              and h.shape[1] % mesh.shape["model"] == 0 else None)
    hspec = P_(ba, seq_ax, None)
    pspec = jax.tree_util.tree_map(lambda _: P_(), p)
    return compat.shard_map(
        lambda pp, hh: moe_apply_gather(pp, arch, hh),
        mesh=mesh, in_specs=(pspec, hspec), out_specs=hspec,
        check_vma=False)(p, h)


def moe_apply(p: Params, arch: ArchConfig, h: jax.Array,
              path: str = "dense") -> jax.Array:
    if path == "einsum":
        return moe_apply_einsum(p, arch, h)
    if path == "gather":
        return moe_apply_gather(p, arch, h)
    if path == "local":
        return moe_apply_local(p, arch, h)
    return moe_apply_dense(p, arch, h)


def aux_load_balance_loss(p: Params, arch: ArchConfig, h: jax.Array
                          ) -> jax.Array:
    """Switch-style auxiliary loss: E * sum_e (frac_tokens_e * mean_prob_e)."""
    E = arch.moe.n_experts
    _, idx, probs = _router(p, arch, h)
    counts = jnp.mean(jax.nn.one_hot(idx[..., 0], E, dtype=jnp.float32),
                      axis=(0, 1))
    mean_probs = jnp.mean(probs, axis=(0, 1))
    return E * jnp.sum(counts * mean_probs)

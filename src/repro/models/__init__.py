"""Model zoo facade: build_model(arch) returns a uniform functional surface
regardless of family.

    m = build_model(arch)
    params = m.init(key)
    loss   = m.loss(params, batch)            # train objective
    logits, cache = m.decode_step(params, tokens, cache)
    logits, cache = m.prefill(params, tokens, cache[, length])  # parallel
    cache  = m.init_cache(params, batch_size, max_seq[, batch])

``prefill`` runs a whole token chunk through the full-sequence parallel
paths (DEER/ELK solver cascade, associative scans, flash attention) and
lands the resulting recurrent states / KV entries in the cache — the
serving engine's admission path. It is None for families without a chunked
prefill implementation (audio enc-dec).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.config import ArchConfig
from repro.models import encdec, lm


@dataclasses.dataclass(frozen=True)
class Model:
    """Uniform functional model surface (see module docstring).

    ``spec_forward``/``spec_commit`` are the speculative-decoding verify
    seam (lm.py): a read-only (B, k)-window forward over per-slot
    positions returning (logits, staged window artifacts), and the masked
    post-verification commit of the accepted prefix. None for families
    without the seam (audio enc-dec)."""
    arch: ArchConfig
    init: Callable
    loss: Callable
    apply: Callable
    decode_step: Callable
    init_cache: Callable
    prefill: Optional[Callable] = None
    spec_forward: Optional[Callable] = None
    spec_commit: Optional[Callable] = None


def build_model(arch: ArchConfig, moe_path: str = "dense") -> Model:
    """Construct the uniform Model surface for ``arch`` (LM zoo or the
    enc-dec audio family). ``moe_path`` selects the MoE dispatch
    implementation for the LM losses."""
    if arch.family == "audio":
        return Model(
            arch=arch,
            init=lambda key: encdec.init_encdec(arch, key),
            loss=lambda p, b: encdec.encdec_loss(arch, p, b),
            apply=lambda p, b: encdec.decode_train(
                arch, p, b["tokens"], encdec.encode(arch, p, b["frames"])),
            decode_step=lambda p, t, c: encdec.encdec_decode_step(arch, p, t, c),
            init_cache=lambda p, bsz, max_seq, batch=None:
                encdec.init_encdec_cache(
                    arch, p,
                    batch["frames"] if batch is not None else
                    jnp.zeros((bsz, arch.enc_seq, arch.frontend_dim),
                              arch.dtype),
                    max_seq),
        )
    return Model(
        arch=arch,
        init=lambda key: lm.init_lm(arch, key),
        loss=lambda p, b: lm.lm_loss(arch, p, b, moe_path=moe_path),
        apply=lambda p, b: lm.apply_lm(arch, p, b, moe_path=moe_path),
        decode_step=lambda p, t, c: lm.decode_step(arch, p, t, c),
        init_cache=lambda p, bsz, max_seq, batch=None:
            lm.init_cache(arch, bsz, max_seq),
        prefill=lambda p, t, c, length=None: lm.prefill(arch, p, t, c,
                                                        length),
        spec_forward=lambda p, t, c, solver_iters=None:
            lm.spec_forward(arch, p, t, c, solver_iters),
        spec_commit=lambda c, staged, acc: lm.spec_commit(arch, c, staged,
                                                          acc),
    )

"""Sequence mixers for the LM zoo, all built on the same diagonal-recurrence
machinery as the paper's core:

  * mamba1 — selective SSM (falcon-mamba-7b): per-channel diagonal state,
             input-dependent (Delta, B, C). LINEAR recurrence -> one scan.
  * mamba2 — scalar-per-head decay (zamba2-7b): SSD-style, one scan.
  * lrc    — the paper's NONLINEAR LrcSSM as an LM sequence mixer (the
             technique as a first-class framework feature): DEER fixed-point,
             K scans.

All recurrences run through chunked_diag_scan: O(chunk * D) workspace
(VMEM schedule on TPU via kernels/diag_scan), sequential carry across chunks.

Decode: every mixer carries O(D) recurrent state — no KV cache — which is
why ssm/hybrid cells are the only ones allowed at long_500k.

Three execution modes per mixer, dispatched on (state, T):

  * ``state is None``            — full-sequence training forward (parallel
                                   scan / DEER solve, no state returned);
  * ``state`` given, ``T == 1``  — one-token decode (serve tick): O(D)
                                   state update, no scan at all;
  * ``state`` given, ``T > 1``   — PARALLEL PREFILL (serve admission): the
                                   same parallel solve as training but
                                   seeded with the carried state, returning
                                   the state at position ``prefill_len - 1``
                                   (the valid-prompt boundary inside a
                                   padded chunk). This is the scan-for-
                                   prefill / recurrence-for-decode split the
                                   serving engine (repro.serve) is built on.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import nn
from repro.config import ArchConfig, SSMConfig
from repro.core.deer import DeerConfig, deer_solve
from repro.core.scan import chunked_diag_scan, diag_linear_scan
from repro.distributed.sharding import (tp_gather_weight, tp_index, tp_info,
                                        tp_psum, tp_region_in, tp_region_out)

Params = Dict[str, Any]

_dsl = jax.lax.dynamic_slice_in_dim


# ---------------------------------------------------------------------------
# causal depthwise conv (mamba front-end)
# ---------------------------------------------------------------------------

def causal_conv1d(w: jax.Array, b: jax.Array, x: jax.Array) -> jax.Array:
    """x: (B, T, C), w: (W, C) depthwise, left-padded causal."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(W):                     # W is 4: unrolled taps fuse well
        out = out + xp[:, i:i + x.shape[1]] * w[i]
    return out + b


def conv_step(w: jax.Array, b: jax.Array, buf: jax.Array, x_t: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    """Streaming conv for decode. buf: (B, W-1, C) past inputs."""
    window = jnp.concatenate([buf, x_t[:, None]], axis=1)   # (B, W, C)
    y = jnp.einsum("bwc,wc->bc", window, w) + b
    return window[:, 1:], y


def causal_conv1d_prefill(w: jax.Array, b: jax.Array, buf: jax.Array,
                          x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv with carried history (chunked prefill).

    ``buf``: (B, W-1, C) raw inputs preceding this chunk; ``x``: (B, T, C).
    Returns ``(out, xp)`` where ``out`` is the (B, T, C) conv output and
    ``xp`` the (B, T+W-1, C) history-prepended input stream — the caller
    slices the next chunk's buffer out of it at the valid-length boundary
    (``xp[:, L : L+W-1]`` after ``L`` valid tokens).
    """
    W = w.shape[0]
    xp = jnp.concatenate([buf.astype(x.dtype), x], axis=1)
    out = jnp.zeros_like(x)
    for i in range(W):
        out = out + xp[:, i:i + x.shape[1]] * w[i]
    return out + b, xp


def _state_at(traj: jax.Array, length) -> jax.Array:
    """State at position ``length - 1`` of a (B, T, ...) state trajectory
    (the last VALID position of a right-padded prefill chunk). ``length``
    may be a scalar or a (B,) per-row vector (batched multi-request
    admission prefill: every row has its own valid length)."""
    length = jnp.asarray(length)
    if length.ndim == 0:
        return jax.lax.dynamic_index_in_dim(traj, length - 1, axis=1,
                                            keepdims=False)
    idx = (length - 1).reshape((-1,) + (1,) * (traj.ndim - 1))
    idx = jnp.broadcast_to(idx, traj.shape[:1] + (1,) + traj.shape[2:])
    return jnp.take_along_axis(traj, idx, axis=1)[:, 0]


def _history_slice(xp: jax.Array, start, width: int) -> jax.Array:
    """``xp[:, start : start + width]`` with a scalar or per-row (B,)
    ``start`` — the conv-buffer slice at the valid-length boundary."""
    start = jnp.asarray(start)
    if start.ndim == 0:
        return jax.lax.dynamic_slice_in_dim(xp, start, width, axis=1)
    return jax.vmap(lambda row, s: jax.lax.dynamic_slice_in_dim(
        row, s, width, axis=0))(xp, start)


# ---------------------------------------------------------------------------
# Mamba-1 mixer
# ---------------------------------------------------------------------------

def mamba1_dims(arch: ArchConfig):
    d = arch.d_model
    s = arch.ssm
    d_inner = s.expand * d
    dt_rank = max(1, math.ceil(d / 16))
    return d_inner, dt_rank, s.d_state, s.d_conv


def mamba1_init(arch: ArchConfig, key) -> Params:
    d = arch.d_model
    d_inner, dt_rank, N, W = mamba1_dims(arch)
    ks = jax.random.split(key, 6)
    pdt = arch.param_dtype
    A = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None], (d_inner, 1))
    return {
        "in_proj": nn.dense_init(ks[0], d, 2 * d_inner, pdt, bias=False),
        "conv_w": (jax.random.normal(ks[1], (W, d_inner)) * (1.0 / W)).astype(pdt),
        "conv_b": jnp.zeros((d_inner,), pdt),
        "x_proj": nn.dense_init(ks[2], d_inner, dt_rank + 2 * N, pdt, bias=False),
        "dt_proj": nn.dense_init(ks[3], dt_rank, d_inner, pdt),
        "A_log": jnp.log(A).astype(pdt),
        "D": jnp.ones((d_inner,), pdt),
        "out_proj": nn.dense_init(ks[4], d_inner, d, pdt, bias=False),
    }


def mamba1_apply(p: Params, arch: ArchConfig, h: jax.Array,
                 state: Optional[Dict] = None, prefill_len=None,
                 return_traj: bool = False, solver_iters=None):
    """h: (B, T, d). Returns (out, new_state). state holds (ssm (B,di,N),
    conv buffer (B,W-1,di)) for decode/prefill; None => full-sequence mode.
    With state and T > 1 the call is a PREFILL: the selective scan runs in
    parallel from the carried state and ``new_state`` is taken at position
    ``prefill_len - 1`` (default T; scalar or per-row (B,) vector).

    ``return_traj`` (speculative-verify staging) returns, instead of the
    boundary state, the FULL window artifacts: {"ssm": (B,T,di,N) state
    trajectory, "conv": (B,T+W-1,di) history-prepended conv input stream}
    — ``models/lm.spec_commit`` slices both at the per-slot accept
    boundary after verification. ``solver_iters`` is accepted for mixer-API
    uniformity; the linear scan is exact, so it is a no-op here."""
    B, T, _ = h.shape
    d_inner, dt_rank, N, W = mamba1_dims(arch)
    cdt = arch.dtype
    prefill = state is not None and T > 1
    L = T if prefill_len is None else prefill_len

    tp_ax, tp_m = tp_info()
    tp = (tp_ax is not None
          and p["in_proj"]["w"].shape[1] * tp_m == 2 * d_inner)
    if tp:
        # channel-parallel mixer: gather the packed [x|z] in_proj, slice
        # this rank's channel block from each segment; per-channel params
        # (conv, dt_proj cols, D) arrive already sharded by the specs.
        # A_log is (d_inner, N) and stays replicated — slice it behind a
        # tp_region_in seam so its gradient is psum'd back to replicated.
        di_l = d_inner // tp_m
        r = tp_index(tp_ax)
        wf = tp_gather_weight(p["in_proj"]["w"], tp_ax, 1)
        h_t = tp_region_in(h, tp_ax)
        x = h_t @ _dsl(wf, r * di_l, di_l, 1)
        z = h_t @ _dsl(wf, d_inner + r * di_l, di_l, 1)
        A_log = _dsl(tp_region_in(p["A_log"], tp_ax), r * di_l, di_l, 0)
        d_inner = di_l
    else:
        A_log = p["A_log"]
        xz = nn.dense(p["in_proj"], h)
        x, z = jnp.split(xz, 2, axis=-1)

    if state is None:
        x = causal_conv1d(p["conv_w"], p["conv_b"], x)
        conv_buf_new = None
    elif prefill:
        x, xp = causal_conv1d_prefill(p["conv_w"], p["conv_b"],
                                      state["conv"], x)
        conv_buf_new = (xp if return_traj else
                        _history_slice(xp, L, W - 1)
                        .astype(state["conv"].dtype))
    else:
        conv_buf_new, xs = conv_step(p["conv_w"], p["conv_b"], state["conv"],
                                     x[:, 0])
        x = xs[:, None]
    x = jax.nn.silu(x)

    dbc = nn.dense(p["x_proj"], x)
    if tp:
        # row-parallel x_proj: the partial (dt, B, C) sums to the full
        # value and is consumed shard-wise below -> tp_psum seam
        dbc = tp_psum(dbc, tp_ax)
    dt, Bc, Cc = jnp.split(dbc, [dt_rank, dt_rank + N], axis=-1)
    delta = jax.nn.softplus(nn.dense(p["dt_proj"], dt))        # (B,T,di)
    A = -jnp.exp(A_log.astype(jnp.float32))                    # (di,N)

    lam = jnp.exp(delta[..., None].astype(jnp.float32) * A)    # (B,T,di,N)
    beta = (delta[..., None] * Bc[..., None, :] * x[..., None]).astype(jnp.float32)

    if state is None or prefill:
        # (B,T,di,N) scan over T, vmapped over batch; prefill seeds the scan
        # with the carried state (x0) instead of zero
        chunk = 0 if arch.exact_hlo else arch.ssm.chunk
        scan = lambda l, b, x0: chunked_diag_scan(l, b, x0, chunk=chunk)
        if state is None:
            hs = jax.vmap(lambda l, b: scan(l, b, None))(lam, beta)
            ssm_new = None
        else:
            hs = jax.vmap(scan)(lam, beta, state["ssm"])        # (B,T,di,N)
            ssm_new = hs if return_traj else _state_at(hs, L)
    else:
        hs = lam[:, 0] * state["ssm"] + beta[:, 0]              # (B,di,N)
        ssm_new = hs
        hs = hs[:, None]

    y = jnp.einsum("btdn,btn->btd", hs, Cc.astype(jnp.float32))
    y = y.astype(cdt) + p["D"].astype(cdt) * x
    y = y * jax.nn.silu(z)
    out = nn.dense(p["out_proj"], y)
    if tp:
        out = tp_region_out(out, tp_ax)
    new_state = None if state is None else {"conv": conv_buf_new, "ssm": ssm_new}
    return out, new_state


def mamba1_init_state(arch: ArchConfig, batch: int) -> Dict:
    d_inner, _, N, W = mamba1_dims(arch)
    return {"conv": jnp.zeros((batch, W - 1, d_inner), arch.dtype),
            "ssm": jnp.zeros((batch, d_inner, N), jnp.float32)}


# ---------------------------------------------------------------------------
# Mamba-2 mixer (scalar-per-head decay; zamba2)
# ---------------------------------------------------------------------------

def mamba2_dims(arch: ArchConfig):
    d = arch.d_model
    s = arch.ssm
    d_inner = s.expand * d
    n_heads = s.n_heads or d_inner // s.head_dim
    return d_inner, n_heads, s.head_dim, s.d_state, s.d_conv


def mamba2_init(arch: ArchConfig, key) -> Params:
    d = arch.d_model
    d_inner, H, P, N, W = mamba2_dims(arch)
    ks = jax.random.split(key, 4)
    pdt = arch.param_dtype
    # in_proj emits [x (d_inner), z (d_inner), B (N), C (N), dt (H)]
    d_proj = 2 * d_inner + 2 * N + H
    return {
        "in_proj": nn.dense_init(ks[0], d, d_proj, pdt, bias=False),
        "conv_w": (jax.random.normal(ks[1], (W, d_inner + 2 * N)) * 0.25).astype(pdt),
        "conv_b": jnp.zeros((d_inner + 2 * N,), pdt),
        "A_log": jnp.zeros((H,), pdt),
        "dt_bias": jnp.zeros((H,), pdt),
        "D": jnp.ones((H,), pdt),
        "norm": nn.rmsnorm_init(d_inner, pdt),
        "out_proj": nn.dense_init(ks[2], d_inner, d, pdt, bias=False),
    }


def mamba2_apply(p: Params, arch: ArchConfig, h: jax.Array,
                 state: Optional[Dict] = None, prefill_len=None,
                 return_traj: bool = False, solver_iters=None):
    """SSD-style mixer. Same three-mode dispatch as ``mamba1_apply``:
    full-sequence (state None), one-token decode (T == 1), or parallel
    prefill from the carried state (T > 1); ``prefill_len`` scalar or
    per-row, ``return_traj``/``solver_iters`` as in ``mamba1_apply``."""
    B, T, _ = h.shape
    d_inner, H, P, N, W = mamba2_dims(arch)
    cdt = arch.dtype
    prefill = state is not None and T > 1
    L = T if prefill_len is None else prefill_len

    tp_ax, tp_m = tp_info()
    tp = (tp_ax is not None
          and p["in_proj"]["w"].shape[1] * tp_m == 2 * d_inner + 2 * N + H)
    if tp:
        # head-parallel mixer: gather the packed [x|z|B|C|dt] in_proj and
        # slice this rank's channel/head blocks; the B and C segments are
        # SHARED (state dim is per-head-replicated) so every rank keeps
        # them whole — the gather's psum_scatter transpose sums the
        # overlapping cotangents, keeping their gradients exact. The conv
        # weight/bias are packed [x|B|C] the same way.
        d_full, di_l, H_l = d_inner, d_inner // tp_m, H // tp_m
        r = tp_index(tp_ax)
        wf = tp_gather_weight(p["in_proj"]["w"], tp_ax, 1)
        h_t = tp_region_in(h, tp_ax)
        x = h_t @ _dsl(wf, r * di_l, di_l, 1)
        z = h_t @ _dsl(wf, d_full + r * di_l, di_l, 1)
        Bc = h_t @ _dsl(wf, 2 * d_full, N, 1)
        Cc = h_t @ _dsl(wf, 2 * d_full + N, N, 1)
        dt = h_t @ _dsl(wf, 2 * d_full + 2 * N + r * H_l, H_l, 1)
        cwf = tp_gather_weight(p["conv_w"], tp_ax, 1)
        conv_w = jnp.concatenate([_dsl(cwf, r * di_l, di_l, 1),
                                  _dsl(cwf, d_full, 2 * N, 1)], axis=1)
        cbf = tp_gather_weight(p["conv_b"], tp_ax, 0)
        conv_b = jnp.concatenate([_dsl(cbf, r * di_l, di_l, 0),
                                  _dsl(cbf, d_full, 2 * N, 0)], axis=0)
        d_inner, H = di_l, H_l
    else:
        d_full = d_inner
        conv_w, conv_b = p["conv_w"], p["conv_b"]
        proj = nn.dense(p["in_proj"], h)
        x, z, Bc, Cc, dt = jnp.split(
            proj, [d_inner, 2 * d_inner, 2 * d_inner + N,
                   2 * d_inner + 2 * N],
            axis=-1)
    xbc = jnp.concatenate([x, Bc, Cc], axis=-1)
    if state is None:
        xbc = causal_conv1d(conv_w, conv_b, xbc)
        conv_new = None
    elif prefill:
        xbc, xp = causal_conv1d_prefill(conv_w, conv_b,
                                        state["conv"], xbc)
        conv_new = (xp if return_traj else
                    _history_slice(xp, L, W - 1)
                    .astype(state["conv"].dtype))
    else:
        conv_new, xs = conv_step(conv_w, conv_b, state["conv"],
                                 xbc[:, 0])
        xbc = xs[:, None]
    xbc = jax.nn.silu(xbc)
    x, Bc, Cc = jnp.split(xbc, [d_inner, d_inner + N], axis=-1)

    xh = x.reshape(B, -1, H, P)
    delta = jax.nn.softplus(dt + p["dt_bias"]).astype(jnp.float32)  # (B,T,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                    # (H,)
    lam = jnp.exp(delta * A)                                        # (B,T,H)

    # state (B, T, H, P, N): lam broadcast per head; beta = dt * B outer x
    beta = (delta[..., None, None] * Bc.astype(jnp.float32)[:, :, None, None, :]
            * xh.astype(jnp.float32)[..., None])                    # (B,T,H,P,N)
    lam_full = lam[..., None, None]

    if state is None or prefill:
        chunk = 0 if arch.exact_hlo else arch.ssm.chunk
        scan = lambda l, b, x0: chunked_diag_scan(l, b, x0, chunk=chunk)
        lam_b = jnp.broadcast_to(lam_full, beta.shape)
        if state is None:
            hs = jax.vmap(lambda l, b: scan(l, b, None))(lam_b, beta)
            ssm_new = None
        else:
            hs = jax.vmap(scan)(lam_b, beta, state["ssm"])
            ssm_new = hs if return_traj else _state_at(hs, L)
    else:
        hs = lam_full[:, 0] * state["ssm"] + beta[:, 0]
        ssm_new = hs
        hs = hs[:, None]

    y = jnp.einsum("bthpn,btn->bthp", hs, Cc.astype(jnp.float32))
    y = y + p["D"].astype(jnp.float32)[:, None] * xh.astype(jnp.float32)
    y = y.reshape(B, -1, d_inner).astype(cdt)
    g = y * jax.nn.silu(z)
    if tp:
        # the internal RMSNorm reduces over the FULL d_inner: local sum of
        # squares, tp_psum'd across the shards (rank-varying cotangents)
        gf = g.astype(jnp.float32)
        ms = tp_psum(jnp.sum(gf * gf, axis=-1, keepdims=True),
                     tp_ax) / d_full
        y = ((gf * jax.lax.rsqrt(ms + 1e-6))
             * p["norm"]["scale"].astype(jnp.float32)).astype(g.dtype)
    else:
        y = nn.rmsnorm(p["norm"], g)
    out = nn.dense(p["out_proj"], y)
    if tp:
        out = tp_region_out(out, tp_ax)
    new_state = None if state is None else {"conv": conv_new, "ssm": ssm_new}
    return out, new_state


def mamba2_init_state(arch: ArchConfig, batch: int) -> Dict:
    d_inner, H, P, N, W = mamba2_dims(arch)
    return {"conv": jnp.zeros((batch, W - 1, d_inner + 2 * N), arch.dtype),
            "ssm": jnp.zeros((batch, H, P, N), jnp.float32)}


# ---------------------------------------------------------------------------
# LrcSSM mixer — the paper's technique inside an LM block
# ---------------------------------------------------------------------------

def lrc_mixer_init(arch: ArchConfig, key) -> Params:
    """LRC nonlinear SSM as sequence mixer: in_proj -> LRC(D=d_inner) via
    DEER -> gated out_proj. Input features are full-rank in u (two matmuls);
    state coupling is diagonal (the paper's design)."""
    d = arch.d_model
    d_inner = arch.ssm.expand * d
    ks = jax.random.split(key, 5)
    pdt = arch.param_dtype
    return {
        "in_proj": nn.dense_init(ks[0], d, 2 * d_inner, pdt, bias=False),
        # input-dependent gate projections (computed once per sequence)
        "a_u": nn.lecun_normal(ks[1], (d_inner, d_inner), pdt),
        "w_u": nn.lecun_normal(ks[2], (d_inner, d_inner), pdt),
        "b_u": jnp.zeros((d_inner,), pdt),
        "v_u": jnp.zeros((d_inner,), pdt),
        # self-loop (diagonal) state parameters
        "a_x": nn.lecun_normal(ks[3], (d_inner,), pdt, fan_in=1),
        "b_x": jnp.zeros((d_inner,), pdt),
        "g_max_x": jnp.full((d_inner,), 0.5, pdt),
        "k_max_x": jnp.full((d_inner,), 0.5, pdt),
        "g_max_u": jnp.full((d_inner,), 0.5, pdt),
        "k_max_u": jnp.full((d_inner,), 0.5, pdt),
        "w_x": jnp.full((d_inner,), 0.5, pdt),
        "v_x": jnp.zeros((d_inner,), pdt),
        "g_leak": jnp.full((d_inner,), 0.1, pdt),
        "e_leak": jnp.ones((d_inner,), pdt),
        "out_proj": nn.dense_init(ks[4], d_inner, d, pdt, bias=False),
    }


def _lrc_mixer_step(p: Params, x, s_u, eps_u):
    s_x = jax.nn.sigmoid(p["a_x"] * x + p["b_x"])
    f = p["g_max_x"] * s_x + p["g_max_u"] * s_u + p["g_leak"]
    z = p["k_max_x"] * s_x + p["k_max_u"] * s_u + p["g_leak"]
    eps = p["w_x"] * x + p["v_x"] + eps_u
    sig_e = jax.nn.sigmoid(eps)
    lam = 1.0 - jax.nn.sigmoid(f) * sig_e
    beta = jnp.tanh(z) * sig_e * p["e_leak"]
    return lam * x + beta


def lrc_mixer_apply(p: Params, arch: ArchConfig, h: jax.Array,
                    state: Optional[Dict] = None, prefill_len=None,
                    return_traj: bool = False, solver_iters=None):
    """The paper's nonlinear mixer. Full-sequence and prefill modes run the
    DEER Newton solve (sequence-parallel when ``arch.ssm.seq_shard``);
    decode (T == 1) is ONE exact step of the recurrence — the O(D)
    state-cache property the serving engine banks on.

    ``solver_iters`` caps the Newton iteration count below
    ``arch.ssm.deer_iters`` — the speculative-decode DRAFT path (an
    early-exit K=1–2 solve is a cheap predictor of the converged
    trajectory; "predictability enables parallelization"). The VERIFY pass
    always runs at full depth, so truncation never affects emitted tokens.
    ``return_traj`` returns the full (B,T,di) state trajectory instead of
    the boundary state (verify staging; prefill mode only)."""
    B, T, _ = h.shape
    d_inner = arch.ssm.expand * arch.d_model
    cdt = arch.dtype
    prefill = state is not None and T > 1

    tp_ax, tp_m = tp_info()
    tp = (tp_ax is not None
          and p["in_proj"]["w"].shape[1] * tp_m == 2 * d_inner)
    if tp:
        # channel-parallel mixer: z is sliced per rank, but u stays FULL on
        # every rank — the full-rank gate matmuls below consume all of u
        # with column-sharded a_u/w_u, which lands s_u/eps_u on this rank's
        # channels. Per-channel cell params arrive sharded by the specs.
        di_l = d_inner // tp_m
        r = tp_index(tp_ax)
        wf = tp_gather_weight(p["in_proj"]["w"], tp_ax, 1)
        h_t = tp_region_in(h, tp_ax)
        u = h_t @ _dsl(wf, 0, d_inner, 1)
        z = h_t @ _dsl(wf, d_inner + r * di_l, di_l, 1)
        d_inner = di_l
    else:
        xz = nn.dense(p["in_proj"], h)
        u, z = jnp.split(xz, 2, axis=-1)

    # Newton-invariant input features: two matmuls, computed once.
    s_u = jax.nn.sigmoid(u @ p["a_u"] + p["b_u"]).astype(jnp.float32)
    eps_u = (u @ p["w_u"] + p["v_u"]).astype(jnp.float32)

    # Serve-time state quantisation (SSMConfig.state_quant, injected by
    # ServeEngine from its PrecisionPolicy): every recurrence tick is
    # quantize-roundtripped onto the cache storage grid, so decode, chunked
    # prefill and the speculative verify window all walk ONE trajectory —
    # spec decode stays token-identical to quantized greedy, and a slot
    # evicted/re-prefilled mid-stream reproduces the uninterrupted stream.
    # Only serving paths (state is not None) quantise; training is exact.
    # The roundtrip carries an identity JVP (straight-through), so DEER's
    # Newton linearization still sees the true cell Jacobian.
    _sq = arch.ssm.state_quant if state is not None else None
    if _sq is not None:
        from repro.distributed.precision import quantize_roundtrip_rows
        _q = lambda v: quantize_roundtrip_rows(v, _sq,
                                               arch.ssm.state_quant_block)

    if state is None or prefill:
        cell_keys = ("a_x", "b_x", "g_max_x", "k_max_x", "g_max_u",
                     "k_max_u", "w_x", "v_x", "g_leak", "e_leak")
        cell_p = {k: p[k].astype(jnp.float32) for k in cell_keys}
        step = lambda x, fs, cp: _lrc_mixer_step(cp, x, *fs)
        if _sq is not None:
            step = lambda x, fs, cp: _q(_lrc_mixer_step(cp, x, *fs))
        n_iters = arch.ssm.deer_iters
        draft = solver_iters is not None and solver_iters < n_iters
        if draft:
            n_iters = solver_iters
        elif T < n_iters:
            # exactness cap: a full Newton step fixes at least one more
            # timestep per iteration, so DEER is EXACT after T iterations
            # on a length-T window — the k-token verify window never pays
            # the full ladder
            n_iters = T
        dc = DeerConfig(max_iters=n_iters, mode="fixed",
                        grad="implicit",
                        scan_chunk=0 if arch.exact_hlo else arch.ssm.chunk,
                        unroll=arch.exact_hlo)
        x0 = None if state is None else state["ssm"]
        # the quantised step can't fuse into the Pallas tiers (the kernel
        # recurrence has no roundtrip hook) — route through the lax solver
        states = _lrc_solve_trajectory(arch, step, cell_p, s_u, eps_u,
                                       d_inner, dc, x0=x0, draft=draft,
                                       allow_fused=_sq is None)  # (B,T,di)
        if return_traj and state is not None:
            ssm_new = states
        else:
            ssm_new = (None if state is None
                       else _state_at(states, T if prefill_len is None
                                      else prefill_len))
    else:
        states = _lrc_mixer_step(p, state["ssm"], s_u[:, 0], eps_u[:, 0])
        if _sq is not None:
            states = _q(states)
        ssm_new = states
        states = states[:, None]

    y = states.astype(cdt) * jax.nn.silu(z)
    out = nn.dense(p["out_proj"], y)
    if tp:
        out = tp_region_out(out, tp_ax)
    return out, (None if state is None else {"ssm": ssm_new})


def _lrc_solve_trajectory(arch: ArchConfig, step, cell_p, s_u, eps_u,
                          d_inner: int, dc: DeerConfig,
                          x0: Optional[jax.Array] = None,
                          draft: bool = False,
                          allow_fused: bool = True) -> jax.Array:
    """DEER solve of the lrc-mixer trajectory. s_u/eps_u: (B, T, di).
    ``x0``: (B, di) initial state (chunked-prefill carry) or None for zero.
    ``draft`` marks the truncated speculative-draft solve (dc.max_iters
    already capped) — routed through the early-exit kernel entry so the
    fused tier can also skip converged chunks.

    With ``arch.ssm.fused`` the solve routes through the fused Pallas
    tiers (kernels/lrc_deer): the whole-Newton megakernel (replicated) or
    the shard-composable per-iteration kernel (sequence-parallel), both
    with the fused implicit-adjoint backward — so LM training AND prefill
    hit the kernel roofline, not just inference.

    With ``arch.ssm.seq_shard`` and an active mesh carrying a "model" axis
    (the ring-attention convention for the time dimension), the Newton solve
    runs sequence-parallel (core/deer_sharded.py): time over "model", batch
    over the DP axes, per-device trajectory (T/P, B_local, di). When the
    batch CANNOT shard over the DP axes (batch=1 long-sequence cells, the
    long_500k shape), the time axis takes those axes too —
    seq_axis=("data", "model"), mirroring sharded_decode_attention's
    fallback — so the whole mesh still participates. Otherwise: replicated
    solve vmapped over the batch.
    """
    B, T = s_u.shape[0], s_u.shape[1]
    fused = arch.ssm.fused and not arch.exact_hlo and allow_fused
    mesh = seq_axes = ba = None
    if arch.ssm.seq_shard:
        from repro.core.deer_sharded import n_seq_shards
        from repro.distributed import compat
        from repro.distributed.sharding import (batch_axes, current_mesh,
                                                in_manual_body)
        # inside a fully-manual shard_map body (the explicit gradient seam)
        # the solver must not open its own shard_map — run the local tier
        mesh = None if in_manual_body() else current_mesh()
        if mesh is not None and "model" in mesh.axis_names:
            ba = batch_axes(mesh)
            if ba is not None and B % compat.axis_size(mesh, ba) != 0:
                ba = None
            seq_axes = "model"
            if ba is None:
                # batch can't use the DP axes: fold them into time sharding
                wide = tuple(a for a in ("data", "model")
                             if a in mesh.axis_names)
                if len(wide) > 1 and T % n_seq_shards(mesh, wide) == 0:
                    seq_axes = wide
            if T % n_seq_shards(mesh, seq_axes) != 0:
                mesh = seq_axes = None
        else:
            mesh = None

    xb = (jnp.zeros((B, d_inner), jnp.float32) if x0 is None
          else x0.astype(jnp.float32))

    if fused:
        got = _lrc_fused_trajectory(s_u, eps_u, cell_p, xb, dc,
                                    mesh=mesh, seq_axes=seq_axes,
                                    batch_sharded=ba is not None,
                                    draft=draft,
                                    io_dtype=arch.ssm.kernel_io)
        if got is not None:
            return got

    if mesh is not None:
        from repro.core.deer_sharded import sharded_deer_solve
        fused_scan = None
        if fused:
            from repro.kernels.lrc_deer.ops import make_fused_adjoint_scans
            _, fused_scan = make_fused_adjoint_scans(dt=1.0)
        states, _ = sharded_deer_solve(
            step, (jnp.swapaxes(s_u, 0, 1), jnp.swapaxes(eps_u, 0, 1)),
            xb, T, dc, mesh=mesh, seq_axis=seq_axes, params=cell_p,
            batch_axes=ba, fused_scan=fused_scan)
        return jnp.swapaxes(states, 0, 1)
    solve = lambda su, eu, xi: deer_solve(step, (su, eu), xi, T, dc,
                                          params=cell_p)[0]
    return jax.vmap(solve)(s_u, eps_u, xb)


def _lrc_fused_trajectory(s_u, eps_u, cell_p, x0, dc: DeerConfig, *,
                          mesh, seq_axes, batch_sharded: bool,
                          draft: bool = False, io_dtype=None):
    """Fused-kernel route for the lrc mixer: fold the batch into the
    channel axis ((B, T, di) -> (T, B*di); every kernel quantity is
    per-channel elementwise) and run the megakernel (replicated) or the
    shard-composable fused solve (time-sharded, batch replicated).

    Returns None when no fused tier applies — a batch that RIDES SHARDED
    through the lax solver must not be silently replicated by the channel
    fold, so that case falls back to the sharded-lax tier."""
    from repro.kernels.lrc_deer.ops import (fold_channel_batch,
                                            lrc_deer_draft_solve,
                                            lrc_deer_solve,
                                            sharded_fused_viable,
                                            sharded_lrc_deer_solve)
    B, T, di = s_u.shape
    suf, euf, pp, x0f = fold_channel_batch(
        jnp.swapaxes(s_u, 0, 1), jnp.swapaxes(eps_u, 0, 1), cell_p, x0)
    if mesh is not None and not batch_sharded:
        if sharded_fused_viable(T, mesh, seq_axes, D=B * di,
                                n_iters=dc.max_iters):
            states = sharded_lrc_deer_solve(
                suf, euf, pp, x0f, mesh=mesh, seq_axis=seq_axes,
                n_iters=dc.max_iters, io_dtype=io_dtype)
            return jnp.swapaxes(states.reshape(T, B, di), 0, 1)
        return None
    if mesh is not None:
        return None
    if draft:
        states = lrc_deer_draft_solve(suf, euf, pp, x0f,
                                      draft_iters=dc.max_iters)
    else:
        states = lrc_deer_solve(suf, euf, pp, x0f, n_iters=dc.max_iters,
                                io_dtype=io_dtype)
    return jnp.swapaxes(states.reshape(T, B, di), 0, 1)


def lrc_mixer_init_state(arch: ArchConfig, batch: int) -> Dict:
    d_inner = arch.ssm.expand * arch.d_model
    return {"ssm": jnp.zeros((batch, d_inner), jnp.float32)}


MIXERS = {
    "mamba1": (mamba1_init, mamba1_apply, mamba1_init_state),
    "mamba2": (mamba2_init, mamba2_apply, mamba2_init_state),
    "lrc": (lrc_mixer_init, lrc_mixer_apply, lrc_mixer_init_state),
}

"""Encoder-decoder transformer (whisper-base backbone).

The conv/mel frontend is a STUB per the assignment: the model consumes
precomputed frame embeddings (B, enc_seq, d_model) directly. Sinusoidal
positional embeddings, full bidirectional encoder self-attention, causal
decoder self-attention + cross-attention.

Decode caches: decoder self-attn KV ring + STATIC cross-attn KV computed
once at prefill from the encoder output (cross K/V never change during
decoding — the classic enc-dec serving optimisation).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import nn
from repro.config import ArchConfig
from repro.distributed.sharding import shard_activation
from repro.models import attention as attn_lib

Params = Dict[str, Any]


def sinusoidal_pos(T: int, d: int, offset: int = 0) -> jax.Array:
    pos = jnp.arange(offset, offset + T, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None]
    ang = pos / (10000.0 ** (dim / d))
    pe = jnp.zeros((T, d))
    pe = pe.at[:, 0::2].set(jnp.sin(ang))
    pe = pe.at[:, 1::2].set(jnp.cos(ang))
    return pe


def _attn_init(arch: ArchConfig, key, cross: bool = False) -> Params:
    d, H, hd = arch.d_model, arch.n_heads, arch.resolved_head_dim
    k1, k2, k3 = jax.random.split(key, 3)
    pdt = arch.param_dtype
    return {
        "wq": nn.lecun_normal(k1, (d, H * hd), pdt),
        "wkv": nn.lecun_normal(k2, (d, 2 * H * hd), pdt),
        "wo": nn.lecun_normal(k3, (H * hd, d), pdt, fan_in=H * hd),
    }


def _attn(arch: ArchConfig, p: Params, x: jax.Array, kv_src: jax.Array,
          causal: bool) -> jax.Array:
    B, T, _ = x.shape
    H, hd = arch.n_heads, arch.resolved_head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, T, H, hd)
    kv = kv_src @ p["wkv"].astype(x.dtype)
    k, v = jnp.split(kv, 2, axis=-1)
    S = kv_src.shape[1]
    k = k.reshape(B, S, H, hd)
    v = v.reshape(B, S, H, hd)
    o = attn_lib.attention(q, k, v, causal=causal)
    return o.reshape(B, T, H * hd) @ p["wo"].astype(x.dtype)


def _enc_layer_init(arch, key):
    k1, k2 = jax.random.split(key)
    d = arch.d_model
    return {"norm1": nn.layernorm_init(d, arch.param_dtype),
            "attn": _attn_init(arch, k1),
            "norm2": nn.layernorm_init(d, arch.param_dtype),
            "mlp": nn.mlp_init(k2, d, arch.d_ff, d, arch.param_dtype)}


def _dec_layer_init(arch, key):
    k1, k2, k3 = jax.random.split(key, 3)
    d = arch.d_model
    return {"norm1": nn.layernorm_init(d, arch.param_dtype),
            "self_attn": _attn_init(arch, k1),
            "norm2": nn.layernorm_init(d, arch.param_dtype),
            "cross_attn": _attn_init(arch, k2),
            "norm3": nn.layernorm_init(d, arch.param_dtype),
            "mlp": nn.mlp_init(k3, d, arch.d_ff, d, arch.param_dtype)}


def init_encdec(arch: ArchConfig, key) -> Params:
    from repro.models.lm import padded_vocab
    ks = jax.random.split(key, 4 + arch.enc_layers + arch.n_layers)
    d = arch.d_model
    pdt = arch.param_dtype
    return {
        "embed": (jax.random.normal(ks[0], (padded_vocab(arch), d))
                  * d ** -0.5).astype(pdt),
        "enc_layers": [_enc_layer_init(arch, ks[2 + i])
                       for i in range(arch.enc_layers)],
        "enc_norm": nn.layernorm_init(d, pdt),
        "dec_layers": [_dec_layer_init(arch, ks[2 + arch.enc_layers + i])
                       for i in range(arch.n_layers)],
        "dec_norm": nn.layernorm_init(d, pdt),
    }


def encode(arch: ArchConfig, p: Params, frames: jax.Array) -> jax.Array:
    """frames: (B, enc_seq, d_model) precomputed stub embeddings."""
    p = nn.cast_tree(p, arch.dtype)
    x = frames.astype(arch.dtype)
    x = x + sinusoidal_pos(x.shape[1], arch.d_model).astype(x.dtype)
    x = shard_activation(x, "act")
    for lp in p["enc_layers"]:
        x = x + _attn(arch, lp["attn"], nn.layernorm(lp["norm1"], x),
                      nn.layernorm(lp["norm1"], x), causal=False)
        x = x + nn.mlp(lp["mlp"], nn.layernorm(lp["norm2"], x))
    return nn.layernorm(p["enc_norm"], x)


def decode_train(arch: ArchConfig, p: Params, tokens: jax.Array,
                 enc_out: jax.Array) -> jax.Array:
    """Teacher-forced decoder forward: (B, T) tokens -> (B, T, D)."""
    p = nn.cast_tree(p, arch.dtype)
    x = jnp.take(p["embed"], tokens, axis=0).astype(arch.dtype)
    x = x + sinusoidal_pos(x.shape[1], arch.d_model).astype(x.dtype)
    x = shard_activation(x, "act")
    for lp in p["dec_layers"]:
        x = x + _attn(arch, lp["self_attn"], nn.layernorm(lp["norm1"], x),
                      nn.layernorm(lp["norm1"], x), causal=True)
        x = x + _attn(arch, lp["cross_attn"], nn.layernorm(lp["norm2"], x),
                      enc_out, causal=False)
        x = x + nn.mlp(lp["mlp"], nn.layernorm(lp["norm3"], x))
    return nn.layernorm(p["dec_norm"], x)


def encdec_loss(arch: ArchConfig, p: Params, batch: Dict) -> jax.Array:
    from repro.models.lm import _mask_padded_logits
    enc_out = encode(arch, p, batch["frames"])
    h = decode_train(arch, p, batch["tokens"], enc_out)
    logits = _mask_padded_logits(
        (h @ p["embed"].T.astype(h.dtype)).astype(jnp.float32), arch.vocab)
    labels = jnp.pad(batch["tokens"][:, 1:], ((0, 0), (0, 1)),
                     constant_values=-1)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None],
                               axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_encdec_cache(arch: ArchConfig, p: Params, frames: jax.Array,
                      max_seq: int) -> Dict:
    """Prefill: run encoder once, precompute static cross-attn K/V."""
    B = frames.shape[0]
    H, hd = arch.n_heads, arch.resolved_head_dim
    enc_out = encode(arch, p, frames)
    layers = []
    for lp in p["dec_layers"]:
        kv = enc_out @ lp["cross_attn"]["wkv"].astype(enc_out.dtype)
        ck, cv = jnp.split(kv, 2, axis=-1)
        S = enc_out.shape[1]
        layers.append({
            "k": jnp.zeros((B, max_seq, H, hd), arch.dtype),
            "v": jnp.zeros((B, max_seq, H, hd), arch.dtype),
            "ck": ck.reshape(B, S, H, hd),
            "cv": cv.reshape(B, S, H, hd),
        })
    return {"pos": jnp.zeros((), jnp.int32), "layers": layers}


def encdec_decode_step(arch: ArchConfig, p: Params, tokens: jax.Array,
                       cache: Dict) -> Tuple[jax.Array, Dict]:
    """One decoder token. tokens: (B, 1)."""
    p = nn.cast_tree(p, arch.dtype)
    B = tokens.shape[0]
    H, hd = arch.n_heads, arch.resolved_head_dim
    pos = cache["pos"]
    x = jnp.take(p["embed"], tokens, axis=0).astype(arch.dtype)
    x = x + sinusoidal_pos(1, arch.d_model, offset=0).astype(x.dtype)  # static
    new_layers = []
    for lp, cl in zip(p["dec_layers"], cache["layers"]):
        hn = nn.layernorm(lp["norm1"], x)
        q = (hn @ lp["self_attn"]["wq"].astype(x.dtype)).reshape(B, 1, H, hd)
        kv = hn @ lp["self_attn"]["wkv"].astype(x.dtype)
        k, v = jnp.split(kv, 2, axis=-1)
        kc, vc = attn_lib.update_kv_cache(cl["k"], cl["v"],
                                          k.reshape(B, 1, H, hd),
                                          v.reshape(B, 1, H, hd), pos)
        o = attn_lib.decode_attention(q, kc, vc, pos + 1)
        x = x + o.reshape(B, 1, H * hd) @ lp["self_attn"]["wo"].astype(x.dtype)
        hn = nn.layernorm(lp["norm2"], x)
        q = (hn @ lp["cross_attn"]["wq"].astype(x.dtype)).reshape(B, 1, H, hd)
        o = attn_lib.decode_attention(q, cl["ck"], cl["cv"],
                                      cl["ck"].shape[1])
        x = x + o.reshape(B, 1, H * hd) @ lp["cross_attn"]["wo"].astype(x.dtype)
        x = x + nn.mlp(lp["mlp"], nn.layernorm(lp["norm3"], x))
        new_layers.append({**cl, "k": kc, "v": vc})
    x = nn.layernorm(p["dec_norm"], x)
    from repro.models.lm import _mask_padded_logits
    logits = _mask_padded_logits(x @ p["embed"].T.astype(x.dtype),
                                 arch.vocab)
    return logits, {"pos": pos + 1, "layers": new_layers}

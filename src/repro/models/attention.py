"""Attention substrate: GQA + RoPE + causal/sliding-window masks, a
flash-style KV-chunked implementation for long prefill, and the cached
decode step.

Memory discipline: materialising a (T, T) score matrix at prefill_32k would
be 32768^2 * heads * batch elements — the chunked path keeps the working set
at (T, kv_chunk) with running max/denominator (online softmax), the same
blocking the Pallas kernel (kernels/flash_attn) uses on TPU; XLA fuses each
chunk iteration into a bounded-footprint loop body.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import compat

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., T, H, hd); positions: (..., T)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                        # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., T, hd/2)
    cos = jnp.cos(ang)[..., None, :]                     # (..., T, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# full / chunked attention
# ---------------------------------------------------------------------------

def _repeat_kv(k: jax.Array, groups: int) -> jax.Array:
    """(B, S, K, hd) -> (B, S, K*groups, hd) head-replication for GQA."""
    if groups == 1:
        return k
    return jnp.repeat(k, groups, axis=2)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: Optional[int] = None,
              q_offset: int = 0, kv_chunk: int = 1024) -> jax.Array:
    """GQA attention. q: (B, T, H, hd); k, v: (B, S, K, hd), H % K == 0.

    ``window``: sliding-window width (gemma3 local layers); None = full.
    ``q_offset``: absolute position of q[0] relative to k[0] (prefill
    continuation); q position i attends to kv positions <= q_offset + i.
    Uses the online-softmax KV-chunked schedule when S > kv_chunk.
    """
    B, T, H, hd = q.shape
    S, K = k.shape[1], k.shape[2]
    groups = H // K
    scale = hd ** -0.5

    q32 = q.astype(jnp.float32) * scale
    kh = _repeat_kv(k, groups).astype(jnp.float32)
    vh = _repeat_kv(v, groups).astype(jnp.float32)

    if S <= kv_chunk:
        scores = jnp.einsum("bthd,bshd->bhts", q32, kh)
        scores = _mask(scores, T, S, q_offset, causal, window)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhts,bshd->bthd", probs, vh)
        return out.astype(q.dtype)

    # flash-style online softmax over KV chunks
    n_chunks = -(-S // kv_chunk)
    pad = n_chunks * kv_chunk - S
    if pad:
        kh = jnp.pad(kh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vh = jnp.pad(vh, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kh = kh.reshape(B, n_chunks, kv_chunk, H, hd)
    vh = vh.reshape(B, n_chunks, kv_chunk, H, hd)

    def body(carry, inputs):
        m_prev, l_prev, acc = carry
        kc, vc, cidx = inputs
        scores = jnp.einsum("bthd,bshd->bhts", q32, kc)   # (B,H,T,chunk)
        kv_pos = cidx * kv_chunk + jnp.arange(kv_chunk)
        q_pos = q_offset + jnp.arange(T)
        mask = kv_pos[None, :] <= q_pos[:, None] if causal else \
            jnp.ones((T, kv_chunk), bool)
        mask = jnp.logical_and(mask, kv_pos[None, :] < S)
        if window is not None:
            mask = jnp.logical_and(mask,
                                   kv_pos[None, :] > q_pos[:, None] - window)
        scores = jnp.where(mask[None, None], scores, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1))
        p = jnp.exp(scores - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bhts,bshd->bhtd", p, vc)
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, H, T), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, T), jnp.float32)
    a0 = jnp.zeros((B, H, T, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (kh.transpose(1, 0, 2, 3, 4), vh.transpose(1, 0, 2, 3, 4),
         jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)     # (B,T,H,hd)


def _mask(scores, T, S, q_offset, causal, window):
    q_pos = q_offset + jnp.arange(T)
    kv_pos = jnp.arange(S)
    m = jnp.ones((T, S), bool)
    if causal:
        m = kv_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m = jnp.logical_and(m, kv_pos[None, :] > q_pos[:, None] - window)
    return jnp.where(m[None, None], scores, NEG_INF)


# ---------------------------------------------------------------------------
# ring attention (sequence parallelism over the "model" mesh axis)
# ---------------------------------------------------------------------------

def ring_attention(q: jax.Array, k: jax.Array, v: jax.Array, *, mesh,
                   axis: str = "model", causal: bool = True) -> jax.Array:
    """Sequence-parallel attention: the time axis of q/k/v is sharded over
    ``axis`` (P shards). Each shard flash-accumulates against its local KV
    block, then the KV blocks rotate around the ring (collective-permute)
    P-1 times.

    Wire volume per chip: (P-1)/P * |K|+|V| bytes per layer — versus the
    Megatron activation all-reduce of 2 * 2 * |activations| per block. For
    long prefill (T >> d) this is the decisive win recorded in
    EXPERIMENTS.md §Perf; the permutes also overlap with the local block
    matmuls (XLA async collective-permute).

    q: (B, T, H, hd); k, v: (B, T, K, hd) with H % K == 0 — the RAW kv heads
    rotate around the ring (GQA repetition happens inside each local block:
    rotating pre-repeated heads would multiply the wire volume by H/K —
    the B2 -> B5 iteration in EXPERIMENTS.md §Perf).
    """
    n_shards = mesh.shape[axis]

    def local_fn(qs, ks, vs):
        idx = compat.axis_index(axis)
        B, Tl, H, hd = qs.shape
        groups = H // ks.shape[2]
        scale = hd ** -0.5
        q32 = qs.astype(jnp.float32) * scale
        m = jnp.full((B, H, Tl), NEG_INF, jnp.float32)
        l = jnp.zeros((B, H, Tl), jnp.float32)
        acc = jnp.zeros((B, H, Tl, hd), jnp.float32)
        q_pos = idx * Tl + jnp.arange(Tl)

        ks_cur, vs_cur = ks, vs
        for s in range(n_shards):
            kv_idx = (idx - s) % n_shards
            kv_pos = kv_idx * Tl + jnp.arange(Tl)
            scores = jnp.einsum("bthd,bshd->bhts", q32,
                                _repeat_kv(ks_cur, groups).astype(jnp.float32))
            if causal:
                mask = kv_pos[None, :] <= q_pos[:, None]
                scores = jnp.where(mask[None, None], scores, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(scores, axis=-1))
            p = jnp.exp(scores - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhts,bshd->bhtd", p,
                _repeat_kv(vs_cur, groups).astype(jnp.float32))
            m = m_new
            if s < n_shards - 1:
                perm = [(i, (i + 1) % n_shards) for i in range(n_shards)]
                ks_cur = compat.ppermute(ks_cur, axis, perm)
                vs_cur = compat.ppermute(vs_cur, axis, perm)
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.transpose(0, 2, 1, 3).astype(qs.dtype)

    from repro.distributed.sharding import make_spec as P_
    # batch stays sharded over the DP axes INSIDE the shard_map — an
    # in_spec of None there would force an all-gather of the batch (the
    # B2-ring refuted-iteration bug: 16x redundant compute + gathers)
    ba = tuple(a for a in ("pod", "data") if a in mesh.axis_names) or None
    spec = P_(ba, axis, None, None)
    return compat.shard_map(local_fn, mesh=mesh,
                            in_specs=(spec, spec, spec),
                            out_specs=spec)(q, k, v)


# ---------------------------------------------------------------------------
# sequence-sharded decode attention (shard_map; TP over context)
# ---------------------------------------------------------------------------

def sharded_decode_attention(q: jax.Array, k_cache: jax.Array,
                             v_cache: jax.Array, k_new: jax.Array,
                             v_new: jax.Array, slot: jax.Array,
                             eff_len: jax.Array, *, mesh,
                             axis: str = "model"):
    """One-token decode against a SEQUENCE-sharded KV cache, fully manual.

    The cache's time axis is sharded over ``axis``; the owning shard writes
    the new (k, v) at ``slot``; every shard computes partial scores over its
    context slice; the softmax combines with three tiny collectives
    (pmax (B,H), psum (B,H), psum (B,H,hd)) — versus GSPMD's
    involuntary full-cache fp32 regather (§Perf C).

    q: (B,1,H,hd); caches: (B,S,K,hd); k_new/v_new: (B,1,K,hd).
    Returns (out (B,1,H,hd), k_cache, v_cache).
    """
    axes = axis if isinstance(axis, tuple) else (axis,)
    n_shards = compat.axis_size(mesh, axes)
    S = k_cache.shape[1]
    S_loc = S // n_shards

    def local_fn(qs, kc, vc, kn, vn, slot_, eff_):
        idx = compat.axis_index(axes)
        B, _, H, hd = qs.shape
        K = kc.shape[2]
        groups = H // K
        # masked owner write
        owner = (slot_ // S_loc) == idx
        lpos = slot_ % S_loc
        kc_w = jax.lax.dynamic_update_slice_in_dim(
            kc, kn.astype(kc.dtype), lpos, axis=1)
        vc_w = jax.lax.dynamic_update_slice_in_dim(
            vc, vn.astype(vc.dtype), lpos, axis=1)
        kc = jnp.where(owner, kc_w, kc)
        vc = jnp.where(owner, vc_w, vc)
        # partial attention over the local context slice
        kh = _repeat_kv(kc, groups).astype(jnp.float32)
        vh = _repeat_kv(vc, groups).astype(jnp.float32)
        q32 = qs.astype(jnp.float32) * hd ** -0.5
        scores = jnp.einsum("bthd,bshd->bhts", q32, kh)[:, :, 0]  # (B,H,Sl)
        gpos = idx * S_loc + jnp.arange(S_loc)
        valid = gpos[None, :] < jnp.asarray(eff_).reshape(-1, 1)
        scores = jnp.where(valid[:, None, :], scores, NEG_INF)
        m_loc = jnp.max(scores, axis=-1)
        m = compat.pmax(m_loc, axes)                       # (B,H) tiny
        p = jnp.exp(scores - m[..., None])
        l = compat.psum(jnp.sum(p, axis=-1), axes)         # (B,H) tiny
        o = compat.psum(jnp.einsum("bhs,bshd->bhd", p, vh), axes)
        out = (o / jnp.maximum(l[..., None], 1e-30))[:, None]
        return out.astype(qs.dtype), kc, vc

    from repro.distributed.sharding import make_spec as P_
    B = q.shape[0]
    ba = tuple(a for a in ("pod", "data") if a in mesh.axis_names
               and a not in axes) or None
    if ba is not None and B % compat.axis_size(mesh, ba) != 0:
        ba = None
    rep = P_(ba, None, None, None)
    shd = P_(ba, axis, None, None)
    return compat.shard_map(
        local_fn, mesh=mesh,
        in_specs=(rep, shd, shd, rep, rep, P_(), P_()),
        out_specs=(rep, shd, shd))(
            q, k_cache, v_cache, k_new, v_new,
            jnp.asarray(slot), jnp.asarray(eff_len))


# ---------------------------------------------------------------------------
# decode step with KV cache
# ---------------------------------------------------------------------------

def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array, *, window: Optional[int] = None
                     ) -> jax.Array:
    """One-token decode. q: (B, 1, H, hd); caches: (B, S, K, hd) with valid
    prefix of length cache_len (per-batch scalar or python int). Cost is
    O(S * H * hd) — linear in context, the memory-bound regime the roofline
    analysis shows dominating decode cells.
    """
    B, _, H, hd = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    groups = H // K
    scale = hd ** -0.5

    q32 = q.astype(jnp.float32) * scale
    kh = _repeat_kv(k_cache, groups).astype(jnp.float32)
    vh = _repeat_kv(v_cache, groups).astype(jnp.float32)
    scores = jnp.einsum("bthd,bshd->bhts", q32, kh)[:, :, 0]   # (B,H,S)
    kv_pos = jnp.arange(S)
    valid = kv_pos[None, :] < jnp.asarray(cache_len).reshape(-1, 1)
    if window is not None:
        valid = jnp.logical_and(
            valid, kv_pos[None, :] >= jnp.asarray(cache_len).reshape(-1, 1) - window)
    scores = jnp.where(valid[:, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhs,bshd->bhd", probs, vh)
    return out[:, None].astype(q.dtype)                         # (B,1,H,hd)


def update_kv_cache(k_cache: jax.Array, v_cache: jax.Array,
                    k_new: jax.Array, v_new: jax.Array,
                    cache_len) -> Tuple[jax.Array, jax.Array]:
    """Insert (B, 1, K, hd) new entries at position cache_len."""
    idx = jnp.asarray(cache_len)
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k_new.astype(k_cache.dtype), idx, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v_new.astype(v_cache.dtype), idx, axis=1)
    return k_cache, v_cache


def update_kv_cache_rows(k_cache: jax.Array, v_cache: jax.Array,
                         k_new: jax.Array, v_new: jax.Array,
                         slots: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-row cache insert for continuous batching: each batch row writes
    its (1, K, hd) entry at its OWN position ``slots[b]`` — decode slots in a
    serving batch sit at different sequence positions, so a single
    batch-wide dynamic_update_slice cannot express the write."""
    upd = jax.vmap(lambda c, n, s: jax.lax.dynamic_update_slice_in_dim(
        c, n.astype(c.dtype), s, axis=0))
    return upd(k_cache, k_new, slots), upd(v_cache, v_new, slots)


def spec_window_attention(q: jax.Array, k_cache: jax.Array,
                          v_cache: jax.Array, k_new: jax.Array,
                          v_new: jax.Array, pos: jax.Array, *,
                          ring: bool = False) -> jax.Array:
    """Speculative-verify attention for a k-token window per serve slot,
    READ-ONLY against the cache.

    Query i of row b sits at absolute position ``pos[b] + i`` and attends
    [the row's committed cache entries] ++ [the window's own k/v up to i].
    Nothing is written: the accepted prefix length depends on the FINAL
    logits, so cache commits happen post-hoc (``models/lm.spec_commit``)
    rather than layer-by-layer.

    ``ring=True`` gives sliding-window semantics over an S-slot ring where
    absolute position p lives at slot p % S and the effective window is S
    (the same convention decode/prefill use): slot j of row b holds
    absolute position ``pos_b - 1 - ((pos_b - 1 - j) mod S)``, masked to
    >= 0 (written) and > q_abs - S (in window). ``ring=False`` is the
    full-context cache: slots 0..pos_b-1 are valid (always causal, since
    every committed position precedes every query).

    q: (B, k, H, hd); caches: (B, S, K, hd); k_new/v_new: (B, k, K, hd);
    pos: (B,) int32. Requires k <= S. Returns out (B, k, H, hd).
    """
    B, T, H, hd = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    groups = H // K
    pos = jnp.asarray(pos)

    kh = _repeat_kv(jnp.concatenate([k_cache.astype(k_new.dtype), k_new],
                                    axis=1), groups).astype(jnp.float32)
    vh = _repeat_kv(jnp.concatenate([v_cache.astype(v_new.dtype), v_new],
                                    axis=1), groups).astype(jnp.float32)
    q32 = q.astype(jnp.float32) * hd ** -0.5
    scores = jnp.einsum("bthd,bshd->bhts", q32, kh)      # (B,H,k,S+k)

    q_abs = pos[:, None] + jnp.arange(T)[None]           # (B,k)
    j = jnp.arange(S)
    if ring:
        a = pos[:, None] - 1 - jnp.mod(pos[:, None] - 1 - j[None, :], S)
        cache_mask = ((a[:, None, :] >= 0)
                      & (a[:, None, :] > q_abs[:, :, None] - S))
    else:
        cache_mask = jnp.broadcast_to(
            (j[None, None, :] < pos[:, None, None]), (B, T, S))
    li, qi = jnp.arange(T)[None, :], jnp.arange(T)[:, None]
    win_mask = li <= qi
    if ring:
        win_mask = win_mask & (li > qi - S)
    win_mask = jnp.broadcast_to(win_mask[None], (B, T, T))
    mask = jnp.concatenate([cache_mask, win_mask], axis=-1)

    scores = jnp.where(mask[:, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhts,bshd->bthd", probs, vh)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# chunked prefill against caches (the serving engine's admission path)
# ---------------------------------------------------------------------------

def prefill_full_attention(q: jax.Array, k_cache: jax.Array,
                           v_cache: jax.Array, k_new: jax.Array,
                           v_new: jax.Array, pos, *,
                           kv_chunk: int = 1024
                           ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Prefill a T-token chunk at absolute positions ``pos..pos+T-1``
    against a full-context KV cache: write the chunk's k/v at ``pos``, then
    attend causally over the whole cache (earlier chunks included; unwritten
    tail positions are masked out by causality). Returns
    (out (B,T,H,hd), k_cache, v_cache)."""
    pos = jnp.asarray(pos)
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k_new.astype(k_cache.dtype), pos, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v_new.astype(v_cache.dtype), pos, axis=1)
    out = attention(q, k_cache, v_cache, causal=True, q_offset=pos,
                    kv_chunk=kv_chunk)
    return out, k_cache, v_cache


def prefill_ring_attention(q: jax.Array, k_cache: jax.Array,
                           v_cache: jax.Array, k_new: jax.Array,
                           v_new: jax.Array, pos, length=None
                           ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Prefill a T-token chunk through a sliding-window RING cache of S
    slots (absolute position p lives at slot p % S, effective window = S —
    the same semantics the decode path uses).

    Attention runs against [the S-1 ring entries preceding the chunk] ++
    [the chunk's own k/v], with explicit validity masking (absolute
    position >= 0, causal, within-window) — the ring is only written
    AFTERWARDS, because the chunk's writes overwrite exactly the history
    slots its own early queries still need. ``length`` (default T) is the
    valid token count of a right-padded chunk: unlike the full-context
    cache, padding garbage written into the ring would WRAP onto live
    window slots, so only the last min(S, length) valid positions are
    committed. ``length`` may be a (B,) vector (batched multi-request
    admission: every row carries its own valid length; the write turns
    per-row). Returns (out (B,T,H,hd), k_cache, v_cache)."""
    B, T, H, hd = q.shape
    S = k_cache.shape[1]
    K = k_cache.shape[2]
    groups = H // K
    pos = jnp.asarray(pos)

    hist_abs = pos - (S - 1) + jnp.arange(S - 1)          # (S-1,) absolute
    ring_idx = jnp.mod(hist_abs, S)
    k_hist = jnp.take(k_cache, ring_idx, axis=1)
    v_hist = jnp.take(v_cache, ring_idx, axis=1)
    k_ctx = jnp.concatenate([k_hist.astype(k_new.dtype), k_new], axis=1)
    v_ctx = jnp.concatenate([v_hist.astype(v_new.dtype), v_new], axis=1)
    abs_kv = jnp.concatenate([hist_abs, pos + jnp.arange(T)])  # (S-1+T,)
    q_abs = pos + jnp.arange(T)                                # (T,)

    kh = _repeat_kv(k_ctx, groups).astype(jnp.float32)
    vh = _repeat_kv(v_ctx, groups).astype(jnp.float32)
    q32 = q.astype(jnp.float32) * hd ** -0.5
    scores = jnp.einsum("bthd,bshd->bhts", q32, kh)
    mask = ((abs_kv[None, :] >= 0)
            & (abs_kv[None, :] <= q_abs[:, None])
            & (abs_kv[None, :] > q_abs[:, None] - S))
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhts,bshd->bthd", probs, vh).astype(q.dtype)

    # ring write: the last min(S, length) VALID chunk positions (earlier
    # ones share residues — writing them would make the scatter
    # order-dependent; padded ones would wrap onto live window slots)
    L = T if length is None else jnp.asarray(length)
    n_keep = min(T, S)
    if getattr(L, "ndim", 0) > 0:
        # per-row valid lengths: each row picks its own slice of the chunk
        # and its own ring slots — vmapped single-row writes
        def row_write(cache_row, new_row, Lb):
            start_b = jnp.clip(Lb - n_keep, 0, T - n_keep)
            idx_b = start_b + jnp.arange(n_keep)
            wslots_b = jnp.mod(pos + idx_b, S)
            valid_b = (idx_b < Lb)[:, None, None]
            sel = jax.lax.dynamic_slice_in_dim(new_row, start_b, n_keep,
                                               axis=0)
            return cache_row.at[wslots_b].set(
                jnp.where(valid_b, sel.astype(cache_row.dtype),
                          jnp.take(cache_row, wslots_b, axis=0)))
        k_cache = jax.vmap(row_write, in_axes=(0, 0, 0))(k_cache, k_new, L)
        v_cache = jax.vmap(row_write, in_axes=(0, 0, 0))(v_cache, v_new, L)
        return out, k_cache, v_cache
    start = jnp.clip(L - n_keep, 0, T - n_keep)
    idx = start + jnp.arange(n_keep)                      # chunk-local
    wslots = jnp.mod(pos + idx, S)                        # unique: contiguous
    valid = (idx < L)[None, :, None, None]
    k_sel = jax.lax.dynamic_slice_in_dim(k_new, start, n_keep, axis=1)
    v_sel = jax.lax.dynamic_slice_in_dim(v_new, start, n_keep, axis=1)
    k_cache = k_cache.at[:, wslots].set(
        jnp.where(valid, k_sel.astype(k_cache.dtype),
                  jnp.take(k_cache, wslots, axis=1)))
    v_cache = v_cache.at[:, wslots].set(
        jnp.where(valid, v_sel.astype(v_cache.dtype),
                  jnp.take(v_cache, wslots, axis=1)))
    return out, k_cache, v_cache

"""falcon-mamba-7b [ssm]: 64L d_model=4096 (attn-free) vocab=65024,
ssm_state=16 — Mamba-1 architecture [arXiv:2410.05355; unverified].
d_inner = 2*4096 = 8192, dt_rank = ceil(4096/16) = 256, conv width 4.
Attention-free: every layer is the selective-scan mixer built on the same
chunked diagonal scan as the paper's DEER solver. Sub-quadratic ->
long_500k runs (O(D) state decode).
"""
from repro.config import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=65024,
    norm="rmsnorm", rope_theta=0.0,
    ssm=SSMConfig(kind="mamba1", d_state=16, d_conv=4, expand=2, chunk=256),
    subquadratic=True,
)

REDUCED = ArchConfig(
    name="falcon-mamba-7b-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=512,
    norm="rmsnorm", rope_theta=0.0,
    ssm=SSMConfig(kind="mamba1", d_state=4, d_conv=4, expand=2, chunk=16),
    subquadratic=True,
)

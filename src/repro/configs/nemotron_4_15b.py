"""nemotron-4-15b [dense]: 32L d_model=6144 48H (GQA kv=8) d_ff=24576
vocab=256000 — GQA, squared-ReLU [arXiv:2402.16819; unverified].
Plain (non-gated) squared-ReLU MLP, LayerNorm, RoPE. 256k vocabulary makes
the embedding/lm_head the TP-sharding stress case. Full attention ->
no long_500k.
"""
from repro.config import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-15b", family="dense",
    n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=24576, vocab=256000,
    act="squared_relu", norm="layernorm", rope_theta=10000.0,
    subquadratic=False,
)

REDUCED = ArchConfig(
    name="nemotron-4-15b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
    d_ff=256, vocab=1024,
    act="squared_relu", norm="layernorm", rope_theta=10000.0,
    subquadratic=False,
)

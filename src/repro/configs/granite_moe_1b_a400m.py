"""granite-moe-1b-a400m [moe]: 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]. SwiGLU experts, RMSNorm,
tied embeddings. Expert axis shards over "model" (EP). Full attention ->
no long_500k.
"""
from repro.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8,
    d_ff=512, vocab=49155,
    act="silu", norm="rmsnorm", rope_theta=10000.0,
    moe=MoEConfig(n_experts=32, top_k=8), tie_embeddings=True,
    subquadratic=False,
)

REDUCED = ArchConfig(
    name="granite-moe-1b-a400m-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=32, vocab=512,
    act="silu", norm="rmsnorm", rope_theta=10000.0,
    moe=MoEConfig(n_experts=4, top_k=2), tie_embeddings=True,
    subquadratic=False,
)

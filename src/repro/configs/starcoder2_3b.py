"""starcoder2-3b [dense]: 30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152 — GQA, RoPE [arXiv:2402.19173; hf]. Plain-GELU MLP, LayerNorm,
learned biases per the released model. Full attention -> no long_500k.
"""
from repro.config import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2,
    d_ff=12288, vocab=49152,
    act="gelu", norm="layernorm", rope_theta=1e5,
    subquadratic=False,
)

REDUCED = ArchConfig(
    name="starcoder2-3b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=256, vocab=512,
    act="gelu", norm="layernorm", rope_theta=1e5,
    subquadratic=False,
)

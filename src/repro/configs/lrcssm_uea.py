"""The paper's own architecture: LrcSSM sequence classifier (Figure 1),
per-dataset tuned hyperparameters from Table 5.
"""
from repro.core.block import LrcSSMConfig
from repro.core.deer import DeerConfig

# Table 5 optimised hyperparameters (lr handled by the trainer)
TABLE5 = {
    # name: (input_size, n_classes, seq_len, hidden, state, blocks, lr)
    "heartbeat": (61, 2, 405, 64, 64, 4, 1e-3),
    "scp1": (6, 2, 896, 64, 16, 2, 1e-3),
    "scp2": (7, 2, 1152, 128, 64, 2, 1e-3),
    "ethanol": (2, 4, 1751, 128, 16, 2, 1e-4),
    "motor": (63, 2, 3000, 16, 16, 4, 1e-4),
    "worms": (6, 5, 17984, 64, 16, 4, 1e-4),
}


def uea_config(dataset: str, **overrides) -> LrcSSMConfig:
    p, n_cls, _, hidden, state, blocks, _ = TABLE5[dataset]
    kw = dict(d_input=p, d_hidden=hidden, d_state=state, n_blocks=blocks,
              n_classes=n_cls, cell="lrc", solver="deer",
              deer=DeerConfig(max_iters=12, mode="fixed", grad="implicit"))
    kw.update(overrides)
    return LrcSSMConfig(**kw)


def uea_seq_len(dataset: str) -> int:
    return TABLE5[dataset][2]


def uea_lr(dataset: str) -> float:
    return TABLE5[dataset][6]


# fixed ablation setup (Tables 2, 8-11): 6 blocks x 64 units, encoder 64
def ablation_config(cell: str = "lrc", d_input: int = 6, n_classes: int = 2,
                    **overrides) -> LrcSSMConfig:
    kw = dict(d_input=d_input, d_hidden=64, d_state=64, n_blocks=6,
              n_classes=n_classes, cell=cell, solver="deer",
              deer=DeerConfig(max_iters=12, mode="fixed", grad="implicit"))
    kw.update(overrides)
    return LrcSSMConfig(**kw)


CONFIG = uea_config("worms")      # longest-horizon benchmark as default
REDUCED = uea_config("scp1", d_hidden=16, d_state=8, n_blocks=2)

"""zamba2-7b [hybrid]: 81L d_model=3584 32H (GQA kv=32) d_ff=14336
vocab=32000, ssm_state=64 — Mamba-2 blocks + SHARED attention block
[arXiv:2411.15242; unverified].

Mapping: every 6th layer is followed by the shared transformer block
(one set of attention+MLP weights reused at each application — zamba's
parameter-sharing design). 81 = 13 groups of 6 + 3 trailing mamba2 layers.
Mamba-2: head_dim 64, d_state 64, scalar-per-head decay. Sub-quadratic
backbone -> long_500k runs (global-attn share has its own full cache but
is 1-in-6 and weight-shared).
"""
from repro.config import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab=32000,
    act="silu", norm="rmsnorm", rope_theta=10000.0,
    ssm=SSMConfig(kind="mamba2", d_state=64, d_conv=4, expand=2,
                  head_dim=64, chunk=256),
    hybrid_period=6,
    subquadratic=True,
)

REDUCED = ArchConfig(
    name="zamba2-7b-smoke", family="hybrid",
    n_layers=7, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=512,
    act="silu", norm="rmsnorm", rope_theta=10000.0,
    ssm=SSMConfig(kind="mamba2", d_state=8, d_conv=4, expand=2,
                  head_dim=16, chunk=16),
    hybrid_period=3,
    subquadratic=True,
)

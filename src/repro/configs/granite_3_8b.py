"""granite-3-8b [dense]: 40L d_model=4096 32H (GQA kv=8) d_ff=12800
vocab=49155 — GQA [hf:ibm-granite/granite-3.0-2b-base; hf]. SwiGLU, RMSNorm,
RoPE, tied embeddings (granite 3.0 ties embed/lm_head). Full attention ->
no long_500k.
"""
from repro.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-8b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=12800, vocab=49155,
    act="silu", norm="rmsnorm", rope_theta=10000.0,
    tie_embeddings=True,
    subquadratic=False,
)

REDUCED = ArchConfig(
    name="granite-3-8b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=192, vocab=512,
    act="silu", norm="rmsnorm", rope_theta=10000.0,
    tie_embeddings=True,
    subquadratic=False,
)

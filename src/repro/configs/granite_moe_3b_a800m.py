"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8) d_ff=512
vocab=49155, MoE 40 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]. 40 experts on a 16-way
model axis is the uneven-EP stress case (2.5 experts/chip -> GSPMD pads);
see EXPERIMENTS.md §Perf for the padded-vs-replicated trade-off. Full
attention -> no long_500k.
"""
from repro.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
    d_ff=512, vocab=49155,
    act="silu", norm="rmsnorm", rope_theta=10000.0,
    moe=MoEConfig(n_experts=40, top_k=8, pad_to=48), tie_embeddings=True,
    subquadratic=False,
)

REDUCED = ArchConfig(
    name="granite-moe-3b-a800m-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=32, vocab=512,
    act="silu", norm="rmsnorm", rope_theta=10000.0,
    moe=MoEConfig(n_experts=5, top_k=2), tie_embeddings=True,
    subquadratic=False,
)

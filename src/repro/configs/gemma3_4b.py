"""gemma3-4b [dense]: 34L d_model=2560 8H (GQA kv=4) d_ff=10240
vocab=262144 — 5:1 local:global attention, 128k context
[hf:google/gemma-3-1b-pt; unverified]. head_dim=256 (explicit; not
d_model/H), GeGLU, RMSNorm. window_pattern=(1024, 5): five sliding-window
(1024) layers per global layer.

long_500k IS run for this arch: decode-time cost is dominated by the local
layers' bounded ring caches; only the 1-in-6 global layers keep full 512k
KV (see DESIGN.md §Arch-applicability).
"""
from repro.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b", family="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4,
    d_ff=10240, vocab=262144, head_dim=256,
    act="gelu_tanh", norm="rmsnorm", rope_theta=1e6,
    window_pattern=(1024, 5), tie_embeddings=True,
    subquadratic=True,   # 5/6 of layers are sliding-window
)

REDUCED = ArchConfig(
    name="gemma3-4b-smoke", family="dense",
    n_layers=7, d_model=48, n_heads=4, n_kv_heads=2,
    d_ff=96, vocab=512, head_dim=16,
    act="gelu_tanh", norm="rmsnorm", rope_theta=1e6,
    window_pattern=(8, 5), tie_embeddings=True,
    subquadratic=True,
)

"""whisper-base [audio]: 6L d_model=512 8H d_ff=2048 vocab=51865 — enc-dec,
conv frontend STUB [arXiv:2212.04356; unverified].

6 encoder + 6 decoder layers. The mel-spectrogram conv frontend is a stub:
input_specs() provides precomputed frame embeddings (B, 1500, 512).
Sinusoidal positions (rope_theta=0). Enc-dec with full attention ->
long_500k skipped; decode shapes exercise decoder self-attn KV cache +
static cross-attn cache.
"""
from repro.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base", family="audio",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab=51865,
    act="gelu", norm="layernorm", rope_theta=0.0,
    enc_layers=6, enc_seq=1500, frontend_dim=512,
    subquadratic=False,
)

REDUCED = ArchConfig(
    name="whisper-base-smoke", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=512,
    act="gelu", norm="layernorm", rope_theta=0.0,
    enc_layers=2, enc_seq=32, frontend_dim=64,
    subquadratic=False,
)

"""internvl2-26b [vlm]: InternViT-6B frontend (STUB) + InternLM2-20B backbone.

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553 [arXiv:2404.16821; hf].
The vision frontend is a stub: input_specs() supplies precomputed patch
embeddings (n_patches=256 per image, d_vit=3200 = InternViT-6B hidden);
a 2-layer MLP projector maps them into the LM embedding space.
Full attention everywhere -> long_500k cell skipped (DESIGN.md).
"""
from repro.config import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab=92553,
    act="silu", norm="rmsnorm", rope_theta=1e6,
    frontend_dim=3200, frontend_tokens=256,
    subquadratic=False,
)

REDUCED = ArchConfig(
    name="internvl2-26b-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=512,
    act="silu", norm="rmsnorm", rope_theta=1e6,
    frontend_dim=48, frontend_tokens=8,
    subquadratic=False,
)

"""Architecture registry: one module per assigned architecture.

``get_config(name)`` returns the full-size ArchConfig (dry-run only —
never allocated on CPU); ``get_reduced(name)`` returns the same-family
smoke-test config (small widths/depths, tiny vocab) that runs a real
forward/train step on one CPU device.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import List

ARCH_NAMES: List[str] = [
    "internvl2_26b",
    "starcoder2_3b",
    "nemotron_4_15b",
    "granite_3_8b",
    "gemma3_4b",
    "falcon_mamba_7b",
    "granite_moe_1b_a400m",
    "granite_moe_3b_a800m",
    "zamba2_7b",
    "whisper_base",
    # the paper's own architecture (UEA classifier) — not an LM cell
    "lrcssm_uea",
]


def _mod(name: str):
    return importlib.import_module(f"repro.configs.{name.replace('-', '_')}")


def get_config(name: str):
    return _mod(name).CONFIG


def get_reduced(name: str):
    return _mod(name).REDUCED


def list_archs() -> List[str]:
    return [n for n in ARCH_NAMES if n != "lrcssm_uea"]

"""Data pipeline: deterministic, shardable, restart-safe synthetic sources.

Two source families:
  * ``TokenTaskSource`` — synthetic LM corpora with learnable structure
    (Zipfian unigrams + copy/induction patterns) so example trainers show a
    real, decreasing loss rather than log(V) noise.
  * ``UEALikeSource``  — multivariate time-series classification generators
    matching the UEA benchmark geometry (channels, seq lengths, classes of
    Table 1) with class-dependent temporal dynamics: long-horizon tasks
    place their class signal in slow frequencies / long-range correlations
    so models must carry state across thousands of steps (the paper's
    setting, reproducible offline).

Determinism contract: batch i of epoch e is a pure function of
(seed, e, i) — a restarted job (checkpoint/restore) resumes mid-epoch with
identical data. Sharding: each source yields GLOBAL batches; the trainer
places them against the mesh (host-local slicing is a thin wrapper,
``shard_for_mesh``).

Fault injection rides the same contract: ``reliability.FaultySource``
wraps any ``batch_at`` source and poisons scheduled steps with values
that are themselves a pure function of (fault seed, step) — so a chaos
run replays bit-identically, and the preempt-resume bit-exactness
scenarios hold with injection active (tools/chaos_suite.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# LM token source
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TokenTaskSource:
    vocab: int
    seq_len: int
    batch: int
    seed: int = 0
    induction: bool = True     # plant copy patterns (learnable signal)

    def batch_at(self, step: int) -> Dict[str, jax.Array]:
        rng = np.random.default_rng((self.seed, step))
        # Zipfian unigram distribution
        ranks = np.arange(1, self.vocab + 1)
        probs = 1.0 / ranks
        probs /= probs.sum()
        toks = rng.choice(self.vocab, size=(self.batch, self.seq_len),
                          p=probs).astype(np.int32)
        if self.induction and self.seq_len >= 8:
            # repeat a prefix span later in the sequence: A B ... A B
            span = self.seq_len // 4
            start2 = self.seq_len // 2
            toks[:, start2:start2 + span] = toks[:, :span]
        labels = np.pad(toks[:, 1:], ((0, 0), (0, 1)), constant_values=-1)
        return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


# ---------------------------------------------------------------------------
# UEA-like classification source
# ---------------------------------------------------------------------------

UEA_GEOMETRY = {
    # name: (seq_len, channels, classes) — Table 1
    "heartbeat": (405, 61, 2),
    "scp1": (896, 6, 2),
    "scp2": (1152, 7, 2),
    "ethanol": (1751, 2, 4),
    "motor": (3000, 63, 2),
    "worms": (17984, 6, 5),
}


@dataclasses.dataclass
class UEALikeSource:
    """Class signal = class-specific slow oscillation + class-specific AR(1)
    long-memory channel correlation, buried in noise. Long-horizon datasets
    get proportionally slower class frequencies, so only models that
    integrate state over the full sequence separate the classes."""
    dataset: str
    batch: int
    seed: int = 0
    seq_len: Optional[int] = None     # override (reduced-scale tests)
    noise: float = 1.0

    def geometry(self) -> Tuple[int, int, int]:
        T, C, K = UEA_GEOMETRY[self.dataset]
        return (self.seq_len or T, C, K)

    def batch_at(self, step: int) -> Tuple[jax.Array, jax.Array]:
        T, C, K = self.geometry()
        rng = np.random.default_rng((self.seed, step))
        y = rng.integers(0, K, size=(self.batch,))
        t = np.arange(T) / T
        x = rng.normal(0, self.noise, size=(self.batch, T, C)).astype(np.float32)
        for i in range(self.batch):
            k = y[i]
            # slow class oscillation on a rotating subset of channels
            freq = 1.5 + k                      # cycles over the WHOLE sequence
            phase = rng.uniform(0, 2 * np.pi)
            ch = (np.arange(C) + k) % C < max(C // 2, 1)
            x[i, :, ch] += 0.8 * np.sin(2 * np.pi * freq * t + phase)
            # class-dependent AR(1) memory in channel 0
            a = 0.9 + 0.015 * k
            e = rng.normal(0, 0.3, size=T)
            ar = np.zeros(T)
            for tt in range(1, T):
                ar[tt] = a * ar[tt - 1] + e[tt]
            x[i, :, 0] += ar.astype(np.float32)
        return jnp.asarray(x), jnp.asarray(y.astype(np.int32))

    def splits(self, n_train: int, n_test: int, split_seed: int = 0):
        """Deterministic train/test split batches (paper's 5-seed protocol)."""
        src_tr = dataclasses.replace(self, seed=(self.seed * 1000 + split_seed))
        src_te = dataclasses.replace(self,
                                     seed=(self.seed * 1000 + split_seed + 500))
        xs, ys = [], []
        bs = self.batch
        for s in range(-(-n_train // bs)):
            x, y = src_tr.batch_at(s)
            xs.append(x), ys.append(y)
        xtr = jnp.concatenate(xs)[:n_train]
        ytr = jnp.concatenate(ys)[:n_train]
        xs, ys = [], []
        for s in range(-(-n_test // bs)):
            x, y = src_te.batch_at(s)
            xs.append(x), ys.append(y)
        return (xtr, ytr), (jnp.concatenate(xs)[:n_test],
                            jnp.concatenate(ys)[:n_test])


def shard_for_mesh(batch, mesh, specs):
    """Place a host-global batch against the mesh with the given specs."""
    from jax.sharding import NamedSharding
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), batch, specs)

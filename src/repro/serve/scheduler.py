"""SLO-aware serve scheduler: batched admission + prefill/decode
interleaving over the continuous-batching engine.

The engine (``serve/engine.py``) knows HOW to admit and decode; this
module decides WHEN. Each ``tick()``:

  1. spends the PREFILL BUDGET — up to ``prefill_budget`` batched
     admission launches (``engine._admit(max_prefills=...)``), each one
     popping the longest FIFO prefix of equal-chunk-count requests and
     prefilling them in ONE parallel launch;
  2. runs one batched decode tick (plain or speculative) for every
     active slot.

The budget is the prefill/decode interleaving knob: prefill launches are
long (whole prompt chunks through the parallel solvers) and every queued
admission stalls all active decode streams for that long — the classic
continuous-batching head-of-line problem. ``decode_slo_ms`` makes the
budget ADAPTIVE: while the recent decode-tick p50 exceeds the SLO and
slots are active, admission is paused entirely (budget 0) so decode
catches up; drained slots always re-open admission (starvation-proof:
with no active slots there is nothing to protect, so the budget is
always spent).

All scheduling state is host-side bookkeeping over the engine's public
surface — the device-side tick shapes are untouched, so the scheduler
adds zero compiles.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional

import numpy as np

from repro.serve.engine import (EngineStalledError, QueueFullError, Request,
                                ServeEngine)


@dataclasses.dataclass(frozen=True)
class SLOConfig:
    """Scheduler knobs.

    ``decode_slo_ms``: target per-decode-tick p50 latency; 0 disables the
    adaptive admission pause. ``prefill_budget``: max batched-admission
    launches per tick. ``admit_batch``: cap on requests per admission
    launch (0 = fill all free slots). ``window``: number of recent decode
    samples the SLO comparison looks at."""
    decode_slo_ms: float = 0.0
    prefill_budget: int = 1
    admit_batch: int = 0
    window: int = 16


class SLOScheduler:
    """Drives a ``ServeEngine`` tick-by-tick under an ``SLOConfig``,
    recording queue-depth and admission-wait statistics alongside the
    engine's latency percentiles."""

    def __init__(self, engine: ServeEngine, cfg: SLOConfig = SLOConfig()):
        self.engine = engine
        self.cfg = cfg
        self.queue_depth: deque = deque(maxlen=65536)
        self.admit_wait: deque = deque(maxlen=65536)
        self._submit_t: Dict[int, float] = {}
        self._queued: Dict[int, Request] = {}
        self.rejected = 0

    # -- submission ---------------------------------------------------------

    def submit(self, req: Request) -> bool:
        """Queue a request on the engine, stamping its arrival for the
        admission-wait statistic. Backpressure rejects (bounded engine
        queue at capacity) are absorbed here into a counted, structured
        outcome: returns False with ``req.status == "rejected"`` instead
        of propagating ``QueueFullError`` — the scheduler IS the layer
        that decides what load-shedding looks like."""
        try:
            self.engine.submit(req)
        except QueueFullError:
            self.rejected += 1
            return False
        self._submit_t[req.uid] = time.perf_counter()
        self._queued[req.uid] = req
        return True

    def _note_departures(self) -> None:
        """Record admission wait for every request that left the engine
        queue since the last tick (admitted OR completed-at-admission)."""
        still = {r.uid for r in self.engine.queue}
        now = time.perf_counter()
        for uid in list(self._queued):
            if uid not in still:
                self.admit_wait.append(now - self._submit_t.pop(uid))
                del self._queued[uid]

    # -- the tick -----------------------------------------------------------

    def _decode_p50_ms(self) -> Optional[float]:
        lat = self.engine.token_lat["decode"]
        if not lat:
            return None
        recent = list(lat)[-self.cfg.window:]
        return float(np.percentile(np.asarray(recent), 50)) * 1e3

    def tick(self) -> int:
        """One scheduled engine tick; returns active-slot count."""
        budget = self.cfg.prefill_budget
        any_active = any(r is not None for r in self.engine.active)
        if self.cfg.decode_slo_ms > 0 and any_active:
            p50 = self._decode_p50_ms()
            if p50 is not None and p50 > self.cfg.decode_slo_ms:
                budget = 0           # decode is over SLO: pause admission
        if budget > 0:
            self.engine._admit(max_prefills=budget,
                               max_batch=self.cfg.admit_batch or None)
            self._note_departures()
        self.queue_depth.append(len(self.engine.queue))
        return self.engine.step(admit=False)

    def run_until_drained(self, max_ticks: int = 100_000):
        """Tick until queue and slots drain; returns engine.finished.
        Raises ``EngineStalledError`` (same contract as the engine's own
        drain loop) when the tick budget runs out with work pending."""
        for _ in range(max_ticks):
            self.tick()
            if (not self.engine.queue
                    and not any(r is not None for r in self.engine.active)):
                return self.engine.finished
        if (self.engine.queue
                or any(r is not None for r in self.engine.active)):
            raise EngineStalledError(
                max_ticks, len(self.engine.queue),
                sum(r is not None for r in self.engine.active))
        return self.engine.finished

    # -- stats --------------------------------------------------------------

    def stats(self) -> Dict[str, float]:
        """Engine latency percentiles + scheduler queue/admission stats +
        degradation counters (rejects, expiries, failures, quarantines) +
        speculative accept rate (when the engine runs speculative)."""
        out: Dict[str, float] = dict(self.engine.latency_percentiles())
        ev = self.engine.events
        out["rejected"] = float(ev.count("queue_reject"))
        out["expired"] = float(ev.count("expired"))
        out["failed"] = float(ev.count("failed"))
        out["quarantined"] = float(ev.count("slot_quarantine"))
        if self.queue_depth:
            q = np.asarray(list(self.queue_depth))
            out["queue_depth_p50"] = float(np.percentile(q, 50))
            out["queue_depth_max"] = float(q.max())
        if self.admit_wait:
            w = np.asarray(list(self.admit_wait))
            out["admit_wait_p50_s"] = float(np.percentile(w, 50))
            out["admit_wait_p99_s"] = float(np.percentile(w, 99))
        ss = self.engine.spec_stats
        if ss["draft_tokens"]:
            out["accept_rate"] = ss["accepted_tokens"] / ss["draft_tokens"]
            out["draft_tokens"] = float(ss["draft_tokens"])
            out["accepted_tokens"] = float(ss["accepted_tokens"])
            out["verify_calls"] = float(ss["verify_calls"])
        return out

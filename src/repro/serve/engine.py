"""Batched serving engine: continuous-batching decode loop over a shared
KV/state cache.

Production shape: requests arrive with prompts; the engine packs them into
a fixed batch of decode slots, prefills each prompt into its slot, then
steps all slots together (one serve_step per token). Finished slots (EOS or
max_tokens) are immediately recycled for queued requests — continuous
batching. SSM-family models hold O(D) state per slot, so slot recycling is a
cache reset, not an eviction decision.

This runs for real at reduced scale on CPU (tests/test_serve.py) and lowers
at production scale via the dry-run decode cells.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray               # (T,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model: Model, params, batch_slots: int = 4,
                 max_seq: int = 256):
        self.model = model
        self.params = params
        self.slots = batch_slots
        self.max_seq = max_seq
        self.queue: deque[Request] = deque()
        self.active: List[Optional[Request]] = [None] * batch_slots
        self.cache = model.init_cache(params, batch_slots, max_seq)
        self._decode = jax.jit(model.decode_step)
        self._slot_pos = np.zeros(batch_slots, np.int32)

    def submit(self, req: Request):
        self.queue.append(req)

    def _prefill_slot(self, slot: int, req: Request):
        """Prefill by stepping the prompt token-by-token into slot state.

        Single-cache-per-batch design: caches are batched, so per-slot
        prefill steps the whole batch with masked writes. At production
        scale this is the dedicated prefill graph (dry-run prefill cells);
        here we reuse the decode graph for simplicity and exactness.
        """
        for t in range(len(req.prompt) - 1):
            tok = np.zeros((self.slots, 1), np.int32)
            tok[slot, 0] = req.prompt[t]
            _, self.cache = self._decode(self.params, jnp.asarray(tok),
                                         self.cache)

    def step(self) -> int:
        """One engine tick: schedule, decode one token for every active slot.
        Returns number of active slots."""
        # schedule waiting requests into free slots
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                req = self.queue.popleft()
                self._prefill_slot(s, req)
                self.active[s] = req
                self._slot_pos[s] = len(req.prompt) - 1

        if not any(self.active):
            return 0

        tok = np.zeros((self.slots, 1), np.int32)
        for s, req in enumerate(self.active):
            if req is None:
                continue
            if req.out_tokens:
                tok[s, 0] = req.out_tokens[-1]
            else:
                tok[s, 0] = req.prompt[-1]
        logits, self.cache = self._decode(self.params, jnp.asarray(tok),
                                          self.cache)
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        n_active = 0
        for s, req in enumerate(self.active):
            if req is None:
                continue
            req.out_tokens.append(int(nxt[s]))
            if (len(req.out_tokens) >= req.max_new_tokens or
                    (req.eos_id is not None and int(nxt[s]) == req.eos_id)):
                req.done = True
                self.active[s] = None     # recycle slot (continuous batching)
            else:
                n_active += 1
        return n_active

    def run_until_drained(self, max_ticks: int = 10_000) -> List[Request]:
        finished: List[Request] = []
        seen: set = set()
        for _ in range(max_ticks):
            self.step()
            for req in list(self.queue) + self.active:
                pass
            if not self.queue and not any(self.active):
                break
        return finished

"""Continuous-batching stateful serving engine.

Requests arrive with prompts on a host admission queue; the engine owns a
fixed budget of decode SLOTS (``serve/cache.py``) and interleaves two
compute shapes:

  * **parallel prefill** (admission): the prompt runs through
    ``model.prefill`` in fixed-size chunks — each chunk is ONE parallel
    solve (DEER/ELK cascade for lrc mixers, associative selective scans for
    mamba, flash attention for attention layers; sequence-sharded when the
    model config asks for it), never a token-by-token loop — and the
    resulting O(D)-per-layer state fragment is scattered into a free slot.
  * **batched decode** (``step()``): one jit-compiled tick
    (``serve/decode.py``) advances EVERY active slot by one token,
    regardless of how far apart their sequence positions are (per-slot
    ``pos`` vector).

Finished slots (EOS / token budget) are recycled immediately — continuous
batching. Eviction (``evict``) is the state-cache counterpart of KV-cache
preemption: because a slot is O(D) re-derivable state, evicting costs ZERO
cache bytes — the request just re-queues with its generated tokens folded
into the prompt and is re-prefilled (in parallel) on re-admission.

Tokens stream to the caller through per-request ``on_token`` callbacks,
invoked in generation order within a request and in slot order within a
tick.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model
from repro.serve.cache import StateCache
from repro.serve.decode import make_decode_step


@dataclasses.dataclass
class Request:
    """One serving request: prompt in, streamed greedy tokens out.

    ``on_token(uid, token, done)`` fires once per generated token, in
    order; ``done`` is True exactly once (the final token). ``out_tokens``
    accumulates the same tokens for callers that prefer polling."""
    uid: int
    prompt: np.ndarray               # (T,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    on_token: Optional[Callable[[int, int, bool], None]] = None
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Continuous-batching scheduler over a fixed slot budget.

    ``batch_slots`` bounds concurrent decode streams; ``prefill_chunk`` is
    the admission chunk length (prompts are right-padded to a multiple, so
    every chunk shares one compiled prefill); ``mesh`` routes the decode
    tick through ``train/step.jit_step``'s sharded serve wiring."""

    def __init__(self, model: Model, params, batch_slots: int = 4,
                 max_seq: int = 256, prefill_chunk: int = 32, mesh=None,
                 policy=None):
        if policy is not None and mesh is None:
            mesh = policy.build_mesh()
        self.policy = policy
        if model.prefill is None:
            raise ValueError(f"model family {model.arch.family!r} has no "
                             "chunked-prefill implementation — the serve "
                             "engine requires Model.prefill")
        self.model = model
        self.params = params
        self.slots = batch_slots
        self.max_seq = max_seq
        self.prefill_chunk = prefill_chunk
        self.queue: deque[Request] = deque()
        self.active: List[Optional[Request]] = [None] * batch_slots
        self.finished: deque = deque(maxlen=65536)
        self.cache = StateCache(model, params, batch_slots, max_seq)
        self._decode = make_decode_step(model, params, self.cache.cache,
                                        mesh=mesh, batch_size=batch_slots)
        self._prefill = jax.jit(
            lambda p, t, c, l: model.prefill(p, t, c, l))
        self._last_tok = np.zeros((batch_slots, 1), np.int32)
        # per-token wall-clock samples: "prefill" covers each request's
        # first token (admission cost), "decode" one batched tick. Bounded
        # (and `finished` too) so a long-running server does not grow
        # host memory linearly with tokens served.
        self.token_lat: Dict[str, deque] = {
            "prefill": deque(maxlen=4096), "decode": deque(maxlen=4096)}

    # -- admission ----------------------------------------------------------

    def submit(self, req: Request) -> None:
        """Queue a request (FIFO). Validates it fits the slot geometry,
        INCLUDING prefill-chunk padding: the worst-case prefill feed is the
        prompt plus all-but-one generated token (an eviction just before
        completion), rounded up to a chunk multiple — a padded chunk
        writing past ``max_seq`` would clamp its dynamic-slice start and
        corrupt valid cache entries, so it is rejected here instead."""
        if len(req.prompt) == 0:
            raise ValueError(f"request {req.uid}: empty prompt (prefill "
                             "needs at least one token to condition on)")
        need = len(req.prompt) + req.max_new_tokens
        C = self.prefill_chunk
        worst_feed = len(req.prompt) + max(req.max_new_tokens - 1, 0)
        worst_padded = -(-worst_feed // C) * C
        if need > self.max_seq or worst_padded > self.max_seq:
            raise ValueError(
                f"request {req.uid}: prompt ({len(req.prompt)}) + "
                f"max_new_tokens ({req.max_new_tokens}) needs "
                f"{max(need, worst_padded)} cache positions (incl. "
                f"prefill_chunk={C} padding) but max_seq={self.max_seq}; "
                f"raise max_seq or lower prefill_chunk")
        self.queue.append(req)

    def _prefill_request(self, req: Request):
        """Run the request's feed (prompt + any already-generated tokens —
        the eviction/re-admission path) through chunked parallel prefill.
        Returns (batch=1 cache fragment, first generated token)."""
        feed = np.concatenate(
            [np.asarray(req.prompt, np.int32),
             np.asarray(req.out_tokens, np.int32)])
        L = len(feed)
        C = self.prefill_chunk
        n_chunks = max(1, -(-L // C))
        padded = np.zeros(n_chunks * C, np.int32)
        padded[:L] = feed
        frag = self.model.init_cache(self.params, 1, self.max_seq)
        logits = valid = None
        for ci in range(n_chunks):
            chunk = jnp.asarray(padded[None, ci * C:(ci + 1) * C])
            valid = min(C, L - ci * C)
            logits, frag = self._prefill(self.params, chunk, frag,
                                         jnp.asarray(valid, jnp.int32))
        # deliberate host boundary: one sync per ADMISSION (not per step) —
        # the first token feeds host-side slot bookkeeping and callbacks
        first_tok = int(jnp.argmax(logits[0, valid - 1]))  # repro-lint: disable=host-sync
        return frag, first_tok

    def _emit(self, req: Request, tok: int) -> bool:
        """Record one generated token; fire the stream callback; returns
        (and latches) the request's done state."""
        req.out_tokens.append(tok)
        done = (len(req.out_tokens) >= req.max_new_tokens
                or (req.eos_id is not None and tok == req.eos_id))
        req.done = done
        if req.on_token is not None:
            req.on_token(req.uid, tok, done)
        if done:
            self.finished.append(req)
        return done

    def _admit(self) -> None:
        """Fill free slots from the queue: prefill + scatter + first token."""
        while self.queue and self.cache.n_free > 0:
            req = self.queue.popleft()
            slot = self.cache.alloc()
            t0 = time.perf_counter()
            frag, first_tok = self._prefill_request(req)
            self.cache.write_slot(slot, frag)
            self.token_lat["prefill"].append(time.perf_counter() - t0)
            if self._emit(req, first_tok):
                self.cache.free(slot)          # one-token request
            else:
                self.active[slot] = req
                self._last_tok[slot, 0] = first_tok

    # -- the tick -----------------------------------------------------------

    def step(self) -> int:
        """One engine tick: admit waiting requests, then one batched decode
        advancing every active slot. Returns the number of slots that were
        active this tick (0 = fully drained)."""
        self._admit()
        act = [s for s, r in enumerate(self.active) if r is not None]
        if not act:
            return 0
        t0 = time.perf_counter()
        next_tok, _, new_cache = self._decode(
            self.params, jnp.asarray(self._last_tok), self.cache.cache)
        self.cache.cache = new_cache
        nxt = np.asarray(next_tok)
        wall = time.perf_counter() - t0
        for s in act:
            req = self.active[s]
            tok = int(nxt[s, 0])
            self.token_lat["decode"].append(wall)
            if self._emit(req, tok):
                self.active[s] = None          # recycle: continuous batching
                self.cache.free(s)
            else:
                self._last_tok[s, 0] = tok
        return len(act)

    def evict(self, slot: int) -> Request:
        """Preempt ``slot``: the in-flight request re-queues at the FRONT of
        the admission queue with its generated tokens folded into the
        prompt feed. No cache bytes move — the O(D) state is re-derived by
        parallel prefill on re-admission (the state-cache eviction story;
        contrast with KV-cache preemption, which must either transfer the
        whole ring or replay the sequence)."""
        req = self.active[slot]
        if req is None:
            raise ValueError(f"slot {slot} is not active")
        self.active[slot] = None
        self.cache.free(slot)
        self.queue.appendleft(req)
        return req

    def run_until_drained(self, max_ticks: int = 10_000) -> "deque[Request]":
        """Tick until the queue and all slots are empty; returns the
        finished-requests deque (completion order, bounded retention)."""
        for _ in range(max_ticks):
            self.step()
            if not self.queue and not any(r is not None for r in self.active):
                break
        return self.finished

    # -- stats --------------------------------------------------------------

    def latency_percentiles(self) -> Dict[str, float]:
        """p50/p99 per-token wall-clock latency over decode ticks (and p50
        admission latency), in seconds — the benchmark's record format."""
        out: Dict[str, float] = {}
        if self.token_lat["decode"]:
            d = np.asarray(list(self.token_lat["decode"]))
            out["decode_p50_s"] = float(np.percentile(d, 50))
            out["decode_p99_s"] = float(np.percentile(d, 99))
        if self.token_lat["prefill"]:
            p = np.asarray(list(self.token_lat["prefill"]))
            out["prefill_p50_s"] = float(np.percentile(p, 50))
        return out

"""Continuous-batching stateful serving engine.

Requests arrive with prompts on a host admission queue; the engine owns a
fixed budget of decode SLOTS (``serve/cache.py``) and interleaves two
compute shapes:

  * **parallel prefill** (admission): the prompt runs through
    ``model.prefill`` in fixed-size chunks — each chunk is ONE parallel
    solve (DEER/ELK cascade for lrc mixers, associative selective scans for
    mamba, flash attention for attention layers; sequence-sharded when the
    model config asks for it), never a token-by-token loop — and the
    resulting O(D)-per-layer state fragment is scattered into a free slot.
  * **batched decode** (``step()``): one jit-compiled tick
    (``serve/decode.py``) advances EVERY active slot by one token,
    regardless of how far apart their sequence positions are (per-slot
    ``pos`` vector).

Finished slots (EOS / token budget) are recycled immediately — continuous
batching. Eviction (``evict``) is the state-cache counterpart of KV-cache
preemption: because a slot is O(D) re-derivable state, evicting costs ZERO
cache bytes — the request just re-queues with its generated tokens folded
into the prompt and is re-prefilled (in parallel) on re-admission.

Tokens stream to the caller through per-request ``on_token`` callbacks,
invoked in generation order within a request and in slot order within a
tick.

**Self-speculative decoding** (``SpecConfig``): instead of one token per
tick, the engine carries k-1 DRAFT tokens per slot and verifies the whole
(B, k) window in ONE prefill-style parallel solve (``serve/decode.
make_verify_step`` over ``models/lm.spec_forward``). The longest draft
prefix matching the model's own greedy continuation is accepted (always
>= 1 token — never slower than plain decode in tokens per tick);
rejected-tail state is simply never committed, so rollback is free and
bit-exact, and the emitted stream is token-identical to sequential greedy
decode. Drafts come either from the previous window's verified leftovers
("reuse" — zero extra compute, the Jacobi warm start) or from an
early-exit truncated-Newton forward ("solve" — ``draft_iters`` on the
DEER ladder).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.precision import (PrecisionPolicy, dequantize_weights,
                                         is_quantized, quantize_params,
                                         tree_state_bytes)
from repro.distributed.sharding import _path_str
from repro.models import Model, build_model
from repro.reliability.events import EventLog
from repro.serve.cache import StateCache, batch_axis_for
from repro.serve.decode import make_decode_step, make_verify_step


class QueueFullError(RuntimeError):
    """Structured admission reject: the bounded queue is at capacity.

    Carries the request uid and the queue depth at reject time so the
    caller (or the SLOScheduler, which counts these) can shed load
    deliberately instead of growing host memory without bound."""

    def __init__(self, uid: int, depth: int, max_queue: int):
        super().__init__(
            f"request {uid} rejected: admission queue at capacity "
            f"({depth}/{max_queue}) — backpressure, resubmit later")
        self.uid = uid
        self.depth = depth
        self.max_queue = max_queue


class EngineStalledError(RuntimeError):
    """``run_until_drained`` exhausted its tick budget with work still
    pending — a stall (wedged admission, hold-backed retries, a tick
    budget sized too small), never a silent return. Carries a structured
    report of what was left."""

    def __init__(self, ticks: int, queued: int, active: int):
        super().__init__(
            f"engine stalled: {queued} queued + {active} active requests "
            f"after {ticks} ticks (raise max_ticks or inspect "
            "engine.events for the degradation trail)")
        self.ticks = ticks
        self.queued = queued
        self.active = active


def _make_slot_health(slots: int):
    """Build the watchdog's per-slot health predicate (jitted by the
    engine): AND of ``isfinite`` over every float cache leaf, reduced over
    all axes except the leaf's slot axis (``batch_axis_for``), plus the
    raw ``pos`` vector for the host-side progress check. Quantized leaves
    are checked through their scales (integer payloads cannot be
    non-finite; a poisoned scale is how corruption manifests there).
    One device call per watchdog pass — never per tick."""

    def health(cache):
        ok = jnp.ones((slots,), bool)
        flat = jax.tree_util.tree_flatten_with_path(
            cache, is_leaf=is_quantized)[0]
        for path, leaf in flat:
            ps = _path_str(path)
            if ps.rsplit("/", 1)[-1] == "pos":
                continue
            if is_quantized(leaf):
                leaf = leaf.scale
                if leaf is None:      # bf16/fp8 modes carry no scales
                    continue
            if not jnp.issubdtype(leaf.dtype, jnp.floating):
                continue
            ax = batch_axis_for(ps)
            axes = tuple(i for i in range(leaf.ndim) if i != ax)
            ok = ok & jnp.all(jnp.isfinite(leaf), axis=axes)
        return ok, cache["pos"]
    return health


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Self-speculative decoding knobs.

    ``k`` is the verify-window length (1 verified anchor + k-1 drafts);
    ``draft`` picks the draft source: "reuse" recycles the previous
    window's verified-but-unemitted leftovers (zero extra compute),
    "solve" runs an extra early-exit forward at ``draft_iters`` Newton
    iterations (lrc mixers; other families run the plain window forward).
    Both are LOSSLESS — the full-depth verify pass gates every emitted
    token."""
    k: int = 4
    draft: str = "reuse"          # "reuse" | "solve"
    draft_iters: int = 2


@dataclasses.dataclass
class Request:
    """One serving request: prompt in, streamed greedy tokens out.

    ``on_token(uid, token, done)`` fires once per generated token, in
    order; ``done`` is True exactly once (the final token). ``out_tokens``
    accumulates the same tokens for callers that prefer polling.

    ``deadline_s`` (optional) is a wall-clock budget measured from
    ``submit``: a request past its deadline is CANCELLED (queued: dropped
    at admission; active: slot freed mid-stream) with ``status``
    "expired". ``status`` tracks the lifecycle — queued -> active ->
    done | expired | failed | rejected — and ``retries`` counts watchdog
    quarantines (re-prefills) this request has survived."""
    uid: int
    prompt: np.ndarray               # (T,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    on_token: Optional[Callable[[int, int, bool], None]] = None
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    deadline_s: Optional[float] = None
    status: str = "queued"
    submit_t: float = 0.0
    retries: int = 0


class ServeEngine:
    """Continuous-batching scheduler over a fixed slot budget.

    ``batch_slots`` bounds concurrent decode streams; ``prefill_chunk`` is
    the admission chunk length (prompts are right-padded to a multiple, so
    every chunk shares one compiled prefill); ``mesh`` routes the decode
    tick through ``train/step.jit_step``'s sharded serve wiring.

    ``precision`` (a ``distributed/precision.PrecisionPolicy`` or its
    ``from_string`` spec, e.g. "int8" / "fp8" / "weights=int8,cache=fp8")
    turns on quantized serving: the resident params and slot cache are
    encoded once at construction and every tick decodes/recommits inside
    its jit (``serve/decode.py``). For lrc mixers the policy is also
    INJECTED into the arch (``SSMConfig.state_quant``), so every
    recurrence tick is quantize-roundtripped onto the storage grid —
    that alignment is what keeps speculative decode token-identical to
    quantized greedy and eviction round trips self-consistent. Quantized
    policies do not compose with a mesh yet.

    Degradation knobs (docs/reliability.md):
      * ``max_queue``: bounded admission queue — ``submit`` raises
        ``QueueFullError`` at capacity (0 = unbounded).
      * ``watchdog_every``: every N ticks, a jitted per-slot health check
        (all-finite state + position-progress) runs BEFORE decode; a bad
        slot is quarantined via the eviction/re-prefill path with capped
        exponential backoff, and after ``max_retries`` quarantines the
        request fails structurally instead of looping (0 = off).
      * ``spec_min_accept``: sustained-accept-rate floor for speculative
        decoding — when the mean accepted-draft fraction over the last
        ``spec_window`` verify ticks drops below it, spec is auto-disabled
        for ``spec_cooldown`` ticks (plain decode; token streams stay
        greedy-identical since both paths are exact), then re-enabled
        with cold-start drafts (0.0 = never disable).
      * ``faults``: a ``reliability.FaultPlan`` — ``serve_stall`` faults
        suppress admission on the scheduled ticks (simulated wedged
        admission for the chaos suite).
    Every degradation transition is recorded on ``self.events``
    (a ``reliability.EventLog``)."""

    def __init__(self, model: Model, params, batch_slots: int = 4,
                 max_seq: int = 256, prefill_chunk: int = 32, mesh=None,
                 policy=None, spec: Optional[SpecConfig] = None,
                 precision=None, max_queue: int = 0, watchdog_every: int = 0,
                 max_retries: int = 3, backoff_cap: int = 8,
                 spec_min_accept: float = 0.0, spec_window: int = 8,
                 spec_cooldown: int = 16, faults=None):
        if policy is not None and mesh is None:
            mesh = policy.build_mesh()
        self.policy = policy
        if isinstance(precision, str):
            precision = PrecisionPolicy.from_string(precision)
        self.precision = precision
        if model.prefill is None:
            raise ValueError(f"model family {model.arch.family!r} has no "
                             "chunked-prefill implementation — the serve "
                             "engine requires Model.prefill")
        quant_cache = precision is not None and precision.quantizes_cache
        ssm = getattr(model.arch, "ssm", None)
        if quant_cache and ssm is not None and ssm.kind == "lrc":
            # rebuild the facade with the cache rule injected into the
            # mixer: grid-aligned ticks everywhere (decode, prefill, the
            # spec verify window) — the losslessness precondition
            arch = dataclasses.replace(
                model.arch, ssm=dataclasses.replace(
                    ssm, state_quant=precision.cache,
                    state_quant_block=precision.block))
            model = build_model(arch)
        if quant_cache and spec is not None and not (
                ssm is not None and ssm.kind == "lrc"
                and model.arch.family == "ssm"):
            raise ValueError(
                "speculative decoding on a quantized cache is only "
                "lossless for pure-lrc stacks (the tick-aligned state "
                "roundtrip); attention KV rings read full-precision "
                "in-window keys on the verify path, so spec + quantized "
                f"cache is rejected for family={model.arch.family!r}/"
                f"ssm={getattr(ssm, 'kind', None)!r}")
        self.model = model
        self.params = (params if precision is None
                       else quantize_params(params, precision))
        self.slots = batch_slots
        self.max_seq = max_seq
        self.prefill_chunk = prefill_chunk
        self.queue: deque[Request] = deque()
        self.active: List[Optional[Request]] = [None] * batch_slots
        self.finished: deque = deque(maxlen=65536)
        self.cache = StateCache(model, params, batch_slots, max_seq,
                                precision=precision)
        self._decode = make_decode_step(model, self.params, self.cache.cache,
                                        mesh=mesh, batch_size=batch_slots,
                                        precision=precision)
        self._prefill = jax.jit(
            lambda p, t, c, l: model.prefill(
                dequantize_weights(p, precision), t, c, l))
        self._last_tok = np.zeros((batch_slots, 1), np.int32)
        self.spec = spec
        self._verify = None
        self._draft_tok = None
        self.spec_stats: Dict[str, int] = {
            "draft_tokens": 0, "accepted_tokens": 0, "emitted_tokens": 0,
            "verify_calls": 0}
        if spec is not None:
            self._check_spec(spec)
            if spec.draft == "solve":
                di = spec.draft_iters
            elif spec.draft == "reuse":
                di = None
            else:
                raise ValueError(f"unknown draft strategy: {spec.draft!r}")
            # "solve" drafting is FUSED into the verify dispatch — one
            # device call per tick either way
            self._verify = make_verify_step(model, self.params,
                                            self.cache.cache, mesh=mesh,
                                            batch_size=batch_slots,
                                            spec_k=spec.k, draft_iters=di,
                                            precision=precision)
            self._draft_tok = np.zeros((batch_slots, spec.k - 1), np.int32)
        # per-token wall-clock samples: "prefill" covers each request's
        # first token (admission cost), "decode" one batched tick. Bounded
        # (and `finished` too) so a long-running server does not grow
        # host memory linearly with tokens served.
        self.token_lat: Dict[str, deque] = {
            "prefill": deque(maxlen=4096), "decode": deque(maxlen=4096)}
        # degradation state (docs/reliability.md): tick counter, event
        # log, expected per-slot position (host mirror of committed
        # progress — the watchdog's zero-progress detector), hold-backs
        # for quarantined requests (uid -> earliest re-admission tick),
        # and the spec auto-disable window/cooldown bookkeeping
        self.max_queue = max_queue
        self.watchdog_every = watchdog_every
        self.max_retries = max_retries
        self.backoff_cap = backoff_cap
        self.spec_min_accept = spec_min_accept
        self.spec_cooldown = spec_cooldown
        self.faults = faults
        self.events = EventLog()
        self._ticks = 0
        self._expected_pos = np.zeros((batch_slots,), np.int64)
        self._hold: Dict[int, int] = {}
        self._accept_window: deque = deque(maxlen=max(spec_window, 1))
        self._spec_off = False
        self._spec_off_until = 0
        self._health = (jax.jit(_make_slot_health(batch_slots))
                        if watchdog_every else None)

    def _check_spec(self, spec: SpecConfig) -> None:
        """Reject spec geometries the commit/verify paths cannot serve
        losslessly: the window must fit strictly inside every attention
        ring (a k-row masked commit into an S-slot ring needs k < S), and
        for lrc mixers the verify window must be short enough that the
        fixed-depth Newton ladder is EXACT on it (DEER converges in <= T
        iterations on a length-T window)."""
        if spec.k < 2:
            raise ValueError(f"spec.k={spec.k}: the window is 1 verified "
                             "anchor + k-1 drafts, so k must be >= 2")
        rings: List[int] = []

        def scan_leaf(path, leaf):
            ps = _path_str(path)
            if ps.rsplit("/", 1)[-1] in ("k", "v"):
                # quantized rings keep the logical shape on the payload
                arr = leaf.q if is_quantized(leaf) else leaf
                rings.append(arr.shape[batch_axis_for(ps) + 1])
            return leaf
        jax.tree_util.tree_map_with_path(scan_leaf, self.cache.cache,
                                         is_leaf=is_quantized)
        if rings and spec.k >= min(rings):
            raise ValueError(
                f"spec.k={spec.k} does not fit the smallest attention "
                f"ring ({min(rings)} slots): the verify window must be "
                "strictly shorter than every KV ring")
        ssm = getattr(self.model.arch, "ssm", None)
        if ssm is not None and ssm.kind == "lrc" and spec.k > ssm.deer_iters:
            raise ValueError(
                f"spec.k={spec.k} > deer_iters={ssm.deer_iters}: the "
                "verify solve would be approximate on the window and "
                "speculative decode would no longer be lossless; lower k "
                "or raise deer_iters")

    # -- admission ----------------------------------------------------------

    def submit(self, req: Request) -> None:
        """Queue a request (FIFO). Validates it fits the slot geometry,
        INCLUDING prefill-chunk padding: the worst-case prefill feed is the
        prompt plus all-but-one generated token (an eviction just before
        completion), rounded up to a chunk multiple — a padded chunk
        writing past ``max_seq`` would clamp its dynamic-slice start and
        corrupt valid cache entries, so it is rejected here instead."""
        if len(req.prompt) == 0:
            raise ValueError(f"request {req.uid}: empty prompt (prefill "
                             "needs at least one token to condition on)")
        need = len(req.prompt) + req.max_new_tokens
        C = self.prefill_chunk
        worst_feed = len(req.prompt) + max(req.max_new_tokens - 1, 0)
        worst_padded = -(-worst_feed // C) * C
        # speculative windows write up to k-1 positions past the last
        # emitted token before the accept decision truncates them
        spec_pad = (self.spec.k - 1) if self.spec is not None else 0
        if need + spec_pad > self.max_seq or worst_padded > self.max_seq:
            raise ValueError(
                f"request {req.uid}: prompt ({len(req.prompt)}) + "
                f"max_new_tokens ({req.max_new_tokens}) needs "
                f"{max(need + spec_pad, worst_padded)} cache positions "
                f"(incl. prefill_chunk={C} padding"
                + (f" and spec window k={self.spec.k}" if spec_pad else "")
                + f") but max_seq={self.max_seq}; raise max_seq or lower "
                "prefill_chunk")
        if self.max_queue and len(self.queue) >= self.max_queue:
            # bounded-queue backpressure: a STRUCTURED reject the caller
            # can act on (shed load / resubmit), never unbounded growth
            self.events.emit("queue_reject", where=req.uid,
                             depth=len(self.queue))
            req.status = "rejected"
            raise QueueFullError(req.uid, len(self.queue), self.max_queue)
        req.status = "queued"
        req.submit_t = time.perf_counter()
        self.queue.append(req)

    def _feed(self, req: Request) -> np.ndarray:
        """The prefill feed: prompt + any already-generated tokens (the
        eviction/re-admission path folds generations into the prompt)."""
        return np.concatenate([np.asarray(req.prompt, np.int32),
                               np.asarray(req.out_tokens, np.int32)])

    def _n_chunks(self, req: Request) -> int:
        """Number of prefill chunks the request's feed needs — the batched
        admission grouping key (equal-chunk requests share one launch)."""
        L = len(req.prompt) + len(req.out_tokens)
        return max(1, -(-L // self.prefill_chunk))

    def _prefill_group(self, group: List[Request], n_chunks: int):
        """Run a batch of same-chunk-count requests through chunked
        parallel prefill in ONE set of launches. Interior chunks are fully
        valid for every row (the grouping key guarantees L > (n_chunks-1)*C),
        so per-row lengths only enter the FINAL chunk, which flips the
        fragment's ``pos`` from scalar to a per-row vector. Returns
        (batch=n cache fragment with vector pos, (n,) first tokens)."""
        C = self.prefill_chunk
        Bn = len(group)
        feeds = [self._feed(r) for r in group]
        lengths = np.asarray([len(f) for f in feeds], np.int32)
        padded = np.zeros((Bn, n_chunks * C), np.int32)
        for j, f in enumerate(feeds):
            padded[j, :len(f)] = f
        frag = self.model.init_cache(self.params, Bn, self.max_seq)
        tail = jnp.asarray(lengths - (n_chunks - 1) * C, jnp.int32)
        logits = None
        for ci in range(n_chunks):
            chunk = jnp.asarray(padded[:, ci * C:(ci + 1) * C])
            valid = tail if ci == n_chunks - 1 else jnp.asarray(C, jnp.int32)
            logits, frag = self._prefill(self.params, chunk, frag, valid)
        last = jnp.take_along_axis(logits, (tail - 1)[:, None, None],
                                   axis=1)[:, 0]
        # deliberate host boundary: one sync per ADMISSION BATCH (not per
        # step) — first tokens feed host-side slot bookkeeping + callbacks
        first = np.asarray(jnp.argmax(last, axis=-1), np.int32)  # repro-lint: disable=host-sync
        return frag, first

    def _prefill_request(self, req: Request):
        """Single-request admission prefill (batch=1 fragment with scalar
        semantics preserved through the group path)."""
        frag, first = self._prefill_group([req], self._n_chunks(req))
        frag = dict(frag)
        frag["pos"] = jnp.reshape(frag["pos"], ())   # (1,) -> scalar
        return frag, int(first[0])

    def _emit(self, req: Request, tok: int) -> bool:
        """Record one generated token; fire the stream callback; returns
        (and latches) the request's done state."""
        req.out_tokens.append(tok)
        done = (len(req.out_tokens) >= req.max_new_tokens
                or (req.eos_id is not None and tok == req.eos_id))
        req.done = done
        if req.on_token is not None:
            req.on_token(req.uid, tok, done)
        if done:
            req.status = "done"
            self.finished.append(req)
        return done

    def _finalize(self, req: Request, status: str, **detail) -> None:
        """Terminally retire a request WITHOUT completing it (deadline
        expiry, retry exhaustion): set its status, move it to
        ``finished`` (``done`` stays False — callers distinguish
        completion from cancellation), and log the degradation event."""
        req.status = status
        self._hold.pop(req.uid, None)
        self.finished.append(req)
        self.events.emit(status, where=req.uid,
                         emitted=len(req.out_tokens), **detail)

    def _admit(self, max_prefills: Optional[int] = None,
               max_batch: Optional[int] = None) -> int:
        """Fill free slots from the queue with BATCHED admission: pop the
        longest FIFO prefix of requests that share a prefill chunk count
        (the compile-shape grouping key), run them through ONE chunked
        parallel prefill, and scatter the whole group in one device op.

        ``max_prefills`` bounds the number of prefill LAUNCHES this call
        may issue (the scheduler's prefill/decode interleaving budget);
        ``max_batch`` caps the admission group size. Returns the number of
        launches issued.

        Before grouping, the queue is swept once: requests past their
        deadline are cancelled ("expired", never admitted) and quarantined
        requests still inside their backoff hold are set aside, then
        reinserted at the FRONT afterwards (they carry retry priority —
        eviction already re-queued them there).

        Injected ``serve_stall`` faults gate HERE (not in ``step``) so a
        scheduler driving admission directly sees the same wedged-
        admission behaviour as the engine's own tick."""
        if (self.faults is not None
                and self.faults.fires("serve_stall", self._ticks)):
            self.events.emit("admission_stalled", tick=self._ticks,
                             queued=len(self.queue))
            return 0
        held: List[Request] = []
        if self._hold or any(r.deadline_s is not None for r in self.queue):
            now = time.perf_counter()
            keep: deque = deque()
            while self.queue:
                r = self.queue.popleft()
                if (r.deadline_s is not None
                        and now - r.submit_t > r.deadline_s):
                    self._finalize(r, "expired")
                elif self._hold.get(r.uid, 0) > self._ticks:
                    held.append(r)
                else:
                    self._hold.pop(r.uid, None)
                    keep.append(r)
            self.queue = keep
        launches = 0
        while self.queue and self.cache.n_free > 0:
            if max_prefills is not None and launches >= max_prefills:
                break
            cap = self.cache.n_free
            if max_batch:
                cap = min(cap, max_batch)
            group = [self.queue.popleft()]
            nc = self._n_chunks(group[0])
            while (self.queue and len(group) < cap
                   and self._n_chunks(self.queue[0]) == nc):
                group.append(self.queue.popleft())
            slots = [self.cache.alloc() for _ in group]
            # feed lengths BEFORE the first emit mutates out_tokens: the
            # fragment's committed pos equals the feed length
            lengths_admitted = [len(r.prompt) + len(r.out_tokens)
                                for r in group]
            t0 = time.perf_counter()
            frag, first = self._prefill_group(group, nc)
            self.cache.write_slots(np.asarray(slots, np.int32), frag)
            wall = time.perf_counter() - t0
            launches += 1
            for j, (req, slot) in enumerate(zip(group, slots)):
                self.token_lat["prefill"].append(wall)
                # host mirror of the slot's committed position (== feed
                # length after prefill) — the watchdog's progress anchor
                self._expected_pos[slot] = int(lengths_admitted[j])
                tok = int(first[j])
                if self._emit(req, tok):
                    self.cache.free(slot)      # one-token request
                else:
                    req.status = "active"
                    self.active[slot] = req
                    self._last_tok[slot, 0] = tok
                    if self._draft_tok is not None:
                        # cold-start drafts: repeat the anchor; the first
                        # verify tick replaces them with real leftovers
                        self._draft_tok[slot, :] = tok
        if held:
            self.queue.extendleft(reversed(held))
        return launches

    # -- the tick -----------------------------------------------------------

    def step(self, admit: bool = True) -> int:
        """One engine tick: admit waiting requests (unless the scheduler
        already did), then one batched decode — plain single-token or
        speculative k-window — advancing every active slot. Returns the
        number of slots that were active this tick (0 = fully drained).

        Degradation ordering (docs/reliability.md): deadline expiry and
        the watchdog run FIRST, so a corrupt or past-deadline slot never
        emits a token this tick; injected ``serve_stall`` faults suppress
        admission; the spec auto-disable gate decides plain vs
        speculative decode last."""
        self._ticks += 1
        self._expire_active()
        if (self._health is not None
                and self._ticks % self.watchdog_every == 0):
            self._watchdog()
        if admit:
            self._admit()
        act = [s for s, r in enumerate(self.active) if r is not None]
        if not act:
            return 0
        if self.spec is not None and self._spec_usable(act):
            return self._spec_tick(act)
        t0 = time.perf_counter()
        next_tok, _, new_cache = self._decode(
            self.params, jnp.asarray(self._last_tok), self.cache.cache)
        self.cache.cache = new_cache
        nxt = np.asarray(next_tok)
        wall = time.perf_counter() - t0
        for s in act:
            req = self.active[s]
            tok = int(nxt[s, 0])
            self._expected_pos[s] += 1         # one committed position
            self.token_lat["decode"].append(wall)
            if self._emit(req, tok):
                self.active[s] = None          # recycle: continuous batching
                self.cache.free(s)
            else:
                self._last_tok[s, 0] = tok
        return len(act)

    def _spec_usable(self, act: List[int]) -> bool:
        """Gate for the speculative path: False while auto-disabled. On
        cooldown expiry, re-enables with cold-start drafts (repeat each
        slot's anchor token — same as admission), so the first verify
        tick is guaranteed >= 1 accepted token and the stream stays
        token-identical throughout the disable/re-enable cycle."""
        if not self._spec_off:
            return True
        if self._ticks < self._spec_off_until:
            return False
        self._spec_off = False
        for s in act:
            self._draft_tok[s, :] = self._last_tok[s, 0]
        self.events.emit("spec_reenable", tick=self._ticks)
        return True

    def _expire_active(self) -> None:
        """Cancel ACTIVE requests past their deadline: free the slot
        (continuous batching reclaims it this tick), retire the request
        as "expired". Runs before decode, so a cancelled request never
        pays for another token."""
        now = time.perf_counter()
        for s, r in enumerate(self.active):
            if (r is not None and r.deadline_s is not None
                    and now - r.submit_t > r.deadline_s):
                self.active[s] = None
                self.cache.free(s)
                self._finalize(r, "expired")

    def _watchdog(self) -> None:
        """Slot-health sweep: one jitted device call checks every slot's
        state for non-finite values and its ``pos`` against the host-side
        expected position (zero-progress / runaway detection). Bad ACTIVE
        slots are quarantined. The host readback here is a sanctioned
        sync — it runs every ``watchdog_every`` ticks, never per tick."""
        act = [s for s, r in enumerate(self.active) if r is not None]
        if not act:
            return
        okv, pos = self._health(self.cache.cache)
        okv = np.asarray(okv)
        pos = np.asarray(pos)
        for s in act:
            state_ok = bool(okv[s])
            pos_ok = int(pos[s]) == int(self._expected_pos[s])
            if state_ok and pos_ok:
                continue
            self._quarantine(s, "state" if not state_ok else "pos")

    def _quarantine(self, slot: int, why: str) -> None:
        """Quarantine a corrupt/stuck slot: evict (the request re-queues
        with its emitted-so-far tokens folded into the feed — re-prefill
        re-derives clean state, so the retry is token-identity-preserving
        by construction), apply capped exponential backoff before
        re-admission, and fail the request structurally once it exhausts
        ``max_retries``."""
        req = self.active[slot]
        self.events.emit("slot_quarantine", where=slot, uid=req.uid,
                         why=why, tick=self._ticks, retry=req.retries + 1)
        self.evict(slot)
        req.retries += 1
        if req.retries > self.max_retries:
            self.queue.remove(req)
            self._finalize(req, "failed", retries=req.retries, why=why)
        else:
            delay = min(2 ** (req.retries - 1), self.backoff_cap)
            self._hold[req.uid] = self._ticks + delay

    def _spec_tick(self, act: List[int]) -> int:
        """One speculative tick: (optionally) refine drafts with the
        early-exit forward, verify the (slots, k) window in one parallel
        solve, emit each slot's accepted prefix, and refill its drafts
        from the verified leftovers (the Jacobi warm start). Inactive
        slots ride along as dead rows — their committed state is garbage
        but is fully overwritten on the next admission."""
        spec = self.spec
        k = spec.k
        window = np.empty((self.slots, k), np.int32)
        window[:, 0] = self._last_tok[:, 0]
        window[:, 1:] = self._draft_tok
        wdev = jnp.asarray(window)
        t0 = time.perf_counter()
        y, acc, new_cache = self._verify(self.params, wdev,
                                         self.cache.cache)
        self.cache.cache = new_cache
        y_h = np.asarray(y)
        acc_h = np.asarray(acc)
        wall = time.perf_counter() - t0
        self.spec_stats["verify_calls"] += 1
        self.spec_stats["draft_tokens"] += (k - 1) * len(act)
        for s in act:
            req = self.active[s]
            a = int(acc_h[s])
            self._expected_pos[s] += a         # a committed positions
            self.spec_stats["accepted_tokens"] += a - 1
            self.token_lat["decode"].append(wall)
            done = False
            for i in range(a):
                self.spec_stats["emitted_tokens"] += 1
                if self._emit(req, int(y_h[s, i])):
                    done = True
                    break
            if done:
                self.active[s] = None          # recycle: continuous batching
                self.cache.free(s)
                continue
            self._last_tok[s, 0] = y_h[s, a - 1]
            # refill drafts from the verified-but-unemitted leftovers;
            # pad by repeating the last available token
            left = y_h[s, a:]
            n = min(len(left), k - 1)
            self._draft_tok[s, :n] = left[:n]
            fillv = left[n - 1] if n > 0 else y_h[s, a - 1]
            self._draft_tok[s, n:] = fillv
        if self.spec_min_accept > 0.0 and act:
            # sustained-accept-rate monitor: when the windowed mean of
            # the accepted-draft fraction falls below the floor, the
            # verify window costs more than it saves — fall back to
            # plain decode for a cooldown (tokens stay identical: both
            # paths emit the model's exact greedy continuation)
            frac = (sum(int(acc_h[s]) - 1 for s in act)
                    / ((k - 1) * len(act)))
            self._accept_window.append(frac)
            win = self._accept_window
            if (len(win) == win.maxlen
                    and sum(win) / len(win) < self.spec_min_accept):
                self._spec_off = True
                self._spec_off_until = self._ticks + self.spec_cooldown
                mean = sum(win) / len(win)
                win.clear()
                self.events.emit("spec_disable", tick=self._ticks,
                                 accept_rate=round(mean, 4),
                                 until=self._spec_off_until)
        return len(act)

    def evict(self, slot: int) -> Request:
        """Preempt ``slot``: the in-flight request re-queues at the FRONT of
        the admission queue with its generated tokens folded into the
        prompt feed. No cache bytes move — the O(D) state is re-derived by
        parallel prefill on re-admission (the state-cache eviction story;
        contrast with KV-cache preemption, which must either transfer the
        whole ring or replay the sequence)."""
        req = self.active[slot]
        if req is None:
            raise ValueError(f"slot {slot} is not active")
        self.active[slot] = None
        self.cache.free(slot)
        req.status = "queued"
        self.queue.appendleft(req)
        return req

    def run_until_drained(self, max_ticks: int = 10_000) -> "deque[Request]":
        """Tick until the queue and all slots are empty; returns the
        finished-requests deque (completion order, bounded retention).

        Raises ``EngineStalledError`` when ``max_ticks`` is exhausted with
        requests still queued or active — a stall is always surfaced
        structurally, never returned as a silently-partial drain."""
        for _ in range(max_ticks):
            self.step()
            if not self.queue and not any(r is not None for r in self.active):
                return self.finished
        if self.queue or any(r is not None for r in self.active):
            raise EngineStalledError(
                max_ticks, len(self.queue),
                sum(r is not None for r in self.active))
        return self.finished

    # -- stats --------------------------------------------------------------

    def state_cache_bytes(self) -> int:
        """Resident FLOAT-state bytes of the slot cache (QTensor payload +
        scales; the integer ``pos`` vector excluded) — the numerator of the
        slot-capacity math in docs/serving.md: capacity ratio = fp32 bytes
        / quantized bytes at equal slot count, or equivalently extra slots
        at equal HBM."""
        return tree_state_bytes(self.cache.cache)

    def latency_percentiles(self) -> Dict[str, float]:
        """p50/p99 per-token wall-clock latency over decode ticks (and p50
        admission latency), in seconds — the benchmark's record format."""
        out: Dict[str, float] = {}
        if self.token_lat["decode"]:
            d = np.asarray(list(self.token_lat["decode"]))
            out["decode_p50_s"] = float(np.percentile(d, 50))
            out["decode_p99_s"] = float(np.percentile(d, 99))
        if self.token_lat["prefill"]:
            p = np.asarray(list(self.token_lat["prefill"]))
            out["prefill_p50_s"] = float(np.percentile(p, 50))
        return out

"""The serving engine's batched single-step decode.

ONE jit-compiled greedy decode tick for all slots, built through the
train-engine step factory (``train/step.make_step(model, "serve")``) so the
serve step is the same object the trainer's eval/serve wiring uses. With a
mesh, the jit wiring (parameter / cache / token shardings, cache donation)
comes from ``train/step.jit_step`` — sharding rules for the engine live in
``train/step.py`` + ``distributed/sharding.py`` and nowhere else. Without a
mesh it is a plain ``jax.jit`` with the cache donated, which keeps the
resident state cache device-side across ticks.

The decode step consumes the continuous-batching cache layout from
``serve/cache.py`` (per-slot ``pos`` vector — ``models/lm.decode_step``
dispatches to per-row cache writes on it).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax

from repro.models import Model
from repro.train.step import jit_step, make_step


def make_decode_step(model: Model, params, cache_like, *,
                     mesh=None, batch_size: int = 0) -> Callable:
    """Build the jitted decode tick: ``(params, tokens (B,1), cache) ->
    (next_tok (B,1), logits (B,1,V), new_cache)``.

    ``cache_like`` fixes the cache pytree structure (and, under a mesh, its
    shardings via ``train/step.train_state_specs``-style rules in
    ``jit_step``). The cache argument is donated in both paths: the engine
    threads one device-resident cache through every tick.
    """
    if mesh is not None:
        return jit_step(model, "serve", mesh, params_like=params,
                        cache_like=cache_like, batch_size=batch_size)
    return jax.jit(make_step(model, "serve"), donate_argnums=(2,))

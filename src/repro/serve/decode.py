"""The serving engine's batched single-step decode.

ONE jit-compiled greedy decode tick for all slots, built through the
train-engine step factory (``train/step.make_step(model, "serve")``) so the
serve step is the same object the trainer's eval/serve wiring uses. With a
mesh, the jit wiring (parameter / cache / token shardings, cache donation)
comes from ``train/step.jit_step`` — sharding rules for the engine live in
``train/step.py`` + ``distributed/sharding.py`` and nowhere else. Without a
mesh it is a plain ``jax.jit`` with the cache donated, which keeps the
resident state cache device-side across ticks.

The decode step consumes the continuous-batching cache layout from
``serve/cache.py`` (per-slot ``pos`` vector — ``models/lm.decode_step``
dispatches to per-row cache writes on it).

With a ``PrecisionPolicy`` (``distributed/precision.py``), each factory
wraps the base step in the quantised-serve seam — dequantize weights and
cache on entry, recommit the new cache under the SAME leaf rules on exit —
all inside the one jitted tick, so the resident cache stays narrow in HBM
and the wire format never crosses the host boundary. The policy composes
with a mesh only when it quantises nothing (sharding rules for QTensor
trees are future work); the engine enforces that.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.distributed.precision import (PrecisionPolicy, dequantize_tree,
                                         dequantize_weights,
                                         requantize_tree)
from repro.models import Model
from repro.train.step import jit_step, make_step


def _active(precision: Optional[PrecisionPolicy]) -> bool:
    return precision is not None and (precision.quantizes_weights
                                      or precision.quantizes_cache)


def _check_mesh(precision: Optional[PrecisionPolicy], mesh) -> None:
    if mesh is not None and _active(precision):
        raise ValueError(
            "quantized serve (PrecisionPolicy with int8/fp8/bf16 weights or "
            "cache) does not compose with a multi-device mesh yet — "
            "sharding specs for QTensor trees are not defined")


def make_decode_step(model: Model, params, cache_like, *,
                     mesh=None, batch_size: int = 0,
                     precision: Optional[PrecisionPolicy] = None) -> Callable:
    """Build the jitted decode tick: ``(params, tokens (B,1), cache) ->
    (next_tok (B,1), logits (B,1,V), new_cache)``.

    ``cache_like`` fixes the cache pytree structure (and, under a mesh, its
    shardings via ``train/step.train_state_specs``-style rules in
    ``jit_step``). The cache argument is donated in both paths: the engine
    threads one device-resident cache through every tick.

    Under an active ``precision`` policy, params/cache may carry QTensor
    leaves: the tick dequantizes on entry (weights honouring
    ``policy.accum``), runs the base step, and requantizes the returned
    cache with the incoming cache's leaf rules — int8/fp8 at rest, fp32
    compute, one jit.
    """
    _check_mesh(precision, mesh)
    if mesh is not None:
        return jit_step(model, "serve", mesh, params_like=params,
                        cache_like=cache_like, batch_size=batch_size)
    base = make_step(model, "serve")
    if not _active(precision):
        return jax.jit(base, donate_argnums=(2,))

    def step(qparams, tokens, qcache):
        p = dequantize_weights(qparams, precision)
        tok, logits, new_cache = base(p, tokens, dequantize_tree(qcache))
        return tok, logits, requantize_tree(qcache, new_cache)
    return jax.jit(step, donate_argnums=(2,))


def make_verify_step(model: Model, params, cache_like, *,
                     mesh=None, batch_size: int = 0, spec_k: int = 2,
                     draft_iters: Optional[int] = None,
                     precision: Optional[PrecisionPolicy] = None) -> Callable:
    """Build the jitted speculative VERIFY tick: ``(params, window (B,k),
    cache) -> (y (B,k), acc (B,), new_cache)``.

    One prefill-style parallel solve over the k-token window for all
    active slots; ``acc`` is the per-slot accepted-prefix length (1..k)
    and ``new_cache`` holds exactly the accepted tokens' state — the
    rejected tail was never written, so rollback is implicit and
    bit-exact. Cache donated, same as the decode tick. ``draft_iters``
    fuses the early-exit draft forward into the same dispatch (the
    "solve" draft strategy without a second host round-trip).

    The quantised-serve seam wraps this tick exactly like the decode
    tick. Losslessness is preserved PER PRECISION: the verify window's
    DEER solve walks the same tick-quantised trajectory the greedy step
    walks (``SSMConfig.state_quant``), so spec output is token-identical
    to quantized greedy output.
    """
    _check_mesh(precision, mesh)
    if mesh is not None:
        return jit_step(model, "verify", mesh, params_like=params,
                        cache_like=cache_like, batch_size=batch_size,
                        spec_k=spec_k, spec_draft_iters=draft_iters)
    base = make_step(model, "verify", draft_iters=draft_iters)
    if not _active(precision):
        return jax.jit(base, donate_argnums=(2,))

    def step(qparams, window, qcache):
        p = dequantize_weights(qparams, precision)
        y, acc, new_cache = base(p, window, dequantize_tree(qcache))
        return y, acc, requantize_tree(qcache, new_cache)
    return jax.jit(step, donate_argnums=(2,))


def make_draft_step(model: Model, draft_iters: int,
                    precision: Optional[PrecisionPolicy] = None) -> Callable:
    """Build the jitted DRAFT tick: ``(params, window (B,k), cache) ->
    refined window (B,k)``.

    A read-only early-exit forward (``solver_iters=draft_iters`` truncates
    the lrc Newton ladder; attention/mamba families run the plain window
    forward) whose greedy argmax refines the draft positions: position 0
    (the last verified token) is kept, drafts 1..k-1 become the model's
    own cheap continuation. The cache is NOT donated and NOT updated —
    drafting must never perturb verified state. Quantised params/cache are
    dequantized on entry (identity on plain trees); nothing is recommitted.
    """
    if model.spec_forward is None:
        raise ValueError(
            f"model family {model.arch.family!r} has no speculative "
            "verify seam (spec_forward is None)")

    @jax.jit
    def draft(params, window, cache):
        p = dequantize_weights(params, precision)
        logits, _ = model.spec_forward(p, window, dequantize_tree(cache),
                                       solver_iters=draft_iters)
        y = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jnp.concatenate([window[:, :1], y[:, :-1]], axis=1)
    return draft

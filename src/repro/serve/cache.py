"""Paged per-sequence state cache for continuous-batching serving.

The resident cache is ONE device-resident batched model cache (the pytree
``model.init_cache`` builds) whose batch dimension is the SLOT axis, plus a
per-slot position vector ``cache["pos"]: (n_slots,) int32`` — the shape
``models/lm.decode_step`` understands as "every slot at its own sequence
position". For SSM-family layers a slot is O(D) floats of recurrent state
(the paper's no-KV-cache property); attention layers keep their (max_seq,
K, hd) rings per slot.

Slot lifecycle (host-side bookkeeping, device-side data):

    alloc() -> slot      admission: claim a free slot
    write_slot(slot, f)  scatter a freshly-prefilled batch=1 cache fragment
                         into the slot row (jit-compiled, donated — the
                         resident cache never round-trips to host)
    read_slot(slot)      gather a slot back out as a batch=1 fragment
    free(slot)           retirement/eviction: recycle (no data movement —
                         the next write_slot overwrites every row)

Fragments come from ``models/lm.prefill`` (scalar-pos, batch=1); the
scatter maps their scalar ``pos`` into the slot's entry of the position
vector. Batch-axis location is derived from the tree path: leaves under
``groups`` stack layer-groups ahead of the batch axis (axis 1), everything
else is batch-leading (axis 0).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.distributed.precision import (QTensor, dequantize_leaf,
                                         is_quantized, quantize_cache,
                                         quantize_leaf)
from repro.distributed.sharding import _path_str
from repro.models import Model


def batch_axis_for(path_str: str) -> int:
    """Slot (batch) axis of a cache leaf: 1 under the stacked layer-group
    prefix, 0 everywhere else (tail / shared / mixer states)."""
    return 1 if path_str.startswith("groups") else 0


def _q_apply(res: QTensor, fn) -> QTensor:
    """Apply one slot-indexing op to a quantised resident leaf's payload
    AND its block scales. The scale rows preserve every axis up to and
    including the slot axis (``quantize_cache`` builds them with
    ``lead = slot_axis + 1``), so the SAME index arithmetic addresses
    both."""
    return QTensor(fn(res.q), None if res.scale is None else fn(res.scale),
                   res.mode, res.odtype, res.lead, res.block)


def _scatter(resident: Dict, fragment: Dict, slot: jax.Array) -> Dict:
    """Write a batch=1 fragment into row ``slot`` of the resident cache.
    Quantised residents encode the fragment on scatter (QUANTIZE-ON-
    SCATTER): the float fragment is RTN/cast-encoded with the resident
    leaf's static rule and only the narrow payload lands in the slot."""
    def leaf(path, res, frag):
        ps = _path_str(path)
        if ps.endswith("pos"):
            return res.at[slot].set(frag.astype(res.dtype))
        ax = batch_axis_for(ps)
        def put(r, f):
            return jax.lax.dynamic_update_slice_in_dim(
                r, f.astype(r.dtype), slot, axis=ax)
        if is_quantized(res):
            fq = quantize_leaf(frag, res.mode, res.block, res.lead)
            return QTensor(put(res.q, fq.q),
                           None if res.scale is None
                           else put(res.scale, fq.scale),
                           res.mode, res.odtype, res.lead, res.block)
        return put(res, frag)
    return jax.tree_util.tree_map_with_path(leaf, resident, fragment,
                                            is_leaf=is_quantized)


def _scatter_rows(resident: Dict, fragment: Dict, slots: jax.Array) -> Dict:
    """Write a batch=n fragment into rows ``slots`` (a (n,) index vector)
    of the resident cache — the batched-admission scatter: one device op
    for the whole admission group instead of n single-slot scatters.
    Quantised residents encode the fragment rows on scatter."""
    def leaf(path, res, frag):
        ps = _path_str(path)
        if ps.endswith("pos"):
            return res.at[slots].set(frag.astype(res.dtype))
        ax = batch_axis_for(ps)
        if is_quantized(res):
            frag = quantize_leaf(frag, res.mode, res.block, res.lead)
        def put(r, f):
            return (r.at[slots].set(f.astype(r.dtype)) if ax == 0
                    else r.at[:, slots].set(f.astype(r.dtype)))
        if is_quantized(res):
            return QTensor(put(res.q, frag.q),
                           None if res.scale is None
                           else put(res.scale, frag.scale),
                           res.mode, res.odtype, res.lead, res.block)
        return put(res, frag)
    return jax.tree_util.tree_map_with_path(leaf, resident, fragment,
                                            is_leaf=is_quantized)


def _gather(resident: Dict, slot: jax.Array) -> Dict:
    """Read row ``slot`` back out as a batch=1 fragment (scalar pos).
    Quantised residents decode on gather (DEQUANTIZE-ON-GATHER): the slot's
    payload + scales are sliced narrow, then decoded to the original float
    dtype — the fragment a re-admission would quantise back EXACTLY
    (idempotent RTN grid), which is what makes eviction round trips
    self-consistent."""
    def leaf(path, res):
        ps = _path_str(path)
        if ps.endswith("pos"):
            return res[slot]
        ax = batch_axis_for(ps)
        take = lambda r: jax.lax.dynamic_slice_in_dim(r, slot, 1, axis=ax)
        if is_quantized(res):
            return dequantize_leaf(_q_apply(res, take))
        return take(res)
    return jax.tree_util.tree_map_with_path(leaf, resident,
                                            is_leaf=is_quantized)


class StateCache:
    """Device-resident slot cache + host-side free-list admission state.

    ``n_free``/``alloc``/``free`` are the host admission queue's view;
    ``write_slot``/``read_slot`` move slot rows on device (one jit-compiled
    scatter/gather each, slot index traced so every slot shares a compile).

    ``precision`` (a ``distributed/precision.PrecisionPolicy``) quantises
    the resident slot state: every float leaf becomes a ``QTensor``
    (payload + per-slot-row block scales) and the slot ops encode on
    scatter / decode on gather — fragments crossing the API stay float, so
    prefill and eviction plumbing never see the wire format. The ``pos``
    vector is never quantised.
    """

    def __init__(self, model: Model, params, n_slots: int, max_seq: int,
                 precision=None):
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.precision = precision
        cache = model.init_cache(params, n_slots, max_seq)
        cache["pos"] = jnp.zeros((n_slots,), jnp.int32)
        if precision is not None and precision.quantizes_cache:
            cache = quantize_cache(cache, precision, batch_axis_for)
        self.cache: Dict[str, Any] = cache
        self._free: List[int] = list(range(n_slots - 1, -1, -1))
        self._scatter = jax.jit(_scatter, donate_argnums=(0,))
        self._scatter_rows = jax.jit(_scatter_rows, donate_argnums=(0,))
        self._gather = jax.jit(_gather)

    @property
    def n_free(self) -> int:
        """Number of unclaimed slots."""
        return len(self._free)

    def alloc(self) -> Optional[int]:
        """Claim a free slot (None when the slot budget is exhausted)."""
        return self._free.pop() if self._free else None

    def free(self, slot: int) -> None:
        """Recycle a slot. Pure bookkeeping: slot data is left in place and
        fully overwritten by the next ``write_slot`` — O(D) states make
        eviction a free-list operation, not a cache transfer."""
        assert slot not in self._free, f"double free of slot {slot}"
        self._free.append(slot)

    def write_slot(self, slot: int, fragment: Dict) -> None:
        """Scatter a batch=1 prefill fragment into ``slot`` (device-side)."""
        self.cache = self._scatter(self.cache, fragment,
                                   jnp.asarray(slot, jnp.int32))

    def write_slots(self, slots, fragment: Dict) -> None:
        """Scatter a batch=n prefill fragment into rows ``slots`` — the
        batched-admission counterpart of ``write_slot`` (vector ``pos`` in
        the fragment, one donated device scatter for the group)."""
        self.cache = self._scatter_rows(self.cache, fragment,
                                        jnp.asarray(slots, jnp.int32))

    def read_slot(self, slot: int) -> Dict:
        """Gather ``slot`` as a batch=1 fragment (scalar pos) — the inverse
        of ``write_slot``; used by tests and state migration."""
        return self._gather(self.cache, jnp.asarray(slot, jnp.int32))

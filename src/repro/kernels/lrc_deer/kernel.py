"""Pallas TPU kernels for the LRC-DEER solver stack.

Three kernels share one closed-form gate/Jacobian body (`_gates_jac`):

``_lrc_deer_kernel`` — ONE fused Newton iteration (gate + exact diagonal
Jacobian + Hillis-Steele chunk scan) in a single HBM round trip.  Per
iteration the unfused path materialises ~10 (T, D)-streams in HBM; this
kernel reads x_shift/s_u/eps_u and writes the new states: 4 streams.

``_lrc_deer_megakernel`` — a WHOLE K-iteration DEER solve in one kernel
launch.  The grid is (d_tile, t_chunk, newton_iter) with the Newton
dimension INNERMOST: a loop-skewed (wavefront) traversal of the
(iteration, time) plane.  Iteration k+1 on chunk c needs only

    * the chunk's iteration-k trajectory            (VMEM scratch, just
      computed one grid step earlier),
    * the last state of chunk c-1 at iteration k    (the shifted-guess
      boundary) and at iteration k+1 (the scan carry) — a (K+1)-slot
      boundary vector per chunk, double-buffered in VMEM scratch,

so the schedule computes EXACTLY the same values as K full-trajectory
Newton sweeps while s_u/eps_u are fetched once per chunk (the block index
map is constant in the innermost grid dimension, so the pipeline does not
re-copy) and the trajectory is written once.  HBM traffic for the whole
K-iteration solve: 2 (T, D) reads + 1 write, vs K x (4..6) streams for the
per-iteration kernel — the memory-roofline term of the solve drops by
~2K x.  VMEM residency is O(chunk * d_tile), independent of T and K.

The kernel also reduces the per-iteration Newton residual
max_t |x^{k+1} - x^k| per channel into a (K, D) output, so ``tol``-mode
iteration counts (and compute early-exit via ``skip_tol``) are available
on device without a host sync.

``_lrc_deer_adjoint_kernel`` — the implicit-adjoint reverse recurrence

    g_t = gbar_t + J_{t+1} * g_{t+1},     g_{T+1} = 0

fused into one pass: gate recompute at the converged trajectory, exact
diagonal J, in-kernel shift-left of J (chunks walked right-to-left, the
neighbouring chunk's first-row J carried in scratch), reverse
Hillis-Steele chunk scan + right-edge carry.  ``with_cumulative`` emits
the local reverse affine map (A_cum, g|zero-terminal) that
``core.scan.sharded_scan_fixup(reverse=True)`` stitches across time
shards — the same seam the forward kernel uses.

The Jacobian is the exact closed-form elementwise derivative of the LRC
Euler step (diagonal BY MODEL DESIGN — the paper's central property):

    x' = lam*x + beta,  lam = 1 - dt*sig_f*sig_e,  beta = dt*tau_z*sig_e*el
    J  = lam + x*dlam/dx + dbeta/dx        (all elementwise)

Per-channel parameters (10 x (D,)) ride along as a (10, Dt) block.

``interpret=None`` on every entry point auto-detects the backend:
compiled on TPU, interpreter as the CPU fallback (CI hosts).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# row indices of the packed parameter block
P_AX, P_BX, P_GMX, P_KMX, P_GMU, P_KMU, P_WX, P_VX, P_GL, P_EL = range(10)


def _sigmoid(x):
    return jax.nn.sigmoid(x)


def resolve_interpret(interpret) -> bool:
    """None -> auto-detect: compiled on TPU, interpreter elsewhere (CPU CI)."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


def _gates_jac(xs, su, eu, pp, dt: float):
    """Shared closed-form body: gates at the guess + exact diagonal Jacobian.

    xs/su/eu: (C, Dt) f32 tiles; pp: (10, Dt).  Returns (f_s, J) — the step
    value F(xs) and dF/dxs, both (C, Dt)."""
    a_x, b_x = pp[P_AX], pp[P_BX]
    gmx, kmx = pp[P_GMX], pp[P_KMX]
    gmu, kmu = pp[P_GMU], pp[P_KMU]
    w_x, v_x = pp[P_WX], pp[P_VX]
    g_l, e_l = pp[P_GL], pp[P_EL]

    s_x = _sigmoid(a_x * xs + b_x)
    f = gmx * s_x + gmu * su + g_l
    z = kmx * s_x + kmu * su + g_l
    eps = w_x * xs + v_x + eu
    sig_f = _sigmoid(f)
    sig_e = _sigmoid(eps)
    tau_z = jnp.tanh(z)
    lam = 1.0 - dt * sig_f * sig_e
    beta = dt * tau_z * sig_e * e_l
    f_s = lam * xs + beta

    ds_x = s_x * (1.0 - s_x) * a_x
    dsig_f = sig_f * (1.0 - sig_f) * (gmx * ds_x)
    dsig_e = sig_e * (1.0 - sig_e) * w_x
    dtau_z = (1.0 - tau_z * tau_z) * (kmx * ds_x)
    dlam = -dt * (dsig_f * sig_e + sig_f * dsig_e)
    dbeta = dt * e_l * (dtau_z * sig_e + tau_z * dsig_e)
    J = lam + xs * dlam + dbeta
    return f_s, J


def _fwd_chunk_scan(A, B, chunk: int):
    """In-register Hillis-Steele prefix over the affine maps (A, B):
    after the sweep, row t holds the composition of rows 0..t."""
    k = 1
    while k < chunk:
        ones = jnp.ones((k, A.shape[1]), jnp.float32)
        zeros = jnp.zeros((k, B.shape[1]), jnp.float32)
        A_prev = jnp.concatenate([ones, A[:-k]], axis=0)
        B_prev = jnp.concatenate([zeros, B[:-k]], axis=0)
        B = A * B_prev + B
        A = A * A_prev
        k *= 2
    return A, B


def _rev_chunk_scan(A, B, chunk: int):
    """Reverse (suffix) Hillis-Steele: after the sweep, row t holds the
    composition of rows t..chunk-1, i.e. g_t = A_t * g_term + B_t."""
    k = 1
    while k < chunk:
        ones = jnp.ones((k, A.shape[1]), jnp.float32)
        zeros = jnp.zeros((k, B.shape[1]), jnp.float32)
        A_next = jnp.concatenate([A[k:], ones], axis=0)
        B_next = jnp.concatenate([B[k:], zeros], axis=0)
        B = A * B_next + B
        A = A * A_next
        k *= 2
    return A, B


# ---------------------------------------------------------------------------
# single Newton iteration (kept: the sharded per-iteration seam needs it)
# ---------------------------------------------------------------------------

def _lrc_deer_kernel(xs_ref, su_ref, eu_ref, pp_ref, x0_ref, *refs,
                     chunk: int, dt: float, with_cumulative: bool = False):
    if with_cumulative:
        out_ref, aout_ref, carry_ref, acarry_ref = refs
    else:
        (out_ref, carry_ref), aout_ref, acarry_ref = refs, None, None
    t = pl.program_id(1)

    xs = xs_ref[...].astype(jnp.float32)     # (C, Dt) shifted guess
    su = su_ref[...].astype(jnp.float32)
    eu = eu_ref[...].astype(jnp.float32)
    pp = pp_ref[...].astype(jnp.float32)     # (10, Dt)

    f_s, J = _gates_jac(xs, su, eu, pp, dt)
    b_lin = f_s - J * xs

    # ---- carry init ----------------------------------------------------------
    @pl.when(t == 0)
    def _():
        carry_ref[...] = x0_ref[...].astype(jnp.float32)
        if with_cumulative:
            acarry_ref[...] = jnp.ones_like(acarry_ref)

    # ---- Hillis-Steele chunk scan -------------------------------------------
    A, B = _fwd_chunk_scan(J, b_lin, chunk)

    carry = carry_ref[...]
    states = A * carry + B
    out_ref[...] = states.astype(out_ref.dtype)
    carry_ref[...] = states[-1:]
    if with_cumulative:
        # Running cumulative Jacobian product from the SLICE start — with a
        # zero x0 the (states, A_glob) pair is exactly the (B_cum, A_cum)
        # local affine map that core.scan.sharded_scan_fixup composes across
        # time shards.
        a_glob = A * acarry_ref[...]
        aout_ref[...] = a_glob.astype(aout_ref.dtype)
        acarry_ref[...] = a_glob[-1:]


@functools.partial(jax.jit,
                   static_argnames=("chunk", "d_tile", "dt", "interpret",
                                    "with_cumulative"))
def lrc_deer_iteration_pallas(x_shift: jax.Array, s_u: jax.Array,
                              eps_u: jax.Array, packed_params: jax.Array,
                              x0: jax.Array, *, chunk: int = 256,
                              d_tile: int = 512, dt: float = 1.0,
                              interpret: bool | None = None,
                              with_cumulative: bool = False):
    """One fused Newton iteration. x_shift/s_u/eps_u: (T, D);
    packed_params: (10, D) rows [a_x,b_x,g_max_x,k_max_x,g_max_u,k_max_u,
    w_x,v_x,g_leak,e_leak]; x0: (D,). Returns new states (T, D).

    With ``with_cumulative`` the kernel ALSO emits the running cumulative
    Jacobian product A_cum from the slice start, returning (states, A_cum):
    the local affine map (A_cum, states|_{x0=0}) that the shard-composable
    entry point (``ops.sharded_lrc_deer_solve``) stitches across time shards
    with ``core.scan.sharded_scan_fixup``.

    ``interpret=None`` auto-detects the backend (compiled on TPU,
    interpreter on CPU hosts).
    """
    interpret = resolve_interpret(interpret)
    T, D = x_shift.shape
    assert T % chunk == 0 and D % d_tile == 0
    grid = (D // d_tile, T // chunk)
    t_spec = pl.BlockSpec((chunk, d_tile), lambda d, t: (t, d))
    out_specs = [t_spec, t_spec] if with_cumulative else t_spec
    out_shape = jax.ShapeDtypeStruct((T, D), x_shift.dtype)
    scratch = [pltpu.VMEM((1, d_tile), jnp.float32)]
    if with_cumulative:
        out_shape = [out_shape, jax.ShapeDtypeStruct((T, D), x_shift.dtype)]
        scratch = scratch + [pltpu.VMEM((1, d_tile), jnp.float32)]
    return pl.pallas_call(
        functools.partial(_lrc_deer_kernel, chunk=chunk, dt=dt,
                          with_cumulative=with_cumulative),
        grid=grid,
        in_specs=[
            t_spec,
            t_spec,
            t_spec,
            pl.BlockSpec((10, d_tile), lambda d, t: (0, d)),
            pl.BlockSpec((1, d_tile), lambda d, t: (0, d)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=interpret,
    )(x_shift, s_u, eps_u, packed_params, x0.reshape(1, D))


# ---------------------------------------------------------------------------
# whole-Newton megakernel (wavefront schedule)
# ---------------------------------------------------------------------------

def _lrc_deer_megakernel(su_ref, eu_ref, pp_ref, x0_ref, out_ref, resid_ref,
                         traj_ref, bound_ref, ldelta_ref, *,
                         chunk: int, n_iters: int, dt: float,
                         valid_rows: int, skip_tol: float):
    """Wavefront body: grid step (d, c, k) computes iteration k+1 of chunk c.

    Scratch layout (all f32):
      traj_ref   (2*chunk, Dt)      — parity-k double buffer of the chunk's
                                      trajectory (guess at rows src*chunk..,
                                      result at dst*chunk..).
      bound_ref  (2*(K+1), Dt)      — parity-c double buffer of the chunk's
                                      last-row states per iteration:
                                      row p*(K+1)+j = last state of x^j of
                                      the previous (p == c%2) or current
                                      (p == (c+1)%2) chunk.  x^0 is the zero
                                      initial guess; the "chunk -1" boundary
                                      is x0 for every j.
      ldelta_ref (1, Dt)            — previous step's chunk residual (the
                                      ``skip_tol`` compute gate).
    """
    c = pl.program_id(1)
    k = pl.program_id(2)
    K = n_iters

    su = su_ref[...].astype(jnp.float32)
    eu = eu_ref[...].astype(jnp.float32)
    pp = pp_ref[...].astype(jnp.float32)
    d_tile = su.shape[1]

    # ---- initialisation -----------------------------------------------------
    @pl.when(jnp.logical_and(c == 0, k == 0))
    def _():
        # chunk -1 boundary := x0 at every iteration slot (parity 0)
        bound_ref[pl.ds(0, K + 1), :] = jnp.broadcast_to(
            x0_ref[...].astype(jnp.float32), (K + 1, d_tile))
        resid_ref[...] = jnp.zeros_like(resid_ref)

    p_prev = jax.lax.rem(c, 2)
    p_cur = 1 - p_prev

    @pl.when(k == 0)
    def _():
        # iteration-0 guess of this chunk is all-zero …
        traj_ref[pl.ds(0, chunk), :] = jnp.zeros((chunk, d_tile), jnp.float32)
        # … so its last row (next chunk's k=0 guess boundary) is zero too
        bound_ref[pl.ds(p_cur * (K + 1), 1), :] = jnp.zeros(
            (1, d_tile), jnp.float32)
        ldelta_ref[...] = jnp.full((1, d_tile), jnp.inf, jnp.float32)

    src = jax.lax.rem(k, 2)
    dst = 1 - src
    guess = traj_ref[pl.ds(src * chunk, chunk), :]
    left = bound_ref[pl.ds(p_prev * (K + 1) + k, 1), :]        # guess boundary
    carry = bound_ref[pl.ds(p_prev * (K + 1) + k + 1, 1), :]   # scan carry

    def newton_step(_):
        x_shift = jnp.concatenate([left, guess[:-1]], axis=0)
        f_s, J = _gates_jac(x_shift, su, eu, pp, dt)
        b_lin = f_s - J * x_shift
        A, B = _fwd_chunk_scan(J, b_lin, chunk)
        return A * carry + B

    if skip_tol > 0.0:
        # chunk-local compute early exit: if the previous step left this
        # chunk AND both incoming boundary slots unchanged (<= skip_tol),
        # iteration k+1 reproduces iteration k — copy instead of compute.
        left_prev = bound_ref[pl.ds(p_prev * (K + 1) +
                                    jnp.maximum(k - 1, 0), 1), :]
        carry_prev = left    # carry at step k-1 was bound_prev[k]
        bnd_delta = jnp.maximum(jnp.max(jnp.abs(left - left_prev)),
                                jnp.max(jnp.abs(carry - carry_prev)))
        converged = jnp.logical_and(
            k > 0, jnp.logical_and(jnp.max(ldelta_ref[...]) <= skip_tol,
                                   bnd_delta <= skip_tol))
        states = jax.lax.cond(converged, lambda _: guess, newton_step, None)
    else:
        states = newton_step(None)

    # ---- residual reduction (per channel, valid rows only) ------------------
    delta = jnp.abs(states - guess)
    if valid_rows % chunk != 0:
        row = jax.lax.broadcasted_iota(jnp.int32, (chunk, d_tile), 0)
        delta = jnp.where(row + c * chunk < valid_rows, delta, 0.0)
    delta = jnp.max(delta, axis=0, keepdims=True)
    ldelta_ref[...] = delta
    resid_ref[pl.ds(k, 1), :] = jnp.maximum(resid_ref[pl.ds(k, 1), :], delta)

    # ---- commit -------------------------------------------------------------
    out_ref[...] = states.astype(out_ref.dtype)   # flushed once per chunk
    traj_ref[pl.ds(dst * chunk, chunk), :] = states
    bound_ref[pl.ds(p_cur * (K + 1) + k + 1, 1), :] = states[-1:]


@functools.partial(jax.jit,
                   static_argnames=("n_iters", "chunk", "d_tile", "dt",
                                    "interpret", "valid_rows", "skip_tol"))
def lrc_deer_megakernel_pallas(s_u: jax.Array, eps_u: jax.Array,
                               packed_params: jax.Array, x0: jax.Array, *,
                               n_iters: int = 10, chunk: int = 256,
                               d_tile: int = 512, dt: float = 1.0,
                               interpret: bool | None = None,
                               valid_rows: int | None = None,
                               skip_tol: float = 0.0):
    """Whole K-iteration DEER solve in ONE kernel launch (zero init guess).

    s_u/eps_u: (T, D); packed_params: (10, D); x0: (D,).  Returns
    ``(states (T, D), resid (n_iters, D))`` where ``resid[k, d]`` is the
    channel-d Newton residual max_t |x^{k+1}_t - x^k_t| of iteration k+1
    over the first ``valid_rows`` timesteps (default T) — the on-device
    input for ``tol``-mode iteration counting without a host sync.

    Identical values to ``n_iters`` applications of
    ``lrc_deer_iteration_pallas`` (the wavefront schedule is a loop-skewed
    traversal of the same iteration space), at 2 reads + 1 write of (T, D)
    HBM traffic for the WHOLE solve.

    ``skip_tol > 0`` additionally gates the per-chunk compute: once a
    chunk's trajectory and both incoming boundary slots move less than
    ``skip_tol`` between consecutive iterations, remaining iterations on
    that chunk degenerate to copies (an approximate compute early exit;
    0.0 = exact schedule).
    """
    interpret = resolve_interpret(interpret)
    T, D = s_u.shape
    assert T % chunk == 0 and D % d_tile == 0
    if valid_rows is None:
        valid_rows = T
    grid = (D // d_tile, T // chunk, n_iters)
    t_spec = pl.BlockSpec((chunk, d_tile), lambda d, c, k: (c, d))
    return pl.pallas_call(
        functools.partial(_lrc_deer_megakernel, chunk=chunk, n_iters=n_iters,
                          dt=dt, valid_rows=valid_rows, skip_tol=skip_tol),
        grid=grid,
        in_specs=[
            t_spec,
            t_spec,
            pl.BlockSpec((10, d_tile), lambda d, c, k: (0, d)),
            pl.BlockSpec((1, d_tile), lambda d, c, k: (0, d)),
        ],
        out_specs=[
            t_spec,
            pl.BlockSpec((n_iters, d_tile), lambda d, c, k: (0, d)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, D), s_u.dtype),
            jax.ShapeDtypeStruct((n_iters, D), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((2 * chunk, d_tile), jnp.float32),
            pltpu.VMEM((2 * (n_iters + 1), d_tile), jnp.float32),
            pltpu.VMEM((1, d_tile), jnp.float32),
        ],
        interpret=interpret,
    )(s_u, eps_u, packed_params, x0.reshape(1, D))


# ---------------------------------------------------------------------------
# fused implicit-adjoint reverse kernel
# ---------------------------------------------------------------------------

def _lrc_deer_adjoint_kernel(xs_ref, su_ref, eu_ref, pp_ref, gbar_ref,
                             jr_ref, *refs, chunk: int, n_chunks: int,
                             dt: float, valid_rows: int,
                             with_cumulative: bool):
    if with_cumulative:
        out_ref, aout_ref, gcarry_ref, acarry_ref, jb_ref = refs
    else:
        (out_ref, gcarry_ref, jb_ref), aout_ref, acarry_ref = refs, None, None
    t = pl.program_id(1)   # walks chunks right-to-left (index maps reversed)

    xs = xs_ref[...].astype(jnp.float32)     # shifted CONVERGED states
    su = su_ref[...].astype(jnp.float32)
    eu = eu_ref[...].astype(jnp.float32)
    pp = pp_ref[...].astype(jnp.float32)
    gbar = gbar_ref[...].astype(jnp.float32)

    @pl.when(t == 0)
    def _():
        # rightmost chunk: J just past the end (zero, or the right
        # neighbour's first-row J on a time shard) and terminal g = 0
        jb_ref[...] = jr_ref[...].astype(jnp.float32)
        gcarry_ref[...] = jnp.zeros_like(gcarry_ref)
        if with_cumulative:
            acarry_ref[...] = jnp.ones_like(acarry_ref)

    _, J = _gates_jac(xs, su, eu, pp, dt)
    jac_next = jnp.concatenate([J[1:], jb_ref[...]], axis=0)
    jb_ref[...] = J[:1]

    if valid_rows % chunk != 0:
        # padded tail: identity affine maps (A=1, B=0) pass the carry
        # through unchanged and the true right-boundary J applies at the
        # LAST VALID row, so the emitted cumulative map is exact for the
        # real rows — required by the cross-shard reverse fixup.
        c_actual = n_chunks - 1 - t
        grow = (jax.lax.broadcasted_iota(jnp.int32, gbar.shape, 0)
                + c_actual * chunk)
        jac_next = jnp.where(grow >= valid_rows, 1.0, jac_next)
        jac_next = jnp.where(grow == valid_rows - 1,
                             jr_ref[...].astype(jnp.float32), jac_next)
        gbar = jnp.where(grow >= valid_rows, 0.0, gbar)

    # reverse Hillis-Steele: g_t = A_t * g_{edge+1} + B_t within the chunk
    A, B = _rev_chunk_scan(jac_next, gbar, chunk)

    if with_cumulative:
        # local affine map from the SLICE's right edge: compose the chunk's
        # suffix map with the carry map accumulated from chunks to the right
        a_glob = A * acarry_ref[...]
        g_glob = A * gcarry_ref[...] + B
        out_ref[...] = g_glob.astype(out_ref.dtype)
        aout_ref[...] = a_glob.astype(aout_ref.dtype)
        acarry_ref[...] = a_glob[:1]
        gcarry_ref[...] = g_glob[:1]
    else:
        g = A * gcarry_ref[...] + B
        out_ref[...] = g.astype(out_ref.dtype)
        gcarry_ref[...] = g[:1]


@functools.partial(jax.jit,
                   static_argnames=("chunk", "d_tile", "dt", "interpret",
                                    "valid_rows", "with_cumulative"))
def lrc_deer_adjoint_pallas(x_shift: jax.Array, s_u: jax.Array,
                            eps_u: jax.Array, packed_params: jax.Array,
                            gbar: jax.Array, jac_right: jax.Array, *,
                            chunk: int = 256, d_tile: int = 512,
                            dt: float = 1.0, interpret: bool | None = None,
                            valid_rows: int | None = None,
                            with_cumulative: bool = False):
    """Fused implicit-adjoint reverse scan: solves

        g_t = gbar_t + J_{t+1} * g_{t+1},   g_{T+1} = 0

    in one pass — gate recompute at the converged trajectory (``x_shift`` =
    states shifted right by one, slot 0 = x0), exact diagonal J, in-kernel
    shift-left of J, reverse Hillis-Steele chunk scan.  ``jac_right`` (D,)
    is J at the step just past the end: zeros for a replicated solve, the
    right neighbour's first-row J on a time shard.

    Returns g (T, D).  With ``with_cumulative``: (g0, A_cum) where g0 is
    the solution with zero terminal state and A_cum the cumulative
    jac_next product from the slice's right edge — the reverse local
    affine map ``core.scan.sharded_scan_fixup(reverse=True)`` composes
    across time shards.
    """
    interpret = resolve_interpret(interpret)
    T, D = x_shift.shape
    assert T % chunk == 0 and D % d_tile == 0
    if valid_rows is None:
        valid_rows = T
    n_t = T // chunk
    grid = (D // d_tile, n_t)
    t_spec = pl.BlockSpec((chunk, d_tile), lambda d, t: (n_t - 1 - t, d))
    out_specs = [t_spec, t_spec] if with_cumulative else t_spec
    out_shape = jax.ShapeDtypeStruct((T, D), gbar.dtype)
    scratch = [pltpu.VMEM((1, d_tile), jnp.float32)]
    if with_cumulative:
        out_shape = [out_shape, jax.ShapeDtypeStruct((T, D), gbar.dtype)]
        scratch = scratch + [pltpu.VMEM((1, d_tile), jnp.float32)]
    scratch = scratch + [pltpu.VMEM((1, d_tile), jnp.float32)]  # jb_ref
    return pl.pallas_call(
        functools.partial(_lrc_deer_adjoint_kernel, chunk=chunk,
                          n_chunks=n_t, dt=dt, valid_rows=valid_rows,
                          with_cumulative=with_cumulative),
        grid=grid,
        in_specs=[
            t_spec,
            t_spec,
            t_spec,
            pl.BlockSpec((10, d_tile), lambda d, t: (0, d)),
            t_spec,
            pl.BlockSpec((1, d_tile), lambda d, t: (0, d)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=interpret,
    )(x_shift, s_u, eps_u, packed_params, gbar, jac_right.reshape(1, D))

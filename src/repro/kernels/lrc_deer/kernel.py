"""Pallas TPU kernel: fused LRC-gate + exact-linearise + scan — one full
DEER Newton iteration for the LrcSSM cell in a single HBM round trip.

Per Newton iteration the unfused path materialises in HBM: the gate
pre-activations, the step values f_s, the diagonal Jacobian J_s, the
linearisation offset b_s, and the scan intermediates — 5+ (T, D) tensors
read/written. This kernel computes everything on VMEM tiles:

    read   x_shift (guess, pre-shifted), s_u, eps_u          (3 reads)
    VMEM   gates sigma/tanh, ANALYTIC diagonal Jacobian J,
           b = f - J*x_shift, Hillis-Steele chunk scan + carry
    write  new states                                         (1 write)

=> HBM traffic per iteration drops from ~10 (T,D)-streams to 4, directly
scaling the memory-roofline term of the DEER solve by ~2.5x (§Perf log).

The Jacobian is the exact closed-form elementwise derivative of the LRC
Euler step (diagonal BY MODEL DESIGN — the paper's central property):

    x' = lam*x + beta,  lam = 1 - dt*sig_f*sig_e,  beta = dt*tau_z*sig_e*el
    J  = lam + x*dlam/dx + dbeta/dx        (all elementwise)

Per-channel parameters (10 x (D,)) ride along as a (10, Dt) block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# row indices of the packed parameter block
P_AX, P_BX, P_GMX, P_KMX, P_GMU, P_KMU, P_WX, P_VX, P_GL, P_EL = range(10)


def _sigmoid(x):
    return jax.nn.sigmoid(x)


def _lrc_deer_kernel(xs_ref, su_ref, eu_ref, pp_ref, x0_ref, *refs,
                     chunk: int, dt: float, with_cumulative: bool = False):
    if with_cumulative:
        out_ref, aout_ref, carry_ref, acarry_ref = refs
    else:
        (out_ref, carry_ref), aout_ref, acarry_ref = refs, None, None
    t = pl.program_id(1)

    xs = xs_ref[...].astype(jnp.float32)     # (C, Dt) shifted guess
    su = su_ref[...].astype(jnp.float32)
    eu = eu_ref[...].astype(jnp.float32)
    pp = pp_ref[...].astype(jnp.float32)     # (10, Dt)

    a_x, b_x = pp[P_AX], pp[P_BX]
    gmx, kmx = pp[P_GMX], pp[P_KMX]
    gmu, kmu = pp[P_GMU], pp[P_KMU]
    w_x, v_x = pp[P_WX], pp[P_VX]
    g_l, e_l = pp[P_GL], pp[P_EL]

    # ---- gates at the guess -------------------------------------------------
    s_x = _sigmoid(a_x * xs + b_x)
    f = gmx * s_x + gmu * su + g_l
    z = kmx * s_x + kmu * su + g_l
    eps = w_x * xs + v_x + eu
    sig_f = _sigmoid(f)
    sig_e = _sigmoid(eps)
    tau_z = jnp.tanh(z)
    lam = 1.0 - dt * sig_f * sig_e
    beta = dt * tau_z * sig_e * e_l
    f_s = lam * xs + beta                    # step value F(x_guess)

    # ---- exact diagonal Jacobian (closed form) ------------------------------
    ds_x = s_x * (1.0 - s_x) * a_x
    dsig_f = sig_f * (1.0 - sig_f) * (gmx * ds_x)
    dsig_e = sig_e * (1.0 - sig_e) * w_x
    dtau_z = (1.0 - tau_z * tau_z) * (kmx * ds_x)
    dlam = -dt * (dsig_f * sig_e + sig_f * dsig_e)
    dbeta = dt * e_l * (dtau_z * sig_e + tau_z * dsig_e)
    J = lam + xs * dlam + dbeta
    b_lin = f_s - J * xs

    # ---- carry init ----------------------------------------------------------
    @pl.when(t == 0)
    def _():
        carry_ref[...] = x0_ref[...].astype(jnp.float32)
        if with_cumulative:
            acarry_ref[...] = jnp.ones_like(acarry_ref)

    # ---- Hillis-Steele chunk scan -------------------------------------------
    A, B = J, b_lin
    k = 1
    while k < chunk:
        ones = jnp.ones((k, A.shape[1]), jnp.float32)
        zeros = jnp.zeros((k, B.shape[1]), jnp.float32)
        A_prev = jnp.concatenate([ones, A[:-k]], axis=0)
        B_prev = jnp.concatenate([zeros, B[:-k]], axis=0)
        B = A * B_prev + B
        A = A * A_prev
        k *= 2

    carry = carry_ref[...]
    states = A * carry + B
    out_ref[...] = states.astype(out_ref.dtype)
    carry_ref[...] = states[-1:]
    if with_cumulative:
        # Running cumulative Jacobian product from the SLICE start — with a
        # zero x0 the (states, A_glob) pair is exactly the (B_cum, A_cum)
        # local affine map that core.scan.sharded_scan_fixup composes across
        # time shards.
        a_glob = A * acarry_ref[...]
        aout_ref[...] = a_glob.astype(aout_ref.dtype)
        acarry_ref[...] = a_glob[-1:]


@functools.partial(jax.jit,
                   static_argnames=("chunk", "d_tile", "dt", "interpret",
                                    "with_cumulative"))
def lrc_deer_iteration_pallas(x_shift: jax.Array, s_u: jax.Array,
                              eps_u: jax.Array, packed_params: jax.Array,
                              x0: jax.Array, *, chunk: int = 256,
                              d_tile: int = 512, dt: float = 1.0,
                              interpret: bool = True,
                              with_cumulative: bool = False):
    """One fused Newton iteration. x_shift/s_u/eps_u: (T, D);
    packed_params: (10, D) rows [a_x,b_x,g_max_x,k_max_x,g_max_u,k_max_u,
    w_x,v_x,g_leak,e_leak]; x0: (D,). Returns new states (T, D).

    With ``with_cumulative`` the kernel ALSO emits the running cumulative
    Jacobian product A_cum from the slice start, returning (states, A_cum):
    the local affine map (A_cum, states|_{x0=0}) that the shard-composable
    entry point (``ops.sharded_lrc_deer_solve``) stitches across time shards
    with ``core.scan.sharded_scan_fixup``.
    """
    T, D = x_shift.shape
    assert T % chunk == 0 and D % d_tile == 0
    grid = (D // d_tile, T // chunk)
    t_spec = pl.BlockSpec((chunk, d_tile), lambda d, t: (t, d))
    out_specs = [t_spec, t_spec] if with_cumulative else t_spec
    out_shape = jax.ShapeDtypeStruct((T, D), x_shift.dtype)
    scratch = [pltpu.VMEM((1, d_tile), jnp.float32)]
    if with_cumulative:
        out_shape = [out_shape, jax.ShapeDtypeStruct((T, D), x_shift.dtype)]
        scratch = scratch + [pltpu.VMEM((1, d_tile), jnp.float32)]
    return pl.pallas_call(
        functools.partial(_lrc_deer_kernel, chunk=chunk, dt=dt,
                          with_cumulative=with_cumulative),
        grid=grid,
        in_specs=[
            t_spec,
            t_spec,
            t_spec,
            pl.BlockSpec((10, d_tile), lambda d, t: (0, d)),
            pl.BlockSpec((1, d_tile), lambda d, t: (0, d)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=interpret,
    )(x_shift, s_u, eps_u, packed_params, x0.reshape(1, D))

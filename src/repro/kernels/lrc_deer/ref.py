"""Pure-jnp oracle for the fused LRC-DEER iteration kernel."""
import jax
import jax.numpy as jnp

from repro.kernels.lrc_deer.kernel import (P_AX, P_BX, P_EL, P_GL, P_GMU,
                                           P_GMX, P_KMU, P_KMX, P_VX, P_WX)


def _step(pp, xs, su, eu, dt):
    s_x = jax.nn.sigmoid(pp[P_AX] * xs + pp[P_BX])
    f = pp[P_GMX] * s_x + pp[P_GMU] * su + pp[P_GL]
    z = pp[P_KMX] * s_x + pp[P_KMU] * su + pp[P_GL]
    eps = pp[P_WX] * xs + pp[P_VX] + eu
    sig_f, sig_e, tau_z = (jax.nn.sigmoid(f), jax.nn.sigmoid(eps),
                           jnp.tanh(z))
    lam = 1.0 - dt * sig_f * sig_e
    beta = dt * tau_z * sig_e * pp[P_EL]
    return lam * xs + beta


def lrc_deer_iteration_ref(x_shift, s_u, eps_u, packed_params, x0,
                           dt: float = 1.0):
    """One Newton iteration, unfused: jvp Jacobian + sequential scan."""
    pp = packed_params.astype(jnp.float32)
    xs = x_shift.astype(jnp.float32)
    su = s_u.astype(jnp.float32)
    eu = eps_u.astype(jnp.float32)

    fn = lambda x: _step(pp, x, su, eu, dt)
    f_s, J = jax.jvp(fn, (xs,), (jnp.ones_like(xs),))
    b_lin = f_s - J * xs

    def scan_step(x, jb):
        j, b = jb
        x = j * x + b
        return x, x
    _, states = jax.lax.scan(scan_step, x0.astype(jnp.float32), (J, b_lin))
    return states.astype(x_shift.dtype)


def lrc_deer_iteration_affine_ref(x_shift, s_u, eps_u, packed_params,
                                  dt: float = 1.0):
    """Oracle for the kernel's ``with_cumulative`` contract: the local
    affine map (A_cum, B_cum) of the linearised recurrence from the slice
    start — states(x0) = A_cum * x0 + B_cum. This is what the
    shard-composable entry stitches across time shards."""
    pp = packed_params.astype(jnp.float32)
    xs = x_shift.astype(jnp.float32)
    fn = lambda x: _step(pp, x, s_u.astype(jnp.float32),
                         eps_u.astype(jnp.float32), dt)
    f_s, J = jax.jvp(fn, (xs,), (jnp.ones_like(xs),))
    b_lin = f_s - J * xs

    def scan_step(carry, jb):
        a, x = carry
        j, b = jb
        out = (j * a, j * x + b)
        return out, out

    init = (jnp.ones_like(xs[0]), jnp.zeros_like(xs[0]))
    _, (A_cum, B_cum) = jax.lax.scan(scan_step, init, (J, b_lin))
    return A_cum.astype(x_shift.dtype), B_cum.astype(x_shift.dtype)


def lrc_deer_solve_ref(s_u, eps_u, packed_params, x0, n_iters: int = 10,
                       dt: float = 1.0):
    """Full DEER solve with the unfused reference iteration."""
    T = s_u.shape[0]
    states = jnp.zeros((T,) + x0.shape, s_u.dtype)
    for _ in range(n_iters):
        x_shift = jnp.concatenate([x0[None], states[:-1]], axis=0)
        states = lrc_deer_iteration_ref(x_shift, s_u, eps_u, packed_params,
                                        x0, dt)
    return states


def lrc_jac_ref(x_shift, s_u, eps_u, packed_params, dt: float = 1.0):
    """Exact diagonal Jacobian dF/dx at ``x_shift`` (any (.., D) shape) —
    one jvp through the closed-form step.  Oracle for the in-kernel
    analytic J, and the cheap one-row boundary-J producer the sharded
    fused adjoint ppermutes between time shards."""
    pp = packed_params.astype(jnp.float32)
    fn = lambda x: _step(pp, x, s_u.astype(jnp.float32),
                         eps_u.astype(jnp.float32), dt)
    _, J = jax.jvp(fn, (x_shift.astype(jnp.float32),),
                   (jnp.ones_like(x_shift, jnp.float32),))
    return J


def lrc_deer_adjoint_ref(x_shift, s_u, eps_u, packed_params, gbar,
                         dt: float = 1.0):
    """Unfused oracle for the fused adjoint kernel: jvp Jacobian at the
    converged (shifted) trajectory, shift-left, sequential reverse solve of
    g_t = gbar_t + J_{t+1} * g_{t+1} with zero terminal state."""
    J = lrc_jac_ref(x_shift, s_u, eps_u, packed_params, dt)
    jac_next = jnp.concatenate([J[1:], jnp.zeros_like(J[:1])], axis=0)

    def step(g_next, ab):
        a, b = ab
        g = a * g_next + b
        return g, g

    _, g = jax.lax.scan(step, jnp.zeros_like(gbar[0], jnp.float32),
                        (jac_next, gbar.astype(jnp.float32)), reverse=True)
    return g.astype(gbar.dtype)

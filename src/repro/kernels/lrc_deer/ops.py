"""Public wrappers: full DEER solve driven by the fused Pallas iteration.

``pack_lrc_params`` adapts a core.lrc parameter dict to the kernel's packed
(10, D) layout, so the kernel is a drop-in backend for LrcCellConfig models
(same math as core.deer with grad="unroll", mode="fixed").

Two solve entry points:

  * ``lrc_deer_solve``          — replicated: full (T, D) trajectory per
                                  device, the kernel's sequential chunk
                                  carry spans the whole sequence.
  * ``sharded_lrc_deer_solve``  — shard-composable: the on-chip Pallas
                                  schedule runs on a LOCAL T/P time slice
                                  (zero carry, emitting the slice's
                                  cumulative affine map) and the cross-chip
                                  decomposition is the same P-sized
                                  summary exchange + prefix fixup the lax
                                  solvers use (core.scan.sharded_scan_fixup)
                                  — composing the paper's two parallelism
                                  levels. Forward-only (the Pallas kernel
                                  has no vjp); per Newton iteration one
                                  (D,) ppermute + 2*P*D all-gather.
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.deer_sharded import _left_boundary, n_seq_shards
from repro.core.scan import sharded_scan_fixup
from repro.distributed import compat
from repro.kernels.lrc_deer.kernel import lrc_deer_iteration_pallas

PACK_ORDER = ("a_x", "b_x", "g_max_x", "k_max_x", "g_max_u", "k_max_u",
              "w_x", "v_x", "g_leak", "e_leak")


def pack_lrc_params(p: Dict[str, jax.Array]) -> jax.Array:
    return jnp.stack([p[k].astype(jnp.float32) for k in PACK_ORDER], axis=0)


def _pad_axis(x, axis, mult):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _adapt_chunk(T: int, chunk: int) -> int:
    """Shrink the chunk to a power of two >= 8 when the (local) time extent
    is smaller than the requested chunk — one rule for both solve entries."""
    return chunk if T >= chunk else max(8, 1 << max(T - 1, 1).bit_length())


@functools.partial(jax.jit, static_argnames=("n_iters", "chunk", "d_tile",
                                             "dt", "interpret"))
def lrc_deer_solve(s_u: jax.Array, eps_u: jax.Array,
                   packed_params: jax.Array, x0: jax.Array, *,
                   n_iters: int = 10, chunk: int = 256, d_tile: int = 512,
                   dt: float = 1.0, interpret: bool = True) -> jax.Array:
    """DEER fixed-point solve of the LrcSSM recurrence using the fused
    Pallas iteration. s_u, eps_u: (T, D); returns states (T, D)."""
    T, D = s_u.shape
    c = _adapt_chunk(T, chunk)
    dtile = d_tile if D >= d_tile else 128
    su = _pad_axis(_pad_axis(s_u, 0, c), 1, dtile)
    eu = _pad_axis(_pad_axis(eps_u, 0, c), 1, dtile)
    pp = _pad_axis(packed_params, 1, dtile)
    x0p = _pad_axis(x0, 0, dtile)
    Tp, Dp = su.shape

    def body(_, states):
        x_shift = jnp.concatenate([x0p[None], states[:-1]], axis=0)
        return lrc_deer_iteration_pallas(
            x_shift, su, eu, pp, x0p, chunk=c, d_tile=dtile, dt=dt,
            interpret=interpret)

    states = jax.lax.fori_loop(
        0, n_iters, body, jnp.zeros((Tp, Dp), s_u.dtype), unroll=False)
    return states[:T, :D]


def sharded_fused_viable(T: int, mesh, seq_axis, chunk: int = 256) -> bool:
    """True when ``sharded_lrc_deer_solve`` would actually run SHARDED for
    this (T, mesh, seq_axis): axes present, T divisible by the shard count,
    local slice a multiple of the adapted chunk. Routing layers
    (core/block.py) check this so a non-viable fused tier falls to the
    sharded-lax tier — NOT to the replicated fused solve this entry point
    itself degrades to for direct callers."""
    n = n_seq_shards(mesh, seq_axis)
    if n <= 1 or T % n != 0:
        return False
    T_loc = T // n
    return T_loc % _adapt_chunk(T_loc, chunk) == 0


def sharded_lrc_deer_solve(s_u: jax.Array, eps_u: jax.Array,
                           packed_params: jax.Array, x0: jax.Array, *,
                           mesh, seq_axis="data", n_iters: int = 10,
                           chunk: int = 256, d_tile: int = 512,
                           dt: float = 1.0,
                           interpret: bool = True) -> jax.Array:
    """DEER fixed-point solve with the fused Pallas iteration running on a
    T/P time shard per device, the trajectory sharded over mesh axis (or
    axes tuple) ``seq_axis`` for the whole solve.

    Per Newton iteration, inside one shard_map: ppermute of the left
    neighbour's last state (the shifted-guess boundary), one fused kernel
    invocation over the local (T/P, D) slice with a ZERO carry — emitting
    the slice states and the cumulative Jacobian product, i.e. the local
    affine map — then the cross-shard prefix fixup
    (``core.scan.sharded_scan_fixup``: all-gather of P summaries, exclusive
    prefix, one elementwise apply).

    Same result as ``lrc_deer_solve`` (values only; forward-only like it).
    Falls back to the replicated ``lrc_deer_solve`` when any ``seq_axis``
    name is missing from the mesh or T/P is not a positive multiple of the
    (adapted) chunk.
    """
    T, D = s_u.shape
    n_shards = n_seq_shards(mesh, seq_axis)
    repl = functools.partial(lrc_deer_solve, n_iters=n_iters, chunk=chunk,
                             d_tile=d_tile, dt=dt, interpret=interpret)
    if n_shards <= 1 or T % n_shards != 0:
        return repl(s_u, eps_u, packed_params, x0)
    T_loc = T // n_shards
    c = _adapt_chunk(T_loc, chunk)
    if T_loc % c != 0:
        return repl(s_u, eps_u, packed_params, x0)

    dtile = d_tile if D >= d_tile else 128
    su = _pad_axis(s_u, 1, dtile)
    eu = _pad_axis(eps_u, 1, dtile)
    pp = _pad_axis(packed_params, 1, dtile)
    x0p = _pad_axis(x0, 0, dtile)
    Dp = su.shape[1]

    def local(su_s, eu_s, pp_r, x0_r):
        zeros0 = jnp.zeros_like(x0_r)

        def body(_, states_s):
            left = _left_boundary(states_s, x0_r, seq_axis, n_shards)
            x_shift = jnp.concatenate([left[None], states_s[:-1]], axis=0)
            b_cum, a_cum = lrc_deer_iteration_pallas(
                x_shift, su_s, eu_s, pp_r, zeros0, chunk=c, d_tile=dtile,
                dt=dt, interpret=interpret, with_cumulative=True)
            return sharded_scan_fixup(a_cum, b_cum, x0_r, seq_axis)

        return jax.lax.fori_loop(0, n_iters, body,
                                 jnp.zeros((T_loc, Dp), su_s.dtype),
                                 unroll=False)

    t_spec = P(seq_axis)
    states = compat.shard_map(
        local, mesh=mesh,
        in_specs=(t_spec, t_spec, P(), P()),
        out_specs=t_spec,
        check_vma=False,
    )(su, eu, pp, x0p)
    return states[:, :D]

"""Public wrappers: full DEER solves driven by the fused Pallas kernels.

``pack_lrc_params`` adapts a core.lrc parameter dict to the kernel's packed
(10, D) layout, so the kernel is a drop-in backend for LrcCellConfig models
(same math as core.deer with mode="fixed").

Solve entry points (all DIFFERENTIABLE via the implicit-function-theorem
adjoint, run by the fused reverse kernel — the fixed point's gradient does
not depend on how many Newton iterations produced it):

  * ``lrc_deer_solve``          — replicated: full (T, D) trajectory per
                                  device.  By default the whole K-iteration
                                  Newton solve runs inside ONE megakernel
                                  launch (``megakernel=False`` falls back
                                  to K per-iteration kernel calls, kept as
                                  the benchmark baseline).
  * ``lrc_deer_solve_tol``      — megakernel + the in-kernel residual
                                  reduction: returns (states, n_iters)
                                  with ``tol``-mode iteration counting on
                                  device (no host sync).
  * ``sharded_lrc_deer_solve``  — shard-composable: the on-chip Pallas
                                  schedule runs on a LOCAL T/P time slice
                                  and the cross-chip decomposition is the
                                  same P-sized summary exchange + prefix
                                  fixup the lax solvers use
                                  (core.scan.sharded_scan_fixup), in BOTH
                                  time directions: per Newton iteration one
                                  (D,) ppermute + 2*P*D all-gather forward;
                                  one ppermute + one reverse fixup for the
                                  fused adjoint backward.

Tiling (``chunk``/``d_tile``) defaults to ``kernels.autotune.get_tiling``
— the measured/analytic sweep with the persistent per-(backend, T, D, K)
cache; pass explicit values to pin the geometry.  ``interpret=None``
auto-detects the backend (compiled on TPU, interpreter on CPU).

``make_fused_adjoint_scans`` builds the hooks that plug the fused reverse
kernel into the GENERIC solvers' IFT backward passes
(``core.deer.implicit_adjoint`` / ``core.deer_sharded.
sharded_implicit_adjoint``) for cells in the packed-lrc form.
"""
from __future__ import annotations

import functools
from typing import Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
from repro.distributed.sharding import make_spec as P

from repro.core.deer_sharded import (_left_boundary, _right_jac_first,
                                     n_seq_shards)
from repro.core.scan import sharded_scan_fixup
from repro.distributed import compat
from repro.kernels import autotune
from repro.kernels.lrc_deer.kernel import (lrc_deer_adjoint_pallas,
                                           lrc_deer_iteration_pallas,
                                           lrc_deer_megakernel_pallas)
from repro.kernels.lrc_deer.ref import _step as _ref_step
from repro.kernels.lrc_deer.ref import lrc_jac_ref

PACK_ORDER = ("a_x", "b_x", "g_max_x", "k_max_x", "g_max_u", "k_max_u",
              "w_x", "v_x", "g_leak", "e_leak")


def pack_lrc_params(p: Dict[str, jax.Array]) -> jax.Array:
    """Stack the 10 per-channel cell parameters into the kernels' (10, D)
    packed layout (row order = ``PACK_ORDER``)."""
    return jnp.stack([p[k].astype(jnp.float32) for k in PACK_ORDER], axis=0)


def _pad_axis(x, axis, mult):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _adapt_chunk(T: int, chunk: int) -> int:
    """Shrink the chunk to a power of two >= 8 when the (local) time extent
    is smaller than the requested chunk — one rule for both solve entries."""
    return chunk if T >= chunk else max(8, 1 << max(T - 1, 1).bit_length())


def _resolve_tiling(T: int, D: int, n_iters: int,
                    chunk: Optional[int], d_tile: Optional[int],
                    io_bytes: int = 4):
    """Fill unset chunk/d_tile from the autotune layer, then clamp both to
    the problem extent (small-T chunk adaptation, small-D 128-lane tile).
    ``io_bytes`` is the HBM-stream element width (4 fp32, 2 bf16, 1 fp8):
    narrower streams shrink the pipeline VMEM term, widening the viable
    tiling set the autotuner picks from."""
    if chunk is None or d_tile is None:
        t = autotune.get_tiling(T, D, n_iters, io_bytes=io_bytes)
        chunk = chunk if chunk is not None else t.chunk
        d_tile = d_tile if d_tile is not None else t.d_tile
    return _adapt_chunk(T, chunk), (d_tile if D >= d_tile else 128)


# HBM-stream dtypes the fused solves accept for their (T, D) streams; VMEM
# accumulation stays fp32 regardless (the kernels read every ref through
# .astype(f32)). NOTE compiled-TPU sublane minima are (16, 128) bf16 /
# (32, 128) fp8 — `_adapt_chunk`'s small-T floor of 8 rows is
# interpret-mode-only territory there.
_IO_DTYPES = {"bf16": jnp.bfloat16, "fp8": jnp.float8_e4m3fn}
_FP8_MAX = 448.0  # e4m3 saturation (no inf encoding)


def _io_cast(x: jax.Array, io_dtype: Optional[str]) -> jax.Array:
    if io_dtype is None:
        return x
    if io_dtype not in _IO_DTYPES:
        raise ValueError(f"io_dtype={io_dtype!r}: expected one of "
                         f"{tuple(_IO_DTYPES)} or None")
    if io_dtype == "fp8":
        x = jnp.clip(x.astype(jnp.float32), -_FP8_MAX, _FP8_MAX)
    return x.astype(_IO_DTYPES[io_dtype])


def _f32_step(dt: float):
    """The closed-form Euler step in f32, as a 4-ary function of
    (packed_params, x_shift, s_u, eps_u) — the vjp target for the
    implicit-adjoint parameter/feature cotangents."""
    def step(pp, xs, su, eu):
        return _ref_step(pp.astype(jnp.float32), xs.astype(jnp.float32),
                         su.astype(jnp.float32), eu.astype(jnp.float32), dt)
    return step


# ---------------------------------------------------------------------------
# replicated solve (megakernel by default, differentiable)
# ---------------------------------------------------------------------------

class _SolveCfg(NamedTuple):
    n_iters: int
    chunk: int
    d_tile: int
    dt: float
    interpret: Optional[bool]
    megakernel: bool
    skip_tol: float


def _solve_fwd_impl(cfg: _SolveCfg, su, eu, pp, x0, valid_rows):
    """Forward Newton solve on PADDED (Tp, Dp) arrays."""
    if cfg.megakernel:
        states, resid = lrc_deer_megakernel_pallas(
            su, eu, pp, x0, n_iters=cfg.n_iters, chunk=cfg.chunk,
            d_tile=cfg.d_tile, dt=cfg.dt, interpret=cfg.interpret,
            valid_rows=valid_rows, skip_tol=cfg.skip_tol)
        return states, resid
    def body(_, states):
        x_shift = jnp.concatenate([x0[None], states[:-1]], axis=0)
        return lrc_deer_iteration_pallas(
            x_shift, su, eu, pp, x0, chunk=cfg.chunk, d_tile=cfg.d_tile,
            dt=cfg.dt, interpret=cfg.interpret)
    states = jax.lax.fori_loop(0, cfg.n_iters, body,
                               jnp.zeros(su.shape, su.dtype), unroll=False)
    return states, None


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _fused_solve(cfg: _SolveCfg, su, eu, pp, x0):
    """Padded-domain fixed-point solve with the IFT custom_vjp."""
    states, _ = _solve_fwd_impl(cfg, su, eu, pp, x0, su.shape[0])
    return states


def _fused_solve_fwd(cfg, su, eu, pp, x0):
    states = _fused_solve(cfg, su, eu, pp, x0)
    return states, (su, eu, pp, x0, states)


def _fused_solve_bwd(cfg, res, gbar):
    su, eu, pp, x0, states = res
    shifted = jnp.concatenate([x0[None], states[:-1]], axis=0)
    g = lrc_deer_adjoint_pallas(
        shifted, su, eu, pp, gbar.astype(jnp.float32),
        jnp.zeros((su.shape[1],), jnp.float32), chunk=cfg.chunk,
        d_tile=cfg.d_tile, dt=cfg.dt, interpret=cfg.interpret)
    _, vjp = jax.vjp(_f32_step(cfg.dt), pp, shifted, su, eu)
    d_pp, d_xs, d_su, d_eu = vjp(g)
    return (d_su.astype(su.dtype), d_eu.astype(eu.dtype),
            d_pp.astype(pp.dtype), d_xs[0].astype(x0.dtype))


_fused_solve.defvjp(_fused_solve_fwd, _fused_solve_bwd)


def _pad_solve_args(s_u, eps_u, packed_params, x0, c, dtile):
    su = _pad_axis(_pad_axis(s_u, 0, c), 1, dtile)
    eu = _pad_axis(_pad_axis(eps_u, 0, c), 1, dtile)
    pp = _pad_axis(packed_params, 1, dtile)
    x0p = _pad_axis(x0, 0, dtile)
    return su, eu, pp, x0p


def lrc_deer_solve(s_u: jax.Array, eps_u: jax.Array,
                   packed_params: jax.Array, x0: jax.Array, *,
                   n_iters: int = 10, chunk: Optional[int] = None,
                   d_tile: Optional[int] = None, dt: float = 1.0,
                   interpret: Optional[bool] = None,
                   megakernel: bool = True,
                   skip_tol: float = 0.0,
                   io_dtype: Optional[str] = None) -> jax.Array:
    """DEER fixed-point solve of the LrcSSM recurrence with the fused
    Pallas kernels.  s_u, eps_u: (T, D); returns states (T, D).

    Differentiable w.r.t. every array argument via the fused
    implicit-adjoint reverse kernel (exact IFT gradient at the fixed
    point).  ``megakernel=True`` (default) runs all ``n_iters`` Newton
    iterations inside one kernel launch — ~3 HBM (T, D)-streams for the
    whole solve; ``False`` issues one fused kernel per iteration (the
    pre-megakernel baseline, kept for the roofline benchmark).
    ``chunk``/``d_tile`` default to the autotuned tiling.

    ``io_dtype`` ("bf16" | "fp8" | None): stream the (T, D) HBM traffic —
    s_u, eps_u, the trajectory, and their cotangents — in a narrow dtype
    while every VMEM accumulation (gates, Jacobian cumprods, scans) stays
    fp32; the solve is stream-bound, so bytes-per-element scales wall
    clock directly (``autotune.solver_hbm_bytes``).  The casts sit OUTSIDE
    the custom_vjp, so autodiff routes gradients through them exactly
    (narrow cotangents on the wire, fp32 beyond the seam).  Returns fp32.
    """
    if io_dtype is not None:
        s_u, eps_u, x0 = (_io_cast(a, io_dtype) for a in (s_u, eps_u, x0))
    T, D = s_u.shape
    io_b = jnp.dtype(s_u.dtype).itemsize
    c, dtile = _resolve_tiling(T, D, n_iters, chunk, d_tile, io_bytes=io_b)
    su, eu, pp, x0p = _pad_solve_args(s_u, eps_u, packed_params, x0, c, dtile)
    cfg = _SolveCfg(n_iters, c, dtile, dt, interpret, megakernel, skip_tol)
    out = _fused_solve(cfg, su, eu, pp, x0p)[:T, :D]
    return out.astype(jnp.float32) if io_dtype is not None else out


def tol_iteration_count(resid: jax.Array, tol: float,
                        max_iters: int) -> jax.Array:
    """Iterations a ``tol``-mode while_loop would have run, from the
    per-iteration residual vector ``resid`` (max-norm over state entries,
    shape (max_iters,)): the first 1-based iteration whose residual is
    <= tol, or ``max_iters`` when none converges (exactly the
    ``core.deer`` while_loop trip count)."""
    conv = resid <= tol
    return jnp.where(jnp.any(conv),
                     1 + jnp.argmax(conv).astype(jnp.int32),
                     jnp.asarray(max_iters, jnp.int32))


def lrc_deer_solve_tol(s_u: jax.Array, eps_u: jax.Array,
                       packed_params: jax.Array, x0: jax.Array, *,
                       max_iters: int = 12, tol: float = 1e-6,
                       chunk: Optional[int] = None,
                       d_tile: Optional[int] = None, dt: float = 1.0,
                       interpret: Optional[bool] = None,
                       skip_tol: float = 0.0):
    """``tol``-mode megakernel solve: runs ``max_iters`` Newton iterations
    in one launch and derives the effective iteration count from the
    in-kernel residual reduction — no host sync, same counting semantics
    as ``core.deer.deer_solve(mode="tol")``.

    ``skip_tol > 0`` additionally lets chunks whose local update AND
    boundary slots moved less than ``skip_tol`` skip their remaining
    per-iteration compute inside the kernel (a skipped chunk records a
    zero residual).  That is an APPROXIMATE compute saver: with it on,
    reported n_iters can undercount the exact while_loop semantics, so it
    is opt-in — the default keeps exact counting parity.
    Returns (states (T, D), n_iters (), resid (max_iters,)).
    """
    T, D = s_u.shape
    c, dtile = _resolve_tiling(T, D, max_iters, chunk, d_tile)
    su, eu, pp, x0p = _pad_solve_args(s_u, eps_u, packed_params, x0, c, dtile)
    states, resid = lrc_deer_megakernel_pallas(
        su, eu, pp, x0p, n_iters=max_iters, chunk=c, d_tile=dtile, dt=dt,
        interpret=interpret, valid_rows=T, skip_tol=skip_tol)
    resid_max = jnp.max(resid[:, :D], axis=1)
    return (states[:T, :D], tol_iteration_count(resid_max, tol, max_iters),
            resid_max)


def lrc_deer_draft_solve(s_u: jax.Array, eps_u: jax.Array,
                         packed_params: jax.Array, x0: jax.Array, *,
                         draft_iters: int = 2,
                         chunk: Optional[int] = None,
                         d_tile: Optional[int] = None, dt: float = 1.0,
                         interpret: Optional[bool] = None) -> jax.Array:
    """Early-exit DRAFT solve for speculative decoding: a K=``draft_iters``
    truncated-Newton megakernel pass with chunk skipping enabled — a cheap
    PREDICTOR of the converged trajectory ("predictability enables
    parallelization"), whose drafted tokens the serve verify seam accepts
    or rolls back. Losslessness never depends on this output: the
    full-depth verify pass gates every emitted token, so both the iteration
    truncation and the approximate ``skip_tol`` early exit are safe here
    (and only here — the exact-counting caveat on ``lrc_deer_solve_tol``
    does not apply to a path whose answer is merely a guess).

    Forward-only (inference path: no custom_vjp detour). s_u/eps_u: (T, D);
    returns states (T, D)."""
    states, _, _ = lrc_deer_solve_tol(
        s_u, eps_u, packed_params, x0, max_iters=draft_iters, tol=0.0,
        chunk=chunk, d_tile=d_tile, dt=dt, interpret=interpret,
        skip_tol=1e-3)
    return states


# ---------------------------------------------------------------------------
# shard-composable solve (differentiable)
# ---------------------------------------------------------------------------

def _sharded_tiling(T_loc: int, D: int, n_iters: int,
                    chunk: Optional[int], d_tile: Optional[int]):
    """Tiling for the local T/P slice: explicit values win, otherwise the
    autotuner — the SAME resolution ``sharded_fused_viable`` uses, so the
    router's viability answer matches what the solve will actually run."""
    return _resolve_tiling(T_loc, D, n_iters, chunk, d_tile)


def sharded_fused_viable(T: int, mesh, seq_axis,
                         chunk: Optional[int] = None, *, D: int = 128,
                         n_iters: int = 10) -> bool:
    """True when ``sharded_lrc_deer_solve`` would actually run SHARDED for
    this (T, mesh, seq_axis): axes present, T divisible by the shard count,
    local slice a multiple of the (autotuned or explicit, then adapted)
    chunk. Routing layers (core/block.py) check this so a non-viable fused
    tier falls to the next tier rather than silently re-replicating the
    trajectory."""
    n = n_seq_shards(mesh, seq_axis)
    if n <= 1 or T % n != 0:
        return False
    T_loc = T // n
    c, _ = _sharded_tiling(T_loc, D, n_iters, chunk, None)
    return T_loc % c == 0


class _ShardedCfg(NamedTuple):
    mesh: object
    seq_axis: object
    n_shards: int
    n_iters: int
    chunk: int
    d_tile: int
    dt: float
    interpret: Optional[bool]


def _sharded_specs(cfg: _ShardedCfg):
    t_spec = P(cfg.seq_axis)
    return t_spec, P(), P()


def _sharded_fwd_impl(cfg: _ShardedCfg, su, eu, pp, x0p):
    t_spec, _, _ = _sharded_specs(cfg)
    T_loc = su.shape[0] // cfg.n_shards

    def local(su_s, eu_s, pp_r, x0_r):
        zeros0 = jnp.zeros_like(x0_r)

        def body(_, states_s):
            left = _left_boundary(states_s, x0_r, cfg.seq_axis, cfg.n_shards)
            x_shift = jnp.concatenate([left[None], states_s[:-1]], axis=0)
            b_cum, a_cum = lrc_deer_iteration_pallas(
                x_shift, su_s, eu_s, pp_r, zeros0, chunk=cfg.chunk,
                d_tile=cfg.d_tile, dt=cfg.dt, interpret=cfg.interpret,
                with_cumulative=True)
            return sharded_scan_fixup(a_cum, b_cum, x0_r, cfg.seq_axis)

        return jax.lax.fori_loop(0, cfg.n_iters, body,
                                 jnp.zeros((T_loc, su_s.shape[1]),
                                           su_s.dtype),
                                 unroll=False)

    return compat.shard_map(
        local, mesh=cfg.mesh,
        in_specs=(t_spec, t_spec, P(), P()),
        out_specs=t_spec,
        check_vma=False,
    )(su, eu, pp, x0p)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _sharded_fused_solve(cfg: _ShardedCfg, su, eu, pp, x0):
    return _sharded_fwd_impl(cfg, su, eu, pp, x0)


def _sharded_fused_fwd(cfg, su, eu, pp, x0):
    states = _sharded_fused_solve(cfg, su, eu, pp, x0)
    return states, (su, eu, pp, x0, states)


def _sharded_fused_bwd(cfg, res, gbar):
    su, eu, pp, x0, states = res
    t_spec, _, _ = _sharded_specs(cfg)

    def local(su_s, eu_s, pp_r, x0_r, states_s, gbar_s):
        idx = compat.axis_index(cfg.seq_axis)
        left = _left_boundary(states_s, x0_r, cfg.seq_axis, cfg.n_shards)
        shifted = jnp.concatenate([left[None], states_s[:-1]], axis=0)
        # boundary J for the shifted-left Jacobian: THIS shard's first-row
        # J travels to the left neighbour (zero past the global end)
        j0 = lrc_jac_ref(shifted[:1], su_s[:1], eu_s[:1], pp_r, cfg.dt)
        jR = _right_jac_first(j0, cfg.seq_axis, cfg.n_shards)
        g0, a_cum = lrc_deer_adjoint_pallas(
            shifted, su_s, eu_s, pp_r, gbar_s.astype(jnp.float32), jR,
            chunk=cfg.chunk, d_tile=cfg.d_tile, dt=cfg.dt,
            interpret=cfg.interpret, with_cumulative=True)
        g = sharded_scan_fixup(a_cum, g0, None, cfg.seq_axis, reverse=True)
        _, vjp = jax.vjp(_f32_step(cfg.dt), pp_r, shifted, su_s, eu_s)
        d_pp, d_xs, d_su, d_eu = vjp(g)
        d_pp = compat.psum(d_pp, cfg.seq_axis)
        d_x0 = compat.psum(
            jnp.where(idx == 0, d_xs[0], jnp.zeros_like(d_xs[0])),
            cfg.seq_axis)
        return (d_su.astype(su_s.dtype), d_eu.astype(eu_s.dtype),
                d_pp.astype(pp_r.dtype), d_x0.astype(x0_r.dtype))

    return compat.shard_map(
        local, mesh=cfg.mesh,
        in_specs=(t_spec, t_spec, P(), P(), t_spec, t_spec),
        out_specs=(t_spec, t_spec, P(), P()),
        check_vma=False,
    )(su, eu, pp, x0, states, gbar)


_sharded_fused_solve.defvjp(_sharded_fused_fwd, _sharded_fused_bwd)


def sharded_lrc_deer_solve(s_u: jax.Array, eps_u: jax.Array,
                           packed_params: jax.Array, x0: jax.Array, *,
                           mesh, seq_axis="data", n_iters: int = 10,
                           chunk: Optional[int] = None,
                           d_tile: Optional[int] = None,
                           dt: float = 1.0,
                           interpret: Optional[bool] = None,
                           io_dtype: Optional[str] = None) -> jax.Array:
    """DEER fixed-point solve with the fused Pallas iteration running on a
    T/P time shard per device, the trajectory sharded over mesh axis (or
    axes tuple) ``seq_axis`` for the whole solve.

    Per Newton iteration, inside one shard_map: ppermute of the left
    neighbour's last state (the shifted-guess boundary), one fused kernel
    invocation over the local (T/P, D) slice with a ZERO carry — emitting
    the slice states and the cumulative Jacobian product, i.e. the local
    affine map — then the cross-shard prefix fixup
    (``core.scan.sharded_scan_fixup``: all-gather of P summaries, exclusive
    prefix, one elementwise apply).

    DIFFERENTIABLE: the backward pass is the fused implicit-adjoint kernel
    on each time shard (gate recompute + exact diagonal J + reverse
    Hillis-Steele, ``with_cumulative``) composed through the SAME fixup
    seam in reverse, plus one ppermute for the boundary Jacobian — the
    shard-level mirror of ``core.deer_sharded.sharded_implicit_adjoint``.

    Same result as ``lrc_deer_solve`` (values AND gradients).  Falls back
    to the replicated megakernel solve when any ``seq_axis`` name is
    missing from the mesh or T/P is not a positive multiple of the
    (adapted) chunk.

    ``io_dtype`` ("bf16" | "fp8" | None): narrow HBM streams with fp32
    VMEM accumulation, exactly as on ``lrc_deer_solve`` — and here the
    cross-shard boundary/summary exchange rides the same narrow dtype.
    Returns fp32 when set.
    """
    T, D = s_u.shape
    n_shards = n_seq_shards(mesh, seq_axis)
    if not sharded_fused_viable(T, mesh, seq_axis, chunk, D=D,
                                n_iters=n_iters):
        return lrc_deer_solve(s_u, eps_u, packed_params, x0,
                              n_iters=n_iters, chunk=chunk, d_tile=d_tile,
                              dt=dt, interpret=interpret,
                              io_dtype=io_dtype)
    if io_dtype is not None:
        s_u, eps_u, x0 = (_io_cast(a, io_dtype) for a in (s_u, eps_u, x0))
    T_loc = T // n_shards
    c, dtile = _sharded_tiling(T_loc, D, n_iters, chunk, d_tile)
    su = _pad_axis(s_u, 1, dtile)
    eu = _pad_axis(eps_u, 1, dtile)
    pp = _pad_axis(packed_params, 1, dtile)
    x0p = _pad_axis(x0, 0, dtile)
    cfg = _ShardedCfg(mesh, seq_axis, n_shards, n_iters, c, dtile, dt,
                      interpret)
    out = _sharded_fused_solve(cfg, su, eu, pp, x0p)[:, :D]
    return out.astype(jnp.float32) if io_dtype is not None else out


# ---------------------------------------------------------------------------
# fused-adjoint hooks for the generic IFT solvers
# ---------------------------------------------------------------------------

def _fold(x: jax.Array) -> jax.Array:
    """(T, ...) -> (T, prod(...)): fold trailing batch/state dims into the
    kernel's channel axis (every kernel quantity is per-channel
    elementwise, so the fold is exact)."""
    return x.reshape(x.shape[0], -1)


def _packed_for(params, d_fold: int) -> jax.Array:
    pp = pack_lrc_params(params)
    reps = d_fold // pp.shape[1]
    return jnp.tile(pp, (1, reps)) if reps > 1 else pp


def fold_channel_batch(s_u: jax.Array, eps_u: jax.Array, params,
                       x0: Optional[jax.Array] = None):
    """Fold a time-major batched problem into the kernels' 2D layout:
    s_u/eps_u (T, B, S) -> (T, B*S), params dict -> the (10, B*S) tiled
    packed block, x0 (B, S) -> (B*S,) (None -> zeros).  The single fold
    used by every batched caller (core/block.py tiers, the lrc LM mixer)
    — every kernel quantity is per-channel elementwise, so the fold is
    exact; channel b*S+s carries params[s]."""
    T = s_u.shape[0]
    suf, euf = _fold(s_u), _fold(eps_u)
    pp = _packed_for(params, suf.shape[1])
    if x0 is None:
        x0f = jnp.zeros((suf.shape[1],), s_u.dtype)
    else:
        x0f = x0.reshape(suf.shape[1])
    return suf, euf, pp, x0f


def make_fused_adjoint_scans(dt: float = 1.0, chunk: Optional[int] = None,
                             d_tile: Optional[int] = None,
                             interpret: Optional[bool] = None):
    """Build the (replicated, sharded) fused-adjoint hooks that replace the
    jvp + reverse-scan segment of ``core.deer.implicit_adjoint`` /
    ``core.deer_sharded.sharded_implicit_adjoint`` with the fused reverse
    kernel, for step functions in the packed-lrc closed form (params dict
    carrying the ``PACK_ORDER`` keys; feats = (s_u, eps_u); uniform
    ``dt``).

    Hook protocols (see the solver modules):
      replicated(shifted, feats, params, gbar)                        -> g
      sharded(shifted, feats, params, gbar, jac_right, seq_axis)      -> g
    Shapes may carry trailing batch dims — (T, B, S) folds to (T, B*S).
    """
    def _tiling(T, D):
        return _resolve_tiling(T, D, 1, chunk, d_tile)

    def _padded_adjoint(xs2, su2, eu2, pp, g2, jr, with_cumulative):
        T, D = xs2.shape
        c, dtile = _tiling(T, D)
        xs_p, su_p, eu_p, g_p = (
            _pad_axis(_pad_axis(a, 0, c), 1, dtile)
            for a in (xs2, su2, eu2, g2))
        pp_p = _pad_axis(pp, 1, dtile)
        jr_p = _pad_axis(jr, 0, dtile)
        out = lrc_deer_adjoint_pallas(
            xs_p, su_p, eu_p, pp_p, g_p, jr_p, chunk=c, d_tile=dtile,
            dt=dt, interpret=interpret, valid_rows=T,
            with_cumulative=with_cumulative)
        if with_cumulative:
            return out[0][:T, :D], out[1][:T, :D]
        return out[:T, :D]

    def replicated(shifted, feats, params, gbar):
        su, eu = feats
        xs2 = _fold(shifted).astype(jnp.float32)
        g2 = _fold(gbar).astype(jnp.float32)
        pp = _packed_for(params, xs2.shape[1])
        g = _padded_adjoint(xs2, _fold(su).astype(jnp.float32),
                            _fold(eu).astype(jnp.float32), pp, g2,
                            jnp.zeros((xs2.shape[1],), jnp.float32), False)
        return g.reshape(gbar.shape).astype(gbar.dtype)

    def sharded(shifted, feats, params, gbar, jac_right, seq_axis):
        su, eu = feats
        xs2 = _fold(shifted).astype(jnp.float32)
        g2 = _fold(gbar).astype(jnp.float32)
        pp = _packed_for(params, xs2.shape[1])
        g0, a_cum = _padded_adjoint(
            xs2, _fold(su).astype(jnp.float32),
            _fold(eu).astype(jnp.float32), pp, g2,
            jac_right.reshape(-1).astype(jnp.float32), True)
        g = sharded_scan_fixup(a_cum, g0, None, seq_axis, reverse=True)
        return g.reshape(gbar.shape).astype(gbar.dtype)

    return replicated, sharded

"""Public wrapper: full DEER solve driven by the fused Pallas iteration.

``pack_lrc_params`` adapts a core.lrc parameter dict to the kernel's packed
(10, D) layout, so the kernel is a drop-in backend for LrcCellConfig models
(same math as core.deer with grad="unroll", mode="fixed").
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp

from repro.kernels.lrc_deer.kernel import lrc_deer_iteration_pallas

PACK_ORDER = ("a_x", "b_x", "g_max_x", "k_max_x", "g_max_u", "k_max_u",
              "w_x", "v_x", "g_leak", "e_leak")


def pack_lrc_params(p: Dict[str, jax.Array]) -> jax.Array:
    return jnp.stack([p[k].astype(jnp.float32) for k in PACK_ORDER], axis=0)


def _pad_axis(x, axis, mult):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("n_iters", "chunk", "d_tile",
                                             "dt", "interpret"))
def lrc_deer_solve(s_u: jax.Array, eps_u: jax.Array,
                   packed_params: jax.Array, x0: jax.Array, *,
                   n_iters: int = 10, chunk: int = 256, d_tile: int = 512,
                   dt: float = 1.0, interpret: bool = True) -> jax.Array:
    """DEER fixed-point solve of the LrcSSM recurrence using the fused
    Pallas iteration. s_u, eps_u: (T, D); returns states (T, D)."""
    T, D = s_u.shape
    c = chunk if T >= chunk else max(8, 1 << max(T - 1, 1).bit_length())
    dtile = d_tile if D >= d_tile else 128
    su = _pad_axis(_pad_axis(s_u, 0, c), 1, dtile)
    eu = _pad_axis(_pad_axis(eps_u, 0, c), 1, dtile)
    pp = _pad_axis(packed_params, 1, dtile)
    x0p = _pad_axis(x0, 0, dtile)
    Tp, Dp = su.shape

    def body(_, states):
        x_shift = jnp.concatenate([x0p[None], states[:-1]], axis=0)
        return lrc_deer_iteration_pallas(
            x_shift, su, eu, pp, x0p, chunk=c, d_tile=dtile, dt=dt,
            interpret=interpret)

    states = jax.lax.fori_loop(
        0, n_iters, body, jnp.zeros((Tp, Dp), s_u.dtype), unroll=False)
    return states[:T, :D]

"""Autotuned tiling layer for the lrc_deer Pallas solver stack.

Picks (chunk, d_tile) for the whole-Newton megakernel (and the
per-iteration / adjoint kernels, which share the same block geometry) per
(backend, T, D, K) problem shape:

  1. **Analytic VMEM-budget pruning** — ``megakernel_vmem_bytes`` models
     the kernel's VMEM residency (double-buffered pipeline blocks + the
     wavefront scratch) and candidates exceeding the budget (default
     16 MiB, override ``REPRO_VMEM_BUDGET_BYTES``) are discarded before
     anything runs.
  2. **Measured sweep** — on a real TPU backend the surviving candidates
     are timed on synthetic data (median of 3) and the fastest wins.  On
     CPU/interpret hosts measuring the interpreter is meaningless, so the
     analytic score (largest tile area = fewest grid steps, biased toward
     wide lanes) decides unless ``REPRO_AUTOTUNE_MEASURE=1`` forces a
     sweep.
  3. **Persistent cache** — decisions land in a JSON file keyed
     ``{backend}:T{T}:D{D}:K{K}`` (``REPRO_AUTOTUNE_CACHE`` overrides the
     default ``~/.cache/repro/lrc_autotune.json``), so a process restart
     never re-measures a known shape.  Corrupt/unwritable cache files
     degrade to in-memory-only operation, never to an error.

``get_tiling`` is the single entry point the ops layer calls when the
caller does not pin ``chunk``/``d_tile`` explicitly.

The module also owns the HBM stream roofline model
(``solver_hbm_streams``) that the kernel benchmark and docs quote: how
many (T, D)-sized HBM streams one K-iteration solve moves per solver
implementation.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from typing import Dict, Optional, Tuple

DEFAULT_VMEM_BUDGET = 16 * 1024 * 1024
CHUNK_CANDIDATES = (128, 256, 512, 1024)
D_TILE_CANDIDATES = (128, 256, 512)
_CACHE_VERSION = 1

# in-memory layer over the persistent file (also serves cacheless mode)
_mem_cache: Dict[str, Tuple[int, int]] = {}


@dataclasses.dataclass(frozen=True)
class Tiling:
    """One autotune decision: the block geometry plus how it was chosen
    (``source``: "explicit" | "cache" | "measured" | "analytic")."""
    chunk: int
    d_tile: int
    source: str = "analytic"


def vmem_budget_bytes() -> int:
    """The VMEM budget candidates are pruned against (env-overridable)."""
    try:
        return int(os.environ.get("REPRO_VMEM_BUDGET_BYTES",
                                  DEFAULT_VMEM_BUDGET))
    except ValueError:
        return DEFAULT_VMEM_BUDGET


def megakernel_vmem_bytes(chunk: int, d_tile: int, n_iters: int,
                          io_bytes: int = 4) -> int:
    """Analytic VMEM residency of the megakernel for one grid step.

    Pipeline buffers (double-buffered by Mosaic): s_u + eps_u blocks in,
    states block out — 3 x 2 x (chunk, d_tile) at ``io_bytes`` per element
    (4 fp32 streams, 2 bf16, 1 fp8: narrow HBM I/O shrinks exactly the
    double-buffered blocks) — plus the single-copy (n_iters, d_tile)
    residual output block, the packed params and x0 rows, and the
    wavefront scratch: the (2*chunk, d_tile) trajectory parity buffer, the
    (2*(K+1), d_tile) boundary vector and the (1, d_tile) residual gate.
    Scratch and params stay f32 regardless of the stream dtype — VMEM
    accumulation is never quantised.
    """
    f32 = 4
    tile = chunk * d_tile * io_bytes
    pipeline = 6 * tile + n_iters * d_tile * f32 + 2 * (10 + 1) * d_tile * f32
    scratch = (2 * chunk * d_tile + 2 * (n_iters + 1) * d_tile +
               d_tile) * f32
    return pipeline + scratch


def _padded(n: int, mult: int) -> int:
    return n + (-n) % mult


def viable_tilings(T: int, D: int, n_iters: int,
                   budget: Optional[int] = None, io_bytes: int = 4):
    """All (chunk, d_tile) candidates that fit the VMEM budget, with the
    padding overhead each would impose on this (T, D) problem.
    ``io_bytes`` is the HBM-stream element width — narrower streams admit
    larger tiles under the same budget."""
    budget = vmem_budget_bytes() if budget is None else budget
    out = []
    for chunk in CHUNK_CANDIDATES:
        for d_tile in D_TILE_CANDIDATES:
            if megakernel_vmem_bytes(chunk, d_tile, n_iters,
                                     io_bytes) > budget:
                continue
            waste = (_padded(T, chunk) * _padded(D, d_tile)) / float(T * D)
            out.append((chunk, d_tile, waste))
    return out


def _analytic_pick(T: int, D: int, n_iters: int,
                   budget: Optional[int] = None,
                   io_bytes: int = 4) -> Tiling:
    cands = viable_tilings(T, D, n_iters, budget, io_bytes)
    if not cands:
        return Tiling(128, 128, "analytic")
    # fewest grid steps (largest tile) among the low-padding-waste set,
    # ties broken toward wide lanes (better VPU utilisation)
    min_waste = min(w for _, _, w in cands)
    best = max((c for c in cands if c[2] <= min_waste * 1.25),
               key=lambda c: (c[0] * c[1], c[1]))
    return Tiling(best[0], best[1], "analytic")


def _measure_pick(T: int, D: int, n_iters: int,
                  budget: Optional[int] = None,
                  io_bytes: int = 4) -> Tiling:
    import time

    import jax
    import jax.numpy as jnp

    from repro.kernels.lrc_deer.kernel import lrc_deer_megakernel_pallas

    cands = viable_tilings(T, D, n_iters, budget, io_bytes)
    if not cands:
        return Tiling(128, 128, "analytic")
    Tp = max(_padded(T, c) for c, _, _ in cands)
    Dp = max(_padded(D, d) for _, d, _ in cands)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    # synthesise streams in the dtype being tuned for — stream-bound wall
    # clock depends on the wire width
    io_dt = {4: jnp.float32, 2: jnp.bfloat16,
             1: jnp.float8_e4m3fn}.get(io_bytes, jnp.float32)
    su = jax.nn.sigmoid(jax.random.normal(ks[0], (Tp, Dp))).astype(io_dt)
    eu = jax.random.normal(ks[1], (Tp, Dp)).astype(io_dt)
    pp = jax.random.normal(ks[2], (10, Dp)) * 0.5
    x0 = jnp.zeros((Dp,))
    best, best_us = None, None
    for chunk, d_tile, _ in cands:
        Tc, Dc = _padded(T, chunk), _padded(D, d_tile)
        args = (su[:Tc, :Dc], eu[:Tc, :Dc], pp[:, :Dc], x0[:Dc])
        try:
            fn = lambda: lrc_deer_megakernel_pallas(
                *args, n_iters=n_iters, chunk=chunk, d_tile=d_tile)[0]
            jax.block_until_ready(fn())   # compile + warm
            ts = []
            for _ in range(3):
                t0 = time.perf_counter()
                jax.block_until_ready(fn())
                ts.append(time.perf_counter() - t0)
            us = sorted(ts)[1] * 1e6
        except Exception:
            continue
        if best_us is None or us < best_us:
            best, best_us = (chunk, d_tile), us
    if best is None:
        return _analytic_pick(T, D, n_iters, budget, io_bytes)
    return Tiling(best[0], best[1], "measured")


# ---------------------------------------------------------------------------
# persistent cache
# ---------------------------------------------------------------------------

def cache_path() -> str:
    """Location of the persistent autotune cache file."""
    env = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "lrc_autotune.json")


def _cache_key(backend: str, T: int, D: int, n_iters: int,
               io_bytes: int = 4) -> str:
    # fp32 keeps the historical key shape so existing caches stay valid;
    # narrow-stream decisions get their own ":b{io_bytes}" namespace
    suffix = "" if io_bytes == 4 else f":b{io_bytes}"
    return f"{backend}:T{T}:D{D}:K{n_iters}:v{_CACHE_VERSION}{suffix}"


def load_cache(path: Optional[str] = None) -> Dict[str, list]:
    """Read the on-disk cache; any read/parse failure yields {}."""
    path = path or cache_path()
    try:
        with open(path) as f:
            data = json.load(f)
        return data if isinstance(data, dict) else {}
    except Exception:
        return {}


def _save_cache(data: Dict[str, list], path: Optional[str] = None) -> None:
    """Best-effort atomic write; failures (read-only FS) are swallowed —
    the in-memory layer still serves the session."""
    path = path or cache_path()
    try:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                                   prefix=".autotune-")
        with os.fdopen(fd, "w") as f:
            json.dump(data, f, indent=2, sort_keys=True)
        os.replace(tmp, path)
    except Exception:  # repro-lint: disable=bare-except
        pass           # sanctioned: best-effort persistent layer only


def clear_cache(path: Optional[str] = None) -> None:
    """Drop both cache layers (tests; or after a kernel change)."""
    _mem_cache.clear()
    path = path or cache_path()
    try:
        os.remove(path)
    except OSError:
        pass


def get_tiling(T: int, D: int, n_iters: int, *,
               backend: Optional[str] = None,
               measure: Optional[bool] = None,
               io_bytes: int = 4) -> Tiling:
    """The (chunk, d_tile) to run shape (T, D, K) with on ``backend``.

    Resolution order: in-memory cache -> persistent file cache -> measured
    sweep (TPU, or ``REPRO_AUTOTUNE_MEASURE=1``) -> analytic pick.  The
    decision is written back to both cache layers.  ``io_bytes`` (HBM
    stream element width) keys its own cache namespace and feeds the VMEM
    budget model — narrow streams change which tilings fit.
    """
    if backend is None:
        import jax
        backend = jax.default_backend()
    key = _cache_key(backend, T, D, n_iters, io_bytes)
    if key in _mem_cache:
        c, d = _mem_cache[key]
        return Tiling(c, d, "cache")
    disk = load_cache()
    if key in disk:
        try:
            c, d = int(disk[key][0]), int(disk[key][1])
            _mem_cache[key] = (c, d)
            return Tiling(c, d, "cache")
        except Exception:  # repro-lint: disable=bare-except
            pass           # sanctioned: corrupt cache entry -> re-measure
    if measure is None:
        measure = (backend == "tpu"
                   or os.environ.get("REPRO_AUTOTUNE_MEASURE") == "1")
    tiling = (_measure_pick if measure else _analytic_pick)(
        T, D, n_iters, None, io_bytes)
    _mem_cache[key] = (tiling.chunk, tiling.d_tile)
    disk[key] = [tiling.chunk, tiling.d_tile, tiling.source]
    _save_cache(disk)
    return tiling


# ---------------------------------------------------------------------------
# HBM stream roofline model
# ---------------------------------------------------------------------------

def solver_hbm_streams(n_iters: int, kind: str) -> float:
    """(T, D)-sized HBM streams one K-iteration DEER solve moves.

      * ``lax``        — unfused Newton iteration (jvp gate pass, J/b
                         materialisation, associative scan): ~10 streams
                         per iteration (kernels/lrc_deer docstring).
      * ``fused_iter`` — per-iteration fused kernel: 3 reads + 1 write in
                         the kernel, plus the host-side shifted-guess
                         concatenate (1 read + 1 write) between calls.
      * ``mega``       — whole-Newton megakernel: s_u + eps_u read once,
                         trajectory written once; the guess never leaves
                         VMEM.
    """
    if kind == "lax":
        return 10.0 * n_iters
    if kind == "fused_iter":
        return 6.0 * n_iters
    if kind == "mega":
        return 3.0
    raise ValueError(f"unknown solver kind: {kind!r}")


def solver_hbm_bytes(n_iters: int, kind: str, io_bytes: int = 4) -> float:
    """HBM BYTES per trajectory element one K-iteration solve moves:
    ``solver_hbm_streams`` x the stream element width.  This is the
    roofline quantity narrow kernel I/O actually improves — the megakernel
    at bf16 moves 3 x 2 = 6 bytes/element where the per-iteration fused
    kernel at fp32 moves 6K x 4, a (4K)x reduction on the stream-bound
    axis (BENCH_kernels' ``stream_bytes_ratio``)."""
    return solver_hbm_streams(n_iters, kind) * float(io_bytes)

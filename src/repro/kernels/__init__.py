"""Pallas TPU kernels for the perf-critical compute layers.

  diag_scan/   — chunked diagonal linear-recurrence scan (the paper's core
                 primitive; shared by DEER, Mamba-1/2 mixers)
  lrc_deer/    — fused LRC-gate + linearise + scan Newton iteration
                 (one HBM round-trip per DEER iteration instead of five)
  flash_attn/  — online-softmax attention (prefill hot-spot)

Each kernel directory has kernel.py (pl.pallas_call + BlockSpec),
ops.py (jit'd public wrapper with interpret fallback), and ref.py
(pure-jnp oracle used by the allclose test sweeps).

TPU is the TARGET; on this CPU container every kernel is validated with
interpret=True (the kernel body executes with the Python/jnp semantics the
TPU compiler would see).
"""

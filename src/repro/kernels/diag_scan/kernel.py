"""Pallas TPU kernel: chunked diagonal linear-recurrence scan.

Solves x_t = lam_t * x_{t-1} + b_t over (T, D) with the time axis split into
VMEM-resident chunks and the channel axis tiled to the lane width.

Schedule (the TPU adaptation of the paper's O(log T) scan):
  grid = (D_tiles, T_chunks)   — T innermost => sequential on TPU, so the
                                  inter-chunk carry lives in VMEM scratch.
  per chunk: Hillis-Steele doubling over the chunk (log2(C) unrolled steps,
             pure VPU elementwise work on (C, Dt) tiles), then one affine
             application of the incoming carry.

Why chunked instead of a monolithic associative scan: a full-T scan
materialises O(T * D) intermediates in HBM per doubling level; the chunked
form reads lam/b once, writes x once, and keeps all O(log C) temporaries in
VMEM. Arithmetic intensity rises from ~0.17 to ~(C bounded) — the kernel is
HBM-streaming bound, which IS the roofline for this memory-bound primitive.

VMEM budget (defaults C=256, Dt=512, f32): 3 live (C, Dt) buffers
~1.6 MB << 128 MB VMEM, leaving room for double buffering (the compiler
pipelines the HBM->VMEM copies across the sequential grid automatically).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_chunk_kernel(lam_ref, b_ref, x0_ref, out_ref, carry_ref, *,
                       chunk: int):
    """One (T-chunk, D-tile) cell. carry_ref: VMEM scratch (1, Dt) f32."""
    t = pl.program_id(1)

    lam = lam_ref[...].astype(jnp.float32)        # (C, Dt)
    b = b_ref[...].astype(jnp.float32)

    # reset carry at the first chunk of every D-tile pass
    @pl.when(t == 0)
    def _():
        carry_ref[...] = x0_ref[...].astype(jnp.float32)

    # Hillis-Steele doubling: after step k, (A, B)[i] composes elements
    # (i-2k, i]. log2(chunk) unrolled elementwise steps on VMEM tiles.
    A, B = lam, b
    k = 1
    while k < chunk:
        ones = jnp.ones((k, A.shape[1]), jnp.float32)
        zeros = jnp.zeros((k, B.shape[1]), jnp.float32)
        A_prev = jnp.concatenate([ones, A[:-k]], axis=0)
        B_prev = jnp.concatenate([zeros, B[:-k]], axis=0)
        B = A * B_prev + B
        A = A * A_prev
        k *= 2

    carry = carry_ref[...]                        # (1, Dt)
    states = A * carry + B                        # broadcast over chunk rows
    out_ref[...] = states.astype(out_ref.dtype)
    carry_ref[...] = states[-1:]


@functools.partial(jax.jit, static_argnames=("chunk", "d_tile", "interpret"))
def diag_scan_pallas(lam: jax.Array, b: jax.Array, x0: jax.Array, *,
                     chunk: int = 256, d_tile: int = 512,
                     interpret: bool = True) -> jax.Array:
    """x_t = lam_t x_{t-1} + b_t. lam, b: (T, D); x0: (D,). T % chunk == 0,
    D % d_tile == 0 (wrapper pads otherwise)."""
    T, D = lam.shape
    assert T % chunk == 0 and D % d_tile == 0, (T, D, chunk, d_tile)
    grid = (D // d_tile, T // chunk)

    return pl.pallas_call(
        functools.partial(_scan_chunk_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((chunk, d_tile), lambda d, t: (t, d)),
            pl.BlockSpec((chunk, d_tile), lambda d, t: (t, d)),
            pl.BlockSpec((1, d_tile), lambda d, t: (0, d)),
        ],
        out_specs=pl.BlockSpec((chunk, d_tile), lambda d, t: (t, d)),
        out_shape=jax.ShapeDtypeStruct((T, D), lam.dtype),
        scratch_shapes=[pltpu.VMEM((1, d_tile), jnp.float32)],
        interpret=interpret,
    )(lam, b, x0.reshape(1, D))

"""Public jit'd wrapper for the diag_scan Pallas kernel: shape padding,
batching (vmap), dtype handling, interpret fallback on CPU."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.diag_scan.kernel import diag_scan_pallas


def _pad_to(x: jax.Array, axis: int, mult: int):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


@functools.partial(jax.jit, static_argnames=("chunk", "d_tile", "interpret"))
def diag_scan(lam: jax.Array, b: jax.Array, x0: jax.Array | None = None, *,
              chunk: int = 256, d_tile: int = 512,
              interpret: bool = True) -> jax.Array:
    """Drop-in replacement for core.scan.diag_linear_scan on (T, D) or
    (B, T, D) inputs (real dtypes). Pads T to the chunk and D to the lane
    tile; identity padding (lam=1? no — lam=0, b=0) keeps results exact:
    padded channels produce zeros, padded time steps are sliced off."""
    if lam.ndim == 3:
        f = lambda l2, b2, x2: diag_scan(l2, b2, x2, chunk=chunk,
                                         d_tile=d_tile, interpret=interpret)
        if x0 is None:
            x0 = jnp.zeros((lam.shape[0], lam.shape[-1]), lam.dtype)
        return jax.vmap(f)(lam, b, x0)

    T, D = lam.shape
    if x0 is None:
        x0 = jnp.zeros((D,), lam.dtype)
    c = chunk if T >= chunk else max(8, 1 << max(T - 1, 1).bit_length())
    dt = d_tile if D >= d_tile else 128
    lam_p, _ = _pad_to(lam, 0, c)
    b_p, _ = _pad_to(b, 0, c)
    lam_p, _ = _pad_to(lam_p, 1, dt)
    b_p, _ = _pad_to(b_p, 1, dt)
    x0_p, _ = _pad_to(x0, 0, dt)
    out = diag_scan_pallas(lam_p, b_p, x0_p, chunk=c, d_tile=dt,
                           interpret=interpret)
    return out[:T, :D]

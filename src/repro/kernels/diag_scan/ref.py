"""Pure-jnp oracle for the diag_scan kernel."""
import jax
import jax.numpy as jnp


def diag_scan_ref(lam: jax.Array, b: jax.Array, x0: jax.Array) -> jax.Array:
    """Sequential reference: x_t = lam_t x_{t-1} + b_t, x_0 given."""
    def step(x, lb):
        l, bb = lb
        x = l * x + bb
        return x, x
    _, xs = jax.lax.scan(step, x0.astype(jnp.float32),
                         (lam.astype(jnp.float32), b.astype(jnp.float32)))
    return xs.astype(lam.dtype)

"""Pure-jnp oracle for flash attention."""
import jax
import jax.numpy as jnp


def attention_ref(q, k, v, causal: bool = True):
    """q, k, v: (BH, T, hd). Naive softmax attention in fp32."""
    T = q.shape[1]
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((T, T), bool))
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)

"""Public wrapper: GQA layout adaptation + padding + interpret fallback."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attn.kernel import flash_attention_pallas


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_kv",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 128,
                    block_kv: int = 128, interpret: bool = True) -> jax.Array:
    """GQA attention via the Pallas kernel. q: (B, T, H, hd);
    k, v: (B, T, K, hd) with H % K == 0. Returns (B, T, H, hd)."""
    B, T, H, hd = q.shape
    K = k.shape[2]
    groups = H // K
    if groups > 1:
        k = jnp.repeat(k, groups, axis=2)
        v = jnp.repeat(v, groups, axis=2)

    bq = block_q if T >= block_q else max(8, 1 << max(T - 1, 1).bit_length())
    bkv = block_kv if T >= block_kv else bq
    pad_t = (-T) % max(bq, bkv)

    qf = q.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    if pad_t:
        # pad kv with zeros — masked out by causality for q rows < T
        widths = ((0, 0), (0, pad_t), (0, 0))
        qf, kf, vf = (jnp.pad(x, widths) for x in (qf, kf, vf))
    out = flash_attention_pallas(qf, kf, vf, block_q=bq, block_kv=bkv,
                                 causal=causal, interpret=interpret)
    out = out[:, :T].reshape(B, H, T, hd).transpose(0, 2, 1, 3)
    return out

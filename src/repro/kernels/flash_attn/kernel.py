"""Pallas TPU kernel: flash (online-softmax) causal attention.

The prefill hot-spot for the dense/vlm/moe archs. Blocking:

    grid = (batch*heads, q_blocks, kv_blocks)   kv innermost (sequential)
    q tile    (Bq, hd)   stays resident across the kv sweep
    k/v tiles (Bkv, hd)  streamed
    scratch: m (Bq,1), l (Bq,1), acc (Bq, hd) — fp32 running softmax state

MXU alignment: Bq = Bkv = 128 and hd padded to a multiple of 128 keep both
matmuls (q@k^T and p@v) on hardware-native tiles. The causal mask is
evaluated from block indices; fully-masked kv blocks are skipped via
pl.when (the standard ~2x causal win).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  block_q: int, block_kv: int, scale: float, causal: bool):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    n_kv = pl.num_programs(2)

    @pl.when(ki == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = True
    if causal:
        # skip blocks entirely above the diagonal
        run = (ki * block_kv) <= (qi * block_q + block_q - 1)

    @pl.when(run if causal else True)
    def _():
        q = q_ref[0].astype(jnp.float32) * scale          # (Bq, hd)
        k = k_ref[0].astype(jnp.float32)                  # (Bkv, hd)
        v = v_ref[0].astype(jnp.float32)
        s = q @ k.T                                       # (Bq, Bkv)
        if causal:
            q_pos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 0)
            k_pos = ki * block_kv + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 1)
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + p @ v
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_kv",
                                             "causal", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           block_q: int = 128, block_kv: int = 128,
                           causal: bool = True,
                           interpret: bool = True) -> jax.Array:
    """q, k, v: (BH, T, hd) same-length self attention (GQA head repetition
    handled by the ops wrapper). Returns (BH, T, hd)."""
    BH, T, hd = q.shape
    assert T % block_q == 0 and T % block_kv == 0, (T, block_q, block_kv)
    scale = hd ** -0.5
    grid = (BH, T // block_q, T // block_kv)
    return pl.pallas_call(
        functools.partial(_flash_kernel, block_q=block_q, block_kv=block_kv,
                          scale=scale, causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_kv, hd), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_kv, hd), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, T, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)

#!/usr/bin/env python
"""Docstring-coverage gate for the public API surface — the dependency-free
local equivalent of the CI `doc-lint` job's

    interrogate --ignore-nested-functions --ignore-init-method \
        --fail-under <N> <paths>

Counts module, class, and (non-nested, non-``__init__``) function/method
docstrings — semiprivate ``_underscore`` units included, matching the CI
invocation — over the gated paths below and fails when coverage drops
under the threshold. Run from the repo root:

    python tools/doc_coverage.py [--fail-under 95] [-v]
"""
from __future__ import annotations

import argparse
import ast
import os
import sys

# The gated public API surface (ISSUE 4 satellite: compat, sharding, the
# step factory, and the whole serving subsystem). Paths relative to repo
# root; directories are walked for *.py.
GATED_PATHS = [
    "src/repro/distributed/compat.py",
    "src/repro/distributed/sharding.py",
    "src/repro/train/step.py",
    "src/repro/serve",
    "src/repro/models/__init__.py",
]
DEFAULT_FAIL_UNDER = 95.0


def _iter_files(paths):
    for p in paths:
        if os.path.isdir(p):
            for root, _, files in os.walk(p):
                for f in sorted(files):
                    if f.endswith(".py"):
                        yield os.path.join(root, f)
        else:
            yield p


def _doc_nodes(tree):
    """Yield (name, has_docstring) for the module, every class, and every
    non-nested function/method (interrogate's default unit set minus nested
    functions and __init__)."""
    yield "<module>", bool(ast.get_docstring(tree))

    def walk(node, prefix, inside_function):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if inside_function or child.name == "__init__":
                    continue
                yield (f"{prefix}{child.name}",
                       bool(ast.get_docstring(child)))
                yield from walk(child, f"{prefix}{child.name}.", True)
            elif isinstance(child, ast.ClassDef):
                yield (f"{prefix}{child.name}",
                       bool(ast.get_docstring(child)))
                yield from walk(child, f"{prefix}{child.name}.",
                                inside_function)
            else:
                yield from walk(child, prefix, inside_function)

    yield from walk(tree, "", False)


def main() -> int:
    """Scan the gated paths; print per-file coverage; exit 1 under the
    threshold."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--fail-under", type=float, default=DEFAULT_FAIL_UNDER)
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="list undocumented units")
    args = ap.parse_args()

    total = documented = 0
    for path in _iter_files(GATED_PATHS):
        with open(path) as f:
            tree = ast.parse(f.read(), filename=path)
        units = list(_doc_nodes(tree))
        n_doc = sum(1 for _, d in units if d)
        total += len(units)
        documented += n_doc
        pct = 100.0 * n_doc / len(units)
        print(f"{path}: {n_doc}/{len(units)} ({pct:.1f}%)")
        if args.verbose:
            for name, d in units:
                if not d:
                    print(f"    MISSING: {name}")

    pct = 100.0 * documented / max(total, 1)
    print(f"TOTAL: {documented}/{total} ({pct:.1f}%), "
          f"fail-under {args.fail_under:.1f}%")
    if pct < args.fail_under:
        print("doc coverage FAILED", file=sys.stderr)
        return 1
    print("doc coverage OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())

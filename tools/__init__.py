"""Repo tooling: static analysis (repro_lint), contract suite, doc
coverage. Package marker so ``python -m tools.repro_lint`` works from the
repo root."""

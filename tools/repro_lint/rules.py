"""repro-lint rule catalog (stdlib-ast only — no jax import, so the rules
run on a bare CI runner before any dependency install).

Every rule sees a :class:`tools.repro_lint.engine.FileContext` — the
parsed AST, the import-alias table (local name -> fully-qualified dotted
path, so ``import jax.lax as jl; jl.psum`` and multi-line parenthesized
``from jax.lax import (psum, ...)`` resolve identically), and the
repo-relative posix path that scopes the rule.

Suppression: append ``# repro-lint: disable=<rule>[,<rule>...]`` to the
flagged line (or the line directly above it); ``# repro-lint:
disable-file=<rule>`` anywhere in the file disables a rule for the whole
file. docs/static_analysis.md is the user-facing catalog.
"""
from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Tuple

from tools.repro_lint.engine import FileContext, Finding

#: the psum-family collectives whose only sanctioned spelling is
#: ``repro.distributed.compat.<name>`` (ROADMAP distributed-layer contract)
COLLECTIVES = frozenset({
    "psum", "pmax", "pmin", "pmean", "all_gather", "ppermute",
    "psum_scatter", "axis_index", "all_to_all",
})

#: the only modules allowed to CONSTRUCT a PartitionSpec — every other
#: call site goes through ``distributed.sharding.make_spec`` (or the
#: higher-level spec helpers), keeping the axis-name vocabulary reviewable
#: in one place (ShardingPolicy satellite contract)
SPEC_PATHS = ("src/repro/distributed/sharding.py", "src/repro/train/step.py")

COMPAT_PATH = "src/repro/distributed/compat.py"
HOT_PATHS = ("src/repro/train/", "src/repro/serve/", "src/repro/core/",
             "src/repro/kernels/")


def _resolve(node: ast.AST, aliases: dict) -> Optional[str]:
    """Fully-qualified dotted path of a Name/Attribute chain, via the
    file's import aliases; None when the root is not an imported name."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        base = aliases.get(node.id)
        if base is None:
            return None
        return ".".join([base] + parts[::-1])
    return None


def _mentions_jax(node: ast.AST, aliases: dict) -> bool:
    """True when any sub-expression resolves into the ``jax`` package —
    the syntactic evidence that an expression holds a traced/device
    value (``jnp`` resolves to ``jax.numpy``)."""
    for sub in ast.walk(node):
        q = _resolve(sub, aliases)
        if q is not None and (q == "jax" or q.startswith("jax.")):
            return True
    return False


def _usages(tree: ast.AST) -> Iterator[ast.AST]:
    for node in ast.walk(tree):
        if isinstance(node, (ast.Name, ast.Attribute)):
            yield node


class Rule:
    """Base rule: ``name`` is the suppression/selection key, ``check``
    yields findings for one file."""

    name: str = ""
    description: str = ""

    def applies(self, relpath: str) -> bool:
        """Whether this rule scopes over ``relpath`` (posix, repo-root
        relative)."""
        return True

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        """Yield findings for one parsed file."""
        raise NotImplementedError

    def _finding(self, ctx: FileContext, node: ast.AST, msg: str) -> Finding:
        return Finding(rule=self.name, path=ctx.relpath,
                       line=getattr(node, "lineno", 1),
                       col=getattr(node, "col_offset", 0), message=msg)


class CompatCollectiveRule(Rule):
    """ALL shard_map and psum-family collective call sites must resolve
    through ``repro.distributed.compat`` — never ``jax.shard_map`` /
    ``jax.experimental.shard_map`` / ``jax.lax.psum``-family directly
    (the jax spelling drifted across the supported 0.4.30 -> current
    range; one distribution API surface to patch). Replaces the
    tools/lint_compat.sh grep, closing its false negatives: aliased
    module imports (``import jax.lax as jl``) and parenthesized
    multi-line ``from jax.lax import (...)`` imports resolve through the
    alias table instead of a line regex."""

    name = "compat-collective"
    description = ("shard_map / raw jax.lax collectives outside "
                   "distributed/compat.py (route through compat.*)")

    def applies(self, relpath: str) -> bool:
        return relpath != COMPAT_PATH

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ImportFrom) and not node.level:
                mod = node.module or ""
                for alias in node.names:
                    if mod == "jax.lax" and (alias.name in COLLECTIVES
                                             or alias.name == "*"):
                        yield self._finding(
                            ctx, node,
                            f"import of jax.lax.{alias.name}: use "
                            f"repro.distributed.compat.{alias.name}")
                    elif (mod, alias.name) == ("jax", "shard_map") or \
                            (mod, alias.name) == ("jax.experimental",
                                                  "shard_map") or \
                            mod.startswith("jax.experimental.shard_map"):
                        yield self._finding(
                            ctx, node,
                            "direct shard_map import: use "
                            "repro.distributed.compat.shard_map")
            elif isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.name.startswith("jax.experimental.shard_map"):
                        yield self._finding(
                            ctx, node,
                            "direct shard_map import: use "
                            "repro.distributed.compat.shard_map")
        for node in _usages(ctx.tree):
            q = _resolve(node, ctx.aliases)
            if q is None:
                continue
            if q == "jax.shard_map" or q.startswith(
                    "jax.experimental.shard_map"):
                yield self._finding(
                    ctx, node, f"direct {q} reference: use "
                    "repro.distributed.compat.shard_map")
            else:
                parts = q.split(".")
                if (len(parts) == 3 and parts[:2] == ["jax", "lax"]
                        and parts[2] in COLLECTIVES):
                    yield self._finding(
                        ctx, node, f"raw collective {q}: use "
                        f"repro.distributed.compat.{parts[2]}")


class KernelsShardMapRule(Rule):
    """``src/repro/kernels`` must never spell shard_map except through
    ``compat.shard_map`` — Pallas kernels are the lowest layer; sharded
    composition belongs to the ops wrappers via
    ``core.scan.sharded_scan_fixup``, not inside kernel bodies."""

    name = "kernels-shard-map"
    description = ("shard_map spelled inside src/repro/kernels/ "
                   "(only compat.shard_map is allowed there)")

    def applies(self, relpath: str) -> bool:
        return relpath.startswith("src/repro/kernels/")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ImportFrom):
                for alias in node.names:
                    if "shard_map" in ((node.module or "") + alias.name):
                        yield self._finding(
                            ctx, node, "kernels/ imports shard_map: spell "
                            "compat.shard_map in the ops wrapper instead")
            elif isinstance(node, ast.Import):
                for alias in node.names:
                    if "shard_map" in alias.name:
                        yield self._finding(
                            ctx, node, "kernels/ imports shard_map: spell "
                            "compat.shard_map in the ops wrapper instead")
            elif isinstance(node, ast.Name) and node.id == "shard_map":
                yield self._finding(
                    ctx, node, "bare shard_map in kernels/: only "
                    "compat.shard_map is allowed")
            elif isinstance(node, ast.Attribute) and \
                    node.attr == "shard_map":
                base = _resolve(node.value, ctx.aliases)
                base_name = (node.value.id
                             if isinstance(node.value, ast.Name) else "")
                if not ((base or "").endswith("compat")
                        or base_name == "compat"):
                    yield self._finding(
                        ctx, node, "non-compat shard_map attribute in "
                        "kernels/: only compat.shard_map is allowed")


class HostSyncRule(Rule):
    """No per-step host synchronisation in the hot paths (train/, serve/,
    core/, kernels/) — the PR-3 "loss stays device-side" win regresses
    silently the moment someone writes ``float(loss)`` in step code.
    Flags the syntactically-evident device->host pulls: ``.item()``,
    ``jax.device_get(...)``, ``float()/int()/bool()`` over an expression
    rooted in jax/jnp, and ``np.asarray()/np.array()`` over such an
    expression. Deliberate host boundaries (log-cadence syncs, the serve
    engine's token readout) carry a suppression comment naming the rule —
    making every sanctioned sync point grep-able."""

    name = "host-sync"
    description = ("host-sync (.item()/device_get/float()/np.asarray on "
                   "jax values) inside train/serve/core/kernels hot paths")

    def applies(self, relpath: str) -> bool:
        return relpath.startswith(HOT_PATHS)

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            func = node.func
            if isinstance(func, ast.Attribute) and func.attr == "item" \
                    and not node.args and not node.keywords:
                yield self._finding(
                    ctx, node, ".item() forces a device->host sync")
                continue
            q = _resolve(func, ctx.aliases)
            if q == "jax.device_get":
                yield self._finding(
                    ctx, node, "jax.device_get forces a device->host sync")
                continue
            if isinstance(func, ast.Name) \
                    and func.id in ("float", "int", "bool") \
                    and func.id not in ctx.aliases \
                    and len(node.args) == 1 and not node.keywords \
                    and _mentions_jax(node.args[0], ctx.aliases):
                yield self._finding(
                    ctx, node, f"{func.id}() over a jax expression blocks "
                    "on the device (host sync)")
                continue
            if q in ("numpy.asarray", "numpy.array") and node.args \
                    and _mentions_jax(node.args[0], ctx.aliases):
                yield self._finding(
                    ctx, node, f"{q.replace('numpy', 'np')} over a jax "
                    "expression copies device->host (host sync)")


class PallasCallRule(Rule):
    """Pallas stays in ``src/repro/kernels/``: no direct
    ``pallas_call`` / ``jax.experimental.pallas`` import elsewhere in
    src/repro — every kernel launch goes through the kernels/ ops
    wrappers (which own tiling/autotune, interpret auto-detection and the
    sharded composition seam)."""

    name = "pallas-call-outside-kernels"
    description = ("pallas_call / jax.experimental.pallas referenced "
                   "outside src/repro/kernels/")

    def applies(self, relpath: str) -> bool:
        return (relpath.startswith("src/repro/")
                and not relpath.startswith("src/repro/kernels/"))

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ImportFrom) and not node.level:
                mod = node.module or ""
                if mod.startswith("jax.experimental.pallas") or (
                        mod == "jax.experimental"
                        and any(a.name == "pallas" for a in node.names)):
                    yield self._finding(
                        ctx, node, "pallas imported outside kernels/: "
                        "kernel launches live in src/repro/kernels ops")
            elif isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.name.startswith("jax.experimental.pallas"):
                        yield self._finding(
                            ctx, node, "pallas imported outside kernels/: "
                            "kernel launches live in src/repro/kernels ops")
        for node in _usages(ctx.tree):
            q = _resolve(node, ctx.aliases)
            if q and q.startswith("jax.experimental.pallas") \
                    and q.endswith("pallas_call"):
                yield self._finding(
                    ctx, node, f"direct {q} outside kernels/: use the "
                    "src/repro/kernels ops wrappers")


class HardcodedInterpretRule(Rule):
    """No literal ``interpret=True`` in library code: Pallas execution
    mode is auto-detected per backend (``LrcSSMConfig.kernel_interpret``,
    PR-5 contract — a hardcoded True silently runs the interpreter on
    TPU). Thread ``interpret=interpret`` / ``interpret=None`` instead."""

    name = "hardcoded-interpret"
    description = ("literal interpret=True in src/repro (breaks backend "
                   "auto-detection; thread the config value)")

    def applies(self, relpath: str) -> bool:
        return relpath.startswith("src/repro/")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            for kw in node.keywords:
                if kw.arg == "interpret" \
                        and isinstance(kw.value, ast.Constant) \
                        and kw.value.value is True:
                    yield self._finding(
                        ctx, kw.value, "hardcoded interpret=True: thread "
                        "the auto-detected value (kernel_interpret) "
                        "instead")


class PartitionSpecConfinementRule(Rule):
    """``PartitionSpec`` is only CONSTRUCTED in ``distributed/sharding.py``
    and ``train/step.py`` — everywhere else in src/repro specs come from
    ``sharding.make_spec`` or the higher-level helpers (``param_specs``,
    ``batch_specs``, ``ShardingPolicy.param_specs``, ...). A stray
    ``P("model")`` in model/kernel code bypasses the ShardingPolicy
    surface and silently hardcodes an axis assignment the policy no
    longer controls. Flags imports of ``jax.sharding.PartitionSpec`` and
    attribute references resolving to it."""

    name = "partition-spec-confinement"
    description = ("PartitionSpec constructed outside "
                   "distributed/sharding.py + train/step.py (use "
                   "sharding.make_spec / the spec helpers)")

    def applies(self, relpath: str) -> bool:
        return (relpath.startswith("src/repro/")
                and relpath not in SPEC_PATHS)

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ImportFrom) and not node.level:
                mod = node.module or ""
                for alias in node.names:
                    if (mod == "jax.sharding"
                            and alias.name in ("PartitionSpec", "*")) or \
                            (mod == "jax" and alias.name == "P"):
                        yield self._finding(
                            ctx, node,
                            "PartitionSpec imported outside the spec "
                            "modules: use sharding.make_spec or the spec "
                            "helpers")
        for node in _usages(ctx.tree):
            q = _resolve(node, ctx.aliases)
            if q in ("jax.sharding.PartitionSpec", "jax.P"):
                yield self._finding(
                    ctx, node, f"direct {q} reference outside the spec "
                    "modules: use sharding.make_spec")


#: where silent exception-swallowing is a reliability hazard: the hot
#: paths plus the fault-domain modules the reliability PR hardened
#: (checkpoint integrity, data determinism, the fault-injection layer)
BARE_EXCEPT_PATHS = HOT_PATHS + ("src/repro/checkpoint/", "src/repro/data/",
                                 "src/repro/reliability/")


class BareExceptRule(Rule):
    """No silent exception-swallowing in the failure domains the
    reliability layer hardens: a bare ``except:`` (catches KeyboardInterrupt
    / SystemExit and hides the fault taxonomy) is always flagged, and
    ``except Exception:`` / ``except BaseException:`` whose body is ONLY
    ``pass``/``...`` (pure swallow — the failure never reaches a guard,
    an event log, or the chaos suite) is flagged too. Broad handlers that
    DO something (return a verdict, log, re-raise) are allowed; the few
    sanctioned boundary swallows carry a suppression comment naming this
    rule, making every one grep-able."""

    name = "bare-except"
    description = ("bare `except:` or silently-swallowing `except "
                   "Exception: pass` in train/serve/core/kernels/"
                   "checkpoint/data/reliability")

    def applies(self, relpath: str) -> bool:
        return relpath.startswith(BARE_EXCEPT_PATHS)

    @staticmethod
    def _broad(type_node: Optional[ast.AST]) -> bool:
        nodes = (type_node.elts if isinstance(type_node, ast.Tuple)
                 else [type_node])
        return any(isinstance(n, ast.Name)
                   and n.id in ("Exception", "BaseException")
                   for n in nodes)

    @staticmethod
    def _swallows(body: List[ast.stmt]) -> bool:
        return all(isinstance(s, ast.Pass)
                   or (isinstance(s, ast.Expr)
                       and isinstance(s.value, ast.Constant)
                       and s.value.value is Ellipsis)
                   for s in body)

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if node.type is None:
                yield self._finding(
                    ctx, node, "bare `except:` catches KeyboardInterrupt/"
                    "SystemExit too — name the exception (narrowest that "
                    "fits the fault taxonomy)")
            elif self._broad(node.type) and self._swallows(node.body):
                yield self._finding(
                    ctx, node, "`except Exception: pass` silently swallows "
                    "the failure — handle it (guard/event/re-raise) or "
                    "narrow the type")


#: registry, in reporting order
ALL_RULES: Tuple[Rule, ...] = (
    CompatCollectiveRule(),
    KernelsShardMapRule(),
    HostSyncRule(),
    PallasCallRule(),
    HardcodedInterpretRule(),
    PartitionSpecConfinementRule(),
    BareExceptRule(),
)

"""repro-lint engine: file walking, import-alias resolution, suppression
handling, and JSON/human reporting.

Pure stdlib (ast + re) by design: the ``lint-compat`` CI entry point runs
before any dependency install, and ``tools/lint_compat.sh`` execs into
this engine. Rules live in :mod:`tools.repro_lint.rules`.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os
import re
from typing import Dict, Iterable, List, Optional, Sequence, Set

#: directories linted when no paths are given (mirrors the old grep lint)
DEFAULT_PATHS = ("src", "tests", "benchmarks", "examples")

_SUPPRESS_RE = re.compile(r"#\s*repro-lint:\s*disable=([\w\-, ]+)")
_FILE_SUPPRESS_RE = re.compile(r"#\s*repro-lint:\s*disable-file=([\w\-, ]+)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One lint violation: ``rule`` at ``path:line:col`` with a message."""
    rule: str
    path: str
    line: int
    col: int
    message: str

    def to_json(self) -> Dict[str, object]:
        """Plain-dict form for the JSON report."""
        return dataclasses.asdict(self)

    def human(self) -> str:
        """One ``path:line:col: rule: message`` diagnostic line."""
        return f"{self.path}:{self.line}:{self.col}: {self.rule}: " \
               f"{self.message}"


@dataclasses.dataclass
class FileContext:
    """Everything a rule sees for one file: the parsed AST, the
    import-alias table (local name -> fully-qualified dotted path), the
    repo-relative posix path, and the raw source lines."""
    relpath: str
    tree: ast.AST
    aliases: Dict[str, str]
    lines: List[str]


def build_aliases(tree: ast.AST) -> Dict[str, str]:
    """Map every imported local name to its fully-qualified dotted path,
    walking ALL import statements (module- and function-level):

      import jax                  -> {"jax": "jax"}
      import jax.lax as jl        -> {"jl": "jax.lax"}
      from jax import lax         -> {"lax": "jax.lax"}
      from jax.lax import (psum,
                           pmax)  -> {"psum": "jax.lax.psum", ...}

    The parenthesized multi-line form resolves identically to the single
    line form — the false negative the old line-regex grep had.
    """
    aliases: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.asname:
                    aliases[alias.asname] = alias.name
                else:
                    # `import a.b.c` binds only the root name `a`
                    root = alias.name.split(".")[0]
                    aliases[root] = root
        elif isinstance(node, ast.ImportFrom):
            mod = ("." * node.level) + (node.module or "")
            for alias in node.names:
                if alias.name == "*":
                    continue
                aliases[alias.asname or alias.name] = f"{mod}.{alias.name}"
    return aliases


def _suppressions(lines: Sequence[str]) -> "tuple[Dict[int, Set[str]], Set[str]]":
    """Parse suppression comments: per-line ``# repro-lint: disable=a,b``
    (applies to its own line and the line below it, so long flagged
    expressions can carry the comment above) and file-level
    ``# repro-lint: disable-file=a,b``."""
    per_line: Dict[int, Set[str]] = {}
    whole_file: Set[str] = set()
    for i, line in enumerate(lines, start=1):
        m = _SUPPRESS_RE.search(line)
        if m:
            names = {s.strip() for s in m.group(1).split(",") if s.strip()}
            per_line.setdefault(i, set()).update(names)
            per_line.setdefault(i + 1, set()).update(names)
        m = _FILE_SUPPRESS_RE.search(line)
        if m:
            whole_file.update(
                s.strip() for s in m.group(1).split(",") if s.strip())
    return per_line, whole_file


def lint_source(source: str, relpath: str,
                rules: Optional[Sequence] = None) -> List[Finding]:
    """Lint one file's source text; returns unsuppressed findings."""
    if rules is None:
        from tools.repro_lint.rules import ALL_RULES
        rules = ALL_RULES
    lines = source.splitlines()
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Finding(rule="syntax-error", path=relpath,
                        line=e.lineno or 1, col=e.offset or 0,
                        message=f"file does not parse: {e.msg}")]
    ctx = FileContext(relpath=relpath, tree=tree,
                      aliases=build_aliases(tree), lines=lines)
    per_line, whole_file = _suppressions(lines)
    findings: List[Finding] = []
    seen = set()
    for rule in rules:
        if not rule.applies(relpath):
            continue
        if rule.name in whole_file:
            continue
        for f in rule.check(ctx):
            key = (f.rule, f.line, f.col, f.message)
            if key in seen:
                continue
            seen.add(key)
            if f.rule in per_line.get(f.line, ()):
                continue
            findings.append(f)
    findings.sort(key=lambda f: (f.line, f.col, f.rule))
    return findings


def iter_py_files(root: str, paths: Iterable[str]) -> Iterable[str]:
    """Yield repo-relative posix paths of every .py under ``paths``
    (files or directories, relative to ``root``); skips __pycache__ and
    hidden directories. Missing paths are ignored (a repo without
    examples/ still lints)."""
    for p in paths:
        full = os.path.join(root, p)
        if os.path.isfile(full) and p.endswith(".py"):
            yield p.replace(os.sep, "/")
        elif os.path.isdir(full):
            for dirpath, dirnames, filenames in os.walk(full):
                dirnames[:] = [d for d in sorted(dirnames)
                               if d != "__pycache__"
                               and not d.startswith(".")]
                for fn in sorted(filenames):
                    if fn.endswith(".py"):
                        rel = os.path.relpath(os.path.join(dirpath, fn),
                                              root)
                        yield rel.replace(os.sep, "/")


def default_root() -> str:
    """The repo root (two levels above this package)."""
    return os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))


def run_lint(paths: Optional[Sequence[str]] = None,
             root: Optional[str] = None,
             rules: Optional[Sequence] = None,
             ) -> "tuple[List[Finding], int]":
    """Lint ``paths`` (repo-relative; default :data:`DEFAULT_PATHS`)
    under ``root`` (default: this repo). Returns ``(findings, n_files)``.
    """
    if root is None:
        root = default_root()
    if paths is None:
        paths = DEFAULT_PATHS
    findings: List[Finding] = []
    n_files = 0
    for rel in iter_py_files(root, paths):
        n_files += 1
        with open(os.path.join(root, rel), encoding="utf-8") as f:
            source = f.read()
        findings.extend(lint_source(source, rel, rules))
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings, n_files


def report_json(findings: Sequence[Finding], n_files: int,
                rules: Sequence) -> Dict[str, object]:
    """The machine-readable report uploaded as a CI artifact."""
    counts: Dict[str, int] = {}
    for f in findings:
        counts[f.rule] = counts.get(f.rule, 0) + 1
    return {
        "tool": "repro-lint",
        "ok": not findings,
        "n_files": n_files,
        "n_findings": len(findings),
        "counts_by_rule": counts,
        "rules": [{"name": r.name, "description": r.description}
                  for r in rules],
        "findings": [f.to_json() for f in findings],
    }


def main(argv: Optional[Sequence[str]] = None) -> int:
    """CLI entry (``python -m tools.repro_lint``): exit 1 on violations."""
    import argparse

    from tools.repro_lint.rules import ALL_RULES

    ap = argparse.ArgumentParser(
        prog="repro_lint",
        description="AST contract linter for the solver/train/serve stack "
                    "(see docs/static_analysis.md)")
    ap.add_argument("paths", nargs="*", default=None,
                    help=f"files/dirs relative to --root "
                         f"(default: {' '.join(DEFAULT_PATHS)})")
    ap.add_argument("--root", default=None,
                    help="repo root (default: auto-detected)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule subset to run")
    ap.add_argument("--json", default=None, metavar="FILE",
                    help="write the JSON report to FILE")
    ap.add_argument("--format", choices=("human", "json"), default="human",
                    help="stdout format")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    args = ap.parse_args(argv)

    rules = list(ALL_RULES)
    if args.rules:
        wanted = {s.strip() for s in args.rules.split(",") if s.strip()}
        known = {r.name for r in rules}
        unknown = wanted - known
        if unknown:
            ap.error(f"unknown rule(s): {', '.join(sorted(unknown))}; "
                     f"known: {', '.join(sorted(known))}")
        rules = [r for r in rules if r.name in wanted]

    if args.list_rules:
        for r in rules:
            print(f"{r.name}: {r.description}")
        return 0

    findings, n_files = run_lint(paths=args.paths or None, root=args.root,
                                 rules=rules)
    report = report_json(findings, n_files, rules)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2)
    if args.format == "json":
        print(json.dumps(report, indent=2))
    else:
        for f in findings:
            print(f.human())
        names = ",".join(r.name for r in rules)
        if findings:
            print(f"repro-lint: {len(findings)} violation(s) over "
                  f"{n_files} files (rules: {names})")
        else:
            print(f"repro-lint OK: 0 violations over {n_files} files "
                  f"(rules: {names})")
    return 1 if findings else 0

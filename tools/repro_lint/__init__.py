"""repro-lint: AST rule engine enforcing the repo's source-level
contracts (compat-collective routing, kernels-shard_map isolation,
no-host-sync hot paths, pallas-call containment, no hardcoded
interpret=True).

Run ``python -m tools.repro_lint`` from the repo root; the companion
lowered-artifact layer is ``repro.contracts`` + ``tools/contract_suite.py``.
See docs/static_analysis.md for the rule catalog and suppression syntax.
"""
from tools.repro_lint.engine import (Finding, lint_source,  # noqa: F401
                                     report_json, run_lint)
from tools.repro_lint.rules import ALL_RULES  # noqa: F401

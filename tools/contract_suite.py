"""CI contract suite: evaluates the repo's documented LOWERING contracts
through the declarative API (``repro.contracts``) on every commit and
emits a JSON report (uploaded as a CI artifact by the static-analysis
job).

Contracts checked (see docs/static_analysis.md):

  * the five solver tiers — replicated DEER, replicated ELK, the fused
    whole-Newton megakernel, the sharded-lax solve and the sharded-fused
    solve (core/block.py routing) — each lower with NO sequential loop of
    sequence length T (parallel fixed-point iteration: the only loops are
    short carries whose trip counts are independent of T);
  * serve prefill (models/lm.py::prefill) lowers with NO sequential loop
    of prompt length (the PR-4 parallel-prefill acceptance check);
  * the explicit-int8 gradient step emits NO gradient-sized fp32
    cross-pod collective in its compiled HLO — with the gspmd baseline as
    a positive control that MUST violate the same clause (proving the
    checker has teeth on this jax version);
  * the FSDP explicit seam gathers parameters ONCE per step: compiled
    HLO shows reduce-scatter'd gradients and no full-parameter fp32
    all-gather inside a while-loop body — with a deliberately-naive
    gather-per-microbatch seam as the must-violate positive control;
  * quantized decode: the int8-cache decode tick declares NO cache-sized
    fp32 parameter in its compiled HLO (the narrow wire format is what
    crosses the call boundary) — with the fp32-cache tick as the positive
    control that MUST declare one;
  * compat routing: the AST rule engine (tools/repro_lint) reports zero
    violations across all rules.

With ``--pyright`` the suite also runs pyright (basic mode, scoped by
pyrightconfig.json to distributed/train/serve) as a NON-BLOCKING first
pass, recording the error count in the report without affecting the exit
code.

Usage (standalone; sets up 8 forced host devices itself):

    python tools/contract_suite.py [--json FILE] [--pyright] [--only SUB]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# Must precede the jax import: the sharded tiers and the pod-collective
# contract need a multi-device mesh on a CPU host.
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def _entry(name, report, detail=None):
    """One contract row: the LoweringReport flattened for the JSON
    artifact."""
    d = report.to_json()
    return {"name": name, "ok": d["ok"], "violations": d["violations"],
            "loop_lengths": d["loop_lengths"], "detail": detail or {}}


def solver_tier_contracts():
    """The five solver tiers each lower free of length-T sequential
    loops (forbidding unbounded while_loops too — fixed-iteration
    configs must not hide a data-dependent sweep)."""
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.contracts import check_lowering
    from repro.core.block import LrcSSMConfig, apply_lrcssm, init_lrcssm
    from repro.core.deer import DeerConfig
    from repro.core.elk import ElkConfig
    from repro.distributed import sharding as shd

    B, T = 2, 128
    base = LrcSSMConfig(d_input=6, n_classes=2, d_hidden=16, d_state=16,
                        n_blocks=1,
                        deer=DeerConfig(max_iters=6, mode="fixed"),
                        elk=ElkConfig(max_iters=6, mode="fixed"))
    params = init_lrcssm(base, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, 6))

    tiers = [
        ("solver-tier-replicated-deer", base, False),
        ("solver-tier-replicated-elk",
         dataclasses.replace(base, solver="elk"), False),
        ("solver-tier-fused-megakernel",
         dataclasses.replace(base, fused=True), False),
        ("solver-tier-sharded-lax",
         dataclasses.replace(base, seq_axis="data"), True),
        ("solver-tier-sharded-fused",
         dataclasses.replace(base, fused=True, seq_axis="data"), True),
    ]
    rows = []
    for name, cfg, needs_mesh in tiers:
        fn = lambda p, xx, c=cfg: apply_lrcssm(c, p, xx)
        if needs_mesh:
            mesh = jax.make_mesh((8,), ("data",))
            with shd.use_mesh(mesh):
                report = check_lowering(fn, (params, x),
                                        forbid_sequential_loop_over=T)
        else:
            report = check_lowering(fn, (params, x),
                                    forbid_sequential_loop_over=T)
        rows.append(_entry(name, report, {"T": T, "B": B}))
    return rows


def serve_prefill_contract():
    """Chunked parallel prefill lowers with NO sequential loop of prompt
    length (the tests/test_serve.py acceptance clause, re-checked here
    against the CI jax matrix)."""
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.configs import get_reduced
    from repro.contracts import check_lowering
    from repro.models import build_model

    arch = dataclasses.replace(get_reduced("falcon_mamba_7b"),
                               dtype=jnp.float32)
    model = build_model(arch)
    params = model.init(jax.random.PRNGKey(0))
    T = 32
    cache = model.init_cache(params, 1, 2 * T)
    report = check_lowering(
        lambda p, t, c: model.prefill(p, t, c, T),
        (params, jnp.zeros((1, T), jnp.int32), cache),
        forbid_sequential_loop_over=T)
    return [_entry("serve-prefill-parallel", report,
                   {"arch": arch.name, "T": T})]


def serve_verify_contract():
    """The speculative-decoding batched VERIFY step lowers with NO
    sequential loop of the window length k: the k-token window for all
    slots is ONE prefill-style parallel solve (DEER ladder / associative
    scan / window attention), never k decode ticks. k=24 is distinctive —
    it collides with no reduced-config solver iteration count, conv width
    or layer count, so a length-24 loop in the jaxpr can only be a
    sequential walk over the window."""
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.config import SSMConfig
    from repro.configs import get_reduced
    from repro.contracts import check_lowering
    from repro.models import build_model
    from repro.train.step import make_step

    k, slots, max_seq = 24, 4, 96
    out = []
    for name, patch in (
            ("falcon_mamba_7b", {"ssm": SSMConfig(kind="lrc", expand=2,
                                                  deer_iters=8, chunk=0)}),
            ("gemma3_4b", {})):
        arch = dataclasses.replace(get_reduced(name), dtype=jnp.float32,
                                   **patch)
        model = build_model(arch)
        params = model.init(jax.random.PRNGKey(0))
        cache = model.init_cache(params, slots, max_seq)
        cache["pos"] = jnp.zeros((slots,), jnp.int32)
        report = check_lowering(
            make_step(model, "verify"),
            (params, jnp.zeros((slots, k), jnp.int32), cache),
            forbid_sequential_loop_over=k)
        tag = arch.ssm.kind if name.startswith("falcon") else "windowed"
        out.append(_entry(f"serve-verify-parallel-{tag}", report,
                          {"arch": arch.name, "k": k, "slots": slots}))
    return out


def explicit_grad_contract():
    """The explicit-int8 train step compiles with NO gradient-sized fp32
    cross-pod collective; the gspmd baseline is the positive control and
    MUST violate the same clause."""
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.config import ShapeConfig, TrainConfig
    from repro.configs import get_reduced
    from repro.contracts import LoweringReport, Violation, \
        check_hlo_collectives
    from repro.distributed import sharding as shd
    from repro.launch.specs import make_batch
    from repro.models import build_model
    from repro.train.state import train_state_init
    from repro.train.step import jit_train_step

    arch = dataclasses.replace(get_reduced("granite_3_8b"),
                               dtype=jnp.float32)
    model = build_model(arch)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(arch, ShapeConfig("s", 16, 8, "train"),
                       jax.random.PRNGKey(1))
    mesh = jax.make_mesh((8,), ("pod",))     # every collective is cross-pod
    THRESH = 16384    # >> per-block int8 scales (n/256), << any grad leaf
    NO_BIG_F32 = [{"dtype": "f32", "min_elems": THRESH}]

    def hlo(mode, comp):
        tcfg = TrainConfig(warmup_steps=0, grad_reduce=mode,
                           grad_compression=comp)
        with shd.use_mesh(mesh):
            state = train_state_init(params, tcfg, mesh)
            jstep = jit_train_step(model, tcfg, mesh, state, batch,
                                   donate=False)
            return jstep.lower(state, batch).compile().as_text()

    ops, violations = check_hlo_collectives(hlo("explicit", "int8"),
                                            forbid=NO_BIG_F32)
    int8_payload = sum(1 for o in ops if o["dtype"] == "s8")
    _, base_violations = check_hlo_collectives(hlo("gspmd", "none"),
                                               forbid=NO_BIG_F32)
    extra = []
    if not base_violations:
        extra.append(Violation(
            "checker-control",
            "positive control failed: the gspmd fp32 baseline produced no "
            "forbidden-collective violation — the HLO parser may not match "
            "this jax version's collective spelling", {}))
    if not int8_payload:
        extra.append(Violation(
            "checker-control",
            "explicit-int8 HLO shows no int8 collective payload", {}))
    report = LoweringReport(violations=list(violations) + extra)
    return [_entry("train-explicit-no-fp32-pod-collective", report,
                   {"threshold_elems": THRESH,
                    "int8_collectives": int8_payload,
                    "gspmd_baseline_violations": len(base_violations)})]


def tp_fsdp_contract():
    """The FSDP explicit seam gathers parameters ONCE per step — the
    compiled HLO shows reduce-scatter'd gradients and NO full-parameter
    fp32 all-gather inside a loop body (per-microbatch re-gather). The
    positive control is a deliberately-naive seam that all-gathers inside
    the microbatch scan and MUST violate the same in-loop clause (proving
    the while-region HLO parser has teeth on this jax version)."""
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.config import ShapeConfig, TrainConfig
    from repro.configs import get_reduced
    from repro.contracts import LoweringReport, Violation, \
        check_hlo_collectives
    from repro.distributed import compat
    from repro.distributed import sharding as shd
    from repro.launch.specs import make_batch
    from repro.models import build_model
    from repro.train.state import train_state_init
    from repro.train.step import jit_train_step

    arch = dataclasses.replace(get_reduced("granite_3_8b"),
                               dtype=jnp.float32)
    model = build_model(arch)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(arch, ShapeConfig("s", 16, 8, "train"),
                       jax.random.PRNGKey(1))
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    THRESH = 16384
    # the FSDP seam clause: nothing full-parameter-sized is re-gathered
    # per microbatch iteration
    NO_LOOP_GATHER = [{"kind": "all-gather", "dtype": "f32",
                       "min_elems": THRESH, "in_loop": True}]

    def hlo(psh):
        tcfg = TrainConfig(warmup_steps=0, grad_reduce="explicit",
                           param_sharding=psh, microbatch=2)
        with shd.use_mesh(mesh):
            state = train_state_init(params, tcfg, mesh)
            jstep = jit_train_step(model, tcfg, mesh, state, batch,
                                   donate=False)
            return jstep.lower(state, batch).compile().as_text()

    ops, violations = check_hlo_collectives(hlo("fsdp"),
                                            forbid=NO_LOOP_GATHER)
    n_rs = sum(1 for o in ops if o["kind"] == "reduce-scatter")
    n_gather = sum(1 for o in ops if o["kind"] == "all-gather"
                   and o["elems"] > THRESH and not o["in_loop"])
    extra = []
    if not n_rs:
        extra.append(Violation(
            "checker-control",
            "FSDP HLO shows no reduce-scatter — gradients are not "
            "scatter-reduced on the explicit seam", {}))
    if not n_gather:
        extra.append(Violation(
            "checker-control",
            "FSDP HLO shows no out-of-loop parameter all-gather — the "
            "gather-once seam is missing entirely", {}))

    # positive control: a naive seam whose gather is INSIDE the
    # microbatch scan (carry-dependent, so XLA cannot hoist it)
    w_shard = jnp.zeros((256 // 8, 4096), jnp.float32)
    mb = jnp.zeros((4, 2, 4096), jnp.float32)
    flat = jax.make_mesh((8,), ("data",))

    def naive(w, b):
        def micro(carry, x):
            w_full = compat.all_gather(w + carry * 0, "data", axis=0,
                                       tiled=True)
            return carry + jnp.sum(x @ w_full.T), None
        loss, _ = jax.lax.scan(micro, 0.0, b)
        return compat.pmean(loss, "data")

    naive_hlo = compat.shard_map(
        naive, mesh=flat,
        in_specs=(shd.make_spec("data"), shd.make_spec()),
        out_specs=shd.make_spec(), check_vma=False)
    with shd.use_mesh(flat):
        naive_text = jax.jit(naive_hlo).lower(
            w_shard, mb).compile().as_text()
    _, naive_violations = check_hlo_collectives(naive_text,
                                                forbid=NO_LOOP_GATHER)
    if not naive_violations:
        extra.append(Violation(
            "checker-control",
            "positive control failed: the naive in-loop all-gather seam "
            "produced no violation — the while-region parser may not "
            "match this XLA version's HLO text", {}))
    report = LoweringReport(violations=list(violations) + extra)
    return [_entry("train-fsdp-gather-once-reduce-scatter", report,
                   {"threshold_elems": THRESH,
                    "reduce_scatters": n_rs,
                    "out_of_loop_gathers": n_gather,
                    "naive_control_violations": len(naive_violations)})]


def quantized_decode_contract():
    """The int8-cache decode tick compiles with NO cache-sized fp32
    parameter: the resident wire format (int8 payload + per-row block
    scales) is what crosses the compiled call boundary, and the fp32
    shadow exists only as transient values inside the tick (dequantize on
    entry, requantize before the donated cache is returned). The fp32
    decode tick is the positive control that MUST declare a cache-sized
    fp32 parameter — proving the parameter scanner sees cache-sized
    tensors when they are there.

    "Cache-sized" is computed, not guessed: the largest float leaf of the
    fp32 resident cache. Weight quantization uses ``min_weight_elems=1``
    so every >=2-D float weight also goes narrow and cannot alias the
    threshold."""
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.config import SSMConfig
    from repro.configs import get_reduced
    from repro.contracts import (LoweringReport, Violation,
                                 hlo_parameter_tensors)
    from repro.distributed.precision import (PrecisionPolicy,
                                             quantize_params)
    from repro.models import build_model
    from repro.serve.cache import StateCache
    from repro.serve.decode import make_decode_step

    slots, max_seq = 8, 64
    arch = dataclasses.replace(
        get_reduced("falcon_mamba_7b"), dtype=jnp.float32,
        ssm=SSMConfig(kind="lrc", expand=2, deer_iters=4, chunk=0))
    model = build_model(arch)
    params = model.init(jax.random.PRNGKey(0))
    toks = jnp.zeros((slots, 1), jnp.int32)

    def params_of(precision):
        p = (quantize_params(params, precision)
             if precision is not None else params)
        cache = StateCache(model, params, slots, max_seq,
                           precision=precision)
        step = make_decode_step(model, p, cache.cache, precision=precision)
        txt = step.lower(p, toks, cache.cache).compile().as_text()
        return hlo_parameter_tensors(txt)

    fp32_cache = StateCache(model, params, slots, max_seq).cache
    thresh = max(l.size for l in jax.tree_util.tree_leaves(fp32_cache)
                 if hasattr(l, "dtype")
                 and jnp.issubdtype(l.dtype, jnp.floating))

    int8 = PrecisionPolicy(weights="int8", cache="int8", kernel_io="bf16",
                           min_weight_elems=1)
    offenders = [r for r in params_of(int8)
                 if r["dtype"] == "f32" and r["elems"] >= thresh]
    control = [r for r in params_of(None)
               if r["dtype"] == "f32" and r["elems"] >= thresh]

    violations = [Violation(
        "quantized-cache-parameter",
        f"int8-cache decode declares a cache-sized fp32 parameter: "
        f"{r['elems']} elems", r) for r in offenders]
    if not control:
        violations.append(Violation(
            "positive-control",
            f"fp32 decode declared NO fp32 parameter >= {thresh} elems — "
            "the parameter scanner is blind on this jax version"))
    report = LoweringReport(violations=violations)
    return [_entry("serve-quantized-decode-narrow-wire", report,
                   {"threshold_elems": thresh,
                    "int8_fp32_params_over_threshold": len(offenders),
                    "control_fp32_params_over_threshold": len(control)})]


def compat_routing_contract():
    """The AST rule engine reports zero violations across all rules (the
    source-level half of the contract surface)."""
    from tools.repro_lint import ALL_RULES, report_json, run_lint

    findings, n_files = run_lint(root=_ROOT)
    rep = report_json(findings, n_files, ALL_RULES)
    return [{"name": "compat-routing-ast-lint", "ok": rep["ok"],
             "violations": [
                 {"contract": f["rule"],
                  "message": f"{f['path']}:{f['line']}: {f['message']}",
                  "detail": f} for f in rep["findings"]],
             "loop_lengths": None,
             "detail": {"n_files": n_files,
                        "counts_by_rule": rep["counts_by_rule"]}}]


def run_pyright():
    """Non-blocking pyright (basic mode; scope + extraPaths from
    pyrightconfig.json). Returns a record for the report — never fails
    the suite; the error count is the tracked signal."""
    import shutil
    import subprocess

    exe = shutil.which("pyright")
    if exe is None:
        return {"available": False, "note": "pyright not installed"}
    try:
        r = subprocess.run([exe, "--outputjson"], cwd=_ROOT,
                           capture_output=True, text=True, timeout=600)
        data = json.loads(r.stdout)
        summ = data.get("summary", {})
        return {"available": True,
                "errors": summ.get("errorCount"),
                "warnings": summ.get("warningCount"),
                "files": summ.get("filesAnalyzed"),
                "first_errors": [
                    {"file": d.get("file"),
                     "line": d.get("range", {}).get("start", {}).get("line"),
                     "message": d.get("message", "")[:200]}
                    for d in data.get("generalDiagnostics", [])
                    if d.get("severity") == "error"][:20]}
    except Exception as e:
        return {"available": True, "error": f"pyright run failed: {e!r}"}


def main(argv=None) -> int:
    """Run the suite; exit 1 when any contract is violated."""
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", default=os.environ.get("CONTRACTS_JSON_OUT"),
                    metavar="FILE", help="write the JSON report to FILE")
    ap.add_argument("--pyright", action="store_true",
                    help="also record a non-blocking pyright pass")
    ap.add_argument("--only", default=None,
                    help="run only contracts whose name contains SUB")
    args = ap.parse_args(argv)

    import jax

    groups = (solver_tier_contracts, serve_prefill_contract,
              serve_verify_contract, quantized_decode_contract,
              explicit_grad_contract, tp_fsdp_contract,
              compat_routing_contract)
    rows = []
    for group in groups:
        for row in group():
            if args.only and args.only not in row["name"]:
                continue
            rows.append(row)
            status = "OK " if row["ok"] else "FAIL"
            print(f"[{status}] {row['name']}", flush=True)
            for v in row["violations"]:
                print(f"       {v['contract']}: {v['message']}", flush=True)

    report = {
        "suite": "repro-contracts",
        "ok": all(r["ok"] for r in rows),
        "jax_version": jax.__version__,
        "n_contracts": len(rows),
        "n_failed": sum(not r["ok"] for r in rows),
        "contracts": rows,
    }
    if args.pyright:
        report["pyright"] = run_pyright()
        pr = report["pyright"]
        if pr.get("available") and "errors" in pr:
            print(f"[info] pyright (non-blocking): {pr['errors']} errors, "
                  f"{pr['warnings']} warnings over {pr['files']} files",
                  flush=True)
        else:
            print(f"[info] pyright (non-blocking): {pr}", flush=True)

    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2)
        print(f"# wrote {args.json}", file=sys.stderr, flush=True)

    print(f"contract suite: {report['n_contracts'] - report['n_failed']}/"
          f"{report['n_contracts']} contracts hold "
          f"(jax {jax.__version__})", flush=True)
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())

#!/usr/bin/env bash
# ROADMAP distributed-layer contract lint (enforced by CI, runnable locally):
#
#   ALL shard_map and collective call sites must resolve through
#   src/repro/distributed/compat.py — never either jax spelling directly
#   (jax.shard_map moved modules and renamed its kwarg across the supported
#   0.4.30 -> current range), and never the raw jax.lax.* collectives the
#   shard_map bodies compose with (one distribution API surface to patch).
#
# Usage: bash tools/lint_compat.sh   (exits non-zero on any violation)
set -u
cd "$(dirname "$0")/.."

pattern='jax\.shard_map|jax\.experimental\.shard_map|from jax\.experimental import shard_map|jax\.lax\.(psum|pmax|pmin|pmean|all_gather|ppermute|psum_scatter|axis_index)\b'
hits=$(grep -rn --include='*.py' -E "$pattern" src tests benchmarks examples 2>/dev/null \
         | grep -v 'src/repro/distributed/compat\.py' || true)

# ALSO reject the aliased spellings of the psum-family collectives that the
# jax.lax.* pattern above misses: `from jax import lax; lax.psum(...)` and
# `from jax.lax import psum`. The pod-local gradient engine (train/step.py)
# made the explicit-collective surface much larger, so the grep has to be
# spelling-complete — any of these bypasses the single-patch-point contract.
alias_pattern='(^|[^.[:alnum:]_])lax\.(psum|pmax|pmin|pmean|all_gather|ppermute|psum_scatter|axis_index)[[:space:]]*\(|from jax\.lax import[^#]*(psum|pmax|pmin|pmean|all_gather|ppermute|psum_scatter|axis_index)'
alias_hits=$(grep -rn --include='*.py' -E "$alias_pattern" src tests benchmarks examples 2>/dev/null \
         | grep -v 'src/repro/distributed/compat\.py' || true)

# Kernel-layer guard: src/repro/kernels must never spell shard_map except
# through compat.shard_map — Pallas kernels are the lowest layer and any
# direct jax shard_map import there would dodge both the version-portability
# shim AND the solver-level seam (sharded composition belongs to the ops
# wrappers via core.scan.sharded_scan_fixup, not inside kernel bodies).
kernel_pattern='(^|[^.[:alnum:]_])shard_map[[:space:]]*\(|import[^#]*[[:space:]]shard_map'
kernel_hits=$(grep -rnE --include='*.py' "$kernel_pattern" src/repro/kernels 2>/dev/null \
         | grep -v 'compat\.shard_map' || true)

if [ -n "$hits" ] || [ -n "$alias_hits" ] || [ -n "$kernel_hits" ]; then
  echo "compat-contract violation: shard_map / raw collectives referenced" >&2
  echo "outside src/repro/distributed/compat.py (route through compat.*):" >&2
  [ -n "$hits" ] && echo "$hits" >&2
  [ -n "$alias_hits" ] && echo "$alias_hits" >&2
  [ -n "$kernel_hits" ] && { echo "kernels/ shard_map guard:" >&2; echo "$kernel_hits" >&2; }
  exit 1
fi
echo "compat lint OK: all shard_map/collective call sites route through distributed/compat.py"

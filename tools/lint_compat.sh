#!/usr/bin/env bash
# ROADMAP distributed-layer contract lint (enforced by CI, runnable locally):
#
#   ALL shard_map and collective call sites must resolve through
#   src/repro/distributed/compat.py — never either jax spelling directly —
#   and src/repro/kernels must never spell shard_map except through
#   compat.shard_map.
#
# This script is now a THIN WRAPPER over the AST rule engine
# (tools/repro_lint), which replaced the old grep: alias resolution makes
# the check spelling-complete — `import jax.lax as jl; jl.psum(...)` and
# the parenthesized multi-line `from jax.lax import (\n    psum, ...)`
# form the line-regex grep missed both resolve to the same qualified name.
# Stdlib-only: runs on a bare runner before any dependency install.
#
# Usage: bash tools/lint_compat.sh   (exits non-zero on any violation)
# Full rule set + JSON reports: python -m tools.repro_lint --help
# (see docs/static_analysis.md for the rule catalog)
set -u
cd "$(dirname "$0")/.."

PY=$(command -v python3 || command -v python) || {
  echo "lint_compat: no python interpreter found" >&2; exit 2; }
exec "$PY" -m tools.repro_lint \
  --rules compat-collective,kernels-shard-map "$@"

"""CI chaos suite: deterministic fault-injection scenarios end-to-end.

Every scenario drives a REAL subsystem (trainer, checkpoint manager,
serve engine, solver ladder) through a seeded :mod:`repro.reliability`
fault plan and asserts the documented recovery/degradation contract
(docs/reliability.md). The acceptance bar for every scenario: the run
ends either FULLY RECOVERED or in a DECLARED degraded state — never a
hang, an unhandled exception, or silently-wrong tokens.

Scenarios:

  * nan_batch_guard         — NaN batches are skipped on device, counted,
                              and the clean-loss bar still holds;
  * rollback_consecutive    — a sustained NaN window triggers exactly one
                              rollback to a verified checkpoint (barrier:
                              no rollback livelock), then skips through;
  * corrupt_latest_checkpoint — restore(None) falls back past a
                              truncated/bit-flipped latest step; an
                              explicit restore of the damaged step raises;
  * mid_save_kill           — an orphaned .tmp_step_* dir (kill between
                              makedirs and rename) never corrupts
                              latest_step/restore and is swept by gc;
  * preempt_resume_bitexact — a FaultPlan preemption + resume replays a
                              loss trajectory bit-identical to the
                              uninterrupted run;
  * slot_corruption         — the serve watchdog quarantines a NaN'd slot
                              and the re-prefilled stream is
                              token-identical to the fault-free run;
  * queue_stall             — a wedged admission window surfaces as a
                              structured EngineStalledError under a small
                              tick budget and drains under a larger one;
  * solver_divergence       — a tol-mode solve that exhausts its ladder
                              reports diverged=True (and the healthy
                              config does not);
  * spec_auto_disable       — a forced-low accept rate disables spec
                              decode, re-enables after cooldown, and the
                              stream stays greedy-identical throughout;
  * deadline_backpressure   — bounded-queue rejects and deadline expiries
                              are structured statuses, and the mix drains
                              without hanging.

Usage (standalone):

    python tools/chaos_suite.py [--json FILE] [--only SUB]
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
import tempfile

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def _row(name, ok, detail=None, violations=()):
    """One scenario row for the JSON artifact."""
    return {"name": name, "ok": bool(ok), "violations": list(violations),
            "detail": detail or {}}


# --------------------------------------------------------------- train toys

def _toy_trainer(tmp, faults=None, guard=True, rollback_after=0,
                 checkpoint_every=0, seed=0):
    """A tiny least-squares trainer on a 1-device mesh with a
    step-indexed data source — small enough that every chaos scenario
    re-runs it in seconds, real enough that it exercises the actual
    Trainer/step/checkpoint code paths."""
    import jax
    import jax.numpy as jnp

    from repro.config import TrainConfig
    from repro.models import Model
    from repro.train.loop import Trainer

    D, B = 16, 8
    w_true = 0.5 * jnp.ones((D,))

    def init(key):
        return {"w": jnp.zeros((D,), jnp.float32)}

    def loss(p, b):
        return jnp.mean((b["tokens"] @ p["w"] - b["labels"]) ** 2)

    model = Model(arch=None, init=init, loss=loss, apply=None,
                  decode_step=None, init_cache=None)

    class Source:
        """Pure function of step — the batch_at replay contract."""

        def batch_at(self, s):
            x = jax.random.normal(jax.random.PRNGKey(1000 + s), (B, D))
            return {"tokens": x, "labels": x @ w_true}

    tcfg = TrainConfig(learning_rate=1e-1, warmup_steps=0,
                       total_steps=100000, weight_decay=0.0,
                       checkpoint_every=checkpoint_every,
                       checkpoint_dir=tmp, guard_nonfinite=guard,
                       guard_rollback_after=rollback_after, seed=seed)
    mesh = jax.make_mesh((1,), ("data",))
    trainer = Trainer(model, tcfg, mesh=mesh, log_every=1,
                      log_fn=lambda s: None, faults=faults)
    return trainer, Source()


def scenario_nan_batch_guard():
    """NaN-poisoned batches: the device-side guard skips them (counted),
    parameters stay finite, and the loss tracks the clean run within the
    documented bar — a run that skipped k steps is compared against the
    clean run at the SAME number of effective updates (skipping costs
    exactly the skipped updates, nothing more), with 1.5x headroom for
    the different batch mix."""
    import jax
    import numpy as np

    from repro.reliability import FaultPlan, FaultSpec, FaultySource

    tmp_a, tmp_b = tempfile.mkdtemp(), tempfile.mkdtemp()
    try:
        plan = FaultPlan(seed=0, faults=(
            FaultSpec("nan_batch", 5, until=7, frac=0.5),))
        trainer, src = _toy_trainer(tmp_a)
        hist = trainer.fit(FaultySource(src, plan), 30)

        clean, csrc = _toy_trainer(tmp_b)
        chist = clean.fit(csrc, 30)

        final = hist[-1].loss
        bad_steps = [st.step for st in hist if not st.ok]
        # clean-run loss after the same 27 effective updates
        bar = chist[30 - trainer.skipped_steps - 1].loss
        params_finite = all(
            bool(np.all(np.isfinite(np.asarray(v))))
            for v in jax.tree_util.tree_leaves(trainer.params))
        ok = (trainer.skipped_steps == 3 and bad_steps == [6, 7, 8]
              and params_finite and np.isfinite(final)
              and final <= max(1.5 * bar, bar + 1e-3))
        return [_row("chaos-nan-batch-guard", ok, {
            "skipped": trainer.skipped_steps, "bad_steps": bad_steps,
            "final_loss": float(final), "clean_loss_same_updates":
            float(bar), "clean_loss_final": float(chist[-1].loss),
            "recovered": "full"})]
    finally:
        shutil.rmtree(tmp_a, ignore_errors=True)
        shutil.rmtree(tmp_b, ignore_errors=True)


def scenario_rollback_consecutive():
    """A sustained NaN window (longer than guard_rollback_after) rolls
    back to a verified checkpoint a BOUNDED number of times — each
    rollback must land on a strictly newer restore point (the barrier),
    and checkpoints keep publishing inside the window, so the count is
    bounded by the checkpoints the window spans (here: 2), never a
    livelock; training then skips through and completes with finite
    parameters."""
    import jax
    import numpy as np

    from repro.reliability import FaultPlan, FaultSpec, FaultySource

    tmp = tempfile.mkdtemp()
    try:
        plan = FaultPlan(seed=0, faults=(
            FaultSpec("nan_batch", 12, until=18, frac=0.5),))
        trainer, src = _toy_trainer(tmp, rollback_after=3,
                                    checkpoint_every=5)
        hist = trainer.fit(FaultySource(src, plan), 30)
        params_finite = all(
            bool(np.all(np.isfinite(np.asarray(v))))
            for v in jax.tree_util.tree_leaves(trainer.params))
        ok = (1 <= trainer.rollbacks <= 2 and trainer.skipped_steps > 0
              and hist[-1].step == 30 and np.isfinite(hist[-1].loss)
              and params_finite)
        return [_row("chaos-rollback-consecutive", ok, {
            "rollbacks": trainer.rollbacks,
            "skipped": trainer.skipped_steps,
            "final_loss": float(hist[-1].loss), "recovered": "full"})]
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def scenario_corrupt_latest_checkpoint():
    """Corrupt/truncated LATEST checkpoint: restore(None) walks back to
    the newest VERIFIED step; an explicit restore of the damaged step
    raises instead of silently substituting."""
    import jax.numpy as jnp
    import numpy as np

    from repro.checkpoint.manager import CheckpointManager
    from repro.reliability import corrupt_checkpoint

    tmp = tempfile.mkdtemp()
    try:
        mgr = CheckpointManager(tmp, async_save=False, max_to_keep=10)
        mgr.save(1, {"w": jnp.arange(8.0)})
        mgr.save(2, {"w": jnp.arange(8.0) * 2})
        corrupt_checkpoint(tmp, 2, mode="truncate")
        step, tree, _ = mgr.restore()
        fell_back = (step == 1
                     and bool(np.allclose(tree["w"], np.arange(8.0))))
        explicit_raises = False
        try:
            mgr.restore(2)
        except Exception:  # the contract IS that this raises
            explicit_raises = True

        mgr.save(3, {"w": jnp.arange(8.0) * 3})
        corrupt_checkpoint(tmp, 3, mode="bitflip", seed=1)
        step2, _, _ = mgr.restore()
        bitflip_fell_back = step2 == 1    # step 2 still truncated
        ok = fell_back and explicit_raises and bitflip_fell_back
        return [_row("chaos-corrupt-latest-checkpoint", ok, {
            "fallback_step": int(step), "explicit_raises": explicit_raises,
            "bitflip_fallback_step": int(step2), "recovered": "degraded:"
            "older-checkpoint"})]
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def scenario_mid_save_kill():
    """A kill between the temp-dir makedirs and the atomic rename leaves
    an orphaned .tmp_step_* dir: latest_step/restore never see it, and
    the next save's gc sweeps it."""
    import jax.numpy as jnp

    from repro.checkpoint.manager import CheckpointManager

    tmp = tempfile.mkdtemp()
    try:
        mgr = CheckpointManager(tmp, async_save=False, max_to_keep=10)
        mgr.save(1, {"w": jnp.arange(4.0)})
        # simulate the torn write: a tmp dir with a partial payload
        orphan = os.path.join(tmp, ".tmp_step_99")
        os.makedirs(orphan)
        with open(os.path.join(orphan, "arrays.npz"), "wb") as f:
            f.write(b"PARTIAL")
        unaffected = (mgr.latest_step() == 1
                      and mgr.restore()[0] == 1
                      and 99 not in mgr.all_steps())
        mgr.save(2, {"w": jnp.arange(4.0) * 2})   # triggers _gc
        swept = not any(n.startswith(".tmp_step_")
                        for n in os.listdir(tmp))
        ok = unaffected and swept and mgr.restore()[0] == 2
        return [_row("chaos-mid-save-kill", ok, {
            "orphan_visible": not unaffected, "swept": swept,
            "recovered": "full"})]
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def scenario_preempt_resume_bitexact():
    """Simulated preemption (FaultPlan -> Trainer.preempt seam) at an
    arbitrary step, then resume in a fresh Trainer: the combined loss
    trajectory is BIT-IDENTICAL to the uninterrupted run (checkpointed
    full TrainState + step-indexed data replay)."""
    tmp_a, tmp_b = tempfile.mkdtemp(), tempfile.mkdtemp()
    try:
        from repro.reliability import FaultPlan, FaultSpec

        plan = FaultPlan(seed=0, faults=(FaultSpec("preempt", 12),))
        t1, src = _toy_trainer(tmp_a, faults=plan, checkpoint_every=5)
        h1 = t1.fit(src, 30)
        preempted_at = h1[-1].step if h1 else 0

        t2, _ = _toy_trainer(tmp_a, checkpoint_every=5)
        resumed = t2.maybe_resume()
        h2 = t2.fit(src, 30 - t2.step)

        ref, rsrc = _toy_trainer(tmp_b, checkpoint_every=5)
        href = ref.fit(rsrc, 30)

        got = {st.step: st.loss for st in h1 + h2}
        want = {st.step: st.loss for st in href}
        bitexact = (sorted(got) == sorted(want)
                    and all(got[s] == want[s] for s in want))
        ok = resumed and preempted_at == 12 and bitexact
        return [_row("chaos-preempt-resume-bitexact", ok, {
            "preempted_at": int(preempted_at), "resumed": resumed,
            "bitexact": bitexact, "steps": len(got),
            "recovered": "full"})]
    finally:
        shutil.rmtree(tmp_a, ignore_errors=True)
        shutil.rmtree(tmp_b, ignore_errors=True)


# --------------------------------------------------------------- serve toys

_SERVE = {}


def _serve_model():
    """One reduced fp32 falcon-mamba facade shared by every serve
    scenario (compile cost paid once)."""
    if not _SERVE:
        import dataclasses

        import jax
        import jax.numpy as jnp

        from repro.configs import get_reduced
        from repro.models import build_model

        arch = dataclasses.replace(get_reduced("falcon_mamba_7b"),
                                   dtype=jnp.float32)
        model = build_model(arch)
        params = model.init(jax.random.PRNGKey(0))
        _SERVE.update(arch=arch, model=model, params=params)
    return _SERVE["arch"], _SERVE["model"], _SERVE["params"]


def _mk_req(uid, vocab, n_new=6, prompt_len=4, **kw):
    """A deterministic toy request (prompt seeded by uid)."""
    import jax
    import numpy as np

    from repro.serve.engine import Request

    p = np.asarray(jax.random.randint(jax.random.PRNGKey(uid),
                                      (prompt_len,), 0, vocab))
    return Request(uid=uid, prompt=p, max_new_tokens=n_new, **kw)


def _greedy_reference(n_reqs=4, n_new=6):
    """Fault-free greedy token streams — the identity baseline every
    degraded-path scenario must match."""
    arch, model, params = _serve_model()
    from repro.serve.engine import ServeEngine

    eng = ServeEngine(model, params, batch_slots=2, max_seq=48,
                      prefill_chunk=8)
    for i in range(n_reqs):
        eng.submit(_mk_req(i, arch.vocab, n_new))
    fin = eng.run_until_drained()
    return {r.uid: list(r.out_tokens) for r in fin}


def scenario_slot_corruption():
    """NaN'd slot state between ticks: the watchdog quarantines the slot
    (evict -> re-prefill), a quarantine event is logged, every request
    still completes, and the streams are TOKEN-IDENTICAL to the
    fault-free run."""
    arch, model, params = _serve_model()
    from repro.reliability import corrupt_slot
    from repro.serve.engine import ServeEngine

    ref = _greedy_reference()
    eng = ServeEngine(model, params, batch_slots=2, max_seq=48,
                      prefill_chunk=8, watchdog_every=1)
    for i in range(4):
        eng.submit(_mk_req(i, arch.vocab))
    eng.step()                         # admit + first decode tick
    corrupt_slot(eng, 0, mode="nan")   # poison slot 0 mid-stream
    fin = eng.run_until_drained()
    got = {r.uid: list(r.out_tokens) for r in fin if r.status == "done"}
    quar = eng.events.count("slot_quarantine")
    ok = (got == ref and quar >= 1
          and all(r.status == "done" for r in fin))
    return [_row("chaos-slot-corruption", ok, {
        "quarantines": quar, "token_identical": got == ref,
        "completed": len(got), "recovered": "full"})]


def scenario_queue_stall():
    """A wedged admission window (serve_stall FaultPlan): a too-small
    tick budget surfaces as a STRUCTURED EngineStalledError (queued
    count + tick budget attached), and a budget that outlasts the window
    drains normally."""
    arch, model, params = _serve_model()
    from repro.reliability import FaultPlan, FaultSpec
    from repro.serve.engine import EngineStalledError, ServeEngine

    plan = FaultPlan(seed=0, faults=(
        FaultSpec("serve_stall", 1, until=10),))

    def build():
        eng = ServeEngine(model, params, batch_slots=2, max_seq=48,
                          prefill_chunk=8, faults=plan)
        eng.submit(_mk_req(0, arch.vocab))
        return eng

    stalled = None
    eng = build()
    try:
        eng.run_until_drained(max_ticks=5)
    except EngineStalledError as e:
        stalled = {"queued": e.queued, "active": e.active,
                   "ticks": e.ticks}
    events = eng.events.count("admission_stalled")

    eng2 = build()
    fin = eng2.run_until_drained(max_ticks=40)   # outlasts the window
    drained = all(r.status == "done" for r in fin) and len(fin) == 1
    ok = (stalled is not None and stalled["queued"] == 1
          and events >= 1 and drained)
    return [_row("chaos-queue-stall", ok, {
        "stall_report": stalled, "stall_events": events,
        "drained_after_window": drained,
        "recovered": "full (after window)"})]


def scenario_solver_divergence():
    """A tol-mode solve pushed past contractivity (large dt, tiny
    iteration cap): the SolveReport flags diverged=True and the caller
    routes it up as a degradation event; the healthy config's report
    stays clean."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.block import LrcSSMConfig, apply_lrcssm, init_lrcssm
    from repro.core.deer import DeerConfig
    from repro.reliability import EventLog

    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 3))
    good = LrcSSMConfig(d_input=3, d_hidden=8, d_state=8, n_blocks=2,
                        n_classes=2,
                        deer=DeerConfig(max_iters=8, mode="tol", tol=1e-5))
    pg = init_lrcssm(good, jax.random.PRNGKey(0))
    _, rep_g = apply_lrcssm(good, pg, x, return_report=True)

    bad = LrcSSMConfig(d_input=3, d_hidden=8, d_state=8, n_blocks=2,
                       n_classes=2, dt=50.0,
                       deer=DeerConfig(max_iters=2, mode="tol", tol=1e-9))
    pb = init_lrcssm(bad, jax.random.PRNGKey(0))
    _, rep_b = apply_lrcssm(bad, pb, 5.0 * x, return_report=True)

    events = EventLog(log_fn=None)
    if bool(np.any(np.asarray(rep_b.diverged))):
        events.emit("solver_divergence",
                    residual=float(np.max(np.asarray(rep_b.residual))),
                    blocks=int(np.sum(np.asarray(rep_b.diverged))))
    ok = (not bool(np.any(np.asarray(rep_g.diverged)))
          and bool(np.all(np.asarray(rep_b.diverged)))
          and events.count("solver_divergence") == 1)
    return [_row("chaos-solver-divergence", ok, {
        "healthy_residual": float(np.max(np.asarray(rep_g.residual))),
        "diverged_residual": float(np.max(np.asarray(rep_b.residual))),
        "event_logged": events.count("solver_divergence") == 1,
        "recovered": "degraded:reported"})]


def scenario_spec_auto_disable():
    """Forced-low accept rate (floor > 1.0): spec decode disables after
    the window fills, re-enables after cooldown, cycles — and the token
    streams stay identical to plain greedy the whole way."""
    arch, model, params = _serve_model()
    from repro.serve.engine import ServeEngine, SpecConfig

    ref = _greedy_reference(n_reqs=3, n_new=10)
    eng = ServeEngine(model, params, batch_slots=2, max_seq=48,
                      prefill_chunk=8, spec=SpecConfig(k=3),
                      spec_min_accept=1.01, spec_window=2, spec_cooldown=3)
    for i in range(3):
        eng.submit(_mk_req(i, arch.vocab, n_new=10))
    fin = eng.run_until_drained()
    got = {r.uid: list(r.out_tokens) for r in fin}
    dis, ren = (eng.events.count("spec_disable"),
                eng.events.count("spec_reenable"))
    ok = got == ref and dis >= 1 and ren >= 1
    return [_row("chaos-spec-auto-disable", ok, {
        "disables": dis, "reenables": ren,
        "token_identical": got == ref,
        "recovered": "degraded:plain-decode-windows"})]


def scenario_deadline_backpressure():
    """Bounded queue + deadline mix: over-capacity submits reject
    structurally (QueueFullError), zero-budget deadlines expire (queued
    AND active paths), generous deadlines complete — and the whole mix
    drains without hanging."""
    arch, model, params = _serve_model()
    from repro.serve.engine import QueueFullError, ServeEngine

    eng = ServeEngine(model, params, batch_slots=1, max_seq=48,
                      prefill_chunk=8, max_queue=3)
    outcomes = {"rejected": 0}
    for i in range(6):
        dl = 0.0 if i == 1 else (30.0 if i % 2 else None)
        try:
            eng.submit(_mk_req(i, arch.vocab, deadline_s=dl))
        except QueueFullError:
            outcomes["rejected"] += 1
    fin = eng.run_until_drained(max_ticks=200)
    statuses = sorted(r.status for r in fin)
    done = sum(s == "done" for s in statuses)
    expired = sum(s == "expired" for s in statuses)
    ok = (outcomes["rejected"] == 3 and expired >= 1
          and done == len(statuses) - expired
          and eng.events.count("queue_reject") == 3)
    return [_row("chaos-deadline-backpressure", ok, {
        "rejected": outcomes["rejected"], "expired": expired,
        "done": done, "statuses": statuses,
        "recovered": "degraded:shed-load"})]


SCENARIOS = (
    scenario_nan_batch_guard,
    scenario_rollback_consecutive,
    scenario_corrupt_latest_checkpoint,
    scenario_mid_save_kill,
    scenario_preempt_resume_bitexact,
    scenario_slot_corruption,
    scenario_queue_stall,
    scenario_solver_divergence,
    scenario_spec_auto_disable,
    scenario_deadline_backpressure,
)


def main(argv=None) -> int:
    """Run the chaos scenarios; exit 1 when any contract is violated."""
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", default=os.environ.get("CHAOS_JSON_OUT"),
                    metavar="FILE", help="write the JSON report to FILE")
    ap.add_argument("--only", default=None,
                    help="run only scenarios whose name contains SUB")
    args = ap.parse_args(argv)

    import jax

    rows = []
    for scenario in SCENARIOS:
        if args.only and args.only not in scenario.__name__:
            continue
        try:
            new = scenario()
        except Exception as e:   # an unhandled exception IS a failure
            new = [_row(f"chaos-{scenario.__name__}", False,
                        violations=[f"unhandled {type(e).__name__}: {e}"])]
        for row in new:
            rows.append(row)
            status = "OK " if row["ok"] else "FAIL"
            print(f"[{status}] {row['name']}", flush=True)
            for v in row["violations"]:
                print(f"       {v}", flush=True)

    report = {
        "suite": "repro-chaos",
        "ok": all(r["ok"] for r in rows),
        "jax_version": jax.__version__,
        "n_scenarios": len(rows),
        "n_failed": sum(not r["ok"] for r in rows),
        "scenarios": rows,
    }
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2)
        print(f"# wrote {args.json}", file=sys.stderr, flush=True)

    print(f"chaos suite: {report['n_scenarios'] - report['n_failed']}/"
          f"{report['n_scenarios']} scenarios hold "
          f"(jax {jax.__version__})", flush=True)
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())

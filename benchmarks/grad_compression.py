"""Pod-local gradient engine benchmark: explicit-int8 vs gspmd-fp32.

On a forced 8-host-device ("pod", "data") = (2, 4) mesh — the production
multi-pod topology in miniature — measures, for one train step of a reduced
LM, BOTH gradient-reduction modes:

  * ``gspmd-fp32``     — GSPMD owns the DP collective (fp32 all-reduce over
    ("pod", "data") inserted by XLA);
  * ``explicit-fp32``  — the shard_map'd pod-local engine, uncompressed
    (sanity tier: same bytes, ownership inverted);
  * ``explicit-int8``  — pod-local grads, fp32 psum over "data" only, int8
    all-gather (+ fp32 per-block scales) over "pod" with the error-feedback
    residual threaded through TrainState.

Per mode it reports the jitted step wall time AND cross-pod gradient
bytes-on-wire, two ways: the analytic per-device accounting
(``distributed/compression.reduction_wire_bytes``) and the per-op HLO
collective inventory (``repro.contracts.collective_ops_from_hlo``) so the
analytic number is auditable against what XLA actually lowered. The
summary row asserts-by-reporting the acceptance ratio: explicit-int8
moves >= 3x fewer cross-pod gradient bytes than gspmd-fp32 at the
production pod count (P=2: analytic ratio ~3.94x).

Environment knobs (read by the subprocess):
  GRAD_COMPRESSION_TOY=1 — smaller model/batch for the CI bench-smoke job;
  BENCH_JSON_OUT=path    — write rows as a JSON list (the CI workflow
                           uploads this as BENCH_grad_compression.json).

Standalone:  PYTHONPATH=src python benchmarks/grad_compression.py
"""
from __future__ import annotations

import os
import subprocess
import sys

N_DEV = 8
N_POD = 2
STEPS = 5


def _inner() -> None:
    """Runs with XLA_FLAGS already set (subprocess entry)."""
    import dataclasses
    import json
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.config import ShapeConfig, TrainConfig
    from repro.configs import get_reduced
    from repro.distributed import sharding as shd
    from repro.contracts import collective_ops_from_hlo, ring_wire_bytes
    from repro.distributed.compression import (reduction_wire_bytes,
                                               tree_elems)
    from repro.launch.specs import make_batch
    from repro.models import build_model
    from repro.train.state import train_state_init
    from repro.train.step import jit_train_step

    toy = os.environ.get("GRAD_COMPRESSION_TOY") == "1"
    seq, batch_sz = (16, 8) if toy else (64, 32)

    arch = dataclasses.replace(get_reduced("granite_3_8b"),
                               dtype=jnp.float32)
    model = build_model(arch)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(arch, ShapeConfig("s", seq, batch_sz, "train"),
                       jax.random.PRNGKey(1))
    mesh = jax.make_mesh((N_POD, N_DEV // N_POD), ("pod", "data"))
    n_elems = tree_elems(params)

    rows = []

    def measure(name, grad_reduce, comp):
        tcfg = TrainConfig(warmup_steps=0, grad_reduce=grad_reduce,
                           grad_compression=comp)
        with shd.use_mesh(mesh):
            state = train_state_init(params, tcfg, mesh)
            jstep = jit_train_step(model, tcfg, mesh, state, batch,
                                   donate=False)
            compiled = jstep.lower(state, batch).compile()
            state, m = jax.block_until_ready(jstep(state, batch))  # warmup
            ts = []
            for _ in range(STEPS):
                t0 = time.perf_counter()
                state, m = jax.block_until_ready(jstep(state, batch))
                ts.append(time.perf_counter() - t0)
        us = float(np.median(ts) * 1e6)
        wire_mode = ("int8_rsag" if comp == "int8"
                     else "fp32_allreduce")
        wire = reduction_wire_bytes(params, N_POD, wire_mode)
        ops = collective_ops_from_hlo(compiled.as_text())
        # replica-group size tells intra-pod from cross-pod on this mesh:
        # "data"-axis groups have size N_DEV/N_POD (contiguous, never leave
        # the pod); anything else (pod-axis pairs, or the group-of-all-8
        # GSPMD DP all-reduce) crosses the DCN link. Note GSPMD reduce-
        # scatters over "data" first, so ITS cross-pod fp32 collectives are
        # shard-sized but numerous — bytes, not op counts, are comparable.
        intra = N_DEV // N_POD

        cross = [o for o in ops if o["group"] != intra]
        hlo = {
            "cross_pod_f32_bytes": sum(o["bytes"] for o in cross
                                       if o["dtype"] == "f32"),
            "cross_pod_s8_bytes": sum(o["bytes"] for o in cross
                                      if o["dtype"] == "s8"),
            "intra_pod_f32_bytes": sum(o["bytes"] for o in ops
                                       if o["group"] == intra
                                       and o["dtype"] == "f32"),
        }
        # ring wire accounting shared with the contract layer
        # (repro.contracts.ring_wire_bytes — same factors the roofline
        # collective term uses)
        measured = int(sum(ring_wire_bytes(o) for o in cross))
        rows.append({"name": name, "us_per_step": us,
                     "cross_pod_grad_bytes": wire,
                     "cross_pod_wire_measured": measured,
                     "param_elems": n_elems, "n_pod": N_POD,
                     "n_dev": N_DEV, "hlo": hlo})
        print(f"{name},{us:.1f},cross_pod_grad_bytes={wire};"
              f"measured={measured};"
              f"hlo_cross_pod_f32={hlo['cross_pod_f32_bytes']};"
              f"hlo_cross_pod_s8={hlo['cross_pod_s8_bytes']}",
              flush=True)
        return wire, measured

    base, _ = measure("grad_gspmd_fp32", "gspmd", "none")
    _, fp32_measured = measure("grad_explicit_fp32", "explicit", "none")
    comp, int8_measured = measure("grad_explicit_int8", "explicit", "int8")

    # Two ratios, both must clear 3x:
    #  * analytic  — the wire-format accounting (fp32 ring all-reduce vs
    #    int8 all-gather) at this P, a closed-form function of the formats;
    #  * measured  — ring-factored bytes of the cross-pod collectives XLA
    #    ACTUALLY lowered, explicit-fp32 vs explicit-int8 (apples-to-apples
    #    reduction pattern). This one is the regression canary: if the
    #    compressed path ever re-grows an fp32 pod all-reduce, it collapses
    #    regardless of what the analytic formula claims.
    ratio = base / max(comp, 1)
    ratio_measured = fp32_measured / max(int8_measured, 1)
    rows.append({"name": "wire_ratio_fp32_over_int8", "ratio": ratio,
                 "ratio_measured": ratio_measured,
                 "meets_3x": bool(ratio >= 3.0 and ratio_measured >= 3.0)})
    print(f"wire_ratio_fp32_over_int8,{ratio:.2f},"
          f"measured={ratio_measured:.2f};"
          f"meets_3x={ratio >= 3.0 and ratio_measured >= 3.0}",
          flush=True)

    out = os.environ.get("BENCH_JSON_OUT")
    if out:
        with open(out, "w") as f:
            json.dump(rows, f, indent=2)
        print(f"# wrote {out}", file=sys.stderr, flush=True)


def bench_grad_compression() -> None:
    """benchmarks/run.py entry: re-exec with forced host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={N_DEV}"
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-m", "benchmarks.grad_compression",
                        "--inner"],
                       capture_output=True, text=True, timeout=1800, env=env,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    sys.stderr.write(r.stderr)
    if r.returncode != 0:
        raise RuntimeError(f"grad_compression subprocess failed:\n{r.stdout}")
    for line in r.stdout.strip().splitlines():
        print(line, flush=True)


if __name__ == "__main__":
    if "--inner" in sys.argv:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={N_DEV}")
        _inner()
    else:
        bench_grad_compression()

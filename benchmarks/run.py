"""Benchmark harness: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

Every run also APPENDS one JSON line per benchmark to
``BENCH_history.jsonl`` at the repo root (override via
``BENCH_HISTORY_OUT``; empty string disables): ``{ts, git_sha, bench,
wall_s, status}``. The ``BENCH_*.json`` files the individual benchmarks
write are per-commit SNAPSHOTS — overwritten on every run — so without
the history file a regression's onset is unrecoverable once the next run
lands; the append-only log is what trend tooling diffs across commits.

    PYTHONPATH=src python -m benchmarks.run [--only substring] [--quick]
"""
import argparse
import datetime
import json
import os
import subprocess
import sys
import time
import traceback

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=_ROOT,
            capture_output=True, text=True, timeout=10).stdout.strip() or "?"
    except Exception:
        return "?"


def _append_history(path: str, row: dict) -> None:
    try:
        with open(path, "a") as f:
            f.write(json.dumps(row) + "\n")
    except OSError as e:
        print(f"# history append failed: {e}", file=sys.stderr, flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run benchmarks whose name contains this substring")
    args = ap.parse_args()

    history = os.environ.get("BENCH_HISTORY_OUT",
                             os.path.join(_ROOT, "BENCH_history.jsonl"))
    sha = _git_sha()

    from benchmarks import (ablations, grad_compression, kernels,
                            paper_tables, seq_parallel, serve)
    benches = [
        paper_tables.table1_accuracy,
        paper_tables.table2_variants,
        paper_tables.table3_complexity,
        paper_tables.table6_runtime,
        paper_tables.fig2_iterations,
        ablations.table8_capacitance,
        ablations.table9_dense_vs_diagonal,
        ablations.table10_state_dependency,
        ablations.table11_complex_params,
        ablations.kernels_micro,
        kernels.bench_kernels,
        seq_parallel.bench_seq_parallel,
        grad_compression.bench_grad_compression,
        serve.bench_serve,
    ]
    print("name,us_per_call,derived")
    failures = 0
    for fn in benches:
        if args.only and args.only not in fn.__name__:
            continue
        t0 = time.time()
        status = "ok"
        try:
            fn()
        except Exception:
            traceback.print_exc()
            print(f"{fn.__name__},0,FAILED")
            status = "failed"
            failures += 1
        wall = time.time() - t0
        print(f"# {fn.__name__} done in {wall:.1f}s",
              file=sys.stderr, flush=True)
        if history:
            _append_history(history, {
                "ts": datetime.datetime.now(datetime.timezone.utc)
                .isoformat(timespec="seconds"),
                "git_sha": sha,
                "bench": fn.__name__,
                "wall_s": round(wall, 3),
                "status": status,
            })
    sys.exit(1 if failures else 0)


if __name__ == '__main__':
    main()

"""Benchmark harness: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--only substring] [--quick]
"""
import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run benchmarks whose name contains this substring")
    args = ap.parse_args()

    from benchmarks import (ablations, grad_compression, kernels,
                            paper_tables, seq_parallel, serve)
    benches = [
        paper_tables.table1_accuracy,
        paper_tables.table2_variants,
        paper_tables.table3_complexity,
        paper_tables.table6_runtime,
        paper_tables.fig2_iterations,
        ablations.table8_capacitance,
        ablations.table9_dense_vs_diagonal,
        ablations.table10_state_dependency,
        ablations.table11_complex_params,
        ablations.kernels_micro,
        kernels.bench_kernels,
        seq_parallel.bench_seq_parallel,
        grad_compression.bench_grad_compression,
        serve.bench_serve,
    ]
    print("name,us_per_call,derived")
    failures = 0
    for fn in benches:
        if args.only and args.only not in fn.__name__:
            continue
        t0 = time.time()
        try:
            fn()
        except Exception:
            traceback.print_exc()
            print(f"{fn.__name__},0,FAILED")
            failures += 1
        print(f"# {fn.__name__} done in {time.time()-t0:.1f}s",
              file=sys.stderr, flush=True)
    sys.exit(1 if failures else 0)


if __name__ == '__main__':
    main()

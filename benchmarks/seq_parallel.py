"""Sequence-parallel solver benchmark: replicated vs time-sharded solves.

Measures, on a forced 8-host-device mesh (same substrate as the distributed
tests), for the LrcSSM cell, ALL THREE solver-parallelism tiers:

  * ``deer``  — replicated ``deer_solve`` vs ``sharded_deer_solve``;
  * ``elk``   — replicated ``elk_solve`` vs ``sharded_elk_solve`` (the
    trust-region Kalman-smoother path on time shards);
  * ``fused`` — the fused Pallas iteration, replicated ``lrc_deer_solve``
    vs shard-composable ``sharded_lrc_deer_solve`` (interpret mode on CPU,
    so absolute us/call is NOT comparable to the lax tiers — the record is
    the sharded-vs-replicated ratio and the memory columns).

For each: tokens/sec of the jitted solve and per-device peak/temp memory
from ``memory_analysis()`` — the O(T*D) vs O(T/P*D) trajectory-residency
claim, measured rather than asserted.

Because the forced device count must be set before jax initialises, the
``bench_seq_parallel`` entry registered in benchmarks/run.py re-execs this
module in a subprocess (the shared pattern from tests/conftest.py) and
relays its CSV rows.

Environment knobs (read by the subprocess):
  SEQ_PARALLEL_TOY=1   — toy sizes for the CI benchmark-smoke job;
  BENCH_JSON_OUT=path  — ALSO write the rows as a JSON list (the CI
                         workflow uploads this as the BENCH_* artifact so
                         the perf trajectory accumulates per commit).

Standalone:  PYTHONPATH=src python -m benchmarks.seq_parallel --inner
"""
from __future__ import annotations

import os
import subprocess
import sys

N_DEV = 8
T, B, D = 4096, 4, 64
ITERS = 12
TOY_T, TOY_B, TOY_D = 512, 2, 32
TOY_ITERS = 6


def _inner() -> None:
    """Runs with XLA_FLAGS already set (subprocess entry)."""
    import json
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.deer import DeerConfig, deer_solve
    from repro.core.deer_sharded import sharded_deer_solve
    from repro.core.elk import ElkConfig, elk_solve
    from repro.core.elk_sharded import sharded_elk_solve
    from repro.core.lrc import (LrcCellConfig, init_lrc_params,
                                input_features, lrc_step)
    from repro.kernels.lrc_deer.ops import (lrc_deer_solve, pack_lrc_params,
                                            sharded_lrc_deer_solve)

    toy = os.environ.get("SEQ_PARALLEL_TOY") == "1"
    t, b, d = (TOY_T, TOY_B, TOY_D) if toy else (T, B, D)
    iters = TOY_ITERS if toy else ITERS

    mesh = jax.make_mesh((N_DEV,), ("data",))
    cfg = LrcCellConfig(d_input=d, d_state=d)
    p = init_lrc_params(cfg, jax.random.PRNGKey(0))
    u = jax.random.normal(jax.random.PRNGKey(1), (t, b, d))
    s_u, eps_u = input_features(p, u)
    step = lambda x, fs, cp: lrc_step(cp, cfg, x, *fs)
    x0 = jnp.zeros((b, d))
    dc = DeerConfig(max_iters=iters, mode="fixed", grad="unroll")
    ec = ElkConfig(max_iters=iters, mode="fixed")

    # fused tier operates on (T, D) with the batch folded into channels
    su_f = s_u.reshape(t, b * d)
    eu_f = eps_u.reshape(t, b * d)
    pp_f = jnp.tile(pack_lrc_params(p), (1, b))
    x0_f = jnp.zeros((b * d,))

    rows = []

    def measure(name, fn, args):
        with mesh:
            jitted = jax.jit(fn)
            compiled = jitted.lower(*args).compile()
            mem = "mem_na"
            temp_bytes = arg_bytes = None
            try:
                ma = compiled.memory_analysis()
                if ma is not None:
                    temp_bytes = int(ma.temp_size_in_bytes)
                    arg_bytes = int(ma.argument_size_in_bytes)
                    mem = f"temp_bytes={temp_bytes};arg_bytes={arg_bytes}"
            except Exception:
                pass
            jax.block_until_ready(jitted(*args))   # warmup
            ts = []
            for _ in range(3):
                t0 = time.perf_counter()
                jax.block_until_ready(jitted(*args))
                ts.append(time.perf_counter() - t0)
        us = float(np.median(ts) * 1e6)
        tok_s = t * b / (us * 1e-6)
        rows.append({"name": name, "us_per_call": us, "tokens_per_s": tok_s,
                     "temp_bytes": temp_bytes, "arg_bytes": arg_bytes,
                     "T": t, "B": b, "D": d, "iters": iters,
                     "n_dev": N_DEV})
        print(f"{name},{us:.1f},tokens_per_s={tok_s:.0f};{mem}", flush=True)

    lax_args = (s_u, eps_u, p)
    measure(f"deer_replicated_T{t}",
            lambda su, eu, pp: deer_solve(step, (su, eu), x0, t, dc,
                                          params=pp)[0], lax_args)
    measure(f"deer_seq_sharded_T{t}_P{N_DEV}",
            lambda su, eu, pp: sharded_deer_solve(
                step, (su, eu), x0, t, dc, mesh=mesh, seq_axis="data",
                params=pp)[0], lax_args)
    measure(f"elk_replicated_T{t}",
            lambda su, eu, pp: elk_solve(step, (su, eu), x0, t, ec,
                                         params=pp)[0], lax_args)
    measure(f"elk_seq_sharded_T{t}_P{N_DEV}",
            lambda su, eu, pp: sharded_elk_solve(
                step, (su, eu), x0, t, ec, mesh=mesh, seq_axis="data",
                params=pp)[0], lax_args)

    fused_args = (su_f, eu_f, pp_f, x0_f)
    chunk = min(256, t // N_DEV)
    measure(f"fused_iter_replicated_T{t}",
            lambda su, eu, pp, x_: lrc_deer_solve(
                su, eu, pp, x_, n_iters=iters, chunk=chunk,
                megakernel=False), fused_args)
    measure(f"fused_mega_replicated_T{t}",
            lambda su, eu, pp, x_: lrc_deer_solve(
                su, eu, pp, x_, n_iters=iters, chunk=chunk,
                megakernel=True), fused_args)
    measure(f"fused_seq_sharded_T{t}_P{N_DEV}",
            lambda su, eu, pp, x_: sharded_lrc_deer_solve(
                su, eu, pp, x_, mesh=mesh, seq_axis="data", n_iters=iters,
                chunk=chunk), fused_args)

    out = os.environ.get("BENCH_JSON_OUT")
    if out:
        with open(out, "w") as f:
            json.dump(rows, f, indent=2)
        print(f"# wrote {out}", file=sys.stderr, flush=True)


def bench_seq_parallel() -> None:
    """benchmarks/run.py entry: re-exec with forced host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={N_DEV}"
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-m", "benchmarks.seq_parallel",
                        "--inner"],
                       capture_output=True, text=True, timeout=1800, env=env,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    sys.stderr.write(r.stderr)
    if r.returncode != 0:
        raise RuntimeError(f"seq_parallel subprocess failed:\n{r.stdout}")
    for line in r.stdout.strip().splitlines():
        print(line, flush=True)


if __name__ == "__main__":
    if "--inner" in sys.argv:
        # unconditional: a pre-set XLA_FLAGS (e.g. a leaked debug flag)
        # would otherwise leave device_count at 1 and break make_mesh
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={N_DEV}")
        _inner()
    else:
        bench_seq_parallel()

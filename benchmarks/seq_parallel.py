"""Sequence-parallel DEER benchmark: replicated vs time-sharded Newton solve.

Measures, on a forced 8-host-device mesh (same substrate as the distributed
tests), for the LrcSSM cell:

  * tokens/sec of the jitted solve (replicated ``deer_solve`` vs
    ``sharded_deer_solve`` with the trajectory sharded over the mesh);
  * per-device peak/temp memory from the compiled executable's
    ``memory_analysis()`` — the O(T*D) vs O(T/P*D) trajectory-residency
    claim, measured rather than asserted.

Because the forced device count must be set before jax initialises, the
``bench_seq_parallel`` entry registered in benchmarks/run.py re-execs this
module in a subprocess (the shared pattern from tests/conftest.py) and
relays its CSV rows.

Standalone:  PYTHONPATH=src python -m benchmarks.seq_parallel --inner
"""
from __future__ import annotations

import os
import subprocess
import sys

N_DEV = 8
T, B, D = 4096, 4, 64
ITERS = 12


def _inner() -> None:
    """Runs with XLA_FLAGS already set (subprocess entry)."""
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.deer import DeerConfig, deer_solve
    from repro.core.deer_sharded import sharded_deer_solve
    from repro.core.lrc import (LrcCellConfig, init_lrc_params,
                                input_features, lrc_step)

    mesh = jax.make_mesh((N_DEV,), ("data",))
    cfg = LrcCellConfig(d_input=D, d_state=D)
    p = init_lrc_params(cfg, jax.random.PRNGKey(0))
    u = jax.random.normal(jax.random.PRNGKey(1), (T, B, D))
    s_u, eps_u = input_features(p, u)
    step = lambda x, fs, cp: lrc_step(cp, cfg, x, *fs)
    x0 = jnp.zeros((B, D))
    dc = DeerConfig(max_iters=ITERS, mode="fixed", grad="unroll")

    def replicated(su, eu, pp):
        return deer_solve(step, (su, eu), x0, T, dc, params=pp)[0]

    def sharded(su, eu, pp):
        return sharded_deer_solve(step, (su, eu), x0, T, dc, mesh=mesh,
                                  seq_axis="data", params=pp)[0]

    def measure(name, fn):
        with mesh:
            jitted = jax.jit(fn)
            lowered = jitted.lower(s_u, eps_u, p)
            compiled = lowered.compile()
            mem = "mem_na"
            try:
                ma = compiled.memory_analysis()
                if ma is not None:
                    mem = (f"temp_bytes={int(ma.temp_size_in_bytes)}"
                           f";arg_bytes={int(ma.argument_size_in_bytes)}")
            except Exception:
                pass
            jax.block_until_ready(jitted(s_u, eps_u, p))   # warmup
            ts = []
            for _ in range(3):
                t0 = time.perf_counter()
                jax.block_until_ready(jitted(s_u, eps_u, p))
                ts.append(time.perf_counter() - t0)
        us = float(np.median(ts) * 1e6)
        tok_s = T * B / (us * 1e-6)
        print(f"{name},{us:.1f},tokens_per_s={tok_s:.0f};{mem}", flush=True)

    measure(f"deer_replicated_T{T}", replicated)
    measure(f"deer_seq_sharded_T{T}_P{N_DEV}", sharded)


def bench_seq_parallel() -> None:
    """benchmarks/run.py entry: re-exec with forced host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={N_DEV}"
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-m", "benchmarks.seq_parallel",
                        "--inner"],
                       capture_output=True, text=True, timeout=1800, env=env,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    sys.stderr.write(r.stderr)
    if r.returncode != 0:
        raise RuntimeError(f"seq_parallel subprocess failed:\n{r.stdout}")
    for line in r.stdout.strip().splitlines():
        print(line, flush=True)


if __name__ == "__main__":
    if "--inner" in sys.argv:
        # unconditional: a pre-set XLA_FLAGS (e.g. a leaked debug flag)
        # would otherwise leave device_count at 1 and break make_mesh
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={N_DEV}")
        _inner()
    else:
        bench_seq_parallel()

"""Appendix E ablation benchmarks (Tables 8-11) + kernel micro-benchmarks."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._util import (emit, time_fn, train_classifier,
                              train_classifier_grid)
from repro.configs.lrcssm_uea import ablation_config
from repro.core.block import LrcSSMConfig
from repro.core.full_lrc import (FullLrcConfig, full_lrc_sequential,
                                 init_full_lrc_params, quasi_deer_solve)

DS, T, STEPS, BATCH = "scp1", 512, 90, 16


def _acc(cfg, seed=2, **kw):
    # all ablation cells are lrc-family: the tuned regime is lr=1e-2
    return train_classifier_grid(cfg, DS, seq_len=T, steps=STEPS,
                                 batch=BATCH, seed=seed, lrs=(1e-2,),
                                 **kw)[0]


def table8_capacitance():
    """Table 8: liquid (LrcSSM) vs constant capacitance (StcSSM)."""
    t0 = time.perf_counter()
    acc_lrc = _acc(ablation_config("lrc", d_input=6, n_classes=2,
                                   d_hidden=32, d_state=32, n_blocks=2))
    acc_stc = _acc(ablation_config("stc", d_input=6, n_classes=2,
                                   d_hidden=32, d_state=32, n_blocks=2))
    emit("table8/capacitance", (time.perf_counter() - t0) * 1e6,
         f"lrc_acc={acc_lrc:.3f};stc_acc={acc_stc:.3f}")


def table9_dense_vs_diagonal():
    """Table 9: diagonal-by-design Jacobian loses nothing vs the dense
    LRC solved with quasi-DEER. Checked at solver level (trajectory parity
    with sequential ground truth) + accuracy level (diag model trains)."""
    D, n = 16, 6
    fcfg = FullLrcConfig(d_input=n, d_state=D)
    fp = init_full_lrc_params(fcfg, jax.random.PRNGKey(0))
    u = jax.random.normal(jax.random.PRNGKey(1), (256, n))
    truth = full_lrc_sequential(fp, fcfg, u)
    t0 = time.perf_counter()
    states, iters = jax.jit(lambda uu: quasi_deer_solve(fp, fcfg, uu,
                                                        max_iters=50))(u)
    us = (time.perf_counter() - t0) * 1e6
    err = float(jnp.max(jnp.abs(states - truth)))
    emit("table9/quasi_deer_dense", us,
         f"newton_iters={int(iters)};traj_err={err:.2e};converged={err < 1e-3}")

    acc_diag = _acc(ablation_config("lrc", d_input=6, n_classes=2,
                                    d_hidden=32, d_state=32, n_blocks=2))
    emit("table9/diag_model_acc", 0.0, f"diag_acc={acc_diag:.3f}")


def table10_state_dependency():
    """Table 10: A(x,u)/b(x,u) vs A(u)/b(x,u) vs A(u)/b(u)."""
    t0 = time.perf_counter()
    rows = {}
    for name, (sa, sb) in {"AxU_bxU": (True, True),
                           "AU_bxU": (False, True),
                           "AU_bU": (False, False)}.items():
        cfg = ablation_config("lrc", d_input=6, n_classes=2, d_hidden=32,
                              d_state=32, n_blocks=2,
                              state_dependent_a=sa, state_dependent_b=sb)
        rows[name] = _acc(cfg)
    emit("table10/state_dependency", (time.perf_counter() - t0) * 1e6,
         ";".join(f"{k}={v:.3f}" for k, v in rows.items()))


def table11_complex_params():
    """Table 11: real vs complex state-coupled parameters."""
    t0 = time.perf_counter()
    acc_real = _acc(ablation_config("lrc", d_input=6, n_classes=2,
                                    d_hidden=32, d_state=32, n_blocks=2))
    acc_cplx = _acc(ablation_config("lrc", d_input=6, n_classes=2,
                                    d_hidden=32, d_state=32, n_blocks=2,
                                    complex_state_params=True))
    emit("table11/complex", (time.perf_counter() - t0) * 1e6,
         f"real_acc={acc_real:.3f};complex_acc={acc_cplx:.3f}")


def kernels_micro():
    """Pallas kernels (interpret mode) vs pure-jnp reference: correctness
    and CPU-interpret timing (TPU timing is a dry-run target, not runnable
    here — the HBM-traffic derivation is in EXPERIMENTS.md §Perf)."""
    from repro.kernels.diag_scan.ops import diag_scan
    from repro.kernels.diag_scan.ref import diag_scan_ref
    from repro.kernels.lrc_deer.ops import lrc_deer_solve
    from repro.kernels.lrc_deer.ref import lrc_deer_solve_ref

    T, D = 1024, 256
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    lam = jax.random.uniform(ks[0], (T, D)) * 0.9
    b = jax.random.normal(ks[1], (T, D))
    x0 = jnp.zeros((D,))
    us_k = time_fn(lambda: diag_scan(lam, b, x0, chunk=256, d_tile=128))
    want = diag_scan_ref(lam, b, x0)
    got = diag_scan(lam, b, x0, chunk=256, d_tile=128)
    err = float(jnp.max(jnp.abs(got - want)))
    emit("kernels/diag_scan_1024x256", us_k,
         f"max_err={err:.2e};hbm_streams=3(read)+1(write)")

    su = jax.nn.sigmoid(jax.random.normal(ks[2], (T, D)))
    eu = jax.random.normal(ks[0], (T, D))
    from repro.kernels.lrc_deer.ops import pack_lrc_params
    from repro.core.lrc import LrcCellConfig, init_lrc_params
    pp = pack_lrc_params(init_lrc_params(
        LrcCellConfig(d_input=4, d_state=D), jax.random.PRNGKey(1)))
    us_f = time_fn(lambda: lrc_deer_solve(su, eu, pp, x0, n_iters=8,
                                          chunk=256, d_tile=128), iters=2)
    got = lrc_deer_solve(su, eu, pp, x0, n_iters=8, chunk=256, d_tile=128)
    want = lrc_deer_solve_ref(su, eu, pp, x0, n_iters=8)
    err = float(jnp.max(jnp.abs(got - want)))
    emit("kernels/lrc_deer_fused_8iter", us_f,
         f"max_err={err:.2e};hbm_per_iter=3reads+1write_vs_10_unfused")

"""Kernel-tier benchmark: lax Newton baseline vs per-iteration fused kernel
vs whole-Newton megakernel, at the acceptance shape T=16384, K=8.

Records, per solver implementation:

  * wall-clock (median of 3 jitted calls) and tokens/s — on CPU CI hosts
    the Pallas kernels run in INTERPRET mode, so absolute kernel numbers
    are not comparable to the compiled lax baseline; the cross-kernel
    ratio is still indicative, and the authoritative CI-host metric is
  * the HBM stream accounting from the roofline model
    (``kernels.autotune.solver_hbm_streams``): how many (T, D)-sized HBM
    streams one K-iteration solve moves.  The megakernel's whole point is
    collapsing K x (4..6) streams to ~3 — this ratio is hardware-
    independent and is what the wall-clock win on a real TPU tracks.  The
    ``megakernel_bf16`` row narrows those streams to 2 bytes/element
    (``io_dtype="bf16"``, fp32 VMEM accumulation) and records the
    bytes-weighted roofline ratio (``solver_hbm_bytes``) plus its parity
    error vs the fp32 megakernel;
  * the early-exit iteration histogram: from the megakernel's in-kernel
    per-channel residual reduction, at which Newton iteration each channel
    of the solve converged below tol (plus the ``tol``-mode effective
    n_iters a while_loop would have run).

Output: ``BENCH_kernels.json`` at the repo root (override via
``BENCH_JSON_OUT``), uploaded as a CI artifact by the bench-smoke job.
``meets_bar`` requires megakernel >= 1.5x per-iteration wall-clock OR
>= 2.5x fewer HBM streams (the interpret-only CI criterion).

    PYTHONPATH=src python benchmarks/kernels.py        # standalone
    KERNELS_BENCH_TOY=1 ...                            # small shape
"""
from __future__ import annotations

import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

T, D, K = 16384, 256, 8
CHUNK, D_TILE = 512, 256
TOY_T, TOY_D = 1024, 128
TOL = 1e-6


def _rand_problem(t, d):
    from repro.kernels.lrc_deer.ops import PACK_ORDER
    ks = jax.random.split(jax.random.PRNGKey(0), len(PACK_ORDER) + 2)
    rows = []
    for i, name in enumerate(PACK_ORDER):
        if name == "g_leak":
            rows.append(jnp.full((d,), 0.1))
        elif name == "e_leak":
            rows.append(jnp.ones((d,)))
        elif name.startswith(("b_", "v_")):
            rows.append(jnp.zeros((d,)))
        else:
            rows.append(jax.random.normal(ks[i], (d,)) * 0.5)
    pp = jnp.stack(rows)
    su = jax.nn.sigmoid(jax.random.normal(ks[-2], (t, d)))
    eu = jax.random.normal(ks[-1], (t, d))
    return su, eu, pp, jnp.zeros((d,))


def _time(fn, args):
    jitted = jax.jit(fn)
    jax.block_until_ready(jitted(*args))   # compile + warm
    ts = []
    for _ in range(3):
        t0 = time.perf_counter()
        jax.block_until_ready(jitted(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def bench_kernels() -> None:
    """benchmarks/run.py entry: CSV rows + the BENCH_kernels.json artifact."""
    from repro.contracts import check_stream_budget
    from repro.core.deer import DeerConfig, deer_solve
    from repro.kernels.autotune import solver_hbm_streams
    from repro.kernels.lrc_deer.kernel import lrc_deer_megakernel_pallas
    from repro.kernels.lrc_deer.ops import (lrc_deer_solve,
                                            tol_iteration_count)
    from repro.kernels.lrc_deer.ref import _step

    toy = os.environ.get("KERNELS_BENCH_TOY") == "1"
    t, d = (TOY_T, TOY_D) if toy else (T, D)
    chunk = min(CHUNK, t)
    d_tile = min(D_TILE, d)
    interp = jax.default_backend() != "tpu"
    su, eu, pp, x0 = _rand_problem(t, d)
    args = (su, eu, pp, x0)
    rows = []

    def record(name, us, streams, err):
        tok_s = t / (us * 1e-6)
        rows.append({"name": name, "us_per_call": us, "tokens_per_s": tok_s,
                     "hbm_td_streams": streams, "max_err_vs_lax": err,
                     "T": t, "D": d, "iters": K, "interpret": interp})
        print(f"{name},{us:.1f},tokens_per_s={tok_s:.0f};"
              f"hbm_td_streams={streams:.0f};max_err={err:.2e}", flush=True)

    # lax baseline: the generic unfused Newton solve (jvp + assoc. scan)
    step = lambda x, fs, cp: _step(cp, x, fs[0], fs[1], 1.0)
    dc = DeerConfig(max_iters=K, mode="fixed", grad="unroll")
    lax_fn = lambda a, b, c, e: deer_solve(step, (a, b), e, t, dc,
                                           params=c)[0]
    lax_us = _time(lax_fn, args)
    want = lax_fn(*args)
    record(f"lax_deer_T{t}_K{K}", lax_us, solver_hbm_streams(K, "lax"), 0.0)

    # per-iteration fused kernel (the pre-megakernel path)
    iter_fn = lambda a, b, c, e: lrc_deer_solve(
        a, b, c, e, n_iters=K, chunk=chunk, d_tile=d_tile,
        megakernel=False, interpret=interp)
    iter_us = _time(iter_fn, args)
    err_i = float(jnp.max(jnp.abs(iter_fn(*args) - want)))
    record(f"fused_iter_T{t}_K{K}", iter_us,
           solver_hbm_streams(K, "fused_iter"), err_i)

    # whole-Newton megakernel
    mega_fn = lambda a, b, c, e: lrc_deer_solve(
        a, b, c, e, n_iters=K, chunk=chunk, d_tile=d_tile,
        megakernel=True, interpret=interp)
    mega_us = _time(mega_fn, args)
    got_m = mega_fn(*args)
    err_m = float(jnp.max(jnp.abs(got_m - want)))
    record(f"megakernel_T{t}_K{K}", mega_us,
           solver_hbm_streams(K, "mega"), err_m)

    # bf16 HBM streams: the same whole-Newton megakernel with
    # io_dtype="bf16" — inputs/outputs cross HBM at 2 bytes/element while
    # every VMEM accumulation stays fp32 (the PrecisionPolicy kernel_io
    # leg). The roofline criterion gains a BYTES dimension on top of the
    # stream-count one: solver_hbm_bytes weighs each (T, D) stream by its
    # element width, so bf16 mega vs f32 per-iteration is (streams ratio)
    # x (4/2) — schedule win and wire-width win compound.
    from repro.kernels.autotune import solver_hbm_bytes
    mega16_fn = lambda a, b, c, e: lrc_deer_solve(
        a, b, c, e, n_iters=K, chunk=chunk, d_tile=d_tile,
        megakernel=True, interpret=interp, io_dtype="bf16")
    mega16_us = _time(mega16_fn, args)
    got_16 = mega16_fn(*args)
    err_16 = float(jnp.max(jnp.abs(got_16 - want)))
    record(f"megakernel_bf16_T{t}_K{K}", mega16_us,
           solver_hbm_streams(K, "mega"), err_16)
    stream_bytes_ratio = (solver_hbm_bytes(K, "fused_iter", 4)
                          / solver_hbm_bytes(K, "mega", 2))
    rows[-1].update({
        "io_dtype": "bf16", "io_bytes_per_elem": 2,
        "stream_bytes_ratio_vs_fused_iter_f32": stream_bytes_ratio,
        "max_err_vs_f32_mega": float(jnp.max(jnp.abs(got_16 - got_m)))})

    # early-exit accounting from the in-kernel residual reduction
    _, resid = lrc_deer_megakernel_pallas(su, eu, pp, x0, n_iters=K,
                                          chunk=chunk, d_tile=d_tile,
                                          interpret=interp)
    resid = np.asarray(resid[:, :d])               # (K, D) per channel
    conv = resid <= TOL
    first = np.where(conv.any(axis=0), 1 + conv.argmax(axis=0), K + 1)
    hist = {f"iter_{k}": int((first == k).sum()) for k in range(1, K + 1)}
    hist["not_converged"] = int((first == K + 1).sum())
    n_iters_tol = int(tol_iteration_count(
        jnp.asarray(resid.max(axis=1)), TOL, K))

    wall_ratio = iter_us / mega_us
    # stream accounting through the declarative contract layer: the
    # megakernel must move >= 2.5x fewer (T,D) HBM streams than the
    # per-iteration kernel (repro.contracts.check_stream_budget — the
    # clause the CI contract suite also evaluates)
    stream_contract = check_stream_budget(K, "mega", baseline="fused_iter",
                                          min_ratio=2.5)
    stream_ratio = (solver_hbm_streams(K, "fused_iter")
                    / solver_hbm_streams(K, "mega"))
    out = {
        "rows": rows,
        "wall_ratio_mega_vs_iter": wall_ratio,
        # NOTE the stream ratio comes from the ANALYTIC roofline model of
        # the kernel schedules (solver_hbm_streams), not a measurement —
        # it is the criterion interpret-only CI hosts are allowed to meet,
        # and it moves only when the schedule itself changes.  Wall-clock
        # is the measured signal: watch wall_ratio_mega_vs_iter per
        # backend for regressions (interpret-mode wall-clock is dominated
        # by the per-grid-step interpreter overhead, so ~1x is expected on
        # CPU; the roofline win shows up compiled on TPU).
        "hbm_stream_ratio_mega_vs_iter": stream_ratio,
        # bytes-weighted variant: bf16 streams halve the per-element width
        # on top of the schedule's stream-count collapse (analytic, like
        # the stream ratio — solver_hbm_bytes = streams x bytes/elem)
        "hbm_stream_bytes_ratio_mega_bf16_vs_iter_f32": stream_bytes_ratio,
        "stream_ratio_is_analytic": True,
        "stream_contract_violations": [v.to_json()
                                       for v in stream_contract.violations],
        # honest wall-clock row: the 1.5x bar is only ENFORCED off
        # interpret — interpret-mode wall-clock measures the Pallas
        # interpreter's per-grid-step overhead, not the kernel schedule,
        # so asserting it there would gate CI on noise. `ok` is None
        # (not-applicable) on interpret hosts; backend/interpret record
        # WHERE the number was measured so a reader can tell a TPU
        # regression from a CPU artefact.
        "meets_1p5x_wall": {
            "wall_ratio": wall_ratio,
            "backend": jax.default_backend(),
            "interpret": interp,
            "enforced": not interp,
            "ok": (wall_ratio >= 1.5) if not interp else None,
        },
        "meets_2p5x_streams": stream_contract.ok,
        # the stream criterion only substitutes for wall-clock on
        # interpret-mode hosts (the acceptance wording); on a compiled
        # backend the bar is the MEASURED 1.5x, so a TPU regression that
        # leaves the analytic schedule untouched still fails the gate
        "meets_bar": (wall_ratio >= 1.5 if not interp
                      else wall_ratio >= 1.5 or stream_ratio >= 2.5),
        "tol": TOL,
        "tol_mode_n_iters": n_iters_tol,
        "early_exit_channel_histogram": hist,
        "resid_max_per_iter": [float(r) for r in resid.max(axis=1)],
        "backend": jax.default_backend(),
    }
    print(f"kernels/summary,0,wall_ratio={wall_ratio:.2f};"
          f"stream_ratio={stream_ratio:.1f};meets_bar={out['meets_bar']};"
          f"tol_iters={n_iters_tol}", flush=True)

    path = os.environ.get("BENCH_JSON_OUT")
    if not path:
        path = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "BENCH_kernels.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"# wrote {path}", file=sys.stderr, flush=True)


if __name__ == "__main__":
    bench_kernels()

"""Shared benchmark utilities: timing, CSV emission, a compact classifier
trainer for the UEA-style tables."""
from __future__ import annotations

import time
from typing import Callable, Dict, Iterable, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import TrainConfig
from repro.core.block import LrcSSMConfig, apply_lrcssm, init_lrcssm
from repro.data.pipeline import UEALikeSource
from repro.optim.adamw import adamw_init, adamw_update

ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def time_fn(fn: Callable, *args, iters: int = 3, warmup: int = 1) -> float:
    """Median wall-time per call in microseconds (blocks on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def train_classifier_grid(cfg, dataset: str, *, seq_len: int, steps: int,
                          batch: int, lrs=(1e-3, 1e-2), seed: int = 0
                          ) -> Tuple[float, Dict]:
    """The paper's protocol in miniature: grid-search the learning rate,
    report the best (LrcSSM 'benefits from higher learning rates' — B.2)."""
    best = (0.0, {})
    for lr in lrs:
        acc, info = train_classifier(cfg, dataset, seq_len=seq_len,
                                     steps=steps, batch=batch, lr=lr,
                                     seed=seed)
        info["lr"] = lr
        if acc >= best[0]:
            best = (acc, info)
    return best


def train_classifier(cfg: LrcSSMConfig, dataset: str, *, seq_len: int,
                     steps: int = 150, batch: int = 16, lr: float = 1e-3,
                     seed: int = 0, noise: float = 1.0
                     ) -> Tuple[float, Dict]:
    """Train the Figure-1 classifier on the UEA-like generator; return test
    accuracy. Deliberately small budgets — the benchmark contrasts MODEL
    VARIANTS under identical conditions (the paper's ablation protocol),
    not absolute UEA numbers (real datasets are not available offline)."""
    src = UEALikeSource(dataset, batch=batch, seed=seed, seq_len=seq_len,
                        noise=noise)
    params = init_lrcssm(cfg, jax.random.PRNGKey(seed))
    tcfg = TrainConfig(learning_rate=lr, warmup_steps=10, total_steps=steps,
                       weight_decay=0.01, grad_clip=1.0)
    opt = adamw_init(params)

    def loss_fn(p, x, y):
        logits = apply_lrcssm(cfg, p, x)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
        return jnp.mean(logz - gold)

    @jax.jit
    def step_fn(p, o, x, y):
        l, g = jax.value_and_grad(loss_fn)(p, x, y)
        p, o, _ = adamw_update(tcfg, g, o, p)
        return p, o, l

    t0 = time.perf_counter()
    for s in range(steps):
        x, y = src.batch_at(s)
        params, opt, l = step_fn(params, opt, x, y)
    train_time = time.perf_counter() - t0

    # deterministic held-out split
    correct = tot = 0
    for s in range(4):
        x, y = src.batch_at(10_000 + s)
        logits = apply_lrcssm(cfg, params, x)
        correct += int(jnp.sum(jnp.argmax(logits, -1) == y))
        tot += len(y)
    return correct / tot, {"train_time_s": train_time, "final_loss": float(l)}

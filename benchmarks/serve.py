"""Serving-engine benchmark: continuous batching vs one-request-at-a-time.

Two engine configurations over the SAME request trace (reduced ssm LM —
the O(D)-state family the serving story is about):

  * ``serve_one_at_a_time`` — 1 slot: every request prefilled, decoded to
    completion, then the next (the baseline a naive server implements);
  * ``serve_continuous``    — 8 slots: admission interleaves with batched
    decode ticks, finished slots recycle immediately.

Per row: tokens/s over generated tokens, p50/p99 per-token decode latency,
p50 admission (prefill) latency. The ``speedup`` row records the
continuous/one-at-a-time tokens/s ratio and the ``meets_2x`` flag (the PR-4
acceptance bar). The ``serve_quantized_cache_{int8,fp8}`` rows run the
end-to-end quantized engine (``PrecisionPolicy`` presets: int8/fp8 weights
+ state cache + narrowed kernel streams) and record the resident
slot-state capacity ratio vs fp32 — the fp8 row carries the ``meets_4x``
acceptance flag (a plain 1-byte cast is exactly 4x; int8 pays f32 block
scales on top). The ``degraded_mode`` row replays a deadline-mixed trace
under injected NaN slot faults with the watchdog on: completed streams
must stay token-identical to the healthy run, and the throughput ratio
is recorded with a ``stays_above_floor`` (>= 0.3x healthy) flag. A
further ``prefill_parallel`` row asserts — at the jaxpr
level, via ``repro.contracts.check_lowering`` — that chunk prefill
contains NO length-T sequential scan (the parallel-solver-lowering
acceptance check) and records the loop lengths it does contain.

Environment knobs:
  SERVE_TOY=1          — smaller trace for the CI bench-smoke job;
  BENCH_JSON_OUT=path  — also write rows as JSON (uploaded as the
                         BENCH_serve.json artifact per commit).

Standalone:  PYTHONPATH=src python benchmarks/serve.py
"""
from __future__ import annotations

import json
import os
import sys
import time

# decode-heavy trace: serving is decode-dominated (prompts amortize through
# one parallel prefill; every generated token is a tick), so max_new >
# prompt_len is the regime the slot-batching claim is about
N_REQUESTS, PROMPT_LEN, MAX_NEW, SLOTS, CHUNK = 16, 32, 64, 8, 16
TOY = (8, 8, 32, 8, 8)


def _run_engine(model, params, slots, max_seq, chunk, reqs_spec,
                spec=None, precision=None):
    """Serve one request trace; returns (tokens/s, latency percentiles,
    tokens, wall, engine) — the engine gives callers ``spec_stats``."""
    import numpy as np

    from repro.serve.engine import Request, ServeEngine

    engine = ServeEngine(model, params, batch_slots=slots, max_seq=max_seq,
                         prefill_chunk=chunk, spec=spec,
                         precision=precision)
    # warmup: replay the WHOLE trace once outside the measured window so
    # every compile shape (admission group widths included) is covered —
    # the measured run is pure steady-state
    warm = [Request(uid=-1 - i, prompt=p.copy(), max_new_tokens=n)
            for i, (p, n) in enumerate(reqs_spec)]
    for r in warm:
        engine.submit(r)
    engine.run_until_drained()
    engine.token_lat = {"prefill": [], "decode": []}
    engine.finished = []
    engine.spec_stats = {k: 0 for k in engine.spec_stats}

    reqs = [Request(uid=i, prompt=p.copy(), max_new_tokens=n)
            for i, (p, n) in enumerate(reqs_spec)]
    for r in reqs:
        engine.submit(r)
    t0 = time.perf_counter()
    engine.run_until_drained()
    wall = time.perf_counter() - t0
    assert all(r.done for r in reqs)
    toks = sum(len(r.out_tokens) for r in reqs)
    return toks / wall, engine.latency_percentiles(), toks, wall, engine


def main() -> None:
    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_reduced
    from repro.contracts import check_lowering
    from repro.models import build_model

    toy = os.environ.get("SERVE_TOY") == "1"
    n_req, p_len, max_new, slots, chunk = TOY if toy else (
        N_REQUESTS, PROMPT_LEN, MAX_NEW, SLOTS, CHUNK)
    max_seq = p_len + max_new + chunk

    arch = get_reduced("falcon_mamba_7b")
    model = build_model(arch)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs_spec = [(rng.integers(0, arch.vocab, size=p_len).astype(np.int32),
                  max_new) for _ in range(n_req)]

    rows = []

    def record(name, tok_s, lat, toks, wall):
        rows.append({"name": name, "tokens_per_s": tok_s,
                     "decode_p50_ms": lat.get("decode_p50_s", 0) * 1e3,
                     "decode_p99_ms": lat.get("decode_p99_s", 0) * 1e3,
                     "prefill_p50_ms": lat.get("prefill_p50_s", 0) * 1e3,
                     "n_requests": n_req, "prompt_len": p_len,
                     "max_new": max_new, "tokens": toks, "wall_s": wall})
        print(f"{name},{wall*1e6:.1f},tokens_per_s={tok_s:.1f};"
              f"p50_ms={rows[-1]['decode_p50_ms']:.2f};"
              f"p99_ms={rows[-1]['decode_p99_ms']:.2f}", flush=True)

    tok_s_1, lat_1, toks, wall, _ = _run_engine(
        model, params, 1, max_seq, chunk, reqs_spec)
    record("serve_one_at_a_time", tok_s_1, lat_1, toks, wall)
    tok_s_c, lat_c, toks, wall, _ = _run_engine(
        model, params, slots, max_seq, chunk, reqs_spec)
    record(f"serve_continuous_slots{slots}", tok_s_c, lat_c, toks, wall)

    speedup = tok_s_c / tok_s_1
    rows.append({"name": "speedup", "continuous_over_serial": speedup,
                 "meets_2x": bool(speedup >= 2.0), "slots": slots})
    print(f"speedup,0,continuous_over_serial={speedup:.2f};"
          f"meets_2x={speedup >= 2.0}", flush=True)

    # ---- speculative vs plain (the DEER verify seam) --------------------
    # The speculative rows run the LRC mixer variant: its decode tick is a
    # sequential single-cell step, while the verify window is ONE parallel
    # DEER Newton solve over k tokens — the seam the speculative decode
    # parallelises. The "solve" draft runs the truncated-Newton early-exit
    # forward (draft_iters << deer_iters), so drafts are genuinely cheap.
    from repro.config import SSMConfig
    from repro.serve.engine import SpecConfig

    spec_k = 4
    arch_lrc = dataclasses.replace(
        arch, ssm=SSMConfig(kind="lrc", expand=2, deer_iters=8, chunk=0,
                            draft_iters=2))
    model_l = build_model(arch_lrc)
    params_l = model_l.init(jax.random.PRNGKey(0))
    tok_s_p, lat_p, toks, wall, _ = _run_engine(
        model_l, params_l, slots, max_seq, chunk, reqs_spec)
    record("serve_plain_lrc", tok_s_p, lat_p, toks, wall)
    tok_s_s, lat_s, toks, wall, eng_s = _run_engine(
        model_l, params_l, slots, max_seq, chunk, reqs_spec,
        spec=SpecConfig(k=spec_k, draft="solve", draft_iters=2))
    ss = eng_s.spec_stats
    accept = ss["accepted_tokens"] / max(ss["draft_tokens"], 1)
    record(f"serve_speculative_k{spec_k}", tok_s_s, lat_s, toks, wall)
    rows[-1].update({"accept_rate": accept,
                     "draft_tokens": ss["draft_tokens"],
                     "accepted_tokens": ss["accepted_tokens"],
                     "verify_calls": ss["verify_calls"]})
    spec_speedup = tok_s_s / tok_s_p
    # tokens emitted per model dispatch — the REGIME-INDEPENDENT criterion:
    # plain decode is pinned at 1.0; the solve-draft verify guarantees >= 2
    # (the draft's first token is always exact, so every window accepts at
    # least the anchor continuation + one draft). The WALL ratio is only
    # enforced on compiled accelerator backends — a CPU host is
    # compute-bound on the tiny reduced model (a k-window Newton solve
    # multiplies FLOPs over one O(D) cell step), so the memory-/latency-
    # bound wall win the dispatch ratio predicts shows up on TPU — same
    # honest-measurement treatment as benchmarks/kernels.py
    # meets_1p5x_wall.
    # per-slot: each verify dispatch advances a slot by 1 + accepted drafts
    tokens_per_verify = 1.0 + accept * (spec_k - 1)
    on_accel = jax.default_backend() in ("tpu", "gpu")
    rows.append({"name": "spec_speedup",
                 "speculative_over_plain": spec_speedup,
                 "accept_rate": accept, "k": spec_k,
                 "tokens_per_verify_dispatch": tokens_per_verify,
                 "meets_2_tokens_per_dispatch": bool(
                     tokens_per_verify >= 2.0),
                 "backend": jax.default_backend(),
                 "enforced": on_accel,
                 "meets_1p5x": (bool(spec_speedup >= 1.5) if on_accel
                                else None)})
    print(f"spec_speedup,0,speculative_over_plain={spec_speedup:.2f};"
          f"accept_rate={accept:.2f};"
          f"tokens_per_verify={tokens_per_verify:.2f};"
          f"enforced={on_accel}", flush=True)

    # ---- quantized state cache: slot capacity + throughput --------------
    # End-to-end quantized serve on the lrc variant (the engine injects
    # tick-aligned state quantization — SSMConfig.state_quant — so decode
    # walks one storage-grid trajectory). Capacity ratio = fp32 resident
    # float-state bytes over the quantized engine's resident bytes
    # (QTensor payload + block scales; the int32 pos vector is excluded
    # from both sides): the factor more slots one HBM budget holds. fp8 is
    # a plain 1-byte cast (no scales) = exactly 4x and carries the
    # acceptance flag; int8 pays f32 block scales on top of the 1-byte
    # payload (~3.9x at block=256 on large rows, less on reduced shapes).
    from repro.distributed.precision import PrecisionPolicy
    from repro.serve.engine import ServeEngine as _Eng

    fp32_bytes = _Eng(model_l, params_l, batch_slots=slots,
                      max_seq=max_seq,
                      prefill_chunk=chunk).state_cache_bytes()
    for mode in ("int8", "fp8"):
        pol = PrecisionPolicy.from_string(mode)
        tok_s_q, lat_q, toks, wall, eng_q = _run_engine(
            model_l, params_l, slots, max_seq, chunk, reqs_spec,
            precision=pol)
        q_bytes = eng_q.state_cache_bytes()
        capacity = fp32_bytes / max(q_bytes, 1)
        record(f"serve_quantized_cache_{mode}", tok_s_q, lat_q, toks, wall)
        rows[-1].update({"cache_mode": mode,
                         "weights_mode": pol.weights,
                         "kernel_io": pol.kernel_io,
                         "fp32_state_bytes": int(fp32_bytes),
                         "quantized_state_bytes": int(q_bytes),
                         "slot_capacity_ratio": capacity})
        if mode == "fp8":
            rows[-1]["meets_4x"] = bool(capacity >= 4.0)
        print(f"serve_quantized_cache_{mode},0,"
              f"capacity={capacity:.2f}x;"
              f"bytes={int(q_bytes)}/{int(fp32_bytes)}", flush=True)

    # ---- p99 under load: >=128 queued requests, SLO scheduler ----------
    from repro.serve.engine import Request, ServeEngine
    from repro.serve.scheduler import SLOConfig, SLOScheduler

    n_load, load_p, load_new = 128, 4, 4
    rng_load = np.random.default_rng(1)
    engine = ServeEngine(model, params, batch_slots=slots, max_seq=max_seq,
                         prefill_chunk=chunk)
    # warmup compile outside the measured window
    engine.submit(Request(uid=-1, prompt=np.zeros(load_p, np.int32),
                          max_new_tokens=load_new))
    engine.run_until_drained()
    engine.token_lat = {"prefill": [], "decode": []}
    sched = SLOScheduler(engine, SLOConfig(decode_slo_ms=0.0,
                                           prefill_budget=1))
    load = [Request(uid=i,
                    prompt=rng_load.integers(0, arch.vocab, size=load_p)
                    .astype(np.int32), max_new_tokens=load_new)
            for i in range(n_load)]
    for r in load:
        sched.submit(r)              # all queued BEFORE the first tick
    t0 = time.perf_counter()
    sched.run_until_drained()
    wall = time.perf_counter() - t0
    assert all(r.done for r in load)
    stats = sched.stats()
    toks = sum(len(r.out_tokens) for r in load)
    rows.append({"name": "p99_under_load", "queued_requests": n_load,
                 "tokens_per_s": toks / wall,
                 "decode_p99_ms": stats.get("decode_p99_s", 0) * 1e3,
                 "decode_p50_ms": stats.get("decode_p50_s", 0) * 1e3,
                 "admit_wait_p99_s": stats.get("admit_wait_p99_s", 0),
                 "queue_depth_max": stats.get("queue_depth_max", 0),
                 "queue_depth_p50": stats.get("queue_depth_p50", 0),
                 "slots": slots, "wall_s": wall})
    print(f"p99_under_load,{wall*1e6:.1f},queued={n_load};"
          f"p99_ms={rows[-1]['decode_p99_ms']:.2f};"
          f"queue_max={rows[-1]['queue_depth_max']:.0f}", flush=True)

    # ---- degraded mode: slot faults + deadline mix ----------------------
    # Same trace twice — once healthy, once with NaN slot corruption
    # injected every few ticks under a per-tick watchdog — plus a deadline
    # mix (every 4th request expires at admission). The acceptance bar is
    # twofold: completed streams must be TOKEN-IDENTICAL to the healthy
    # run (quarantine + re-prefill re-derives O(D) slot state exactly),
    # and throughput under faults must stay above a 0.3x floor of the
    # healthy rate (recorded, not asserted — wall-clock floors are only
    # meaningful off shared CI hosts; the identity check IS asserted).
    from repro.reliability import corrupt_slot

    def _degraded_trial(engine, mix, uid0, fault_every, max_ticks=4000):
        """Submit the mix and tick manually, corrupting one active slot
        every ``fault_every`` ticks; returns (requests, wall_s)."""
        reqs = [Request(uid=(uid0 + i if uid0 >= 0 else uid0 - i),
                        prompt=p.copy(), max_new_tokens=n, deadline_s=dl)
                for i, (p, n, dl) in enumerate(mix)]
        for r in reqs:
            engine.submit(r)
        t0 = time.perf_counter()
        ticks = 0
        while (engine.queue
               or any(r is not None for r in engine.active)):
            ticks += 1
            assert ticks <= max_ticks, "degraded trial stalled"
            if fault_every and ticks % fault_every == 0:
                act = [s for s, r in enumerate(engine.active)
                       if r is not None]
                if act:
                    corrupt_slot(
                        engine, act[(ticks // fault_every) % len(act)],
                        mode="nan")
            engine.step()
        return reqs, time.perf_counter() - t0

    # fp32 build: the re-prefill token-identity contract is pinned at fp32
    # (tests/test_serve.py eviction tests, chaos suite) — in bf16 the
    # parallel prefill and the sequential decode tick round low-order bits
    # differently, which is a numerics property, not a recovery bug
    m32d = build_model(dataclasses.replace(arch, dtype=jnp.float32))
    p32d = m32d.init(jax.random.PRNGKey(0))
    mix = [(rng_load.integers(0, arch.vocab, size=p_len).astype(np.int32),
            max_new, 0.0 if i % 4 == 3 else None) for i in range(n_req)]
    eng_h = ServeEngine(m32d, p32d, batch_slots=slots, max_seq=max_seq,
                        prefill_chunk=chunk)
    _degraded_trial(eng_h, mix, -100, fault_every=0)     # compile warmup
    h_reqs, h_wall = _degraded_trial(eng_h, mix, 0, fault_every=0)
    h_toks = sum(len(r.out_tokens) for r in h_reqs)

    fault_every = 5
    eng_d = ServeEngine(m32d, p32d, batch_slots=slots, max_seq=max_seq,
                        prefill_chunk=chunk, watchdog_every=1,
                        max_retries=8, backoff_cap=2)
    # warmup replays the faulted scenario too, covering the re-prefill
    # resume shapes quarantine recovery compiles
    _degraded_trial(eng_d, mix, -200, fault_every=fault_every)
    ev0 = {k: eng_d.events.count(k)
           for k in ("slot_quarantine", "expired", "failed")}
    d_reqs, d_wall = _degraded_trial(eng_d, mix, 0, fault_every=fault_every)
    d_toks = sum(len(r.out_tokens) for r in d_reqs)
    ref_streams = {r.uid: list(r.out_tokens) for r in h_reqs}
    done_d = [r for r in d_reqs if r.status == "done"]
    assert done_d, "degraded run completed no requests"
    for r in done_d:
        assert list(r.out_tokens) == ref_streams[r.uid], (
            f"degraded stream for uid {r.uid} diverged from healthy run")
    h_tok_s = h_toks / h_wall
    d_tok_s = d_toks / d_wall
    rows.append({"name": "degraded_mode",
                 "tokens_per_s": d_tok_s,
                 "healthy_tokens_per_s": h_tok_s,
                 "throughput_ratio": d_tok_s / h_tok_s,
                 "stays_above_floor": bool(d_tok_s >= 0.3 * h_tok_s),
                 "fault_every_ticks": fault_every,
                 "quarantines": eng_d.events.count("slot_quarantine")
                 - ev0["slot_quarantine"],
                 "expired": eng_d.events.count("expired") - ev0["expired"],
                 "failed": eng_d.events.count("failed") - ev0["failed"],
                 "completed": len(done_d),
                 "token_identical": True,
                 "n_requests": n_req, "wall_s": d_wall})
    print(f"degraded_mode,{d_wall*1e6:.1f},"
          f"ratio={d_tok_s / h_tok_s:.2f};"
          f"quarantines={rows[-1]['quarantines']};"
          f"expired={rows[-1]['expired']};"
          f"stays_above_floor={rows[-1]['stays_above_floor']}", flush=True)

    # parallel-prefill lowering contract: no sequential scan of length T
    # (the same declarative clause tests/test_serve.py and the CI contract
    # suite evaluate — repro.contracts.check_lowering)
    T = chunk
    arch32 = dataclasses.replace(arch, dtype=jnp.float32)
    m32 = build_model(arch32)
    cache = m32.init_cache(params, 1, max_seq)
    report = check_lowering(
        lambda p, t, c: m32.prefill(p, t, c, T),
        (params, jnp.zeros((1, T), jnp.int32), cache),
        forbid_sequential_loop_over=T)
    lens = report.loop_lengths or set()
    rows.append({"name": "prefill_parallel", "chunk_T": T,
                 "seq_loop_lengths": sorted(lens),
                 "no_length_T_scan": bool(report.ok),
                 "violations": [v.to_json() for v in report.violations]})
    print(f"prefill_parallel,0,no_length_T_scan={report.ok};"
          f"loop_lengths={sorted(lens)}", flush=True)
    assert report.ok, (
        f"prefill lowering contract violated: "
        f"{[v.message for v in report.violations]}")

    # batched-verify lowering contract: the speculative verify step must
    # contain no sequential loop of the window length k — the k-token
    # window is ONE parallel solve, not k decode ticks. k=24 is chosen to
    # be distinctive (collides with no solver iteration count, conv width
    # or layer count in the reduced configs).
    from repro.train.step import make_step
    vk = 24
    arch_l32 = dataclasses.replace(arch_lrc, dtype=jnp.float32)
    ml32 = build_model(arch_l32)
    vcache = ml32.init_cache(params_l, slots, max_seq)
    vcache["pos"] = jnp.zeros((slots,), jnp.int32)
    vreport = check_lowering(
        make_step(ml32, "verify"),
        (params_l, jnp.zeros((slots, vk), jnp.int32), vcache),
        forbid_sequential_loop_over=vk)
    vlens = vreport.loop_lengths or set()
    rows.append({"name": "verify_parallel", "window_k": vk,
                 "seq_loop_lengths": sorted(vlens),
                 "no_length_k_scan": bool(vreport.ok),
                 "violations": [v.to_json() for v in vreport.violations]})
    print(f"verify_parallel,0,no_length_k_scan={vreport.ok};"
          f"loop_lengths={sorted(vlens)}", flush=True)
    assert vreport.ok, (
        f"verify lowering contract violated: "
        f"{[v.message for v in vreport.violations]}")

    out = os.environ.get("BENCH_JSON_OUT")
    if out:
        with open(out, "w") as f:
            json.dump(rows, f, indent=2)
        print(f"# wrote {out}", file=sys.stderr, flush=True)


def bench_serve() -> None:
    """benchmarks/run.py entry."""
    main()


if __name__ == "__main__":
    main()

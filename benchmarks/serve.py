"""Serving-engine benchmark: continuous batching vs one-request-at-a-time.

Two engine configurations over the SAME request trace (reduced ssm LM —
the O(D)-state family the serving story is about):

  * ``serve_one_at_a_time`` — 1 slot: every request prefilled, decoded to
    completion, then the next (the baseline a naive server implements);
  * ``serve_continuous``    — 8 slots: admission interleaves with batched
    decode ticks, finished slots recycle immediately.

Per row: tokens/s over generated tokens, p50/p99 per-token decode latency,
p50 admission (prefill) latency. The ``speedup`` row records the
continuous/one-at-a-time tokens/s ratio and the ``meets_2x`` flag (the PR-4
acceptance bar). A further ``prefill_parallel`` row asserts — at the jaxpr
level, via ``repro.contracts.check_lowering`` — that chunk prefill
contains NO length-T sequential scan (the parallel-solver-lowering
acceptance check) and records the loop lengths it does contain.

Environment knobs:
  SERVE_TOY=1          — smaller trace for the CI bench-smoke job;
  BENCH_JSON_OUT=path  — also write rows as JSON (uploaded as the
                         BENCH_serve.json artifact per commit).

Standalone:  PYTHONPATH=src python benchmarks/serve.py
"""
from __future__ import annotations

import json
import os
import sys
import time

# decode-heavy trace: serving is decode-dominated (prompts amortize through
# one parallel prefill; every generated token is a tick), so max_new >
# prompt_len is the regime the slot-batching claim is about
N_REQUESTS, PROMPT_LEN, MAX_NEW, SLOTS, CHUNK = 16, 32, 64, 8, 16
TOY = (8, 8, 32, 8, 8)


def _run_engine(model, params, slots, max_seq, chunk, reqs_spec):
    """Serve one request trace; returns (tokens/s, latency percentiles)."""
    import numpy as np

    from repro.serve.engine import Request, ServeEngine

    engine = ServeEngine(model, params, batch_slots=slots, max_seq=max_seq,
                         prefill_chunk=chunk)
    # warmup: compile prefill + decode once outside the measured window
    warm = [Request(uid=-1 - i, prompt=p.copy(), max_new_tokens=n)
            for i, (p, n) in enumerate(reqs_spec[:2])]
    for r in warm:
        engine.submit(r)
    engine.run_until_drained()
    engine.token_lat = {"prefill": [], "decode": []}
    engine.finished = []

    reqs = [Request(uid=i, prompt=p.copy(), max_new_tokens=n)
            for i, (p, n) in enumerate(reqs_spec)]
    for r in reqs:
        engine.submit(r)
    t0 = time.perf_counter()
    engine.run_until_drained()
    wall = time.perf_counter() - t0
    assert all(r.done for r in reqs)
    toks = sum(len(r.out_tokens) for r in reqs)
    return toks / wall, engine.latency_percentiles(), toks, wall


def main() -> None:
    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_reduced
    from repro.contracts import check_lowering
    from repro.models import build_model

    toy = os.environ.get("SERVE_TOY") == "1"
    n_req, p_len, max_new, slots, chunk = TOY if toy else (
        N_REQUESTS, PROMPT_LEN, MAX_NEW, SLOTS, CHUNK)
    max_seq = p_len + max_new + chunk

    arch = get_reduced("falcon_mamba_7b")
    model = build_model(arch)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs_spec = [(rng.integers(0, arch.vocab, size=p_len).astype(np.int32),
                  max_new) for _ in range(n_req)]

    rows = []

    def record(name, tok_s, lat, toks, wall):
        rows.append({"name": name, "tokens_per_s": tok_s,
                     "decode_p50_ms": lat.get("decode_p50_s", 0) * 1e3,
                     "decode_p99_ms": lat.get("decode_p99_s", 0) * 1e3,
                     "prefill_p50_ms": lat.get("prefill_p50_s", 0) * 1e3,
                     "n_requests": n_req, "prompt_len": p_len,
                     "max_new": max_new, "tokens": toks, "wall_s": wall})
        print(f"{name},{wall*1e6:.1f},tokens_per_s={tok_s:.1f};"
              f"p50_ms={rows[-1]['decode_p50_ms']:.2f};"
              f"p99_ms={rows[-1]['decode_p99_ms']:.2f}", flush=True)

    tok_s_1, lat_1, toks, wall = _run_engine(
        model, params, 1, max_seq, chunk, reqs_spec)
    record("serve_one_at_a_time", tok_s_1, lat_1, toks, wall)
    tok_s_c, lat_c, toks, wall = _run_engine(
        model, params, slots, max_seq, chunk, reqs_spec)
    record(f"serve_continuous_slots{slots}", tok_s_c, lat_c, toks, wall)

    speedup = tok_s_c / tok_s_1
    rows.append({"name": "speedup", "continuous_over_serial": speedup,
                 "meets_2x": bool(speedup >= 2.0), "slots": slots})
    print(f"speedup,0,continuous_over_serial={speedup:.2f};"
          f"meets_2x={speedup >= 2.0}", flush=True)

    # parallel-prefill lowering contract: no sequential scan of length T
    # (the same declarative clause tests/test_serve.py and the CI contract
    # suite evaluate — repro.contracts.check_lowering)
    T = chunk
    arch32 = dataclasses.replace(arch, dtype=jnp.float32)
    m32 = build_model(arch32)
    cache = m32.init_cache(params, 1, max_seq)
    report = check_lowering(
        lambda p, t, c: m32.prefill(p, t, c, T),
        (params, jnp.zeros((1, T), jnp.int32), cache),
        forbid_sequential_loop_over=T)
    lens = report.loop_lengths or set()
    rows.append({"name": "prefill_parallel", "chunk_T": T,
                 "seq_loop_lengths": sorted(lens),
                 "no_length_T_scan": bool(report.ok),
                 "violations": [v.to_json() for v in report.violations]})
    print(f"prefill_parallel,0,no_length_T_scan={report.ok};"
          f"loop_lengths={sorted(lens)}", flush=True)
    assert report.ok, (
        f"prefill lowering contract violated: "
        f"{[v.message for v in report.violations]}")

    out = os.environ.get("BENCH_JSON_OUT")
    if out:
        with open(out, "w") as f:
            json.dump(rows, f, indent=2)
        print(f"# wrote {out}", file=sys.stderr, flush=True)


def bench_serve() -> None:
    """benchmarks/run.py entry."""
    main()


if __name__ == "__main__":
    main()

"""Benchmarks reproducing each paper table/figure at CPU-feasible scale.

Real UEA datasets are not available offline; every accuracy table runs on
the UEALikeSource generators (matched sequence length / channels / classes,
class signal in slow dynamics) under the paper's fixed-protocol comparisons
— the DERIVED column states the paper claim being checked.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._util import (emit, time_fn, train_classifier,
                              train_classifier_grid)
from repro.configs.lrcssm_uea import TABLE5, ablation_config, uea_config
from repro.core.block import LrcSSMConfig, apply_lrcssm, init_lrcssm
from repro.core.deer import DeerConfig, deer_solve
from repro.core.lrc import (LrcCellConfig, init_lrc_params, input_features,
                            lrc_sequential, lrc_step)

# CPU-feasible dataset budgets: (seq_len, steps, batch)
BUDGETS = {
    "heartbeat": (405, 120, 16),
    "scp1": (512, 120, 16),
    "ethanol": (1024, 100, 8),
    "worms": (2048, 60, 4),
}


def table1_accuracy():
    """Table 1: LrcSSM accuracy on short+long-horizon tasks. Claim checked:
    the DEER-parallel LrcSSM classifier LEARNS long-horizon structure
    (acc >> chance) at the paper's tuned hyperparameters."""
    for ds, (T, steps, batch) in BUDGETS.items():
        p, n_cls, _, hidden, state, blocks, lr = TABLE5.get(
            ds, TABLE5["scp1"])
        cfg = uea_config(ds, d_hidden=min(hidden, 64),
                         d_state=min(state, 32),
                         n_blocks=min(blocks, 2))
        t0 = time.perf_counter()
        # LrcSSM's tuned regime is the high-lr end (paper B.2 finding)
        acc, info = train_classifier_grid(cfg, ds, seq_len=T, steps=steps,
                                          batch=batch, lrs=(1e-2,))
        wall = (time.perf_counter() - t0) * 1e6
        chance = 1.0 / n_cls
        emit(f"table1/{ds}", wall / steps,
             f"test_acc={acc:.3f};chance={chance:.3f};lr={info['lr']};"
             f"learned={acc > chance + 0.15}")


def table2_variants():
    """Table 2: generalised diagonal design (Mgu/Gru/Lstm vs Lrc). Claim:
    all variants train via the same exact-DEER solver; LrcSSM competitive."""
    ds, T, steps, batch = "scp1", 512, 100, 16
    accs = {}
    for cell in ("mgu", "gru", "lstm", "lrc"):
        cfg = ablation_config(cell=cell, d_input=6, n_classes=2)
        cfg = LrcSSMConfig(**{**cfg.__dict__, "d_hidden": 32, "d_state": 32,
                              "n_blocks": 2})
        t0 = time.perf_counter()
        acc, info = train_classifier_grid(cfg, ds, seq_len=T, steps=steps,
                                          batch=batch, seed=1)
        accs[cell] = acc
        emit(f"table2/{cell}ssm", (time.perf_counter() - t0) * 1e6 / steps,
             f"test_acc={acc:.3f};lr={info['lr']}")
    emit("table2/summary", 0.0,
         f"lrc_at_least_median={accs['lrc'] >= float(np.median(list(accs.values())))}")


def table3_complexity():
    """Table 3 / A.2: parallel-depth + work scaling of the DEER solve.

    Measures Newton iteration count vs T under TWO parametrisations:
      * rho-clamped (Appendix A.1, |lam| <= 0.95): iterations must be FLAT
        in T — the depth claim. (Measured: 5 iterations at T=256..16384.)
      * unclamped: slow modes (lam -> 1) make the count GROW with T — a
        quantified finding: the stability clamp is not just a gradient
        guarantee, it is what makes DEER depth-uniform.
    """
    D = 32
    results = []
    for rho, tag in ((0.95, "clamped"), (None, "unclamped")):
        cfg = LrcCellConfig(d_input=8, d_state=D, rho=rho)
        p = init_lrc_params(cfg, jax.random.PRNGKey(0))
        for T in (256, 4096, 16384):
            u = jax.random.normal(jax.random.PRNGKey(1), (T, 8))
            s_u, eps_u = input_features(p, u)
            step = lambda x, fs, cp: lrc_step(cp, cfg, x, *fs)
            x0 = jnp.zeros((D,))

            def solve(su, eu):
                return deer_solve(step, (su, eu), x0, T,
                                  DeerConfig(max_iters=100, mode="tol",
                                             tol=1e-6, grad="unroll"),
                                  params=p)

            jsolve = jax.jit(solve)
            st, iters = jsolve(s_u, eps_u)
            us = time_fn(lambda: jsolve(s_u, eps_u), iters=2)
            seq = jax.jit(lambda uu: lrc_sequential(p, cfg, uu))
            us_seq = time_fn(lambda: seq(u), iters=2)
            if rho is not None:
                results.append((T, int(iters)))
            emit(f"table3/{tag}_T{T}", us,
                 f"iters={int(iters)};seq_us={us_seq:.0f};"
                 f"par_work_per_T_us={us / T:.3f}")
    it_growth = results[-1][1] / max(results[0][1], 1)
    emit("table3/depth_claim", 0.0,
         f"clamped_iters_256={results[0][1]};"
         f"clamped_iters_16384={results[-1][1]};"
         f"iters_growth={it_growth:.2f};olog_depth_ok={it_growth < 2.0}")


def table6_runtime():
    """Table 6: training-step runtime per dataset config (per-1000-steps
    projection from measured steady-state step time)."""
    for ds in ("heartbeat", "scp1", "ethanol"):
        T, _, batch = BUDGETS[ds]
        cfg = uea_config(ds, d_hidden=32, d_state=16, n_blocks=2)
        from repro.data.pipeline import UEALikeSource
        from repro.optim.adamw import adamw_init, adamw_update
        from repro.config import TrainConfig
        src = UEALikeSource(ds, batch=batch, seed=0, seq_len=T)
        params = init_lrcssm(cfg, jax.random.PRNGKey(0))
        tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=0)
        opt = adamw_init(params)

        def loss_fn(p, x, y):
            logits = apply_lrcssm(cfg, p, x)
            return jnp.mean(jax.nn.logsumexp(logits, -1)
                            - jnp.take_along_axis(logits, y[:, None], -1)[:, 0])

        @jax.jit
        def step_fn(p, o, x, y):
            l, g = jax.value_and_grad(loss_fn)(p, x, y)
            p, o, _ = adamw_update(tcfg, g, o, p)
            return p, o, l

        x, y = src.batch_at(0)
        us = time_fn(lambda: step_fn(params, opt, x, y), iters=5, warmup=2)
        emit(f"table6/{ds}", us, f"s_per_1000_steps={us * 1e-3:.1f}")


def fig2_iterations():
    """Figure 2: Newton iterations to convergence per dataset config."""
    for ds in ("heartbeat", "scp1", "ethanol", "worms"):
        T, _, _ = BUDGETS[ds]
        pcfg = TABLE5.get(ds, TABLE5["scp1"])
        D = min(pcfg[4], 32)
        cfg = LrcCellConfig(d_input=8, d_state=D)
        p = init_lrc_params(cfg, jax.random.PRNGKey(2))
        u = jax.random.normal(jax.random.PRNGKey(3), (T, 8))
        s_u, eps_u = input_features(p, u)
        step = lambda x, fs, cp: lrc_step(cp, cfg, x, *fs)
        x0 = jnp.zeros((D,))
        _, iters = jax.jit(lambda su, eu: deer_solve(
            step, (su, eu), x0, T,
            DeerConfig(max_iters=50, mode="tol", tol=1e-6, grad="unroll"),
            params=p))(s_u, eps_u)
        emit(f"fig2/{ds}", 0.0, f"newton_iters={int(iters)}")

"""Scan primitives: parallel == sequential oracle, all variants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # optional dep absent: fixed-seed-grid fallback
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.scan import (chunked_diag_scan, diag_linear_scan,
                             diag_linear_scan_seq)

jax.config.update("jax_enable_x64", False)


@pytest.mark.parametrize("T,D", [(1, 4), (7, 3), (64, 16), (130, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.complex64])
def test_parallel_matches_sequential(T, D, dtype):
    k = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(k, 3)
    if dtype == jnp.complex64:
        lam = (jax.random.uniform(k1, (T, D)) * 0.9).astype(dtype) * jnp.exp(
            1j * jax.random.uniform(k2, (T, D)) * 3.0)
        b = (jax.random.normal(k2, (T, D)) + 1j * jax.random.normal(k3, (T, D))).astype(dtype)
        x0 = jnp.zeros((D,), dtype)
    else:
        lam = jax.random.uniform(k1, (T, D), dtype) * 0.95
        b = jax.random.normal(k2, (T, D), dtype)
        x0 = jax.random.normal(k3, (D,), dtype)
    got = diag_linear_scan(lam, b, x0)
    want = diag_linear_scan_seq(lam, b, x0)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_zero_init_default():
    T, D = 32, 4
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    lam = jax.random.uniform(k1, (T, D)) * 0.9
    b = jax.random.normal(k2, (T, D))
    np.testing.assert_allclose(diag_linear_scan(lam, b),
                               diag_linear_scan_seq(lam, b),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_chunked_matches(chunk):
    T, D = 128, 8
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(2), 3)
    lam = jax.random.uniform(k1, (T, D)) * 0.95
    b = jax.random.normal(k2, (T, D))
    x0 = jax.random.normal(k3, (D,))
    np.testing.assert_allclose(chunked_diag_scan(lam, b, x0, chunk=chunk),
                               diag_linear_scan_seq(lam, b, x0),
                               rtol=1e-5, atol=1e-5)


def test_reverse_scan_is_adjoint_recurrence():
    """reverse=True solves g_t = lam_t * g_{t+1} + b_t."""
    T, D = 37, 5
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    lam = jax.random.uniform(k1, (T, D)) * 0.9
    b = jax.random.normal(k2, (T, D))
    got = diag_linear_scan(lam, b, None, reverse=True)
    want = np.zeros((T, D), np.float32)
    g_next = np.zeros((D,), np.float32)
    for t in range(T - 1, -1, -1):
        g_next = np.asarray(lam[t]) * g_next + np.asarray(b[t])
        want[t] = g_next
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(T=st.integers(1, 50), D=st.integers(1, 8),
       scale=st.floats(0.0, 0.99), seed=st.integers(0, 2**16))
def test_property_parallel_equals_sequential(T, D, scale, seed):
    """Property: for any contraction factors |lam|<=scale<1 the parallel scan
    equals the sequential recurrence (system invariant)."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    lam = (jax.random.uniform(k1, (T, D)) * 2 - 1) * scale
    b = jax.random.normal(k2, (T, D))
    x0 = jax.random.normal(k3, (D,))
    np.testing.assert_allclose(diag_linear_scan(lam, b, x0),
                               diag_linear_scan_seq(lam, b, x0),
                               rtol=5e-5, atol=5e-5)


def test_scan_gradients_flow():
    T, D = 16, 3
    k1, k2 = jax.random.split(jax.random.PRNGKey(4))
    lam = jax.random.uniform(k1, (T, D)) * 0.9
    b = jax.random.normal(k2, (T, D))

    def loss_par(lam, b):
        return jnp.sum(diag_linear_scan(lam, b) ** 2)

    def loss_seq(lam, b):
        return jnp.sum(diag_linear_scan_seq(lam, b) ** 2)

    g1 = jax.grad(loss_par, argnums=(0, 1))(lam, b)
    g2 = jax.grad(loss_seq, argnums=(0, 1))(lam, b)
    for a, c in zip(g1, g2):
        np.testing.assert_allclose(a, c, rtol=1e-4, atol=1e-4)

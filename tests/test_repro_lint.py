"""Self-test fixture corpus for the AST rule engine (tools/repro_lint).

Every rule gets (at least) one violating snippet that MUST fire and one
clean snippet that MUST stay silent — so a refactor of the engine can't
silently lobotomize a rule — plus suppression-comment and wrapper tests.
Snippets are linted in-memory via ``lint_source`` at a relpath chosen to
land inside the rule's scope (the rules are path-scoped: host-sync only
watches hot paths, kernels-shard-map only src/repro/kernels/, ...).

The closing test lints the ACTUAL repo tree and requires zero findings —
the "clean on current tree while every rule demonstrably fires" bar.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, REPO)

from tools.repro_lint import lint_source, run_lint  # noqa: E402
from tools.repro_lint.rules import ALL_RULES  # noqa: E402


def lint(src, relpath="src/repro/train/x.py"):
    """Lint a dedented snippet at a path inside the hot-path scope."""
    return lint_source(textwrap.dedent(src), relpath, ALL_RULES)


def rules_fired(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------- compat


class TestCompatCollective:
    def test_direct_lax_import_fires(self):
        fs = lint("from jax.lax import psum\n")
        assert rules_fired(fs) == {"compat-collective"}

    def test_parenthesized_multiline_import_fires(self):
        # the grep-era false negative: names on continuation lines
        fs = lint("""\
            from jax.lax import (
                psum,
                all_gather,
            )
        """)
        assert len([f for f in fs if f.rule == "compat-collective"]) == 2

    def test_aliased_module_usage_fires(self):
        fs = lint("""\
            import jax.lax as jl
            def f(x):
                return jl.psum(x, "data")
        """)
        assert "compat-collective" in rules_fired(fs)

    def test_shard_map_import_fires(self):
        fs = lint("from jax.experimental.shard_map import shard_map\n")
        assert "compat-collective" in rules_fired(fs)

    def test_new_api_attribute_fires(self):
        fs = lint("""\
            import jax
            f = jax.shard_map(lambda x: x, mesh=None, in_specs=None,
                              out_specs=None)
        """)
        assert "compat-collective" in rules_fired(fs)

    def test_compat_import_is_clean(self):
        fs = lint("""\
            from repro.distributed.compat import psum, shard_map
            import jax.numpy as jnp
            def f(x):
                return psum(jnp.sum(x), "data")
        """)
        assert fs == []

    def test_compat_module_itself_exempt(self):
        fs = lint("from jax.lax import psum\n",
                  relpath="src/repro/distributed/compat.py")
        assert fs == []

    def test_unrelated_lax_import_is_clean(self):
        fs = lint("from jax.lax import scan, associative_scan\n")
        assert fs == []


class TestKernelsShardMap:
    def test_any_shard_map_spelling_in_kernels_fires(self):
        fs = lint("from jax.experimental.shard_map import shard_map\n",
                  relpath="src/repro/kernels/k.py")
        assert "kernels-shard-map" in rules_fired(fs)

    def test_compat_shard_map_in_kernels_is_clean(self):
        fs = lint("""\
            from repro.distributed import compat
            def f(fn, mesh, spec):
                return compat.shard_map(fn, mesh=mesh, in_specs=spec,
                                        out_specs=spec)
        """, relpath="src/repro/kernels/k.py")
        assert fs == []

    def test_out_of_scope_path_ignored(self):
        # the kernels rule must not fire outside src/repro/kernels/
        fs = lint("""\
            from repro.distributed.compat import shard_map
            g = shard_map
        """, relpath="benchmarks/b.py")
        assert fs == []


# -------------------------------------------------------------- host-sync


class TestHostSync:
    def test_item_fires(self):
        fs = lint("""\
            def step(loss):
                return loss.item()
        """)
        assert rules_fired(fs) == {"host-sync"}

    def test_device_get_fires(self):
        fs = lint("""\
            import jax
            def step(x):
                return jax.device_get(x)
        """)
        assert "host-sync" in rules_fired(fs)

    def test_float_of_traced_fires(self):
        fs = lint("""\
            import jax.numpy as jnp
            def step(x):
                return float(jnp.sum(x))
        """)
        assert "host-sync" in rules_fired(fs)

    def test_np_asarray_of_traced_fires(self):
        fs = lint("""\
            import numpy as np
            import jax.numpy as jnp
            def step(x):
                return np.asarray(jnp.sum(x))
        """)
        assert "host-sync" in rules_fired(fs)

    def test_host_side_numpy_is_clean(self):
        # float()/np.asarray() over plain-python/numpy values: no finding
        fs = lint("""\
            import numpy as np
            def bookkeeping(xs):
                a = float(np.mean(xs))
                return np.asarray(xs, dtype=np.int32), a
        """)
        assert fs == []

    def test_cold_path_ignored(self):
        fs = lint("def f(loss):\n    return loss.item()\n",
                  relpath="src/repro/configs.py")
        assert fs == []


# ------------------------------------------------------- pallas/interpret


class TestPallasAndInterpret:
    def test_pallas_call_outside_kernels_fires(self):
        fs = lint("""\
            from jax.experimental import pallas as pl
            def f(kernel, x):
                return pl.pallas_call(kernel, out_shape=x)(x)
        """, relpath="src/repro/core/c.py")
        assert "pallas-call-outside-kernels" in rules_fired(fs)

    def test_pallas_call_inside_kernels_is_clean(self):
        fs = lint("""\
            from jax.experimental import pallas as pl
            def f(kernel, x):
                return pl.pallas_call(kernel, out_shape=x)(x)
        """, relpath="src/repro/kernels/lrc_deer/kernel.py")
        assert fs == []

    def test_hardcoded_interpret_true_fires(self):
        fs = lint("""\
            def f(call):
                return call(interpret=True)
        """, relpath="src/repro/kernels/k.py")
        assert "hardcoded-interpret" in rules_fired(fs)

    def test_plumbed_interpret_is_clean(self):
        fs = lint("""\
            def f(call, interpret):
                return call(interpret=interpret)
        """, relpath="src/repro/kernels/k.py")
        assert fs == []


# ------------------------------------------------------------ bare-except


class TestBareExcept:
    def test_bare_except_fires(self):
        fs = lint("""\
            def f():
                try:
                    g()
                except:
                    return 0
        """)
        assert rules_fired(fs) == {"bare-except"}

    def test_broad_swallow_fires(self):
        fs = lint("""\
            def f():
                try:
                    g()
                except Exception:
                    pass
        """, relpath="src/repro/checkpoint/x.py")
        assert rules_fired(fs) == {"bare-except"}

    def test_broad_swallow_in_tuple_fires(self):
        fs = lint("""\
            def f():
                try:
                    g()
                except (ValueError, BaseException):
                    ...
        """, relpath="src/repro/reliability/x.py")
        assert rules_fired(fs) == {"bare-except"}

    def test_broad_handler_with_body_is_silent(self):
        # the sanctioned shape: a broad handler that DOES something
        # (verify_step's loadability verdict) is allowed
        fs = lint("""\
            def f():
                try:
                    g()
                except Exception:
                    return False
        """, relpath="src/repro/checkpoint/x.py")
        assert fs == []

    def test_narrow_swallow_is_silent(self):
        fs = lint("""\
            def f():
                try:
                    g()
                except OSError:
                    pass
        """)
        assert fs == []

    def test_out_of_scope_path_is_silent(self):
        fs = lint("""\
            def f():
                try:
                    g()
                except:
                    pass
        """, relpath="tools/somewhere/x.py")
        assert fs == []

    def test_suppression_works(self):
        fs = lint("""\
            def f():
                try:
                    g()
                except Exception:  # repro-lint: disable=bare-except
                    pass
        """)
        assert fs == []


# ------------------------------------------------------------ suppression


class TestSuppression:
    def test_same_line_suppression(self):
        fs = lint("""\
            def step(loss):
                return loss.item()  # repro-lint: disable=host-sync
        """)
        assert fs == []

    def test_line_above_suppression(self):
        fs = lint("""\
            def step(loss):
                # repro-lint: disable=host-sync
                return loss.item()
        """)
        assert fs == []

    def test_file_level_suppression(self):
        fs = lint("""\
            # repro-lint: disable-file=host-sync
            def step(loss):
                return loss.item()
        """)
        assert fs == []

    def test_suppression_is_rule_specific(self):
        # suppressing one rule must not silence a different one
        fs = lint("""\
            def step(loss):
                return loss.item()  # repro-lint: disable=compat-collective
        """)
        assert "host-sync" in rules_fired(fs)

    def test_syntax_error_reported_not_raised(self):
        fs = lint("def broken(:\n")
        assert [f.rule for f in fs] == ["syntax-error"]


# ----------------------------------------------------------- end-to-end


class TestTree:
    def test_repo_tree_is_clean(self):
        # the acceptance bar: zero findings on the actual tree with every
        # rule enabled (while the fixtures above prove each rule fires)
        findings, n_files = run_lint(root=REPO)
        assert n_files > 50
        assert findings == [], "\n".join(f.human() for f in findings)

    def test_cli_module_exit_zero_on_tree(self):
        r = subprocess.run([sys.executable, "-m", "tools.repro_lint"],
                           cwd=REPO, capture_output=True, text=True,
                           timeout=120)
        assert r.returncode == 0, r.stdout + r.stderr

    def test_wrapper_script_passes(self):
        # satellite: lint_compat.sh is now a thin wrapper over the engine
        r = subprocess.run(["bash", "tools/lint_compat.sh"], cwd=REPO,
                           capture_output=True, text=True, timeout=120)
        assert r.returncode == 0, r.stdout + r.stderr

    def test_cli_catches_planted_violation(self, tmp_path):
        # a planted tree with a parenthesized multi-line import (the
        # grep-era miss) must exit 1 through the same CLI CI invokes
        pkg = tmp_path / "src"
        pkg.mkdir()
        (pkg / "bad.py").write_text(
            "from jax.lax import (\n    psum,\n)\n")
        r = subprocess.run(
            [sys.executable, "-m", "tools.repro_lint", "--root",
             str(tmp_path), "--format", "json"],
            cwd=REPO, capture_output=True, text=True, timeout=120)
        assert r.returncode == 1
        assert "compat-collective" in r.stdout

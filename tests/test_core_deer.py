"""DEER/ELK solvers: convergence to the sequential oracle, gradient parity,
iteration counts, stability properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.deer import DeerConfig, deer_residual, deer_solve
from repro.core.elk import ElkConfig, elk_solve, kalman_smoother_parallel
from repro.core.lrc import (LrcCellConfig, init_lrc_params, input_features,
                            lrc_sequential, lrc_step, lrc_step_and_diag_jac)
from repro.core import variants


def _make_lrc(T=48, n=6, D=12, seed=0, **kw):
    cfg = LrcCellConfig(d_input=n, d_state=D, **kw)
    key = jax.random.PRNGKey(seed)
    p = init_lrc_params(cfg, key)
    u = jax.random.normal(jax.random.PRNGKey(seed + 1), (T, n))
    return cfg, p, u


def test_diag_jacobian_is_exact():
    """The jvp-extracted diagonal equals the full autodiff Jacobian diagonal,
    and the off-diagonals are exactly zero (diagonal BY DESIGN — Sec. 3.1)."""
    cfg, p, u = _make_lrc(T=1, D=6)
    s_u, eps_u = input_features(p, u)
    x = jax.random.normal(jax.random.PRNGKey(2), (6,))
    step = lambda xx: lrc_step(p, cfg, xx, s_u[0], eps_u[0])
    J = jax.jacfwd(step)(x)
    _, diag = lrc_step_and_diag_jac(p, cfg, x, s_u[0], eps_u[0])
    np.testing.assert_allclose(np.diag(J), diag, rtol=1e-5, atol=1e-6)
    off = J - np.diag(np.diag(J))
    np.testing.assert_allclose(off, np.zeros_like(off), atol=1e-7)


@pytest.mark.parametrize("mode", ["fixed", "tol"])
def test_deer_converges_to_sequential(mode):
    cfg, p, u = _make_lrc()
    want = lrc_sequential(p, cfg, u)
    s_u, eps_u = input_features(p, u)
    step = lambda x, fs: lrc_step(p, cfg, x, *fs)
    x0 = jnp.zeros((cfg.d_state,))
    dc = DeerConfig(max_iters=25, tol=1e-9, mode=mode, grad="unroll")
    got, iters = deer_solve(step, (s_u, eps_u), x0, u.shape[0], dc)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
    assert deer_residual(step, (s_u, eps_u), x0, got) < 1e-4
    if mode == "tol":
        assert int(iters) < 25, "should converge well before the cap"


def test_deer_long_sequence():
    cfg, p, u = _make_lrc(T=2048, D=8)
    want = lrc_sequential(p, cfg, u)
    s_u, eps_u = input_features(p, u)
    step = lambda x, fs: lrc_step(p, cfg, x, *fs)
    x0 = jnp.zeros((cfg.d_state,))
    got, _ = deer_solve(step, (s_u, eps_u), x0, 2048,
                        DeerConfig(max_iters=30, mode="tol", grad="unroll",
                                   tol=1e-8))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


def test_deer_rho_clamp_contractive():
    """With the rho clamp (Appendix A.1), |lam| <= rho so trajectories from
    two inits contract at rate rho^t (Lemma 1)."""
    cfg, p, u = _make_lrc(T=64, rho=0.9)
    xa = lrc_sequential(p, cfg, u, x0=jnp.full((cfg.d_state,), 2.0))
    xb = lrc_sequential(p, cfg, u, x0=jnp.full((cfg.d_state,), -2.0))
    d = jnp.linalg.norm(xa - xb, axis=-1)
    assert d[-1] <= (0.9 ** 32) * d[0] + 1e-5


def test_gradient_stability_theorem1():
    """|grad_{x0} L| <= rho^T |grad_{x_T} L| for loss on final state."""
    cfg, p, u = _make_lrc(T=40, rho=0.95)

    def loss(x0):
        xs = lrc_sequential(p, cfg, u, x0=x0)
        return jnp.sum(xs[-1])

    g = jax.grad(loss)(jnp.zeros((cfg.d_state,)))
    gT = jnp.ones((cfg.d_state,))  # grad at x_T of sum(x_T)
    assert jnp.linalg.norm(g) <= (0.95 ** 40) * jnp.linalg.norm(gT) + 1e-6


def test_implicit_grad_matches_unrolled():
    """custom_vjp (IFT adjoint scan) == BPTT through converged iterations."""
    cfg, p, u = _make_lrc(T=32, D=8)
    x0 = jnp.zeros((cfg.d_state,))

    def run(mode, s_u, eps_u):
        step = lambda x, fs: lrc_step(p, cfg, x, *fs)
        dc = DeerConfig(max_iters=30, mode="fixed", grad=mode)
        states, _ = deer_solve(step, (s_u, eps_u), x0, 32, dc)
        return jnp.sum(states ** 2)

    s_u, eps_u = input_features(p, u)
    g_imp = jax.grad(run, argnums=(1, 2))("implicit", s_u, eps_u)
    g_unr = jax.grad(run, argnums=(1, 2))("unroll", s_u, eps_u)
    for a, b in zip(g_imp, g_unr):
        np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-4)


def test_implicit_grad_matches_sequential_bptt():
    """Implicit grads == classic BPTT through the sequential rollout —
    the strongest exactness check for the adjoint parallel scan."""
    cfg, p, u = _make_lrc(T=24, D=6)
    x0 = jnp.zeros((cfg.d_state,))

    def loss_seq(u_):
        return jnp.sum(lrc_sequential(p, cfg, u_) ** 2)

    def loss_deer(u_):
        s_u, eps_u = input_features(p, u_)
        step = lambda x, fs: lrc_step(p, cfg, x, *fs)
        st, _ = deer_solve(step, (s_u, eps_u), x0, 24,
                           DeerConfig(max_iters=40, grad="implicit"))
        return jnp.sum(st ** 2)

    np.testing.assert_allclose(jax.grad(loss_deer)(u), jax.grad(loss_seq)(u),
                               rtol=5e-3, atol=5e-4)


@pytest.mark.parametrize("kind", ["gru", "mgu", "lstm", "stc"])
def test_variant_cells_deer_match_sequential(kind):
    """Appendix D: the generalised diagonal design parallelises every cell."""
    ccfg = variants.CellConfig(d_input=5, d_state=9)
    key = jax.random.PRNGKey(7)
    init, feat_fn, step_fn = variants.CELLS[kind]
    p = init(ccfg, key)
    u = jax.random.normal(jax.random.PRNGKey(8), (40, 5))
    want = variants.sequential(kind, p, ccfg, u)
    feats = feat_fn(p, u)
    step = lambda x, fs: step_fn(p, ccfg, x, *fs)
    x0 = jnp.zeros((9,))
    got, _ = deer_solve(step, feats, x0, 40,
                        DeerConfig(max_iters=40, mode="tol", tol=1e-9,
                                   grad="unroll"))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_kalman_smoother_uninformative_obs_equals_scan():
    """mu -> 0 (obs var -> inf): ELK's smoother must reproduce the exact
    linear-recurrence solution (pure Newton/DEER step)."""
    T, D = 33, 4
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(5), 3)
    F = jax.random.uniform(k1, (T, D)) * 0.9
    c = jax.random.normal(k2, (T, D))
    y = jnp.zeros((T, D))
    m0 = jax.random.normal(k3, (D,))
    from repro.core.scan import diag_linear_scan_seq
    want = diag_linear_scan_seq(F, c, m0)
    ms, _ = kalman_smoother_parallel(F, c, 1.0, y, 1e12, m0,
                                     jnp.zeros((D,)) + 1e-9)
    np.testing.assert_allclose(ms, want, rtol=1e-3, atol=1e-3)


def test_kalman_smoother_matches_sequential_reference():
    """Parallel associative-scan smoother == classic sequential RTS."""
    T, D = 21, 3
    ks = jax.random.split(jax.random.PRNGKey(6), 5)
    F = jax.random.uniform(ks[0], (T, D)) * 0.8 + 0.1
    c = jax.random.normal(ks[1], (T, D)) * 0.3
    y = jax.random.normal(ks[2], (T, D))
    q, r = 0.7, 1.3
    m0 = jax.random.normal(ks[3], (D,))
    P0 = jnp.abs(jax.random.normal(ks[4], (D,))) + 0.5

    # sequential Kalman filter + RTS smoother (numpy reference)
    Fn, cn, yn = map(np.asarray, (F, c, y))
    m_f = np.zeros((T, D)); P_f = np.zeros((T, D))
    m, P = np.asarray(m0), np.asarray(P0)
    for t in range(T):
        mp = Fn[t] * m + cn[t]
        Pp = Fn[t] ** 2 * P + q
        K = Pp / (Pp + r)
        m = mp + K * (yn[t] - mp)
        P = (1 - K) * Pp
        m_f[t], P_f[t] = m, P
    ms = np.zeros((T, D)); Ps = np.zeros((T, D))
    ms[-1], Ps[-1] = m_f[-1], P_f[-1]
    for t in range(T - 2, -1, -1):
        Pp = Fn[t + 1] ** 2 * P_f[t] + q
        G = P_f[t] * Fn[t + 1] / Pp
        ms[t] = m_f[t] + G * (ms[t + 1] - (Fn[t + 1] * m_f[t] + cn[t + 1]))
        Ps[t] = P_f[t] + G ** 2 * (Ps[t + 1] - Pp)

    got_m, got_P = kalman_smoother_parallel(F, c, q, y, r, m0, P0)
    np.testing.assert_allclose(got_m, ms, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(got_P, Ps, rtol=1e-4, atol=1e-4)


def test_elk_converges_to_sequential():
    cfg, p, u = _make_lrc(T=40, D=8)
    want = lrc_sequential(p, cfg, u)
    s_u, eps_u = input_features(p, u)
    step = lambda x, fs: lrc_step(p, cfg, x, *fs)
    x0 = jnp.zeros((cfg.d_state,))
    got, _ = elk_solve(step, (s_u, eps_u), x0, 40,
                       ElkConfig(max_iters=60, mode="tol", tol=1e-10,
                                 trust_mu=0.05))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)

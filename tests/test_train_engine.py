"""Pod-local gradient engine tests (train/step.py grad_reduce modes).

Subprocess tests run on 8 forced host devices (tests/conftest.py). The
toy problem used by the error-feedback tests is engineered so int8
round-to-nearest visibly hurts: one high-scale NON-learnable feature keeps
the cross-pod gradient (and hence the per-block quantisation scale) large
forever, so the many small learnable coordinates quantise to zero every
step unless the error-feedback residual accumulates them. The probe loss
zeroes that noise feature out, isolating the learnable component.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.config import TrainConfig


# shared toy problem (stringified into subprocesses; indentation matches the
# per-test bodies so run_sub's dedent applies uniformly)
_TOY = """
        from repro.config import TrainConfig
        from repro.models import Model
        from repro.train.state import train_state_init
        from repro.train.step import jit_train_step
        from repro.distributed import sharding as shd

        D, B = 256, 64
        scales = jnp.ones((D,)).at[0].set(30.0)
        w_true = jnp.concatenate([jnp.zeros((1,)), 0.5 * jnp.ones((D - 1,))])

        def init(key):
            return {"w": jnp.zeros((D,), jnp.float32)}
        def loss(p, b):
            return jnp.mean((b["tokens"] @ p["w"] - b["labels"]) ** 2)
        model = Model(arch=None, init=init, loss=loss, apply=None,
                      decode_step=None, init_cache=None)

        def batch_at(s):
            k1, k2 = jax.random.split(jax.random.PRNGKey(1000 + s))
            x = jax.random.normal(k1, (B, D)) * scales
            # non-learnable per-batch component on the big feature: the pod
            # gradient for coord 0 stays large forever -> the quantisation
            # scale never shrinks -> small grads crush to 0 without EF
            sign = jnp.where(jax.random.bernoulli(k2), 1.0, -1.0)
            eps = sign * (0.5 + 0.2 * jax.random.normal(
                jax.random.fold_in(k2, 1)))
            return {"tokens": x, "labels": x @ w_true + x[:, 0] * eps}

        probe_x = jax.random.normal(jax.random.PRNGKey(777), (512, D))
        probe_x = probe_x.at[:, 0].set(0.0)
        probe = {"tokens": probe_x, "labels": probe_x @ w_true}

        def run(mesh, grad_reduce, comp, ef, steps=50, lr=1e-1):
            tcfg = TrainConfig(learning_rate=lr, warmup_steps=0,
                               total_steps=100000, weight_decay=0.0,
                               grad_clip=1e9, grad_reduce=grad_reduce,
                               grad_compression=comp, error_feedback=ef)
            with shd.use_mesh(mesh):
                state = train_state_init(model.init(None), tcfg, mesh)
                jstep = jit_train_step(model, tcfg, mesh, state, batch_at(0),
                                       donate=False)
                for s in range(steps):
                    state, metrics = jstep(state, batch_at(s))
            params = jax.tree_util.tree_map(np.asarray, state.params)
            return float(loss(params, probe)), state
"""


def test_microbatch_remainder_raises():
    """B % microbatch != 0 must be a factory-time ValueError, not a silent
    truncation of the batch."""
    from repro.models import Model
    from repro.train.state import train_state_init
    from repro.train.step import jit_train_step, make_train_step

    model = Model(arch=None, init=lambda k: {"w": jnp.zeros((4,))},
                  loss=lambda p, b: jnp.mean(b["tokens"] @ p["w"]),
                  apply=None, decode_step=None, init_cache=None)
    batch = {"tokens": jnp.zeros((10, 4))}
    tcfg = TrainConfig(microbatch=4)
    state = train_state_init(model.init(None), tcfg)
    mesh = jax.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="microbatch=4 does not divide"):
        jit_train_step(model, tcfg, mesh, state, batch)
    # the pure (un-wired) step raises at trace time too
    with pytest.raises(ValueError, match="silently drop"):
        jax.eval_shape(make_train_step(model, tcfg), state, batch)


def test_residual_layout_and_dtype():
    """train_state_init residual: leading n_pod dim, TrainConfig-selected
    dtype, {} whenever compression is off or the mesh has no pod axis."""
    from repro.train.state import train_state_init

    params = {"w": jnp.zeros((16, 8)), "b": jnp.zeros(())}
    pod_mesh = jax.make_mesh((1,), ("pod",))
    st = train_state_init(params, TrainConfig(grad_compression="int8",
                                              residual_dtype="bfloat16"),
                          pod_mesh)
    assert st.residual["w"].shape == (1, 16, 8)
    assert st.residual["w"].dtype == jnp.bfloat16
    assert st.residual["b"].shape == (1,)
    # no pod axis / no compression -> no residual state
    data_mesh = jax.make_mesh((1,), ("data",))
    assert train_state_init(
        params, TrainConfig(grad_compression="int8"), data_mesh).residual == {}
    assert train_state_init(
        params, TrainConfig(), pod_mesh).residual == {}


def test_unified_factory_eval_mode():
    """The same factory wires eval steps (loss only, replicated out)."""
    from repro.models import Model
    from repro.train.step import jit_step, make_step

    model = Model(arch=None, init=lambda k: {"w": jnp.ones((4,))},
                  loss=lambda p, b: jnp.mean((b["tokens"] @ p["w"]) ** 2),
                  apply=None, decode_step=None, init_cache=None)
    params = model.init(None)
    batch = {"tokens": jnp.ones((8, 4))}
    mesh = jax.make_mesh((1,), ("data",))
    estep = jit_step(model, "eval", mesh, params_like=params,
                     batch_like=batch)
    assert float(estep(params, batch)) == pytest.approx(16.0)
    with pytest.raises(ValueError, match="unknown step mode"):
        make_step(model, "deploy")


def test_wire_bytes_accounting():
    """The analytic accounting behind BENCH_grad_compression: at the
    production pod count (P=2) the int8 all-gather format moves ~3.9x
    fewer bytes than a fp32 ring all-reduce; the advantage decays with P
    (documented crossover ~8)."""
    from repro.distributed.compression import reduction_wire_bytes
    tree = {"w": jnp.zeros((1024, 256))}
    n = 1024 * 256
    fp32 = reduction_wire_bytes(tree, 2, "fp32_allreduce")
    int8 = reduction_wire_bytes(tree, 2, "int8_allgather")
    assert fp32 == 4 * n                       # 2*(P-1)/P*4, P=2
    assert int8 == int(round(n * (1 + 4 / 256)))
    assert fp32 / int8 > 3.0                   # acceptance: >=3x fewer
    # all-gather scaling loses at high P — the documented crossover
    assert (reduction_wire_bytes(tree, 16, "int8_allgather")
            > reduction_wire_bytes(tree, 16, "fp32_allreduce"))
    # the rsag (reduce-scatter + all-gather) format holds ~3.9x at ANY P:
    # same 2*(P-1)/P payload factor as the fp32 ring, int8+scale payload
    for P in (2, 8, 16, 64):
        fp32_p = reduction_wire_bytes(tree, P, "fp32_allreduce")
        rsag_p = reduction_wire_bytes(tree, P, "int8_rsag")
        assert fp32_p / rsag_p > 3.9, (P, fp32_p, rsag_p)
    with pytest.raises(ValueError):
        reduction_wire_bytes(tree, 2, "fp8_magic")


def test_explicit_matches_gspmd(run_sub):
    """grad_reduce='explicit' (pod-local grads + explicit fp32 reduction)
    is numerically the same optimisation as GSPMD's implicit path."""
    out = run_sub("""
        from repro.configs import get_reduced
        from repro.models import build_model
        from repro.launch.specs import make_batch
        from repro.config import ShapeConfig, TrainConfig
        from repro.train.state import train_state_init
        from repro.train.step import jit_train_step
        from repro.distributed import sharding as shd
        import dataclasses

        arch = dataclasses.replace(get_reduced("granite_3_8b"),
                                   dtype=jnp.float32)
        model = build_model(arch)
        params = model.init(jax.random.PRNGKey(0))
        batch = make_batch(arch, ShapeConfig("s", 16, 8, "train"),
                           jax.random.PRNGKey(1))
        mesh = jax.make_mesh((2, 4), ("pod", "data"))

        final = {}
        for mode in ("gspmd", "explicit"):
            tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=0,
                               grad_clip=1.0, grad_reduce=mode)
            with shd.use_mesh(mesh):
                state = train_state_init(params, tcfg, mesh)
                jstep = jit_train_step(model, tcfg, mesh, state, batch,
                                       donate=False)
                for _ in range(3):
                    state, metrics = jstep(state, batch)
            final[mode] = (float(metrics["loss"]), jax.tree_util.tree_map(
                lambda a: np.asarray(a, np.float32), state.params))
        l1, p1 = final["gspmd"]; l2, p2 = final["explicit"]
        maxd = max(float(np.max(np.abs(a - b))) for a, b in zip(
            jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)))
        print(json.dumps({"loss_diff": abs(l1 - l2), "max_param_diff": maxd}))
    """)
    assert out["loss_diff"] < 1e-4, out
    assert out["max_param_diff"] < 1e-4, out


def test_compressed_explicit_hlo_has_no_fp32_pod_allreduce(run_sub):
    """THE acceptance property of this refactor: in the explicit int8 path
    the lowered HLO contains NO gradient-sized fp32 cross-pod collective —
    the only payload-sized collectives are int8 all-gathers (+ tiny fp32
    per-block scales) — while the gspmd baseline on the same mesh lowers
    gradient-sized fp32 all-reduces. Asserted through the declarative
    contract API (repro.contracts.check_hlo_collectives) — the same clause
    the CI contract suite (tools/contract_suite.py) evaluates per commit."""
    out = run_sub("""
        from repro.configs import get_reduced
        from repro.models import build_model
        from repro.launch.specs import make_batch
        from repro.config import ShapeConfig, TrainConfig
        from repro.contracts import check_hlo_collectives
        from repro.train.state import train_state_init
        from repro.train.step import jit_train_step
        from repro.distributed import sharding as shd
        import dataclasses

        arch = dataclasses.replace(get_reduced("granite_3_8b"),
                                   dtype=jnp.float32)
        model = build_model(arch)
        params = model.init(jax.random.PRNGKey(0))
        batch = make_batch(arch, ShapeConfig("s", 16, 8, "train"),
                           jax.random.PRNGKey(1))
        mesh = jax.make_mesh((8,), ("pod",))   # every collective is cross-pod
        THRESH = 16384   # >> per-block scales (n/256), << any grad leaf
        NO_BIG_F32 = [{"dtype": "f32", "min_elems": THRESH}]

        def collectives(mode, comp):
            tcfg = TrainConfig(warmup_steps=0, grad_reduce=mode,
                               grad_compression=comp)
            with shd.use_mesh(mesh):
                state = train_state_init(params, tcfg, mesh)
                jstep = jit_train_step(model, tcfg, mesh, state, batch,
                                       donate=False)
                txt = jstep.lower(state, batch).compile().as_text()
            return check_hlo_collectives(txt, forbid=NO_BIG_F32)

        comp_ops, comp_violations = collectives("explicit", "int8")
        base_ops, base_violations = collectives("gspmd", "none")
        int8_payload = [o for o in comp_ops if o["dtype"] == "s8"]
        print(json.dumps({
            "big_f32_compressed": len(comp_violations),
            "compressed_violations": [v.to_json()["message"]
                                      for v in comp_violations],
            "big_f32_gspmd": len(base_violations),
            "int8_gathers": len(int8_payload)}))
    """)
    assert out["big_f32_compressed"] == 0, out
    assert out["big_f32_gspmd"] > 0, out       # the baseline DOES all-reduce fp32
    assert out["int8_gathers"] > 0, out        # payload rides int8


def test_error_feedback_convergence(run_sub):
    """int8 + error feedback tracks the fp32 loss within 2% after 50 steps
    (the two-stage reduce-scatter+all-gather format quantises twice, so
    the per-step noise is ~2x the retired single-stage format's);
    per-step round-to-nearest (residual off) visibly drifts."""
    out = run_sub(_TOY + """
        mesh = jax.make_mesh((8,), ("pod",))
        l_fp32, _ = run(mesh, "explicit", "none", True)
        l_ef, s_ef = run(mesh, "explicit", "int8", True)
        l_rtn, _ = run(mesh, "explicit", "int8", False)
        res = jax.tree_util.tree_leaves(s_ef.residual)
        print(json.dumps({
            "fp32": l_fp32, "ef": l_ef, "rtn": l_rtn,
            "residual_nonzero": bool(max(float(jnp.max(jnp.abs(r)))
                                         for r in res) > 0)}))
    """)
    rel_ef = abs(out["ef"] - out["fp32"]) / out["fp32"]
    rel_rtn = (out["rtn"] - out["fp32"]) / out["fp32"]
    assert rel_ef < 0.02, out                  # acceptance: within 2%
    assert rel_rtn > 2 * rel_ef, out           # EF clearly beats rtn
    assert rel_rtn > 0.03, out                 # round-to-nearest drifts
    assert out["rtn"] > out["ef"], out
    assert out["residual_nonzero"], out        # EF state actually carries error


def test_trainstate_checkpoint_elastic_residual_restart(run_sub, tmp_path):
    """Full-TrainState checkpoint (incl. the per-pod residual) restores
    across an 8 -> 4 device elastic restart (pod count preserved) and
    training continues."""
    ckpt = str(tmp_path / "ck")
    out = run_sub((_TOY + """
        from repro.train.loop import Trainer

        def data():
            s = 0
            while True:
                yield batch_at(s); s += 1

        tcfg = TrainConfig(learning_rate=1e-1, warmup_steps=0,
                           total_steps=100000, weight_decay=0.0,
                           grad_clip=1e9, grad_reduce="explicit",
                           grad_compression="int8",
                           checkpoint_every=0, checkpoint_dir="__CKPT__",
                           async_checkpoint=False)

        mesh8 = jax.make_mesh((2, 4), ("pod", "data"))
        tr1 = Trainer(model, tcfg, mesh8, log_fn=lambda *_: None)
        tr1.fit(data(), n_steps=5)
        tr1.preempt()                          # sync checkpoint at step 5
        res1 = [np.asarray(r, np.float32) for r in
                jax.tree_util.tree_leaves(tr1.state.residual)]

        mesh4 = jax.make_mesh((2, 2), ("pod", "data"))
        tr2 = Trainer(model, tcfg, mesh4, log_fn=lambda *_: None)
        resumed = tr2.maybe_resume()
        res2 = [np.asarray(r, np.float32) for r in
                jax.tree_util.tree_leaves(tr2.state.residual)]
        rdiff = max(float(np.max(np.abs(a - b)))
                    for a, b in zip(res1, res2))
        ndev = len(jax.tree_util.tree_leaves(
            tr2.state.params)[0].sharding.device_set)
        hist = tr2.fit(data(), n_steps=1)
        print(json.dumps({
            "resumed": bool(resumed), "step": tr2.step,
            "residual_shapes": [list(r.shape) for r in res2],
            "residual_diff": rdiff,
            "residual_nonzero": bool(max(float(np.max(np.abs(r)))
                                         for r in res1) > 0),
            "n_devices_after": ndev,
            "loss_after": float(hist[-1].loss)}))
    """).replace("__CKPT__", ckpt))
    assert out["resumed"] and out["step"] == 6, out
    assert out["residual_diff"] == 0.0, out
    assert out["residual_nonzero"], out        # restored residual is real EF state
    assert all(s[0] == 2 for s in out["residual_shapes"]), out  # per-pod dim
    assert out["n_devices_after"] == 4, out    # genuinely elastic: 8 -> 4
    assert out["loss_after"] == out["loss_after"], out  # finite, step ran


def test_tp_fsdp_explicit_matches_pure_dp(run_sub):
    """THE tentpole acceptance: a real explicit-seam step on a PxDxM mesh
    with M>1 under FSDP, TP and TP+FSDP — all three parameter layouts
    produce the SAME optimisation as pure DP (replicated) on the same
    mesh. Specs carve the shards; the manual gather/psum seams restore
    the replicated math exactly (f32)."""
    out = run_sub("""
        from repro.configs import get_reduced
        from repro.models import build_model
        from repro.launch.specs import make_batch
        from repro.config import ShapeConfig, TrainConfig
        from repro.train.state import train_state_init
        from repro.train.step import jit_train_step
        from repro.distributed import sharding as shd
        import dataclasses

        arch = dataclasses.replace(get_reduced("granite_3_8b"),
                                   dtype=jnp.float32)
        model = build_model(arch)
        params = model.init(jax.random.PRNGKey(0))
        batch = make_batch(arch, ShapeConfig("s", 16, 8, "train"),
                           jax.random.PRNGKey(1))
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))

        final = {}
        for psh in ("replicated", "fsdp", "tp", "tp_fsdp"):
            tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=0,
                               grad_clip=1.0, grad_reduce="explicit",
                               param_sharding=psh)
            with shd.use_mesh(mesh):
                state = train_state_init(params, tcfg, mesh)
                jstep = jit_train_step(model, tcfg, mesh, state, batch,
                                       donate=False)
                for _ in range(3):
                    state, metrics = jstep(state, batch)
            final[psh] = (float(metrics["loss"]), jax.tree_util.tree_map(
                lambda a: np.asarray(a, np.float32), state.params))
        res = {}
        l0, p0 = final["replicated"]
        for psh in ("fsdp", "tp", "tp_fsdp"):
            l, p = final[psh]
            maxd = max(float(np.max(np.abs(a - b))) for a, b in zip(
                jax.tree_util.tree_leaves(p0),
                jax.tree_util.tree_leaves(p)))
            res[psh] = {"loss_diff": abs(l - l0), "max_param_diff": maxd}
        print(json.dumps(res))
    """)
    for psh in ("fsdp", "tp", "tp_fsdp"):
        assert out[psh]["loss_diff"] < 1e-4, out
        assert out[psh]["max_param_diff"] < 1e-4, out


def test_tp_parity_hybrid_ssm(run_sub):
    """Same parity property for the hybrid SSM stack (zamba2: mamba2
    mixers + shared attention blocks) — exercises the packed in_proj
    gather/slice TP layout, the SHARED B/C segments, and the psum'd
    full-width RMSNorm."""
    out = run_sub("""
        from repro.configs import get_reduced
        from repro.models import build_model
        from repro.launch.specs import make_batch
        from repro.config import ShapeConfig, TrainConfig
        from repro.train.state import train_state_init
        from repro.train.step import jit_train_step
        from repro.distributed import sharding as shd
        import dataclasses

        arch = dataclasses.replace(get_reduced("zamba2_7b"),
                                   dtype=jnp.float32)
        model = build_model(arch)
        params = model.init(jax.random.PRNGKey(0))
        batch = make_batch(arch, ShapeConfig("s", 16, 8, "train"),
                           jax.random.PRNGKey(1))
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))

        final = {}
        for psh in ("replicated", "tp_fsdp"):
            tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=0,
                               grad_clip=1.0, grad_reduce="explicit",
                               param_sharding=psh)
            with shd.use_mesh(mesh):
                state = train_state_init(params, tcfg, mesh)
                jstep = jit_train_step(model, tcfg, mesh, state, batch,
                                       donate=False)
                for _ in range(3):
                    state, metrics = jstep(state, batch)
            final[psh] = (float(metrics["loss"]), jax.tree_util.tree_map(
                lambda a: np.asarray(a, np.float32), state.params))
        l0, p0 = final["replicated"]; l1, p1 = final["tp_fsdp"]
        maxd = max(float(np.max(np.abs(a - b))) for a, b in zip(
            jax.tree_util.tree_leaves(p0), jax.tree_util.tree_leaves(p1)))
        print(json.dumps({"loss_diff": abs(l1 - l0),
                          "max_param_diff": maxd}))
    """)
    assert out["loss_diff"] < 1e-4, out
    assert out["max_param_diff"] < 1e-4, out


def test_elastic_restore_across_tp_degree(run_sub, tmp_path):
    """FSDP+int8 checkpoints are TP-degree elastic: TrainState leaves keep
    GLOBAL logical shapes in every explicit mode (only the specs change),
    so a tp_fsdp+int8 run on a (2,2,2) mesh restores bit-exact onto a
    (2,4,1) fsdp mesh and keeps training."""
    ckpt = str(tmp_path / "ck")
    out = run_sub("""
        from repro.configs import get_reduced
        from repro.models import build_model
        from repro.launch.specs import make_batch
        from repro.config import ShapeConfig, TrainConfig
        from repro.train.loop import Trainer
        from repro.distributed import sharding as shd
        import dataclasses

        arch = dataclasses.replace(get_reduced("granite_3_8b"),
                                   dtype=jnp.float32)
        model = build_model(arch)
        batch = make_batch(arch, ShapeConfig("s", 16, 8, "train"),
                           jax.random.PRNGKey(1))

        def data():
            while True:
                yield batch

        tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=0,
                           grad_clip=1.0, grad_reduce="explicit",
                           grad_compression="int8",
                           param_sharding="tp_fsdp",
                           checkpoint_every=0, checkpoint_dir="__CKPT__",
                           async_checkpoint=False)
        mesh_tp = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        tr1 = Trainer(model, tcfg, mesh_tp, log_fn=lambda *_: None)
        tr1.fit(data(), n_steps=3)
        tr1.preempt()
        p1 = [np.asarray(x, np.float32) for x in
              jax.tree_util.tree_leaves(tr1.state.params)]

        tcfg2 = dataclasses.replace(tcfg, param_sharding="fsdp")
        mesh_dp = jax.make_mesh((2, 4, 1), ("pod", "data", "model"))
        tr2 = Trainer(model, tcfg2, mesh_dp, log_fn=lambda *_: None)
        resumed = tr2.maybe_resume()
        p2 = [np.asarray(x, np.float32) for x in
              jax.tree_util.tree_leaves(tr2.state.params)]
        pdiff = max(float(np.max(np.abs(a - b))) for a, b in zip(p1, p2))
        res2 = jax.tree_util.tree_leaves(tr2.state.residual)
        hist = tr2.fit(data(), n_steps=1)
        print(json.dumps({
            "resumed": bool(resumed), "step": tr2.step,
            "param_diff": pdiff,
            "residual_restored": bool(res2),
            "loss_after": float(hist[-1].loss)}))
    """.replace("__CKPT__", ckpt))
    assert out["resumed"] and out["step"] == 4, out
    assert out["param_diff"] == 0.0, out       # bit-exact across TP degree
    assert out["residual_restored"], out
    assert out["loss_after"] == out["loss_after"], out  # finite, step ran

"""ShardingPolicy — the one public sharding surface (distributed/sharding.py)
and the unified launcher mesh grammar (launch/mesh.py::parse_mesh_spec).

Covers the deprecation aliases: every legacy spelling (TrainConfig fields,
SSMConfig.seq_shard, --mesh/--strategy strings) must construct the same
policy the native API spells directly.
"""
import dataclasses

import jax
import pytest

from repro.config import TrainConfig
from repro.distributed import sharding as shd
from repro.launch.mesh import parse_mesh_spec


def test_param_sharding_axis_assignment():
    """param_sharding is DERIVED from the axis assignment (one source of
    truth), and matches the explicit-seam mode table."""
    assert shd.ShardingPolicy().param_sharding == "replicated"
    assert shd.ShardingPolicy(
        fsdp_axes=("data", "model")).param_sharding == "fsdp"
    assert shd.ShardingPolicy(tp_axis="model").param_sharding == "tp"
    assert shd.ShardingPolicy(
        tp_axis="model", fsdp_axes=("data",)).param_sharding == "tp_fsdp"


@pytest.mark.parametrize("mode", ["replicated", "fsdp", "tp", "tp_fsdp"])
def test_train_config_round_trip(mode):
    """TrainConfig -> from_train_config -> apply_to reproduces the same
    TrainConfig fields (the deprecation alias is lossless)."""
    tcfg = TrainConfig(grad_reduce="explicit", grad_compression="int8",
                       param_sharding=mode)
    policy = shd.ShardingPolicy.from_train_config(tcfg)
    assert policy.param_sharding == mode
    assert policy.grad_reduce == "explicit"
    assert policy.grad_compression == "int8"
    tcfg2 = policy.apply_to(TrainConfig())
    assert tcfg2.grad_reduce == tcfg.grad_reduce
    assert tcfg2.grad_compression == tcfg.grad_compression
    assert tcfg2.param_sharding == tcfg.param_sharding


def test_from_legacy_covers_all_spellings():
    policy = shd.ShardingPolicy.from_legacy(
        mesh_shape=(2, 2, 2), strategy="fsdp", grad_reduce="explicit",
        grad_compression="int8", param_sharding="tp_fsdp", seq_shard=True)
    assert policy.mesh_shape == (2, 2, 2)
    assert policy.mesh_axes is None            # canonical right-aligned
    assert policy.tp_axis == "model"
    assert policy.fsdp_axes == ("data",)
    assert policy.seq_axis == "data"           # seq_shard=True -> "data"
    assert policy.strategy == "fsdp"
    with pytest.raises(ValueError, match="param_sharding"):
        shd.ShardingPolicy.from_legacy(param_sharding="zero3")


def test_from_string_grammar():
    """--policy grammar: key=value pairs; params= sets the axis assignment
    in one word; explicit tp=/fsdp=/dp= spell axes directly."""
    p = shd.ShardingPolicy.from_string(
        "params=tp_fsdp,reduce=explicit,compression=int8,seq=data")
    assert p.param_sharding == "tp_fsdp"
    assert p.grad_reduce == "explicit"
    assert p.grad_compression == "int8"
    assert p.seq_axis == "data"
    # explicit axis spelling, "+"-joined multi-axis
    p2 = shd.ShardingPolicy.from_string("tp=model,fsdp=data+model,dp=pod")
    assert p2.tp_axis == "model"
    assert p2.fsdp_axes == ("data", "model")
    assert p2.dp_axes == ("pod",)
    assert shd.ShardingPolicy.from_string(None) == shd.ShardingPolicy()
    assert shd.ShardingPolicy.from_string("") == shd.ShardingPolicy()
    with pytest.raises(ValueError, match="key=value"):
        shd.ShardingPolicy.from_string("tp_fsdp")
    with pytest.raises(ValueError, match="unknown --policy key"):
        shd.ShardingPolicy.from_string("zero=3")


def test_with_mesh_and_use_policy():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    policy = shd.ShardingPolicy.from_string("params=tp").with_mesh(mesh)
    assert policy.mesh_shape == (1, 1)
    assert policy.mesh_axes == ("data", "model")
    built = policy.build_mesh()
    assert built.axis_names == ("data", "model")
    assert shd.current_policy() is None
    with shd.use_policy(policy) as p:
        assert shd.current_policy() is p
        assert shd.current_mesh() is not None  # policy mesh installed
        assert shd.current_strategy() == p.strategy
    assert shd.current_policy() is None


def test_seq_axis_policy_fallback():
    """core/block.py blocks with no per-block seq_axis inherit the ambient
    policy's (the legacy LrcSSMConfig.seq_axis spelling wins when set)."""
    from repro.core.block import LrcSSMConfig, _with_policy_seq_axis

    cfg = LrcSSMConfig(d_input=4, d_state=4, d_hidden=8, n_classes=2)
    assert _with_policy_seq_axis(cfg).seq_axis is None
    with shd.use_policy(shd.ShardingPolicy(seq_axis="data")):
        assert _with_policy_seq_axis(cfg).seq_axis == "data"
        legacy = dataclasses.replace(cfg, seq_axis=("pod", "data"))
        assert _with_policy_seq_axis(legacy).seq_axis == ("pod", "data")


def test_parse_mesh_spec_grammar():
    """One --mesh grammar for every launcher: right-aligned canonical
    axis names, 1-3 dims."""
    m1 = parse_mesh_spec("1")
    assert m1.axis_names == ("model",)
    m2 = parse_mesh_spec("1x1")
    assert m2.axis_names == ("data", "model")
    assert dict(m2.shape) == {"data": 1, "model": 1}
    m3 = parse_mesh_spec("1x1x1")
    assert m3.axis_names == ("pod", "data", "model")
    for bad in ("", "2q", "1x1x1x1", "0x4", "-1x2"):
        with pytest.raises(ValueError):
            parse_mesh_spec(bad)


def test_policy_param_specs_modes():
    """policy.param_specs routes explicit modes through the seam's
    per-mode table and gspmd through the strategy rules."""
    from jax.sharding import PartitionSpec as P
    params = {"layers": {"attn": {"wqkv": jax.numpy.zeros((8, 24)),
                                  "wo": jax.numpy.zeros((8, 8))},
                         "norm": {"scale": jax.numpy.zeros((8,))}}}
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tp = shd.ShardingPolicy.from_legacy(param_sharding="tp",
                                        grad_reduce="explicit")
    specs = tp.param_specs(params, mesh)
    assert specs["layers"]["attn"]["wqkv"] == P(None, "model")
    # norms replicated (no mesh axis in the spec)
    assert not any(a for a in tuple(specs["layers"]["norm"]["scale"]))
    fsdp = shd.ShardingPolicy.from_legacy(param_sharding="fsdp",
                                          grad_reduce="explicit")
    fspecs = fsdp.param_specs(params, mesh)
    # fsdp shards exactly one dim of each big leaf over the full chip grid
    assert tuple(fspecs["layers"]["attn"]["wqkv"]).count(
        ("data", "model")) == 1


def test_trainer_requires_mesh_or_policy_mesh():
    from repro.train.loop import Trainer
    with pytest.raises(ValueError, match="mesh"):
        Trainer(None, TrainConfig(), mesh=None)

"""Quantized end-to-end inference: the differential-testing harness.

Locks down ``distributed/precision.py`` (PrecisionPolicy / QTensor) and its
three integration seams — serve weights, the quantized StateCache, and the
lrc_deer kernel's narrow HBM streams — with three kinds of evidence:

**Differential decode parity** (quantized engine vs fp32 engine on the
SAME randomized prompts, three mixer families: lrc / dense-attention /
sliding-window). The metric is the mean MATCHED-PREFIX fraction of the
greedy continuations. Random-init reduced models are the WORST CASE for
token agreement — logit gaps are pure noise, so any perturbation flips
argmaxes that a trained checkpoint's margins would absorb; the bars below
sit ~2x under what that worst case measures (calibrated on this seed
grid, jax 0.4.37 CPU):

    int8 preset vs fp32:            measured .77/.83/.81 -> bar 0.45
    cache=fp8 (fp32 weights) vs fp32: measured .79/.54/.46 -> bar 0.25
    fp8 preset vs ROUNDTRIPPED-weight fp32 reference (isolates cache +
    kernel-stream error from weight error; lrc only): .54 -> bar 0.30

**Exactness invariants** — these are equality assertions, not tolerances:

  * quantized-cache eviction round-trip: evict + re-admit (state
    re-derived by prefill over prompt+generated) continues with the SAME
    tokens as the uninterrupted quantized run. Holds because the engine
    injects tick-aligned state quantization (``SSMConfig.state_quant``) so
    prefill and decode walk ONE storage-grid trajectory, and the RTN grid
    is idempotent (re-encoding a dequantized tensor reproduces the payload
    bit-for-bit). Requires ``prefill_chunk <= deer_iters`` (DEER positions
    <= i are exact after i Newton iterations).
  * speculative decode on a quantized cache is LOSSLESS VS ITS OWN
    PRECISION: token-identical to quantized greedy decode (the verify
    window's DEER solve walks the same tick-quantised trajectory).

**Properties** (hypothesis; fixed-seed-grid fallback when absent):
int8 round-trip error <= per-block amax/254 for any shape/block; an
outlier coordinate perturbs ONLY its own block's scale (block isolation);
the two-stage rsag wire format's error-feedback residuals reconstruct the
mean-reduction error exactly (conservation across steps).

Kernel io_dtype bars (T=64, D=128, K=8, interpret): bf16 streams measured
max-err ~0.016 -> bar 0.06; fp8 ~0.247 -> bar 0.4 (docs/precision.md).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # optional dep absent: fixed-seed-grid fallback
    from _hypothesis_compat import given, settings, strategies as st

from repro.config import SSMConfig
from repro.configs import get_reduced
from repro.distributed.precision import (PrecisionPolicy, QTensor,
                                         dequantize_leaf, dequantize_tree,
                                         is_quantized, quantize_leaf,
                                         quantize_params,
                                         quantize_roundtrip_rows,
                                         tree_state_bytes)
from repro.models import build_model


def _f32(name):
    return dataclasses.replace(get_reduced(name), dtype=jnp.float32)


def _family_arch(fam):
    if fam == "lrc":
        return dataclasses.replace(
            _f32("falcon_mamba_7b"),
            ssm=SSMConfig(kind="lrc", expand=2, deer_iters=8, chunk=0,
                          draft_iters=2))
    if fam == "dense":
        return _f32("granite_3_8b")
    return _f32("gemma3_4b")    # sliding-window attention


@pytest.fixture(scope="module", params=["lrc", "dense", "windowed"])
def family_model(request):
    arch = _family_arch(request.param)
    model = build_model(arch)
    params = model.init(jax.random.PRNGKey(0))
    return request.param, arch, model, params


@pytest.fixture(scope="module")
def lrc_model():
    arch = _family_arch("lrc")
    model = build_model(arch)
    params = model.init(jax.random.PRNGKey(0))
    return arch, model, params


def _serve(model, params, prompts, max_new, precision, spec=None, slots=4):
    from repro.serve.engine import Request, ServeEngine
    eng = ServeEngine(model, params, batch_slots=slots, max_seq=64,
                      prefill_chunk=8, precision=precision, spec=spec)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    assert all(r.done for r in reqs)
    return [r.out_tokens for r in reqs], eng


def _prompts(arch, n=4, length=12, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, arch.vocab, size=length).astype(np.int32)
            for _ in range(n)]


def _prefix_agreement(ref, got):
    """Mean matched-prefix fraction of greedy continuations."""
    fr = 0.0
    for a, b in zip(ref, got):
        m = next((i for i, (x, y) in enumerate(zip(a, b)) if x != y),
                 len(a))
        fr += m / len(a)
    return fr / len(ref)


# ---------------------------------------------------------------------------
# PrecisionPolicy grammar
# ---------------------------------------------------------------------------

def test_policy_presets_and_grammar():
    """Presets set all three dtype groups coherently; key=value overrides
    parse ints for block knobs; junk raises."""
    p = PrecisionPolicy.from_string("fp32")
    assert not p.quantizes_weights and not p.quantizes_cache
    assert p.kernel_io_dtype is None

    p = PrecisionPolicy.from_string("int8")
    assert (p.weights, p.cache, p.kernel_io) == ("int8", "int8", "bf16")
    p = PrecisionPolicy.from_string("fp8")
    assert (p.weights, p.cache, p.kernel_io) == ("fp8", "fp8", "fp8")
    assert p.accum == "fp32"     # accumulation NEVER narrows by preset

    p = PrecisionPolicy.from_string(
        "weights=int8,cache=fp8,kernel_io=bf16,block=128,"
        "min_weight_elems=64")
    assert p.block == 128 and p.min_weight_elems == 64
    assert p.cache == "fp8" and p.quantizes_cache

    with pytest.raises(ValueError):
        PrecisionPolicy.from_string("weights=int4")
    with pytest.raises(ValueError):
        PrecisionPolicy.from_string("bogus_key=1")
    with pytest.raises(ValueError):
        PrecisionPolicy.from_string("notapreset")
    with pytest.raises(ValueError):
        PrecisionPolicy(kernel_io="int8")   # no int8 solver stream format


# ---------------------------------------------------------------------------
# QTensor leaf codec
# ---------------------------------------------------------------------------

def test_rtn_grid_idempotent():
    """Re-encoding a dequantized tensor reproduces the int8 payload
    bit-for-bit — the eviction round-trip's foundation."""
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 3, 100)) * 5.0
    q1 = quantize_leaf(x, "int8", 32, lead=2)
    x1 = dequantize_leaf(q1)
    q2 = quantize_leaf(x1, "int8", 32, lead=2)
    np.testing.assert_array_equal(np.asarray(q1.q), np.asarray(q2.q))
    np.testing.assert_array_equal(np.asarray(dequantize_leaf(q2)),
                                  np.asarray(x1))


def test_qtensor_pytree_jit_and_donation():
    """QTensor trees cross jit boundaries (registered pytree) and can be
    donated — the resident-cache contract."""
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 64))
    qt = quantize_leaf(x, "int8", 32, lead=1)
    assert is_quantized(qt) and qt.shape == x.shape

    leaves = jax.tree_util.tree_leaves({"a": qt})
    assert len(leaves) == 2      # payload + scales

    @jax.jit
    def bump(t):
        return QTensor(t.q, t.scale * 2.0, t.mode, t.odtype, t.lead,
                       t.block)
    out = jax.jit(bump, donate_argnums=(0,))(qt)
    assert is_quantized(out)
    np.testing.assert_allclose(np.asarray(out.scale),
                               np.asarray(quantize_leaf(
                                   x, "int8", 32, lead=1).scale) * 2.0,
                               rtol=1e-6)


def test_tree_state_bytes_capacity_ratio():
    """fp8 slot state is EXACTLY 4x smaller than fp32 (plain 1-byte cast,
    no scales); int8 pays f32 block scales on top. Int leaves (pos) are
    excluded from both sides."""
    tree = {"s": jnp.zeros((4, 8, 1024), jnp.float32),
            "pos": jnp.zeros((8,), jnp.int32)}
    fp32_b = tree_state_bytes(tree)
    assert fp32_b == 4 * 8 * 1024 * 4

    pol8 = PrecisionPolicy.from_string("fp8")
    q = {"s": quantize_leaf(tree["s"], "fp8", pol8.block, lead=2),
         "pos": tree["pos"]}
    assert fp32_b / tree_state_bytes(q) == 4.0

    poli = PrecisionPolicy.from_string("int8")
    qi = {"s": quantize_leaf(tree["s"], "int8", poli.block, lead=2),
          "pos": tree["pos"]}
    ratio = fp32_b / tree_state_bytes(qi)
    assert 3.5 < ratio < 4.0     # 1/(1/4 + 4/(4*256)) ~ 3.94


def test_straight_through_gradient_is_identity():
    """quantize_roundtrip_rows carries an identity JVP — DEER Newton keeps
    the true cell Jacobian through tick-aligned state quantization."""
    x = jax.random.normal(jax.random.PRNGKey(2), (8,))
    g = jax.grad(lambda v: jnp.sum(quantize_roundtrip_rows(
        v, "int8", 256)))(x)
    np.testing.assert_allclose(np.asarray(g), np.ones(8), rtol=0, atol=0)


def test_weight_quantization_skips_small_leaves():
    """Leaves under min_weight_elems (norm scales, biases) keep their
    dtype; big >=2-D weights become QTensors."""
    params = {"w": jnp.ones((64, 64)), "scale": jnp.ones((16,)),
              "b": jnp.ones((4, 4))}
    pol = PrecisionPolicy.from_string("int8")
    qp = quantize_params(params, pol)
    assert is_quantized(qp["w"])
    assert not is_quantized(qp["scale"]) and not is_quantized(qp["b"])
    back = dequantize_tree(qp)
    assert back["w"].dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(back["w"]), 1.0, rtol=1e-2)


# ---------------------------------------------------------------------------
# kernel HBM streams (interpret mode) + autotune bytes model
# ---------------------------------------------------------------------------

def _kernel_problem(t=64, d=128):
    from repro.kernels.lrc_deer.ops import PACK_ORDER
    ks = jax.random.split(jax.random.PRNGKey(0), len(PACK_ORDER) + 2)
    rows = []
    for i, name in enumerate(PACK_ORDER):
        if name == "g_leak":
            rows.append(jnp.full((d,), 0.1))
        elif name == "e_leak":
            rows.append(jnp.ones((d,)))
        elif name.startswith(("b_", "v_")):
            rows.append(jnp.zeros((d,)))
        else:
            rows.append(jax.random.normal(ks[i], (d,)) * 0.5)
    su = jax.nn.sigmoid(jax.random.normal(ks[-2], (t, d)))
    eu = jax.random.normal(ks[-1], (t, d))
    return su, eu, jnp.stack(rows), jnp.zeros((d,))


@pytest.mark.parametrize("io_dtype,bar", [("bf16", 0.06), ("fp8", 0.4)])
def test_kernel_io_dtype_parity(io_dtype, bar):
    """Narrow HBM streams with fp32 VMEM accumulation stay within the
    documented error bars vs the fp32 solve (measured ~0.016 bf16 /
    ~0.247 fp8 at this shape — docs/precision.md)."""
    from repro.kernels.lrc_deer.ops import lrc_deer_solve
    su, eu, pp, x0 = _kernel_problem()
    kw = dict(n_iters=8, chunk=32, d_tile=128, megakernel=True,
              interpret=True)
    want = lrc_deer_solve(su, eu, pp, x0, **kw)
    got = lrc_deer_solve(su, eu, pp, x0, io_dtype=io_dtype, **kw)
    assert got.dtype == jnp.float32      # output re-widens
    err = float(jnp.max(jnp.abs(got - want)))
    assert err < bar, f"{io_dtype} stream error {err} above bar {bar}"
    assert err > 0.0                     # the narrow path actually ran


def test_autotune_vmem_model_tracks_io_bytes():
    """The VMEM budget model scales its pipeline term with the stream
    element width, the tiling cache keys narrow configs separately, and
    solver_hbm_bytes = streams x bytes/elem."""
    from repro.kernels import autotune

    full = autotune.megakernel_vmem_bytes(512, 256, 8, io_bytes=4)
    half = autotune.megakernel_vmem_bytes(512, 256, 8, io_bytes=2)
    assert half < full
    # only the 6-buffer pipeline term narrows; scratch/params stay f32
    assert full - half == 6 * 512 * 256 * 2

    assert (autotune._cache_key("cpu", 1024, 128, 8)
            != autotune._cache_key("cpu", 1024, 128, 8, io_bytes=2))
    assert autotune._cache_key("cpu", 1024, 128, 8) == \
        autotune._cache_key("cpu", 1024, 128, 8, io_bytes=4)

    for kind in ("lax", "fused_iter", "mega"):
        assert autotune.solver_hbm_bytes(8, kind, 2) == \
            autotune.solver_hbm_streams(8, kind) * 2.0


# ---------------------------------------------------------------------------
# differential decode harness (quantized vs fp32, three mixer families)
# ---------------------------------------------------------------------------

def test_quantized_decode_parity_int8(family_model):
    """int8 preset (weights+cache+bf16 streams) vs fp32: matched-prefix
    fraction >= 0.45 on every family (measured .77/.83/.81 — see module
    docstring for the worst-case rationale)."""
    fam, arch, model, params = family_model
    prompts = _prompts(arch)
    ref, _ = _serve(model, params, prompts, 12, None)
    got, eng = _serve(model, params, prompts, 12, "int8")
    agree = _prefix_agreement(ref, got)
    assert agree >= 0.45, f"{fam}: int8 prefix agreement {agree:.3f}"
    # the engine really is quantized: resident state is narrow
    fp32_bytes = tree_state_bytes(
        _serve(model, params, prompts[:1], 1, None)[1].cache.cache)
    assert eng.state_cache_bytes() < fp32_bytes / 3


def test_quantized_cache_fp8_parity(family_model):
    """cache=fp8 with fp32 weights — isolates the StateCache quantization
    path: matched-prefix fraction >= 0.25 on every family (measured
    .79/.54/.46)."""
    fam, arch, model, params = family_model
    prompts = _prompts(arch)
    ref, _ = _serve(model, params, prompts, 12, None)
    got, _ = _serve(model, params, prompts, 12, "weights=fp32,cache=fp8")
    agree = _prefix_agreement(ref, got)
    assert agree >= 0.25, f"{fam}: fp8-cache prefix agreement {agree:.3f}"


def test_fp8_engine_vs_roundtripped_weights(lrc_model):
    """fp8 preset vs an fp32 engine running the ROUNDTRIPPED weights:
    isolates cache + kernel-stream error from weight-quantization error
    (the component this PR adds). Bar 0.30, measured 0.54 on lrc."""
    arch, model, params = lrc_model
    pol = PrecisionPolicy.from_string("fp8")
    p_rt = dequantize_tree(quantize_params(params, pol))
    prompts = _prompts(arch)
    ref, _ = _serve(model, p_rt, prompts, 12, None)
    got, _ = _serve(model, params, prompts, 12, "fp8")
    agree = _prefix_agreement(ref, got)
    assert agree >= 0.30, f"fp8 vs roundtripped-weights {agree:.3f}"


# ---------------------------------------------------------------------------
# exactness: eviction round-trip & speculative losslessness (quantized lrc)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["int8", "fp8"])
def test_quantized_eviction_roundtrip_exact(lrc_model, mode):
    """Evict + re-admit on a quantized cache continues with EXACTLY the
    uninterrupted quantized run's tokens: tick-aligned state quantization
    + idempotent RTN grid + prefill_chunk <= deer_iters make the
    re-derived slot state bit-compatible."""
    arch, model, params = lrc_model
    from repro.serve.engine import Request, ServeEngine
    assert 8 <= arch.ssm.deer_iters    # prefill_chunk=8 precondition

    def run(evict_after):
        eng = ServeEngine(model, params, batch_slots=1, max_seq=48,
                          prefill_chunk=8, precision=mode)
        req = Request(uid=0, prompt=np.arange(5, dtype=np.int32) + 3,
                      max_new_tokens=8)
        eng.submit(req)
        for _ in range(60):
            if req.done:
                break
            eng.step()
            if (evict_after is not None and not req.done
                    and len(req.out_tokens) == evict_after
                    and eng.active[0] is req):
                eng.evict(0)
        assert req.done
        return req.out_tokens

    base = run(None)
    assert run(4) == base
    assert run(1) == base


@pytest.mark.parametrize("mode", ["int8", "fp8"])
@pytest.mark.parametrize("draft", ["solve", "reuse"])
def test_spec_decode_lossless_vs_quantized_greedy(lrc_model, mode, draft):
    """Speculative decode on a quantized cache is token-identical to the
    SAME-precision greedy decode — losslessness vs its own precision,
    not vs fp32: the verify window's DEER solve walks the identical
    tick-quantised state trajectory the greedy tick walks."""
    arch, model, params = lrc_model
    from repro.serve.engine import SpecConfig
    prompts = _prompts(arch, n=2, seed=3)
    greedy, _ = _serve(model, params, prompts, 10, mode, slots=2)
    spec, eng = _serve(model, params, prompts, 10, mode, slots=2,
                       spec=SpecConfig(k=4, draft=draft, draft_iters=2))
    assert spec == greedy
    assert eng.spec_stats["verify_calls"] > 0       # spec actually engaged
    if draft == "solve":
        # the model's own refined drafts must land sometimes; "reuse"
        # leftovers may legitimately all reject under heavy quantization
        assert eng.spec_stats["accepted_tokens"] > 0


def test_quantized_rejects_mesh_and_non_lrc_spec():
    """Guard rails: a quantized policy composes with neither a mesh
    (no sharding specs for QTensor trees) nor speculative decoding on a
    non-pure-lrc family (attention verify reads full-precision in-window
    keys)."""
    from repro.serve.decode import _check_mesh
    from repro.serve.engine import ServeEngine, SpecConfig
    pol = PrecisionPolicy.from_string("int8")
    with pytest.raises(ValueError, match="mesh"):
        _check_mesh(pol, object())
    _check_mesh(None, object())                      # fp32 + mesh is fine
    _check_mesh(pol, None)                           # quantized, no mesh

    arch = _f32("gemma3_4b")
    model = build_model(arch)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="quantized"):
        ServeEngine(model, params, batch_slots=2, max_seq=64,
                    prefill_chunk=8, precision="int8",
                    spec=SpecConfig(k=2, draft="reuse"))


# ---------------------------------------------------------------------------
# properties
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16), rows=st.integers(1, 6),
       n=st.integers(1, 300), block=st.integers(1, 64),
       scale=st.floats(0.01, 100.0))
def test_int8_roundtrip_error_bound(seed, rows, n, block, scale):
    """|x - deq(quant(x))| <= per-block amax/254 (+eps) for ANY shape,
    block size, and dynamic range — half the RTN grid pitch."""
    x = np.asarray(jax.random.normal(
        jax.random.PRNGKey(seed), (rows, n))) * scale
    qt = quantize_leaf(jnp.asarray(x), "int8", block, lead=1)
    err = np.abs(np.asarray(dequantize_leaf(qt)) - x)
    bs = max(1, min(block, n))
    nb = -(-n // bs)
    pad = np.pad(np.abs(x), ((0, 0), (0, nb * bs - n)))
    amax = pad.reshape(rows, nb, bs).max(axis=2)
    bound = np.repeat(amax / 254.0, bs, axis=1)[:, :n]
    assert np.all(err <= bound + 1e-6 + 1e-6 * np.abs(x))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16), out_mag=st.floats(10.0, 1e4))
def test_outlier_block_scale_isolation(seed, out_mag):
    """An outlier coordinate inflates ONLY its own block's scale: every
    other block's payload and scale are bit-identical to the
    outlier-free encoding — per-block scales contain the damage."""
    rows, n, block = 2, 256, 64
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(seed), (rows, n)))
    y = x.copy()
    y[0, 10] = out_mag                   # outlier in row 0, block 0
    qx = quantize_leaf(jnp.asarray(x), "int8", block, lead=1)
    qy = quantize_leaf(jnp.asarray(y), "int8", block, lead=1)
    # scales live on (..., n_blocks); block 0 of row 0 moved, rest did not
    sx, sy = np.asarray(qx.scale), np.asarray(qy.scale)
    assert sy[0, 0] > sx[0, 0]
    np.testing.assert_array_equal(sx[0, 1:], sy[0, 1:])
    np.testing.assert_array_equal(sx[1], sy[1])
    np.testing.assert_array_equal(np.asarray(qx.q)[:, block:],
                                  np.asarray(qy.q)[:, block:])
    np.testing.assert_array_equal(np.asarray(qx.q)[1], np.asarray(qy.q)[1])


def test_error_feedback_conservation_rsag(run_sub):
    """The two-stage (reduce-scatter + all-gather) int8 wire format's
    error feedback is EXACT: over a seed grid, mean(g1) + mean(g2) ==
    r1 + r2 + sum_p(residual2_p)/P to float-sum tolerance — no signal is
    created or destroyed across steps, it only moves between the wire
    and the residual state."""
    out = run_sub("""
from repro.distributed.compression import compressed_psum
P = 8
worst = 0.0
for seed in range(5):
    rng = np.random.default_rng(seed)
    g1 = jnp.asarray(rng.normal(size=(P, 40)) * (10.0 ** (seed - 2)))
    g2 = jnp.asarray(rng.normal(size=(P, 40)) * (10.0 ** (seed - 2)))
    step1 = jax.pmap(lambda g: compressed_psum({"g": g}, "pod"),
                     axis_name="pod")
    r1, e1 = step1(g1)
    step2 = jax.pmap(lambda g, e: compressed_psum({"g": g}, "pod",
                                                  error_state=e),
                     axis_name="pod")
    r2, e2 = step2(g2, e1)
    lhs = np.asarray(g1.mean(0) + g2.mean(0))
    rhs = np.asarray(r1["g"][0] + r2["g"][0] + e2["g"].sum(0) / P)
    scale = max(1e-9, float(np.abs(lhs).max()))
    worst = max(worst, float(np.abs(lhs - rhs).max()) / scale)
print(json.dumps({"worst_rel": worst}))
""")
    assert out["worst_rel"] < 1e-5, out
